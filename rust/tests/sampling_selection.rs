//! Property tests for the trust-weighted sampling pre-stage's selection
//! function: the commitment-seeded draw must be (a) byte-identical across
//! replays — any auditor holding the revealed secret reproduces exactly
//! the validator's audit set — and (b) unpredictable without the secret —
//! a worker enumerating guesses does no better than chance at telling
//! which of its uploads will be spot-checked. Engine-free; runs in CI
//! without model artifacts.

use intellect2::coordinator::validation::{SamplerConfig, ValidatorCommitment};
use intellect2::protocol::{Ledger, TrustState};
use sha2::{Digest, Sha256};

/// A small deterministic identity grid: (step, node, submission_idx)
/// triples spanning several steps, nodes and per-step upload indices.
fn identity_grid() -> Vec<(u64, u64, u64)> {
    let mut ids = Vec::new();
    for step in 0..40u64 {
        for node in 0..10u64 {
            for idx in 0..5u64 {
                ids.push((step, node.wrapping_mul(0x9E37_79B9).rotate_left(7), idx));
            }
        }
    }
    ids
}

#[test]
fn selection_is_byte_identical_across_replays() {
    let secret = 0xA11CE_u64;
    // Two independently-constructed commitments from the same revealed
    // secret: every draw must match to the bit, and therefore every
    // select decision at every rate.
    let a = ValidatorCommitment::new(secret);
    let b = ValidatorCommitment::new(a.reveal());
    for &(step, node, idx) in &identity_grid() {
        let da = a.draw(step, node, idx);
        let db = b.draw(step, node, idx);
        assert_eq!(da.to_bits(), db.to_bits(), "draw diverged at ({step},{node},{idx})");
        for rate in [0.0, 0.05, 0.1, 0.25, 0.5, 0.99, 1.0, 2.0] {
            assert_eq!(
                a.selects(step, node, idx, rate),
                b.selects(step, node, idx, rate),
                "selects diverged at ({step},{node},{idx}) rate {rate}"
            );
        }
        // Draws live in [0, 1): p >= 1 must select unconditionally.
        assert!((0.0..1.0).contains(&da));
        assert!(a.selects(step, node, idx, 1.0));
    }
}

#[test]
fn commitment_binds_the_secret() {
    let c = ValidatorCommitment::new(0xC0FFEE);
    // The published commitment is exactly the hash of the later reveal,
    // so workers can verify the validator did not re-roll its secret
    // after seeing the uploads.
    let expect: [u8; 32] = Sha256::digest(c.reveal().to_le_bytes()).into();
    assert_eq!(c.commitment(), expect);
    // And it actually binds: a different secret commits differently.
    assert_ne!(c.commitment(), ValidatorCommitment::new(0xC0FFEF).commitment());
}

#[test]
fn selection_is_chance_level_without_the_secret() {
    let truth = ValidatorCommitment::new(0x5EC2E7);
    let ids = identity_grid();
    let rate = 0.25f64;
    // Chance agreement between two independent Bernoulli(p) streams:
    // p^2 + (1-p)^2. A guesser that recovered any structure would beat
    // this; one that did not sits inside the sampling noise around it.
    let chance = rate * rate + (1.0 - rate) * (1.0 - rate);
    for guess_seed in [0u64, 1, 42, 0x5EC2E6, 0x5EC2E8, u64::MAX] {
        let guess = ValidatorCommitment::new(guess_seed);
        let agree = ids
            .iter()
            .filter(|&&(s, n, i)| guess.selects(s, n, i, rate) == truth.selects(s, n, i, rate))
            .count() as f64
            / ids.len() as f64;
        // 2000 trials: 4 sigma is ~0.043; allow 0.06 for slack.
        assert!(
            (agree - chance).abs() < 0.06,
            "wrong-secret {guess_seed:#x} agreement {agree:.3} not chance-level ({chance:.3})"
        );
        // In particular, no wrong secret reproduces the audit set.
        assert!(agree < 1.0);
    }
    // Neighbouring identities under the TRUE secret are also decorrelated:
    // knowing your previous upload was audited says nothing about the
    // next one (selection is per-(step, node, idx), not per-node-sticky).
    let selected = ids.iter().filter(|&&(s, n, i)| truth.selects(s, n, i, rate)).count() as f64
        / ids.len() as f64;
    assert!((selected - rate).abs() < 0.05, "selection share {selected:.3} far from {rate}");
}

#[test]
fn trust_lifecycle_decay_promotion_and_re_escalation() {
    let ledger = Ledger::default();
    let (pool, node) = (1u64, 7u64);
    let cfg = SamplerConfig { sampling_rate: 0.1, promotion_streak: 4 };
    let p = |t: TrustState| t.verify_probability(cfg.sampling_rate, cfg.promotion_streak);

    // New node: full verification until the streak *passes* promotion
    // (at exactly the promotion streak, promo/streak is still 1.0).
    for _ in 0..=cfg.promotion_streak {
        assert_eq!(p(ledger.trust(pool, node)), 1.0);
        ledger.record_verification(pool, node, true);
    }
    // Past promotion the probability decays monotonically toward the
    // floor and never dips below it.
    let mut prev = p(ledger.trust(pool, node));
    assert!(prev < 1.0, "no decay after {} clean records", cfg.promotion_streak + 1);
    for _ in 0..200 {
        ledger.record_verification(pool, node, true);
        let cur = p(ledger.trust(pool, node));
        assert!(cur <= prev && cur >= cfg.sampling_rate, "decay not monotone: {prev} -> {cur}");
        prev = cur;
    }
    assert_eq!(prev, cfg.sampling_rate, "long clean streak should reach the floor");

    // One reject re-escalates to full verification immediately, no matter
    // how much history the node had banked.
    ledger.record_verification(pool, node, false);
    let t = ledger.trust(pool, node);
    assert_eq!(t.clean_streak, 0);
    assert_eq!(t.rejects, 1);
    assert_eq!(p(t), 1.0);
    // And the node must re-earn the whole streak (plus one) to see a
    // sub-1.0 probability again.
    for _ in 0..=cfg.promotion_streak {
        assert_eq!(p(ledger.trust(pool, node)), 1.0);
        ledger.record_verification(pool, node, true);
    }
    assert!(p(ledger.trust(pool, node)) < 1.0);
}
