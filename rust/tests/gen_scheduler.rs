//! Continuous-batching scheduler: equivalence, refill and determinism
//! properties, engine-free over the deterministic [`MockBackend`] (the
//! vendored xla stub gates device ops, so these must not need artifacts) —
//! plus an artifact-gated end-to-end check on the real engine.
//!
//! The load-bearing claim (§2.3.3): a rollout's observable bytes — tokens,
//! sampled_probs, commit-grid hidden rows, finish reason — are functions
//! of its prompt and its `(gen_seed, rollout_index)` RNG stream only,
//! never of lane assignment, lane count, co-tenants or scheduling path.

use intellect2::runtime::scheduler::{
    rollout_rng, run_continuous, run_static_reference, DecodeBackend, GenRequest, GenStats,
    MockBackend, SchedSpec,
};
use intellect2::runtime::{GenOpts, Generation};
use intellect2::util::rng::Rng;

fn spec(lanes: usize, max_seq: usize) -> SchedSpec {
    SchedSpec { lanes, max_seq, vocab: 32, d_model: 12, pad_id: 0, bos_id: 1, eos_id: 2 }
}

/// Random GRPO-shaped workload: tasks x group_size, mixed prompt lengths.
fn workload(sp: &SchedSpec, n_tasks: usize, group_size: usize, seed: u64) -> Vec<GenRequest> {
    let mut r = Rng::new(seed);
    let mut reqs = Vec::new();
    for task in 0..n_tasks {
        let len = 1 + r.usize((sp.max_seq - 2).min(40));
        let mut prompt = vec![sp.bos_id];
        prompt.extend((1..len).map(|_| 3 + r.usize(sp.vocab - 3) as i32));
        for g in 0..group_size {
            reqs.push(GenRequest {
                prompt: prompt.clone(),
                rng: rollout_rng(seed ^ 0x5EED, (task * group_size + g) as u64),
                prompt_key: task as u64,
            });
        }
    }
    reqs
}

fn assert_same(a: &[Generation], b: &[Generation], ctx: &str) {
    assert_eq!(a.len(), b.len(), "{ctx}: length");
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        assert_eq!(x.tokens, y.tokens, "{ctx}: tokens of rollout {i}");
        assert_eq!(x.sampled_probs, y.sampled_probs, "{ctx}: probs of rollout {i}");
        assert_eq!(x.hidden_rows, y.hidden_rows, "{ctx}: hidden rows of rollout {i}");
        assert_eq!(x.finish, y.finish, "{ctx}: finish of rollout {i}");
        assert_eq!(x.prompt_len, y.prompt_len, "{ctx}: prompt_len of rollout {i}");
    }
}

/// Property: continuous ≡ static reference, byte for byte, across random
/// prompt lengths, EOS patterns (eos_bias sweep), group sizes, lane
/// counts, and commit intervals.
#[test]
fn continuous_equals_static_reference_property() {
    for seed in 0..12u64 {
        let mut r = Rng::new(0xBEEF ^ seed);
        let sp = spec(2 + r.usize(7), 48 + r.usize(3) * 32);
        let n_tasks = 1 + r.usize(6);
        let group_size = 1 + r.usize(4);
        let eos_bias = [0.0f32, 0.05, 0.2, 1.0][r.usize(4)];
        let opts = GenOpts {
            max_new: 1 + r.usize(40),
            temperature: 0.5 + r.f32(),
            commit_interval: [4, 8, 32][r.usize(3)],
        };
        let reqs = workload(&sp, n_tasks, group_size, seed);
        let buckets = MockBackend::default_buckets(sp.max_seq);
        let mut st = GenStats::default();
        let mut ct = GenStats::default();
        let a = run_static_reference(
            &mut MockBackend::new(sp, buckets.clone(), eos_bias),
            &reqs,
            &opts,
            &mut st,
        )
        .unwrap();
        let b = run_continuous(
            &mut MockBackend::new(sp, buckets, eos_bias),
            &reqs,
            &opts,
            &mut ct,
        )
        .unwrap();
        assert_same(&a, &b, &format!("seed {seed}"));
        // The continuous path never does more decode work.
        assert!(
            ct.decode_steps <= st.decode_steps,
            "seed {seed}: {} continuous vs {} static decode steps",
            ct.decode_steps,
            st.decode_steps
        );
    }
}

/// Property: outputs are invariant to lane count and to prefill support —
/// the same requests produce identical bytes on 2 lanes, 7 lanes, and
/// with prompts fed token-by-token (no prefill_kv artifacts).
#[test]
fn outputs_invariant_to_lane_count_and_prefill_support() {
    for seed in 0..6u64 {
        let max_seq = 96;
        let opts = GenOpts { max_new: 24, temperature: 1.0, commit_interval: 8 };
        let mut outs: Vec<Vec<Generation>> = Vec::new();
        for lanes in [2usize, 7] {
            let sp = spec(lanes, max_seq);
            let reqs = workload(&sp, 4, 3, seed);
            for buckets in [MockBackend::default_buckets(max_seq), Vec::new()] {
                let gens = run_continuous(
                    &mut MockBackend::new(sp, buckets, 0.1),
                    &reqs,
                    &opts,
                    &mut GenStats::default(),
                )
                .unwrap();
                outs.push(gens);
            }
        }
        for other in &outs[1..] {
            assert_same(&outs[0], other, &format!("seed {seed}"));
        }
    }
}

/// A retired lane is refilled the same step, and occupancy never drops
/// while prompts are pending: every decode step taken with a non-empty
/// pending queue runs with all lanes full.
#[test]
fn lanes_refill_same_step_and_occupancy_never_drops() {
    let sp = spec(2, 128);
    // Short prompts + moderate EOS pressure: every rollout survives its
    // prefill but finishes after a couple dozen tokens, so lanes retire
    // constantly while the 16-deep queue drains through 2 lanes.
    let reqs: Vec<GenRequest> = (0..16)
        .map(|i| {
            let mut prompt = vec![sp.bos_id];
            prompt.extend((0..2 + i % 4).map(|j| 3 + (i * 5 + j) % 20));
            GenRequest { prompt, rng: rollout_rng(11, i as u64), prompt_key: i as u64 }
        })
        .collect();
    let opts = GenOpts { max_new: 64, temperature: 1.0, commit_interval: 32 };
    let mut stats = GenStats::default();
    run_continuous(
        &mut MockBackend::new(sp, MockBackend::default_buckets(sp.max_seq), 0.15),
        &reqs,
        &opts,
        &mut stats,
    )
    .unwrap();
    assert!(stats.decode_steps > 0 && stats.prefill_calls > 0);
    let mut saw_pending = false;
    for &(active, pending) in &stats.occupancy {
        if pending > 0 {
            saw_pending = true;
            assert_eq!(
                active as usize, sp.lanes,
                "a lane sat idle for a decode step while {pending} prompts were pending"
            );
        }
    }
    assert!(saw_pending, "workload too small to exercise refill");
    // 16 short rollouts over 2 lanes: the queue must have been refilled
    // many times, i.e. multiple prefill waves happened.
    assert!(stats.prefill_calls >= 2, "{}", stats.prefill_calls);
}

/// Group sharing: a GRPO group's identical prompts are computed once per
/// refill wave (unique prompt forwards track tasks, not rollouts), and
/// call count stays at one per wave+bucket.
#[test]
fn group_prompts_share_prefill_forwards() {
    let sp = spec(8, 128);
    let (n_tasks, group_size) = (2usize, 4usize);
    let reqs = workload(&sp, n_tasks, group_size, 3);
    let opts = GenOpts { max_new: 16, temperature: 1.0, commit_interval: 32 };
    let mut stats = GenStats::default();
    run_continuous(
        &mut MockBackend::new(sp, MockBackend::default_buckets(sp.max_seq), 0.2),
        &reqs,
        &opts,
        &mut stats,
    )
    .unwrap();
    // All 8 rollouts fit in one wave: each task's prompt forward happens
    // once, not group_size times — and never n_prompts x group_size.
    assert_eq!(stats.prefill_prompts, n_tasks as u64, "{:?}", stats);
    assert!(stats.prefill_calls <= 2, "one call per bucket in the wave: {:?}", stats);
    assert!((stats.prefill_prompts as usize) < reqs.len());
}

/// Boundary semantics match the reference exactly: prompts at the frame
/// edge, zero-token budgets, and budgets crossing max_seq.
#[test]
fn boundary_cases_match_reference() {
    let sp = spec(3, 64);
    let cases: Vec<(usize, usize)> = vec![
        (sp.max_seq - 1, 16), // prompt at the frame edge: sample-then-stop
        (sp.max_seq - 2, 16), // one feedable position left
        (10, 0),              // zero budget: MaxLen at the frontier, no decode
        (40, 64),             // limit clamped by max_seq, hits the t-1 wall
        (1, 8),               // minimal prompt
    ];
    for (i, &(plen, max_new)) in cases.iter().enumerate() {
        let mut prompt = vec![sp.bos_id];
        prompt.extend((1..plen).map(|j| 3 + (j % 20) as i32));
        let reqs = vec![GenRequest { prompt, rng: rollout_rng(9, i as u64), prompt_key: 0 }];
        let opts = GenOpts { max_new, temperature: 1.0, commit_interval: 8 };
        let a = run_static_reference(
            &mut MockBackend::new(sp, MockBackend::default_buckets(sp.max_seq), 0.05),
            &reqs,
            &opts,
            &mut GenStats::default(),
        )
        .unwrap();
        let b = run_continuous(
            &mut MockBackend::new(sp, MockBackend::default_buckets(sp.max_seq), 0.05),
            &reqs,
            &opts,
            &mut GenStats::default(),
        )
        .unwrap();
        assert_same(&a, &b, &format!("case {i} (plen {plen}, max_new {max_new})"));
    }
}

/// The mock backend honors the prefill contract the real artifact
/// implements: masked-out lanes' caches are untouched, assigned lanes
/// continue from the installed prompt.
#[test]
fn mock_prefill_respects_lane_mask() {
    let sp = spec(4, 64);
    let mut m = MockBackend::new(sp, MockBackend::default_buckets(sp.max_seq), 0.0);
    // Lane 0 runs a live sequence...
    let (l0, _) = m.decode(&[5, 0, 0, 0], &[0, 0, 0, 0]).unwrap();
    // ...lane 2 gets a prompt prefilled; lane 0 must be unaffected.
    let prompt: Vec<i32> = vec![1, 7, 8];
    let mut assign = vec![None; sp.lanes];
    assign[2] = Some(0);
    m.prefill_kv(&[&prompt], 16, &assign).unwrap();
    let (l1, _) = m.decode(&[6, 0, 9, 0], &[1, 0, 3, 0]).unwrap();
    // Lane 0's step-1 logits depend only on its own history [5, 6].
    let mut fresh = MockBackend::new(sp, vec![], 0.0);
    let (f0, _) = fresh.decode(&[5, 0, 0, 0], &[0, 0, 0, 0]).unwrap();
    let (f1, _) = fresh.decode(&[6, 0, 0, 0], &[1, 0, 0, 0]).unwrap();
    assert_eq!(&l0[..sp.vocab], &f0[..sp.vocab]);
    assert_eq!(&l1[..sp.vocab], &f1[..sp.vocab]);
}

// ---------------------------------------------------------------------------
// Real engine (artifact-gated; self-skips like the other engine tests)

#[test]
fn real_engine_continuous_matches_static() {
    use intellect2::runtime::{EngineHost, Runtime};
    use std::sync::Arc;
    if !Runtime::artifacts_dir("nano").join("spec.json").exists() {
        eprintln!("skipping: run `make artifacts` first");
        return;
    }
    let host = EngineHost::spawn_size("nano").unwrap();
    if !host.spec().supports_continuous() {
        eprintln!("skipping: artifacts predate the continuous contract (run `make artifacts`)");
        return;
    }
    let params = Arc::new(host.init_params(5).unwrap());
    let sp = SchedSpec::from(host.spec());
    let reqs = workload(&sp, 3, 2, 21);
    let opts = GenOpts { max_new: 20, temperature: 1.0, commit_interval: 32 };
    let (a, st) = host
        .generate_streams(
            Arc::clone(&params),
            reqs.iter().map(|r| r.prompt.clone()).collect(),
            opts,
            21 ^ 0x5EED,
            0,
        )
        .unwrap();
    let reqs2: Vec<GenRequest> = reqs
        .iter()
        .enumerate()
        .map(|(i, r)| GenRequest {
            prompt: r.prompt.clone(),
            rng: rollout_rng(21 ^ 0x5EED, i as u64),
            prompt_key: r.prompt_key,
        })
        .collect();
    let (b, ct) = host.generate_continuous(params, reqs2, opts).unwrap();
    // On real kernels the prompt frontier comes from prefill_kv, whose
    // batched attention may differ from decode_step in low-order bits —
    // so tokens must agree (a flip needs a sampling near-tie landing on
    // an ulp, vanishingly unlikely here and a real bug if systematic),
    // while probs/hidden rows get the same fp tolerance the TOPLOC
    // validator runs with. Bit-exactness is enforced on the mock above.
    assert_eq!(a.len(), b.len());
    for (x, y) in a.iter().zip(&b) {
        assert_eq!(x.tokens, y.tokens);
        assert_eq!(x.finish, y.finish);
        assert_eq!(x.prompt_len, y.prompt_len);
        for (p, q) in x.sampled_probs.iter().zip(&y.sampled_probs) {
            assert!((p - q).abs() < 2e-3, "{p} vs {q}");
        }
        assert_eq!(x.hidden_rows.len(), y.hidden_rows.len());
        for ((px, rx), (py, ry)) in x.hidden_rows.iter().zip(&y.hidden_rows) {
            assert_eq!(px, py);
            for (u, w) in rx.iter().zip(ry) {
                assert!((u - w).abs() < 2e-3, "{u} vs {w}");
            }
        }
    }
    assert!(ct.prefill_calls > 0 && ct.decode_steps <= st.decode_steps);
}
