//! End-to-end integration over the full decentralized stack: protocol
//! lifecycle + SHARDCAST + TOPLOC validation + PRIME-RL training, with an
//! adversarial worker that must be caught and slashed, and the two-step
//! async pipeline's broadcast overlap measured on the real swarm.

use intellect2::config::RunConfig;
use intellect2::coordinator::Swarm;
use intellect2::runtime::Runtime;
use intellect2::tasks::dataset::EnvMix;

fn artifacts_ready() -> bool {
    Runtime::artifacts_dir("nano").join("spec.json").exists()
}

fn tiny_cfg() -> RunConfig {
    RunConfig {
        model: "nano".into(),
        rl_steps: 2,
        prompts_per_step: 2,
        group_size: 4,
        micro_steps: 1,
        max_new_tokens: 10,
        n_workers: 2,
        n_relays: 2,
        // All four registered environments in the mix: generation, TOPLOC
        // re-verification and training all dispatch through the registry,
        // so the new seq/chain envs ride the same e2e path as math/code.
        env_mix: EnvMix::of(&[("math", 30), ("code", 6), ("seq", 6), ("chain", 6)]),
        ..Default::default()
    }
}

#[test]
fn honest_swarm_trains_and_overlaps() {
    if !artifacts_ready() {
        eprintln!("skipping: run `make artifacts`");
        return;
    }
    let swarm = Swarm::new(tiny_cfg()).unwrap();
    let result = swarm.run(30, false).unwrap();
    // Both RL steps completed (micro-steps may be skipped when online
    // filtering discards every group — a valid outcome at this scale, the
    // curves are still recorded).
    assert_eq!(result.series.get("task_reward").len(), 2);
    assert!(result.final_state.step >= 30, "step={}", result.final_state.step);
    // Submissions flowed through validation.
    assert!(result.stats.submissions_accepted.get() >= 2);
    assert!(result.stats.rollouts_verified.get() >= 4);
    assert_eq!(result.stats.nodes_slashed.get(), 0);
    // SHARDCAST moved checkpoints (pretrain + 2 steps published).
    assert!(result.stats.broadcast_bytes.get() >= 3 * 120_064 * 4);
    // The ledger audit chain holds.
    assert!(result.ledger.verify_chain());
    // Per-step timings recorded, with the broadcast measured on the
    // background thread (checkpoints 1 and 2 broadcast after steps 0/1,
    // checkpoint 0 from the bootstrap).
    assert_eq!(result.step_timings.len(), 2);
    assert!(result.broadcasts.len() >= 3, "broadcasts={}", result.broadcasts.len());
    assert!(result.broadcasts.iter().any(|b| b.step == 0));
    for t in &result.step_timings {
        assert!(t.train_ended_at >= t.train_started_at);
    }
    // Staleness accounting is consistent: everything trained on appears in
    // the per-lag histogram, within the async window.
    let hist = result.stats.staleness_hist();
    let trained: u64 = hist.iter().map(|(_, n)| n).sum();
    assert!(trained > 0, "nothing recorded in the staleness histogram");
    assert!(hist.iter().all(|(lag, _)| *lag <= tiny_cfg().async_level));
    // Per-env pass rates were recorded for the envs that got verified
    // rollouts, keyed by registry names only.
    let envs: Vec<String> =
        result.stats.env_pass.snapshot().into_iter().map(|(e, _, _)| e).collect();
    assert!(!envs.is_empty(), "no per-env pass rates recorded");
    for e in &envs {
        assert!(["math", "code", "seq", "chain"].contains(&e.as_str()), "{e}");
    }
}

#[test]
fn evil_worker_is_slashed_and_excluded() {
    if !artifacts_ready() {
        eprintln!("skipping: run `make artifacts`");
        return;
    }
    let cfg = tiny_cfg();
    let swarm = Swarm::new(cfg).unwrap();
    let result = swarm.run(5, true).unwrap();
    // The reward-hacking worker's submissions were rejected and the node
    // slashed on the ledger (RewardMismatch via the validator's
    // re-verification).
    assert!(
        result.stats.submissions_rejected.get() >= 1,
        "rejected={}",
        result.stats.submissions_rejected.get()
    );
    assert!(result.stats.nodes_slashed.get() >= 1);
    // Honest training still made progress.
    assert_eq!(result.series.get("task_reward").len(), 2);
    assert!(result.ledger.verify_chain());
}

#[test]
fn evil_worker_is_slashed_under_sampling() {
    if !artifacts_ready() {
        eprintln!("skipping: run `make artifacts`");
        return;
    }
    // Spot-check rate 0.25 with instant promotion: proven-honest nodes
    // decay off full verification as fast as they can, while the cheater
    // (zero trust, then flagged) is always fully verified — sampling must
    // not change the adversarial outcome.
    let cfg = RunConfig { sampling_rate: 0.25, trust_promotion_streak: 1, ..tiny_cfg() };
    let swarm = Swarm::new(cfg).unwrap();
    let result = swarm.run(5, true).unwrap();
    assert!(
        result.stats.submissions_rejected.get() >= 1,
        "rejected={}",
        result.stats.submissions_rejected.get()
    );
    assert!(result.stats.nodes_slashed.get() >= 1);
    // The gate was armed (rate < 1.0) and fully verified at least the
    // cheater's uploads.
    assert!(result.stats.submissions_sampled_full.get() >= 1);
    // Skip-admission bookkeeping is consistent: a skipped submission's
    // claimed rewards land in the buffer and in the per-env pass table,
    // explicitly flagged as unverified.
    if result.stats.submissions_skipped_unverified.get() > 0 {
        assert!(result.stats.rollouts_admitted_unverified.get() > 0);
        let envs: Vec<String> =
            result.stats.env_pass.snapshot().into_iter().map(|(e, _, _)| e).collect();
        assert!(
            envs.iter().any(|e| e.ends_with("(unverified)")),
            "skipped submissions not flagged per-env: {envs:?}"
        );
    } else {
        assert_eq!(result.stats.rollouts_admitted_unverified.get(), 0);
    }
    // Honest training still made progress and the audit chain holds.
    assert_eq!(result.series.get("task_reward").len(), 2);
    assert!(result.ledger.verify_chain());
}

#[test]
fn broadcast_overlaps_next_training_step() {
    if !artifacts_ready() {
        eprintln!("skipping: run `make artifacts`");
        return;
    }
    // Shape the origin uplink so each relay mirror takes seconds (like the
    // paper's WAN links): a nano checkpoint is ~480 KB, so 150 KB/s makes
    // the mirror ~3 s while workers keep the verified buffer warm over
    // loopback. If the trainer still blocked on relay mirroring (the old
    // synchronous behavior), training of step 1 could not start before the
    // broadcast of step 0's checkpoint completed.
    let cfg = RunConfig {
        origin_egress_bps: 150_000,
        broadcast_timeout_secs: 30,
        ..tiny_cfg()
    };
    let swarm = Swarm::new(cfg).unwrap();
    let result = swarm.run(30, false).unwrap();
    assert_eq!(result.step_timings.len(), 2);
    let t1 = &result.step_timings[1];
    let b1 = result
        .broadcasts
        .iter()
        .find(|b| b.step == 1)
        .expect("checkpoint 1 broadcast record");
    assert!(
        t1.train_started_at < b1.completed_at,
        "training of step 1 started at {:.2}s, after the broadcast of step 0's \
         checkpoint completed at {:.2}s — the pipeline is not overlapping",
        t1.train_started_at,
        b1.completed_at
    );
    // The measured overlap is visible in the result-level accounting too.
    let overlap = result.broadcast_overlap();
    assert!(
        overlap.iter().any(|(_, secs)| *secs > 0.0),
        "no broadcast/train overlap measured: {overlap:?}"
    );
}

#[test]
fn stale_rollouts_are_dropped_not_trained() {
    if !artifacts_ready() {
        eprintln!("skipping: run `make artifacts`");
        return;
    }
    // async_level = 0 makes every rollout from the previous version stale
    // the moment the trainer advances; with the broadcast shaped to take
    // seconds, workers keep submitting version-0 rollouts while the
    // trainer is already on step 1, and those must be dropped + counted
    // rather than trained on.
    let cfg = RunConfig {
        async_level: 0,
        origin_egress_bps: 150_000,
        broadcast_timeout_secs: 30,
        ..tiny_cfg()
    };
    let swarm = Swarm::new(cfg).unwrap();
    let result = swarm.run(30, false).unwrap();
    assert_eq!(result.series.get("task_reward").len(), 2);
    // Nothing with lag > 0 was ever trained on.
    assert!(result.stats.staleness_hist().iter().all(|(lag, _)| *lag == 0));
    // The stale flow was exercised and counted (buffer evictions, stale
    // submissions, or push-time drops — all land in this counter).
    assert!(
        result.stats.rollouts_dropped_stale.get() > 0,
        "expected stale drops with async_level=0 and a slow broadcast"
    );
    // Staleness is not misbehavior: nobody got slashed for being late.
    assert_eq!(result.stats.nodes_slashed.get(), 0);
}
