//! End-to-end integration over the full decentralized stack: protocol
//! lifecycle + SHARDCAST + TOPLOC validation + PRIME-RL training, with an
//! adversarial worker that must be caught and slashed.

use intellect2::config::RunConfig;
use intellect2::coordinator::Swarm;
use intellect2::runtime::Runtime;

fn artifacts_ready() -> bool {
    Runtime::artifacts_dir("nano").join("spec.json").exists()
}

fn tiny_cfg() -> RunConfig {
    RunConfig {
        model: "nano".into(),
        rl_steps: 2,
        prompts_per_step: 2,
        group_size: 4,
        micro_steps: 1,
        max_new_tokens: 10,
        n_workers: 2,
        n_relays: 2,
        n_math: 40,
        n_code: 8,
        ..Default::default()
    }
}

#[test]
fn honest_swarm_trains_and_overlaps() {
    if !artifacts_ready() {
        eprintln!("skipping: run `make artifacts`");
        return;
    }
    let swarm = Swarm::new(tiny_cfg()).unwrap();
    let result = swarm.run(30, false).unwrap();
    // Both RL steps completed (micro-steps may be skipped when online
    // filtering discards every group — a valid outcome at this scale, the
    // curves are still recorded).
    assert_eq!(result.series.get("task_reward").len(), 2);
    assert!(result.final_state.step >= 30, "step={}", result.final_state.step);
    // Submissions flowed through validation.
    assert!(result.stats.submissions_accepted.get() >= 2);
    assert!(result.stats.rollouts_verified.get() >= 4);
    assert_eq!(result.stats.nodes_slashed.get(), 0);
    // SHARDCAST moved checkpoints (pretrain + 2 steps published).
    assert!(result.stats.broadcast_bytes.get() >= 3 * 120_064 * 4);
    // The ledger audit chain holds.
    assert!(result.ledger.verify_chain());
    // Per-step timings recorded (broadcast, batch-ready, train).
    assert_eq!(result.step_timings.len(), 2);
}

#[test]
fn evil_worker_is_slashed_and_excluded() {
    if !artifacts_ready() {
        eprintln!("skipping: run `make artifacts`");
        return;
    }
    let cfg = tiny_cfg();
    let swarm = Swarm::new(cfg).unwrap();
    let result = swarm.run(5, true).unwrap();
    // The reward-hacking worker's submissions were rejected and the node
    // slashed on the ledger (RewardMismatch via the validator's
    // re-verification).
    assert!(
        result.stats.submissions_rejected.get() >= 1,
        "rejected={}",
        result.stats.submissions_rejected.get()
    );
    assert!(result.stats.nodes_slashed.get() >= 1);
    // Honest training still made progress.
    assert_eq!(result.series.get("task_reward").len(), 2);
    assert!(result.ledger.verify_chain());
}
