//! Churn-torture end-to-end: the full protocol + SHARDCAST stack survives
//! a worker crash, a relay kill and a fresh worker join on every step,
//! with request-level fault injection on every relay — and nobody honest
//! gets slashed. Engine-free (synthetic checkpoints), so it runs in CI
//! without model artifacts.

use std::time::Duration;

use intellect2::coordinator::{run_churn, run_tree_churn, ChurnConfig, TreeChurnConfig};
use intellect2::http::FaultSpec;

#[test]
fn churn_torture_swarm_completes() {
    let cfg = ChurnConfig {
        seed: 11,
        steps: 4,
        churn: true,
        server_faults: Some(FaultSpec {
            fault_rate: 0.25,
            burst_len: 2,
            hang_ms: 150,
            ..FaultSpec::default()
        }),
        // Torture the sampled-audit path too: only commitment-selected
        // fetches are byte-audited, the rest are admitted unaudited.
        sampling_rate: 0.25,
        step_timeout: Duration::from_secs(60),
        ..ChurnConfig::default()
    };
    let report = run_churn(&cfg).unwrap();

    // Liveness: every step's full task quota completed despite the churn.
    assert_eq!(report.steps_completed, cfg.steps, "{report:?}");
    assert!(report.tasks_completed >= cfg.steps * cfg.tasks_per_step as u64, "{report:?}");

    // The schedule actually tortured the swarm: a worker crashed, a relay
    // died and a fresh worker joined on every step (step 1 has no dead
    // slot to restart yet, so restarts lag kills by one step).
    assert_eq!(report.workers_crashed, cfg.steps, "{report:?}");
    assert_eq!(report.workers_joined, cfg.steps, "{report:?}");
    assert_eq!(report.relays_killed, cfg.steps, "{report:?}");
    assert_eq!(report.relays_restarted, cfg.steps - 1, "{report:?}");

    // Recovery machinery fired: crashed workers were evicted by the health
    // sweep, and the transport absorbed failures via retry/failover.
    assert!(report.workers_evicted >= 1, "{report:?}");
    assert!(report.fetch_retries >= 1, "{report:?}");

    // Safety: churn is not cheating — no honest node was slashed.
    assert_eq!(report.honest_slashed, 0, "{report:?}");

    // Sampled auditing: every completed fetch was either fully audited or
    // consciously skipped (and every audit that ran passed, or the step
    // quota above could not have completed).
    assert_eq!(report.audits_full + report.audits_skipped, report.tasks_completed, "{report:?}");
    assert!(report.audits_skipped > 0, "rate 0.25 never skipped an audit: {report:?}");
}

#[test]
fn tree_churn_survives_relay_kill_and_partition() {
    // The gossip-formed SHARDCAST tree, delta + q8 on, with a hub relay
    // killed and a survivor partitioned from its new parent mid-epoch:
    // every live worker still assembles a checksum-valid checkpoint on
    // every step, membership converges by gossip alone (zero central
    // list-endpoint calls), and nobody honest gets slashed.
    let cfg = TreeChurnConfig { steps: 4, ..TreeChurnConfig::default() };
    let report = run_tree_churn(&cfg).unwrap();

    assert_eq!(report.steps_completed, cfg.steps, "{report:?}");
    assert_eq!(report.delivery_rate, 1.0, "{report:?}");

    // The fault schedule actually fired, and the tree routed around it.
    assert_eq!(report.relays_killed, 1, "{report:?}");
    assert_eq!(report.partitions_cut, 1, "{report:?}");
    assert!(report.partition_refusals > 0, "{report:?}");
    assert!(report.reparent_events >= 1, "{report:?}");

    // Membership ran on gossip, not the central discovery list.
    assert_eq!(report.list_calls, 0, "{report:?}");
    assert!(report.invites_via_gossip > 0, "{report:?}");
    assert!(report.gossip_converged, "{report:?}");

    // The encoded wire actually carried deltas, and nobody honest paid.
    assert!(report.delta_shards > 0, "{report:?}");
    assert_eq!(report.honest_slashed, 0, "{report:?}");
}

#[test]
fn fault_free_baseline_is_clean() {
    // The same harness with churn off is a sanity baseline: everything
    // completes, nothing is evicted, requeued or slashed.
    let cfg = ChurnConfig { steps: 2, ..ChurnConfig::default() };
    let report = run_churn(&cfg).unwrap();
    assert_eq!(report.steps_completed, 2, "{report:?}");
    assert_eq!(report.tasks_completed, 2 * cfg.tasks_per_step as u64, "{report:?}");
    assert_eq!(report.workers_evicted, 0, "{report:?}");
    assert_eq!(report.tasks_requeued, 0, "{report:?}");
    assert_eq!(report.honest_slashed, 0, "{report:?}");
    // Default rate 1.0: every fetch is audited, none skipped.
    assert_eq!(report.audits_full, report.tasks_completed, "{report:?}");
    assert_eq!(report.audits_skipped, 0, "{report:?}");
}
