//! Fixture tests for the swarmlint rules engine: every rule firing on a
//! minimal positive fixture and staying quiet on the idiomatic negative,
//! the suppression machinery, the lock-order checker, and — the binding
//! part — the whole `src/` tree coming up clean, which is the same check
//! CI runs via the `swarmlint` binary.

use std::path::Path;

use intellect2::analysis::rules::{analyze_source, repo_config, Config, Rule};
use intellect2::analysis::{analyze_tree, lexer, lockmap};

/// Analyze `src` as a trust-critical file; return unsuppressed rule hits.
fn lint_trusted(src: &str) -> Vec<Rule> {
    let cfg = repo_config();
    analyze_source("toploc/fixture.rs", src, &cfg).unsuppressed().map(|v| v.rule).collect()
}

/// Analyze `src` as a file *outside* the trust set.
fn lint_untrusted(src: &str) -> Vec<Rule> {
    let cfg = repo_config();
    analyze_source("viz/fixture.rs", src, &cfg).unsuppressed().map(|v| v.rule).collect()
}

// --- R1: unordered-iter ----------------------------------------------------

#[test]
fn unordered_iter_fires_on_hash_container_walks() {
    let src = r#"
        use std::collections::{HashMap, HashSet};
        fn f() {
            let m: HashMap<u64, u64> = HashMap::new();
            for (k, v) in m.iter() { println!("{k}{v}"); }
            let s = HashSet::<u64>::new();
            let v: Vec<u64> = s.into_iter().collect();
        }
    "#;
    let hits = lint_trusted(src);
    assert!(hits.iter().filter(|r| **r == Rule::UnorderedIter).count() >= 3, "{hits:?}");
}

#[test]
fn unordered_iter_quiet_on_btree_and_lookups() {
    let src = r#"
        use std::collections::{BTreeMap, HashMap};
        fn f(m: &HashMap<u64, u64>, b: &BTreeMap<u64, u64>) -> Option<u64> {
            let _ = b.iter().count(); // ordered: fine
            m.get(&3).copied() // point lookup, no iteration: fine
        }
    "#;
    assert_eq!(lint_trusted(src), vec![]);
}

#[test]
fn trust_rules_do_not_apply_outside_trust_modules() {
    let src = r#"
        use std::collections::HashMap;
        fn f(m: HashMap<u64, u64>) {
            for k in m.keys() { println!("{k}"); }
            let x: Option<u64> = None;
            x.unwrap();
        }
    "#;
    assert_eq!(lint_untrusted(src), vec![]);
    assert!(!lint_trusted(src).is_empty());
}

// --- R2: wall-clock --------------------------------------------------------

#[test]
fn wall_clock_fires_on_time_and_entropy_sources() {
    let src = r#"
        fn f() -> u64 {
            let t = std::time::Instant::now();
            let s = std::time::SystemTime::now();
            crate::util::now_ms()
        }
    "#;
    let hits = lint_trusted(src);
    assert!(hits.iter().filter(|r| **r == Rule::WallClock).count() >= 3, "{hits:?}");
}

#[test]
fn wall_clock_quiet_on_seeded_rng_and_duration_types() {
    let src = r#"
        use crate::util::rng::Rng;
        fn f(seed: u64) -> u64 {
            let mut rng = Rng::new(seed);
            let _d = std::time::Duration::from_millis(5); // a span, not a reading
            rng.next_u64()
        }
    "#;
    assert_eq!(lint_trusted(src), vec![]);
}

// --- R3: panic-path --------------------------------------------------------

#[test]
fn panic_path_fires_on_unwrap_expect_panic_and_byte_indexing() {
    let src = r#"
        fn parse(bytes: &[u8]) -> u8 {
            let x: Option<u8> = None;
            x.unwrap();
            x.expect("nope");
            if bytes.is_empty() { panic!("empty"); }
            bytes[0]
        }
    "#;
    let hits = lint_trusted(src);
    assert!(hits.iter().filter(|r| **r == Rule::PanicPath).count() >= 4, "{hits:?}");
}

#[test]
fn panic_path_quiet_on_poison_idiom_checked_access_and_tests() {
    let src = r#"
        use std::sync::Mutex;
        fn f(m: &Mutex<u64>, bytes: &[u8]) -> Option<u8> {
            let g = m.lock().unwrap(); // poison idiom: exempt
            let _ = *g;
            bytes.get(0).copied() // checked access: fine
        }
        #[cfg(test)]
        mod tests {
            #[test]
            fn t() {
                let x: Option<u8> = Some(1);
                x.unwrap(); // tests may panic freely
            }
        }
    "#;
    assert_eq!(lint_trusted(src), vec![]);
}

#[test]
fn panic_path_indexing_only_flags_byte_params() {
    // Indexing a local Vec (length under our control) is not the
    // untrusted-byte pattern the rule targets.
    let src = r#"
        fn f(n: usize) -> u64 {
            let v: Vec<u64> = (0..n as u64).collect();
            if v.is_empty() { 0 } else { v[0] }
        }
    "#;
    assert_eq!(lint_trusted(src), vec![]);
}

// --- R4: float-fold --------------------------------------------------------

#[test]
fn float_fold_fires_on_sum_and_product() {
    let src = r#"
        fn f(xs: &[f64]) -> f64 {
            let a: f64 = xs.iter().sum();
            let b: f64 = xs.iter().product();
            a + b + xs.iter().sum::<f64>()
        }
    "#;
    let hits = lint_trusted(src);
    assert!(hits.iter().filter(|r| **r == Rule::FloatFold).count() >= 3, "{hits:?}");
}

#[test]
fn float_fold_quiet_on_canonical_fold() {
    let src = r#"
        fn f(xs: &[f64]) -> f64 {
            crate::util::numeric::fold_f64(xs.iter().copied())
        }
    "#;
    assert_eq!(lint_trusted(src), vec![]);
}

// --- R6: validator-secret --------------------------------------------------

/// Analyze `src` as a worker-side file.
fn lint_worker(src: &str) -> Vec<Rule> {
    let cfg = repo_config();
    analyze_source("protocol/worker.rs", src, &cfg).unsuppressed().map(|v| v.rule).collect()
}

#[test]
fn validator_secret_fires_on_commitment_type_and_derivation_constant() {
    let src = r#"
        use crate::coordinator::validation::ValidatorCommitment;
        fn f(seed: u64) -> u64 {
            seed ^ 0x5E1EC7
        }
    "#;
    let hits = lint_worker(src);
    assert!(hits.iter().filter(|r| **r == Rule::ValidatorSecret).count() >= 2, "{hits:?}");
    // Lowercase hex spells the same secret.
    let lower = "fn g(seed: u64) -> u64 { seed ^ 0x5e1ec7 }";
    assert_eq!(lint_worker(lower), vec![Rule::ValidatorSecret]);
}

#[test]
fn validator_secret_only_applies_to_worker_modules() {
    // The validator itself (and the coordinator-side churn harness)
    // legitimately hold commitments; the rule is about the worker side.
    let src = r#"
        fn f(c: &ValidatorCommitment) -> [u8; 32] {
            c.commit_hash()
        }
    "#;
    assert_eq!(lint_trusted(src), vec![]);
    let cfg = repo_config();
    let churn: Vec<Rule> = analyze_source("coordinator/churn.rs", src, &cfg)
        .unsuppressed()
        .map(|v| v.rule)
        .collect();
    assert_eq!(churn, vec![]);
    assert_eq!(lint_worker(src), vec![Rule::ValidatorSecret]);
}

#[test]
fn validator_secret_parse_round_trips() {
    assert_eq!(Rule::parse("validator-secret"), Some(Rule::ValidatorSecret));
    assert_eq!(Rule::ValidatorSecret.name(), "validator-secret");
}

// --- suppressions ----------------------------------------------------------

#[test]
fn annotation_suppresses_trailing_and_next_line_targets() {
    let src = r#"
        fn f(xs: &[usize]) -> usize {
            let a: usize = xs.iter().sum(); // swarmlint: allow(float-fold) — usize sum
            // swarmlint: allow(float-fold) — usize sum, order-free
            let b: usize = xs.iter().sum();
            a + b
        }
    "#;
    let cfg = repo_config();
    let rep = analyze_source("toploc/fixture.rs", src, &cfg);
    assert_eq!(rep.unsuppressed().count(), 0);
    assert_eq!(rep.violations.iter().filter(|v| v.suppressed).count(), 2);
    assert!(rep.annotations.iter().all(|a| a.used));
}

#[test]
fn allow_fn_covers_the_whole_function_and_nothing_else() {
    let src = r#"
        // swarmlint: allow-fn(panic-path) — every index is bounds-guarded
        fn covered(b: &[u8]) -> u8 {
            if b.len() > 2 { b[0] + b[1] } else { 0 }
        }
        fn uncovered(b: &[u8]) -> u8 {
            b[0]
        }
    "#;
    let cfg = repo_config();
    let rep = analyze_source("toploc/fixture.rs", src, &cfg);
    let open: Vec<_> = rep.unsuppressed().collect();
    assert_eq!(open.len(), 1, "{open:?}");
    assert_eq!(open[0].rule, Rule::PanicPath);
    assert!(rep.violations.iter().filter(|v| v.suppressed).count() >= 2);
}

#[test]
fn annotation_without_justification_is_a_bad_annotation() {
    let src = r#"
        fn f(xs: &[f64]) -> f64 {
            // swarmlint: allow(float-fold)
            let a: f64 = xs.iter().sum();
            // swarmlint: allow(no-such-rule) — whatever
            let b: f64 = xs.iter().sum();
            a + b
        }
    "#;
    let hits = lint_trusted(src);
    assert!(hits.iter().filter(|r| **r == Rule::BadAnnotation).count() == 2, "{hits:?}");
    // The malformed annotations suppress nothing: the sums still fire.
    assert!(hits.iter().filter(|r| **r == Rule::FloatFold).count() == 2, "{hits:?}");
}

#[test]
fn unused_annotations_are_reported_not_silently_dropped() {
    let src = r#"
        fn f() -> u64 {
            // swarmlint: allow(panic-path) — stale waiver, nothing fires
            7
        }
    "#;
    let cfg = repo_config();
    let rep = analyze_source("toploc/fixture.rs", src, &cfg);
    assert_eq!(rep.unsuppressed().count(), 0);
    assert_eq!(rep.annotations.len(), 1);
    assert!(!rep.annotations[0].used);
}

// --- R5: lock-order --------------------------------------------------------

fn lock_cfg() -> Config {
    Config {
        trust_prefixes: vec![],
        worker_prefixes: vec![],
        lock_order: vec!["m::outer".to_string(), "m::inner".to_string()],
    }
}

fn lock_check(src: &str, cfg: &Config) -> Vec<String> {
    let mut reports = vec![analyze_source("m.rs", src, cfg)];
    lockmap::check_edges(&mut reports, &cfg.lock_order);
    reports[0].unsuppressed().map(|v| v.message.clone()).collect()
}

#[test]
fn lock_order_allows_declared_nesting_and_rejects_reversal() {
    let ok = r#"
        fn f(s: &S) {
            let g = s.outer.lock().unwrap();
            let h = s.inner.lock().unwrap();
            drop(h);
            drop(g);
        }
    "#;
    assert_eq!(lock_check(ok, &lock_cfg()), Vec::<String>::new());

    let reversed = r#"
        fn f(s: &S) {
            let g = s.inner.lock().unwrap();
            let h = s.outer.lock().unwrap();
        }
    "#;
    let msgs = lock_check(reversed, &lock_cfg());
    assert_eq!(msgs.len(), 1, "{msgs:?}");
    assert!(msgs[0].contains("against the declared lock order"), "{msgs:?}");
}

#[test]
fn lock_order_flags_same_class_nesting_as_self_deadlock() {
    let src = r#"
        fn f(s: &S) {
            let g = s.inner.lock().unwrap();
            let h = s.inner.lock().unwrap();
        }
    "#;
    let msgs = lock_check(src, &lock_cfg());
    assert_eq!(msgs.len(), 1, "{msgs:?}");
    assert!(msgs[0].contains("self-deadlock"), "{msgs:?}");
}

#[test]
fn lock_order_flags_undeclared_classes_in_edges() {
    let src = r#"
        fn f(s: &S) {
            let g = s.outer.lock().unwrap();
            let h = s.mystery.lock().unwrap();
        }
    "#;
    let msgs = lock_check(src, &lock_cfg());
    assert_eq!(msgs.len(), 1, "{msgs:?}");
    assert!(msgs[0].contains("missing from the declared lock order"), "{msgs:?}");
}

#[test]
fn lock_temporaries_release_at_statement_end() {
    // A chained (unbound) guard dies at the `;`, so sequential statements
    // that each take a lock do not nest — the swarm.rs stats-merge idiom.
    let src = r#"
        fn f(s: &S) {
            let snapshot = s.inner.lock().unwrap().clone();
            let mut g = s.inner.lock().unwrap();
            *g = snapshot;
        }
    "#;
    assert_eq!(lock_check(src, &lock_cfg()), Vec::<String>::new());
}

#[test]
fn dropped_guards_stop_generating_edges() {
    let src = r#"
        fn f(s: &S) {
            let g = s.inner.lock().unwrap();
            drop(g);
            let h = s.outer.lock().unwrap();
        }
    "#;
    assert_eq!(lock_check(src, &lock_cfg()), Vec::<String>::new());
}

// --- the binding gate ------------------------------------------------------

fn src_root() -> &'static Path {
    // Integration tests run with CWD = the package root (`rust/`).
    Path::new("src")
}

#[test]
fn whole_tree_is_swarmlint_clean() {
    let cfg = repo_config();
    let reports = analyze_tree(src_root(), &cfg).expect("src/ readable");
    assert!(reports.len() > 30, "walked only {} files", reports.len());
    let mut open = Vec::new();
    for r in &reports {
        for v in r.unsuppressed() {
            open.push(format!("{}:{} [{}] {}", v.file, v.line, v.rule.name(), v.message));
        }
    }
    assert!(open.is_empty(), "unsuppressed swarmlint violations:\n{}", open.join("\n"));
}

#[test]
fn tree_lock_edges_all_follow_declared_order() {
    let cfg = repo_config();
    let reports = analyze_tree(src_root(), &cfg).expect("src/ readable");
    let map = lockmap::render_map(&reports, &cfg.lock_order);
    assert!(!map.contains("VIOLATION"), "{map}");
    // The map is non-trivial: the crate really does hold locks.
    let sites: usize = reports.iter().map(|r| r.lock_sites.len()).sum();
    assert!(sites >= 40, "only {sites} lock sites found — scan regressed?");
}

#[test]
fn every_tree_annotation_is_used_and_justified() {
    let cfg = repo_config();
    let reports = analyze_tree(src_root(), &cfg).expect("src/ readable");
    let mut stale = Vec::new();
    for r in &reports {
        for a in &r.annotations {
            assert!(!a.justification.is_empty(), "{}:{} lacks justification", r.file, a.line);
            if !a.used {
                stale.push(format!("{}:{}", r.file, a.line));
            }
        }
    }
    assert!(stale.is_empty(), "stale annotations: {stale:?}");
}

#[test]
fn lexer_roundtrips_every_source_file() {
    // Totality + losslessness over the real codebase: lexing any file in
    // src/ and re-joining the token texts reproduces it byte for byte.
    let files = intellect2::analysis::collect_rs_files(src_root()).expect("src/ readable");
    assert!(files.len() > 30);
    for path in files {
        let src = std::fs::read_to_string(&path).expect("readable");
        let toks = lexer::lex(&src);
        assert_eq!(lexer::rejoin(&toks), src, "lossy lex of {}", path.display());
    }
}
