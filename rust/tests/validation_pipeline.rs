//! Equivalence tests for the parallel, length-bucketed validation
//! pipeline: packed/bucketed/threaded validation must produce verdicts
//! byte-identical to the sequential single-submission full-pad reference
//! on mixed honest/cheating submissions — and therefore identical
//! accept/slash/stale counters — regardless of thread count or bucket
//! grain, in both the legacy unsigned mode and the signed-envelope mode
//! (stage 0). Plus adversarial end-to-end coverage for the envelope
//! layer: framing, post-signing tampers, unregistered senders, unsigned
//! uploads and replayed old envelopes.

use std::collections::BTreeMap;
use std::sync::Arc;

use intellect2::config::RunConfig;
use intellect2::coordinator::validation::{
    validate_submission_fullpad, GateOutcome, SamplerConfig, SamplingGate, SigOracle,
    TrustOracle, ValidationPipeline, ValidatorCommitment, Verdict,
};
use intellect2::coordinator::{group_id_base, RolloutGenerator};
use intellect2::protocol::{Identity, Ledger, TrustState};
use intellect2::rl::rollout_file::{Envelope, Submission};
use intellect2::runtime::{EngineHost, ParamSet, Runtime};
use intellect2::tasks::dataset::{Dataset, DatasetConfig, EnvMix};
use intellect2::toploc::{Validator, ValidatorConfig};
use intellect2::util::prop::{check, ensure_eq};
use intellect2::util::rng::Rng;

fn artifacts_ready() -> bool {
    Runtime::artifacts_dir("nano").join("spec.json").exists()
}

struct Fixture {
    host: Arc<EngineHost>,
    dataset: Arc<Dataset>,
    cfg: RunConfig,
    /// The trusted checkpoint, registered as policy version 1.
    params: Arc<ParamSet>,
    /// Key registry: every identity below is registered here except
    /// `unregistered`.
    ledger: Ledger,
    /// address → identity for sealing envelopes in tests.
    ids: BTreeMap<u64, Identity>,
    /// Honest submissions from 3 nodes x 2 submission indices, policy
    /// version 1 (mixed lengths via sampled EOS terminations).
    honest: Vec<Submission>,
    /// Honest submission claiming policy version 0 — aged out of the
    /// versions map by the time it is validated (stale, not slashable).
    old: Submission,
    /// Honest-looking submission claiming version 5, which the trainer
    /// never published (provably fabricated).
    future: Submission,
    /// Identity with no ledger-registered key, and its (otherwise honest)
    /// submission.
    unregistered: Identity,
    unregistered_sub: Submission,
}

impl Fixture {
    fn build() -> Fixture {
        let cfg = RunConfig {
            model: "nano".into(),
            group_size: 2,
            max_new_tokens: 14,
            // All four registered envs: validation parity must hold on
            // mixed-env submissions, not just the historical two domains.
            env_mix: EnvMix::of(&[("math", 30), ("code", 6), ("seq", 6), ("chain", 6)]),
            ..Default::default()
        };
        let host = Arc::new(EngineHost::spawn_size(&cfg.model).unwrap());
        let dataset = Arc::new(
            Dataset::generate(
                &intellect2::verifier::Registry::standard(),
                &DatasetConfig { seed: cfg.seed, mix: cfg.env_mix.clone(), ..Default::default() },
            )
            .unwrap(),
        );
        let generator =
            RolloutGenerator::from_config(Arc::clone(&host), Arc::clone(&dataset), &cfg).unwrap();
        let params = Arc::new(host.init_params(9).unwrap());
        let ledger = Ledger::new();
        let mut ids = BTreeMap::new();
        let mut identity = |seed: u64, register: bool| {
            let id = Identity::from_seed(seed);
            if register {
                ledger.register_key(&id);
            }
            ids.insert(id.address, id.clone());
            id
        };
        let gen = |id: &Identity, step: u64, idx: u64| {
            generator
                .generate_submission(
                    &params,
                    id.address,
                    step,
                    idx,
                    2,
                    cfg.group_size,
                    group_id_base(id.address, step, idx),
                )
                .unwrap()
                .0
        };
        let mut honest = Vec::new();
        for seed in [11u64, 22, 33] {
            let id = identity(seed, true);
            for idx in 0..2u64 {
                honest.push(gen(&id, 1, idx));
            }
        }
        // Self-consistent (seed formula, group ids) at their claimed
        // steps, so they pass the CPU stages and exercise the
        // version-miss paths instead of SeedMismatch.
        let old = gen(&identity(44, true), 0, 0);
        let future = gen(&identity(55, true), 5, 0);
        let unregistered = identity(99, false);
        let unregistered_sub = gen(&unregistered, 1, 0);
        Fixture {
            host,
            dataset,
            cfg,
            params,
            ledger,
            ids,
            honest,
            old,
            future,
            unregistered,
            unregistered_sub,
        }
    }

    fn vcfg(&self) -> ValidatorConfig {
        ValidatorConfig {
            expected_group: self.cfg.group_size,
            max_policy_lag: self.cfg.async_level,
            ..Default::default()
        }
    }

    fn lookup(&self) -> impl Fn(u64) -> Option<Arc<ParamSet>> + '_ {
        |v| (v == 1).then(|| Arc::clone(&self.params))
    }

    /// The ledger's signature check as the stage-0 oracle (key bytes
    /// never leave the ledger).
    fn keys(&self) -> Arc<SigOracle> {
        let ledger = self.ledger.clone();
        Arc::new(move |addr, msg: &[u8], sig: &[u8; 32]| ledger.check_address_sig(addr, msg, sig))
    }

    /// Seal `sub` under its own sender's key (the honest upload path).
    fn sign(&self, sub: &Submission) -> Vec<u8> {
        sub.encode_signed(&self.ids[&sub.node_address])
    }

    /// Encode `sub` signed or raw depending on the mode under test.
    fn encode(&self, sub: &Submission, signed: bool) -> Vec<u8> {
        if signed {
            self.sign(sub)
        } else {
            sub.encode()
        }
    }

    /// The sequential pre-pipeline reference, one submission at a time.
    fn fullpad_verdicts(&self, batch: &[Vec<u8>], current: u64, signed: bool) -> Vec<Verdict> {
        let validator = Validator::new(self.vcfg());
        let keys = signed.then(|| self.keys());
        batch
            .iter()
            .map(|bytes| {
                validate_submission_fullpad(
                    &validator,
                    keys.as_ref(),
                    bytes,
                    &self.dataset,
                    &self.cfg.reward,
                    &self.host,
                    self.host.spec(),
                    self.cfg.max_new_tokens,
                    &|| current,
                    &self.lookup(),
                )
            })
            .collect()
    }

    fn pipeline(&self, threads: usize, bucket: usize, signed: bool) -> ValidationPipeline {
        let p = ValidationPipeline::new(
            Validator::new(self.vcfg()),
            Arc::clone(&self.dataset),
            self.cfg.reward.clone(),
            Arc::clone(&self.host),
            self.cfg.max_new_tokens,
            threads,
            bucket,
        )
        .unwrap();
        if signed {
            p.with_signing(self.keys())
        } else {
            p
        }
    }
}

fn fingerprints(verdicts: &[Verdict]) -> Vec<(&'static str, Option<u64>, String)> {
    verdicts.iter().map(Verdict::fingerprint).collect()
}

/// What the swarm loop would do with these verdicts — the counters the
/// multi-threaded validator must keep identical to the sequential path.
/// `(accepted, verified, rejected, slashed, unattributed, stale,
/// stale_rollouts, unsigned, forged)`.
fn counters(verdicts: &[Verdict]) -> (u64, u64, u64, u64, u64, u64, u64, u64, u64) {
    let (mut accepted, mut verified, mut rejected, mut slashed) = (0, 0, 0, 0);
    let (mut unattributed, mut stale, mut stale_rollouts) = (0, 0, 0);
    let (mut unsigned, mut forged) = (0, 0);
    for v in verdicts {
        match v {
            Verdict::Accept(sub) => {
                accepted += 1;
                verified += sub.rollouts.len() as u64;
            }
            Verdict::Stale { n_rollouts, .. } => {
                stale += 1;
                stale_rollouts += *n_rollouts as u64;
            }
            Verdict::EngineFailure { .. } => {}
            Verdict::Reject { node, .. } => {
                rejected += 1;
                match node {
                    Some(_) => slashed += 1,
                    None => unattributed += 1,
                }
            }
            Verdict::Unsigned { .. } => {
                rejected += 1;
                unsigned += 1;
            }
            Verdict::Forged { .. } => {
                rejected += 1;
                forged += 1;
            }
        }
    }
    (accepted, verified, rejected, slashed, unattributed, stale, stale_rollouts, unsigned, forged)
}

/// A deterministic mixed batch: honest + every cheating/staleness flavor.
/// In signed mode the stage-0 attack surface is included too.
fn mixed_batch(fx: &Fixture, signed: bool) -> Vec<Vec<u8>> {
    let mut batch: Vec<Vec<u8>> = fx.honest.iter().map(|s| fx.encode(s, signed)).collect();

    // Reward hacking (stage-2 reject): claim every task solved. In signed
    // mode the cheater seals its own tampered payload — a valid envelope
    // over dishonest contents, so the slash is *proven*.
    let mut liar = fx.honest[0].clone();
    for w in &mut liar.rollouts {
        w.rollout.task_reward = 1.0;
        w.rollout.reward = 1.0;
    }
    batch.push(fx.encode(&liar, signed));

    // Tampered commitment (stage-4 reject) on a non-first rollout, so the
    // min-rollout-index attribution is exercised.
    let mut forged_commit = fx.honest[1].clone();
    forged_commit.commitment_tamper(2);
    batch.push(fx.encode(&forged_commit, signed));

    // Fabricated probability reports (stage-5 reject).
    let mut fabricated = fx.honest[2].clone();
    for w in &mut fabricated.rollouts {
        for p in &mut w.rollout.sampled_probs {
            *p = 0.97;
        }
    }
    batch.push(fx.encode(&fabricated, signed));

    // Aged-out policy version (version-miss -> stale, not slashable).
    batch.push(fx.encode(&fx.old, signed));

    // Unpublished future version (version-miss -> provably fabricated).
    batch.push(fx.encode(&fx.future, signed));

    // Payload mangled in flight. Unsigned mode: checksum broken beyond
    // attribution. Signed mode: the signed digest no longer covers the
    // bytes — forged, and the signer is NOT slashed for bytes they
    // provably did not vouch for.
    let mut mangled = fx.encode(&fx.honest[5], signed);
    let n = mangled.len();
    mangled[n / 2] ^= 0x55;
    batch.push(mangled);

    if signed {
        // Unsigned upload under a signature-required validator.
        batch.push(fx.honest[3].encode());
        // Framing: the node behind `future` re-uses its own signature but
        // claims the first honest node's address — must not slash the
        // framed node.
        let framer = &fx.ids[&fx.future.node_address];
        let victim = fx.honest[0].node_address;
        let payload = fx.honest[0].encode();
        let sealed = Envelope::seal(framer, 1, 0, &payload);
        let (mut env, payload) = Envelope::parse(&sealed).unwrap();
        env.node_address = victim;
        batch.push(env.encode(payload));
        // Unregistered sender: a valid signature from a key the ledger
        // does not know.
        batch.push(fx.unregistered_sub.encode_signed(&fx.unregistered));
    }

    batch
}

/// Test-local helper: corrupt one rollout's commitment bytes.
trait CommitmentTamper {
    fn commitment_tamper(&mut self, rollout: usize);
}

impl CommitmentTamper for Submission {
    fn commitment_tamper(&mut self, rollout: usize) {
        let r = rollout.min(self.rollouts.len() - 1);
        for b in &mut self.rollouts[r].commitment {
            *b = b.wrapping_add(31);
        }
    }
}

#[test]
fn packed_pipeline_matches_fullpad_reference() {
    if !artifacts_ready() {
        eprintln!("skipping: run `make artifacts`");
        return;
    }
    let fx = Fixture::build();
    for signed in [false, true] {
        let batch = mixed_batch(&fx, signed);
        let want = fingerprints(&fx.fullpad_verdicts(&batch, 1, signed));
        // Sanity on the mix itself: accepts, rejects and stales are all
        // present, so the equivalence below is non-trivial.
        let (accepted, _, rejected, slashed, unattributed, stale, _, unsigned, forged) =
            counters(&fx.fullpad_verdicts(&batch, 1, signed));
        assert!(accepted >= 1, "no honest submission accepted: {want:?}");
        assert!(rejected >= 4 && slashed >= 3, "mix degenerated: {want:?}");
        assert!(stale >= 1, "no stale verdict in the mix: {want:?}");
        if signed {
            // The stage-0 flavors are all represented: the in-flight
            // mangle + framing + unregistered sender are forged, the raw
            // upload is unsigned, and nothing is unattributed (stage 0
            // always names a claimed sender or refuses the upload whole).
            assert_eq!(unsigned, 1, "{want:?}");
            assert_eq!(forged, 3, "{want:?}");
            assert_eq!(unattributed, 0, "{want:?}");
        } else {
            assert!(unattributed >= 1, "mix degenerated: {want:?}");
            assert_eq!(unsigned + forged, 0, "{want:?}");
        }

        // Threaded + packed + bucketed, across thread counts and bucket
        // grains: verdicts must be byte-identical to the reference.
        for (threads, bucket) in [(1usize, 0usize), (4, 0), (4, 1), (4, 4096), (2, 7)] {
            let pipeline = fx.pipeline(threads, bucket, signed);
            let got = pipeline.validate_batch(batch.clone(), &|| 1, &fx.lookup());
            assert_eq!(
                fingerprints(&got),
                want,
                "pipeline(threads={threads}, bucket={bucket}, signed={signed}) diverged"
            );
        }

        // Packing really packed: the surviving submissions reach at most a
        // handful of prefill calls (the baseline issues one full-frame
        // call per submission that reaches stages 4–5).
        let pipeline = fx.pipeline(4, 0, signed);
        let _ = pipeline.validate_batch(batch.clone(), &|| 1, &fx.lookup());
        let calls = pipeline.prefill_calls.get();
        assert!(
            (1..=3).contains(&calls),
            "expected the wave to pack into 1..=3 prefill calls, got {calls} (signed={signed})"
        );
    }
}

/// The registry fingerprint makes a silent env-set mismatch *detectable,
/// not exploitable*: both the worker-side generator and the validator-side
/// pipeline refuse to come up against a dataset built from a different
/// registry — the failure mode where §2.3.3 reward re-verification would
/// slash honest nodes.
#[test]
fn mismatched_registry_refused_at_construction() {
    if !artifacts_ready() {
        eprintln!("skipping: run `make artifacts`");
        return;
    }
    let fx = Fixture::build();
    let subset = || {
        let mut r = intellect2::verifier::Registry::empty();
        r.register(Box::new(intellect2::tasks::math::MathEnv)).unwrap();
        Arc::new(r)
    };
    let err = ValidationPipeline::new(
        Validator::with_registry(fx.vcfg(), subset()),
        Arc::clone(&fx.dataset),
        fx.cfg.reward.clone(),
        Arc::clone(&fx.host),
        fx.cfg.max_new_tokens,
        1,
        0,
    )
    .expect_err("validator over a different registry must be refused");
    assert!(err.to_string().contains("fingerprint"), "{err}");
    let err = RolloutGenerator::with_registry(
        Arc::clone(&fx.host),
        Arc::clone(&fx.dataset),
        &fx.cfg,
        subset(),
    )
    .expect_err("generator over a different registry must be refused");
    assert!(err.to_string().contains("fingerprint"), "{err}");
}

#[test]
fn threaded_counters_match_sequential() {
    if !artifacts_ready() {
        eprintln!("skipping: run `make artifacts`");
        return;
    }
    let fx = Fixture::build();
    for signed in [false, true] {
        let batch = mixed_batch(&fx, signed);
        let sequential = fx.pipeline(1, 0, signed).validate_batch(batch.clone(), &|| 1, &fx.lookup());
        let threaded = fx.pipeline(4, 0, signed).validate_batch(batch, &|| 1, &fx.lookup());
        assert_eq!(counters(&sequential), counters(&threaded), "signed={signed}");
        assert_eq!(fingerprints(&sequential), fingerprints(&threaded), "signed={signed}");
    }
}

/// The tentpole's adversarial end-to-end cases, one by one, with explicit
/// attribution assertions.
#[test]
fn signed_envelope_adversaries() {
    if !artifacts_ready() {
        eprintln!("skipping: run `make artifacts`");
        return;
    }
    let fx = Fixture::build();
    let pipeline = fx.pipeline(4, 0, true);
    let victim = fx.honest[0].node_address;
    let framer_id = &fx.ids[&fx.future.node_address];
    let liar_addr = fx.honest[2].node_address;

    // 1. Framing: a submission "from" the victim signed by someone else.
    let payload = fx.honest[0].encode();
    let sealed = Envelope::seal(framer_id, 1, 0, &payload);
    let (mut env, payload_bytes) = Envelope::parse(&sealed).unwrap();
    env.node_address = victim;
    let framed = env.encode(payload_bytes);
    // 2. Tamper-after-signing: valid header, swapped payload byte.
    let mut tampered = fx.sign(&fx.honest[1]);
    let k = tampered.len() - 3;
    tampered[k] ^= 0x20;
    // 3. Tamper-then-sign: the signer seals its own lie (reward hack).
    let mut liar = fx.honest[2].clone();
    for w in &mut liar.rollouts {
        w.rollout.task_reward = 1.0;
        w.rollout.reward = 1.0;
    }
    let liar_signed = fx.sign(&liar);
    // 4. Signed garbage: proven malformed payload.
    let garbage = Envelope::seal(framer_id, 1, 0, b"not an rpq file");
    // 5. Unregistered sender.
    let unknown = fx.unregistered_sub.encode_signed(&fx.unregistered);
    // 6. The victim's genuine submission, to prove it still lands.
    let genuine = fx.sign(&fx.honest[0]);

    let verdicts = pipeline.validate_batch(
        vec![framed, tampered, liar_signed, garbage, unknown, genuine],
        &|| 1,
        &fx.lookup(),
    );
    match &verdicts[0] {
        Verdict::Forged { claimed, .. } => assert_eq!(*claimed, victim),
        v => panic!("framing: {:?}", v.fingerprint()),
    }
    match &verdicts[1] {
        Verdict::Forged { claimed, .. } => assert_eq!(*claimed, fx.honest[1].node_address),
        v => panic!("tamper-after-signing: {:?}", v.fingerprint()),
    }
    match &verdicts[2] {
        // The signer vouched for the tampered payload: slash the signer.
        Verdict::Reject { node, .. } => assert_eq!(*node, Some(liar_addr)),
        v => panic!("tamper-then-sign: {:?}", v.fingerprint()),
    }
    match &verdicts[3] {
        // Malformed payload under a valid envelope: proven, slash signer.
        Verdict::Reject { node, .. } => assert_eq!(*node, Some(framer_id.address)),
        v => panic!("signed garbage: {:?}", v.fingerprint()),
    }
    assert!(matches!(&verdicts[4], Verdict::Forged { .. }), "unregistered sender");
    match &verdicts[5] {
        Verdict::Accept(sub) => assert_eq!(sub.node_address, victim),
        v => panic!("genuine submission: {:?}", v.fingerprint()),
    }
    // The framed victim was never slashed: its only Reject-with-node
    // verdicts would have named it, and none did.
    for v in &verdicts {
        if let Verdict::Reject { node: Some(n), .. } = v {
            assert_ne!(*n, victim, "framed node must not be slashed");
        }
    }
}

/// Replay binding: a captured envelope re-submitted later fails the
/// staleness window (its signed step aged out) without slashing anyone —
/// and it cannot be re-targeted at a newer step without the key.
#[test]
fn replayed_envelopes_age_out() {
    if !artifacts_ready() {
        eprintln!("skipping: run `make artifacts`");
        return;
    }
    let fx = Fixture::build();
    let pipeline = fx.pipeline(1, 0, true);
    let signed = fx.sign(&fx.honest[0]);

    // Fresh: accepted at current step 1.
    let v = pipeline.validate_batch(vec![signed.clone()], &|| 1, &fx.lookup());
    assert!(matches!(v[0], Verdict::Accept(_)), "{:?}", v[0].fingerprint());

    // Replayed verbatim much later: outside the staleness window. Dropped
    // and counted — never slashed (being replayed is not the signer's
    // dishonesty; the window bound is what makes replays worthless).
    let v = pipeline.validate_batch(vec![signed.clone()], &|| 9, &fx.lookup());
    match &v[0] {
        Verdict::Stale { node, submitted, current, .. } => {
            assert_eq!(*node, fx.honest[0].node_address);
            assert_eq!((*submitted, *current), (1, 9));
        }
        v => panic!("replay: {:?}", v.fingerprint()),
    }

    // An attacker cannot refresh the replay: rewriting the envelope's
    // step breaks the signature (it is bound into the signed bytes).
    let (env, payload) = Envelope::parse(&signed).unwrap();
    let refreshed = Envelope { step: 9, ..env }.encode(payload);
    let v = pipeline.validate_batch(vec![refreshed], &|| 9, &fx.lookup());
    assert!(
        matches!(&v[0], Verdict::Forged { .. }),
        "step-rewritten replay must be forged: {:?}",
        v[0].fingerprint()
    );
}

/// Sampling pre-stage transparency: at rate 1.0 the gate must be a pure
/// pass-through — no upload is ever spot-check exempted, not even for a
/// node with unbounded clean trust, and the verdict set over the full
/// adversarial mix is identical to the ungated pipeline's. (The gate
/// settles stage-0 failures itself, so equality is over the verdict
/// *sets*; the swarm only constructs a gate at rates below 1.0, where
/// positional order is not preserved anyway.)
#[test]
fn sampling_gate_at_rate_one_is_verdict_transparent() {
    if !artifacts_ready() {
        eprintln!("skipping: run `make artifacts`");
        return;
    }
    let fx = Fixture::build();
    let batch = mixed_batch(&fx, true);
    let ungated = fx.pipeline(4, 0, true).validate_batch(batch.clone(), &|| 1, &fx.lookup());
    let mut want = fingerprints(&ungated);

    // The most skip-friendly trust imaginable: an endless clean record.
    // Rate 1.0 must still clamp every node to full verification.
    let trust: Arc<TrustOracle> = Arc::new(|_| TrustState {
        clean_streak: u64::MAX,
        verified_clean: u64::MAX,
        rejects: 0,
    });
    let gate = SamplingGate::new(
        ValidatorCommitment::new(0xFEED),
        SamplerConfig { sampling_rate: 1.0, promotion_streak: 8 },
        trust,
        Arc::clone(&fx.dataset),
        fx.cfg.reward.clone(),
        fx.cfg.max_new_tokens,
        fx.host.spec().max_seq,
    );
    let validator = Validator::new(fx.vcfg());
    let keys = fx.keys();
    let mut fulls: Vec<Vec<u8>> = Vec::new();
    let mut got = Vec::new();
    for bytes in batch.clone() {
        match gate.gate(Some(&keys), &validator, 1, bytes.clone()) {
            // Pass-through is byte-identical: the pipeline sees exactly
            // the upload the worker signed.
            GateOutcome::Full(b) => {
                assert_eq!(b, bytes, "gate must not rewrite upload bytes");
                fulls.push(b);
            }
            GateOutcome::Done(v) => got.push(v.fingerprint()),
            GateOutcome::Skip(_) => panic!("rate 1.0 must never skip verification"),
        }
    }
    assert_eq!(gate.skipped.get(), 0);
    assert_eq!(gate.sampled_full.get(), fulls.len() as u64);
    got.extend(fingerprints(
        &fx.pipeline(4, 0, true).validate_batch(fulls, &|| 1, &fx.lookup()),
    ));
    want.sort();
    got.sort();
    assert_eq!(got, want, "gated verdict set diverged from the ungated pipeline");
}

#[test]
fn pipeline_equivalence_property_random_tampers() {
    if !artifacts_ready() {
        eprintln!("skipping: run `make artifacts`");
        return;
    }
    let fx = Fixture::build();
    // Property: for any per-submission tamper assignment, in either
    // signing mode, the packed pipeline's verdicts equal the full-pad
    // reference's.
    check(
        "packed pipeline == full-pad reference under random tampering",
        6,
        |rng: &mut Rng, _size| {
            let signed = rng.bool(0.5);
            let batch = fx
                .honest
                .iter()
                .map(|sub| {
                    let mut sub = sub.clone();
                    match rng.usize(7) {
                        0 => {} // honest
                        1 => {
                            for w in &mut sub.rollouts {
                                w.rollout.task_reward = 1.0;
                                w.rollout.reward = 1.0;
                            }
                        }
                        2 => sub.commitment_tamper(rng.usize(sub.rollouts.len())),
                        3 => {
                            let r = rng.usize(sub.rollouts.len());
                            for p in &mut sub.rollouts[r].rollout.sampled_probs {
                                *p = 0.93;
                            }
                        }
                        4 => sub = fx.old.clone(),
                        5 => sub = fx.future.clone(),
                        _ => {
                            // In-flight bit flip (position varies).
                            let mut bytes = fx.encode(&sub, signed);
                            let k = rng.usize(bytes.len());
                            bytes[k] ^= 0x10;
                            return DebugBytes(bytes);
                        }
                    }
                    DebugBytes(fx.encode(&sub, signed))
                })
                .collect::<Vec<_>>();
            (signed, batch)
        },
        |(signed, batch)| {
            let bytes: Vec<Vec<u8>> = batch.iter().map(|b| b.0.clone()).collect();
            let want = fingerprints(&fx.fullpad_verdicts(&bytes, 1, *signed));
            let got = fx.pipeline(4, 0, *signed).validate_batch(bytes, &|| 1, &fx.lookup());
            ensure_eq(fingerprints(&got), want, "pipeline diverged")
        },
    );
}

/// Wrapper so the prop harness can Debug-print failing inputs tersely.
struct DebugBytes(Vec<u8>);

impl std::fmt::Debug for DebugBytes {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "<{} submission bytes>", self.0.len())
    }
}
