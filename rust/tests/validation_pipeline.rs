//! Equivalence tests for the parallel, length-bucketed validation
//! pipeline: packed/bucketed/threaded validation must produce verdicts
//! byte-identical to the sequential single-submission full-pad reference
//! on mixed honest/cheating submissions — and therefore identical
//! accept/slash/stale counters — regardless of thread count or bucket
//! grain.

use std::sync::Arc;

use intellect2::config::RunConfig;
use intellect2::coordinator::validation::{
    validate_submission_fullpad, ValidationPipeline, Verdict,
};
use intellect2::coordinator::{group_id_base, RolloutGenerator};
use intellect2::rl::rollout_file::Submission;
use intellect2::runtime::{EngineHost, ParamSet, Runtime};
use intellect2::tasks::dataset::{Dataset, DatasetConfig};
use intellect2::toploc::{Validator, ValidatorConfig};
use intellect2::util::prop::{check, ensure_eq};
use intellect2::util::rng::Rng;

fn artifacts_ready() -> bool {
    Runtime::artifacts_dir("nano").join("spec.json").exists()
}

struct Fixture {
    host: Arc<EngineHost>,
    dataset: Arc<Dataset>,
    cfg: RunConfig,
    /// The trusted checkpoint, registered as policy version 1.
    params: Arc<ParamSet>,
    /// Honest submissions from 3 nodes x 2 submission indices, policy
    /// version 1 (mixed lengths via sampled EOS terminations).
    honest: Vec<Submission>,
    /// Honest submission claiming policy version 0 — aged out of the
    /// versions map by the time it is validated (stale, not slashable).
    old: Submission,
    /// Honest-looking submission claiming version 5, which the trainer
    /// never published (provably fabricated).
    future: Submission,
}

impl Fixture {
    fn build() -> Fixture {
        let cfg = RunConfig {
            model: "nano".into(),
            group_size: 2,
            max_new_tokens: 14,
            n_math: 40,
            n_code: 8,
            ..Default::default()
        };
        let host = Arc::new(EngineHost::spawn_size(&cfg.model).unwrap());
        let dataset = Arc::new(Dataset::generate(&DatasetConfig {
            seed: cfg.seed,
            n_math: cfg.n_math,
            n_code: cfg.n_code,
            ..Default::default()
        }));
        let generator = RolloutGenerator::from_config(Arc::clone(&host), Arc::clone(&dataset), &cfg);
        let params = Arc::new(host.init_params(9).unwrap());
        let mut honest = Vec::new();
        for node in [11u64, 22, 33] {
            for idx in 0..2u64 {
                honest.push(
                    generator
                        .generate_submission(
                            &params,
                            node,
                            1,
                            idx,
                            2,
                            cfg.group_size,
                            group_id_base(node, 1, idx),
                        )
                        .unwrap(),
                );
            }
        }
        // Self-consistent (seed formula, group ids) at their claimed
        // steps, so they pass the CPU stages and exercise the
        // version-miss paths instead of SeedMismatch.
        let old = generator
            .generate_submission(&params, 44, 0, 0, 2, cfg.group_size, group_id_base(44, 0, 0))
            .unwrap();
        let future = generator
            .generate_submission(&params, 55, 5, 0, 2, cfg.group_size, group_id_base(55, 5, 0))
            .unwrap();
        Fixture { host, dataset, cfg, params, honest, old, future }
    }

    fn vcfg(&self) -> ValidatorConfig {
        ValidatorConfig {
            expected_group: self.cfg.group_size,
            max_policy_lag: self.cfg.async_level,
            ..Default::default()
        }
    }

    fn lookup(&self) -> impl Fn(u64) -> Option<Arc<ParamSet>> + '_ {
        |v| (v == 1).then(|| Arc::clone(&self.params))
    }

    /// The sequential pre-pipeline reference, one submission at a time.
    fn fullpad_verdicts(&self, batch: &[Vec<u8>], current: u64) -> Vec<Verdict> {
        let validator = Validator::new(self.vcfg());
        batch
            .iter()
            .map(|bytes| {
                validate_submission_fullpad(
                    &validator,
                    bytes,
                    &self.dataset,
                    &self.cfg.reward,
                    &self.host,
                    self.host.spec(),
                    self.cfg.max_new_tokens,
                    &|| current,
                    &self.lookup(),
                )
            })
            .collect()
    }

    fn pipeline(&self, threads: usize, bucket: usize) -> ValidationPipeline {
        ValidationPipeline::new(
            Validator::new(self.vcfg()),
            Arc::clone(&self.dataset),
            self.cfg.reward.clone(),
            Arc::clone(&self.host),
            self.cfg.max_new_tokens,
            threads,
            bucket,
        )
    }
}

fn fingerprints(verdicts: &[Verdict]) -> Vec<(&'static str, Option<u64>, String)> {
    verdicts.iter().map(Verdict::fingerprint).collect()
}

/// What the swarm loop would do with these verdicts — the counters the
/// multi-threaded validator must keep identical to the sequential path.
fn counters(verdicts: &[Verdict]) -> (u64, u64, u64, u64, u64, u64, u64) {
    let (mut accepted, mut verified, mut rejected, mut slashed) = (0, 0, 0, 0);
    let (mut unattributed, mut stale, mut stale_rollouts) = (0, 0, 0);
    for v in verdicts {
        match v {
            Verdict::Accept(sub) => {
                accepted += 1;
                verified += sub.rollouts.len() as u64;
            }
            Verdict::Stale { n_rollouts, .. } => {
                stale += 1;
                stale_rollouts += *n_rollouts as u64;
            }
            Verdict::EngineFailure { .. } => {}
            Verdict::Reject { node, .. } => {
                rejected += 1;
                match node {
                    Some(_) => slashed += 1,
                    None => unattributed += 1,
                }
            }
        }
    }
    (accepted, verified, rejected, slashed, unattributed, stale, stale_rollouts)
}

/// A deterministic mixed batch: honest + every cheating/staleness flavor.
fn mixed_batch(fx: &Fixture) -> Vec<Vec<u8>> {
    let mut batch: Vec<Vec<u8>> = fx.honest.iter().map(Submission::encode).collect();

    // Reward hacking (stage-2 reject): claim every task solved.
    let mut liar = fx.honest[0].clone();
    for w in &mut liar.rollouts {
        w.rollout.task_reward = 1.0;
        w.rollout.reward = 1.0;
    }
    batch.push(liar.encode());

    // Tampered commitment (stage-4 reject) on a non-first rollout, so the
    // min-rollout-index attribution is exercised.
    let mut forged = fx.honest[1].clone();
    forged.commitment_tamper(2);
    batch.push(forged.encode());

    // Fabricated probability reports (stage-5 reject).
    let mut fabricated = fx.honest[2].clone();
    for w in &mut fabricated.rollouts {
        for p in &mut w.rollout.sampled_probs {
            *p = 0.97;
        }
    }
    batch.push(fabricated.encode());

    // Aged-out policy version (version-miss -> stale, not slashable).
    batch.push(fx.old.encode());

    // Unpublished future version (version-miss -> provably fabricated).
    batch.push(fx.future.encode());

    // Mangled beyond attribution (checksum broken).
    let mut mangled = fx.honest[5].encode();
    let mid = mangled.len() / 2;
    mangled[mid] ^= 0x55;
    batch.push(mangled);

    batch
}

/// Test-local helper: corrupt one rollout's commitment bytes.
trait CommitmentTamper {
    fn commitment_tamper(&mut self, rollout: usize);
}

impl CommitmentTamper for Submission {
    fn commitment_tamper(&mut self, rollout: usize) {
        let r = rollout.min(self.rollouts.len() - 1);
        for b in &mut self.rollouts[r].commitment {
            *b = b.wrapping_add(31);
        }
    }
}

#[test]
fn packed_pipeline_matches_fullpad_reference() {
    if !artifacts_ready() {
        eprintln!("skipping: run `make artifacts`");
        return;
    }
    let fx = Fixture::build();
    let batch = mixed_batch(&fx);
    let want = fingerprints(&fx.fullpad_verdicts(&batch, 1));
    // Sanity on the mix itself: accepts, rejects (attributed and not) and
    // stales are all present, so the equivalence below is non-trivial.
    let (accepted, _, rejected, slashed, unattributed, stale, _) =
        counters(&fx.fullpad_verdicts(&batch, 1));
    assert!(accepted >= 1, "no honest submission accepted: {want:?}");
    assert!(rejected >= 4 && slashed >= 3 && unattributed >= 1, "mix degenerated: {want:?}");
    assert!(stale >= 1, "no stale verdict in the mix: {want:?}");

    // Threaded + packed + bucketed, across thread counts and bucket
    // grains: verdicts must be byte-identical to the reference.
    for (threads, bucket) in [(1usize, 0usize), (4, 0), (4, 1), (4, 4096), (2, 7)] {
        let pipeline = fx.pipeline(threads, bucket);
        let got = pipeline.validate_batch(batch.clone(), &|| 1, &fx.lookup());
        assert_eq!(
            fingerprints(&got),
            want,
            "pipeline(threads={threads}, bucket={bucket}) diverged from reference"
        );
    }

    // Packing really packed: 11 submissions survive to at most a handful
    // of prefill calls (the baseline issues one full-frame call per
    // submission that reaches stages 4–5).
    let pipeline = fx.pipeline(4, 0);
    let _ = pipeline.validate_batch(batch.clone(), &|| 1, &fx.lookup());
    let calls = pipeline.prefill_calls.get();
    assert!(
        (1..=3).contains(&calls),
        "expected the wave to pack into 1..=3 prefill calls, got {calls}"
    );
}

#[test]
fn threaded_counters_match_sequential() {
    if !artifacts_ready() {
        eprintln!("skipping: run `make artifacts`");
        return;
    }
    let fx = Fixture::build();
    let batch = mixed_batch(&fx);
    let sequential = fx.pipeline(1, 0).validate_batch(batch.clone(), &|| 1, &fx.lookup());
    let threaded = fx.pipeline(4, 0).validate_batch(batch, &|| 1, &fx.lookup());
    assert_eq!(counters(&sequential), counters(&threaded));
    assert_eq!(fingerprints(&sequential), fingerprints(&threaded));
}

#[test]
fn pipeline_equivalence_property_random_tampers() {
    if !artifacts_ready() {
        eprintln!("skipping: run `make artifacts`");
        return;
    }
    let fx = Fixture::build();
    // Property: for any per-submission tamper assignment, the packed
    // pipeline's verdicts equal the full-pad reference's.
    check(
        "packed pipeline == full-pad reference under random tampering",
        6,
        |rng: &mut Rng, _size| {
            fx.honest
                .iter()
                .map(|sub| {
                    let mut sub = sub.clone();
                    match rng.usize(6) {
                        0 => {} // honest
                        1 => {
                            for w in &mut sub.rollouts {
                                w.rollout.task_reward = 1.0;
                                w.rollout.reward = 1.0;
                            }
                        }
                        2 => sub.commitment_tamper(rng.usize(sub.rollouts.len())),
                        3 => {
                            let r = rng.usize(sub.rollouts.len());
                            for p in &mut sub.rollouts[r].rollout.sampled_probs {
                                *p = 0.93;
                            }
                        }
                        4 => sub = fx.old.clone(),
                        _ => sub = fx.future.clone(),
                    }
                    sub.encode()
                })
                .map(DebugBytes)
                .collect::<Vec<_>>()
        },
        |batch| {
            let bytes: Vec<Vec<u8>> = batch.iter().map(|b| b.0.clone()).collect();
            let want = fingerprints(&fx.fullpad_verdicts(&bytes, 1));
            let got = fx.pipeline(4, 0).validate_batch(bytes, &|| 1, &fx.lookup());
            ensure_eq(fingerprints(&got), want, "pipeline diverged")
        },
    );
}

/// Wrapper so the prop harness can Debug-print failing inputs tersely.
struct DebugBytes(Vec<u8>);

impl std::fmt::Debug for DebugBytes {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "<{} submission bytes>", self.0.len())
    }
}
