//! Integration: the Rust runtime executes the AOT artifacts end to end —
//! init, pretrain, GRPO step, logprobs, prefill-vs-decode consistency, and
//! the standalone Pallas attention artifact vs a Rust-computed reference.
//!
//! Requires `make artifacts` (skips with a message otherwise).

use std::sync::Arc;

use intellect2::runtime::{EngineHost, GenOpts, GrpoHp, MicroBatch, ParamSet, Runtime};
use intellect2::util::rng::Rng;

fn artifacts_ready() -> bool {
    Runtime::artifacts_dir("nano").join("spec.json").exists()
}

macro_rules! require_artifacts {
    () => {
        if !artifacts_ready() {
            eprintln!("skipping: run `make artifacts` first");
            return;
        }
    };
}

fn host() -> EngineHost {
    EngineHost::spawn_size("nano").expect("spawn engine host")
}

#[test]
fn init_is_deterministic_and_spec_sized() {
    require_artifacts!();
    let h = host();
    let a = h.init_params(7).unwrap();
    let b = h.init_params(7).unwrap();
    let c = h.init_params(8).unwrap();
    assert_eq!(a.n_params(), h.spec().n_params);
    assert_eq!(a.checksum(), b.checksum());
    assert_ne!(a.checksum(), c.checksum());
}

#[test]
fn param_bytes_roundtrip() {
    require_artifacts!();
    let h = host();
    let p = h.init_params(1).unwrap();
    let bytes = p.to_bytes();
    assert_eq!(bytes.len(), h.spec().n_params * 4);
    // Round trip through the serialized form used by SHARDCAST.
    let rt_dir = Runtime::artifacts_dir("nano");
    // from_bytes needs a Runtime; do it on a scratch thread-confined one.
    std::thread::spawn(move || {
        let rt = Runtime::load(&rt_dir).unwrap();
        let q = ParamSet::from_bytes(&rt, &bytes).unwrap();
        assert_eq!(p.checksum(), q.checksum());
    })
    .join()
    .unwrap();
}

#[test]
fn pretrain_reduces_loss() {
    require_artifacts!();
    let h = host();
    let spec = h.spec().clone();
    let (b, t) = (spec.batch_train, spec.max_seq);
    // Repeating pattern corpus.
    let mut tokens = vec![0i32; b * t];
    for r in 0..b {
        for c in 0..t {
            tokens[r * t + c] = 3 + ((c + r) % 8) as i32;
        }
    }
    let segs = vec![1i32; b * t];
    let mut st = h.fresh_train_state(42).unwrap();
    let mut losses = Vec::new();
    for _ in 0..6 {
        let (st2, loss, gnorm) = h
            .pretrain_step(st, tokens.clone(), segs.clone(), 1e-2, 1.0)
            .unwrap();
        st = st2;
        assert!(loss.is_finite() && gnorm.is_finite());
        losses.push(loss);
    }
    assert!(losses[5] < losses[0] * 0.8, "{losses:?}");
}

#[test]
fn grpo_step_invariants_at_ratio_one() {
    require_artifacts!();
    let h = host();
    let spec = h.spec().clone();
    let (b, t) = (spec.batch_train, spec.max_seq);
    let mut rng = Rng::new(3);
    let tokens: Vec<i32> = (0..b * t).map(|_| 3 + rng.usize(60) as i32).collect();
    let segs = vec![1i32; b * t];
    let mut loss_mask = vec![1.0f32; b * t];
    for r in 0..b {
        loss_mask[r * t] = 0.0;
    }
    let adv: Vec<f32> = (0..b * t).map(|_| rng.normal() as f32).collect();

    let st = h.fresh_train_state(9).unwrap();
    let (lp, _ent, _valid) = h
        .logprobs(Arc::new(st.params.clone()), tokens.clone(), segs.clone())
        .unwrap();

    let mb = MicroBatch {
        tokens,
        segs,
        loss_mask,
        advantages: adv,
        old_logprobs: lp,
    };
    let (st2, m) = h.grpo_step(st, mb, GrpoHp::default()).unwrap();
    assert!(m.loss.is_finite());
    assert_eq!(m.clipfrac, 0.0);
    assert!((m.ratio_max - 1.0).abs() < 1e-4, "{}", m.ratio_max);
    assert!(m.kl.abs() < 1e-5);
    assert!(m.gnorm > 0.0);
    assert_eq!(st2.step, 1);
}

#[test]
fn generation_terminates_and_reports_probs() {
    require_artifacts!();
    let h = host();
    let params = Arc::new(h.init_params(5).unwrap());
    let prompts: Vec<Vec<i32>> = (0..4)
        .map(|i| {
            let mut p = vec![h.spec().bos_id];
            p.extend((0..6).map(|j| 3 + ((i + j) % 10) as i32));
            p
        })
        .collect();
    let opts = GenOpts { max_new: 40, temperature: 1.0, commit_interval: 32 };
    let gens = h.generate(params, prompts.clone(), opts, 77).unwrap();
    assert_eq!(gens.len(), 4);
    for (g, p) in gens.iter().zip(&prompts) {
        assert_eq!(g.prompt_len, p.len());
        assert_eq!(&g.tokens[..p.len()], &p[..]);
        assert!(g.completion_len() <= 40);
        assert_eq!(g.sampled_probs.len(), g.completion_len());
        for &pr in &g.sampled_probs {
            assert!((0.0..=1.0).contains(&pr));
        }
        // At least the final hidden row is captured.
        assert!(!g.hidden_rows.is_empty());
        let d = h.spec().d_model;
        for (_, row) in &g.hidden_rows {
            assert_eq!(row.len(), d);
        }
    }
}

#[test]
fn generation_is_deterministic_given_seed() {
    require_artifacts!();
    let h = host();
    let params = Arc::new(h.init_params(5).unwrap());
    let prompts = vec![vec![1, 4, 5, 6], vec![1, 7, 8, 9, 10]];
    let opts = GenOpts { max_new: 24, temperature: 1.0, commit_interval: 32 };
    let a = h.generate(params.clone(), prompts.clone(), opts, 123).unwrap();
    let b = h.generate(params.clone(), prompts.clone(), opts, 123).unwrap();
    let c = h.generate(params, prompts, opts, 124).unwrap();
    for (x, y) in a.iter().zip(&b) {
        assert_eq!(x.tokens, y.tokens);
    }
    assert!(a.iter().zip(&c).any(|(x, y)| x.tokens != y.tokens));
}

#[test]
fn prefill_matches_decode_hidden_states() {
    require_artifacts!();
    let h = host();
    let spec = h.spec().clone();
    let params = Arc::new(h.init_params(11).unwrap());
    let prompts = vec![vec![1, 5, 9, 13, 17, 21]];
    let opts = GenOpts { max_new: 40, temperature: 0.8, commit_interval: 8 };
    let gens = h.generate(params.clone(), prompts, opts, 5).unwrap();
    let g = &gens[0];

    // Validator-style prefill over the full generated sequence.
    let mut padded = vec![spec.pad_id; spec.batch_infer * spec.max_seq];
    for (i, &tok) in g.tokens.iter().enumerate() {
        padded[i] = tok;
    }
    let (_logits, hidden) = h.prefill(params, padded).unwrap();
    let d = spec.d_model;
    for (pos, row) in &g.hidden_rows {
        let pre = &hidden[pos * d..(pos + 1) * d];
        let max_err = row
            .iter()
            .zip(pre)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0f32, f32::max);
        assert!(max_err < 2e-3, "pos {pos}: {max_err}");
    }
}

#[test]
fn pallas_attention_artifact_matches_rust_reference() {
    require_artifacts!();
    // attn_demo: q,k,v f32[2, H, T, Dh] -> causal attention via the Pallas
    // kernel, lowered standalone. Compare against a plain Rust softmax
    // implementation (cross-layer composability proof).
    let rt_dir = Runtime::artifacts_dir("nano");
    std::thread::spawn(move || {
        let rt = Runtime::load(&rt_dir).unwrap();
        let meta = rt.spec.artifact("attn_demo").unwrap().clone();
        let shape = meta.inputs[0].shape.clone(); // [2, H, T, Dh]
        let numel: usize = shape.iter().product();
        let mut rng = Rng::new(1);
        let q: Vec<f32> = (0..numel).map(|_| rng.normal() as f32 * 0.5).collect();
        let k: Vec<f32> = (0..numel).map(|_| rng.normal() as f32 * 0.5).collect();
        let v: Vec<f32> = (0..numel).map(|_| rng.normal() as f32 * 0.5).collect();
        let outs = rt
            .call(
                "attn_demo",
                &[
                    intellect2::runtime::client::lit_f32(&q, &shape),
                    intellect2::runtime::client::lit_f32(&k, &shape),
                    intellect2::runtime::client::lit_f32(&v, &shape),
                ],
            )
            .unwrap();
        let got = outs[0].to_vec::<f32>().unwrap();

        let (b, hh, t, dh) = (shape[0], shape[1], shape[2], shape[3]);
        let scale = 1.0 / (dh as f32).sqrt();
        let mut want = vec![0.0f32; numel];
        for bi in 0..b {
            for hi in 0..hh {
                let base = (bi * hh + hi) * t * dh;
                for qi in 0..t {
                    // scores over keys 0..=qi
                    let mut scores = vec![0.0f32; qi + 1];
                    for ki in 0..=qi {
                        let mut s = 0.0;
                        for di in 0..dh {
                            s += q[base + qi * dh + di] * k[base + ki * dh + di];
                        }
                        scores[ki] = s * scale;
                    }
                    let max = scores.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
                    let exps: Vec<f32> = scores.iter().map(|s| (s - max).exp()).collect();
                    let z: f32 = exps.iter().sum();
                    for di in 0..dh {
                        let mut o = 0.0;
                        for ki in 0..=qi {
                            o += exps[ki] / z * v[base + ki * dh + di];
                        }
                        want[base + qi * dh + di] = o;
                    }
                }
            }
        }
        let max_err = got
            .iter()
            .zip(&want)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0f32, f32::max);
        assert!(max_err < 2e-4, "pallas attention vs rust ref: {max_err}");
    })
    .join()
    .unwrap();
}
