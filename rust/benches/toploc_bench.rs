//! TOPLOC verification throughput (Fig 3's claim: the verifier audits up
//! to ~100x faster than generation, because it runs batched prefill
//! instead of T sequential decode steps) — measured on mixed-length
//! batches, comparing the pre-pipeline baseline (one submission at a
//! time, every prefill padded to the full `batch_infer x max_seq` frame)
//! against the packed, length-bucketed plan the validation pipeline
//! executes. Emits `BENCH_toploc.json` (rollouts/s + speedups) so the
//! perf trajectory is tracked across PRs.
//!
//!   cargo bench --bench toploc_bench

use std::sync::Arc;

use intellect2::coordinator::ValidatorCommitment;
use intellect2::rl::rollout_file::WireRollout;
use intellect2::rl::Rollout;
use intellect2::runtime::{EngineHost, GenOpts, Generation, Runtime};
use intellect2::toploc::pipeline::{plan_padding_fraction, plan_prefills, LaneReq};
use intellect2::toploc::{Commitment, Validator, ValidatorConfig};
use intellect2::util::bench::{BenchReport, Bencher};

/// Wrap a generation as the wire rollout the validator's stage-4/5 checks
/// consume (sanity-stage fields are irrelevant here).
fn wire(g: &Generation, topk: usize) -> WireRollout {
    WireRollout {
        rollout: Rollout {
            task_id: 0,
            group_id: 0,
            policy_step: 0,
            tokens: g.tokens.clone(),
            prompt_len: g.prompt_len,
            target_len: None,
            task_reward: 0.0,
            length_penalty: 0.0,
            reward: 0.0,
            advantage: 0.0,
            sampled_probs: g.sampled_probs.clone(),
            node_address: 0,
        },
        commitment: Commitment::build(&g.hidden_rows, topk).encode(),
        finish_eos: false,
        eos_prob: 0.0,
    }
}

fn main() -> anyhow::Result<()> {
    if !Runtime::artifacts_dir("nano").join("spec.json").exists() {
        eprintln!("skipping toploc_bench: run `make artifacts`");
        return Ok(());
    }
    let host = Arc::new(EngineHost::spawn_size("nano")?);
    let spec = host.spec().clone();
    let params = Arc::new(host.init_params(1)?);
    let validator = Validator::new(ValidatorConfig::default());
    let b = Bencher::quick();
    let mut report = BenchReport::new("toploc");

    // Mixed-length rollout pool: three generation batches with different
    // budgets, real commitments + sampled probs (what honest workers ship).
    let budgets = [16usize, 48, 96];
    let prompts: Vec<Vec<i32>> = (0..spec.batch_infer)
        .map(|i| {
            let mut p = vec![1i32];
            p.extend((0..8).map(|j| 3 + ((i + j) % 10) as i32));
            p
        })
        .collect();
    let gen_batch = |max_new: usize, seed: u64| {
        let opts = GenOpts { max_new, temperature: 1.0, commit_interval: spec.toploc_interval };
        host.generate(Arc::clone(&params), prompts.clone(), opts, seed)
    };

    // Generation cost (what the untrusted workers pay for the same pool).
    let mut gens: Vec<Generation> = Vec::new();
    let r_gen = b.run("generate pool (decode loops, budgets 16/48/96)", || {
        gens.clear();
        for (bi, &max_new) in budgets.iter().enumerate() {
            gens.extend(gen_batch(max_new, 7 + bi as u64).unwrap());
        }
    });
    report.record(&r_gen);
    let n_rollouts = gens.len() as f64;

    // Carve the pool into per-node "submissions" of GRPO-group size — the
    // unit the baseline validator padded a whole batch frame for.
    let group = 4usize;
    let wires: Vec<WireRollout> = gens.iter().map(|g| wire(g, spec.toploc_topk)).collect();
    let subs: Vec<Vec<WireRollout>> = wires.chunks(group).map(|c| c.to_vec()).collect();
    let (bi, t, d, v) = (spec.batch_infer, spec.max_seq, spec.d_model, spec.vocab);

    // Baseline: one submission at a time, full [B, max_seq] frame — most
    // lanes empty, every lane padded to max_seq.
    let r_base = b.run_throughput(
        "verify baseline (per-submission, full-pad)",
        n_rollouts,
        "rollouts",
        || {
            for sub in &subs {
                for chunk in sub.chunks(bi) {
                    let mut padded = vec![spec.pad_id; bi * t];
                    for (i, w) in chunk.iter().enumerate() {
                        padded[i * t..i * t + w.rollout.tokens.len()]
                            .copy_from_slice(&w.rollout.tokens);
                    }
                    let (logits, hidden) =
                        host.prefill(Arc::clone(&params), padded).unwrap();
                    for (i, w) in chunk.iter().enumerate() {
                        validator
                            .check_computation(w, &hidden[i * t * d..(i + 1) * t * d], d)
                            .expect("honest commitment");
                        validator
                            .check_sampling(w, &logits[i * t * v..(i + 1) * t * v], v)
                            .expect("honest sampling");
                    }
                }
            }
        },
    );
    report.record(&r_base);

    // Packed: lanes from all submissions, length-bucketed, all lanes full.
    let lanes: Vec<LaneReq> = subs
        .iter()
        .enumerate()
        .flat_map(|(si, sub)| {
            sub.iter().enumerate().map(move |(ri, w)| LaneReq {
                sub: si,
                rollout: ri,
                len: w.rollout.tokens.len(),
            })
        })
        .collect();
    let plan = plan_prefills(lanes.clone(), bi, spec.toploc_interval, t);
    let r_packed = b.run_throughput(
        "verify packed (cross-submission, length-bucketed)",
        n_rollouts,
        "rollouts",
        || {
            for call in plan_prefills(lanes.clone(), bi, spec.toploc_interval, t) {
                let sl = call.seq_len;
                let mut padded = vec![spec.pad_id; call.lanes.len() * sl];
                for (lane, l) in call.lanes.iter().enumerate() {
                    let toks = &subs[l.sub][l.rollout].rollout.tokens;
                    padded[lane * sl..lane * sl + toks.len()].copy_from_slice(toks);
                }
                let (logits, hidden, stride) = host
                    .prefill_rows(Arc::clone(&params), padded, call.lanes.len(), sl)
                    .unwrap();
                for (lane, l) in call.lanes.iter().enumerate() {
                    let w = &subs[l.sub][l.rollout];
                    validator
                        .check_computation(w, &hidden[lane * stride * d..(lane + 1) * stride * d], d)
                        .expect("honest commitment");
                    validator
                        .check_sampling(w, &logits[lane * stride * v..(lane + 1) * stride * v], v)
                        .expect("honest sampling");
                }
            }
        },
    );
    report.record(&r_packed);

    // Sampled validation (the trust-weighted gate at its floor rate):
    // only commitment-selected submissions pay stages 4-5; the rest are
    // admitted after stage 0 + decode, which is ns-scale next to prefill.
    // Selection takes the bottom quantile of the commitment draws rather
    // than thresholding each draw, pinning the sampled share at exactly
    // the configured rate — the bench wants a stable figure, not one
    // binomial sample of it.
    let rate = 0.1f64;
    let auditor = ValidatorCommitment::new(0xBE9C);
    let mut draws: Vec<(usize, f64)> =
        (0..subs.len()).map(|si| (si, auditor.draw(0, si as u64, 0))).collect();
    draws.sort_by(|a, b| a.1.partial_cmp(&b.1).unwrap());
    let n_selected = ((subs.len() as f64 * rate).ceil() as usize).max(1);
    let selected: Vec<usize> = draws[..n_selected].iter().map(|d| d.0).collect();
    let sampled_lanes: Vec<LaneReq> =
        lanes.iter().filter(|l| selected.contains(&l.sub)).cloned().collect();
    let r_sampled = b.run_throughput(
        "verify sampled (rate 0.1, commitment-selected)",
        // Throughput over the whole admitted pool: skipped submissions'
        // rewards are admitted too (on stake + trust), so every rollout
        // counts against the validator compute spent here.
        n_rollouts,
        "rollouts",
        || {
            for call in plan_prefills(sampled_lanes.clone(), bi, spec.toploc_interval, t) {
                let sl = call.seq_len;
                let mut padded = vec![spec.pad_id; call.lanes.len() * sl];
                for (lane, l) in call.lanes.iter().enumerate() {
                    let toks = &subs[l.sub][l.rollout].rollout.tokens;
                    padded[lane * sl..lane * sl + toks.len()].copy_from_slice(toks);
                }
                let (logits, hidden, stride) = host
                    .prefill_rows(Arc::clone(&params), padded, call.lanes.len(), sl)
                    .unwrap();
                for (lane, l) in call.lanes.iter().enumerate() {
                    let w = &subs[l.sub][l.rollout];
                    validator
                        .check_computation(w, &hidden[lane * stride * d..(lane + 1) * stride * d], d)
                        .expect("honest commitment");
                    validator
                        .check_sampling(w, &logits[lane * stride * v..(lane + 1) * stride * v], v)
                        .expect("honest sampling");
                }
            }
        },
    );
    report.record(&r_sampled);

    let base_calls = subs.iter().map(|s| s.chunks(bi).count()).sum::<usize>();
    let packed_speedup = r_base.mean_ns / r_packed.mean_ns;
    let gen_vs_verify = r_gen.mean_ns / r_packed.mean_ns;
    println!(
        "\npacked pipeline speedup over full-pad baseline: {packed_speedup:.1}x \
         ({base_calls} prefill calls -> {}, lane padding waste {:.0}%)",
        plan.len(),
        100.0 * plan_padding_fraction(&plan, bi)
    );
    println!(
        "verification speedup vs generation: {gen_vs_verify:.1}x (paper claims up to ~100x \
         at 32B scale; grows with sequence length and random sub-sampling)"
    );

    // Proof-construction overhead (§2.1.2 claims ~1%): generation with vs
    // without hidden-state capture is identical in our engine (hidden rows
    // are returned either way by decode_step); the marginal cost is the
    // top-k, measured here per pool:
    let rows: Vec<(usize, Vec<f32>)> =
        gens.iter().flat_map(|g| g.hidden_rows.clone()).collect();
    let r_commit = b.run("commitment construction (top-k over captured rows)", || {
        let _ = Commitment::build(&rows, spec.toploc_topk);
    });
    report.record(&r_commit);
    println!(
        "proof construction overhead: {:.2}% of generation (paper: ~1%)",
        100.0 * r_commit.mean_ns / r_gen.mean_ns
    );

    report.metric("verify_rollouts_per_sec", n_rollouts / (r_packed.mean_ns / 1e9));
    report.metric("baseline_rollouts_per_sec", n_rollouts / (r_base.mean_ns / 1e9));
    report.metric("packed_speedup_vs_fullpad", packed_speedup);
    report.metric("gen_vs_verify_speedup", gen_vs_verify);
    report.metric("prefill_calls_baseline", base_calls as f64);
    report.metric("prefill_calls_packed", plan.len() as f64);
    report.metric("packed_padding_fraction", plan_padding_fraction(&plan, bi));
    report.metric("proof_overhead_frac", r_commit.mean_ns / r_gen.mean_ns);

    // Sampled-validation figures: the win the trust-weighted gate buys is
    // a near-1/r throughput multiplier at rate r, because stages 4-5 are
    // the only per-token validator cost that matters.
    let sampled_speedup = r_packed.mean_ns / r_sampled.mean_ns;
    let total_tokens: usize = wires.iter().map(|w| w.rollout.tokens.len()).sum();
    println!(
        "sampled validation at rate {rate}: {sampled_speedup:.1}x over full verification \
         ({n_selected} of {} submissions selected)",
        subs.len()
    );
    anyhow::ensure!(
        sampled_speedup >= 3.0,
        "sampled validation at rate {rate} only {sampled_speedup:.2}x over full verification \
         (want >= 3x)"
    );
    report.metric("sampled_speedup", sampled_speedup);
    report.metric(
        "validator_compute_per_verified_token",
        r_sampled.mean_ns / total_tokens as f64,
    );
    let path = report.write()?;
    println!("wrote {}", path.display());
    Ok(())
}
