//! TOPLOC verification speed vs generation speed (Fig 3's claim: the
//! verifier audits up to ~100x faster than generation, because it runs a
//! single batched prefill instead of T sequential decode steps).
//!
//!   cargo bench --bench toploc_bench

use std::sync::Arc;

use intellect2::runtime::{EngineHost, GenOpts, Runtime};
use intellect2::toploc::Commitment;
use intellect2::util::bench::Bencher;

fn main() -> anyhow::Result<()> {
    if !Runtime::artifacts_dir("nano").join("spec.json").exists() {
        eprintln!("skipping toploc_bench: run `make artifacts`");
        return Ok(());
    }
    let host = Arc::new(EngineHost::spawn_size("nano")?);
    let spec = host.spec().clone();
    let params = Arc::new(host.init_params(1)?);

    let max_new = 96usize;
    let prompts: Vec<Vec<i32>> = (0..spec.batch_infer)
        .map(|i| {
            let mut p = vec![1i32];
            p.extend((0..8).map(|j| 3 + ((i + j) % 10) as i32));
            p
        })
        .collect();
    let opts = GenOpts { max_new, temperature: 1.0, commit_interval: spec.toploc_interval };

    let b = Bencher::quick();

    // Generation (what the untrusted worker pays).
    let mut gens = Vec::new();
    let r_gen = b.run("generate batch (decode loop, B=16, 96 new tokens)", || {
        gens = host.generate(Arc::clone(&params), prompts.clone(), opts, 7).unwrap();
    });

    // Verification (what the validator pays): one prefill + top-k checks.
    let mut padded = vec![spec.pad_id; spec.batch_infer * spec.max_seq];
    for (i, g) in gens.iter().enumerate() {
        for (j, &tok) in g.tokens.iter().enumerate() {
            padded[i * spec.max_seq + j] = tok;
        }
    }
    let commits: Vec<Commitment> = gens
        .iter()
        .map(|g| Commitment::build(&g.hidden_rows, spec.toploc_topk))
        .collect();
    let d = spec.d_model;
    let r_ver = b.run("verify batch (single prefill + top-k compare)", || {
        let (_logits, hidden) = host.prefill(Arc::clone(&params), padded.clone()).unwrap();
        for (i, (g, c)) in gens.iter().zip(&commits).enumerate() {
            let h = &hidden[i * spec.max_seq * d..(i + 1) * spec.max_seq * d];
            c.verify_against(h, d, g.tokens.len()).expect("honest commitment");
        }
    });

    println!(
        "\nverification speedup: {:.1}x (paper claims up to ~100x at 32B scale; \
         grows with sequence length and with random sub-sampling of batches)",
        r_gen.mean_ns / r_ver.mean_ns
    );

    // Proof-construction overhead (§2.1.2 claims ~1%): generation with vs
    // without hidden-state capture is identical in our engine (hidden rows
    // are returned either way by decode_step); the marginal cost is the
    // top-k, measured here per batch:
    let rows: Vec<(usize, Vec<f32>)> =
        gens.iter().flat_map(|g| g.hidden_rows.clone()).collect();
    let r_commit = b.run("commitment construction (top-k over captured rows)", || {
        let _ = Commitment::build(&rows, spec.toploc_topk);
    });
    println!(
        "proof construction overhead: {:.2}% of generation (paper: ~1%)",
        100.0 * r_commit.mean_ns / r_gen.mean_ns
    );
    Ok(())
}
