//! SHARDCAST benchmarks: broadcast throughput through the relay tree, and
//! the §2.2.2 claim that probabilistic EMA relay selection beats greedily
//! picking the single fastest relay (multiple connections aggregate
//! bandwidth; contention is avoided).
//!
//!   cargo bench --bench shardcast_bench

use std::time::Duration;

use intellect2::http::ServerConfig;
use intellect2::shardcast::{Broadcaster, Origin, Relay, ShardcastClient};
use intellect2::util::bench::Bencher;

fn wait_complete(relays: &[Relay], step: u64) {
    let deadline = std::time::Instant::now() + Duration::from_secs(20);
    while !relays.iter().all(|r| r.store.is_complete(step)) {
        assert!(std::time::Instant::now() < deadline, "relay mirror timeout");
        std::thread::sleep(Duration::from_millis(5));
    }
}

fn main() -> anyhow::Result<()> {
    let payload: Vec<u8> = (0..2_000_000u32).map(|i| (i % 251) as u8).collect();

    // --- raw relay-tree throughput (unshaped) ---
    let origin = Origin::start(ServerConfig::default())?;
    origin.publish(1, &payload, 64 * 1024);
    let relays: Vec<Relay> = (0..3)
        .map(|i| {
            Relay::start(
                &format!("r{i}"),
                origin.url(),
                ServerConfig::default(),
                Duration::from_millis(5),
            )
            .unwrap()
        })
        .collect();
    wait_complete(&relays, 1);
    let urls: Vec<String> = relays.iter().map(Relay::url).collect();

    let b = Bencher::quick();
    let client = ShardcastClient::new("bench-worker", &urls, 1, true);
    b.run_throughput("checkpoint fetch (2 MB, 3 relays, EMA selection)", 2.0, "MB", || {
        let (got, _) = client.fetch_checkpoint(1).unwrap();
        assert_eq!(got.len(), payload.len());
    });

    // --- EMA-vs-greedy under heterogeneous relays (one fast, two slow) ---
    let origin2 = Origin::start(ServerConfig::default())?;
    origin2.publish(1, &payload, 64 * 1024);
    let mk = |name: &str, bps: u64| {
        Relay::start(
            name,
            origin2.url(),
            ServerConfig { egress_bytes_per_sec: bps, ..Default::default() },
            Duration::from_millis(5),
        )
        .unwrap()
    };
    let het = vec![mk("fast", 0), mk("slow1", 4_000_000), mk("slow2", 4_000_000)];
    wait_complete(&het, 1);
    let het_urls: Vec<String> = het.iter().map(Relay::url).collect();

    let ema_client = ShardcastClient::new("ema", &het_urls, 2, true);
    let r_ema = b.run("heterogeneous fetch, EMA probabilistic selection", || {
        ema_client.fetch_checkpoint(1).unwrap();
    });
    // "Greedy": a client pinned to the fastest relay only.
    let greedy_client = ShardcastClient::new("greedy", &het_urls[..1].to_vec(), 3, true);
    let r_greedy = b.run("heterogeneous fetch, greedy single-fastest relay", || {
        greedy_client.fetch_checkpoint(1).unwrap();
    });
    println!(
        "\nEMA vs greedy: {:.2}x (≥ ~1x expected: EMA matches or beats greedy by \
         spreading shards across relays; gap grows under contention)",
        r_greedy.mean_ns / r_ema.mean_ns
    );

    // --- background broadcaster: publish latency seen by the trainer ---
    // The trainer only pays enqueue + serialization; the shard/publish/
    // mirror pipeline runs on the broadcast thread (two-step async, §3.2).
    {
        let origin3 = Origin::start(ServerConfig::default())?;
        let relays3: Vec<Relay> = (0..2)
            .map(|i| {
                Relay::start(
                    &format!("b{i}"),
                    origin3.url(),
                    ServerConfig::default(),
                    Duration::from_millis(5),
                )
                .unwrap()
            })
            .collect();
        let bc = Broadcaster::start(
            origin3.store.clone(),
            relays3.iter().map(|r| r.store.clone()).collect(),
            64 * 1024,
            Duration::from_secs(20),
            8,
        )?;
        let t0 = std::time::Instant::now();
        for step in 1..=8u64 {
            bc.enqueue(step, payload.clone())?;
        }
        let enqueue_secs = t0.elapsed().as_secs_f64();
        let records = bc.finish();
        let total: f64 = records.iter().map(|r| r.total_secs()).sum();
        println!(
            "\nbackground broadcast: 8 x 2 MB enqueued in {:.4}s (trainer-side cost); \
             {:.2}s of publish+mirror ran off-thread ({} timed out)",
            enqueue_secs,
            total,
            records.iter().filter(|r| r.timed_out).count()
        );
    }

    // --- contention: 4 clients at once, EMA spreads load ---
    let t0 = std::time::Instant::now();
    std::thread::scope(|s| {
        for i in 0..4 {
            let urls = het_urls.clone();
            s.spawn(move || {
                let c = ShardcastClient::new(&format!("c{i}"), &urls, 10 + i, true);
                c.fetch_checkpoint(1).unwrap();
            });
        }
    });
    println!(
        "4 concurrent EMA clients, 2 MB each: {:.2}s total ({:.2} MB/s aggregate)",
        t0.elapsed().as_secs_f64(),
        8.0 / t0.elapsed().as_secs_f64()
    );
    Ok(())
}
