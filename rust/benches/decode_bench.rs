//! Rollout-generation throughput (the inference-worker hot path): batched
//! KV-cache decode tokens/sec, plus prefill latency for the validator.
//!
//!   cargo bench --bench decode_bench

use std::sync::Arc;

use intellect2::runtime::{EngineHost, GenOpts, Runtime};
use intellect2::util::bench::Bencher;

fn main() -> anyhow::Result<()> {
    for size in ["nano", "micro"] {
        if !Runtime::artifacts_dir(size).join("spec.json").exists() {
            eprintln!("skipping {size}: run `make artifacts`");
            continue;
        }
        let host = Arc::new(EngineHost::spawn_size(size)?);
        let spec = host.spec().clone();
        let params = Arc::new(host.init_params(1)?);
        let b = Bencher::quick();

        for batch in [1usize, 4, spec.batch_infer] {
            let prompts: Vec<Vec<i32>> = (0..batch)
                .map(|i| {
                    let mut p = vec![1i32];
                    p.extend((0..8).map(|j| 3 + ((i + j) % 10) as i32));
                    p
                })
                .collect();
            let max_new = 48;
            let opts = GenOpts { max_new, temperature: 1.0, commit_interval: 32 };
            let mut produced = 0usize;
            let r = b.run(&format!("{size}: generate B={batch} x {max_new} new tokens"), || {
                let gens =
                    host.generate(Arc::clone(&params), prompts.clone(), opts, 7).unwrap();
                produced = gens.iter().map(|g| g.completion_len()).sum();
            });
            println!(
                "  -> {:.0} tokens/s (batch {batch})",
                produced as f64 / (r.mean_ns / 1e9)
            );
        }

        // Validator prefill (full [B,T] in one call).
        let padded = vec![spec.pad_id; spec.batch_infer * spec.max_seq];
        let toks = (spec.batch_infer * spec.max_seq) as f64;
        b.run_throughput(
            &format!("{size}: prefill B={} T={}", spec.batch_infer, spec.max_seq),
            toks,
            "tok",
            || {
                host.prefill(Arc::clone(&params), padded.clone()).unwrap();
            },
        );
    }
    Ok(())
}
