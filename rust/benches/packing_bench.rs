//! Sequence packing (§4.1): padding waste of FFD cross-sample packing vs
//! the naive one-sample-per-row layout, over realistic completion-length
//! distributions, plus packing throughput.
//!
//!   cargo bench --bench packing_bench

use intellect2::rl::packing::pack;
use intellect2::rl::Rollout;
use intellect2::util::bench::{BenchReport, Bencher};
use intellect2::util::metrics::render_table;
use intellect2::util::rng::Rng;

fn mk(len: usize, rng: &mut Rng) -> Rollout {
    Rollout {
        task_id: 0,
        group_id: rng.next_u64(),
        policy_step: 0,
        tokens: (0..len as i32).map(|i| 3 + i % 50).collect(),
        prompt_len: (len / 3).max(1),
        target_len: None,
        task_reward: 0.0,
        length_penalty: 0.0,
        reward: 0.0,
        advantage: 1.0,
        sampled_probs: Vec::new(),
        node_address: 0,
    }
}

fn main() {
    let (b_rows, t) = (8usize, 256usize);
    let mut report = BenchReport::new("packing");
    let mut rows = Vec::new();
    for (label, lo, hi) in [
        ("uniform short (16..64)", 16usize, 64usize),
        ("uniform wide (16..240)", 16, 240),
        ("bimodal (short+long)", 0, 0),
        ("near-full (200..250)", 200, 250),
    ] {
        let mut rng = Rng::new(42);
        let rollouts: Vec<Rollout> = (0..256)
            .map(|i| {
                let len = if label.starts_with("bimodal") {
                    if i % 4 == 0 {
                        180 + rng.usize(60)
                    } else {
                        16 + rng.usize(32)
                    }
                } else {
                    lo + rng.usize(hi - lo)
                };
                mk(len, &mut rng)
            })
            .collect();
        let out = pack(&rollouts, b_rows, t);
        let key = label.split(" (").next().unwrap_or(label).replace(' ', "_");
        report.metric(&format!("{key}_packed_waste"), out.padding_fraction);
        report.metric(&format!("{key}_naive_waste"), out.naive_padding_fraction);
        report.metric(
            &format!("{key}_compute_gain"),
            (1.0 - out.padding_fraction) / (1.0 - out.naive_padding_fraction),
        );
        rows.push(vec![
            label.to_string(),
            format!("{:.1}%", 100.0 * out.padding_fraction),
            format!("{:.1}%", 100.0 * out.naive_padding_fraction),
            format!(
                "{:.2}x",
                (1.0 - out.padding_fraction) / (1.0 - out.naive_padding_fraction)
            ),
            out.batches.len().to_string(),
        ]);
    }
    println!("== §4.1 packing efficiency (256 rollouts into [8, 256] batches) ==");
    println!(
        "{}",
        render_table(
            &["length distribution", "packed waste", "naive waste", "compute gain", "batches"],
            &rows
        )
    );

    let mut rng = Rng::new(7);
    let rollouts: Vec<Rollout> = (0..1024).map(|_| mk(16 + rng.usize(224), &mut rng)).collect();
    let b = Bencher::default();
    let r = b.run_throughput("pack 1024 rollouts (FFD)", 1024.0, "rollouts", || {
        let out = pack(&rollouts, b_rows, t);
        assert!(!out.batches.is_empty());
    });
    report.record(&r);
    report.metric("pack_rollouts_per_sec", 1024.0 / (r.mean_ns / 1e9));
    match report.write() {
        Ok(path) => println!("wrote {}", path.display()),
        Err(e) => eprintln!("bench json not written: {e}"),
    }
}
