//! GRPO trainer-step latency end-to-end (the L2/L1 hot path as executed by
//! the L3 trainer): packed micro-batch GRPO step, logprobs recompute, and
//! the pretrain step, per model size.
//!
//!   cargo bench --bench grpo_bench

use std::sync::Arc;

use intellect2::runtime::{EngineHost, GrpoHp, MicroBatch, Runtime};
use intellect2::util::bench::Bencher;
use intellect2::util::rng::Rng;

fn main() -> anyhow::Result<()> {
    for size in ["nano", "micro"] {
        if !Runtime::artifacts_dir(size).join("spec.json").exists() {
            eprintln!("skipping {size}: run `make artifacts`");
            continue;
        }
        let host = Arc::new(EngineHost::spawn_size(size)?);
        let spec = host.spec().clone();
        let (bt, t) = (spec.batch_train, spec.max_seq);
        let mut rng = Rng::new(1);
        let tokens: Vec<i32> = (0..bt * t).map(|_| 3 + rng.usize(60) as i32).collect();
        let segs = vec![1i32; bt * t];
        let mut loss_mask = vec![1.0f32; bt * t];
        for r in 0..bt {
            loss_mask[r * t] = 0.0;
        }
        let adv: Vec<f32> = (0..bt * t).map(|_| rng.normal() as f32).collect();

        let mut state = host.fresh_train_state(1)?;
        let (lp, _, _) =
            host.logprobs(Arc::new(state.params.clone()), tokens.clone(), segs.clone())?;
        let mb = MicroBatch {
            tokens: tokens.clone(),
            segs: segs.clone(),
            loss_mask,
            advantages: adv,
            old_logprobs: lp,
        };
        let hp = GrpoHp::default();
        let b = Bencher::quick();
        let tokens_per_step = (bt * t) as f64;

        b.run_throughput(
            &format!("{size}: grpo_step (fwd+bwd+Adam, fused Pallas loss)"),
            tokens_per_step,
            "tok",
            || {
                let (st, m) = host.grpo_step(state.clone(), mb.clone(), hp).unwrap();
                state = st;
                assert!(m.loss.is_finite());
            },
        );
        b.run_throughput(
            &format!("{size}: logprobs recompute (fwd only)"),
            tokens_per_step,
            "tok",
            || {
                host.logprobs(Arc::new(state.params.clone()), tokens.clone(), segs.clone())
                    .unwrap();
            },
        );
        let mut pre_state = host.fresh_train_state(2)?;
        b.run_throughput(
            &format!("{size}: pretrain_step (next-token CE + Adam)"),
            tokens_per_step,
            "tok",
            || {
                let (st, loss, _) = host
                    .pretrain_step(pre_state.clone(), tokens.clone(), segs.clone(), 1e-3, 1.0)
                    .unwrap();
                pre_state = st;
                assert!(loss.is_finite());
            },
        );
        // Model FLOPs utilization estimate: 6 * P * tokens per train step.
        let p = spec.n_params as f64;
        println!(
            "  ({size}: {:.0}M params, {:.2} GFLOP per grpo_step)",
            p / 1e6,
            6.0 * p * tokens_per_step / 1e9
        );
    }
    Ok(())
}
