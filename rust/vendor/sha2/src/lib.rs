//! Vendored from-scratch SHA-256 (FIPS 180-4), exposing the subset of the
//! `sha2` crate API this repository uses: `Sha256`, the `Digest` trait
//! (`new` / `update` / `finalize` / `digest`) and a 32-byte output that
//! converts into `[u8; 32]` and derefs to a byte slice.
//!
//! The offline crate set has no crates.io access, so the workspace vendors
//! this minimal implementation instead (path dependency — see
//! `rust/Cargo.toml` and the CI workflow notes). Correctness is pinned by
//! the FIPS 180-4 test vectors below.

/// Rolling hash state for one SHA-256 computation.
#[derive(Clone)]
pub struct Sha256 {
    state: [u32; 8],
    /// Total message length in bytes (the padding footer needs bits).
    len: u64,
    buf: [u8; 64],
    buf_len: usize,
}

/// 32-byte digest. Derefs to `[u8]` and converts into `[u8; 32]`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Output([u8; 32]);

impl Output {
    pub fn as_slice(&self) -> &[u8] {
        &self.0
    }
}

impl From<Output> for [u8; 32] {
    fn from(o: Output) -> [u8; 32] {
        o.0
    }
}

impl AsRef<[u8]> for Output {
    fn as_ref(&self) -> &[u8] {
        &self.0
    }
}

impl std::ops::Deref for Output {
    type Target = [u8; 32];
    fn deref(&self) -> &[u8; 32] {
        &self.0
    }
}

/// The subset of the `digest` crate's `Digest` trait this repo calls.
pub trait Digest: Sized {
    fn new() -> Self;
    fn update(&mut self, data: impl AsRef<[u8]>);
    fn finalize(self) -> Output;

    /// One-shot convenience: hash `data` in a single call.
    fn digest(data: impl AsRef<[u8]>) -> Output {
        let mut h = Self::new();
        h.update(data);
        h.finalize()
    }
}

const H0: [u32; 8] = [
    0x6a09e667, 0xbb67ae85, 0x3c6ef372, 0xa54ff53a, 0x510e527f, 0x9b05688c, 0x1f83d9ab,
    0x5be0cd19,
];

const K: [u32; 64] = [
    0x428a2f98, 0x71374491, 0xb5c0fbcf, 0xe9b5dba5, 0x3956c25b, 0x59f111f1, 0x923f82a4,
    0xab1c5ed5, 0xd807aa98, 0x12835b01, 0x243185be, 0x550c7dc3, 0x72be5d74, 0x80deb1fe,
    0x9bdc06a7, 0xc19bf174, 0xe49b69c1, 0xefbe4786, 0x0fc19dc6, 0x240ca1cc, 0x2de92c6f,
    0x4a7484aa, 0x5cb0a9dc, 0x76f988da, 0x983e5152, 0xa831c66d, 0xb00327c8, 0xbf597fc7,
    0xc6e00bf3, 0xd5a79147, 0x06ca6351, 0x14292967, 0x27b70a85, 0x2e1b2138, 0x4d2c6dfc,
    0x53380d13, 0x650a7354, 0x766a0abb, 0x81c2c92e, 0x92722c85, 0xa2bfe8a1, 0xa81a664b,
    0xc24b8b70, 0xc76c51a3, 0xd192e819, 0xd6990624, 0xf40e3585, 0x106aa070, 0x19a4c116,
    0x1e376c08, 0x2748774c, 0x34b0bcb5, 0x391c0cb3, 0x4ed8aa4a, 0x5b9cca4f, 0x682e6ff3,
    0x748f82ee, 0x78a5636f, 0x84c87814, 0x8cc70208, 0x90befffa, 0xa4506ceb, 0xbef9a3f7,
    0xc67178f2,
];

impl Sha256 {
    fn compress(&mut self, block: &[u8; 64]) {
        let mut w = [0u32; 64];
        for (i, word) in w.iter_mut().take(16).enumerate() {
            *word = u32::from_be_bytes(block[i * 4..i * 4 + 4].try_into().unwrap());
        }
        for t in 16..64 {
            let s0 = w[t - 15].rotate_right(7) ^ w[t - 15].rotate_right(18) ^ (w[t - 15] >> 3);
            let s1 = w[t - 2].rotate_right(17) ^ w[t - 2].rotate_right(19) ^ (w[t - 2] >> 10);
            w[t] = s1
                .wrapping_add(w[t - 7])
                .wrapping_add(s0)
                .wrapping_add(w[t - 16]);
        }
        let [mut a, mut b, mut c, mut d, mut e, mut f, mut g, mut h] = self.state;
        for t in 0..64 {
            let big_s1 = e.rotate_right(6) ^ e.rotate_right(11) ^ e.rotate_right(25);
            let ch = (e & f) ^ (!e & g);
            let t1 = h
                .wrapping_add(big_s1)
                .wrapping_add(ch)
                .wrapping_add(K[t])
                .wrapping_add(w[t]);
            let big_s0 = a.rotate_right(2) ^ a.rotate_right(13) ^ a.rotate_right(22);
            let maj = (a & b) ^ (a & c) ^ (b & c);
            let t2 = big_s0.wrapping_add(maj);
            h = g;
            g = f;
            f = e;
            e = d.wrapping_add(t1);
            d = c;
            c = b;
            b = a;
            a = t1.wrapping_add(t2);
        }
        for (s, v) in self.state.iter_mut().zip([a, b, c, d, e, f, g, h]) {
            *s = s.wrapping_add(v);
        }
    }
}

impl Digest for Sha256 {
    fn new() -> Sha256 {
        Sha256 { state: H0, len: 0, buf: [0u8; 64], buf_len: 0 }
    }

    fn update(&mut self, data: impl AsRef<[u8]>) {
        let mut data = data.as_ref();
        self.len = self.len.wrapping_add(data.len() as u64);
        if self.buf_len > 0 {
            let take = data.len().min(64 - self.buf_len);
            self.buf[self.buf_len..self.buf_len + take].copy_from_slice(&data[..take]);
            self.buf_len += take;
            data = &data[take..];
            if self.buf_len == 64 {
                let block = self.buf;
                self.compress(&block);
                self.buf_len = 0;
            }
        }
        while data.len() >= 64 {
            let block: [u8; 64] = data[..64].try_into().unwrap();
            self.compress(&block);
            data = &data[64..];
        }
        if !data.is_empty() {
            self.buf[..data.len()].copy_from_slice(data);
            self.buf_len = data.len();
        }
    }

    fn finalize(mut self) -> Output {
        let bit_len = self.len.wrapping_mul(8);
        // Padding: 0x80, zeros to 56 mod 64, then the 64-bit bit length.
        self.update([0x80u8]);
        while self.buf_len != 56 {
            self.update([0u8]);
        }
        self.update(bit_len.to_be_bytes());
        debug_assert_eq!(self.buf_len, 0);
        let mut out = [0u8; 32];
        for (i, s) in self.state.iter().enumerate() {
            out[i * 4..i * 4 + 4].copy_from_slice(&s.to_be_bytes());
        }
        Output(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn hex(d: &[u8]) -> String {
        d.iter().map(|b| format!("{b:02x}")).collect()
    }

    #[test]
    fn fips_vectors() {
        // FIPS 180-4 / NIST example vectors.
        assert_eq!(
            hex(Sha256::digest(b"").as_slice()),
            "e3b0c44298fc1c149afbf4c8996fb92427ae41e4649b934ca495991b7852b855"
        );
        assert_eq!(
            hex(Sha256::digest(b"abc").as_slice()),
            "ba7816bf8f01cfea414140de5dae2223b00361a396177a9cb410ff61f20015ad"
        );
        assert_eq!(
            hex(Sha256::digest(
                b"abcdbcdecdefdefgefghfghighijhijkijkljklmklmnlmnomnopnopq"
            )
            .as_slice()),
            "248d6a61d20638b8e5c026930c3e6039a33ce45964ff2167f6ecedd419db06c1"
        );
        // One million 'a's, hashed incrementally.
        let mut h = Sha256::new();
        for _ in 0..1000 {
            h.update([b'a'; 1000]);
        }
        assert_eq!(
            hex(h.finalize().as_slice()),
            "cdc76e5c9914fb9281a1c7e284d73e67f1809a48a497200e046d39ccc7112cd0"
        );
    }

    #[test]
    fn incremental_matches_oneshot_at_all_splits() {
        let msg: Vec<u8> = (0..257u32).map(|i| (i * 31 % 251) as u8).collect();
        let want = Sha256::digest(&msg);
        for split in 0..msg.len() {
            let mut h = Sha256::new();
            h.update(&msg[..split]);
            h.update(&msg[split..]);
            assert_eq!(h.finalize(), want, "split {split}");
        }
    }

    #[test]
    fn output_conversions() {
        let d = Sha256::digest(b"abc");
        let arr: [u8; 32] = d.into();
        assert_eq!(arr.len(), 32);
        assert_eq!(&arr[..], d.as_slice());
        // Slice indexing through Deref (identity derives addresses this way).
        assert_eq!(&d[..4], &arr[..4]);
    }
}
