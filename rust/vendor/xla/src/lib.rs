//! Vendored **stub** of the PJRT/XLA bindings (`xla` crate) the runtime
//! layer links against.
//!
//! The real bindings wrap native XLA (HLO text -> HloModuleProto ->
//! compile -> execute) and come from the external build harness together
//! with the AOT model artifacts; they cannot be built from a bare checkout.
//! This stub keeps the crate graph closed so `cargo build && cargo test`
//! work offline:
//!
//! - **Host-side literal plumbing is real** ([`Literal`] creation,
//!   `to_vec`, `get_first_element`, `element_count`, tuple decomposition)
//!   — unit tests exercise these without any artifacts.
//! - **Device ops are gated**: [`HloModuleProto::from_text_file`] and
//!   [`PjRtClient::compile`] return a descriptive error. Engine-dependent
//!   tests and benches all self-skip when `artifacts/<size>/spec.json` is
//!   absent, so a stub build never reaches these paths in CI.
//!
//! Deployments with real XLA replace `vendor/xla` with the actual bindings
//! (same API surface); no first-party code changes.

use std::fmt;

/// Stub error: any attempted device op reports itself clearly.
#[derive(Debug)]
pub struct Error(String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for Error {}

fn stub_err(what: &str) -> Error {
    Error(format!(
        "vendored xla stub: {what} requires the real PJRT bindings (external build harness)"
    ))
}

pub type Result<T> = std::result::Result<T, Error>;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ElementType {
    F32,
    S32,
    U32,
}

/// Element types host code moves in and out of literals.
pub trait NativeType: Copy {
    fn scalar_literal(v: Self) -> Literal;
    fn extract(lit: &Literal) -> Option<Vec<Self>>;
}

/// Host tensor: typed data + shape, or a tuple of literals.
#[derive(Clone, Debug)]
pub enum Literal {
    F32 { data: Vec<f32>, shape: Vec<usize> },
    S32 { data: Vec<i32>, shape: Vec<usize> },
    U32 { data: Vec<u32>, shape: Vec<usize> },
    Tuple(Vec<Literal>),
}

impl NativeType for f32 {
    fn scalar_literal(v: f32) -> Literal {
        Literal::F32 { data: vec![v], shape: vec![] }
    }
    fn extract(lit: &Literal) -> Option<Vec<f32>> {
        match lit {
            Literal::F32 { data, .. } => Some(data.clone()),
            _ => None,
        }
    }
}

impl NativeType for i32 {
    fn scalar_literal(v: i32) -> Literal {
        Literal::S32 { data: vec![v], shape: vec![] }
    }
    fn extract(lit: &Literal) -> Option<Vec<i32>> {
        match lit {
            Literal::S32 { data, .. } => Some(data.clone()),
            _ => None,
        }
    }
}

impl NativeType for u32 {
    fn scalar_literal(v: u32) -> Literal {
        Literal::U32 { data: vec![v], shape: vec![] }
    }
    fn extract(lit: &Literal) -> Option<Vec<u32>> {
        match lit {
            Literal::U32 { data, .. } => Some(data.clone()),
            _ => None,
        }
    }
}

impl Literal {
    pub fn scalar<T: NativeType>(v: T) -> Literal {
        T::scalar_literal(v)
    }

    pub fn create_from_shape_and_untyped_data(
        ty: ElementType,
        shape: &[usize],
        bytes: &[u8],
    ) -> Result<Literal> {
        let n: usize = shape.iter().product();
        if bytes.len() != n * 4 {
            return Err(Error(format!(
                "literal data is {} bytes, shape {shape:?} needs {}",
                bytes.len(),
                n * 4
            )));
        }
        let shape = shape.to_vec();
        Ok(match ty {
            ElementType::F32 => Literal::F32 {
                data: bytes
                    .chunks_exact(4)
                    .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
                    .collect(),
                shape,
            },
            ElementType::S32 => Literal::S32 {
                data: bytes
                    .chunks_exact(4)
                    .map(|c| i32::from_le_bytes(c.try_into().unwrap()))
                    .collect(),
                shape,
            },
            ElementType::U32 => Literal::U32 {
                data: bytes
                    .chunks_exact(4)
                    .map(|c| u32::from_le_bytes(c.try_into().unwrap()))
                    .collect(),
                shape,
            },
        })
    }

    pub fn element_count(&self) -> usize {
        match self {
            Literal::F32 { data, .. } => data.len(),
            Literal::S32 { data, .. } => data.len(),
            Literal::U32 { data, .. } => data.len(),
            Literal::Tuple(parts) => parts.iter().map(Literal::element_count).sum(),
        }
    }

    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        T::extract(self).ok_or_else(|| Error("literal type mismatch in to_vec".into()))
    }

    pub fn get_first_element<T: NativeType>(&self) -> Result<T> {
        self.to_vec::<T>()?
            .first()
            .copied()
            .ok_or_else(|| Error("empty literal".into()))
    }

    pub fn decompose_tuple(&mut self) -> Result<Vec<Literal>> {
        match self {
            Literal::Tuple(parts) => Ok(std::mem::take(parts)),
            _ => Err(Error("decompose_tuple on a non-tuple literal".into())),
        }
    }
}

/// Parsed HLO module (device-side in the real bindings; gated here).
pub struct HloModuleProto(());

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        Err(stub_err("HloModuleProto::from_text_file"))
    }
}

pub struct XlaComputation(());

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation(())
    }
}

/// Device buffer handle returned by `execute` (never constructed here).
pub struct PjRtBuffer(());

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(stub_err("PjRtBuffer::to_literal_sync"))
    }
}

pub struct PjRtLoadedExecutable(());

impl PjRtLoadedExecutable {
    pub fn execute<L: std::borrow::Borrow<Literal>>(
        &self,
        _args: &[L],
    ) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(stub_err("PjRtLoadedExecutable::execute"))
    }
}

/// Client handle. Construction succeeds (host-only work is fine); the
/// first compile reports the stub.
pub struct PjRtClient(());

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Ok(PjRtClient(()))
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(stub_err("PjRtClient::compile"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrips() {
        let data = [1.0f32, -2.0, 3.5, 0.25];
        let bytes: Vec<u8> = data.iter().flat_map(|v| v.to_le_bytes()).collect();
        let lit =
            Literal::create_from_shape_and_untyped_data(ElementType::F32, &[2, 2], &bytes)
                .unwrap();
        assert_eq!(lit.element_count(), 4);
        assert_eq!(lit.to_vec::<f32>().unwrap(), data);
        assert!(lit.to_vec::<i32>().is_err());
        assert_eq!(Literal::scalar(7i32).get_first_element::<i32>().unwrap(), 7);
    }

    #[test]
    fn shape_mismatch_rejected() {
        assert!(
            Literal::create_from_shape_and_untyped_data(ElementType::S32, &[3], &[0u8; 8])
                .is_err()
        );
    }

    #[test]
    fn tuple_decomposition() {
        let mut t = Literal::Tuple(vec![Literal::scalar(1.0f32), Literal::scalar(2i32)]);
        assert_eq!(t.element_count(), 2);
        let parts = t.decompose_tuple().unwrap();
        assert_eq!(parts.len(), 2);
        assert!(Literal::scalar(1i32).decompose_tuple().is_err());
    }

    #[test]
    fn device_ops_report_stub() {
        let client = PjRtClient::cpu().unwrap();
        let comp = XlaComputation::from_proto(&HloModuleProto(()));
        let err = client.compile(&comp).unwrap_err().to_string();
        assert!(err.contains("vendored xla stub"), "{err}");
        assert!(HloModuleProto::from_text_file("x.hlo.txt").is_err());
    }
}
