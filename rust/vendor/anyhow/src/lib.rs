//! Vendored minimal `anyhow`: an erased error type plus the `anyhow!`,
//! `bail!` and `ensure!` macros, covering the subset of the real crate's
//! API this repository uses (no `Context`, no downcasting, no backtraces).
//!
//! Like the real crate, [`Error`] deliberately does **not** implement
//! `std::error::Error` — that is what allows the blanket
//! `From<E: std::error::Error>` conversion powering `?` without colliding
//! with the reflexive `From<Error> for Error`.

use std::fmt;

/// Erased error: any `std::error::Error + Send + Sync` or a plain message.
pub struct Error(Box<dyn std::error::Error + Send + Sync + 'static>);

/// `Result<T, anyhow::Error>` with the error type defaulted.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// A message-only error (what `anyhow!("...")` produces).
struct Message(String);

impl fmt::Display for Message {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl fmt::Debug for Message {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for Message {}

impl Error {
    /// Build an error from a displayable message.
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error(Box::new(Message(message.to_string())))
    }

    /// Wrap a concrete error value.
    pub fn new<E: std::error::Error + Send + Sync + 'static>(error: E) -> Error {
        Error(Box::new(error))
    }

    /// The chain of `source()` causes, outermost first.
    pub fn chain(&self) -> impl Iterator<Item = &(dyn std::error::Error + 'static)> {
        let mut next: Option<&(dyn std::error::Error + 'static)> = Some(self.0.as_ref());
        std::iter::from_fn(move || {
            let cur = next?;
            next = cur.source();
            Some(cur)
        })
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        fmt::Display::fmt(&self.0, f)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)?;
        let mut source = self.0.source();
        if source.is_some() {
            write!(f, "\n\nCaused by:")?;
        }
        while let Some(cause) = source {
            write!(f, "\n    {cause}")?;
            source = cause.source();
        }
        Ok(())
    }
}

impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(error: E) -> Error {
        Error(Box::new(error))
    }
}

/// `anyhow!("fmt", args...)` — build an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::msg(format!($($arg)*))
    };
}

/// `bail!("fmt", args...)` — return early with an [`Error`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// `ensure!(cond, "fmt", args...)` — bail unless `cond` holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            return Err($crate::Error::msg(concat!("condition failed: ", stringify!($cond))));
        }
    };
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            return Err($crate::anyhow!($($arg)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[derive(Debug)]
    struct Leaf;

    impl fmt::Display for Leaf {
        fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
            write!(f, "leaf failure")
        }
    }

    impl std::error::Error for Leaf {}

    fn fails(flag: bool) -> Result<u32> {
        ensure!(flag, "flag was {flag}");
        Err(Leaf.into())
    }

    #[test]
    fn conversions_and_macros() {
        assert_eq!(fails(false).unwrap_err().to_string(), "flag was false");
        assert_eq!(fails(true).unwrap_err().to_string(), "leaf failure");
        let e: Error = anyhow!("x = {}", 7);
        assert_eq!(e.to_string(), "x = 7");
        assert_eq!(format!("{e:?}"), "x = 7");
        let io: Error = std::io::Error::other("disk on fire").into();
        assert_eq!(io.to_string(), "disk on fire");
        assert_eq!(io.chain().count(), 1);
    }

    #[test]
    fn bail_returns_early() {
        fn f() -> Result<()> {
            bail!("stop {}", "here");
        }
        assert_eq!(f().unwrap_err().to_string(), "stop here");
    }
}
