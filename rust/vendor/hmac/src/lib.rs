//! Vendored from-scratch HMAC (RFC 2104) over the vendored SHA-256,
//! exposing the subset of the `hmac` crate API this repository uses:
//! `Hmac<Sha256>` with the `Mac` trait's `new_from_slice` / `update` /
//! `finalize().into_bytes()`. Correctness is pinned by the RFC 4231 test
//! vectors below.

use std::marker::PhantomData;

use sha2::{Digest, Sha256};

const BLOCK: usize = 64;

/// HMAC keyed with a hash function `D`. Only `Hmac<Sha256>` is
/// implemented — the one instantiation the repo uses.
#[derive(Clone)]
pub struct Hmac<D> {
    inner: Sha256,
    opad_key: [u8; BLOCK],
    _marker: PhantomData<D>,
}

/// Finished MAC tag; `.into_bytes()` yields the 32-byte output.
pub struct Tag(sha2::Output);

impl Tag {
    pub fn into_bytes(self) -> sha2::Output {
        self.0
    }
}

/// Key-length error. HMAC accepts any key length, so this is never
/// produced here — it exists so `new_from_slice(..).expect(..)` type-checks
/// like the real crate.
#[derive(Debug)]
pub struct InvalidLength;

impl std::fmt::Display for InvalidLength {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str("invalid hmac key length")
    }
}

impl std::error::Error for InvalidLength {}

/// The subset of the `digest` crate's `Mac` trait this repo calls.
pub trait Mac: Sized {
    fn new_from_slice(key: &[u8]) -> Result<Self, InvalidLength>;
    fn update(&mut self, data: &[u8]);
    fn finalize(self) -> Tag;
}

impl Mac for Hmac<Sha256> {
    fn new_from_slice(key: &[u8]) -> Result<Self, InvalidLength> {
        // Keys longer than the block size are hashed first (RFC 2104).
        let mut padded = [0u8; BLOCK];
        if key.len() > BLOCK {
            let d: [u8; 32] = Sha256::digest(key).into();
            padded[..32].copy_from_slice(&d);
        } else {
            padded[..key.len()].copy_from_slice(key);
        }
        let mut ipad_key = [0u8; BLOCK];
        let mut opad_key = [0u8; BLOCK];
        for ((ip, op), p) in ipad_key.iter_mut().zip(opad_key.iter_mut()).zip(padded) {
            *ip = p ^ 0x36;
            *op = p ^ 0x5c;
        }
        let mut inner = Sha256::new();
        inner.update(ipad_key);
        Ok(Hmac { inner, opad_key, _marker: PhantomData })
    }

    fn update(&mut self, data: &[u8]) {
        self.inner.update(data);
    }

    fn finalize(self) -> Tag {
        let inner_digest = self.inner.finalize();
        let mut outer = Sha256::new();
        outer.update(self.opad_key);
        outer.update(inner_digest.as_slice());
        Tag(outer.finalize())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn hmac(key: &[u8], msg: &[u8]) -> String {
        let mut m = Hmac::<Sha256>::new_from_slice(key).unwrap();
        m.update(msg);
        let out: [u8; 32] = m.finalize().into_bytes().into();
        out.iter().map(|b| format!("{b:02x}")).collect()
    }

    #[test]
    fn rfc4231_vectors() {
        // Case 1: 20 x 0x0b key, "Hi There".
        assert_eq!(
            hmac(&[0x0b; 20], b"Hi There"),
            "b0344c61d8db38535ca8afceaf0bf12b881dc200c9833da726e9376c2e32cff7"
        );
        // Case 2: key "Jefe", msg "what do ya want for nothing?".
        assert_eq!(
            hmac(b"Jefe", b"what do ya want for nothing?"),
            "5bdcc146bf60754e6a042426089575c75a003f089d2739839dec58b964ec3843"
        );
        // Case 3: 20 x 0xaa key, 50 x 0xdd message.
        assert_eq!(
            hmac(&[0xaa; 20], &[0xdd; 50]),
            "773ea91e36800e46854db8ebd09181a72959098b3ef8c122d9635514ced565fe"
        );
        // Case 6: 131-byte key (> block size, gets hashed first).
        assert_eq!(
            hmac(&[0xaa; 131], b"Test Using Larger Than Block-Size Key - Hash Key First"),
            "60e431591ee0b67f0d8a26aacbf5b77f8e0bc6213728c5140546040f0ee37f54"
        );
    }

    #[test]
    fn distinct_keys_distinct_tags() {
        assert_ne!(hmac(&[1; 32], b"msg"), hmac(&[2; 32], b"msg"));
        assert_ne!(hmac(&[1; 32], b"msg"), hmac(&[1; 32], b"msh"));
    }
}
