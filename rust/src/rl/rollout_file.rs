//! Wire format for rollout submissions: `rpq` files exchanged between
//! inference workers, TOPLOC validators and the trainer (§2.1.1 uses
//! Parquet; `rpq` is the from-scratch stand-in — see data::rpq).
//!
//! Uploads travel inside a signed [`Envelope`]: a versioned header naming
//! the sender, the policy step and the submission index, carrying the
//! payload's SHA-256 and an HMAC-SHA256 signature (§2.4.1 node keys) over
//! the canonical header bytes. The signature binds the *step*, so a
//! replayed old envelope ages out with the validator's staleness window,
//! and it binds the *payload digest*, so swapping the payload under a
//! captured header invalidates the signature. Verification happens in the
//! validation pipeline's stage 0 against the ledger's key registry.

// Trust-critical parse path: hostile uploads must decode to Err, never
// panic (swarmlint `panic-path`; clippy mirrors the gate in CI).
#![cfg_attr(not(test), deny(clippy::unwrap_used, clippy::expect_used))]

use sha2::{Digest, Sha256};

use super::Rollout;
use crate::data::rpq::{Column, DType, RpqFile, Schema};
use crate::protocol::identity::{hmac_verify, Identity};
use crate::util::wire::Cursor;

/// A rollout plus the trust metadata the validator consumes.
#[derive(Clone, Debug)]
pub struct WireRollout {
    pub rollout: Rollout,
    /// Encoded TOPLOC commitment (toploc::Commitment bytes).
    pub commitment: Vec<u8>,
    /// True if the sequence terminated on EOS (else hit max length).
    pub finish_eos: bool,
    /// Model probability of EOS at the terminating step (§2.3.2).
    pub eos_prob: f32,
}

/// One uploaded file = one batch from one node for one step.
#[derive(Clone, Debug)]
pub struct Submission {
    pub node_address: u64,
    pub step: u64,
    /// Submission index for this node/step (seed formula input, §2.3.3).
    pub submission_idx: u64,
    pub rollouts: Vec<WireRollout>,
}

/// Envelope wire version this build emits and accepts.
pub const ENVELOPE_VERSION: u8 = 1;

/// Envelope magic ("INTELLECT-2 Signed Envelope").
pub const ENVELOPE_MAGIC: [u8; 4] = *b"I2SE";

/// Fixed header size: magic, version, node/step/idx, digest, signature.
pub const ENVELOPE_HEADER_LEN: usize = 4 + 1 + 3 * 8 + 32 + 32;

/// Domain-separation prefix of the canonical signed bytes.
const ENVELOPE_SIGNING_CONTEXT: &[u8; 16] = b"i2-submission-v1";

/// Signed submission header: who uploaded, for which policy step, plus the
/// payload digest the signature commits to.
///
/// Wire layout (little-endian):
/// `"I2SE" | u8 version | u64 node | u64 step | u64 submission_idx |
/// [u8; 32] payload sha256 | [u8; 32] hmac signature | payload bytes`.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Envelope {
    pub node_address: u64,
    pub step: u64,
    pub submission_idx: u64,
    pub payload_digest: [u8; 32],
    pub sig: [u8; 32],
}

impl Envelope {
    /// Canonical byte serialization the signature covers. Binding `step`
    /// makes replays age out with the staleness window; binding the
    /// payload digest makes a payload swap under a captured header
    /// detectable.
    pub fn signing_bytes(
        node_address: u64,
        step: u64,
        submission_idx: u64,
        payload_digest: &[u8; 32],
    ) -> Vec<u8> {
        let mut m = Vec::with_capacity(ENVELOPE_SIGNING_CONTEXT.len() + 3 * 8 + 32);
        m.extend_from_slice(ENVELOPE_SIGNING_CONTEXT);
        m.extend_from_slice(&node_address.to_le_bytes());
        m.extend_from_slice(&step.to_le_bytes());
        m.extend_from_slice(&submission_idx.to_le_bytes());
        m.extend_from_slice(payload_digest);
        m
    }

    /// Build and sign an envelope around `payload` under `identity`'s key
    /// (the honest worker's upload path).
    pub fn seal(identity: &Identity, step: u64, submission_idx: u64, payload: &[u8]) -> Vec<u8> {
        let payload_digest: [u8; 32] = Sha256::digest(payload).into();
        let sig = identity.sign(&Envelope::signing_bytes(
            identity.address,
            step,
            submission_idx,
            &payload_digest,
        ));
        Envelope { node_address: identity.address, step, submission_idx, payload_digest, sig }
            .encode(payload)
    }

    /// Serialize header + payload (no signing — tests use this to forge).
    pub fn encode(&self, payload: &[u8]) -> Vec<u8> {
        let mut out = Vec::with_capacity(ENVELOPE_HEADER_LEN + payload.len());
        out.extend_from_slice(&ENVELOPE_MAGIC);
        out.push(ENVELOPE_VERSION);
        out.extend_from_slice(&self.node_address.to_le_bytes());
        out.extend_from_slice(&self.step.to_le_bytes());
        out.extend_from_slice(&self.submission_idx.to_le_bytes());
        out.extend_from_slice(&self.payload_digest);
        out.extend_from_slice(&self.sig);
        out.extend_from_slice(payload);
        out
    }

    /// Structural parse: split header and payload. `None` when the bytes
    /// do not carry a version-1 envelope at all (legacy raw `rpq` uploads
    /// land here); no signature or digest checking happens yet.
    pub fn parse(bytes: &[u8]) -> Option<(Envelope, &[u8])> {
        let mut c = Cursor::new(bytes);
        if c.array::<4>()? != ENVELOPE_MAGIC || c.u8()? != ENVELOPE_VERSION {
            return None;
        }
        let env = Envelope {
            node_address: c.u64_le()?,
            step: c.u64_le()?,
            submission_idx: c.u64_le()?,
            payload_digest: c.array::<32>()?,
            sig: c.array::<32>()?,
        };
        debug_assert_eq!(c.offset(), ENVELOPE_HEADER_LEN);
        Some((env, bytes.get(c.offset()..)?))
    }

    /// Does the signed digest cover exactly these payload bytes?
    pub fn digest_matches(&self, payload: &[u8]) -> bool {
        let d: [u8; 32] = Sha256::digest(payload).into();
        d == self.payload_digest
    }

    /// Verify the signature against a registered key (the ledger's
    /// address→key registry). True only if `key`'s owner signed exactly
    /// this header.
    pub fn verify_sig(&self, key: &[u8; 32]) -> bool {
        hmac_verify(
            key,
            &Envelope::signing_bytes(
                self.node_address,
                self.step,
                self.submission_idx,
                &self.payload_digest,
            ),
            &self.sig,
        )
    }
}

pub fn schema() -> Schema {
    vec![
        ("node", DType::U64),
        ("step", DType::U64),
        ("submission_idx", DType::U64),
        ("task_id", DType::U64),
        ("group_id", DType::U64),
        ("prompt_len", DType::U64),
        ("target_len", DType::U64),
        ("finish_eos", DType::U64),
        ("tokens", DType::I32List),
        ("task_reward", DType::F32),
        ("length_penalty", DType::F32),
        ("reward", DType::F32),
        ("eos_prob", DType::F32),
        ("sampled_probs", DType::F32List),
        ("commitment", DType::Bytes),
    ]
}

impl Submission {
    pub fn encode(&self) -> Vec<u8> {
        let n = self.rollouts.len();
        let rs = &self.rollouts;
        let mut f = RpqFile::new();
        f.push("node", Column::U64(vec![self.node_address; n]))
            .push("step", Column::U64(vec![self.step; n]))
            .push("submission_idx", Column::U64(vec![self.submission_idx; n]))
            .push("task_id", Column::U64(rs.iter().map(|r| r.rollout.task_id).collect()))
            .push("group_id", Column::U64(rs.iter().map(|r| r.rollout.group_id).collect()))
            .push("prompt_len", Column::U64(rs.iter().map(|r| r.rollout.prompt_len as u64).collect()))
            .push(
                "target_len",
                Column::U64(rs.iter().map(|r| r.rollout.target_len.unwrap_or(0) as u64).collect()),
            )
            .push("finish_eos", Column::U64(rs.iter().map(|r| r.finish_eos as u64).collect()))
            .push("tokens", Column::I32List(rs.iter().map(|r| r.rollout.tokens.clone()).collect()))
            .push("task_reward", Column::F32(rs.iter().map(|r| r.rollout.task_reward).collect()))
            .push(
                "length_penalty",
                Column::F32(rs.iter().map(|r| r.rollout.length_penalty).collect()),
            )
            .push("reward", Column::F32(rs.iter().map(|r| r.rollout.reward).collect()))
            .push("eos_prob", Column::F32(rs.iter().map(|r| r.eos_prob).collect()))
            .push(
                "sampled_probs",
                Column::F32List(rs.iter().map(|r| r.rollout.sampled_probs.clone()).collect()),
            )
            .push("commitment", Column::Bytes(rs.iter().map(|r| r.commitment.clone()).collect()));
        f.encode()
    }

    /// Sign + serialize for upload: the `rpq` payload wrapped in a signed
    /// [`Envelope`] under `identity`'s key.
    pub fn encode_signed(&self, identity: &Identity) -> Vec<u8> {
        Envelope::seal(identity, self.step, self.submission_idx, &self.encode())
    }

    /// Best-effort *unverified* sender attribution for log lines and for
    /// legacy (signature-optional) deployments: if the container decodes
    /// (checksum intact) and carries a uniform `node` column, that address
    /// claimed the upload; failing that, an envelope header's claim is
    /// used. A file mangled beyond both yields `None`. When signatures are
    /// required, slash attribution never comes from here — only from a
    /// verified envelope (stage 0).
    pub fn peek_node_address(bytes: &[u8]) -> Option<u64> {
        if let Some((env, payload)) = Envelope::parse(bytes) {
            return Submission::peek_payload_address(payload).or(Some(env.node_address));
        }
        Submission::peek_payload_address(bytes)
    }

    /// [`Submission::peek_node_address`] on bare payload bytes (no
    /// envelope handling).
    fn peek_payload_address(bytes: &[u8]) -> Option<u64> {
        let f = RpqFile::decode(bytes).ok()?;
        let nodes = f.col("node")?.as_u64()?;
        let first = *nodes.first()?;
        nodes.iter().all(|&n| n == first).then_some(first)
    }

    /// Decode + schema-validate (the validator's "parquet formatting
    /// check": anything that would throw in the trainer dataloader is
    /// rejected here).
    pub fn decode(bytes: &[u8]) -> anyhow::Result<Submission> {
        let f = RpqFile::decode(bytes)?;
        f.validate_schema(&schema())?;
        let n = f.n_rows();
        anyhow::ensure!(n > 0, "empty submission");
        // validate_schema already pinned names and dtypes, but the parse
        // path stays structurally panic-free regardless: a missing or
        // mistyped column is an Err, never an unwrap.
        let missing = |name: &str| anyhow::anyhow!("column {name} missing or mistyped");
        let u64s = |name: &str| -> anyhow::Result<Vec<u64>> {
            Ok(f.col(name).and_then(|c| c.as_u64()).ok_or_else(|| missing(name))?.to_vec())
        };
        let f32s = |name: &str| -> anyhow::Result<Vec<f32>> {
            Ok(f.col(name).and_then(|c| c.as_f32()).ok_or_else(|| missing(name))?.to_vec())
        };
        let node = u64s("node")?;
        let step = u64s("step")?;
        let sub = u64s("submission_idx")?;
        anyhow::ensure!(
            node.windows(2).all(|w| w[0] == w[1])
                && step.windows(2).all(|w| w[0] == w[1])
                && sub.windows(2).all(|w| w[0] == w[1]),
            "mixed node/step/submission in one file"
        );
        let task_id = u64s("task_id")?;
        let group_id = u64s("group_id")?;
        let prompt_len = u64s("prompt_len")?;
        let target_len = u64s("target_len")?;
        let finish = u64s("finish_eos")?;
        let tokens = f
            .col("tokens")
            .and_then(|c| c.as_i32_list())
            .ok_or_else(|| missing("tokens"))?
            .to_vec();
        let task_reward = f32s("task_reward")?;
        let length_penalty = f32s("length_penalty")?;
        let reward = f32s("reward")?;
        let eos_prob = f32s("eos_prob")?;
        let probs = f
            .col("sampled_probs")
            .and_then(|c| c.as_f32_list())
            .ok_or_else(|| missing("sampled_probs"))?
            .to_vec();
        let commits = f
            .col("commitment")
            .and_then(|c| c.as_bytes())
            .ok_or_else(|| missing("commitment"))?
            .to_vec();

        let rollouts = (0..n)
            .map(|i| {
                anyhow::ensure!(
                    (prompt_len[i] as usize) < tokens[i].len().max(1),
                    "row {i}: prompt_len >= tokens"
                );
                Ok(WireRollout {
                    rollout: Rollout {
                        task_id: task_id[i],
                        group_id: group_id[i],
                        policy_step: step[i],
                        tokens: tokens[i].clone(),
                        prompt_len: prompt_len[i] as usize,
                        target_len: if target_len[i] == 0 { None } else { Some(target_len[i] as usize) },
                        task_reward: task_reward[i],
                        length_penalty: length_penalty[i],
                        reward: reward[i],
                        advantage: 0.0,
                        sampled_probs: probs[i].clone(),
                        node_address: node[i],
                    },
                    commitment: commits[i].clone(),
                    finish_eos: finish[i] != 0,
                    eos_prob: eos_prob[i],
                })
            })
            .collect::<anyhow::Result<Vec<_>>>()?;
        Ok(Submission { node_address: node[0], step: step[0], submission_idx: sub[0], rollouts })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    pub(crate) fn sample_submission() -> Submission {
        let mk = |task: u64, group: u64, len: usize| WireRollout {
            rollout: Rollout {
                task_id: task,
                group_id: group,
                policy_step: 4,
                tokens: (0..len as i32).map(|i| 1 + i % 60).collect(),
                prompt_len: 3,
                target_len: if task % 2 == 0 { Some(32) } else { None },
                task_reward: (task % 2) as f32,
                length_penalty: 0.01,
                reward: (task % 2) as f32 - 0.01,
                advantage: 0.0,
                sampled_probs: vec![0.4; len - 3],
                node_address: 0xAB,
            },
            commitment: vec![1, 2, 3, task as u8],
            finish_eos: task % 2 == 0,
            eos_prob: 0.5,
        };
        Submission {
            node_address: 0xAB,
            step: 4,
            submission_idx: 1,
            rollouts: vec![mk(0, 0, 10), mk(1, 0, 14), mk(2, 1, 8)],
        }
    }

    #[test]
    fn roundtrip() {
        let s = sample_submission();
        let bytes = s.encode();
        let d = Submission::decode(&bytes).unwrap();
        assert_eq!(d.node_address, 0xAB);
        assert_eq!(d.step, 4);
        assert_eq!(d.rollouts.len(), 3);
        assert_eq!(d.rollouts[1].rollout.tokens, s.rollouts[1].rollout.tokens);
        assert_eq!(d.rollouts[0].rollout.target_len, Some(32));
        assert_eq!(d.rollouts[1].rollout.target_len, None);
        assert_eq!(d.rollouts[2].commitment, vec![1, 2, 3, 2]);
    }

    #[test]
    fn corrupt_rejected() {
        let mut bytes = sample_submission().encode();
        let n = bytes.len();
        bytes[n / 2] ^= 0x55;
        assert!(Submission::decode(&bytes).is_err());
        // Checksum-broken container: no attribution possible.
        assert_eq!(Submission::peek_node_address(&bytes), None);
    }

    #[test]
    fn peek_attributes_schema_invalid_submissions() {
        // A decodable container with a bogus schema still names its sender.
        let mut f = RpqFile::new();
        f.push("node", Column::U64(vec![0xC0FFEE; 3]))
            .push("junk", Column::F32(vec![1.0; 3]));
        let bytes = f.encode();
        assert!(Submission::decode(&bytes).is_err());
        assert_eq!(Submission::peek_node_address(&bytes), Some(0xC0FFEE));
        // A mixed node column proves nothing -> no attribution.
        let mut g = RpqFile::new();
        g.push("node", Column::U64(vec![1, 2]));
        assert_eq!(Submission::peek_node_address(&g.encode()), None);
    }

    #[test]
    fn wrong_schema_rejected() {
        // A structurally-valid rpq file with the wrong columns.
        let mut f = RpqFile::new();
        f.push("whatever", Column::U64(vec![1]));
        assert!(Submission::decode(&f.encode()).is_err());
    }

    #[test]
    fn envelope_seal_parse_verify() {
        let id = Identity::from_seed(7);
        let mut sub = sample_submission();
        sub.node_address = id.address;
        let bytes = sub.encode_signed(&id);
        let (env, payload) = Envelope::parse(&bytes).expect("envelope present");
        assert_eq!(env.node_address, id.address);
        assert_eq!(env.step, sub.step);
        assert_eq!(env.submission_idx, sub.submission_idx);
        assert!(env.digest_matches(payload));
        assert!(env.verify_sig(&id.secret()));
        // Wrong key: the signature proves nothing.
        assert!(!env.verify_sig(&Identity::from_seed(8).secret()));
        // The payload is the plain rpq file.
        assert_eq!(Submission::decode(payload).unwrap().rollouts.len(), 3);
    }

    #[test]
    fn envelope_binds_header_fields_and_payload() {
        let id = Identity::from_seed(7);
        let payload = sample_submission().encode();
        let bytes = Envelope::seal(&id, 4, 1, &payload);
        let (env, payload) = Envelope::parse(&bytes).unwrap();
        // Any header mutation invalidates the signature (replaying at a
        // different step, claiming another sender, swapping the digest).
        for tampered in [
            Envelope { step: env.step + 1, ..env.clone() },
            Envelope { node_address: env.node_address ^ 1, ..env.clone() },
            Envelope { submission_idx: env.submission_idx + 1, ..env.clone() },
            Envelope { payload_digest: [9u8; 32], ..env.clone() },
        ] {
            assert!(!tampered.verify_sig(&id.secret()), "{tampered:?}");
        }
        // A payload swap under the intact header fails the digest check.
        let mut other = payload.to_vec();
        let mid = other.len() / 2;
        other[mid] ^= 0x40;
        assert!(!env.digest_matches(&other));
        assert!(env.digest_matches(payload));
    }

    #[test]
    fn peek_handles_truncated_and_garbage_headers() {
        // Random garbage: no envelope, no rpq container.
        assert_eq!(Submission::peek_node_address(&[0x13; 40]), None);
        assert_eq!(Submission::peek_node_address(&[]), None);
        // Magic only / header cut short: not parseable as an envelope, and
        // not an rpq file either.
        let mut cut = ENVELOPE_MAGIC.to_vec();
        assert_eq!(Submission::peek_node_address(&cut), None);
        cut.push(ENVELOPE_VERSION);
        cut.extend_from_slice(&[0u8; 20]);
        assert_eq!(Submission::peek_node_address(&cut), None);
        // Unknown version: treated as not-an-envelope, not misparsed.
        let id = Identity::from_seed(3);
        let mut bytes = Envelope::seal(&id, 1, 0, &sample_submission().encode());
        bytes[4] = 2;
        assert_eq!(Envelope::parse(&bytes), None);
        // Envelope wrapping garbage: the header's (unverified) claim.
        let garbage = Envelope::seal(&id, 1, 0, &[0xAB; 10]);
        assert_eq!(Submission::peek_node_address(&garbage), Some(id.address));
        // Envelope wrapping an intact payload: the payload's own claim.
        let signed = Envelope::seal(&id, 1, 0, &sample_submission().encode());
        assert_eq!(Submission::peek_node_address(&signed), Some(0xAB));
    }

    #[test]
    fn hostile_bytes_error_out_instead_of_panicking() {
        use crate::util::rng::Rng;
        // Every prefix and every random mutation of a valid signed upload
        // must flow through parse/decode/peek as a clean miss or an Err —
        // a panicking validator is an unslashable denial of service.
        let id = Identity::from_seed(11);
        let bytes = sample_submission().encode_signed(&id);
        for cut in 0..bytes.len().min(ENVELOPE_HEADER_LEN + 64) {
            let p = &bytes[..cut];
            let _ = Envelope::parse(p);
            let _ = Submission::peek_node_address(p);
            let _ = Submission::decode(p);
        }
        let mut rng = Rng::new(12);
        for _ in 0..300 {
            let mut b = bytes.clone();
            for _ in 0..1 + rng.usize(3) {
                let i = rng.usize(b.len());
                b[i] = b[i].wrapping_add(1 + rng.next_u32() as u8 % 255);
            }
            if let Some((env, payload)) = Envelope::parse(&b) {
                let _ = env.digest_matches(payload);
                let _ = env.verify_sig(&id.secret());
                let _ = Submission::decode(payload);
            }
            let _ = Submission::peek_node_address(&b);
        }
    }

    #[test]
    fn prop_envelope_roundtrip_arbitrary_batches() {
        use crate::util::prop::{check, ensure, ensure_eq};
        use crate::util::rng::Rng;
        // Sign -> serialize -> parse -> verify round-trips for arbitrary
        // rollout batches, and the recovered submission matches the input.
        check(
            "signed envelope roundtrip",
            24,
            |rng: &mut Rng, size| {
                let id_seed = rng.next_u64();
                let id = Identity::from_seed(id_seed);
                let step = rng.next_u64() % 1000;
                let idx = rng.next_u64() % 16;
                let n = 1 + rng.usize(size as usize % 12 + 1);
                let rollouts = (0..n)
                    .map(|i| {
                        let len = 2 + rng.usize(24);
                        WireRollout {
                            rollout: Rollout {
                                task_id: rng.next_u64() % 512,
                                group_id: rng.next_u64(),
                                policy_step: step,
                                tokens: (0..len as i32).map(|t| 1 + (t * 7) % 61).collect(),
                                prompt_len: 1 + rng.usize(len - 1),
                                target_len: (i % 2 == 0).then(|| 8 + rng.usize(56)),
                                task_reward: (rng.next_u32() % 2) as f32,
                                length_penalty: 0.25,
                                reward: 0.75,
                                advantage: 0.0,
                                sampled_probs: vec![0.5; len],
                                node_address: id.address,
                            },
                            commitment: (0..rng.usize(20)).map(|_| rng.next_u32() as u8).collect(),
                            finish_eos: i % 3 == 0,
                            eos_prob: 0.4,
                        }
                    })
                    .collect();
                (
                    id_seed,
                    Submission { node_address: id.address, step, submission_idx: idx, rollouts },
                )
            },
            |(id_seed, sub)| {
                let id = Identity::from_seed(*id_seed);
                let bytes = sub.encode_signed(&id);
                let (env, payload) =
                    Envelope::parse(&bytes).ok_or("envelope did not parse")?;
                ensure(env.digest_matches(payload), "digest mismatch")?;
                ensure(env.verify_sig(&id.secret()), "signature did not verify")?;
                ensure(
                    !env.verify_sig(&Identity::from_seed(id_seed ^ 1).secret()),
                    "foreign key verified",
                )?;
                ensure_eq(env.node_address, sub.node_address, "node")?;
                ensure_eq(env.step, sub.step, "step")?;
                ensure_eq(env.submission_idx, sub.submission_idx, "idx")?;
                let back = Submission::decode(payload).map_err(|e| e.to_string())?;
                ensure_eq(back.rollouts.len(), sub.rollouts.len(), "rollout count")?;
                ensure_eq(
                    back.rollouts.last().unwrap().rollout.tokens.clone(),
                    sub.rollouts.last().unwrap().rollout.tokens.clone(),
                    "tokens roundtrip",
                )
            },
        );
    }
}
