//! Wire format for rollout submissions: `rpq` files exchanged between
//! inference workers, TOPLOC validators and the trainer (§2.1.1 uses
//! Parquet; `rpq` is the from-scratch stand-in — see data::rpq).

use super::Rollout;
use crate::data::rpq::{Column, DType, RpqFile, Schema};

/// A rollout plus the trust metadata the validator consumes.
#[derive(Clone, Debug)]
pub struct WireRollout {
    pub rollout: Rollout,
    /// Encoded TOPLOC commitment (toploc::Commitment bytes).
    pub commitment: Vec<u8>,
    /// True if the sequence terminated on EOS (else hit max length).
    pub finish_eos: bool,
    /// Model probability of EOS at the terminating step (§2.3.2).
    pub eos_prob: f32,
}

/// One uploaded file = one batch from one node for one step.
#[derive(Clone, Debug)]
pub struct Submission {
    pub node_address: u64,
    pub step: u64,
    /// Submission index for this node/step (seed formula input, §2.3.3).
    pub submission_idx: u64,
    pub rollouts: Vec<WireRollout>,
}

pub fn schema() -> Schema {
    vec![
        ("node", DType::U64),
        ("step", DType::U64),
        ("submission_idx", DType::U64),
        ("task_id", DType::U64),
        ("group_id", DType::U64),
        ("prompt_len", DType::U64),
        ("target_len", DType::U64),
        ("finish_eos", DType::U64),
        ("tokens", DType::I32List),
        ("task_reward", DType::F32),
        ("length_penalty", DType::F32),
        ("reward", DType::F32),
        ("eos_prob", DType::F32),
        ("sampled_probs", DType::F32List),
        ("commitment", DType::Bytes),
    ]
}

impl Submission {
    pub fn encode(&self) -> Vec<u8> {
        let n = self.rollouts.len();
        let rs = &self.rollouts;
        let mut f = RpqFile::new();
        f.push("node", Column::U64(vec![self.node_address; n]))
            .push("step", Column::U64(vec![self.step; n]))
            .push("submission_idx", Column::U64(vec![self.submission_idx; n]))
            .push("task_id", Column::U64(rs.iter().map(|r| r.rollout.task_id).collect()))
            .push("group_id", Column::U64(rs.iter().map(|r| r.rollout.group_id).collect()))
            .push("prompt_len", Column::U64(rs.iter().map(|r| r.rollout.prompt_len as u64).collect()))
            .push(
                "target_len",
                Column::U64(rs.iter().map(|r| r.rollout.target_len.unwrap_or(0) as u64).collect()),
            )
            .push("finish_eos", Column::U64(rs.iter().map(|r| r.finish_eos as u64).collect()))
            .push("tokens", Column::I32List(rs.iter().map(|r| r.rollout.tokens.clone()).collect()))
            .push("task_reward", Column::F32(rs.iter().map(|r| r.rollout.task_reward).collect()))
            .push(
                "length_penalty",
                Column::F32(rs.iter().map(|r| r.rollout.length_penalty).collect()),
            )
            .push("reward", Column::F32(rs.iter().map(|r| r.rollout.reward).collect()))
            .push("eos_prob", Column::F32(rs.iter().map(|r| r.eos_prob).collect()))
            .push(
                "sampled_probs",
                Column::F32List(rs.iter().map(|r| r.rollout.sampled_probs.clone()).collect()),
            )
            .push("commitment", Column::Bytes(rs.iter().map(|r| r.commitment.clone()).collect()));
        f.encode()
    }

    /// Best-effort sender attribution for submissions that fail the full
    /// schema check: if the container decodes (checksum intact) and carries
    /// a uniform `node` column, that address claimed the upload. Used to
    /// slash the actual sender of a malformed-but-attributable file instead
    /// of a ghost node; a file mangled beyond this yields `None` and the
    /// rejection is only counted.
    pub fn peek_node_address(bytes: &[u8]) -> Option<u64> {
        let f = RpqFile::decode(bytes).ok()?;
        let nodes = f.col("node")?.as_u64()?;
        let first = *nodes.first()?;
        nodes.iter().all(|&n| n == first).then_some(first)
    }

    /// Decode + schema-validate (the validator's "parquet formatting
    /// check": anything that would throw in the trainer dataloader is
    /// rejected here).
    pub fn decode(bytes: &[u8]) -> anyhow::Result<Submission> {
        let f = RpqFile::decode(bytes)?;
        f.validate_schema(&schema())?;
        let n = f.n_rows();
        anyhow::ensure!(n > 0, "empty submission");
        let u64s = |name: &str| f.col(name).unwrap().as_u64().unwrap().to_vec();
        let f32s = |name: &str| f.col(name).unwrap().as_f32().unwrap().to_vec();
        let node = u64s("node");
        let step = u64s("step");
        let sub = u64s("submission_idx");
        anyhow::ensure!(
            node.windows(2).all(|w| w[0] == w[1])
                && step.windows(2).all(|w| w[0] == w[1])
                && sub.windows(2).all(|w| w[0] == w[1]),
            "mixed node/step/submission in one file"
        );
        let task_id = u64s("task_id");
        let group_id = u64s("group_id");
        let prompt_len = u64s("prompt_len");
        let target_len = u64s("target_len");
        let finish = u64s("finish_eos");
        let tokens = f.col("tokens").unwrap().as_i32_list().unwrap().to_vec();
        let task_reward = f32s("task_reward");
        let length_penalty = f32s("length_penalty");
        let reward = f32s("reward");
        let eos_prob = f32s("eos_prob");
        let probs = f.col("sampled_probs").unwrap().as_f32_list().unwrap().to_vec();
        let commits = f.col("commitment").unwrap().as_bytes().unwrap().to_vec();

        let rollouts = (0..n)
            .map(|i| {
                anyhow::ensure!(
                    (prompt_len[i] as usize) < tokens[i].len().max(1),
                    "row {i}: prompt_len >= tokens"
                );
                Ok(WireRollout {
                    rollout: Rollout {
                        task_id: task_id[i],
                        group_id: group_id[i],
                        policy_step: step[i],
                        tokens: tokens[i].clone(),
                        prompt_len: prompt_len[i] as usize,
                        target_len: if target_len[i] == 0 { None } else { Some(target_len[i] as usize) },
                        task_reward: task_reward[i],
                        length_penalty: length_penalty[i],
                        reward: reward[i],
                        advantage: 0.0,
                        sampled_probs: probs[i].clone(),
                        node_address: node[i],
                    },
                    commitment: commits[i].clone(),
                    finish_eos: finish[i] != 0,
                    eos_prob: eos_prob[i],
                })
            })
            .collect::<anyhow::Result<Vec<_>>>()?;
        Ok(Submission { node_address: node[0], step: step[0], submission_idx: sub[0], rollouts })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    pub(crate) fn sample_submission() -> Submission {
        let mk = |task: u64, group: u64, len: usize| WireRollout {
            rollout: Rollout {
                task_id: task,
                group_id: group,
                policy_step: 4,
                tokens: (0..len as i32).map(|i| 1 + i % 60).collect(),
                prompt_len: 3,
                target_len: if task % 2 == 0 { Some(32) } else { None },
                task_reward: (task % 2) as f32,
                length_penalty: 0.01,
                reward: (task % 2) as f32 - 0.01,
                advantage: 0.0,
                sampled_probs: vec![0.4; len - 3],
                node_address: 0xAB,
            },
            commitment: vec![1, 2, 3, task as u8],
            finish_eos: task % 2 == 0,
            eos_prob: 0.5,
        };
        Submission {
            node_address: 0xAB,
            step: 4,
            submission_idx: 1,
            rollouts: vec![mk(0, 0, 10), mk(1, 0, 14), mk(2, 1, 8)],
        }
    }

    #[test]
    fn roundtrip() {
        let s = sample_submission();
        let bytes = s.encode();
        let d = Submission::decode(&bytes).unwrap();
        assert_eq!(d.node_address, 0xAB);
        assert_eq!(d.step, 4);
        assert_eq!(d.rollouts.len(), 3);
        assert_eq!(d.rollouts[1].rollout.tokens, s.rollouts[1].rollout.tokens);
        assert_eq!(d.rollouts[0].rollout.target_len, Some(32));
        assert_eq!(d.rollouts[1].rollout.target_len, None);
        assert_eq!(d.rollouts[2].commitment, vec![1, 2, 3, 2]);
    }

    #[test]
    fn corrupt_rejected() {
        let mut bytes = sample_submission().encode();
        let n = bytes.len();
        bytes[n / 2] ^= 0x55;
        assert!(Submission::decode(&bytes).is_err());
        // Checksum-broken container: no attribution possible.
        assert_eq!(Submission::peek_node_address(&bytes), None);
    }

    #[test]
    fn peek_attributes_schema_invalid_submissions() {
        // A decodable container with a bogus schema still names its sender.
        let mut f = RpqFile::new();
        f.push("node", Column::U64(vec![0xC0FFEE; 3]))
            .push("junk", Column::F32(vec![1.0; 3]));
        let bytes = f.encode();
        assert!(Submission::decode(&bytes).is_err());
        assert_eq!(Submission::peek_node_address(&bytes), Some(0xC0FFEE));
        // A mixed node column proves nothing -> no attribution.
        let mut g = RpqFile::new();
        g.push("node", Column::U64(vec![1, 2]));
        assert_eq!(Submission::peek_node_address(&g.encode()), None);
    }

    #[test]
    fn wrong_schema_rejected() {
        // A structurally-valid rpq file with the wrong columns.
        let mut f = RpqFile::new();
        f.push("whatever", Column::U64(vec![1]));
        assert!(Submission::decode(&f.encode()).is_err());
    }
}
