//! Rewards (paper §3.1): binary task reward plus the L1-style length
//! penalty  r_total = r_task - alpha * |l_target - l_y|.

use crate::tasks::Task;
use crate::util::rng::Rng;
use crate::verifier::Registry;

#[derive(Clone, Debug)]
pub struct RewardConfig {
    /// Length-penalty weight (paper §4.1 uses 0.0003 at 32K context; our
    /// sequences are ~100x shorter so the default is scaled up).
    pub alpha: f32,
    /// Discrete target-length set sampled per prompt (§3.1.2 — discrete,
    /// unlike L1's continuous range). Empty = no length rewards.
    pub targets: Vec<usize>,
}

impl Default for RewardConfig {
    fn default() -> Self {
        RewardConfig { alpha: 0.0, targets: Vec::new() }
    }
}

impl RewardConfig {
    /// TARGET-SHORT analogue (paper: {1000,2000,3000,4000} at 32K ctx;
    /// scaled to our 256-token context).
    pub fn target_short() -> RewardConfig {
        RewardConfig { alpha: 0.01, targets: vec![16, 32, 48, 64] }
    }

    /// TARGET-LONG analogue (paper: {2000,...,10000}).
    pub fn target_long() -> RewardConfig {
        RewardConfig { alpha: 0.01, targets: vec![32, 64, 96, 128, 160] }
    }

    /// Sample a thinking budget for a prompt (None if length rewards off).
    pub fn sample_target(&self, rng: &mut Rng) -> Option<usize> {
        if self.targets.is_empty() {
            None
        } else {
            Some(*rng.choice(&self.targets))
        }
    }
}

/// Task reward: binary verifiable (1 correct / 0 incorrect), §3.1.1.
pub fn task_reward(reg: &Registry, task: &Task, completion: &str) -> f32 {
    if reg.verify(task, completion) {
        1.0
    } else {
        0.0
    }
}

/// Length penalty term (0 when no target was requested).
pub fn length_penalty(alpha: f32, completion_len: usize, target: Option<usize>) -> f32 {
    match target {
        Some(t) => alpha * (completion_len as f32 - t as f32).abs(),
        None => 0.0,
    }
}

/// Total reward r_task - alpha * |l_target - l_y|.
pub fn total_reward(task_r: f32, alpha: f32, completion_len: usize, target: Option<usize>) -> f32 {
    task_r - length_penalty(alpha, completion_len, target)
}

/// Validator-side value-bounds check (§2.3.3): rewards/advantages reported
/// by untrusted parties must be plausible.
pub fn reward_in_bounds(cfg: &RewardConfig, reward: f32, max_completion: usize) -> bool {
    let max_pen = match cfg.targets.iter().max() {
        Some(&t) => cfg.alpha * (t.max(max_completion)) as f32,
        None => 0.0,
    };
    reward.is_finite() && reward <= 1.0 + 1e-6 && reward >= -max_pen - 1e-6
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tasks::math;

    #[test]
    fn binary_task_reward() {
        let reg = Registry::default();
        let mut rng = Rng::new(1);
        let t = math::generate(0, 0, &mut rng);
        assert_eq!(task_reward(&reg, &t, t.answer()), 1.0);
        assert_eq!(task_reward(&reg, &t, "wrong"), 0.0);
        // Dispatch is registry-wide: every registered env rewards its own
        // reference completion.
        for name in reg.names() {
            let t = reg.generate(name, 1, 1, &mut rng).unwrap();
            assert_eq!(task_reward(&reg, &t, t.answer()), 1.0, "{name}");
        }
    }

    #[test]
    fn length_penalty_shape() {
        assert_eq!(length_penalty(0.01, 64, Some(64)), 0.0);
        assert!((length_penalty(0.01, 32, Some(64)) - 0.32).abs() < 1e-6);
        assert_eq!(length_penalty(0.01, 32, None), 0.0);
        // Penalty symmetric: overshoot == undershoot.
        assert_eq!(
            length_penalty(0.01, 96, Some(64)),
            length_penalty(0.01, 32, Some(64))
        );
    }

    #[test]
    fn totals_combine() {
        assert!((total_reward(1.0, 0.01, 32, Some(64)) - 0.68).abs() < 1e-6);
        assert_eq!(total_reward(0.0, 0.0, 100, None), 0.0);
    }

    #[test]
    fn bounds_check() {
        let cfg = RewardConfig::target_short();
        assert!(reward_in_bounds(&cfg, 1.0, 128));
        assert!(reward_in_bounds(&cfg, -0.5, 128));
        assert!(!reward_in_bounds(&cfg, 5.0, 128));
        assert!(!reward_in_bounds(&cfg, f32::NAN, 128));
        assert!(!reward_in_bounds(&cfg, -100.0, 128));
    }

    #[test]
    fn target_sampling_from_discrete_set() {
        let cfg = RewardConfig::target_short();
        let mut rng = Rng::new(5);
        for _ in 0..50 {
            let t = cfg.sample_target(&mut rng).unwrap();
            assert!(cfg.targets.contains(&t));
        }
        assert_eq!(RewardConfig::default().sample_target(&mut rng), None);
    }
}
