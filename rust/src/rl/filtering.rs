//! Offline difficulty filtering (paper §3.3.1): estimate the base model's
//! pass@k per task, keep tasks inside a difficulty band. The paper filters
//! Deepscaler math with DeepSeek-R1-Distill-Qwen-7B, keeping pass@8 between
//! 12.5% and 50% (1..=4 of 8); we reproduce the same band logic.

#[derive(Clone, Copy, Debug)]
pub struct FilterBand {
    pub k: usize,
    /// Keep tasks with at least this many passes out of k...
    pub min_pass: usize,
    /// ...and at most this many.
    pub max_pass: usize,
}

impl Default for FilterBand {
    /// The paper's band: pass@8 in [1, 4] (12.5%..50%).
    fn default() -> Self {
        FilterBand { k: 8, min_pass: 1, max_pass: 4 }
    }
}

#[derive(Clone, Debug, Default)]
pub struct PassStats {
    /// (task_id, owning env, passes out of k).
    pub per_task: Vec<(u64, &'static str, usize)>,
}

impl PassStats {
    pub fn record(&mut self, task_id: u64, env: &'static str, passes: usize) {
        self.per_task.push((task_id, env, passes));
    }

    /// Task ids inside the band (the filtered training set).
    pub fn keep(&self, band: &FilterBand) -> Vec<u64> {
        self.per_task
            .iter()
            .filter(|(_, _, p)| *p >= band.min_pass && *p <= band.max_pass)
            .map(|(id, _, _)| *id)
            .collect()
    }

    /// Fractions (too_easy, in_band, too_hard) for reporting.
    pub fn band_fractions(&self, band: &FilterBand) -> (f64, f64, f64) {
        let n = self.per_task.len().max(1) as f64;
        let easy = self.per_task.iter().filter(|(_, _, p)| *p > band.max_pass).count() as f64;
        let hard = self.per_task.iter().filter(|(_, _, p)| *p < band.min_pass).count() as f64;
        (easy / n, 1.0 - (easy + hard) / n, hard / n)
    }

    /// Per-environment `(env, kept, total)` breakdown — mixed-env filtering
    /// observability (a band that keeps plenty of math can still starve a
    /// harder env out of the training set entirely).
    pub fn by_env(&self, band: &FilterBand) -> Vec<(&'static str, usize, usize)> {
        let mut out: Vec<(&'static str, usize, usize)> = Vec::new();
        for (_, env, p) in &self.per_task {
            let kept = (*p >= band.min_pass && *p <= band.max_pass) as usize;
            match out.iter_mut().find(|(n, _, _)| n == env) {
                Some((_, k, t)) => {
                    *k += kept;
                    *t += 1;
                }
                None => out.push((env, kept, 1)),
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn band_keeps_middle() {
        let mut s = PassStats::default();
        s.record(0, "math", 0); // too hard
        s.record(1, "math", 1); // keep
        s.record(2, "code", 4); // keep
        s.record(3, "code", 5); // too easy
        s.record(4, "math", 8); // too easy
        let band = FilterBand::default();
        assert_eq!(s.keep(&band), vec![1, 2]);
        let (easy, mid, hard) = s.band_fractions(&band);
        assert!((easy - 0.4).abs() < 1e-9);
        assert!((mid - 0.4).abs() < 1e-9);
        assert!((hard - 0.2).abs() < 1e-9);
        assert_eq!(s.by_env(&band), vec![("math", 1, 3), ("code", 1, 2)]);
    }

    #[test]
    fn empty_stats() {
        let s = PassStats::default();
        assert!(s.keep(&FilterBand::default()).is_empty());
    }
}
