//! Group-relative advantages (GRPO, §3.4) and online filtering (§3.3.2).

use super::Rollout;
use std::collections::BTreeMap;

/// Compute group-normalized advantages in place:
/// A_i = (r_i - mean(group)) / (std(group) + eps). Returns per-group stats
/// (group_id, mean, std, all_same_reward).
pub fn compute_group_advantages(rollouts: &mut [Rollout]) -> Vec<(u64, f32, f32, bool)> {
    let mut groups: BTreeMap<u64, Vec<usize>> = BTreeMap::new();
    for (i, r) in rollouts.iter().enumerate() {
        groups.entry(r.group_id).or_default().push(i);
    }
    let mut stats = Vec::with_capacity(groups.len());
    for (gid, idxs) in groups {
        let n = idxs.len() as f32;
        let mean = idxs.iter().map(|&i| rollouts[i].reward).sum::<f32>() / n;
        let var = idxs.iter().map(|&i| (rollouts[i].reward - mean).powi(2)).sum::<f32>() / n;
        let std = var.sqrt();
        let degenerate = std < 1e-6;
        for &i in &idxs {
            rollouts[i].advantage = if degenerate {
                0.0
            } else {
                (rollouts[i].reward - mean) / (std + 1e-4)
            };
        }
        stats.push((gid, mean, std, degenerate));
    }
    stats
}

/// Online filtering (§3.3.2): keep only groups with non-zero advantage
/// spread; all-same-reward groups contribute no training signal and are
/// discarded (workers keep sampling until the batch fills). Returns
/// (kept rollouts, number of discarded groups).
pub fn online_filter(mut rollouts: Vec<Rollout>) -> (Vec<Rollout>, usize) {
    let stats = compute_group_advantages(&mut rollouts);
    let degenerate: Vec<u64> =
        stats.iter().filter(|(_, _, _, d)| *d).map(|(g, ..)| *g).collect();
    let n_discarded = degenerate.len();
    let kept = rollouts
        .into_iter()
        .filter(|r| !degenerate.contains(&r.group_id))
        .collect();
    (kept, n_discarded)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;
    use crate::util::rng::Rng;

    pub(crate) fn mk(group: u64, reward: f32) -> Rollout {
        Rollout {
            task_id: 0,
            group_id: group,
            policy_step: 0,
            tokens: vec![1, 5, 6, 2],
            prompt_len: 2,
            target_len: None,
            task_reward: reward,
            length_penalty: 0.0,
            reward,
            advantage: 0.0,
            sampled_probs: vec![0.5, 0.5],
            node_address: 0,
        }
    }

    #[test]
    fn advantages_zero_mean_within_group() {
        let mut rs = vec![mk(1, 1.0), mk(1, 0.0), mk(1, 1.0), mk(1, 0.0)];
        compute_group_advantages(&mut rs);
        let sum: f32 = rs.iter().map(|r| r.advantage).sum();
        assert!(sum.abs() < 1e-4);
        assert!(rs[0].advantage > 0.0 && rs[1].advantage < 0.0);
    }

    #[test]
    fn degenerate_groups_get_zero_advantage() {
        let mut rs = vec![mk(7, 1.0), mk(7, 1.0), mk(8, 0.0), mk(8, 0.0)];
        let stats = compute_group_advantages(&mut rs);
        assert!(rs.iter().all(|r| r.advantage == 0.0));
        assert!(stats.iter().all(|(_, _, _, d)| *d));
    }

    #[test]
    fn online_filter_drops_uninformative_groups() {
        let rs = vec![
            mk(1, 1.0),
            mk(1, 0.0),
            mk(2, 1.0),
            mk(2, 1.0), // degenerate
            mk(3, 0.0),
            mk(3, 0.0), // degenerate
        ];
        let (kept, dropped) = online_filter(rs);
        assert_eq!(dropped, 2);
        assert_eq!(kept.len(), 2);
        assert!(kept.iter().all(|r| r.group_id == 1));
    }

    #[test]
    fn prop_groups_isolated() {
        prop::check("advantage group isolation", 48, |rng: &mut Rng, size| {
            let n_groups = 1 + rng.usize(4);
            let mut rs = Vec::new();
            for g in 0..n_groups {
                for _ in 0..(2 + rng.usize(size as usize % 6 + 1)) {
                    rs.push(mk(g as u64, if rng.bool(0.5) { 1.0 } else { 0.0 }));
                }
            }
            rs
        }, |rs| {
            let mut a = rs.clone();
            compute_group_advantages(&mut a);
            // Per-group advantage sums vanish; magnitudes bounded.
            let mut sums: BTreeMap<u64, f32> = BTreeMap::new();
            for r in &a {
                *sums.entry(r.group_id).or_default() += r.advantage;
                prop::ensure(r.advantage.abs() < 100.0, "bounded")?;
            }
            for (_, s) in sums {
                prop::ensure(s.abs() < 1e-3, "zero mean per group")?;
            }
            Ok(())
        });
    }
}
