//! Cross-sample sequence packing (paper §4.1).
//!
//! RL needs whole samples (the loss is per-sample, not local), so instead
//! of truncating we collate multiple rollouts into each `[T]` row with
//! per-row segment ids; the L2 model applies a block-diagonal attention
//! mask and resets positions per segment, preserving the exact per-sample
//! logprobs (verified by `python/tests/test_model.py` and the packing
//! tests below). First-fit-decreasing keeps padding waste low.

use super::Rollout;
use crate::runtime::MicroBatch;

#[derive(Clone, Debug)]
pub struct Placement {
    pub rollout_idx: usize,
    pub batch: usize,
    pub row: usize,
    pub offset: usize,
    pub seg_id: i32,
}

#[derive(Debug, Default)]
pub struct PackResult {
    pub batches: Vec<MicroBatch>,
    pub placements: Vec<Placement>,
    /// Fraction of padded (wasted) token slots across all emitted batches.
    pub padding_fraction: f64,
    /// Padding fraction a naive one-sample-per-row layout would have needed
    /// (the §4.1 efficiency comparison).
    pub naive_padding_fraction: f64,
}

/// Pack rollouts into `[b, t]` micro-batches.
pub fn pack(rollouts: &[Rollout], b: usize, t: usize) -> PackResult {
    // First-fit-decreasing over rows.
    let mut order: Vec<usize> = (0..rollouts.len()).collect();
    order.sort_by_key(|&i| std::cmp::Reverse(rollouts[i].tokens.len()));

    struct Row {
        used: usize,
        next_seg: i32,
        items: Vec<(usize, usize, i32)>, // (rollout idx, offset, seg)
    }
    let mut rows: Vec<Row> = Vec::new();
    for &idx in &order {
        let len = rollouts[idx].tokens.len();
        assert!(len <= t, "rollout longer than context ({len} > {t})");
        let slot = rows.iter_mut().find(|r| r.used + len <= t);
        let row = match slot {
            Some(r) => r,
            None => {
                rows.push(Row { used: 0, next_seg: 1, items: Vec::new() });
                rows.last_mut().unwrap()
            }
        };
        row.items.push((idx, row.used, row.next_seg));
        row.used += len;
        row.next_seg += 1;
    }

    let n_batches = rows.len().div_ceil(b);
    let mut batches = Vec::with_capacity(n_batches);
    let mut placements = Vec::with_capacity(rollouts.len());
    let mut used_tokens = 0usize;

    for bi in 0..n_batches {
        let mut mb = MicroBatch {
            tokens: vec![0; b * t],
            segs: vec![0; b * t],
            loss_mask: vec![0.0; b * t],
            advantages: vec![0.0; b * t],
            old_logprobs: vec![0.0; b * t],
        };
        for ri in 0..b {
            let Some(row) = rows.get(bi * b + ri) else { break };
            for &(idx, offset, seg) in &row.items {
                let r = &rollouts[idx];
                let base = ri * t + offset;
                for (j, &tok) in r.tokens.iter().enumerate() {
                    mb.tokens[base + j] = tok;
                    mb.segs[base + j] = seg;
                }
                // Loss positions: completion tokens (predicting token j
                // from its prefix is valid for j >= 1; prompt_len >= 1
                // because prompts are BOS-prefixed).
                for j in r.prompt_len..r.tokens.len() {
                    mb.loss_mask[base + j] = 1.0;
                    mb.advantages[base + j] = r.advantage;
                }
                used_tokens += r.tokens.len();
                placements.push(Placement {
                    rollout_idx: idx,
                    batch: bi,
                    row: ri,
                    offset,
                    seg_id: seg,
                });
            }
        }
        batches.push(mb);
    }

    let capacity = (n_batches * b * t).max(1);
    let naive_rows = rollouts.len();
    let naive_capacity = (naive_rows.div_ceil(b) * b * t).max(1);
    PackResult {
        batches,
        placements,
        padding_fraction: 1.0 - used_tokens as f64 / capacity as f64,
        naive_padding_fraction: 1.0 - used_tokens as f64 / naive_capacity as f64,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;
    use crate::util::rng::Rng;

    fn mk(len: usize, prompt_len: usize, adv: f32) -> Rollout {
        Rollout {
            task_id: 0,
            group_id: 0,
            policy_step: 0,
            tokens: (0..len as i32).map(|i| 3 + (i % 50)).collect(),
            prompt_len,
            target_len: None,
            task_reward: 0.0,
            length_penalty: 0.0,
            reward: 0.0,
            advantage: adv,
            sampled_probs: vec![0.1; len - prompt_len],
            node_address: 0,
        }
    }

    #[test]
    fn two_short_fit_one_row() {
        let rs = vec![mk(10, 3, 1.0), mk(12, 4, -1.0)];
        let out = pack(&rs, 2, 32);
        assert_eq!(out.batches.len(), 1);
        // Both land in row 0 (FFD), distinct segments.
        let mb = &out.batches[0];
        let segs_row0: Vec<i32> = mb.segs[..32].to_vec();
        assert_eq!(segs_row0[..12], vec![1; 12][..]);
        assert_eq!(segs_row0[12..22], vec![2; 10][..]);
        assert_eq!(segs_row0[22..], vec![0; 10][..]);
    }

    #[test]
    fn loss_mask_covers_exactly_completions() {
        let rs = vec![mk(20, 5, 2.0)];
        let out = pack(&rs, 1, 32);
        let mb = &out.batches[0];
        let mask_on: usize = mb.loss_mask.iter().filter(|&&m| m == 1.0).count();
        assert_eq!(mask_on, 15);
        for j in 0..32 {
            let expect = (5..20).contains(&j);
            assert_eq!(mb.loss_mask[j] == 1.0, expect, "{j}");
            assert_eq!(mb.advantages[j], if expect { 2.0 } else { 0.0 });
        }
    }

    #[test]
    fn packing_beats_naive_padding() {
        let mut rng = Rng::new(4);
        let rs: Vec<Rollout> = (0..40)
            .map(|_| {
                let len = 8 + rng.usize(56);
                mk(len, 4, 1.0)
            })
            .collect();
        let out = pack(&rs, 4, 64);
        assert!(out.padding_fraction < out.naive_padding_fraction);
        assert!(out.padding_fraction < 0.35, "{}", out.padding_fraction);
    }

    #[test]
    fn prop_pack_preserves_all_tokens_no_overlap() {
        prop::check("packing integrity", 48, |rng: &mut Rng, size| {
            let n = 1 + rng.usize((size as usize).clamp(1, 30));
            (0..n)
                .map(|_| {
                    let len = 4 + rng.usize(60);
                    mk(len, 1 + rng.usize(len - 2), rng.normal() as f32)
                })
                .collect::<Vec<_>>()
        }, |rs| {
            let out = pack(rs, 4, 64);
            prop::ensure_eq(out.placements.len(), rs.len(), "all placed")?;
            // Rebuild each rollout from its placement.
            for p in &out.placements {
                let r = &rs[p.rollout_idx];
                let mb = &out.batches[p.batch];
                let base = p.row * 64 + p.offset;
                for (j, &tok) in r.tokens.iter().enumerate() {
                    prop::ensure_eq(mb.tokens[base + j], tok, "token preserved")?;
                    prop::ensure_eq(mb.segs[base + j], p.seg_id, "segment uniform")?;
                }
            }
            // No two placements overlap: count used slots == sum of lens.
            let total: usize = rs.iter().map(|r| r.tokens.len()).sum();
            let used: usize = out
                .batches
                .iter()
                .flat_map(|mb| mb.segs.iter())
                .filter(|&&s| s != 0)
                .count();
            prop::ensure_eq(used, total, "no overlap / no loss")?;
            Ok(())
        });
    }
}
