//! Version-tagged verified-rollout buffer (§3.2): the trainer-side queue
//! between TOPLOC validation and GRPO batching in the asynchronous swarm.
//!
//! Every batch of verified rollouts is tagged with the policy version that
//! generated it. The buffer enforces the paper's bounded off-policy window:
//! rollouts from versions in `[current - window, current]` (and versions
//! published ahead of the trainer's step counter, which are at most one
//! step "in the future" during the broadcast overlap) are admitted; older
//! ones are dropped and counted. Advancing the step re-checks everything
//! still buffered, so rollouts that were fresh when verified but went stale
//! while the trainer was busy are evicted before they can poison a batch.

use std::collections::BTreeMap;
use std::sync::Mutex;

use super::Rollout;

/// What happened to a batch offered to the buffer.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Admission {
    /// Within the window; `lag` = current_step - version (0 for versions
    /// at or ahead of the current step).
    Accepted { lag: u64 },
    /// Older than `current - window`: dropped, never buffered.
    TooStale { lag: u64 },
}

/// Snapshot of the buffer's staleness accounting.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct StalenessStats {
    /// `(lag, n_rollouts)` counted when rollouts are drained for training:
    /// lag = training step - producing policy version.
    pub trained_by_lag: Vec<(u64, u64)>,
    /// Rollouts rejected at push time (version already outside the window).
    pub dropped_at_push: u64,
    /// Rollouts evicted by `advance` (went stale while buffered).
    pub evicted_on_advance: u64,
}

impl StalenessStats {
    pub fn dropped_total(&self) -> u64 {
        self.dropped_at_push + self.evicted_on_advance
    }

    pub fn trained_total(&self) -> u64 {
        self.trained_by_lag.iter().map(|(_, n)| n).sum()
    }
}

struct Inner {
    current: u64,
    /// version -> rollouts verified under that version (insertion order kept
    /// within a version; BTreeMap keeps drain ordering oldest-first).
    by_version: BTreeMap<u64, Vec<Rollout>>,
    len: usize,
    trained_by_lag: BTreeMap<u64, u64>,
    dropped_at_push: u64,
    evicted_on_advance: u64,
}

/// Thread-safe staleness-windowed rollout buffer.
pub struct RolloutBuffer {
    window: u64,
    inner: Mutex<Inner>,
}

impl RolloutBuffer {
    /// `window` is the asynchrony level k: versions in `[current - k,
    /// current]` are acceptable at training time.
    pub fn new(window: u64) -> RolloutBuffer {
        RolloutBuffer {
            window,
            inner: Mutex::new(Inner {
                current: 0,
                by_version: BTreeMap::new(),
                len: 0,
                trained_by_lag: BTreeMap::new(),
                dropped_at_push: 0,
                evicted_on_advance: 0,
            }),
        }
    }

    pub fn window(&self) -> u64 {
        self.window
    }

    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().len
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn current(&self) -> u64 {
        self.inner.lock().unwrap().current
    }

    /// Offer verified rollouts generated under policy `version`. Versions
    /// ahead of the current step (the worker already fetched the checkpoint
    /// the trainer just published) are admitted with lag 0.
    pub fn push(&self, version: u64, rollouts: Vec<Rollout>) -> Admission {
        let mut inner = self.inner.lock().unwrap();
        let lag = inner.current.saturating_sub(version);
        if lag > self.window {
            inner.dropped_at_push += rollouts.len() as u64;
            return Admission::TooStale { lag };
        }
        inner.len += rollouts.len();
        inner.by_version.entry(version).or_default().extend(rollouts);
        Admission::Accepted { lag }
    }

    /// Move the trainer's step forward, evicting anything that fell out of
    /// the window while buffered. Returns the number of evicted rollouts.
    pub fn advance(&self, step: u64) -> u64 {
        let mut inner = self.inner.lock().unwrap();
        inner.current = inner.current.max(step);
        let min_version = inner.current.saturating_sub(self.window);
        let stale: Vec<u64> = inner.by_version.range(..min_version).map(|(&v, _)| v).collect();
        let mut evicted = 0u64;
        for v in stale {
            let dropped = inner.by_version.remove(&v).unwrap_or_default();
            evicted += dropped.len() as u64;
            inner.len -= dropped.len();
        }
        inner.evicted_on_advance += evicted;
        evicted
    }

    /// Take everything buffered, oldest version first (so the batch the
    /// trainer consumes is as close to FIFO in policy-version order as the
    /// swarm allows). Records the per-lag histogram of what was drained.
    pub fn drain(&self) -> Vec<Rollout> {
        let mut inner = self.inner.lock().unwrap();
        let current = inner.current;
        let by_version = std::mem::take(&mut inner.by_version);
        inner.len = 0;
        let mut out = Vec::new();
        for (version, rollouts) in by_version {
            let lag = current.saturating_sub(version);
            *inner.trained_by_lag.entry(lag).or_insert(0) += rollouts.len() as u64;
            out.extend(rollouts);
        }
        out
    }

    pub fn stats(&self) -> StalenessStats {
        let inner = self.inner.lock().unwrap();
        StalenessStats {
            trained_by_lag: inner.trained_by_lag.iter().map(|(&l, &n)| (l, n)).collect(),
            dropped_at_push: inner.dropped_at_push,
            evicted_on_advance: inner.evicted_on_advance,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;
    use crate::util::rng::Rng;

    fn mk(version: u64, tag: u64) -> Rollout {
        Rollout {
            task_id: tag,
            group_id: tag,
            policy_step: version,
            tokens: vec![1, 5, 2],
            prompt_len: 1,
            target_len: None,
            task_reward: 0.0,
            length_penalty: 0.0,
            reward: 0.0,
            advantage: 0.0,
            sampled_probs: vec![0.5, 0.5],
            node_address: 7,
        }
    }

    #[test]
    fn window_acceptance_and_lag() {
        let b = RolloutBuffer::new(2);
        b.advance(5);
        assert_eq!(b.push(5, vec![mk(5, 0)]), Admission::Accepted { lag: 0 });
        assert_eq!(b.push(4, vec![mk(4, 1)]), Admission::Accepted { lag: 1 });
        assert_eq!(b.push(3, vec![mk(3, 2)]), Admission::Accepted { lag: 2 });
        // Ahead of the trainer (broadcast overlap): admitted at lag 0.
        assert_eq!(b.push(6, vec![mk(6, 3)]), Admission::Accepted { lag: 0 });
        assert_eq!(b.len(), 4);
    }

    #[test]
    fn too_stale_is_dropped_and_counted() {
        let b = RolloutBuffer::new(2);
        b.advance(10);
        assert_eq!(
            b.push(7, vec![mk(7, 0), mk(7, 1)]),
            Admission::TooStale { lag: 3 }
        );
        assert_eq!(b.len(), 0);
        assert_eq!(b.stats().dropped_at_push, 2);
        assert_eq!(b.stats().dropped_total(), 2);
    }

    #[test]
    fn advance_evicts_buffered_rollouts_that_went_stale() {
        let b = RolloutBuffer::new(1);
        b.push(0, vec![mk(0, 0), mk(0, 1)]);
        b.push(1, vec![mk(1, 2)]);
        // Step 2: version 0 is out of [1, 2]; version 1 survives.
        assert_eq!(b.advance(2), 2);
        assert_eq!(b.len(), 1);
        assert_eq!(b.stats().evicted_on_advance, 2);
        // Advancing backwards is a no-op (current is monotone).
        assert_eq!(b.advance(0), 0);
        assert_eq!(b.current(), 2);
    }

    #[test]
    fn drain_is_oldest_version_first_and_records_histogram() {
        let b = RolloutBuffer::new(3);
        b.advance(3);
        b.push(3, vec![mk(3, 30)]);
        b.push(1, vec![mk(1, 10), mk(1, 11)]);
        b.push(2, vec![mk(2, 20)]);
        let drained = b.drain();
        let versions: Vec<u64> = drained.iter().map(|r| r.policy_step).collect();
        assert_eq!(versions, vec![1, 1, 2, 3]);
        assert!(b.is_empty());
        let stats = b.stats();
        assert_eq!(stats.trained_by_lag, vec![(0, 1), (1, 1), (2, 2)]);
        assert_eq!(stats.trained_total(), 4);
    }

    #[test]
    fn prop_no_drained_rollout_outside_window() {
        prop::check(
            "staleness window invariant",
            64,
            |rng: &mut Rng, size| {
                let window = rng.usize(4) as u64;
                let ops: Vec<(bool, u64)> = (0..1 + rng.usize(size as usize % 40 + 1))
                    .map(|_| (rng.bool(0.3), rng.usize(12) as u64))
                    .collect();
                (window, ops)
            },
            |(window, ops)| {
                let b = RolloutBuffer::new(*window);
                let mut pushed = 0u64;
                for (is_advance, v) in ops {
                    if *is_advance {
                        b.advance(*v);
                    } else {
                        b.push(*v, vec![mk(*v, pushed)]);
                        pushed += 1;
                    }
                }
                let current = b.current();
                let drained = b.drain();
                // Everything drained respects the window at drain time.
                for r in &drained {
                    prop::ensure(
                        r.policy_step + *window >= current,
                        "drained rollout outside window",
                    )?;
                }
                // Conservation: pushed = drained + dropped + evicted.
                let stats = b.stats();
                prop::ensure_eq(
                    pushed,
                    drained.len() as u64 + stats.dropped_total(),
                    "rollout conservation",
                )?;
                prop::ensure_eq(stats.trained_total(), drained.len() as u64, "histogram total")?;
                Ok(())
            },
        );
    }
}
