//! The GRPO training-recipe layer (paper §3): rewards (task + length),
//! group advantages, online/offline data filtering, sequence packing.

pub mod advantage;
pub mod filtering;
pub mod packing;
pub mod reward;
pub mod rollout_file;

/// One verified rollout as it flows trainer-ward: produced by an inference
/// worker, checked by a TOPLOC validator, packed into micro-batches by the
/// trainer.
#[derive(Clone, Debug)]
pub struct Rollout {
    pub task_id: u64,
    /// GRPO group: all completions of one prompt instance share this.
    pub group_id: u64,
    /// RL step whose policy generated this rollout (async-k bookkeeping).
    pub policy_step: u64,
    /// Prompt + completion tokens (BOS-prefixed, EOS-terminated if any).
    pub tokens: Vec<i32>,
    pub prompt_len: usize,
    /// Thinking-budget target, if the prompt carried one (§3.1.2).
    pub target_len: Option<usize>,
    pub task_reward: f32,
    pub length_penalty: f32,
    pub reward: f32,
    /// Filled by group-advantage computation.
    pub advantage: f32,
    /// Model probability of each sampled completion token (TOPLOC input).
    pub sampled_probs: Vec<f32>,
    /// Producing node (slashing / seed-reproduction bookkeeping).
    pub node_address: u64,
}

impl Rollout {
    pub fn completion_len(&self) -> usize {
        self.tokens.len() - self.prompt_len
    }
}
