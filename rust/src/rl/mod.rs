//! The GRPO training-recipe layer (paper §3): rewards (task + length),
//! group advantages, online/offline data filtering, sequence packing.

pub mod advantage;
pub mod buffer;
pub mod filtering;
pub mod packing;
pub mod reward;
pub mod rollout_file;

pub use buffer::{Admission, RolloutBuffer, StalenessStats};

/// One verified rollout as it flows trainer-ward: produced by an inference
/// worker, checked by a TOPLOC validator, packed into micro-batches by the
/// trainer.
#[derive(Clone, Debug)]
pub struct Rollout {
    pub task_id: u64,
    /// GRPO group: all completions of one prompt instance share this.
    pub group_id: u64,
    /// RL step whose policy generated this rollout (async-k bookkeeping).
    pub policy_step: u64,
    /// Prompt + completion tokens (BOS-prefixed, EOS-terminated if any).
    pub tokens: Vec<i32>,
    pub prompt_len: usize,
    /// Thinking-budget target, if the prompt carried one (§3.1.2).
    pub target_len: Option<usize>,
    pub task_reward: f32,
    pub length_penalty: f32,
    pub reward: f32,
    /// Filled by group-advantage computation.
    pub advantage: f32,
    /// Model probability of each sampled completion token (TOPLOC input).
    pub sampled_probs: Vec<f32>,
    /// Producing node (slashing / seed-reproduction bookkeeping).
    pub node_address: u64,
}

impl Rollout {
    pub fn completion_len(&self) -> usize {
        self.tokens.len() - self.prompt_len
    }
}

/// Collision-resistant GRPO group-id base for one `(node, version, idx)`
/// submission. Group ids within the submission are `base + prompt_index`,
/// so the low 16 bits are reserved (up to 65536 prompts per submission)
/// and the remaining 48 bits come from a SplitMix64-style mix of the full
/// address/version/idx triple. Deterministic on both sides: workers derive
/// their ids from it and the TOPLOC validator re-derives and enforces
/// them, so one node cannot steer its rollouts into another node's groups.
/// (The previous shift-and-xor scheme, `(address << 20) ^ ...`, silently
/// discarded the high 20 address bits, letting two nodes collide and have
/// their rollouts averaged into one group by `compute_group_advantages`.)
pub fn group_id_base(node_address: u64, version: u64, submission_idx: u64) -> u64 {
    let mut h = node_address ^ 0x9E3779B97F4A7C15;
    for k in [version, submission_idx] {
        h ^= k.wrapping_add(0x9E3779B97F4A7C15).wrapping_add(h << 6).wrapping_add(h >> 2);
        h = (h ^ (h >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        h = (h ^ (h >> 27)).wrapping_mul(0x94D049BB133111EB);
        h ^= h >> 31;
    }
    h & !0xFFFFu64
}
