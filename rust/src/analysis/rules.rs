//! The swarmlint rules engine: token-stream checks for the determinism and
//! slashability invariants of the trust-critical modules.
//!
//! See [`crate::analysis`] for the rule catalogue and the annotation
//! syntax. Everything here is heuristic *token-level* analysis — no type
//! information — tuned to this repository's idioms; the limitations of
//! each check are documented on its scan function.

use super::lexer::{lex, TokKind, Token};

/// The rule catalogue. `BadAnnotation` is the meta-rule: a suppression
/// comment that does not parse (or lacks a justification) is itself a
/// violation and can never be suppressed.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum Rule {
    UnorderedIter,
    WallClock,
    PanicPath,
    FloatFold,
    LockOrder,
    ValidatorSecret,
    BadAnnotation,
}

impl Rule {
    pub fn name(self) -> &'static str {
        match self {
            Rule::UnorderedIter => "unordered-iter",
            Rule::WallClock => "wall-clock",
            Rule::PanicPath => "panic-path",
            Rule::FloatFold => "float-fold",
            Rule::LockOrder => "lock-order",
            Rule::ValidatorSecret => "validator-secret",
            Rule::BadAnnotation => "bad-annotation",
        }
    }

    pub fn parse(s: &str) -> Option<Rule> {
        match s {
            "unordered-iter" => Some(Rule::UnorderedIter),
            "wall-clock" => Some(Rule::WallClock),
            "panic-path" => Some(Rule::PanicPath),
            "float-fold" => Some(Rule::FloatFold),
            "lock-order" => Some(Rule::LockOrder),
            "validator-secret" => Some(Rule::ValidatorSecret),
            _ => None,
        }
    }
}

#[derive(Clone, Debug)]
pub struct Violation {
    pub file: String,
    pub line: u32,
    pub rule: Rule,
    pub message: String,
    /// True when a matching `swarmlint: allow` annotation covers it.
    pub suppressed: bool,
    /// The annotation's justification, when suppressed.
    pub justification: Option<String>,
}

/// A parsed `// swarmlint: allow(<rules>) — <justification>` comment.
#[derive(Clone, Debug)]
pub struct Annotation {
    /// Line of the comment itself.
    pub line: u32,
    /// Line the annotation governs: its own line for a trailing comment,
    /// else the first code line below it.
    pub target_line: u32,
    /// `allow-fn` form: covers the whole function starting at the target
    /// line (for e.g. byte parsers whose every index is bounds-guarded).
    pub fn_scoped: bool,
    pub rules: Vec<Rule>,
    pub justification: String,
    /// Set when the annotation suppressed at least one violation.
    pub used: bool,
}

/// One `.lock()` acquisition, classed by `module::receiver`.
#[derive(Clone, Debug)]
pub struct LockSite {
    pub class: String,
    pub line: u32,
}

/// A nested acquisition: `acquired` taken while `held` is live.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct LockEdge {
    pub held: String,
    pub acquired: String,
    pub line: u32,
}

pub struct Config {
    /// Path prefixes (relative to `src/`) where R1–R4 apply.
    pub trust_prefixes: Vec<String>,
    /// Path prefixes (relative to `src/`) of *worker-side* code, where R6
    /// applies: these modules must never reference the validator's
    /// commit-reveal audit-selection machinery. The sim derives the
    /// commitment secret from the shared run seed (see
    /// `coordinator/swarm.rs`), which is sound only because no worker
    /// code path can read it — this list is what makes that claim
    /// mechanical.
    pub worker_prefixes: Vec<String>,
    /// Declared lock hierarchy, outermost first. Nested acquisitions must
    /// step strictly forward in this list; see [`super::lockmap`].
    pub lock_order: Vec<String>,
}

/// The repository's gate configuration: the trust-critical module set from
/// the determinism contract (see [`crate::toploc`]) plus `util::rng`, the
/// RNG provider everything else's reproducibility rests on.
pub fn repo_config() -> Config {
    let trust = [
        "toploc/",
        "coordinator/validation.rs",
        "rl/rollout_file.rs",
        "verifier/",
        "tasks/",
        "runtime/scheduler.rs",
        "serving/",
        "util/rng.rs",
    ];
    // Worker-side code: everything a node operator runs to generate and
    // upload rollouts. `coordinator/churn.rs` is deliberately absent — it
    // is the coordinator-side fault harness and legitimately constructs
    // commitments to test validator recovery.
    let workers = ["protocol/worker.rs", "coordinator/gen.rs", "runtime/scheduler.rs"];
    // Outermost → innermost. A lock may only be taken while holding locks
    // that appear strictly earlier in this list.
    let order = [
        "coordinator/swarm::versions",
        "coordinator/validation::inner",
        "coordinator/validation::slots",
        "rl/buffer::inner",
        "protocol/orchestrator::inner",
        "protocol/ledger::inner",
        "protocol/discovery::inner",
        "protocol/gossip::view",
        "protocol/gossip::seeds",
        "protocol/gossip::rng",
        "protocol/worker::blobs",
        "protocol/worker::gossip_seed",
        "shardcast/server::parents",
        "shardcast/client::relays",
        "shardcast/client::rng",
        "http/server::buckets",
        "http/faults::cuts",
        "util/metrics::rows",
        "util/metrics::inner",
        "util/pool::rx",
        "util/pool::counts",
        "util/pool::results",
        "coordinator/swarm::trained_by_lag",
    ];
    Config {
        trust_prefixes: trust.iter().map(|s| s.to_string()).collect(),
        worker_prefixes: workers.iter().map(|s| s.to_string()).collect(),
        lock_order: order.iter().map(|s| s.to_string()).collect(),
    }
}

pub struct FileReport {
    pub file: String,
    pub violations: Vec<Violation>,
    pub annotations: Vec<Annotation>,
    pub lock_sites: Vec<LockSite>,
    pub lock_edges: Vec<LockEdge>,
}

impl FileReport {
    pub fn unsuppressed(&self) -> impl Iterator<Item = &Violation> {
        self.violations.iter().filter(|v| !v.suppressed)
    }
}

// ---------------------------------------------------------------------------
// File context: significant tokens + structural facts.

struct FnSpan {
    /// Line of the `fn` keyword (annotation anchor for `allow-fn`).
    line: u32,
    /// Significant-token index range of the body, inclusive braces.
    body: (usize, usize),
    first_line: u32,
    last_line: u32,
    /// Names of `&[u8]` parameters (untrusted byte buffers).
    byte_params: Vec<String>,
}

struct Cx {
    sig: Vec<Token>,
    /// Brace depth *before* each significant token.
    depth: Vec<u32>,
    /// Inside a `#[cfg(test)]` / `#[test]` item.
    exempt: Vec<bool>,
    fns: Vec<FnSpan>,
}

impl Cx {
    fn t(&self, i: usize) -> &str {
        self.sig.get(i).map(|t| t.text.as_str()).unwrap_or("")
    }

    fn kind(&self, i: usize) -> Option<TokKind> {
        self.sig.get(i).map(|t| t.kind)
    }

    fn line(&self, i: usize) -> u32 {
        self.sig.get(i).map(|t| t.line).unwrap_or(0)
    }

    fn is_ident(&self, i: usize) -> bool {
        self.kind(i) == Some(TokKind::Ident)
    }
}

/// Matching close brace/bracket/paren for the opener at `open`, scanning
/// significant tokens. Returns the last index on unbalanced input.
fn matching(sig: &[Token], open: usize, open_ch: &str, close_ch: &str) -> usize {
    let mut depth = 0i64;
    for (i, t) in sig.iter().enumerate().skip(open) {
        if t.text == open_ch {
            depth += 1;
        } else if t.text == close_ch {
            depth -= 1;
            if depth <= 0 {
                return i;
            }
        }
    }
    sig.len().saturating_sub(1)
}

fn build_cx(src: &str) -> (Cx, Vec<Token>) {
    let all = lex(src);
    let sig: Vec<Token> = all.iter().filter(|t| t.is_significant()).cloned().collect();
    let mut depth = Vec::with_capacity(sig.len());
    let mut d = 0u32;
    for t in &sig {
        depth.push(d);
        if t.text == "{" {
            d += 1;
        } else if t.text == "}" {
            d = d.saturating_sub(1);
        }
    }
    let mut cx = Cx { sig, depth, exempt: Vec::new(), fns: Vec::new() };
    cx.exempt = mark_test_exempt(&cx);
    cx.fns = find_fns(&cx);
    (cx, all)
}

/// Mark the token range of every item carrying `#[cfg(test)]` or
/// `#[test]`. Convention in this repo: test modules are `#[cfg(test)] mod
/// tests { ... }` at the end of each file.
fn mark_test_exempt(cx: &Cx) -> Vec<bool> {
    let n = cx.sig.len();
    let mut exempt = vec![false; n];
    let mut i = 0usize;
    while i < n {
        let is_attr = cx.t(i) == "#" && cx.t(i + 1) == "[";
        let is_test_attr = is_attr
            && (cx.t(i + 2) == "test"
                || (cx.t(i + 2) == "cfg" && cx.t(i + 3) == "(" && cx.t(i + 4) == "test"));
        if !is_test_attr {
            i += 1;
            continue;
        }
        let start = i;
        let mut j = matching(&cx.sig, i + 1, "[", "]") + 1;
        // Skip any further attributes on the same item.
        while cx.t(j) == "#" && cx.t(j + 1) == "[" {
            j = matching(&cx.sig, j + 1, "[", "]") + 1;
        }
        // The item runs to its body's closing brace, or to `;`.
        let mut end = j;
        while end < n && cx.t(end) != "{" && cx.t(end) != ";" {
            end += 1;
        }
        if cx.t(end) == "{" {
            end = matching(&cx.sig, end, "{", "}");
        }
        for flag in exempt.iter_mut().take((end + 1).min(n)).skip(start) {
            *flag = true;
        }
        i = end + 1;
    }
    exempt
}

/// Record every `fn` item: its line, body token range, and which of its
/// parameters are `&[u8]` slices (untrusted byte buffers for R3's
/// indexing check).
fn find_fns(cx: &Cx) -> Vec<FnSpan> {
    let n = cx.sig.len();
    let mut out = Vec::new();
    let mut i = 0usize;
    while i < n {
        if !(cx.t(i) == "fn" && cx.is_ident(i + 1)) {
            i += 1;
            continue;
        }
        let fn_line = cx.line(i);
        // Find the parameter list.
        let mut p = i + 2;
        while p < n && cx.t(p) != "(" && cx.t(p) != "{" && cx.t(p) != ";" {
            p += 1;
        }
        if cx.t(p) != "(" {
            i += 1;
            continue;
        }
        let close = matching(&cx.sig, p, "(", ")");
        let mut byte_params = Vec::new();
        let mut pd = 0i32;
        for j in p..=close {
            match cx.t(j) {
                "(" => pd += 1,
                ")" => pd -= 1,
                ":" if pd == 1 && cx.t(j + 1) != ":" && cx.t(j.wrapping_sub(1)) != ":" => {
                    // `name: <type>` at the top level of the param list.
                    let mut ty = j + 1;
                    while matches!(cx.t(ty), "&" | "mut") || cx.kind(ty) == Some(TokKind::Lifetime)
                    {
                        ty += 1;
                    }
                    if cx.t(ty) == "[" && cx.t(ty + 1) == "u8" && cx.t(ty + 2) == "]"
                        && cx.is_ident(j.wrapping_sub(1))
                    {
                        byte_params.push(cx.t(j - 1).to_string());
                    }
                }
                _ => {}
            }
        }
        // Body (or `;` for trait method declarations).
        let mut b = close + 1;
        while b < n && cx.t(b) != "{" && cx.t(b) != ";" {
            b += 1;
        }
        if cx.t(b) == "{" {
            let end = matching(&cx.sig, b, "{", "}");
            out.push(FnSpan {
                line: fn_line,
                body: (b, end),
                first_line: fn_line,
                last_line: cx.line(end),
                byte_params,
            });
            i += 2; // nested fns are found too; spans may overlap
        } else {
            i = b + 1;
        }
    }
    out
}

// ---------------------------------------------------------------------------
// Annotations.

fn parse_annotations(all: &[Token], file: &str) -> (Vec<Annotation>, Vec<Violation>) {
    let mut anns = Vec::new();
    let mut bad = Vec::new();
    let mut last_sig_line = 0u32;
    // (comment index in `all`, trailing?) for each candidate.
    let mut candidates: Vec<(usize, bool)> = Vec::new();
    for (i, t) in all.iter().enumerate() {
        if t.is_significant() {
            last_sig_line = t.line;
        } else if t.kind == TokKind::LineComment
            && t.text.contains("swarmlint:")
            // Doc comments (`///`, `//!`) describe the syntax — e.g. the
            // rule catalogue in analysis/mod.rs — and are never waivers.
            && !t.text.starts_with("///")
            && !t.text.starts_with("//!")
        {
            candidates.push((i, t.line == last_sig_line));
        }
    }
    for (i, trailing) in candidates {
        let t = &all[i];
        let target_line = if trailing {
            t.line
        } else {
            all.iter()
                .skip(i)
                .find(|x| x.is_significant())
                .map(|x| x.line)
                .unwrap_or(t.line)
        };
        match parse_allow(&t.text) {
            Ok((fn_scoped, rules, justification)) => anns.push(Annotation {
                line: t.line,
                target_line,
                fn_scoped,
                rules,
                justification,
                used: false,
            }),
            Err(msg) => bad.push(Violation {
                file: file.to_string(),
                line: t.line,
                rule: Rule::BadAnnotation,
                message: msg,
                suppressed: false,
                justification: None,
            }),
        }
    }
    (anns, bad)
}

/// Parse `swarmlint: allow(<r>[, <r>]*) — <justification>` out of a line
/// comment. `allow-fn` scopes to the function below instead of one line.
fn parse_allow(comment: &str) -> Result<(bool, Vec<Rule>, String), String> {
    let after = match comment.split_once("swarmlint:") {
        Some((_, rest)) => rest.trim_start(),
        None => return Err("no swarmlint: marker".into()),
    };
    let (fn_scoped, rest) = if let Some(r) = after.strip_prefix("allow-fn(") {
        (true, r)
    } else if let Some(r) = after.strip_prefix("allow(") {
        (false, r)
    } else {
        return Err(format!("expected allow(...) or allow-fn(...), got `{after}`"));
    };
    let (inside, tail) = match rest.split_once(')') {
        Some(x) => x,
        None => return Err("unclosed allow(".into()),
    };
    let mut rules = Vec::new();
    for name in inside.split(',') {
        let name = name.trim();
        match Rule::parse(name) {
            Some(r) => rules.push(r),
            None => return Err(format!("unknown rule `{name}`")),
        }
    }
    if rules.is_empty() {
        return Err("empty rule list".into());
    }
    let justification: String = tail
        .trim_start_matches(|c: char| c.is_whitespace() || matches!(c, '-' | '—' | '–' | ':'))
        .trim()
        .to_string();
    if justification.is_empty() {
        return Err("missing justification after allow(...)".into());
    }
    Ok((fn_scoped, rules, justification))
}

// ---------------------------------------------------------------------------
// R1 unordered-iter.

const ITER_METHODS: [&str; 9] = [
    "iter",
    "iter_mut",
    "keys",
    "values",
    "values_mut",
    "drain",
    "into_iter",
    "into_keys",
    "into_values",
];

/// R1: iterating a `HashMap`/`HashSet` in a trust module. Heuristic
/// binding discovery — `name: HashMap<...>` annotations (fields, params,
/// lets) and `name = HashMap::new()` initializers; containers nested
/// inside wrappers (`RefCell<HashMap<..>>`) or behind generic positions
/// are not tracked. Membership ops (`contains`, `get`, `insert`, `len`)
/// are fine — only order-revealing iteration is flagged.
fn scan_unordered_iter(cx: &Cx, file: &str, out: &mut Vec<Violation>) {
    let n = cx.sig.len();
    // Pass 1: names bound to hash containers.
    let mut bound: Vec<String> = Vec::new();
    for i in 0..n {
        if cx.exempt[i] || !(cx.t(i) == "HashMap" || cx.t(i) == "HashSet") {
            continue;
        }
        let mut j = i;
        // Walk back over a leading path (`std::collections::HashMap`).
        while j >= 2 && cx.t(j - 1) == ":" && cx.t(j - 2) == ":" {
            j -= 2;
            if j >= 1 && cx.is_ident(j - 1) {
                j -= 1;
            } else {
                break;
            }
        }
        if j == 0 {
            continue;
        }
        let prev = cx.t(j - 1);
        let name = if prev == ":" && j >= 2 && cx.is_ident(j - 2) {
            Some(cx.t(j - 2).to_string())
        } else if prev == "=" {
            // `let [mut] name = HashMap::new()` or `path.name = ...`.
            let mut k = j - 1;
            let mut name = None;
            let mut steps = 0;
            while k > 0 && steps < 12 {
                k -= 1;
                steps += 1;
                if cx.t(k) == ";" || cx.t(k) == "{" || cx.t(k) == "}" {
                    break;
                }
                if cx.t(k) == "let" {
                    let mut m = k + 1;
                    while matches!(cx.t(m), "mut" | "(") {
                        m += 1;
                    }
                    if cx.is_ident(m) {
                        name = Some(cx.t(m).to_string());
                    }
                    break;
                }
            }
            name.or_else(|| {
                if j >= 2 && cx.is_ident(j - 2) {
                    Some(cx.t(j - 2).to_string())
                } else {
                    None
                }
            })
        } else {
            None
        };
        if let Some(nm) = name {
            if !bound.contains(&nm) {
                bound.push(nm);
            }
        }
    }
    if bound.is_empty() {
        return;
    }
    // Pass 2: order-revealing uses of those names.
    for i in 0..n {
        if cx.exempt[i] || !cx.is_ident(i) {
            continue;
        }
        let name = cx.t(i);
        if name == "for" {
            // `for pat in <expr> {` — flag bound names inside <expr>.
            let mut j = i + 1;
            let mut guard = 0;
            while j < n && cx.t(j) != "in" && cx.t(j) != "{" && guard < 24 {
                j += 1;
                guard += 1;
            }
            if cx.t(j) != "in" {
                continue;
            }
            let mut k = j + 1;
            let mut guard = 0;
            while k < n && cx.t(k) != "{" && cx.t(k) != ";" && guard < 24 {
                if cx.is_ident(k) && bound.iter().any(|b| b == cx.t(k)) {
                    out.push(Violation {
                        file: file.to_string(),
                        line: cx.line(k),
                        rule: Rule::UnorderedIter,
                        message: format!(
                            "for-loop over unordered container `{}`",
                            cx.t(k)
                        ),
                        suppressed: false,
                        justification: None,
                    });
                    break;
                }
                k += 1;
                guard += 1;
            }
        } else if bound.iter().any(|b| b == name)
            && cx.t(i + 1) == "."
            && ITER_METHODS.contains(&cx.t(i + 2))
            && cx.t(i + 3) == "("
        {
            out.push(Violation {
                file: file.to_string(),
                line: cx.line(i),
                rule: Rule::UnorderedIter,
                message: format!(
                    "`{}.{}()` iterates an unordered container",
                    name,
                    cx.t(i + 2)
                ),
                suppressed: false,
                justification: None,
            });
        }
    }
}

// ---------------------------------------------------------------------------
// R2 wall-clock.

const WALL_CLOCK_IDENTS: [&str; 6] =
    ["SystemTime", "Instant", "thread_rng", "from_entropy", "getrandom", "now_ms"];

/// R2: wall-clock or entropy sources in trust modules. Commitments, wire
/// bytes and RNG seeds must be functions of the submission alone; any
/// time-derived value is irreproducible by the validator. RNG must come
/// from `util::rng::Rng` seeded constructors.
fn scan_wall_clock(cx: &Cx, file: &str, out: &mut Vec<Violation>) {
    for i in 0..cx.sig.len() {
        if cx.exempt[i] || !cx.is_ident(i) {
            continue;
        }
        if WALL_CLOCK_IDENTS.contains(&cx.t(i)) {
            out.push(Violation {
                file: file.to_string(),
                line: cx.line(i),
                rule: Rule::WallClock,
                message: format!("`{}` in a trust-critical module", cx.t(i)),
                suppressed: false,
                justification: None,
            });
        }
    }
}

// ---------------------------------------------------------------------------
// R3 panic-path.

/// Methods whose `.unwrap()` is the mutex-poison idiom, not input
/// handling: poisoning means another validator thread already panicked,
/// which the panic firewall (`util::pool`) turns into an engine-failure
/// verdict — propagating it is correct and cannot be attacker-triggered.
const POISON_METHODS: [&str; 6] = ["lock", "read", "write", "join", "wait", "wait_timeout"];

fn is_poison_chain(cx: &Cx, dot: usize) -> bool {
    // `dot` is the `.` before unwrap/expect; exempt `<recv>.m(...).unwrap()`
    // when m is a poison-returning method.
    if dot == 0 || cx.t(dot - 1) != ")" {
        return false;
    }
    let mut depth = 0i32;
    let mut i = dot - 1;
    loop {
        match cx.t(i) {
            ")" => depth += 1,
            "(" => {
                depth -= 1;
                if depth == 0 {
                    return i > 0 && POISON_METHODS.contains(&cx.t(i - 1));
                }
            }
            _ => {}
        }
        if i == 0 {
            return false;
        }
        i -= 1;
    }
}

/// R3: panics reachable in trust-module code. Untrusted bytes must turn
/// into reject verdicts — a panicking validator is an unslashable DoS.
/// Flags `.unwrap()` / `.expect(` (minus the poison idiom), panic-family
/// macros, and — inside functions taking `&[u8]` — direct indexing of
/// those buffers. `assert!`/`debug_assert!` are deliberately not flagged.
fn scan_panic_path(cx: &Cx, file: &str, out: &mut Vec<Violation>) {
    let n = cx.sig.len();
    let mut push = |line: u32, message: String, out: &mut Vec<Violation>| {
        out.push(Violation {
            file: file.to_string(),
            line,
            rule: Rule::PanicPath,
            message,
            suppressed: false,
            justification: None,
        });
    };
    for i in 0..n {
        if cx.exempt[i] || !cx.is_ident(i) {
            continue;
        }
        match cx.t(i) {
            "unwrap" if cx.t(i + 1) == "(" && i > 0 && cx.t(i - 1) == "." => {
                if !is_poison_chain(cx, i - 1) {
                    push(cx.line(i), "`.unwrap()` on a trust path".into(), out);
                }
            }
            "expect" if cx.t(i + 1) == "(" && i > 0 && cx.t(i - 1) == "." => {
                if !is_poison_chain(cx, i - 1) {
                    push(cx.line(i), "`.expect(..)` on a trust path".into(), out);
                }
            }
            "panic" | "unreachable" | "todo" | "unimplemented" if cx.t(i + 1) == "!" => {
                push(cx.line(i), format!("`{}!` on a trust path", cx.t(i)), out);
            }
            _ => {}
        }
    }
    // Unchecked indexing of untrusted byte buffers.
    for f in &cx.fns {
        if f.byte_params.is_empty() {
            continue;
        }
        for i in f.body.0..=f.body.1.min(n.saturating_sub(1)) {
            if cx.exempt[i] || !cx.is_ident(i) {
                continue;
            }
            if f.byte_params.iter().any(|p| p == cx.t(i))
                && cx.t(i + 1) == "["
                && (i == 0 || cx.t(i - 1) != ".")
            {
                push(
                    cx.line(i),
                    format!("indexing untrusted byte buffer `{}`", cx.t(i)),
                    out,
                );
            }
        }
    }
}

// ---------------------------------------------------------------------------
// R4 float-fold.

/// R4: `.sum()` / `.product()` in trust modules. Iterator folds have an
/// order fixed by the iterator, but nothing in the code states it, and a
/// refactor to an unordered source silently changes results; commitment /
/// verdict float accumulation must go through `util::numeric` fold
/// helpers. Integer sums are order-independent — annotate those.
fn scan_float_fold(cx: &Cx, file: &str, out: &mut Vec<Violation>) {
    for i in 0..cx.sig.len() {
        if cx.exempt[i] || !cx.is_ident(i) {
            continue;
        }
        let t = cx.t(i);
        if (t == "sum" || t == "product")
            && i > 0
            && cx.t(i - 1) == "."
            && (cx.t(i + 1) == "(" || (cx.t(i + 1) == ":" && cx.t(i + 2) == ":"))
        {
            out.push(Violation {
                file: file.to_string(),
                line: cx.line(i),
                rule: Rule::FloatFold,
                message: format!("`.{t}()` — use util::numeric fold helpers for floats"),
                suppressed: false,
                justification: None,
            });
        }
    }
}

// ---------------------------------------------------------------------------
// R6 validator-secret.

/// R6: references to the validator's commit-reveal machinery in
/// worker-side modules. The sampled-validation gate's security argument
/// requires that workers cannot predict which uploads are audited; the
/// sim derives the commitment secret from the public run seed, which is
/// sound *only if* no worker code path touches it. Flags the
/// `ValidatorCommitment` type and the secret-derivation XOR constant
/// (`0x5E1EC7`) anywhere in a worker module.
fn scan_validator_secret(cx: &Cx, file: &str, out: &mut Vec<Violation>) {
    for i in 0..cx.sig.len() {
        if cx.exempt[i] {
            continue;
        }
        let t = cx.t(i);
        let hit = if cx.is_ident(i) {
            t == "ValidatorCommitment"
        } else {
            // The derivation constant in any radix/case (`0x5E1EC7`).
            t.to_ascii_uppercase().contains("5E1EC7")
        };
        if hit {
            out.push(Violation {
                file: file.to_string(),
                line: cx.line(i),
                rule: Rule::ValidatorSecret,
                message: format!(
                    "`{t}` in worker-side code: workers must not be able to \
                     derive the audit-selection secret"
                ),
                suppressed: false,
                justification: None,
            });
        }
    }
}

// ---------------------------------------------------------------------------
// R5 lock-order (per-file scan; cross-file aggregation in `lockmap`).

struct Guard {
    class: String,
    name: Option<String>,
    depth: u32,
    temp: bool,
}

/// Track `.lock()` acquisitions and which guards are live when each one
/// happens. A `let g = x.lock().unwrap();` guard lives to the end of its
/// block (or an explicit `drop(g)`); a lock consumed inside a larger
/// expression lives to the end of its statement. Purely lexical: a guard
/// held across a call into another module is invisible here — the lock
/// map report exists so humans can audit those seams.
fn scan_locks(cx: &Cx, module: &str) -> (Vec<LockSite>, Vec<LockEdge>) {
    let n = cx.sig.len();
    let mut sites = Vec::new();
    let mut edges = Vec::new();
    let mut guards: Vec<Guard> = Vec::new();
    let mut depth = 0u32;
    let mut stmt_let: Option<String> = None;
    for i in 0..n {
        match cx.t(i) {
            "{" => {
                depth += 1;
                stmt_let = None;
            }
            "}" => {
                depth = depth.saturating_sub(1);
                guards.retain(|g| g.depth <= depth);
                stmt_let = None;
            }
            ";" => {
                guards.retain(|g| !(g.temp && g.depth == depth));
                stmt_let = None;
            }
            "let" => {
                let mut m = i + 1;
                while matches!(cx.t(m), "mut" | "(") {
                    m += 1;
                }
                if cx.is_ident(m) {
                    stmt_let = Some(cx.t(m).to_string());
                }
            }
            "drop" if cx.t(i + 1) == "(" && cx.is_ident(i + 2) && cx.t(i + 3) == ")" => {
                let victim = cx.t(i + 2).to_string();
                guards.retain(|g| g.name.as_deref() != Some(victim.as_str()));
            }
            "lock"
                if cx.t(i + 1) == "(" && cx.t(i + 2) == ")" && i > 0 && cx.t(i - 1) == "." =>
            {
                if cx.exempt[i] {
                    continue;
                }
                let receiver =
                    if i >= 2 && cx.is_ident(i - 2) { cx.t(i - 2) } else { "<expr>" };
                let class = format!("{module}::{receiver}");
                let line = cx.line(i);
                for g in &guards {
                    edges.push(LockEdge {
                        held: g.class.clone(),
                        acquired: class.clone(),
                        line,
                    });
                }
                sites.push(LockSite { class: class.clone(), line });
                // Guard extent: bound to a `let` if the unwrap/expect
                // chain ends the statement, else a temporary.
                let mut j = i + 3;
                while cx.t(j) == "."
                    && matches!(cx.t(j + 1), "unwrap" | "expect")
                    && cx.t(j + 2) == "("
                {
                    j = matching(&cx.sig, j + 2, "(", ")") + 1;
                }
                let bound = stmt_let.is_some() && cx.t(j) == ";";
                guards.push(Guard {
                    class,
                    name: if bound { stmt_let.clone() } else { None },
                    depth,
                    temp: !bound,
                });
            }
            _ => {}
        }
    }
    (sites, edges)
}

// ---------------------------------------------------------------------------
// Entry point.

fn module_key(rel_path: &str) -> String {
    let p = rel_path.strip_suffix(".rs").unwrap_or(rel_path);
    p.strip_suffix("/mod").unwrap_or(p).to_string()
}

fn is_trusted(rel_path: &str, cfg: &Config) -> bool {
    cfg.trust_prefixes.iter().any(|p| rel_path.starts_with(p.as_str()))
}

fn is_worker(rel_path: &str, cfg: &Config) -> bool {
    cfg.worker_prefixes.iter().any(|p| rel_path.starts_with(p.as_str()))
}

/// Analyze one source file (path relative to `src/`, unix separators).
/// Lock-order *edges* are collected here; turning them into violations
/// happens in [`super::lockmap::check_edges`] so the whole-crate map stays
/// in one place.
pub fn analyze_source(rel_path: &str, src: &str, cfg: &Config) -> FileReport {
    let (cx, all) = build_cx(src);
    let mut violations = Vec::new();
    if is_trusted(rel_path, cfg) {
        scan_unordered_iter(&cx, rel_path, &mut violations);
        scan_wall_clock(&cx, rel_path, &mut violations);
        scan_panic_path(&cx, rel_path, &mut violations);
        scan_float_fold(&cx, rel_path, &mut violations);
    }
    if is_worker(rel_path, cfg) {
        scan_validator_secret(&cx, rel_path, &mut violations);
    }
    let (lock_sites, lock_edges) = scan_locks(&cx, &module_key(rel_path));
    let (mut annotations, mut bad) = parse_annotations(&all, rel_path);
    violations.append(&mut bad);
    // Lock-order edge violations are appended by the caller (lockmap) and
    // suppressed through the same annotation table, so expose it.
    apply_suppressions(&mut violations, &mut annotations, &cx);
    violations.sort_by_key(|v| (v.line, v.rule));
    FileReport { file: rel_path.to_string(), violations, annotations, lock_sites, lock_edges }
}

/// Match violations against annotations; used by `analyze_source` and
/// again by `lockmap` after edge violations are appended.
pub(crate) fn apply_suppressions_pub(
    violations: &mut [Violation],
    annotations: &mut [Annotation],
    fn_ranges: &[(u32, u32, u32)],
) {
    for v in violations.iter_mut() {
        if v.rule == Rule::BadAnnotation || v.suppressed {
            continue;
        }
        for a in annotations.iter_mut() {
            if !a.rules.contains(&v.rule) {
                continue;
            }
            let hit = if a.fn_scoped {
                fn_ranges
                    .iter()
                    .any(|&(fl, first, last)| {
                        fl == a.target_line && v.line >= first && v.line <= last
                    })
            } else {
                a.target_line == v.line
            };
            if hit {
                a.used = true;
                v.suppressed = true;
                v.justification = Some(a.justification.clone());
                break;
            }
        }
    }
}

fn apply_suppressions(violations: &mut [Violation], annotations: &mut [Annotation], cx: &Cx) {
    let ranges: Vec<(u32, u32, u32)> =
        cx.fns.iter().map(|f| (f.line, f.first_line, f.last_line)).collect();
    apply_suppressions_pub(violations, annotations, &ranges);
}
