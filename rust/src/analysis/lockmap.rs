//! Whole-crate lock-acquisition map (rule `lock-order`).
//!
//! Deadlock prevention here is hand-rolled — `util::pool`'s condvar join,
//! `coordinator::validation`'s bounded ingest queue, the shardcast relay
//! table — so the invariant "locks nest only along the declared order" is
//! enforced by this map instead of a runtime detector. Each file's scan
//! (see `rules::scan_locks`) yields its `.lock()` sites, classed as
//! `module::receiver`, plus every *nested* acquisition observed while
//! another guard is lexically live. This module turns those edges into
//! violations when they contradict [`super::rules::Config::lock_order`]:
//!
//! - nesting the **same class** twice is always a violation (self-deadlock
//!   on a non-reentrant `std::sync::Mutex`);
//! - an edge between declared classes must step **strictly forward** in
//!   the order list;
//! - an edge touching an **undeclared** class is a violation — declaring
//!   the hierarchy is part of adding a nested lock.
//!
//! The scan is lexical: a guard held across a call into another module's
//! locking code is invisible. The rendered map is the audit surface for
//! those seams — reviewers can see every class a function touches.

use super::rules::{apply_suppressions_pub, FileReport, LockEdge, Rule, Violation};

/// Why an edge is illegal, or `None` when it follows the declared order.
pub fn edge_problem(e: &LockEdge, order: &[String]) -> Option<String> {
    if e.held == e.acquired {
        return Some(format!(
            "nested acquisition of the same lock class `{}` (self-deadlock)",
            e.held
        ));
    }
    let held = order.iter().position(|c| c == &e.held);
    let acquired = order.iter().position(|c| c == &e.acquired);
    match (held, acquired) {
        (Some(h), Some(a)) if h < a => None,
        (Some(_), Some(_)) => Some(format!(
            "`{}` acquired while `{}` is held — against the declared lock order",
            e.acquired, e.held
        )),
        _ => Some(format!(
            "nested acquisition `{}` -> `{}` uses a class missing from the declared lock order",
            e.held, e.acquired
        )),
    }
}

/// Turn illegal edges into (suppressible) `lock-order` violations, in
/// place. `allow` annotations for lock-order are line-targeted only: the
/// line is the inner acquisition's.
pub fn check_edges(reports: &mut [FileReport], order: &[String]) {
    for r in reports.iter_mut() {
        let mut found: Vec<Violation> = Vec::new();
        for e in &r.lock_edges {
            if let Some(message) = edge_problem(e, order) {
                found.push(Violation {
                    file: r.file.clone(),
                    line: e.line,
                    rule: Rule::LockOrder,
                    message,
                    suppressed: false,
                    justification: None,
                });
            }
        }
        if !found.is_empty() {
            apply_suppressions_pub(&mut found, &mut r.annotations, &[]);
            r.violations.extend(found);
            r.violations.sort_by_key(|v| (v.line, v.rule));
        }
    }
}

/// Human-readable whole-crate map: per-file acquisition counts by class,
/// then every nested edge with its status.
pub fn render_map(reports: &[FileReport], order: &[String]) -> String {
    let mut out = String::new();
    let total: usize = reports.iter().map(|r| r.lock_sites.len()).sum();
    let files = reports.iter().filter(|r| !r.lock_sites.is_empty()).count();
    out.push_str(&format!("lock map: {total} acquisition sites in {files} files\n"));
    for r in reports {
        if r.lock_sites.is_empty() {
            continue;
        }
        let mut by_class: Vec<(String, usize)> = Vec::new();
        for s in &r.lock_sites {
            match by_class.iter_mut().find(|(c, _)| c == &s.class) {
                Some((_, n)) => *n += 1,
                None => by_class.push((s.class.clone(), 1)),
            }
        }
        let rendered: Vec<String> =
            by_class.iter().map(|(c, n)| format!("{c} x{n}")).collect();
        out.push_str(&format!("  {}: {}\n", r.file, rendered.join(", ")));
    }
    let mut any = false;
    for r in reports {
        for e in &r.lock_edges {
            if !any {
                out.push_str("nested acquisitions:\n");
                any = true;
            }
            let status = match edge_problem(e, order) {
                None => "ok (declared order)".to_string(),
                Some(m) => format!("VIOLATION: {m}"),
            };
            out.push_str(&format!(
                "  {}:{} {} -> {} [{}]\n",
                r.file, e.line, e.held, e.acquired, status
            ));
        }
    }
    if !any {
        out.push_str("nested acquisitions: none\n");
    }
    out
}
