//! `swarmlint` — a from-scratch static-analysis gate for the swarm's
//! trust-critical paths.
//!
//! Every trust guarantee in this repo rests on invariants no compiler
//! checks: TOPLOC slashing (paper §2.3.3) is only sound if validator
//! verdicts and sampled tokens are **bit-for-bit reproducible**, and the
//! signed-envelope gate is only sound if untrusted bytes can never crash
//! the validator. Earlier PRs enforced these by hand — PR 1 fixed
//! `HashSet` iteration feeding group ids, PR 2 converted decode panics on
//! hostile rollout files into reject verdicts — but each was a one-off
//! audit. This module makes the audit mechanical: a total, lossless Rust
//! [`lexer`], a token-level [`rules`] engine, a whole-crate [`lockmap`],
//! and the `swarmlint` binary that scans `rust/src` and fails CI on any
//! unsuppressed violation.
//!
//! # The rules
//!
//! Rules R1–R4 apply inside the *trust-critical modules* declared in
//! [`rules::repo_config`] (`toploc`, `coordinator/validation`,
//! `rl/rollout_file`, `verifier`, `tasks`, `runtime/scheduler`,
//! `serving` — served responses are slashable, so its deadline math takes
//! the clock reading as an argument rather than sampling ambient time —
//! and `util/rng`); R5 applies crate-wide; R6 applies inside the
//! *worker-side modules* (`protocol/worker`, `coordinator/gen`,
//! `runtime/scheduler`). Test modules are exempt.
//!
//! - **R1 `unordered-iter`** — no iteration over `HashMap`/`HashSet`.
//!   Hash iteration order is unspecified and differs across processes
//!   (and std versions), so anything it feeds — serialized bytes, hashed
//!   fingerprints, verdict ordering — diverges between worker and
//!   validator. The PR-1 bug class: group ids derived from a `HashSet`
//!   walk validated locally and failed remotely. Use `BTreeMap`/`BTreeSet`
//!   or sort before iterating.
//! - **R2 `wall-clock`** — no `SystemTime`/`Instant` (or entropy sources:
//!   `thread_rng`, `from_entropy`, `getrandom`, and this repo's `now_ms`)
//!   in trust modules. A commitment, wire byte, or RNG seed derived from
//!   the clock cannot be recomputed by the validator; all randomness must
//!   flow from [`crate::util::rng::Rng`] seeded constructors (`new` /
//!   `fold`).
//! - **R3 `panic-path`** — no `.unwrap()` / `.expect(..)` /
//!   `panic!`-family macros, nor direct indexing of `&[u8]` parameters,
//!   in trust-module code. Untrusted submission bytes must surface as a
//!   reject `Verdict`, never a panic: a panicking validator is an
//!   unslashable denial of service. This is the PR-2 bug class (decode
//!   panics on truncated rollout files). The mutex-poison idiom
//!   (`.lock().unwrap()` and friends) is exempt — poisoning means a
//!   sibling validator thread already panicked, which the
//!   `util::pool` panic firewall converts to an engine-failure verdict.
//! - **R4 `float-fold`** — no `.sum()` / `.product()` in trust modules.
//!   Float addition is non-associative; an accumulation whose order is
//!   not pinned can flip a tolerance comparison between worker and
//!   validator. Float folds go through [`crate::util::numeric`]
//!   (`fold_f32` / `fold_f64`, documented left-to-right); integer sums
//!   are order-independent and get annotated instead.
//! - **R5 `lock-order`** — every `.lock()` site is classed as
//!   `module::receiver` and nested acquisitions (a lock taken while a
//!   guard is lexically live) must follow the declared hierarchy in
//!   [`rules::repo_config`]. Same-class nesting is always flagged
//!   (non-reentrant mutex self-deadlock); undeclared classes in an edge
//!   are flagged too. See [`lockmap`] for the map rendering.
//! - **R6 `validator-secret`** — worker-side modules must never reference
//!   the validator's commit-reveal audit-selection machinery
//!   (`ValidatorCommitment`, or the secret-derivation constant
//!   `0x5E1EC7`). Sampled validation stays negative-EV only while a
//!   worker cannot predict which of its uploads will be audited; the sim
//!   derives the commitment secret from the shared run seed, which is
//!   sound precisely because this rule guarantees no worker code path
//!   reads it. `coordinator/churn` is coordinator-side and exempt — its
//!   fault harness legitimately constructs commitments.
//!
//! # Suppressions
//!
//! A violation is suppressible only by an inline annotation that names
//! the rule and justifies itself:
//!
//! ```text
//! // swarmlint: allow(panic-path) — slot invariant: every pool job
//! // writes its slot before wait_idle returns.
//! ```
//!
//! The annotation governs the line it trails, or — when written on its
//! own line — the first code line below it. The `allow-fn(<rule>)` form,
//! placed above a `fn` item, covers that whole function (used for byte
//! parsers whose every index is bounds-guarded, where per-line noise
//! would drown the signal). A justification is mandatory: an annotation
//! without one (or naming an unknown rule) is itself a `bad-annotation`
//! violation, which nothing can suppress. The binary prints a summary
//! table of every suppression so review sees the full waiver list.
//!
//! # Running
//!
//! `make lint` or `cargo run --release --bin swarmlint` (CI runs it as a
//! binding job). Exit code 1 on any unsuppressed violation, with the
//! whole-crate lock map and the suppression table on stdout.

pub mod lexer;
pub mod lockmap;
pub mod rules;

use std::path::{Path, PathBuf};

/// All `.rs` files under `root`, sorted by relative path so reports and
/// exit behavior are deterministic.
pub fn collect_rs_files(root: &Path) -> std::io::Result<Vec<PathBuf>> {
    let mut out = Vec::new();
    let mut stack = vec![root.to_path_buf()];
    while let Some(dir) = stack.pop() {
        for entry in std::fs::read_dir(&dir)? {
            let path = entry?.path();
            if path.is_dir() {
                stack.push(path);
            } else if path.extension().map(|e| e == "rs").unwrap_or(false) {
                out.push(path);
            }
        }
    }
    out.sort();
    Ok(out)
}

/// Analyze every file under `root` (the crate's `src/` directory) with
/// the given config, including cross-file lock-order checking.
pub fn analyze_tree(
    root: &Path,
    cfg: &rules::Config,
) -> std::io::Result<Vec<rules::FileReport>> {
    let mut reports = Vec::new();
    for path in collect_rs_files(root)? {
        let rel = path
            .strip_prefix(root)
            .unwrap_or(&path)
            .to_string_lossy()
            .replace('\\', "/");
        let src = std::fs::read_to_string(&path)?;
        reports.push(rules::analyze_source(&rel, &src, cfg));
    }
    lockmap::check_edges(&mut reports, &cfg.lock_order);
    Ok(reports)
}
