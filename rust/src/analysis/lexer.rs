//! A total, lossless Rust token scanner.
//!
//! Built from scratch (no syn/proc-macro2 offline) for the swarmlint rules
//! engine, which only needs token-level structure: identifiers, literals,
//! comments (kept as tokens — annotations live in them), and punctuation.
//!
//! Two properties the rules engine relies on, both tested:
//!
//! - **Total**: every input produces a token stream; malformed or
//!   unterminated constructs degrade into best-effort tokens rather than
//!   errors. The linter must never panic on the tree it audits.
//! - **Lossless**: concatenating `text` over all tokens (whitespace
//!   included) reproduces the input exactly, which is what lets fixture
//!   tests and the roundtrip property pin the scanner's behavior on the
//!   classic traps: raw strings, nested block comments, lifetimes vs char
//!   literals, and macro bodies.

/// Token class. `Ident` covers keywords too — the rules engine matches on
/// text where it cares.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TokKind {
    Ident,
    /// `'a`, `'static`, loop labels — the quote plus identifier chars.
    Lifetime,
    /// `'x'`, `'\n'`, `b'x'`.
    Char,
    /// `"..."`, `b"..."` (escapes kept verbatim).
    Str,
    /// `r"..."`, `r#"..."#`, `br#"..."#` (any guard depth).
    RawStr,
    Num,
    LineComment,
    BlockComment,
    Whitespace,
    /// Single punctuation character (compound operators arrive as runs).
    Punct,
}

#[derive(Clone, Debug)]
pub struct Token {
    pub kind: TokKind,
    pub text: String,
    /// 1-based line of the token's first character.
    pub line: u32,
}

impl Token {
    /// Significant tokens are what the rules walk; comments are read
    /// separately for annotations.
    pub fn is_significant(&self) -> bool {
        !matches!(
            self.kind,
            TokKind::Whitespace | TokKind::LineComment | TokKind::BlockComment
        )
    }
}

fn is_ident_start(c: char) -> bool {
    c == '_' || c.is_alphabetic()
}

fn is_ident_continue(c: char) -> bool {
    c == '_' || c.is_alphanumeric()
}

struct Scanner {
    cs: Vec<char>,
    i: usize,
    line: u32,
}

impl Scanner {
    fn peek(&self, ahead: usize) -> Option<char> {
        self.cs.get(self.i + ahead).copied()
    }

    fn bump(&mut self, out: &mut String) {
        if let Some(&c) = self.cs.get(self.i) {
            if c == '\n' {
                self.line += 1;
            }
            out.push(c);
            self.i += 1;
        }
    }

    fn bump_while(&mut self, out: &mut String, f: impl Fn(char) -> bool) {
        while self.peek(0).map(&f).unwrap_or(false) {
            self.bump(out);
        }
    }

    fn line_comment(&mut self, out: &mut String) {
        self.bump_while(out, |c| c != '\n');
    }

    fn block_comment(&mut self, out: &mut String) {
        // Consume the opening `/*`, then balance nested pairs. EOF inside
        // a comment terminates the token (total, not an error).
        self.bump(out);
        self.bump(out);
        let mut depth = 1usize;
        while depth > 0 && self.i < self.cs.len() {
            if self.peek(0) == Some('/') && self.peek(1) == Some('*') {
                depth += 1;
                self.bump(out);
                self.bump(out);
            } else if self.peek(0) == Some('*') && self.peek(1) == Some('/') {
                depth -= 1;
                self.bump(out);
                self.bump(out);
            } else {
                self.bump(out);
            }
        }
    }

    /// `"..."` with backslash escapes; the opening quote is next.
    fn string(&mut self, out: &mut String) {
        self.bump(out);
        while let Some(c) = self.peek(0) {
            if c == '\\' {
                self.bump(out);
                self.bump(out);
            } else if c == '"' {
                self.bump(out);
                break;
            } else {
                self.bump(out);
            }
        }
    }

    /// `#`-guarded raw string; `self.i` is at the first `#` or the quote.
    fn raw_string(&mut self, out: &mut String) {
        let mut guards = 0usize;
        while self.peek(0) == Some('#') {
            guards += 1;
            self.bump(out);
        }
        self.bump(out); // opening quote
        loop {
            match self.peek(0) {
                None => break,
                Some('"') => {
                    self.bump(out);
                    let mut seen = 0usize;
                    while seen < guards && self.peek(0) == Some('#') {
                        seen += 1;
                        self.bump(out);
                    }
                    if seen == guards {
                        break;
                    }
                }
                Some(_) => self.bump(out),
            }
        }
    }

    /// After an opening `'` that is known to start a char literal.
    fn char_literal(&mut self, out: &mut String) {
        self.bump(out);
        if self.peek(0) == Some('\\') {
            self.bump(out);
            self.bump(out);
        } else {
            self.bump(out);
        }
        // `'\u{1F600}'` and friends: anything up to the closing quote.
        self.bump_while(out, |c| c != '\'');
        self.bump(out);
    }

    fn number(&mut self, out: &mut String) {
        let radix_prefix = self.peek(0) == Some('0')
            && matches!(self.peek(1), Some('x') | Some('o') | Some('b'));
        if radix_prefix {
            self.bump(out);
            self.bump(out);
            self.bump_while(out, |c| c.is_ascii_hexdigit() || c == '_');
        } else {
            self.bump_while(out, |c| c.is_ascii_digit() || c == '_');
            // Fractional part only when followed by a digit (`0..n` and
            // `1.max(2)` must leave the dot alone).
            let frac = self.peek(1).map(|c| c.is_ascii_digit()).unwrap_or(false);
            if self.peek(0) == Some('.') && frac {
                self.bump(out);
                self.bump_while(out, |c| c.is_ascii_digit() || c == '_');
            }
            // Exponent, optionally signed (`1e3`, `1e-3`, `2.5E+10`).
            let exp_digit_at = match self.peek(1) {
                Some('+') | Some('-') => 2,
                _ => 1,
            };
            if matches!(self.peek(0), Some('e') | Some('E'))
                && self.peek(exp_digit_at).map(|c| c.is_ascii_digit()).unwrap_or(false)
            {
                for _ in 0..exp_digit_at {
                    self.bump(out);
                }
                self.bump_while(out, |c| c.is_ascii_digit() || c == '_');
            }
        }
        // Type suffix (`u64`, `f32`, `usize`).
        self.bump_while(out, is_ident_continue);
    }
}

/// Tokenize `src` completely; never fails.
pub fn lex(src: &str) -> Vec<Token> {
    let mut s = Scanner { cs: src.chars().collect(), i: 0, line: 1 };
    let mut toks: Vec<Token> = Vec::new();
    while s.i < s.cs.len() {
        let line = s.line;
        let mut text = String::new();
        let c = match s.peek(0) {
            Some(c) => c,
            None => break,
        };
        let kind = if c.is_whitespace() {
            s.bump_while(&mut text, char::is_whitespace);
            TokKind::Whitespace
        } else if c == '/' && s.peek(1) == Some('/') {
            s.line_comment(&mut text);
            TokKind::LineComment
        } else if c == '/' && s.peek(1) == Some('*') {
            s.block_comment(&mut text);
            TokKind::BlockComment
        } else if c == '"' {
            s.string(&mut text);
            TokKind::Str
        } else if c == '\'' {
            // `'a` / `'static` are lifetimes; `'x'` / `'\n'` are chars.
            // Disambiguate with two characters of lookahead: a quote two
            // ahead (or a backslash next) means char literal.
            let next = s.peek(1);
            let is_char = match next {
                Some('\\') => true,
                Some(n) if is_ident_continue(n) => s.peek(2) == Some('\''),
                Some(_) => true, // `'+'`, `' '`, ...
                None => true,
            };
            if is_char {
                s.char_literal(&mut text);
                TokKind::Char
            } else {
                s.bump(&mut text);
                s.bump_while(&mut text, is_ident_continue);
                TokKind::Lifetime
            }
        } else if c.is_ascii_digit() {
            s.number(&mut text);
            TokKind::Num
        } else if is_ident_start(c) {
            s.bump_while(&mut text, is_ident_continue);
            // An identifier can actually be the prefix of a literal:
            // `r"…"`, `r#"…"#`, `br#"…"#`, `b"…"`, `b'x'`, or a raw
            // identifier `r#name`.
            let raw_prefix = text == "r" || text == "br";
            if raw_prefix && s.peek(0) == Some('"') {
                s.raw_string(&mut text);
                TokKind::RawStr
            } else if raw_prefix && s.peek(0) == Some('#') {
                let mut g = 0usize;
                while s.peek(g) == Some('#') {
                    g += 1;
                }
                if s.peek(g) == Some('"') {
                    s.raw_string(&mut text);
                    TokKind::RawStr
                } else {
                    // Raw identifier `r#try`: keep scanning ident chars.
                    s.bump(&mut text);
                    s.bump_while(&mut text, is_ident_continue);
                    TokKind::Ident
                }
            } else if text == "b" && s.peek(0) == Some('"') {
                s.string(&mut text);
                TokKind::Str
            } else if text == "b" && s.peek(0) == Some('\'') {
                s.char_literal(&mut text);
                TokKind::Char
            } else {
                TokKind::Ident
            }
        } else {
            s.bump(&mut text);
            TokKind::Punct
        };
        toks.push(Token { kind, text, line });
    }
    toks
}

/// Lossless-ness check used by tests: token texts concatenate back to the
/// exact input.
pub fn rejoin(toks: &[Token]) -> String {
    toks.iter().map(|t| t.text.as_str()).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn kinds(src: &str) -> Vec<(TokKind, String)> {
        lex(src)
            .into_iter()
            .filter(Token::is_significant)
            .map(|t| (t.kind, t.text))
            .collect()
    }

    #[test]
    fn roundtrips_simple_source() {
        let src = "fn main() {\n    let x = 1 + 2; // done\n}\n";
        assert_eq!(rejoin(&lex(src)), src);
    }

    #[test]
    fn raw_strings_swallow_quotes_and_guards() {
        let src = r##"let s = r#"contains "quotes" and \ backslash"#;"##;
        let ts = kinds(src);
        let raw = ts.iter().find(|(k, _)| *k == TokKind::RawStr).unwrap();
        assert!(raw.1.starts_with("r#\""));
        assert!(raw.1.ends_with("\"#"));
        assert_eq!(rejoin(&lex(src)), src);
    }

    #[test]
    fn raw_string_contents_are_not_code() {
        // An `.unwrap()` inside a raw string must be literal text, not an
        // Ident token the rules engine could trip on.
        let src = r#"let s = r"x.unwrap()";"#;
        let ts = kinds(src);
        assert!(!ts.iter().any(|(k, t)| *k == TokKind::Ident && t == "unwrap"));
    }

    #[test]
    fn nested_block_comments_balance() {
        let src = "a /* outer /* inner */ still comment */ b";
        let ts = lex(src);
        let comment = ts.iter().find(|t| t.kind == TokKind::BlockComment).unwrap();
        assert_eq!(comment.text, "/* outer /* inner */ still comment */");
        assert_eq!(rejoin(&ts), src);
    }

    #[test]
    fn lifetimes_vs_char_literals() {
        let src = "fn f<'a>(x: &'a str) -> char { let c = 'x'; let n = '\\n'; c }";
        let ts = kinds(src);
        let lifetimes: Vec<&str> = ts
            .iter()
            .filter(|(k, _)| *k == TokKind::Lifetime)
            .map(|(_, t)| t.as_str())
            .collect();
        let chars: Vec<&str> = ts
            .iter()
            .filter(|(k, _)| *k == TokKind::Char)
            .map(|(_, t)| t.as_str())
            .collect();
        assert_eq!(lifetimes, vec!["'a", "'a"]);
        assert_eq!(chars, vec!["'x'", "'\\n'"]);
    }

    #[test]
    fn static_lifetime_and_labels() {
        let src = "let x: &'static str = s; 'outer: loop { break 'outer; }";
        let ts = kinds(src);
        let lifetimes: Vec<_> = ts
            .iter()
            .filter(|(k, _)| *k == TokKind::Lifetime)
            .map(|(_, t)| t.as_str())
            .collect();
        assert_eq!(lifetimes, vec!["'static", "'outer", "'outer"]);
    }

    #[test]
    fn macro_bodies_lex_as_tokens() {
        let src = "crate::warn!(\"pool\", \"job {} panicked\", id); panic!(\"boom\");";
        let ts = kinds(src);
        assert!(ts.iter().any(|(k, t)| *k == TokKind::Ident && t == "warn"));
        assert!(ts.iter().any(|(k, t)| *k == TokKind::Ident && t == "panic"));
        assert!(ts.iter().any(|(k, t)| *k == TokKind::Str && t.contains("panicked")));
        assert_eq!(rejoin(&lex(src)), src);
    }

    #[test]
    fn numbers_do_not_eat_range_or_method_dots() {
        let src = "for i in 0..10 { let y = 1.max(2); let f = 2.5_f32; let e = 1e-3; }";
        let ts = kinds(src);
        let nums: Vec<_> = ts
            .iter()
            .filter(|(k, _)| *k == TokKind::Num)
            .map(|(_, t)| t.as_str())
            .collect();
        assert_eq!(nums, vec!["0", "10", "1", "2", "2.5_f32", "1e-3"]);
        assert_eq!(rejoin(&lex(src)), src);
    }

    #[test]
    fn byte_literals_and_hex() {
        let src = "let m = b\"I2SE\"; let c = b'+'; let h = 0xFF_u32;";
        let ts = kinds(src);
        assert!(ts.iter().any(|(k, t)| *k == TokKind::Str && t == "b\"I2SE\""));
        assert!(ts.iter().any(|(k, t)| *k == TokKind::Char && t == "b'+'"));
        assert!(ts.iter().any(|(k, t)| *k == TokKind::Num && t == "0xFF_u32"));
    }

    #[test]
    fn unterminated_constructs_do_not_panic() {
        for src in ["\"abc", "/* never closed", "r#\"open", "'", "b\"", "1e-"] {
            let ts = lex(src);
            assert_eq!(rejoin(&ts), src, "lossless on {src:?}");
        }
    }

    #[test]
    fn line_tracking_counts_comment_newlines() {
        let src = "a\n/* 1\n2\n3 */\nb";
        let ts = lex(src);
        let b = ts.iter().find(|t| t.text == "b").unwrap();
        assert_eq!(b.line, 5);
    }
}
