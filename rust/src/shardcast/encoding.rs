//! Wire encodings that cut SHARDCAST origin egress (§2.2; INTELLECT-1
//! shipped int8-reduced weights for the same reason).
//!
//! Two independent reductions compose here:
//!
//! - **Per-shard deltas** ([`encode_delta`] / [`decode_delta`]): the
//!   publisher XORs each shard against the same-index shard of a *base*
//!   checkpoint version and run-length-encodes the zero runs. RL policy
//!   weights change a little every step, so consecutive checkpoints share
//!   most of their bytes and the delta wire is a fraction of the shard.
//!   The codec is exactly invertible: `decode_delta(base, encode_delta
//!   (base, cur)) == cur`, byte for byte, so the manifest's per-shard and
//!   assembled digests (computed over the *decoded* shards) still gate
//!   integrity — a corrupt or truncated wire fails the checksum, never
//!   panics.
//! - **Block-quantized payloads** ([`quantize_q8`] / [`dequantize_q8`]):
//!   an f32 weight blob becomes int8 codes plus one f32 scale per
//!   [`Q8_BLOCK`] values (~4x smaller). Quantization happens *before*
//!   manifest build, so the published checkpoint IS the quantized blob:
//!   full fetches and delta fetches reconstruct the identical bytes and
//!   the §2.2.3 checksum contract is untouched. Consumers that need f32
//!   weights dequantize after verification.
//!
//! Both encoders are pure functions of their inputs — a relay that had to
//! fall back to a full-shard pull can recompute the same delta wire the
//! origin would have produced and keep serving deltas to its own subtree.

/// Values per quantization block (one f32 scale amortized over each).
pub const Q8_BLOCK: usize = 64;

/// Delta wire markers (first byte of the wire).
const WIRE_RAW: u8 = 0x00;
const WIRE_XRLE: u8 = 0x01;

/// Encode `cur` against `base` as `XOR + zero-RLE`. When the encoded form
/// would not actually be smaller (the shards share few bytes, or `base`
/// is shorter than `cur`), falls back to an escaped raw copy — the wire
/// is never more than `1 + cur.len()` bytes.
///
/// Wire grammar after the marker byte (`WIRE_XRLE`): a sequence of
/// `(zero_run: u32 LE, lit_len: u32 LE, lit_bytes)` groups over the XOR
/// stream, covering exactly `cur.len()` bytes.
pub fn encode_delta(base: &[u8], cur: &[u8]) -> Vec<u8> {
    let xor: Vec<u8> = cur
        .iter()
        .enumerate()
        .map(|(i, &b)| b ^ base.get(i).copied().unwrap_or(0))
        .collect();
    let mut wire = Vec::with_capacity(cur.len() / 4 + 16);
    wire.push(WIRE_XRLE);
    let mut i = 0usize;
    while i < xor.len() {
        let zero_start = i;
        while i < xor.len() && xor[i] == 0 {
            i += 1;
        }
        let lit_start = i;
        while i < xor.len() && xor[i] != 0 {
            i += 1;
        }
        wire.extend_from_slice(&((lit_start - zero_start) as u32).to_le_bytes());
        wire.extend_from_slice(&((i - lit_start) as u32).to_le_bytes());
        wire.extend_from_slice(&xor[lit_start..i]);
    }
    if wire.len() >= cur.len() + 1 {
        let mut raw = Vec::with_capacity(cur.len() + 1);
        raw.push(WIRE_RAW);
        raw.extend_from_slice(cur);
        return raw;
    }
    wire
}

/// Invert [`encode_delta`]: reconstruct the full shard bytes from `base`
/// and the delta wire. Untrusted input: every malformed shape (unknown
/// marker, truncated group header, literal overrun) is an `Err`, not a
/// panic — the caller treats it like a checksum failure and falls back to
/// a full-shard fetch.
pub fn decode_delta(base: &[u8], wire: &[u8]) -> anyhow::Result<Vec<u8>> {
    let (&marker, body) = wire.split_first().ok_or_else(|| anyhow::anyhow!("empty delta wire"))?;
    match marker {
        WIRE_RAW => Ok(body.to_vec()),
        WIRE_XRLE => {
            let mut out = Vec::new();
            let mut p = 0usize;
            while p < body.len() {
                anyhow::ensure!(p + 8 <= body.len(), "truncated delta group header");
                let zeros =
                    u32::from_le_bytes(body[p..p + 4].try_into().unwrap()) as usize;
                let lits =
                    u32::from_le_bytes(body[p + 4..p + 8].try_into().unwrap()) as usize;
                p += 8;
                anyhow::ensure!(p + lits <= body.len(), "truncated delta literals");
                let start = out.len();
                for k in 0..zeros {
                    out.push(base.get(start + k).copied().unwrap_or(0));
                }
                let start = out.len();
                for (k, &x) in body[p..p + lits].iter().enumerate() {
                    out.push(x ^ base.get(start + k).copied().unwrap_or(0));
                }
                p += lits;
                anyhow::ensure!(out.len() <= (u32::MAX as usize), "delta output overrun");
            }
            Ok(out)
        }
        m => anyhow::bail!("unknown delta wire marker {m:#x}"),
    }
}

/// Quantize an f32 blob (little-endian, length a multiple of 4) to the
/// `q8` payload encoding: per block of [`Q8_BLOCK`] values, one f32 scale
/// followed by that many int8 codes (`code = round(v / scale)`, scale =
/// absmax/127). Trailing bytes that do not form a whole f32 are carried
/// verbatim after the blocks. Roughly 4x smaller than the input.
pub fn quantize_q8(payload: &[u8]) -> Vec<u8> {
    let n_floats = payload.len() / 4;
    let tail = &payload[n_floats * 4..];
    let mut out = Vec::with_capacity(payload.len() / 4 + 16);
    out.extend_from_slice(&(n_floats as u32).to_le_bytes());
    for block in 0..n_floats.div_ceil(Q8_BLOCK) {
        let lo = block * Q8_BLOCK;
        let hi = (lo + Q8_BLOCK).min(n_floats);
        let vals: Vec<f32> = (lo..hi)
            .map(|i| f32::from_le_bytes(payload[4 * i..4 * i + 4].try_into().unwrap()))
            .collect();
        let absmax = vals.iter().fold(0.0f32, |a, v| a.max(v.abs()));
        let scale = if absmax > 0.0 { absmax / 127.0 } else { 1.0 };
        out.extend_from_slice(&scale.to_le_bytes());
        for v in vals {
            let q = (v / scale).round().clamp(-127.0, 127.0) as i8;
            out.push(q as u8);
        }
    }
    out.extend_from_slice(tail);
    out
}

/// Reconstruct approximate f32 bytes from a `q8` blob. Lossy relative to
/// the pre-quantization weights (bounded by scale/2 per value) but a pure
/// function of the blob — every worker that verified the same checkpoint
/// dequantizes to identical f32 bytes. Malformed blobs error.
pub fn dequantize_q8(blob: &[u8]) -> anyhow::Result<Vec<u8>> {
    anyhow::ensure!(blob.len() >= 4, "q8 blob too short");
    let n_floats = u32::from_le_bytes(blob[..4].try_into().unwrap()) as usize;
    let mut out = Vec::with_capacity(n_floats * 4);
    let mut p = 4usize;
    let mut produced = 0usize;
    while produced < n_floats {
        anyhow::ensure!(p + 4 <= blob.len(), "q8 blob truncated at scale");
        let scale = f32::from_le_bytes(blob[p..p + 4].try_into().unwrap());
        p += 4;
        let take = (n_floats - produced).min(Q8_BLOCK);
        anyhow::ensure!(p + take <= blob.len(), "q8 blob truncated at codes");
        for &code in &blob[p..p + take] {
            let v = (code as i8) as f32 * scale;
            out.extend_from_slice(&v.to_le_bytes());
        }
        p += take;
        produced += take;
    }
    out.extend_from_slice(&blob[p..]);
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;
    use crate::util::rng::Rng;

    #[test]
    fn delta_roundtrip_arbitrary_pairs() {
        // Property: decode(base, encode(base, cur)) == cur for arbitrary
        // lengths (including mismatched base/cur lengths) and change
        // densities — the byte-identity the checksum contract rests on.
        prop::check(
            "delta_roundtrip",
            60,
            |rng, size| {
                let blen = rng.usize(size * 40 + 2);
                let clen = rng.usize(size * 40 + 2);
                let base: Vec<u8> = (0..blen).map(|_| rng.range(0, 256) as u8).collect();
                let mut cur: Vec<u8> = base.iter().copied().take(clen).collect();
                while cur.len() < clen {
                    cur.push(rng.range(0, 256) as u8);
                }
                // Sparse mutations so zero-RLE has runs to chew on.
                for _ in 0..rng.usize(8) {
                    if !cur.is_empty() {
                        let i = rng.usize(cur.len());
                        cur[i] ^= 1 + rng.range(0, 255) as u8;
                    }
                }
                (base, cur)
            },
            |(base, cur)| {
                let wire = encode_delta(base, cur);
                let back = decode_delta(base, &wire).map_err(|e| e.to_string())?;
                prop::ensure_eq(back, cur.clone(), "delta roundtrip")
            },
        );
    }

    #[test]
    fn sparse_change_compresses_identity_does_not_grow() {
        let base: Vec<u8> = (0..64 * 1024u32).map(|i| (i % 251) as u8).collect();
        let mut cur = base.clone();
        for i in (0..cur.len()).step_by(4096) {
            cur[i] ^= 0x5A;
        }
        let wire = encode_delta(&base, &cur);
        assert!(wire.len() < cur.len() / 10, "sparse delta too big: {}", wire.len());
        // Unrelated payloads: the raw escape caps growth at one byte.
        let other: Vec<u8> = (0..cur.len() as u32).map(|i| (i * 7 % 256) as u8).collect();
        assert!(encode_delta(&base, &other).len() <= other.len() + 1);
        // Identical payloads compress to a few header bytes.
        assert!(encode_delta(&base, &base).len() < 16);
    }

    #[test]
    fn malformed_wire_errors_not_panics() {
        let base = vec![1u8; 100];
        assert!(decode_delta(&base, &[]).is_err());
        assert!(decode_delta(&base, &[0x77, 1, 2]).is_err()); // unknown marker
        // Truncated group header / literal overrun.
        assert!(decode_delta(&base, &[WIRE_XRLE, 4, 0, 0]).is_err());
        let mut lying = vec![WIRE_XRLE];
        lying.extend_from_slice(&0u32.to_le_bytes());
        lying.extend_from_slice(&1000u32.to_le_bytes()); // promises 1000 literals
        lying.push(9);
        assert!(decode_delta(&base, &lying).is_err());
    }

    #[test]
    fn q8_shrinks_and_roundtrips_deterministically() {
        let mut rng = Rng::new(41);
        let floats: Vec<u8> =
            (0..4096).flat_map(|_| ((rng.f64() as f32) - 0.5).to_le_bytes()).collect();
        let q = quantize_q8(&floats);
        assert!(
            q.len() * 3 < floats.len(),
            "q8 not ~4x smaller: {} vs {}",
            q.len(),
            floats.len()
        );
        // Pure function: same input, same blob; dequantize is total on it.
        assert_eq!(q, quantize_q8(&floats));
        let deq = dequantize_q8(&q).unwrap();
        assert_eq!(deq.len(), floats.len());
        // Error bound: |v - deq(v)| <= scale/2 <= absmax/254 per block.
        for i in 0..4096 {
            let a = f32::from_le_bytes(floats[4 * i..4 * i + 4].try_into().unwrap());
            let b = f32::from_le_bytes(deq[4 * i..4 * i + 4].try_into().unwrap());
            assert!((a - b).abs() <= 0.5 / 127.0 + 1e-6, "value {i}: {a} vs {b}");
        }
        // Malformed blobs error cleanly.
        assert!(dequantize_q8(&[]).is_err());
        assert!(dequantize_q8(&q[..q.len() / 2]).is_err());
    }

    #[test]
    fn q8_carries_non_f32_tail() {
        let mut payload: Vec<u8> = 1.5f32.to_le_bytes().to_vec();
        payload.extend_from_slice(&[0xAA, 0xBB, 0xCC]); // 3 trailing bytes
        let q = quantize_q8(&payload);
        let deq = dequantize_q8(&q).unwrap();
        assert_eq!(&deq[deq.len() - 3..], &[0xAA, 0xBB, 0xCC]);
    }
}
