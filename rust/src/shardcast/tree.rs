//! Self-organizing SHARDCAST tree (§2.2): plan the relay topology from a
//! gossiped membership view instead of hand-wiring parent chains.
//!
//! [`plan_tree`] is a pure, deterministic function of the view: relays
//! are ranked by a bandwidth score (advertised `uplink_mbps` discounted
//! by measured pull latency), the best ones become the origin's direct
//! children, and every later relay attaches under the shallowest placed
//! node with spare fan-out capacity (ties broken by score, then name).
//! Loop-freedom is *by construction*, not by cycle check: a child's depth
//! is `parent depth + 1` and every fallback candidate a relay is given —
//! the list fed to [`super::server::Relay::set_parents`] — sits at
//! strictly smaller depth, with the origin (depth 0) always last. However
//! the [`super::server::REPARENT_AFTER`] rotation walks that list, the
//! pull graph stays acyclic and rooted at the origin.
//!
//! Churn re-formation is re-planning: drop dead or quarantined relays
//! from the view, call [`plan_tree`] again, and push the fresh candidate
//! lists to the survivors mid-epoch. Half-mirrored checkpoints survive
//! re-parenting because the relay puller resumes missing shards from
//! whichever parent it currently has (see `pull_once`).

use std::collections::BTreeMap;

/// One relay as seen through the gossiped membership view.
#[derive(Clone, Debug)]
pub struct RelayPeer {
    pub name: String,
    pub url: String,
    /// Advertised uplink (gossiped hardware metadata, §2.4.1).
    pub uplink_mbps: u64,
    /// Measured pull latency toward this relay (0 = unmeasured).
    pub pull_latency_ms: u64,
}

impl RelayPeer {
    /// Parent-selection score: fat, close relays make good hubs.
    pub fn score(&self) -> f64 {
        self.uplink_mbps as f64 / (1.0 + self.pull_latency_ms as f64)
    }
}

/// A planned topology over one membership view.
#[derive(Clone, Debug, Default)]
pub struct TreePlan {
    /// Relay name -> tree depth (origin children are depth 1).
    pub depth: BTreeMap<String, u32>,
    /// Relay name -> ordered parent candidates (preferred first, origin
    /// always last). Every candidate sits at strictly smaller depth.
    pub parents: BTreeMap<String, Vec<String>>,
    /// Hub name (`"@origin"` for the root) -> names of its children.
    pub children: BTreeMap<String, Vec<String>>,
    /// Relay name -> url (for mapping assertions back to servers).
    pub urls: BTreeMap<String, String>,
    origin_url: String,
}

/// Reserved hub key for the origin in [`TreePlan::children`].
pub const ORIGIN_HUB: &str = "@origin";

/// Extra lower-depth fallbacks handed to each relay besides its chosen
/// parent and the origin.
const EXTRA_FALLBACKS: usize = 2;

/// Plan the relay tree for `peers` under a per-node fan-out bound.
/// Deterministic in its inputs: same view, same tree (the view itself is
/// what churn changes). `fanout` is clamped to >= 1.
pub fn plan_tree(origin_url: &str, peers: &[RelayPeer], fanout: usize) -> TreePlan {
    let fanout = fanout.max(1);
    let mut ranked: Vec<&RelayPeer> = peers.iter().collect();
    ranked.sort_by(|a, b| {
        b.score().total_cmp(&a.score()).then_with(|| a.name.cmp(&b.name))
    });

    let mut plan = TreePlan { origin_url: origin_url.to_string(), ..TreePlan::default() };
    // Placed nodes eligible as parents: (name, url, depth, score, used).
    struct Placed {
        name: String,
        url: String,
        depth: u32,
        score: f64,
        used: usize,
    }
    let mut placed: Vec<Placed> = vec![Placed {
        name: ORIGIN_HUB.to_string(),
        url: origin_url.to_string(),
        depth: 0,
        score: f64::INFINITY,
        used: 0,
    }];

    for peer in ranked {
        // Shallowest spare-capacity node wins; ties prefer the fatter
        // hub, then name order. Total capacity always exceeds placed
        // count (every node adds `fanout` slots), so a slot exists.
        let parent_idx = (0..placed.len())
            .filter(|&i| placed[i].used < fanout)
            .min_by(|&i, &j| {
                placed[i]
                    .depth
                    .cmp(&placed[j].depth)
                    .then(placed[j].score.total_cmp(&placed[i].score))
                    .then(placed[i].name.cmp(&placed[j].name))
            })
            .expect("capacity invariant: some placed node has a spare slot");
        let depth = placed[parent_idx].depth + 1;
        let parent_url = placed[parent_idx].url.clone();
        let parent_name = placed[parent_idx].name.clone();
        placed[parent_idx].used += 1;

        // Candidate list: chosen parent, then the best other strictly-
        // shallower nodes, then the origin as the fallback of last
        // resort. Strictly-smaller depth everywhere keeps the
        // REPARENT_AFTER rotation loop-free no matter which entry a
        // relay lands on.
        let mut candidates = vec![parent_url.clone()];
        let mut extras: Vec<&Placed> = placed
            .iter()
            .filter(|p| p.depth < depth && p.url != parent_url && p.url != origin_url)
            .collect();
        extras.sort_by(|a, b| {
            a.depth.cmp(&b.depth).then(b.score.total_cmp(&a.score)).then(a.name.cmp(&b.name))
        });
        for e in extras.into_iter().take(EXTRA_FALLBACKS) {
            candidates.push(e.url.clone());
        }
        if !candidates.contains(&origin_url.to_string()) {
            candidates.push(origin_url.to_string());
        }

        plan.depth.insert(peer.name.clone(), depth);
        plan.parents.insert(peer.name.clone(), candidates);
        plan.children.entry(parent_name).or_default().push(peer.name.clone());
        plan.urls.insert(peer.name.clone(), peer.url.clone());
        placed.push(Placed {
            name: peer.name.clone(),
            url: peer.url.clone(),
            depth,
            score: peer.score(),
            used: 0,
        });
    }
    plan
}

/// Re-form the tree after churn: plan over the survivors only. Callers
/// push the fresh candidate lists via `Relay::set_parents`.
pub fn reform(origin_url: &str, peers: &[RelayPeer], dead: &[String], fanout: usize) -> TreePlan {
    let survivors: Vec<RelayPeer> =
        peers.iter().filter(|p| !dead.contains(&p.name)).cloned().collect();
    plan_tree(origin_url, &survivors, fanout)
}

impl TreePlan {
    /// Children count of a hub (by relay name, or [`ORIGIN_HUB`]).
    pub fn children_of(&self, hub: &str) -> usize {
        self.children.get(hub).map_or(0, Vec::len)
    }

    pub fn max_depth(&self) -> u32 {
        self.depth.values().copied().max().unwrap_or(0)
    }

    /// Every parent candidate of every relay sits at strictly smaller
    /// depth (origin = depth 0) — the by-construction loop-freedom
    /// invariant, checkable over the whole plan.
    pub fn is_loop_free(&self) -> bool {
        let depth_of_url = |url: &str| -> Option<u32> {
            if url == self.origin_url {
                return Some(0);
            }
            self.urls.iter().find(|(_, u)| u.as_str() == url).and_then(|(n, _)| {
                self.depth.get(n).copied()
            })
        };
        self.parents.iter().all(|(name, candidates)| {
            let d = self.depth.get(name).copied().unwrap_or(u32::MAX);
            !candidates.is_empty()
                && candidates.iter().all(|c| depth_of_url(c).is_some_and(|pd| pd < d))
        })
    }

    /// Every planned relay reaches the origin along its preferred
    /// parents (full connectivity).
    pub fn all_reach_origin(&self) -> bool {
        self.parents.iter().all(|(name, candidates)| {
            let mut hops = 0u32;
            let mut at = candidates.first().cloned().unwrap_or_default();
            while at != self.origin_url {
                hops += 1;
                if hops > self.parents.len() as u32 + 1 {
                    return false;
                }
                let Some((n, _)) = self.urls.iter().find(|(_, u)| **u == at) else {
                    return false;
                };
                let Some(next) = self.parents.get(n).and_then(|c| c.first()).cloned() else {
                    return false;
                };
                at = next;
            }
            self.depth.contains_key(name)
        })
    }

    /// Fan-out bound holds for every hub (origin included).
    pub fn respects_fanout(&self, fanout: usize) -> bool {
        self.children.values().all(|c| c.len() <= fanout.max(1))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    fn view(specs: &[(&str, u64, u64)]) -> Vec<RelayPeer> {
        specs
            .iter()
            .map(|(name, up, lat)| RelayPeer {
                name: name.to_string(),
                url: format!("http://{name}"),
                uplink_mbps: *up,
                pull_latency_ms: *lat,
            })
            .collect()
    }

    #[test]
    fn fat_low_latency_relays_sit_next_to_the_origin() {
        let peers = view(&[
            ("thin", 50, 0),
            ("fat-far", 900, 80),
            ("fat-near", 900, 2),
            ("mid", 300, 5),
        ]);
        let plan = plan_tree("http://origin", &peers, 2);
        assert_eq!(plan.depth["fat-near"], 1);
        assert!(plan.depth["thin"] >= 2, "thin uplink must not displace hubs");
        assert!(plan.is_loop_free() && plan.all_reach_origin());
        assert!(plan.respects_fanout(2));
        // Deterministic: identical views produce identical plans.
        let again = plan_tree("http://origin", &peers, 2);
        assert_eq!(plan.parents, again.parents);
        assert_eq!(plan.depth, again.depth);
    }

    #[test]
    fn starved_uplink_relay_is_never_a_hub() {
        // One starved relay among six healthy ones: it must end up a
        // leaf — zero children — and the deepest rank it can hold.
        let peers = view(&[
            ("a", 800, 1),
            ("b", 700, 1),
            ("c", 600, 1),
            ("d", 500, 1),
            ("e", 400, 1),
            ("starved", 1, 1),
        ]);
        for fanout in 1..=3usize {
            let plan = plan_tree("http://origin", &peers, fanout);
            assert_eq!(
                plan.children_of("starved"),
                0,
                "fanout {fanout}: starved relay was made a hub: {:?}",
                plan.children
            );
            assert_eq!(plan.depth["starved"], plan.max_depth());
            assert!(plan.is_loop_free() && plan.all_reach_origin());
        }
        // Same for a fat-but-unreachable relay (latency swamps uplink).
        let peers = view(&[("a", 500, 1), ("b", 500, 1), ("c", 500, 1), ("laggy", 900, 5000)]);
        let plan = plan_tree("http://origin", &peers, 2);
        assert_eq!(plan.children_of("laggy"), 0);
    }

    #[test]
    fn planned_trees_are_loop_free_and_connected() {
        // Property: over arbitrary seeded membership views (size, uplinks,
        // latencies, fanout), the plan is loop-free, fully connected,
        // fan-out bounded, and every candidate list ends at the origin.
        prop::check(
            "tree_invariants",
            60,
            |rng, size| {
                let n = 1 + rng.usize(size.max(1) + 30);
                let peers: Vec<RelayPeer> = (0..n)
                    .map(|i| RelayPeer {
                        name: format!("r{i:03}"),
                        url: format!("http://r{i:03}"),
                        uplink_mbps: 1 + rng.range(0, 1000),
                        pull_latency_ms: rng.range(0, 300),
                    })
                    .collect();
                (peers, 1 + rng.usize(4))
            },
            |(peers, fanout)| {
                let plan = plan_tree("http://origin", peers, *fanout);
                prop::ensure(plan.depth.len() == peers.len(), "every relay placed")?;
                prop::ensure(plan.is_loop_free(), "loop-free")?;
                prop::ensure(plan.all_reach_origin(), "fully connected")?;
                prop::ensure(plan.respects_fanout(*fanout), "fan-out bound")?;
                for c in plan.parents.values() {
                    prop::ensure(
                        c.last().map(String::as_str) == Some("http://origin"),
                        "origin is the fallback of last resort",
                    )?;
                }
                Ok(())
            },
        );
    }

    #[test]
    fn reform_after_kill_reconnects_every_survivor() {
        // Property: kill an arbitrary subset mid-epoch; the re-planned
        // tree places every survivor and still satisfies the invariants —
        // the convergence half of the re-parenting story.
        prop::check(
            "tree_reform",
            60,
            |rng, size| {
                let n = 2 + rng.usize(size.max(1) + 20);
                let peers: Vec<RelayPeer> = (0..n)
                    .map(|i| RelayPeer {
                        name: format!("r{i:03}"),
                        url: format!("http://r{i:03}"),
                        uplink_mbps: 1 + rng.range(0, 1000),
                        pull_latency_ms: rng.range(0, 100),
                    })
                    .collect();
                let dead: Vec<String> = (0..n)
                    .filter(|_| rng.bool(0.3))
                    .map(|i| format!("r{i:03}"))
                    .collect();
                (peers, dead, 1 + rng.usize(3))
            },
            |(peers, dead, fanout)| {
                let plan = reform("http://origin", peers, dead, *fanout);
                prop::ensure(
                    plan.depth.len() == peers.len() - dead.len(),
                    "every survivor placed",
                )?;
                for d in dead {
                    prop::ensure(!plan.depth.contains_key(d), "dead relay planned back in")?;
                    prop::ensure(
                        !plan.parents.values().any(|c| c.contains(&format!("http://{d}"))),
                        "dead relay left in a candidate list",
                    )?;
                }
                prop::ensure(plan.is_loop_free(), "loop-free after reform")?;
                prop::ensure(plan.all_reach_origin(), "connected after reform")?;
                prop::ensure(plan.respects_fanout(*fanout), "fan-out after reform")?;
                Ok(())
            },
        );
    }
}
