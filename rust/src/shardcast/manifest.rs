//! Checkpoint manifest: step, shard layout, per-shard + assembled SHA-256.
//! Broadcast alongside the shards so workers can verify integrity (§2.2.3).

use crate::util::json::Json;
use sha2::{Digest, Sha256};

pub const DEFAULT_SHARD_BYTES: usize = 64 * 1024;

#[derive(Clone, Debug, PartialEq)]
pub struct Manifest {
    /// RL step this checkpoint belongs to (checkpoint version).
    pub step: u64,
    pub total_bytes: usize,
    pub shard_bytes: usize,
    pub shard_sha256: Vec<[u8; 32]>,
    /// Checksum of the assembled checkpoint, produced by the training
    /// nodes — the reference the workers compare against.
    pub assembled_sha256: [u8; 32],
    /// Checkpoint version this publication also carries per-shard delta
    /// wires against (`/delta` endpoint). `None` = full shards only.
    /// Advisory: the digests above are always over the *decoded* full
    /// shards, and any peer missing the base falls back to `/shard`.
    pub base_step: Option<u64>,
    /// Payload encoding of the published bytes: `"raw"` (plain weight
    /// blob) or `"q8"` (block-quantized, [`super::encoding::quantize_q8`]
    /// — consumers dequantize *after* checksum verification).
    pub encoding: String,
}

impl Manifest {
    pub fn n_shards(&self) -> usize {
        self.shard_sha256.len()
    }

    /// Split a checkpoint payload into shards + manifest.
    pub fn build(step: u64, payload: &[u8], shard_bytes: usize) -> (Manifest, Vec<Vec<u8>>) {
        let shards: Vec<Vec<u8>> = payload.chunks(shard_bytes.max(1)).map(<[u8]>::to_vec).collect();
        let manifest = Manifest {
            step,
            total_bytes: payload.len(),
            shard_bytes,
            shard_sha256: shards.iter().map(|s| Sha256::digest(s).into()).collect(),
            assembled_sha256: Sha256::digest(payload).into(),
            base_step: None,
            encoding: "raw".to_string(),
        };
        (manifest, shards)
    }

    /// Advertise per-shard delta availability against `base_step`.
    pub fn with_base(mut self, base_step: u64) -> Manifest {
        self.base_step = Some(base_step);
        self
    }

    /// Tag the payload encoding (`"raw"` / `"q8"`).
    pub fn with_encoding(mut self, encoding: &str) -> Manifest {
        self.encoding = encoding.to_string();
        self
    }

    /// Reassemble + verify (§2.2.3). Returns the payload or a description
    /// of what failed (worker then skips to the next checkpoint rather than
    /// re-downloading — it would be stale by then).
    pub fn assemble(&self, shards: &[Vec<u8>]) -> anyhow::Result<Vec<u8>> {
        anyhow::ensure!(shards.len() == self.n_shards(), "shard count mismatch");
        let mut out = Vec::with_capacity(self.total_bytes);
        for (i, s) in shards.iter().enumerate() {
            let d: [u8; 32] = Sha256::digest(s).into();
            anyhow::ensure!(d == self.shard_sha256[i], "shard {i} checksum mismatch");
            out.extend_from_slice(s);
        }
        anyhow::ensure!(out.len() == self.total_bytes, "assembled size mismatch");
        let d: [u8; 32] = Sha256::digest(&out).into();
        anyhow::ensure!(d == self.assembled_sha256, "assembled checksum mismatch");
        Ok(out)
    }

    pub fn to_json(&self) -> Json {
        let mut pairs = vec![
            ("step", self.step.into()),
            ("total_bytes", self.total_bytes.into()),
            ("shard_bytes", self.shard_bytes.into()),
            ("shards", Json::Arr(self.shard_sha256.iter().map(|d| Json::Str(hex(d))).collect())),
            ("assembled", Json::Str(hex(&self.assembled_sha256))),
            ("encoding", self.encoding.clone().into()),
        ];
        if let Some(b) = self.base_step {
            pairs.push(("base_step", b.into()));
        }
        Json::obj(pairs)
    }

    pub fn from_json(j: &Json) -> anyhow::Result<Manifest> {
        let shard_sha256 = j
            .get("shards")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow::anyhow!("missing shards"))?
            .iter()
            .map(|s| unhex(s.as_str().unwrap_or("")))
            .collect::<anyhow::Result<Vec<_>>>()?;
        Ok(Manifest {
            step: j.get("step").and_then(Json::as_u64).ok_or_else(|| anyhow::anyhow!("missing step"))?,
            total_bytes: j.get("total_bytes").and_then(Json::as_usize).unwrap_or(0),
            shard_bytes: j.get("shard_bytes").and_then(Json::as_usize).unwrap_or(0),
            shard_sha256,
            assembled_sha256: unhex(
                j.get("assembled").and_then(Json::as_str).unwrap_or(""),
            )?,
            base_step: j.get("base_step").and_then(Json::as_u64),
            encoding: j
                .get("encoding")
                .and_then(Json::as_str)
                .unwrap_or("raw")
                .to_string(),
        })
    }
}

pub fn hex(d: &[u8]) -> String {
    crate::util::json::hex_string(d)
}

pub fn unhex(s: &str) -> anyhow::Result<[u8; 32]> {
    anyhow::ensure!(s.len() == 64, "bad digest length");
    let mut out = [0u8; 32];
    for i in 0..32 {
        out[i] = u8::from_str_radix(&s[2 * i..2 * i + 2], 16)?;
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn build_assemble_roundtrip() {
        let payload: Vec<u8> = (0..300_000u32).map(|i| (i % 251) as u8).collect();
        let (m, shards) = Manifest::build(3, &payload, DEFAULT_SHARD_BYTES);
        assert_eq!(m.n_shards(), payload.len().div_ceil(DEFAULT_SHARD_BYTES));
        assert_eq!(m.assemble(&shards).unwrap(), payload);
    }

    #[test]
    fn corrupted_shard_detected() {
        let payload = vec![9u8; 100_000];
        let (m, mut shards) = Manifest::build(1, &payload, 32 * 1024);
        shards[1][5] ^= 1;
        let err = m.assemble(&shards).unwrap_err().to_string();
        assert!(err.contains("shard 1"), "{err}");
    }

    #[test]
    fn wrong_shard_count_detected() {
        let (m, shards) = Manifest::build(1, &[1, 2, 3], 2);
        assert!(m.assemble(&shards[..1]).is_err());
    }

    #[test]
    fn json_roundtrip() {
        let (m, _) = Manifest::build(7, &vec![3u8; 50_000], 8192);
        let j = m.to_json();
        let m2 = Manifest::from_json(&Json::parse(&j.to_string()).unwrap()).unwrap();
        assert_eq!(m, m2);
    }

    #[test]
    fn json_roundtrip_with_encoding_metadata() {
        let (m, _) = Manifest::build(8, &vec![4u8; 20_000], 4096);
        let m = m.with_base(7).with_encoding("q8");
        let m2 = Manifest::from_json(&Json::parse(&m.to_json().to_string()).unwrap()).unwrap();
        assert_eq!(m, m2);
        assert_eq!(m2.base_step, Some(7));
        assert_eq!(m2.encoding, "q8");
        // Manifests from pre-delta publishers parse with defaults.
        let (legacy, _) = Manifest::build(1, &[1, 2, 3], 2);
        assert_eq!(legacy.base_step, None);
        assert_eq!(legacy.encoding, "raw");
    }

    #[test]
    fn hex_roundtrip() {
        let d = [7u8; 32];
        assert_eq!(unhex(&hex(&d)).unwrap(), d);
        assert!(unhex("zz").is_err());
    }
}
