//! SHARDCAST servers: the origin (training side) and relay tier (§2.2).
//!
//! HTTP API (served by the in-tree HTTP substrate, which provides the
//! nginx-role protections: per-node rate limiting, allowlist firewall,
//! egress shaping):
//!   GET /probe                 - dummy payload for bandwidth estimation
//!   GET /versions              - JSON list of stored checkpoint steps
//!   GET /manifest?step=N       - manifest (or latest when step omitted)
//!   GET /shard?step=N&idx=I    - shard bytes (503 while still streaming in)

use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::Arc;

use super::manifest::Manifest;
use super::store::Store;
use crate::http::{HttpClient, HttpServer, Request, Response, ServerConfig};
use crate::util::json::Json;
use crate::util::metrics::Counter;
use crate::util::retry::RetryPolicy;
use crate::util::rng::Rng;

pub const PROBE_BYTES: usize = 16 * 1024;

/// Consecutive failed pull cycles after which a relay abandons its current
/// parent and rotates to the next one in its parent list.
pub const REPARENT_AFTER: u32 = 2;

fn handle(store: &Store, req: &Request) -> Response {
    match req.path.as_str() {
        "/probe" => Response::ok(vec![0xAB; PROBE_BYTES]),
        "/versions" => Response::json(&Json::Arr(
            store.versions().into_iter().map(Json::from).collect(),
        )),
        "/manifest" => {
            let step = match req.query.get("step") {
                Some(s) => s.parse::<u64>().ok(),
                None => store.latest_step(),
            };
            match step.and_then(|s| store.manifest(s)) {
                Some(m) => Response::json(&m.to_json()),
                None => Response::error(404, "no such checkpoint"),
            }
        }
        "/shard" => {
            let step = req.query_u64("step", u64::MAX);
            let idx = req.query_u64("idx", u64::MAX) as usize;
            match store.manifest(step) {
                None => Response::error(404, "no such checkpoint"),
                Some(m) if idx >= m.n_shards() => Response::error(404, "shard index out of range"),
                Some(_) => match store.shard(step, idx) {
                    Some(data) => Response::ok(data.as_ref().clone()),
                    // Pipelined streaming: manifest exists but this shard
                    // has not arrived at this relay yet.
                    None => Response::error(503, "shard not yet available"),
                },
            }
        }
        _ => Response::error(404, "unknown endpoint"),
    }
}

/// Origin server owned by the training node: publish checkpoints, serve
/// the relay tier.
pub struct Origin {
    pub store: Store,
    pub server: HttpServer,
}

impl Origin {
    pub fn start(cfg: ServerConfig) -> anyhow::Result<Origin> {
        let store = Store::new();
        let s = store.clone();
        let server = HttpServer::start(cfg, move |req| handle(&s, req))?;
        Ok(Origin { store, server })
    }

    /// Shard + publish a checkpoint payload (returns its manifest).
    pub fn publish(&self, step: u64, payload: &[u8], shard_bytes: usize) -> Manifest {
        let (manifest, shards) = Manifest::build(step, payload, shard_bytes);
        self.store.publish_full(manifest.clone(), shards);
        manifest
    }

    pub fn url(&self) -> String {
        self.server.url()
    }
}

/// Relay server: pulls new checkpoints from a parent (origin or another
/// relay — tree topology) in a pipelined fashion and serves workers.
///
/// Self-healing: a relay built with [`Relay::start_with_parents`] holds an
/// ordered list of candidate parents. After [`REPARENT_AFTER`] consecutive
/// failed pull cycles it rotates to the next candidate, so a dead upstream
/// costs a few poll intervals, not the subtree. Partially-mirrored
/// checkpoints are resumed from the new parent (only fully-complete steps
/// are skipped by the puller).
pub struct Relay {
    pub store: Store,
    pub server: HttpServer,
    pub name: String,
    stop: Arc<AtomicBool>,
    puller: Option<std::thread::JoinHandle<()>>,
    parents: Vec<String>,
    parent_idx: Arc<AtomicUsize>,
    reparent_events: Arc<Counter>,
}

impl Relay {
    pub fn start(
        name: &str,
        parent_url: String,
        cfg: ServerConfig,
        poll_interval: std::time::Duration,
    ) -> anyhow::Result<Relay> {
        Relay::start_with_parents(name, vec![parent_url], cfg, poll_interval)
    }

    /// Start a relay with an ordered list of fallback parents (first entry
    /// is the preferred upstream).
    pub fn start_with_parents(
        name: &str,
        parents: Vec<String>,
        cfg: ServerConfig,
        poll_interval: std::time::Duration,
    ) -> anyhow::Result<Relay> {
        anyhow::ensure!(!parents.is_empty(), "relay {name}: empty parent list");
        let store = Store::new();
        let s = store.clone();
        let server = HttpServer::start(cfg, move |req| handle(&s, req))?;
        let stop = Arc::new(AtomicBool::new(false));
        let parent_idx = Arc::new(AtomicUsize::new(0));
        let reparent_events = Arc::new(Counter::default());
        let puller = {
            let store = store.clone();
            let stop = Arc::clone(&stop);
            let parents = parents.clone();
            let parent_idx = Arc::clone(&parent_idx);
            let reparent_events = Arc::clone(&reparent_events);
            let client = HttpClient::new(&format!("relay-{name}"));
            let name = name.to_string();
            // Deterministic backoff jitter, seeded from the relay's name.
            let seed = name.bytes().fold(0u64, |a, b| a.wrapping_mul(31).wrapping_add(b as u64));
            std::thread::Builder::new().name(format!("i2-relay-{name}")).spawn(move || {
                let mut rng = Rng::new(seed);
                let mut failures = 0u32;
                while !stop.load(Ordering::SeqCst) {
                    let parent = parents[parent_idx.load(Ordering::SeqCst) % parents.len()].clone();
                    match pull_once(&client, &parent, &store, &mut rng) {
                        Ok(()) => failures = 0,
                        Err(e) => {
                            failures += 1;
                            crate::debug!("shardcast", "relay {name} pull from {parent}: {e}");
                            if failures >= REPARENT_AFTER && parents.len() > 1 {
                                let next = (parent_idx.load(Ordering::SeqCst) + 1) % parents.len();
                                parent_idx.store(next, Ordering::SeqCst);
                                reparent_events.inc();
                                failures = 0;
                                crate::warn!(
                                    "shardcast",
                                    "relay {name}: re-parenting {parent} -> {} after repeated \
                                     pull failures",
                                    parents[next]
                                );
                            }
                        }
                    }
                    std::thread::sleep(poll_interval);
                }
            })?
        };
        Ok(Relay {
            store,
            server,
            name: name.to_string(),
            stop,
            puller: Some(puller),
            parents,
            parent_idx,
            reparent_events,
        })
    }

    pub fn url(&self) -> String {
        self.server.url()
    }

    /// The parent URL this relay is currently pulling from.
    pub fn current_parent(&self) -> String {
        self.parents[self.parent_idx.load(Ordering::SeqCst) % self.parents.len()].clone()
    }

    /// How many times this relay abandoned a dead upstream.
    pub fn reparent_count(&self) -> u64 {
        self.reparent_events.get()
    }

    pub fn stop(&mut self) {
        self.stop.store(true, Ordering::SeqCst);
        if let Some(t) = self.puller.take() {
            let _ = t.join();
        }
    }
}

impl Drop for Relay {
    fn drop(&mut self) {
        self.stop();
    }
}

/// One pull cycle: mirror any parent checkpoint we don't have yet,
/// publishing the manifest immediately and shards as they arrive so
/// children can start downloading before we finish (pipelining, §2.2).
///
/// Only *fully-mirrored* steps are skipped: a checkpoint left half-pulled
/// by a dying parent is resumed (missing shards only) on the next cycle —
/// possibly from a different parent after re-parenting.
fn pull_once(
    client: &HttpClient,
    parent: &str,
    store: &Store,
    rng: &mut Rng,
) -> anyhow::Result<()> {
    let resp = client.get(&format!("{parent}/versions"))?;
    anyhow::ensure!(resp.status == 200, "versions: {}", resp.status);
    let versions = Json::parse(std::str::from_utf8(&resp.body)?)?;
    let steps: Vec<u64> =
        versions.as_arr().unwrap_or(&[]).iter().filter_map(Json::as_u64).collect();
    for step in steps {
        if store.is_complete(step) {
            continue;
        }
        let manifest = match store.manifest(step) {
            Some(m) => m,
            None => {
                let resp = client.get(&format!("{parent}/manifest?step={step}"))?;
                if resp.status != 200 {
                    continue;
                }
                let m = Manifest::from_json(&Json::parse(std::str::from_utf8(&resp.body)?)?)?;
                store.publish_manifest(m.clone());
                m
            }
        };
        let policy = RetryPolicy::relay_pull();
        for idx in 0..manifest.n_shards() {
            if store.shard(step, idx).is_some() {
                continue;
            }
            // Parent may itself still be streaming this shard (503):
            // retry under the shared backoff policy instead of the old
            // fixed 20 ms poll loop.
            let body = policy.run(&format!("pull shard {step}/{idx}"), rng, |_| {
                let r = client.get(&format!("{parent}/shard?step={step}&idx={idx}"))?;
                anyhow::ensure!(r.status == 200, "status {}", r.status);
                Ok(r.body)
            })?;
            store.put_shard(step, idx, Arc::new(body));
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Duration;

    #[test]
    fn origin_serves_manifest_and_shards() {
        let origin = Origin::start(ServerConfig::default()).unwrap();
        let payload: Vec<u8> = (0..100_000u32).map(|i| (i % 13) as u8).collect();
        let m = origin.publish(1, &payload, 16 * 1024);
        let c = HttpClient::new("w1");
        let r = c.get(&format!("{}/manifest", origin.url())).unwrap();
        assert_eq!(r.status, 200);
        let got = Manifest::from_json(&Json::parse(std::str::from_utf8(&r.body).unwrap()).unwrap()).unwrap();
        assert_eq!(got, m);
        let mut shards = Vec::new();
        for i in 0..m.n_shards() {
            let r = c.get(&format!("{}/shard?step=1&idx={i}", origin.url())).unwrap();
            assert_eq!(r.status, 200);
            shards.push(r.body);
        }
        assert_eq!(m.assemble(&shards).unwrap(), payload);
        // Unknown checkpoint / shard
        assert_eq!(c.get(&format!("{}/manifest?step=9", origin.url())).unwrap().status, 404);
        assert_eq!(c.get(&format!("{}/shard?step=1&idx=999", origin.url())).unwrap().status, 404);
    }

    #[test]
    fn relay_mirrors_origin() {
        let origin = Origin::start(ServerConfig::default()).unwrap();
        let payload = vec![7u8; 64_000];
        origin.publish(2, &payload, 8 * 1024);
        let relay = Relay::start("r1", origin.url(), ServerConfig::default(),
                                 Duration::from_millis(10)).unwrap();
        // Wait for the mirror.
        let deadline = std::time::Instant::now() + Duration::from_secs(5);
        while !relay.store.is_complete(2) {
            assert!(std::time::Instant::now() < deadline, "relay never completed");
            std::thread::sleep(Duration::from_millis(10));
        }
        let c = HttpClient::new("w2");
        let r = c.get(&format!("{}/shard?step=2&idx=0", relay.url())).unwrap();
        assert_eq!(r.status, 200);
        assert_eq!(r.body.len(), 8 * 1024);
    }

    #[test]
    fn two_tier_tree_topology() {
        let origin = Origin::start(ServerConfig::default()).unwrap();
        origin.publish(1, &vec![1u8; 40_000], 8 * 1024);
        let tier1 = Relay::start("t1", origin.url(), ServerConfig::default(),
                                 Duration::from_millis(10)).unwrap();
        let tier2 = Relay::start("t2", tier1.url(), ServerConfig::default(),
                                 Duration::from_millis(10)).unwrap();
        let deadline = std::time::Instant::now() + Duration::from_secs(5);
        while !tier2.store.is_complete(1) {
            assert!(std::time::Instant::now() < deadline, "tier2 never completed");
            std::thread::sleep(Duration::from_millis(10));
        }
    }

    #[test]
    fn relay_reparents_when_upstream_dies() {
        // Tree: origin -> tier1 -> tier2, with tier2 holding the origin as
        // a fallback parent. Kill tier1 between checkpoints: tier2 must
        // rotate to the origin and keep mirroring new steps.
        let origin = Origin::start(ServerConfig::default()).unwrap();
        origin.publish(1, &vec![4u8; 40_000], 8 * 1024);
        let tier1 = Relay::start("t1", origin.url(), ServerConfig::default(),
                                 Duration::from_millis(10)).unwrap();
        let tier2 = Relay::start_with_parents(
            "t2",
            vec![tier1.url(), origin.url()],
            ServerConfig::default(),
            Duration::from_millis(10),
        )
        .unwrap();
        let deadline = std::time::Instant::now() + Duration::from_secs(5);
        while !tier2.store.is_complete(1) {
            assert!(std::time::Instant::now() < deadline, "tier2 never mirrored step 1");
            std::thread::sleep(Duration::from_millis(10));
        }
        assert_eq!(tier2.current_parent(), tier1.url());

        drop(tier1); // upstream dies; its port now refuses connections
        origin.publish(2, &vec![5u8; 40_000], 8 * 1024);
        let deadline = std::time::Instant::now() + Duration::from_secs(10);
        while !tier2.store.is_complete(2) {
            assert!(std::time::Instant::now() < deadline, "tier2 never healed after re-parent");
            std::thread::sleep(Duration::from_millis(10));
        }
        assert_eq!(tier2.current_parent(), origin.url());
        assert!(tier2.reparent_count() >= 1);
    }
}
