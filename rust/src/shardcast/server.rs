//! SHARDCAST servers: the origin (training side) and relay tier (§2.2).
//!
//! HTTP API (served by the in-tree HTTP substrate, which provides the
//! nginx-role protections: per-node rate limiting, allowlist firewall,
//! egress shaping):
//!   GET /probe                 - dummy payload for bandwidth estimation
//!   GET /versions              - JSON list of stored checkpoint steps
//!   GET /manifest?step=N       - manifest (or latest when step omitted)
//!   GET /shard?step=N&idx=I    - shard bytes (503 while still streaming in)

use std::sync::Arc;

use super::manifest::Manifest;
use super::store::Store;
use crate::http::{HttpClient, HttpServer, Request, Response, ServerConfig};
use crate::util::json::Json;

pub const PROBE_BYTES: usize = 16 * 1024;

fn handle(store: &Store, req: &Request) -> Response {
    match req.path.as_str() {
        "/probe" => Response::ok(vec![0xAB; PROBE_BYTES]),
        "/versions" => Response::json(&Json::Arr(
            store.versions().into_iter().map(Json::from).collect(),
        )),
        "/manifest" => {
            let step = match req.query.get("step") {
                Some(s) => s.parse::<u64>().ok(),
                None => store.latest_step(),
            };
            match step.and_then(|s| store.manifest(s)) {
                Some(m) => Response::json(&m.to_json()),
                None => Response::error(404, "no such checkpoint"),
            }
        }
        "/shard" => {
            let step = req.query_u64("step", u64::MAX);
            let idx = req.query_u64("idx", u64::MAX) as usize;
            match store.manifest(step) {
                None => Response::error(404, "no such checkpoint"),
                Some(m) if idx >= m.n_shards() => Response::error(404, "shard index out of range"),
                Some(_) => match store.shard(step, idx) {
                    Some(data) => Response::ok(data.as_ref().clone()),
                    // Pipelined streaming: manifest exists but this shard
                    // has not arrived at this relay yet.
                    None => Response::error(503, "shard not yet available"),
                },
            }
        }
        _ => Response::error(404, "unknown endpoint"),
    }
}

/// Origin server owned by the training node: publish checkpoints, serve
/// the relay tier.
pub struct Origin {
    pub store: Store,
    pub server: HttpServer,
}

impl Origin {
    pub fn start(cfg: ServerConfig) -> anyhow::Result<Origin> {
        let store = Store::new();
        let s = store.clone();
        let server = HttpServer::start(cfg, move |req| handle(&s, req))?;
        Ok(Origin { store, server })
    }

    /// Shard + publish a checkpoint payload (returns its manifest).
    pub fn publish(&self, step: u64, payload: &[u8], shard_bytes: usize) -> Manifest {
        let (manifest, shards) = Manifest::build(step, payload, shard_bytes);
        self.store.publish_full(manifest.clone(), shards);
        manifest
    }

    pub fn url(&self) -> String {
        self.server.url()
    }
}

/// Relay server: pulls new checkpoints from a parent (origin or another
/// relay — tree topology) in a pipelined fashion and serves workers.
pub struct Relay {
    pub store: Store,
    pub server: HttpServer,
    pub name: String,
    stop: Arc<std::sync::atomic::AtomicBool>,
    puller: Option<std::thread::JoinHandle<()>>,
}

impl Relay {
    pub fn start(
        name: &str,
        parent_url: String,
        cfg: ServerConfig,
        poll_interval: std::time::Duration,
    ) -> anyhow::Result<Relay> {
        let store = Store::new();
        let s = store.clone();
        let server = HttpServer::start(cfg, move |req| handle(&s, req))?;
        let stop = Arc::new(std::sync::atomic::AtomicBool::new(false));
        let puller = {
            let store = store.clone();
            let stop = Arc::clone(&stop);
            let client = HttpClient::new(&format!("relay-{name}"));
            std::thread::Builder::new().name(format!("i2-relay-{name}")).spawn(move || {
                while !stop.load(std::sync::atomic::Ordering::SeqCst) {
                    if let Err(e) = pull_once(&client, &parent_url, &store) {
                        crate::debug!("shardcast", "relay pull: {e}");
                    }
                    std::thread::sleep(poll_interval);
                }
            })?
        };
        Ok(Relay { store, server, name: name.to_string(), stop, puller: Some(puller) })
    }

    pub fn url(&self) -> String {
        self.server.url()
    }

    pub fn stop(&mut self) {
        self.stop.store(true, std::sync::atomic::Ordering::SeqCst);
        if let Some(t) = self.puller.take() {
            let _ = t.join();
        }
    }
}

impl Drop for Relay {
    fn drop(&mut self) {
        self.stop();
    }
}

/// One pull cycle: mirror any parent checkpoint we don't have yet,
/// publishing the manifest immediately and shards as they arrive so
/// children can start downloading before we finish (pipelining, §2.2).
fn pull_once(client: &HttpClient, parent: &str, store: &Store) -> anyhow::Result<()> {
    let resp = client.get(&format!("{parent}/versions"))?;
    anyhow::ensure!(resp.status == 200, "versions: {}", resp.status);
    let versions = Json::parse(std::str::from_utf8(&resp.body)?)?;
    let steps: Vec<u64> = versions.as_arr().unwrap_or(&[]).iter().filter_map(Json::as_u64).collect();
    for step in steps {
        if store.manifest(step).is_some() {
            continue;
        }
        let resp = client.get(&format!("{parent}/manifest?step={step}"))?;
        if resp.status != 200 {
            continue;
        }
        let manifest = Manifest::from_json(&Json::parse(std::str::from_utf8(&resp.body)?)?)?;
        let n = manifest.n_shards();
        store.publish_manifest(manifest);
        for idx in 0..n {
            // Parent may itself still be streaming: retry 503s briefly.
            let mut attempts = 0;
            loop {
                let r = client.get(&format!("{parent}/shard?step={step}&idx={idx}"))?;
                match r.status {
                    200 => {
                        store.put_shard(step, idx, Arc::new(r.body));
                        break;
                    }
                    503 if attempts < 50 => {
                        attempts += 1;
                        std::thread::sleep(std::time::Duration::from_millis(20));
                    }
                    _ => anyhow::bail!("shard {step}/{idx}: status {}", r.status),
                }
            }
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Duration;

    #[test]
    fn origin_serves_manifest_and_shards() {
        let origin = Origin::start(ServerConfig::default()).unwrap();
        let payload: Vec<u8> = (0..100_000u32).map(|i| (i % 13) as u8).collect();
        let m = origin.publish(1, &payload, 16 * 1024);
        let c = HttpClient::new("w1");
        let r = c.get(&format!("{}/manifest", origin.url())).unwrap();
        assert_eq!(r.status, 200);
        let got = Manifest::from_json(&Json::parse(std::str::from_utf8(&r.body).unwrap()).unwrap()).unwrap();
        assert_eq!(got, m);
        let mut shards = Vec::new();
        for i in 0..m.n_shards() {
            let r = c.get(&format!("{}/shard?step=1&idx={i}", origin.url())).unwrap();
            assert_eq!(r.status, 200);
            shards.push(r.body);
        }
        assert_eq!(m.assemble(&shards).unwrap(), payload);
        // Unknown checkpoint / shard
        assert_eq!(c.get(&format!("{}/manifest?step=9", origin.url())).unwrap().status, 404);
        assert_eq!(c.get(&format!("{}/shard?step=1&idx=999", origin.url())).unwrap().status, 404);
    }

    #[test]
    fn relay_mirrors_origin() {
        let origin = Origin::start(ServerConfig::default()).unwrap();
        let payload = vec![7u8; 64_000];
        origin.publish(2, &payload, 8 * 1024);
        let relay = Relay::start("r1", origin.url(), ServerConfig::default(),
                                 Duration::from_millis(10)).unwrap();
        // Wait for the mirror.
        let deadline = std::time::Instant::now() + Duration::from_secs(5);
        while !relay.store.is_complete(2) {
            assert!(std::time::Instant::now() < deadline, "relay never completed");
            std::thread::sleep(Duration::from_millis(10));
        }
        let c = HttpClient::new("w2");
        let r = c.get(&format!("{}/shard?step=2&idx=0", relay.url())).unwrap();
        assert_eq!(r.status, 200);
        assert_eq!(r.body.len(), 8 * 1024);
    }

    #[test]
    fn two_tier_tree_topology() {
        let origin = Origin::start(ServerConfig::default()).unwrap();
        origin.publish(1, &vec![1u8; 40_000], 8 * 1024);
        let tier1 = Relay::start("t1", origin.url(), ServerConfig::default(),
                                 Duration::from_millis(10)).unwrap();
        let tier2 = Relay::start("t2", tier1.url(), ServerConfig::default(),
                                 Duration::from_millis(10)).unwrap();
        let deadline = std::time::Instant::now() + Duration::from_secs(5);
        while !tier2.store.is_complete(1) {
            assert!(std::time::Instant::now() < deadline, "tier2 never completed");
            std::thread::sleep(Duration::from_millis(10));
        }
    }
}
