//! SHARDCAST servers: the origin (training side) and relay tier (§2.2).
//!
//! HTTP API (served by the in-tree HTTP substrate, which provides the
//! nginx-role protections: per-node rate limiting, allowlist firewall,
//! egress shaping):
//!   GET /probe                 - dummy payload for bandwidth estimation
//!   GET /versions              - JSON list of stored checkpoint steps
//!   GET /manifest?step=N       - manifest (or latest when step omitted)
//!   GET /shard?step=N&idx=I    - shard bytes (503 while still streaming in)
//!   GET /delta?step=N&idx=I    - delta wire vs the manifest's base_step
//!                                (404 when this publication has none)

use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};

use sha2::{Digest, Sha256};

use super::manifest::Manifest;
use super::store::Store;
use crate::http::{HttpClient, HttpServer, Request, Response, ServerConfig};
use crate::util::json::Json;
use crate::util::metrics::Counter;
use crate::util::retry::RetryPolicy;
use crate::util::rng::Rng;

pub const PROBE_BYTES: usize = 16 * 1024;

/// Consecutive failed pull cycles after which a relay abandons its current
/// parent and rotates to the next one in its parent list.
pub const REPARENT_AFTER: u32 = 2;

fn handle(store: &Store, req: &Request) -> Response {
    match req.path.as_str() {
        "/probe" => Response::ok(vec![0xAB; PROBE_BYTES]),
        "/versions" => Response::json(&Json::Arr(
            store.versions().into_iter().map(Json::from).collect(),
        )),
        "/manifest" => {
            let step = match req.query.get("step") {
                Some(s) => s.parse::<u64>().ok(),
                None => store.latest_step(),
            };
            match step.and_then(|s| store.manifest(s)) {
                Some(m) => Response::json(&m.to_json()),
                None => Response::error(404, "no such checkpoint"),
            }
        }
        "/shard" => {
            let step = req.query_u64("step", u64::MAX);
            let idx = req.query_u64("idx", u64::MAX) as usize;
            match store.manifest(step) {
                None => Response::error(404, "no such checkpoint"),
                Some(m) if idx >= m.n_shards() => Response::error(404, "shard index out of range"),
                Some(_) => match store.shard(step, idx) {
                    Some(data) => Response::ok(data.as_ref().clone()),
                    // Pipelined streaming: manifest exists but this shard
                    // has not arrived at this relay yet.
                    None => Response::error(503, "shard not yet available"),
                },
            }
        }
        "/delta" => {
            // Best-effort: a 404 here just sends the puller down the
            // full-shard path, so absence is never an error condition.
            let step = req.query_u64("step", u64::MAX);
            let idx = req.query_u64("idx", u64::MAX) as usize;
            match store.delta(step, idx) {
                Some(wire) => Response::ok(wire.as_ref().clone()),
                None => Response::error(404, "no delta for this shard"),
            }
        }
        _ => Response::error(404, "unknown endpoint"),
    }
}

/// Origin server owned by the training node: publish checkpoints, serve
/// the relay tier.
pub struct Origin {
    pub store: Store,
    pub server: HttpServer,
}

impl Origin {
    pub fn start(cfg: ServerConfig) -> anyhow::Result<Origin> {
        let store = Store::new();
        let s = store.clone();
        let server = HttpServer::start(cfg, move |req| handle(&s, req))?;
        Ok(Origin { store, server })
    }

    /// Shard + publish a checkpoint payload (returns its manifest).
    pub fn publish(&self, step: u64, payload: &[u8], shard_bytes: usize) -> Manifest {
        let (manifest, shards) = Manifest::build(step, payload, shard_bytes);
        self.store.publish_full(manifest.clone(), shards);
        manifest
    }

    pub fn url(&self) -> String {
        self.server.url()
    }
}

/// Relay server: pulls new checkpoints from a parent (origin or another
/// relay — tree topology) in a pipelined fashion and serves workers.
///
/// Self-healing: a relay built with [`Relay::start_with_parents`] holds an
/// ordered list of candidate parents. After [`REPARENT_AFTER`] consecutive
/// failed pull cycles it rotates to the next candidate, so a dead upstream
/// costs a few poll intervals, not the subtree. Partially-mirrored
/// checkpoints are resumed from the new parent (only fully-complete steps
/// are skipped by the puller).
///
/// The candidate list is *dynamic*: [`Relay::set_parents`] swaps in a
/// fresh list mid-epoch, which is how the tree planner
/// ([`super::tree::plan_tree`]) re-forms the topology after churn without
/// restarting relays — the puller snapshots the list once per cycle and
/// resumes half-mirrored checkpoints from whatever upstream it lands on.
pub struct Relay {
    pub store: Store,
    pub server: HttpServer,
    pub name: String,
    stop: Arc<AtomicBool>,
    puller: Option<std::thread::JoinHandle<()>>,
    parents: Arc<Mutex<Vec<String>>>,
    parent_idx: Arc<AtomicUsize>,
    reparent_events: Arc<Counter>,
}

impl Relay {
    pub fn start(
        name: &str,
        parent_url: String,
        cfg: ServerConfig,
        poll_interval: std::time::Duration,
    ) -> anyhow::Result<Relay> {
        Relay::start_with_parents(name, vec![parent_url], cfg, poll_interval)
    }

    /// Start a relay with an ordered list of fallback parents (first entry
    /// is the preferred upstream).
    pub fn start_with_parents(
        name: &str,
        parents: Vec<String>,
        cfg: ServerConfig,
        poll_interval: std::time::Duration,
    ) -> anyhow::Result<Relay> {
        anyhow::ensure!(!parents.is_empty(), "relay {name}: empty parent list");
        let store = Store::new();
        let s = store.clone();
        let server = HttpServer::start(cfg, move |req| handle(&s, req))?;
        let stop = Arc::new(AtomicBool::new(false));
        let parent_idx = Arc::new(AtomicUsize::new(0));
        let reparent_events = Arc::new(Counter::default());
        let parents = Arc::new(Mutex::new(parents));
        let puller = {
            let store = store.clone();
            let stop = Arc::clone(&stop);
            let parents = Arc::clone(&parents);
            let parent_idx = Arc::clone(&parent_idx);
            let reparent_events = Arc::clone(&reparent_events);
            let client = HttpClient::new(&format!("relay-{name}"));
            let name = name.to_string();
            // Deterministic backoff jitter, seeded from the relay's name.
            let seed = name.bytes().fold(0u64, |a, b| a.wrapping_mul(31).wrapping_add(b as u64));
            std::thread::Builder::new().name(format!("i2-relay-{name}")).spawn(move || {
                let mut rng = Rng::new(seed);
                let mut failures = 0u32;
                while !stop.load(Ordering::SeqCst) {
                    // Snapshot the candidate list (it can be swapped by
                    // set_parents mid-epoch) and drop the guard before
                    // any network or store work.
                    let snapshot = parents.lock().unwrap().clone();
                    let parent =
                        snapshot[parent_idx.load(Ordering::SeqCst) % snapshot.len()].clone();
                    match pull_once(&client, &parent, &store, &mut rng) {
                        Ok(()) => failures = 0,
                        Err(e) => {
                            failures += 1;
                            crate::debug!("shardcast", "relay {name} pull from {parent}: {e}");
                            if failures >= REPARENT_AFTER && snapshot.len() > 1 {
                                let next =
                                    (parent_idx.load(Ordering::SeqCst) + 1) % snapshot.len();
                                parent_idx.store(next, Ordering::SeqCst);
                                reparent_events.inc();
                                failures = 0;
                                crate::warn!(
                                    "shardcast",
                                    "relay {name}: re-parenting {parent} -> {} after repeated \
                                     pull failures",
                                    snapshot[next]
                                );
                            }
                        }
                    }
                    std::thread::sleep(poll_interval);
                }
            })?
        };
        Ok(Relay {
            store,
            server,
            name: name.to_string(),
            stop,
            puller: Some(puller),
            parents,
            parent_idx,
            reparent_events,
        })
    }

    pub fn url(&self) -> String {
        self.server.url()
    }

    /// The parent URL this relay is currently pulling from.
    pub fn current_parent(&self) -> String {
        let parents = self.parents.lock().unwrap();
        parents[self.parent_idx.load(Ordering::SeqCst) % parents.len()].clone()
    }

    /// Swap in a fresh candidate-parent list (tree re-formation after
    /// churn). Resets the rotation to the new preferred parent; the
    /// puller picks the change up on its next cycle. Empty lists are
    /// ignored — a relay must always have somewhere to pull from.
    pub fn set_parents(&self, new_parents: Vec<String>) {
        if new_parents.is_empty() {
            return;
        }
        *self.parents.lock().unwrap() = new_parents;
        self.parent_idx.store(0, Ordering::SeqCst);
    }

    /// How many times this relay abandoned a dead upstream.
    pub fn reparent_count(&self) -> u64 {
        self.reparent_events.get()
    }

    pub fn stop(&mut self) {
        self.stop.store(true, Ordering::SeqCst);
        if let Some(t) = self.puller.take() {
            let _ = t.join();
        }
    }
}

impl Drop for Relay {
    fn drop(&mut self) {
        self.stop();
    }
}

/// One pull cycle: mirror any parent checkpoint we don't have yet,
/// publishing the manifest immediately and shards as they arrive so
/// children can start downloading before we finish (pipelining, §2.2).
///
/// Only *fully-mirrored* steps are skipped: a checkpoint left half-pulled
/// by a dying parent is resumed (missing shards only) on the next cycle —
/// possibly from a different parent after re-parenting.
///
/// Delta fallback ladder (per shard): when the manifest advertises a
/// `base_step` this relay holds *complete*, try `/delta` first and verify
/// the decoded shard against the manifest digest; on any failure (404,
/// decode error, checksum mismatch) fall back to the full `/shard` pull.
/// After a full-shard fallback the wire is recomputed locally — the codec
/// is pure — so this relay keeps serving `/delta` to its own subtree.
fn pull_once(
    client: &HttpClient,
    parent: &str,
    store: &Store,
    rng: &mut Rng,
) -> anyhow::Result<()> {
    let resp = client.get(&format!("{parent}/versions"))?;
    anyhow::ensure!(resp.status == 200, "versions: {}", resp.status);
    let versions = Json::parse(std::str::from_utf8(&resp.body)?)?;
    let steps: Vec<u64> =
        versions.as_arr().unwrap_or(&[]).iter().filter_map(Json::as_u64).collect();
    for step in steps {
        if store.is_complete(step) {
            continue;
        }
        let manifest = match store.manifest(step) {
            Some(m) => m,
            None => {
                let resp = client.get(&format!("{parent}/manifest?step={step}"))?;
                if resp.status != 200 {
                    continue;
                }
                let m = Manifest::from_json(&Json::parse(std::str::from_utf8(&resp.body)?)?)?;
                store.publish_manifest(m.clone());
                m
            }
        };
        let base = manifest.base_step.filter(|b| store.is_complete(*b));
        let policy = RetryPolicy::relay_pull();
        for idx in 0..manifest.n_shards() {
            if store.shard(step, idx).is_some() {
                continue;
            }
            if let Some(b) = base {
                if let Some((full, wire)) = try_delta_pull(client, parent, store, &manifest, b, idx)
                {
                    store.put_delta(step, idx, Arc::new(wire));
                    store.put_shard(step, idx, Arc::new(full));
                    continue;
                }
            }
            // Parent may itself still be streaming this shard (503):
            // retry under the shared backoff policy instead of the old
            // fixed 20 ms poll loop.
            let body = policy.run(&format!("pull shard {step}/{idx}"), rng, |_| {
                let r = client.get(&format!("{parent}/shard?step={step}&idx={idx}"))?;
                anyhow::ensure!(r.status == 200, "status {}", r.status);
                Ok(r.body)
            })?;
            if let Some(b) = base {
                let base_bytes =
                    store.shard(b, idx).map(|a| a.as_ref().clone()).unwrap_or_default();
                let wire = super::encoding::encode_delta(&base_bytes, &body);
                store.put_delta(step, idx, Arc::new(wire));
            }
            store.put_shard(step, idx, Arc::new(body));
        }
    }
    Ok(())
}

/// One delta attempt for shard `idx` of `manifest.step` against local base
/// step `b`. Returns the verified full shard plus the wire, or `None` to
/// send the caller down the full-shard path.
fn try_delta_pull(
    client: &HttpClient,
    parent: &str,
    store: &Store,
    manifest: &Manifest,
    base: u64,
    idx: usize,
) -> Option<(Vec<u8>, Vec<u8>)> {
    let r = client.get(&format!("{parent}/delta?step={}&idx={idx}", manifest.step)).ok()?;
    if r.status != 200 {
        return None;
    }
    // Base may have fewer shards than the new step (payload grew): the
    // encoder treats missing base bytes as zero, so an empty slice is the
    // correct stand-in, not an error.
    let base_bytes = store.shard(base, idx).map(|a| a.as_ref().clone()).unwrap_or_default();
    let full = super::encoding::decode_delta(&base_bytes, &r.body).ok()?;
    let digest: [u8; 32] = Sha256::digest(&full).into();
    if digest != manifest.shard_sha256[idx] {
        crate::warn!(
            "shardcast",
            "delta for shard {}/{idx} decoded to a checksum mismatch; falling back to full pull",
            manifest.step
        );
        return None;
    }
    Some((full, r.body))
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Duration;

    #[test]
    fn origin_serves_manifest_and_shards() {
        let origin = Origin::start(ServerConfig::default()).unwrap();
        let payload: Vec<u8> = (0..100_000u32).map(|i| (i % 13) as u8).collect();
        let m = origin.publish(1, &payload, 16 * 1024);
        let c = HttpClient::new("w1");
        let r = c.get(&format!("{}/manifest", origin.url())).unwrap();
        assert_eq!(r.status, 200);
        let got = Manifest::from_json(&Json::parse(std::str::from_utf8(&r.body).unwrap()).unwrap()).unwrap();
        assert_eq!(got, m);
        let mut shards = Vec::new();
        for i in 0..m.n_shards() {
            let r = c.get(&format!("{}/shard?step=1&idx={i}", origin.url())).unwrap();
            assert_eq!(r.status, 200);
            shards.push(r.body);
        }
        assert_eq!(m.assemble(&shards).unwrap(), payload);
        // Unknown checkpoint / shard
        assert_eq!(c.get(&format!("{}/manifest?step=9", origin.url())).unwrap().status, 404);
        assert_eq!(c.get(&format!("{}/shard?step=1&idx=999", origin.url())).unwrap().status, 404);
    }

    #[test]
    fn relay_mirrors_origin() {
        let origin = Origin::start(ServerConfig::default()).unwrap();
        let payload = vec![7u8; 64_000];
        origin.publish(2, &payload, 8 * 1024);
        let relay = Relay::start("r1", origin.url(), ServerConfig::default(),
                                 Duration::from_millis(10)).unwrap();
        // Wait for the mirror.
        let deadline = std::time::Instant::now() + Duration::from_secs(5);
        while !relay.store.is_complete(2) {
            assert!(std::time::Instant::now() < deadline, "relay never completed");
            std::thread::sleep(Duration::from_millis(10));
        }
        let c = HttpClient::new("w2");
        let r = c.get(&format!("{}/shard?step=2&idx=0", relay.url())).unwrap();
        assert_eq!(r.status, 200);
        assert_eq!(r.body.len(), 8 * 1024);
    }

    #[test]
    fn two_tier_tree_topology() {
        let origin = Origin::start(ServerConfig::default()).unwrap();
        origin.publish(1, &vec![1u8; 40_000], 8 * 1024);
        let tier1 = Relay::start("t1", origin.url(), ServerConfig::default(),
                                 Duration::from_millis(10)).unwrap();
        let tier2 = Relay::start("t2", tier1.url(), ServerConfig::default(),
                                 Duration::from_millis(10)).unwrap();
        let deadline = std::time::Instant::now() + Duration::from_secs(5);
        while !tier2.store.is_complete(1) {
            assert!(std::time::Instant::now() < deadline, "tier2 never completed");
            std::thread::sleep(Duration::from_millis(10));
        }
    }

    #[test]
    fn relay_reparents_when_upstream_dies() {
        // Tree: origin -> tier1 -> tier2, with tier2 holding the origin as
        // a fallback parent. Kill tier1 between checkpoints: tier2 must
        // rotate to the origin and keep mirroring new steps.
        let origin = Origin::start(ServerConfig::default()).unwrap();
        origin.publish(1, &vec![4u8; 40_000], 8 * 1024);
        let tier1 = Relay::start("t1", origin.url(), ServerConfig::default(),
                                 Duration::from_millis(10)).unwrap();
        let tier2 = Relay::start_with_parents(
            "t2",
            vec![tier1.url(), origin.url()],
            ServerConfig::default(),
            Duration::from_millis(10),
        )
        .unwrap();
        let deadline = std::time::Instant::now() + Duration::from_secs(5);
        while !tier2.store.is_complete(1) {
            assert!(std::time::Instant::now() < deadline, "tier2 never mirrored step 1");
            std::thread::sleep(Duration::from_millis(10));
        }
        assert_eq!(tier2.current_parent(), tier1.url());

        drop(tier1); // upstream dies; its port now refuses connections
        origin.publish(2, &vec![5u8; 40_000], 8 * 1024);
        let deadline = std::time::Instant::now() + Duration::from_secs(10);
        while !tier2.store.is_complete(2) {
            assert!(std::time::Instant::now() < deadline, "tier2 never healed after re-parent");
            std::thread::sleep(Duration::from_millis(10));
        }
        assert_eq!(tier2.current_parent(), origin.url());
        assert!(tier2.reparent_count() >= 1);
    }

    #[test]
    fn relay_mirrors_delta_publication_and_reserves_it() {
        // Origin publishes step 1 full, then step 2 as delta vs step 1.
        // The relay must (a) assemble byte-identical shards for step 2 and
        // (b) hold the delta wire itself so its own children can pull
        // /delta — whether it arrived via the delta path or was recomputed
        // after a full-shard fallback.
        let origin = Origin::start(ServerConfig::default()).unwrap();
        let base_payload: Vec<u8> = (0..60_000u32).map(|i| (i % 251) as u8).collect();
        let mut cur_payload = base_payload.clone();
        cur_payload[10_000] ^= 0x5A;
        cur_payload[45_000] ^= 0x5A;
        origin.publish(1, &base_payload, 8 * 1024);
        let (m2, sh2) = Manifest::build(2, &cur_payload, 8 * 1024);
        let base_shards: Vec<Vec<u8>> =
            (0..sh2.len()).map(|i| origin.store.shard(1, i).unwrap().as_ref().clone()).collect();
        let wires: Vec<Vec<u8>> = sh2
            .iter()
            .enumerate()
            .map(|(i, s)| super::super::encoding::encode_delta(&base_shards[i], s))
            .collect();
        origin.store.publish_full_with_deltas(m2.clone().with_base(1), sh2, wires.clone());

        // The origin serves /delta; unknown combos are 404 (not 5xx).
        let c = HttpClient::new("probe");
        assert_eq!(c.get(&format!("{}/delta?step=2&idx=0", origin.url())).unwrap().status, 200);
        assert_eq!(c.get(&format!("{}/delta?step=1&idx=0", origin.url())).unwrap().status, 404);

        let relay = Relay::start("rd", origin.url(), ServerConfig::default(),
                                 Duration::from_millis(10)).unwrap();
        let deadline = std::time::Instant::now() + Duration::from_secs(5);
        while !relay.store.is_complete(2) {
            assert!(std::time::Instant::now() < deadline, "relay never mirrored delta step");
            std::thread::sleep(Duration::from_millis(10));
        }
        for i in 0..m2.n_shards() {
            assert_eq!(
                relay.store.delta(2, i).unwrap().as_ref(),
                &wires[i],
                "relay must re-serve the shard {i} delta wire to its subtree"
            );
        }
        let shards: Vec<Vec<u8>> =
            (0..m2.n_shards()).map(|i| relay.store.shard(2, i).unwrap().as_ref().clone()).collect();
        assert_eq!(m2.assemble(&shards).unwrap(), cur_payload);
    }

    #[test]
    fn partition_forces_reparent_then_set_parents_reforms_tree() {
        // Satellite: a netsplit (http::Partition) between tier2 and its
        // preferred parent forces rotation to the fallback; once the
        // planner re-forms the tree, set_parents() moves it back.
        let partition = crate::http::Partition::new();
        let origin = Origin::start(ServerConfig::default()).unwrap();
        origin.publish(1, &vec![4u8; 40_000], 8 * 1024);
        let t1_cfg = ServerConfig {
            partition: Some(Arc::clone(&partition)),
            domain: "t1".to_string(),
            ..ServerConfig::default()
        };
        let tier1 =
            Relay::start("t1", origin.url(), t1_cfg, Duration::from_millis(10)).unwrap();
        let tier2 = Relay::start_with_parents(
            "t2",
            vec![tier1.url(), origin.url()],
            ServerConfig::default(),
            Duration::from_millis(10),
        )
        .unwrap();
        let deadline = std::time::Instant::now() + Duration::from_secs(5);
        while !tier2.store.is_complete(1) {
            assert!(std::time::Instant::now() < deadline, "tier2 never mirrored step 1");
            std::thread::sleep(Duration::from_millis(10));
        }

        // Sever tier2 -> tier1 only (tier1 still reaches the origin).
        partition.cut("relay-t2", "t1", 1_000);
        origin.publish(2, &vec![5u8; 40_000], 8 * 1024);
        let deadline = std::time::Instant::now() + Duration::from_secs(10);
        while !tier2.store.is_complete(2) {
            assert!(std::time::Instant::now() < deadline, "tier2 never routed around the cut");
            std::thread::sleep(Duration::from_millis(10));
        }
        assert_eq!(tier2.current_parent(), origin.url());
        assert!(tier2.reparent_count() >= 1);
        assert!(partition.refused.get() >= 1, "the cut must have actually refused pulls");
        assert!(tier1.store.is_complete(2), "tier1's own uplink must be unaffected");

        // Partition heals; the planner pushes a fresh candidate list.
        partition.advance_to(2_000);
        assert_eq!(partition.live_cuts(), 0);
        tier2.set_parents(vec![tier1.url(), origin.url()]);
        assert_eq!(tier2.current_parent(), tier1.url());
        origin.publish(3, &vec![6u8; 40_000], 8 * 1024);
        let deadline = std::time::Instant::now() + Duration::from_secs(10);
        while !tier2.store.is_complete(3) {
            assert!(std::time::Instant::now() < deadline, "tier2 never pulled via healed tier1");
            std::thread::sleep(Duration::from_millis(10));
        }
        // Ignored: a relay must never be left parentless.
        tier2.set_parents(Vec::new());
        assert_eq!(tier2.current_parent(), tier1.url());
    }
}
