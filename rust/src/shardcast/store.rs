//! Versioned in-memory checkpoint store shared by origin and relay servers.
//! Shards can be present partially (pipelined streaming: a relay serves
//! shard i while it is still fetching shard i+1). Only the last
//! `MAX_VERSIONS` checkpoints are retained (§2.2: relays keep five —
//! rollouts from older policies would be rejected anyway).

use std::collections::BTreeMap;
use std::sync::{Arc, RwLock};

use super::manifest::Manifest;

pub const MAX_VERSIONS: usize = 5;

#[derive(Clone)]
pub struct Checkpoint {
    pub manifest: Manifest,
    pub shards: Vec<Option<Arc<Vec<u8>>>>,
    /// Per-shard delta wires against `manifest.base_step` (same indexing
    /// as `shards`). Optional: populated on delta-encoded publications so
    /// this server can offer `/delta` to its own children; absence only
    /// costs bandwidth (children fall back to full shards).
    pub deltas: Vec<Option<Arc<Vec<u8>>>>,
}

impl Checkpoint {
    pub fn complete(&self) -> bool {
        self.shards.iter().all(Option::is_some)
    }
}

#[derive(Default, Clone)]
pub struct Store {
    inner: Arc<RwLock<BTreeMap<u64, Checkpoint>>>,
}

impl Store {
    pub fn new() -> Store {
        Store::default()
    }

    /// Publish a manifest (shards may stream in afterwards).
    pub fn publish_manifest(&self, manifest: Manifest) {
        let mut map = self.inner.write().unwrap();
        let n = manifest.n_shards();
        map.insert(
            manifest.step,
            Checkpoint { manifest, shards: vec![None; n], deltas: vec![None; n] },
        );
        while map.len() > MAX_VERSIONS {
            let oldest = *map.keys().next().unwrap();
            map.remove(&oldest);
        }
    }

    pub fn put_shard(&self, step: u64, idx: usize, data: Arc<Vec<u8>>) {
        let mut map = self.inner.write().unwrap();
        if let Some(cp) = map.get_mut(&step) {
            if idx < cp.shards.len() {
                cp.shards[idx] = Some(data);
            }
        }
    }

    /// Record the delta wire for `(step, idx)` so it can be served to
    /// children over `/delta`. No-op for unknown steps / out-of-range
    /// indices (mirrors `put_shard`).
    pub fn put_delta(&self, step: u64, idx: usize, wire: Arc<Vec<u8>>) {
        let mut map = self.inner.write().unwrap();
        if let Some(cp) = map.get_mut(&step) {
            if idx < cp.deltas.len() {
                cp.deltas[idx] = Some(wire);
            }
        }
    }

    pub fn delta(&self, step: u64, idx: usize) -> Option<Arc<Vec<u8>>> {
        self.inner.read().unwrap().get(&step).and_then(|c| c.deltas.get(idx).cloned().flatten())
    }

    /// Publish a full checkpoint at once (origin side).
    pub fn publish_full(&self, manifest: Manifest, shards: Vec<Vec<u8>>) {
        self.publish_manifest(manifest.clone());
        for (i, s) in shards.into_iter().enumerate() {
            self.put_shard(manifest.step, i, Arc::new(s));
        }
    }

    /// Publish a checkpoint together with its per-shard delta wires (the
    /// delta-encoded origin path; `manifest.base_step` names the base).
    pub fn publish_full_with_deltas(
        &self,
        manifest: Manifest,
        shards: Vec<Vec<u8>>,
        deltas: Vec<Vec<u8>>,
    ) {
        self.publish_full(manifest.clone(), shards);
        for (i, w) in deltas.into_iter().enumerate() {
            self.put_delta(manifest.step, i, Arc::new(w));
        }
    }

    pub fn manifest(&self, step: u64) -> Option<Manifest> {
        self.inner.read().unwrap().get(&step).map(|c| c.manifest.clone())
    }

    /// Highest version with a published manifest.
    pub fn latest_step(&self) -> Option<u64> {
        self.inner.read().unwrap().keys().next_back().copied()
    }

    pub fn shard(&self, step: u64, idx: usize) -> Option<Arc<Vec<u8>>> {
        self.inner.read().unwrap().get(&step).and_then(|c| c.shards.get(idx).cloned().flatten())
    }

    pub fn versions(&self) -> Vec<u64> {
        self.inner.read().unwrap().keys().copied().collect()
    }

    pub fn is_complete(&self, step: u64) -> bool {
        self.inner.read().unwrap().get(&step).map(Checkpoint::complete).unwrap_or(false)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn retains_last_five_versions() {
        let s = Store::new();
        for step in 0..8u64 {
            let (m, shards) = Manifest::build(step, &vec![step as u8; 1000], 256);
            s.publish_full(m, shards);
        }
        assert_eq!(s.versions(), vec![3, 4, 5, 6, 7]);
        assert_eq!(s.latest_step(), Some(7));
        assert!(s.manifest(2).is_none());
    }

    #[test]
    fn partial_availability() {
        let s = Store::new();
        let (m, shards) = Manifest::build(1, &vec![5u8; 1000], 256);
        s.publish_manifest(m.clone());
        assert!(!s.is_complete(1));
        assert!(s.shard(1, 0).is_none());
        s.put_shard(1, 0, Arc::new(shards[0].clone()));
        assert!(s.shard(1, 0).is_some());
        assert!(s.shard(1, 1).is_none());
        for (i, sh) in shards.iter().enumerate().skip(1) {
            s.put_shard(1, i, Arc::new(sh.clone()));
        }
        assert!(s.is_complete(1));
    }

    #[test]
    fn delta_wires_stored_and_served_per_shard() {
        let s = Store::new();
        let base = vec![1u8; 1000];
        let mut cur = base.clone();
        cur[500] ^= 7;
        let (m0, sh0) = Manifest::build(1, &base, 256);
        s.publish_full(m0, sh0.clone());
        let (m1, sh1) = Manifest::build(2, &cur, 256);
        let wires: Vec<Vec<u8>> = sh1
            .iter()
            .enumerate()
            .map(|(i, s1)| super::super::encoding::encode_delta(&sh0[i], s1))
            .collect();
        s.publish_full_with_deltas(m1.with_base(1), sh1.clone(), wires.clone());
        assert!(s.is_complete(2));
        for i in 0..sh1.len() {
            assert_eq!(s.delta(2, i).unwrap().as_ref(), &wires[i]);
        }
        // Completeness never depends on deltas; unknown indices no-op.
        assert!(s.delta(2, 99).is_none());
        assert!(s.delta(1, 0).is_none());
        s.put_delta(9, 0, Arc::new(vec![1]));
        assert!(s.delta(9, 0).is_none());
    }
}
