//! SHARDCAST worker-side client (§2.2.2, §2.2.3).
//!
//! Server selection: each client probes every relay once to initialize
//! bandwidth/success estimates, then samples relays per shard with
//! probability proportional to  success_rate x bandwidth  (EMA-smoothed,
//! with a healing factor that re-explores idle relays). Probabilistic
//! sampling beats greedy-fastest both under contention and without it
//! (multiple concurrent connections aggregate bandwidth) — reproduced by
//! `benches/shardcast_bench.rs`.

use std::sync::Mutex;
use std::time::Instant;

use super::manifest::Manifest;
use crate::http::HttpClient;
use crate::util::json::Json;
use crate::util::rng::Rng;

const EMA_ALPHA: f64 = 0.3;
/// Healing factor: relative score bonus per second of idleness.
const HEAL_PER_SEC: f64 = 0.25;

#[derive(Debug, Clone)]
struct RelayEstimate {
    url: String,
    bandwidth: f64, // bytes/sec EMA
    success: f64,   // EMA of {0,1}
    last_used: Instant,
}

impl RelayEstimate {
    fn score(&self) -> f64 {
        let idle = self.last_used.elapsed().as_secs_f64();
        (self.success * self.bandwidth).max(1.0) * (1.0 + HEAL_PER_SEC * idle)
    }
}

#[derive(Debug)]
pub struct DownloadReport {
    pub step: u64,
    pub bytes: usize,
    pub seconds: f64,
    pub per_relay_shards: Vec<(String, usize)>,
    pub retries: usize,
}

pub struct ShardcastClient {
    pub http: HttpClient,
    relays: Mutex<Vec<RelayEstimate>>,
    rng: Mutex<Rng>,
}

impl ShardcastClient {
    /// `probe`: request a dummy file from every relay to initialize the
    /// estimates (the paper's bootstrap step).
    pub fn new(node_id: &str, relay_urls: &[String], seed: u64, probe: bool) -> ShardcastClient {
        let http = HttpClient::new(node_id);
        let mut relays = Vec::new();
        for url in relay_urls {
            let bandwidth = if probe {
                let t0 = Instant::now();
                match http.get(&format!("{url}/probe")) {
                    Ok(r) if r.status == 200 => {
                        r.body.len() as f64 / t0.elapsed().as_secs_f64().max(1e-6)
                    }
                    _ => 1.0,
                }
            } else {
                1e6
            };
            relays.push(RelayEstimate {
                url: url.clone(),
                bandwidth,
                success: 1.0,
                last_used: Instant::now(),
            });
        }
        ShardcastClient { http, relays: Mutex::new(relays), rng: Mutex::new(Rng::new(seed)) }
    }

    pub fn with_ingress(mut self, bps: u64) -> ShardcastClient {
        self.http.ingress_bytes_per_sec = bps;
        self
    }

    fn pick_relay(&self) -> String {
        let relays = self.relays.lock().unwrap();
        let weights: Vec<f64> = relays.iter().map(RelayEstimate::score).collect();
        let idx = self.rng.lock().unwrap().weighted(&weights);
        relays[idx].url.clone()
    }

    fn update(&self, url: &str, success: bool, bytes: usize, secs: f64) {
        let mut relays = self.relays.lock().unwrap();
        if let Some(r) = relays.iter_mut().find(|r| r.url == url) {
            r.last_used = Instant::now();
            r.success = (1.0 - EMA_ALPHA) * r.success + EMA_ALPHA * if success { 1.0 } else { 0.0 };
            if success && secs > 0.0 {
                let sample = bytes as f64 / secs;
                r.bandwidth = (1.0 - EMA_ALPHA) * r.bandwidth + EMA_ALPHA * sample;
            }
        }
    }

    pub fn estimates(&self) -> Vec<(String, f64, f64)> {
        self.relays
            .lock()
            .unwrap()
            .iter()
            .map(|r| (r.url.clone(), r.bandwidth, r.success))
            .collect()
    }

    /// Latest checkpoint step visible on any relay.
    pub fn latest_step(&self) -> Option<u64> {
        let relays: Vec<String> =
            self.relays.lock().unwrap().iter().map(|r| r.url.clone()).collect();
        let mut best = None;
        for url in relays {
            if let Ok(r) = self.http.get(&format!("{url}/versions")) {
                if r.status == 200 {
                    if let Ok(j) = Json::parse(std::str::from_utf8(&r.body).unwrap_or("")) {
                        for v in j.as_arr().unwrap_or(&[]) {
                            if let Some(s) = v.as_u64() {
                                best = Some(best.map_or(s, |b: u64| b.max(s)));
                            }
                        }
                    }
                }
            }
        }
        best
    }

    /// Download + verify checkpoint `step`. On integrity failure returns an
    /// error — per §2.2.3 the worker should move on to the next checkpoint
    /// instead of retrying the same one.
    pub fn fetch_checkpoint(&self, step: u64) -> anyhow::Result<(Vec<u8>, DownloadReport)> {
        let t0 = Instant::now();
        let url = self.pick_relay();
        let resp = self.http.get(&format!("{url}/manifest?step={step}"))?;
        anyhow::ensure!(resp.status == 200, "manifest {step}: status {}", resp.status);
        let manifest = Manifest::from_json(&Json::parse(std::str::from_utf8(&resp.body)?)?)?;

        let mut shards: Vec<Vec<u8>> = vec![Vec::new(); manifest.n_shards()];
        let mut per_relay: Vec<(String, usize)> = Vec::new();
        let mut retries = 0usize;
        for idx in 0..manifest.n_shards() {
            let mut attempts = 0;
            loop {
                let url = self.pick_relay();
                let t = Instant::now();
                let result = self.http.get(&format!("{url}/shard?step={step}&idx={idx}"));
                match result {
                    Ok(r) if r.status == 200 => {
                        self.update(&url, true, r.body.len(), t.elapsed().as_secs_f64());
                        match per_relay.iter_mut().find(|(u, _)| *u == url) {
                            Some((_, n)) => *n += 1,
                            None => per_relay.push((url.clone(), 1)),
                        }
                        shards[idx] = r.body;
                        break;
                    }
                    Ok(r) => {
                        // 503 = still streaming on that relay; 429 = rate
                        // limited; both count against its estimate.
                        self.update(&url, false, 0, 0.0);
                        retries += 1;
                        attempts += 1;
                        anyhow::ensure!(
                            attempts < 200,
                            "shard {idx}: giving up (last status {})",
                            r.status
                        );
                        std::thread::sleep(std::time::Duration::from_millis(10));
                    }
                    Err(e) => {
                        self.update(&url, false, 0, 0.0);
                        retries += 1;
                        attempts += 1;
                        anyhow::ensure!(attempts < 200, "shard {idx}: {e}");
                    }
                }
            }
        }
        let payload = manifest.assemble(&shards)?;
        let report = DownloadReport {
            step,
            bytes: payload.len(),
            seconds: t0.elapsed().as_secs_f64(),
            per_relay_shards: per_relay,
            retries,
        };
        Ok((payload, report))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::http::ServerConfig;
    use crate::shardcast::server::{Origin, Relay};
    use std::time::Duration;

    fn swarm(payload: &[u8]) -> (Origin, Vec<Relay>) {
        let origin = Origin::start(ServerConfig::default()).unwrap();
        origin.publish(1, payload, 8 * 1024);
        let relays: Vec<Relay> = (0..3)
            .map(|i| {
                Relay::start(&format!("r{i}"), origin.url(), ServerConfig::default(),
                             Duration::from_millis(5)).unwrap()
            })
            .collect();
        let deadline = Instant::now() + Duration::from_secs(5);
        while !relays.iter().all(|r| r.store.is_complete(1)) {
            assert!(Instant::now() < deadline);
            std::thread::sleep(Duration::from_millis(5));
        }
        (origin, relays)
    }

    #[test]
    fn fetch_verifies_and_spreads_load() {
        let payload: Vec<u8> = (0..200_000u32).map(|i| (i % 7) as u8).collect();
        let (_origin, relays) = swarm(&payload);
        let urls: Vec<String> = relays.iter().map(Relay::url).collect();
        let client = ShardcastClient::new("worker-1", &urls, 42, true);
        let (got, report) = client.fetch_checkpoint(1).unwrap();
        assert_eq!(got, payload);
        assert_eq!(report.bytes, payload.len());
        // Probabilistic selection uses more than one relay for 25 shards.
        assert!(report.per_relay_shards.len() >= 2, "{:?}", report.per_relay_shards);
    }

    #[test]
    fn corrupted_relay_detected() {
        let payload = vec![3u8; 50_000];
        let (origin, _relays) = swarm(&payload);
        // A lying relay: serves the manifest but corrupts shard bytes.
        let evil_store = origin.store.clone();
        let evil = crate::http::HttpServer::start(ServerConfig::default(), move |req| {
            let resp = {
                // Reuse origin handler logic by fetching from the store.
                if req.path == "/shard" {
                    let step = req.query_u64("step", 0);
                    let idx = req.query_u64("idx", 0) as usize;
                    match evil_store.shard(step, idx) {
                        Some(d) => {
                            let mut d = d.as_ref().clone();
                            if !d.is_empty() {
                                d[0] ^= 0xFF;
                            }
                            crate::http::Response::ok(d)
                        }
                        None => crate::http::Response::error(404, "x"),
                    }
                } else if req.path == "/manifest" {
                    match evil_store.manifest(req.query_u64("step", 1)) {
                        Some(m) => crate::http::Response::json(&m.to_json()),
                        None => crate::http::Response::error(404, "x"),
                    }
                } else {
                    crate::http::Response::ok(vec![0u8; 128])
                }
            };
            resp
        })
        .unwrap();
        let client = ShardcastClient::new("worker-2", &[evil.url()], 7, false);
        let err = client.fetch_checkpoint(1).unwrap_err().to_string();
        assert!(err.contains("checksum"), "{err}");
    }

    #[test]
    fn ema_prefers_faster_relay_over_time() {
        let payload = vec![1u8; 400_000];
        let origin = Origin::start(ServerConfig::default()).unwrap();
        origin.publish(1, &payload, 16 * 1024);
        // Fast relay unshaped; slow relay heavily shaped.
        let fast = Relay::start("fast", origin.url(), ServerConfig::default(),
                                Duration::from_millis(5)).unwrap();
        let slow_cfg = ServerConfig { egress_bytes_per_sec: 64 * 1024, ..Default::default() };
        let slow = Relay::start("slow", origin.url(), slow_cfg, Duration::from_millis(5)).unwrap();
        let deadline = Instant::now() + Duration::from_secs(5);
        while !(fast.store.is_complete(1) && slow.store.is_complete(1)) {
            assert!(Instant::now() < deadline);
            std::thread::sleep(Duration::from_millis(5));
        }
        let client = ShardcastClient::new("worker-3", &[fast.url(), slow.url()], 3, true);
        let (_, report) = client.fetch_checkpoint(1).unwrap();
        let fast_n = report.per_relay_shards.iter().find(|(u, _)| *u == fast.url()).map(|(_, n)| *n).unwrap_or(0);
        let slow_n = report.per_relay_shards.iter().find(|(u, _)| *u == slow.url()).map(|(_, n)| *n).unwrap_or(0);
        // The EMA must have learned the bandwidth ordering; shard counts
        // lean fast-ward but keep exploring the slow relay (healing factor),
        // so we assert the learned estimates rather than exact counts.
        let est = client.estimates();
        let bw = |url: &str| est.iter().find(|(u, _, _)| u == url).map(|(_, b, _)| *b).unwrap();
        assert!(
            bw(&fast.url()) > bw(&slow.url()),
            "bandwidth estimates: fast={} slow={} (shards fast={fast_n} slow={slow_n})",
            bw(&fast.url()),
            bw(&slow.url())
        );
        assert!(fast_n + slow_n > 0);
    }
}
