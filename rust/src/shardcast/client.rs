//! SHARDCAST worker-side client (§2.2.2, §2.2.3).
//!
//! Server selection: each client probes every relay once to initialize
//! bandwidth/success estimates, then samples relays per shard with
//! probability proportional to  success_rate x bandwidth  (EMA-smoothed,
//! with a healing factor that re-explores idle relays). Probabilistic
//! sampling beats greedy-fastest both under contention and without it
//! (multiple concurrent connections aggregate bandwidth) — reproduced by
//! `benches/shardcast_bench.rs`.
//!
//! Churn hardening: every fetch (manifest and shards) retries under the
//! shared [`RetryPolicy`], failing over to a freshly-sampled relay each
//! attempt; a relay that fails [`QUARANTINE_AFTER`] times in a row is
//! quarantined out of the sampling pool until it serves again (it re-earns
//! trust through the desperation probe that fires when *every* relay is
//! quarantined). A relay dying mid-checkpoint therefore costs a few
//! retries, not the download.

use std::sync::Mutex;
use std::time::Instant;

use sha2::{Digest, Sha256};

use super::manifest::Manifest;
use crate::http::HttpClient;
use crate::util::json::Json;
use crate::util::metrics::Counter;
use crate::util::retry::RetryPolicy;
use crate::util::rng::Rng;

const EMA_ALPHA: f64 = 0.3;
/// Healing factor: relative score bonus per second of idleness.
const HEAL_PER_SEC: f64 = 0.25;
/// Consecutive failures after which a relay leaves the sampling pool.
pub const QUARANTINE_AFTER: u32 = 3;

#[derive(Debug, Clone)]
struct RelayEstimate {
    url: String,
    bandwidth: f64, // bytes/sec EMA
    success: f64,   // EMA of {0,1}
    last_used: Instant,
    /// Failures since the last success; >= [`QUARANTINE_AFTER`] means
    /// quarantined (skipped by `pick_relay` while alternatives exist).
    consecutive_failures: u32,
}

impl RelayEstimate {
    fn score(&self) -> f64 {
        let idle = self.last_used.elapsed().as_secs_f64();
        (self.success * self.bandwidth).max(1.0) * (1.0 + HEAL_PER_SEC * idle)
    }

    fn quarantined(&self) -> bool {
        self.consecutive_failures >= QUARANTINE_AFTER
    }
}

#[derive(Debug)]
pub struct DownloadReport {
    pub step: u64,
    /// Assembled checkpoint size (after delta decode, before dequantize).
    pub bytes: usize,
    pub seconds: f64,
    pub per_relay_shards: Vec<(String, usize)>,
    pub retries: usize,
    /// Bytes actually received on the shard plane (delta wires + full
    /// shard bodies) — the egress the relay tier really paid for this
    /// download. Equals the sum of shard sizes on a pure full-shard
    /// fetch, (much) less when deltas were used.
    pub wire_bytes: usize,
    /// How many shards arrived as delta wires rather than full pulls.
    pub delta_shards: usize,
}

pub struct ShardcastClient {
    pub http: HttpClient,
    relays: Mutex<Vec<RelayEstimate>>,
    rng: Mutex<Rng>,
    /// Times any relay crossed the quarantine threshold.
    pub quarantine_events: Counter,
    /// Failed attempts across all fetches (manifest + shards).
    pub fetch_retries: Counter,
}

impl ShardcastClient {
    /// `probe`: request a dummy file from every relay to initialize the
    /// estimates (the paper's bootstrap step).
    pub fn new(node_id: &str, relay_urls: &[String], seed: u64, probe: bool) -> ShardcastClient {
        let http = HttpClient::new(node_id);
        let mut relays = Vec::new();
        for url in relay_urls {
            let bandwidth = if probe {
                let t0 = Instant::now();
                match http.get(&format!("{url}/probe")) {
                    Ok(r) if r.status == 200 => {
                        r.body.len() as f64 / t0.elapsed().as_secs_f64().max(1e-6)
                    }
                    _ => 1.0,
                }
            } else {
                1e6
            };
            relays.push(RelayEstimate {
                url: url.clone(),
                bandwidth,
                success: 1.0,
                last_used: Instant::now(),
                consecutive_failures: 0,
            });
        }
        ShardcastClient {
            http,
            relays: Mutex::new(relays),
            rng: Mutex::new(Rng::new(seed)),
            quarantine_events: Counter::default(),
            fetch_retries: Counter::default(),
        }
    }

    pub fn with_ingress(mut self, bps: u64) -> ShardcastClient {
        self.http.ingress_bytes_per_sec = bps;
        self
    }

    /// Replace the relay set (the self-healing tree re-forms under churn
    /// and a relay directory pushes the new URLs). Estimates for surviving
    /// URLs are kept; new URLs start optimistic; gone URLs are dropped.
    /// `urls` must be non-empty.
    pub fn set_relays(&self, urls: &[String]) {
        let mut relays = self.relays.lock().unwrap();
        relays.retain(|r| urls.contains(&r.url));
        for url in urls {
            if !relays.iter().any(|r| &r.url == url) {
                relays.push(RelayEstimate {
                    url: url.clone(),
                    bandwidth: 1e6,
                    success: 1.0,
                    last_used: Instant::now(),
                    consecutive_failures: 0,
                });
            }
        }
    }

    fn pick_relay(&self) -> String {
        let relays = self.relays.lock().unwrap();
        let n = relays.len();
        let healthy: Vec<usize> = (0..n).filter(|&i| !relays[i].quarantined()).collect();
        // Every relay quarantined = total-outage mode: sample among all of
        // them (this desperation probe is also how a recovered relay gets
        // the request that clears its quarantine).
        let pool: Vec<usize> = if healthy.is_empty() { (0..n).collect() } else { healthy };
        let weights: Vec<f64> = pool.iter().map(|&i| relays[i].score()).collect();
        let k = self.rng.lock().unwrap().weighted(&weights);
        relays[pool[k]].url.clone()
    }

    fn update(&self, url: &str, success: bool, bytes: usize, secs: f64) {
        let mut relays = self.relays.lock().unwrap();
        if let Some(r) = relays.iter_mut().find(|r| r.url == url) {
            r.last_used = Instant::now();
            r.success = (1.0 - EMA_ALPHA) * r.success + EMA_ALPHA * if success { 1.0 } else { 0.0 };
            if success {
                r.consecutive_failures = 0;
                if secs > 0.0 {
                    let sample = bytes as f64 / secs;
                    r.bandwidth = (1.0 - EMA_ALPHA) * r.bandwidth + EMA_ALPHA * sample;
                }
            } else {
                r.consecutive_failures += 1;
                if r.consecutive_failures == QUARANTINE_AFTER {
                    self.quarantine_events.inc();
                }
            }
        }
    }

    pub fn estimates(&self) -> Vec<(String, f64, f64)> {
        self.relays
            .lock()
            .unwrap()
            .iter()
            .map(|r| (r.url.clone(), r.bandwidth, r.success))
            .collect()
    }

    /// URLs currently quarantined out of the sampling pool.
    pub fn quarantined(&self) -> Vec<String> {
        self.relays
            .lock()
            .unwrap()
            .iter()
            .filter(|r| r.quarantined())
            .map(|r| r.url.clone())
            .collect()
    }

    /// Latest checkpoint step visible on any relay.
    pub fn latest_step(&self) -> Option<u64> {
        let relays: Vec<String> =
            self.relays.lock().unwrap().iter().map(|r| r.url.clone()).collect();
        let mut best = None;
        for url in relays {
            if let Ok(r) = self.http.get(&format!("{url}/versions")) {
                if r.status == 200 {
                    if let Ok(j) = Json::parse(std::str::from_utf8(&r.body).unwrap_or("")) {
                        for v in j.as_arr().unwrap_or(&[]) {
                            if let Some(s) = v.as_u64() {
                                best = Some(best.map_or(s, |b: u64| b.max(s)));
                            }
                        }
                    }
                }
            }
        }
        best
    }

    /// Download + verify checkpoint `step`. On integrity failure returns an
    /// error — per §2.2.3 the worker should move on to the next checkpoint
    /// instead of retrying the same one.
    ///
    /// Transport failures are survivable: the manifest and every shard
    /// retry under the shared [`RetryPolicy`] budgets, each attempt
    /// failing over to a freshly-sampled relay, so one dead relay costs
    /// retries (and its quarantine), not the checkpoint.
    pub fn fetch_checkpoint(&self, step: u64) -> anyhow::Result<(Vec<u8>, DownloadReport)> {
        self.fetch_checkpoint_with_base(step, None)
    }

    /// Like [`ShardcastClient::fetch_checkpoint`], but when the caller
    /// still holds the assembled payload of an earlier checkpoint it can
    /// offer it as a delta base. If the manifest advertises the *same*
    /// `base_step`, each shard is first attempted as a `/delta` wire
    /// (decoded against the re-chunked base, verified against the
    /// manifest's per-shard digest); any miss falls back to the full
    /// `/shard` pull, so the result is byte-identical either way — only
    /// `wire_bytes` changes.
    pub fn fetch_checkpoint_with_base(
        &self,
        step: u64,
        base: Option<(u64, &[u8])>,
    ) -> anyhow::Result<(Vec<u8>, DownloadReport)> {
        let t0 = Instant::now();
        // Backoff jitter stream: deterministic per (client seed, step), and
        // independent of the relay-sampling stream.
        let mut jrng = self.rng.lock().unwrap().fold(0xBACC0FF ^ step);
        let mut retries = 0usize;

        // Manifest: failed over across relays, not pinned to one sample —
        // the checkpoint must survive the first relay we ask being down.
        let manifest = RetryPolicy::shardcast_manifest().run(
            &format!("manifest {step}"),
            &mut jrng,
            |_| {
                let url = self.pick_relay();
                let resp = match self.http.get(&format!("{url}/manifest?step={step}")) {
                    Ok(r) if r.status == 200 => r,
                    Ok(r) => {
                        self.update(&url, false, 0, 0.0);
                        retries += 1;
                        anyhow::bail!("from {url}: status {}", r.status);
                    }
                    Err(e) => {
                        self.update(&url, false, 0, 0.0);
                        retries += 1;
                        anyhow::bail!("from {url}: {e}");
                    }
                };
                Manifest::from_json(&Json::parse(std::str::from_utf8(&resp.body)?)?)
            },
        )?;

        // Delta eligibility: the manifest's advertised base must be the
        // exact step the caller holds — shard geometry is shared across
        // steps, so re-chunking the base payload at the manifest's
        // shard_bytes reproduces the base shards the publisher diffed
        // against.
        let base_shards: Option<Vec<&[u8]>> = match (manifest.base_step, base) {
            (Some(mb), Some((cb, payload))) if mb == cb => {
                Some(payload.chunks(manifest.shard_bytes.max(1)).collect())
            }
            _ => None,
        };

        let mut shards: Vec<Vec<u8>> = vec![Vec::new(); manifest.n_shards()];
        let mut per_relay: Vec<(String, usize)> = Vec::new();
        let mut wire_bytes = 0usize;
        let mut delta_shards = 0usize;
        let shard_policy = RetryPolicy::shardcast_shard();
        for idx in 0..manifest.n_shards() {
            // One delta attempt, no retry: a 404 / decode failure /
            // digest mismatch just drops to the full-shard ladder below.
            // Failures are *not* charged to the relay's estimate — a
            // relay without a delta wire is not an unhealthy relay.
            if let Some(bs) = &base_shards {
                if let Some((full, wire_len, url)) =
                    self.try_delta_shard(&manifest, bs, step, idx)
                {
                    wire_bytes += wire_len;
                    delta_shards += 1;
                    match per_relay.iter_mut().find(|(u, _)| *u == url) {
                        Some((_, n)) => *n += 1,
                        None => per_relay.push((url, 1)),
                    }
                    shards[idx] = full;
                    continue;
                }
            }
            shards[idx] = shard_policy.run(&format!("shard {step}/{idx}"), &mut jrng, |_| {
                let url = self.pick_relay();
                let t = Instant::now();
                match self.http.get(&format!("{url}/shard?step={step}&idx={idx}")) {
                    Ok(r) if r.status == 200 => {
                        self.update(&url, true, r.body.len(), t.elapsed().as_secs_f64());
                        match per_relay.iter_mut().find(|(u, _)| *u == url) {
                            Some((_, n)) => *n += 1,
                            None => per_relay.push((url.clone(), 1)),
                        }
                        Ok(r.body)
                    }
                    Ok(r) => {
                        // 503 = still streaming on that relay; 429 = rate
                        // limited; both count against its estimate and
                        // fail over.
                        self.update(&url, false, 0, 0.0);
                        retries += 1;
                        anyhow::bail!("from {url}: status {}", r.status)
                    }
                    Err(e) => {
                        self.update(&url, false, 0, 0.0);
                        retries += 1;
                        anyhow::bail!("from {url}: {e}")
                    }
                }
            })?;
            wire_bytes += shards[idx].len();
        }
        self.fetch_retries.add(retries as u64);
        let payload = manifest.assemble(&shards)?;
        let report = DownloadReport {
            step,
            bytes: payload.len(),
            seconds: t0.elapsed().as_secs_f64(),
            per_relay_shards: per_relay,
            retries,
            wire_bytes,
            delta_shards,
        };
        Ok((payload, report))
    }

    /// One delta attempt for `(step, idx)`: fetch the wire from a sampled
    /// relay, decode against the caller's base shard, verify against the
    /// manifest digest. Returns `(full_shard, wire_len, relay_url)` on
    /// success, `None` to fall back to the full-shard pull.
    fn try_delta_shard(
        &self,
        manifest: &Manifest,
        base_shards: &[&[u8]],
        step: u64,
        idx: usize,
    ) -> Option<(Vec<u8>, usize, String)> {
        let url = self.pick_relay();
        let t = Instant::now();
        let r = self.http.get(&format!("{url}/delta?step={step}&idx={idx}")).ok()?;
        if r.status != 200 {
            return None;
        }
        let base_bytes: &[u8] = base_shards.get(idx).copied().unwrap_or(&[]);
        let full = super::encoding::decode_delta(base_bytes, &r.body).ok()?;
        let digest: [u8; 32] = Sha256::digest(&full).into();
        if digest != manifest.shard_sha256[idx] {
            return None;
        }
        self.update(&url, true, r.body.len(), t.elapsed().as_secs_f64());
        Some((full, r.body.len(), url))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::http::ServerConfig;
    use crate::shardcast::server::{Origin, Relay};
    use std::time::Duration;

    fn swarm(payload: &[u8]) -> (Origin, Vec<Relay>) {
        let origin = Origin::start(ServerConfig::default()).unwrap();
        origin.publish(1, payload, 8 * 1024);
        let relays: Vec<Relay> = (0..3)
            .map(|i| {
                Relay::start(&format!("r{i}"), origin.url(), ServerConfig::default(),
                             Duration::from_millis(5)).unwrap()
            })
            .collect();
        let deadline = Instant::now() + Duration::from_secs(5);
        while !relays.iter().all(|r| r.store.is_complete(1)) {
            assert!(Instant::now() < deadline);
            std::thread::sleep(Duration::from_millis(5));
        }
        (origin, relays)
    }

    #[test]
    fn fetch_verifies_and_spreads_load() {
        let payload: Vec<u8> = (0..200_000u32).map(|i| (i % 7) as u8).collect();
        let (_origin, relays) = swarm(&payload);
        let urls: Vec<String> = relays.iter().map(Relay::url).collect();
        let client = ShardcastClient::new("worker-1", &urls, 42, true);
        let (got, report) = client.fetch_checkpoint(1).unwrap();
        assert_eq!(got, payload);
        assert_eq!(report.bytes, payload.len());
        // Probabilistic selection uses more than one relay for 25 shards.
        assert!(report.per_relay_shards.len() >= 2, "{:?}", report.per_relay_shards);
    }

    #[test]
    fn corrupted_relay_detected() {
        let payload = vec![3u8; 50_000];
        let (origin, _relays) = swarm(&payload);
        // A lying relay: serves the manifest but corrupts shard bytes.
        let evil_store = origin.store.clone();
        let evil = crate::http::HttpServer::start(ServerConfig::default(), move |req| {
            let resp = {
                // Reuse origin handler logic by fetching from the store.
                if req.path == "/shard" {
                    let step = req.query_u64("step", 0);
                    let idx = req.query_u64("idx", 0) as usize;
                    match evil_store.shard(step, idx) {
                        Some(d) => {
                            let mut d = d.as_ref().clone();
                            if !d.is_empty() {
                                d[0] ^= 0xFF;
                            }
                            crate::http::Response::ok(d)
                        }
                        None => crate::http::Response::error(404, "x"),
                    }
                } else if req.path == "/manifest" {
                    match evil_store.manifest(req.query_u64("step", 1)) {
                        Some(m) => crate::http::Response::json(&m.to_json()),
                        None => crate::http::Response::error(404, "x"),
                    }
                } else {
                    crate::http::Response::ok(vec![0u8; 128])
                }
            };
            resp
        })
        .unwrap();
        let client = ShardcastClient::new("worker-2", &[evil.url()], 7, false);
        let err = client.fetch_checkpoint(1).unwrap_err().to_string();
        assert!(err.contains("checksum"), "{err}");
    }

    #[test]
    fn ema_prefers_faster_relay_over_time() {
        let payload = vec![1u8; 400_000];
        let origin = Origin::start(ServerConfig::default()).unwrap();
        origin.publish(1, &payload, 16 * 1024);
        // Fast relay unshaped; slow relay heavily shaped.
        let fast = Relay::start("fast", origin.url(), ServerConfig::default(),
                                Duration::from_millis(5)).unwrap();
        let slow_cfg = ServerConfig { egress_bytes_per_sec: 64 * 1024, ..Default::default() };
        let slow = Relay::start("slow", origin.url(), slow_cfg, Duration::from_millis(5)).unwrap();
        let deadline = Instant::now() + Duration::from_secs(5);
        while !(fast.store.is_complete(1) && slow.store.is_complete(1)) {
            assert!(Instant::now() < deadline);
            std::thread::sleep(Duration::from_millis(5));
        }
        let client = ShardcastClient::new("worker-3", &[fast.url(), slow.url()], 3, true);
        let (_, report) = client.fetch_checkpoint(1).unwrap();
        let fast_n = report.per_relay_shards.iter().find(|(u, _)| *u == fast.url()).map(|(_, n)| *n).unwrap_or(0);
        let slow_n = report.per_relay_shards.iter().find(|(u, _)| *u == slow.url()).map(|(_, n)| *n).unwrap_or(0);
        // The EMA must have learned the bandwidth ordering; shard counts
        // lean fast-ward but keep exploring the slow relay (healing factor),
        // so we assert the learned estimates rather than exact counts.
        let est = client.estimates();
        let bw = |url: &str| est.iter().find(|(u, _, _)| u == url).map(|(_, b, _)| *b).unwrap();
        assert!(
            bw(&fast.url()) > bw(&slow.url()),
            "bandwidth estimates: fast={} slow={} (shards fast={fast_n} slow={slow_n})",
            bw(&fast.url()),
            bw(&slow.url())
        );
        assert!(fast_n + slow_n > 0);
    }

    #[test]
    fn fetch_fails_over_when_a_relay_is_down() {
        // One of two relays is dead before the fetch starts: the manifest
        // and every shard must fail over to the survivor, the dead relay
        // must end up quarantined, and its estimate must collapse. With
        // QUARANTINE_AFTER = 3 < the manifest policy's 6 attempts, success
        // is guaranteed for any RNG seed: the dead relay can eat at most 3
        // attempts before it leaves the sampling pool.
        let payload: Vec<u8> = (0..300_000u32).map(|i| (i % 11) as u8).collect();
        let origin = Origin::start(ServerConfig::default()).unwrap();
        origin.publish(1, &payload, 8 * 1024);
        let poll = Duration::from_millis(5);
        let alive = Relay::start("alive", origin.url(), ServerConfig::default(), poll).unwrap();
        let dead = Relay::start("dead", origin.url(), ServerConfig::default(), poll).unwrap();
        let deadline = Instant::now() + Duration::from_secs(5);
        while !(alive.store.is_complete(1) && dead.store.is_complete(1)) {
            assert!(Instant::now() < deadline);
            std::thread::sleep(Duration::from_millis(5));
        }
        let urls = vec![dead.url(), alive.url()];
        let dead_url = dead.url();
        drop(dead); // port closes: connections now refused

        let client = ShardcastClient::new("worker-4", &urls, 17, false);
        let (got, report) = client.fetch_checkpoint(1).unwrap();
        assert_eq!(got, payload);
        assert!(report.retries >= 1, "no failover retries recorded: {report:?}");
        assert_eq!(client.fetch_retries.get(), report.retries as u64);
        assert!(
            client.quarantined().contains(&dead_url),
            "dead relay not quarantined: {:?}",
            client.quarantined()
        );
        let est = client.estimates();
        let succ = |url: &str| est.iter().find(|(u, _, _)| u == url).map(|(_, _, s)| *s).unwrap();
        assert!(succ(&dead_url) < succ(&alive.url()), "estimate did not collapse: {est:?}");
    }

    #[test]
    fn relay_death_mid_download_completes_from_survivors() {
        // Kill a relay *between shards* of an in-flight fetch: the client
        // sees connection errors partway through, fails over, and still
        // assembles a byte-identical checkpoint from the survivor.
        let payload: Vec<u8> = (0..400_000u32).map(|i| (i % 17) as u8).collect();
        let origin = Origin::start(ServerConfig::default()).unwrap();
        origin.publish(1, &payload, 8 * 1024);
        // Egress shaping stretches the download (~50 shards at ~1 MiB/s
        // aggregate ≈ 0.4 s+) so the kill below lands mid-fetch.
        let shaped = ServerConfig { egress_bytes_per_sec: 512 * 1024, ..Default::default() };
        let doomed =
            Relay::start("doomed", origin.url(), shaped.clone(), Duration::from_millis(5)).unwrap();
        let survivor =
            Relay::start("survivor", origin.url(), shaped, Duration::from_millis(5)).unwrap();
        let deadline = Instant::now() + Duration::from_secs(5);
        while !(doomed.store.is_complete(1) && survivor.store.is_complete(1)) {
            assert!(Instant::now() < deadline);
            std::thread::sleep(Duration::from_millis(5));
        }
        let urls = vec![doomed.url(), survivor.url()];
        let doomed_url = doomed.url();
        let victim = std::sync::Arc::new(Mutex::new(Some(doomed)));
        let killer = {
            let victim = std::sync::Arc::clone(&victim);
            std::thread::spawn(move || {
                std::thread::sleep(Duration::from_millis(120));
                victim.lock().unwrap().take();
            })
        };

        let client = ShardcastClient::new("worker-5", &urls, 23, false);
        let (got, report) = client.fetch_checkpoint(1).unwrap();
        killer.join().unwrap();
        assert_eq!(got, payload);
        assert!(report.retries >= 1, "kill did not force any retries: {report:?}");
        assert!(client.quarantine_events.get() >= 1, "dead relay never quarantined");
        let est = client.estimates();
        let succ = |url: &str| est.iter().find(|(u, _, _)| u == url).map(|(_, _, s)| *s).unwrap();
        assert!(
            succ(&doomed_url) < succ(&survivor.url()),
            "dead relay's estimate did not collapse: {est:?}"
        );
    }

    #[test]
    fn delta_and_full_paths_assemble_identical_bytes() {
        // Property at the heart of the encoding contract: a worker that
        // downloads step 2 via per-shard deltas against its held step-1
        // payload must end up with *byte-identical* output (and identical
        // digests) to a worker that pulled every shard in full — deltas
        // are a transport optimization, never a semantic change.
        let base_payload: Vec<u8> = (0..120_000u32).map(|i| (i % 249) as u8).collect();
        let mut cur_payload = base_payload.clone();
        for pos in [5_000usize, 60_000, 119_999] {
            cur_payload[pos] ^= 0x33;
        }
        let origin = Origin::start(ServerConfig::default()).unwrap();
        origin.publish(1, &base_payload, 8 * 1024);
        let (m2, sh2) = Manifest::build(2, &cur_payload, 8 * 1024);
        let wires: Vec<Vec<u8>> = sh2
            .iter()
            .enumerate()
            .map(|(i, s)| {
                let b = origin.store.shard(1, i).unwrap();
                crate::shardcast::encoding::encode_delta(&b, s)
            })
            .collect();
        origin.store.publish_full_with_deltas(m2.clone().with_base(1), sh2, wires);

        let relay = Relay::start("dr", origin.url(), ServerConfig::default(),
                                 Duration::from_millis(5)).unwrap();
        let deadline = Instant::now() + Duration::from_secs(5);
        while !(relay.store.is_complete(1) && relay.store.is_complete(2)) {
            assert!(Instant::now() < deadline, "relay never mirrored both steps");
            std::thread::sleep(Duration::from_millis(5));
        }

        let client = ShardcastClient::new("worker-6", &[relay.url()], 11, false);
        let (held_base, _) = client.fetch_checkpoint(1).unwrap();
        assert_eq!(held_base, base_payload);
        let (full, full_rep) = client.fetch_checkpoint(2).unwrap();
        let (via_delta, delta_rep) =
            client.fetch_checkpoint_with_base(2, Some((1, &held_base))).unwrap();
        assert_eq!(full, via_delta, "delta and full decode paths diverged");
        assert_eq!(
            Sha256::digest(&full)[..],
            Sha256::digest(&via_delta)[..],
            "checksum mismatch between paths"
        );
        assert_eq!(full_rep.delta_shards, 0);
        assert_eq!(full_rep.wire_bytes, cur_payload.len());
        assert_eq!(delta_rep.delta_shards, m2.n_shards(), "{delta_rep:?}");
        assert!(
            delta_rep.wire_bytes * 2 < full_rep.wire_bytes,
            "sparse delta saved too little: {} vs {}",
            delta_rep.wire_bytes,
            full_rep.wire_bytes
        );
        // A base the manifest does not advertise (stale by one step) must
        // fall back to full pulls and still agree byte-for-byte.
        let (stale, stale_rep) =
            client.fetch_checkpoint_with_base(2, Some((0, &held_base))).unwrap();
        assert_eq!(stale, full);
        assert_eq!(stale_rep.delta_shards, 0);
    }
}
