//! SHARDCAST (paper §2.2): HTTP tree-topology broadcast of policy weights
//! from the training node to decentralized inference workers — sharded,
//! pipelined, checksummed, rate-limited and firewalled.
//!
//! # Failure model
//!
//! The relay tier and its clients assume an unreliable swarm and treat the
//! following faults as *survivable* (they cost retries, never the
//! checkpoint):
//!
//! - **Relay death mid-download** — [`ShardcastClient`] retries every
//!   manifest/shard request under [`crate::util::retry::RetryPolicy`]
//!   budgets, failing over to a freshly-sampled relay per attempt. A relay
//!   that fails [`client::QUARANTINE_AFTER`] times in a row is quarantined
//!   out of the sampling pool (it re-earns trust via the desperation probe
//!   that fires when every relay is quarantined).
//! - **Upstream death inside the tree** — a [`Relay`] started with
//!   [`server::Relay::start_with_parents`] rotates to its next candidate
//!   parent after [`server::REPARENT_AFTER`] consecutive failed pull
//!   cycles, and resumes half-mirrored checkpoints shard-by-shard from the
//!   new parent.
//! - **Slow/streaming peers** — 503 "shard not yet available" responses
//!   back off under the same retry policies (pipelining means a parent may
//!   legitimately lag by a few shards).
//!
//! *Not* survivable by design: payload corruption. A checksum mismatch in
//! [`Manifest::assemble`] fails the fetch outright — per §2.2.3 the worker
//! skips to the next checkpoint rather than re-trusting a lying relay.
//!
//! All retry schedules draw jitter from the deterministic
//! [`crate::util::rng::Rng`], so chaos runs driven by
//! [`crate::http::FaultPlan`] replay exactly.

pub mod client;
pub mod manifest;
pub mod publisher;
pub mod server;
pub mod store;

pub use client::{DownloadReport, ShardcastClient};
pub use manifest::Manifest;
pub use publisher::{BroadcastRecord, Broadcaster};
pub use server::{Origin, Relay};
pub use store::Store;
