//! SHARDCAST (paper §2.2): HTTP tree-topology broadcast of policy weights
//! from the training node to decentralized inference workers — sharded,
//! pipelined, checksummed, rate-limited and firewalled. The tree is
//! *self-organizing*: relays plan their parents from a gossiped membership
//! view ([`tree::plan_tree`]) and re-form the topology under churn instead
//! of relying on a hand-wired hub-and-spoke.
//!
//! # Parent selection
//!
//! Each relay scores candidate hubs by advertised uplink discounted by
//! measured pull latency ([`tree::RelayPeer::score`]): the fattest,
//! closest relays become the origin's direct children and everything else
//! attaches under the shallowest hub with spare fan-out capacity. The
//! resulting [`tree::TreePlan`] hands every relay an *ordered* candidate
//! list in which each entry sits at strictly smaller depth than the relay
//! itself, with the origin always last — so any rotation through the list
//! is loop-free by construction, no cycle detection needed. A starved or
//! distant relay is planned as a leaf and never becomes a hub.
//!
//! # Re-formation triggers
//!
//! Two mechanisms heal the tree, at different speeds:
//!
//! - **Local rotation (fast, autonomous)** — a [`Relay`] rotates to its
//!   next candidate parent after [`server::REPARENT_AFTER`] consecutive
//!   failed pull cycles (dead upstream, netsplit via
//!   [`crate::http::Partition`], sustained 5xx). Costs a few poll
//!   intervals; needs no coordination.
//! - **Re-planning (global, gossip-driven)** — when the gossiped
//!   membership view changes (peer TTL expiry, quarantine, joins), the
//!   planner recomputes the tree over the survivors ([`tree::reform`])
//!   and pushes fresh candidate lists via [`server::Relay::set_parents`].
//!   Relays resume half-mirrored checkpoints shard-by-shard from their
//!   new parent — only fully-complete steps are skipped by the puller.
//!
//! # Delta fallback ladder
//!
//! A publication may advertise `base_step` in its [`Manifest`]: per-shard
//! XOR+RLE delta wires against that earlier checkpoint (optionally over a
//! block-quantized payload — [`encoding`]). Every consumer walks the same
//! ladder, per shard:
//!
//! 1. holds the base in full → try `GET /delta`, decode against the base
//!    shard, verify against the manifest's per-shard digest;
//! 2. any miss (404, decode error, digest mismatch, no base) → full
//!    `GET /shard` pull, identical bytes guaranteed by the digests;
//! 3. a relay that fell back still re-derives the wire locally (the codec
//!    is pure), so its own subtree keeps its delta savings.
//!
//! Integrity is never delegated to the encoding: manifest digests are
//! always over the *decoded full shards*, so the §2.2.3 checksum contract
//! is the same on both paths and a corrupt wire can only cost bandwidth.
//!
//! # Failure model
//!
//! The relay tier and its clients assume an unreliable swarm and treat the
//! following faults as *survivable* (they cost retries, never the
//! checkpoint):
//!
//! - **Relay death mid-download** — [`ShardcastClient`] retries every
//!   manifest/shard request under [`crate::util::retry::RetryPolicy`]
//!   budgets, failing over to a freshly-sampled relay per attempt. A relay
//!   that fails [`client::QUARANTINE_AFTER`] times in a row is quarantined
//!   out of the sampling pool (it re-earns trust via the desperation probe
//!   that fires when every relay is quarantined).
//! - **Upstream death or partition inside the tree** — local rotation,
//!   then gossip-driven re-planning, as above.
//! - **Slow/streaming peers** — 503 "shard not yet available" responses
//!   back off under the same retry policies (pipelining means a parent may
//!   legitimately lag by a few shards).
//! - **Missing delta base** — transparent fall-through to full shards.
//!
//! *Not* survivable by design: payload corruption. A checksum mismatch in
//! [`Manifest::assemble`] fails the fetch outright — per §2.2.3 the worker
//! skips to the next checkpoint rather than re-trusting a lying relay.
//!
//! All retry schedules draw jitter from the deterministic
//! [`crate::util::rng::Rng`], so chaos runs driven by
//! [`crate::http::FaultPlan`] replay exactly.

pub mod client;
pub mod encoding;
pub mod manifest;
pub mod publisher;
pub mod server;
pub mod store;
pub mod tree;

pub use client::{DownloadReport, ShardcastClient};
pub use encoding::{decode_delta, dequantize_q8, encode_delta, quantize_q8};
pub use manifest::Manifest;
pub use publisher::{BroadcastEncoding, BroadcastRecord, Broadcaster};
pub use server::{Origin, Relay};
pub use store::Store;
pub use tree::{plan_tree, reform, RelayPeer, TreePlan};
