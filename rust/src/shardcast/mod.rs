//! SHARDCAST (paper §2.2): HTTP tree-topology broadcast of policy weights
//! from the training node to decentralized inference workers — sharded,
//! pipelined, checksummed, rate-limited and firewalled.

pub mod client;
pub mod manifest;
pub mod publisher;
pub mod server;
pub mod store;

pub use client::{DownloadReport, ShardcastClient};
pub use manifest::Manifest;
pub use publisher::{BroadcastRecord, Broadcaster};
pub use server::{Origin, Relay};
pub use store::Store;
