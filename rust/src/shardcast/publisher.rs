//! Asynchronous checkpoint publishing (§3.2): a background broadcast
//! thread that overlaps SHARDCAST distribution with the next training
//! step. The trainer enqueues `(step, payload)` and immediately returns to
//! training; the broadcaster shards + publishes to the origin store, then
//! waits for the relay tier to finish mirroring, recording per-checkpoint
//! timings so the pipeline's true overlap can be measured (Fig 6 / §4.2).

//! Encoding-aware publishing: [`Broadcaster::start_with_encoding`] can
//! quantize each checkpoint ([`super::encoding::quantize_q8`]) *before*
//! sharding — so the published blob IS the quantized payload and every
//! checksum in the manifest covers exactly what travels — and/or attach
//! per-shard delta wires against the previously published step (INTELLECT-1
//! style egress reduction: most weights barely move between RL steps).

use std::sync::mpsc::{sync_channel, SyncSender};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use super::encoding::{encode_delta, quantize_q8};
use super::manifest::Manifest;
use super::store::Store;

/// What the broadcast thread does to each payload before publishing.
#[derive(Clone, Copy, Debug, Default)]
pub struct BroadcastEncoding {
    /// Attach per-shard delta wires against the previously published
    /// checkpoint (manifest advertises `base_step`; children missing the
    /// base transparently fall back to full shards).
    pub delta: bool,
    /// Block-quantize the payload (`"q8"`) before sharding. Consumers
    /// dequantize after checksum verification.
    pub quantize: bool,
}

/// Timing record for one broadcast, all timestamps in seconds relative to
/// the broadcaster's epoch (`Broadcaster::start`).
#[derive(Clone, Debug)]
pub struct BroadcastRecord {
    /// Checkpoint version that was broadcast.
    pub step: u64,
    pub bytes: usize,
    /// When the trainer handed the payload over.
    pub enqueued_at: f64,
    /// When the broadcaster started working on it (> enqueued_at when a
    /// previous broadcast was still in flight).
    pub started_at: f64,
    /// When every relay had a complete mirror (or the timeout fired).
    pub completed_at: f64,
    /// Sharding + origin-store publish time.
    pub publish_secs: f64,
    /// Origin-complete -> all relays complete.
    pub mirror_secs: f64,
    /// True when the relay tier did not finish inside the timeout.
    pub timed_out: bool,
}

impl BroadcastRecord {
    /// Wall-clock the broadcast occupied (start -> relays complete).
    pub fn total_secs(&self) -> f64 {
        self.completed_at - self.started_at
    }
}

/// Background checkpoint broadcaster. Dropping it without calling
/// [`Broadcaster::finish`] joins the thread and discards the records.
pub struct Broadcaster {
    /// `(step, payload, enqueued_at_secs)` — the timestamp is stamped on
    /// the sending thread so queue wait is measurable. Bounded: `enqueue`
    /// blocks once `queue_depth` checkpoints are in flight, giving the
    /// trainer backpressure instead of unbounded payload buildup.
    tx: Option<SyncSender<(u64, Vec<u8>, f64)>>,
    handle: Option<JoinHandle<Vec<BroadcastRecord>>>,
    epoch: Instant,
}

impl Broadcaster {
    /// `origin` is the training-side store; `relays` are the stores of the
    /// relay tier whose mirrors gate "broadcast complete". `queue_depth`
    /// bounds in-flight checkpoints (the async level): past it, `enqueue`
    /// blocks rather than letting the trainer run arbitrarily ahead of the
    /// broadcast tier.
    pub fn start(
        origin: Store,
        relays: Vec<Store>,
        shard_bytes: usize,
        mirror_timeout: Duration,
        queue_depth: usize,
    ) -> anyhow::Result<Broadcaster> {
        Broadcaster::start_with_encoding(
            origin,
            relays,
            shard_bytes,
            mirror_timeout,
            queue_depth,
            BroadcastEncoding::default(),
        )
    }

    /// [`Broadcaster::start`] with a non-default payload encoding.
    pub fn start_with_encoding(
        origin: Store,
        relays: Vec<Store>,
        shard_bytes: usize,
        mirror_timeout: Duration,
        queue_depth: usize,
        encoding: BroadcastEncoding,
    ) -> anyhow::Result<Broadcaster> {
        let epoch = Instant::now();
        // The enqueue timestamp rides in the message, stamped on the
        // trainer's thread, so queue wait behind an in-flight broadcast is
        // visible as `started_at - enqueued_at`.
        let (tx, rx) = sync_channel::<(u64, Vec<u8>, f64)>(queue_depth.max(1));
        let handle = std::thread::Builder::new().name("i2-broadcast".into()).spawn(move || {
            let mut records = Vec::new();
            // Previously *published* payload (post-quantize) — the delta
            // base the manifest will advertise.
            let mut prev: Option<(u64, Vec<u8>)> = None;
            while let Ok((step, payload, enqueued_at)) = rx.recv() {
                let started_at = epoch.elapsed().as_secs_f64();
                let t0 = Instant::now();
                // Quantize BEFORE sharding: the published blob is the
                // quantized payload, so the manifest digests cover exactly
                // the bytes on the wire and the §2.2.3 checksum contract
                // holds unchanged on both the delta and full paths.
                let published =
                    if encoding.quantize { quantize_q8(&payload) } else { payload };
                let (mut manifest, shards) =
                    Manifest::build(step, &published, shard_bytes.max(1));
                if encoding.quantize {
                    manifest = manifest.with_encoding("q8");
                }
                match prev.as_ref().filter(|_| encoding.delta) {
                    Some((base_step, base_bytes)) => {
                        let base_shards: Vec<&[u8]> =
                            base_bytes.chunks(shard_bytes.max(1)).collect();
                        let wires: Vec<Vec<u8>> = shards
                            .iter()
                            .enumerate()
                            .map(|(i, s)| {
                                encode_delta(base_shards.get(i).copied().unwrap_or(&[]), s)
                            })
                            .collect();
                        origin.publish_full_with_deltas(
                            manifest.with_base(*base_step),
                            shards,
                            wires,
                        );
                    }
                    None => origin.publish_full(manifest, shards),
                }
                if encoding.delta {
                    prev = Some((step, published.clone()));
                }
                let publish_secs = t0.elapsed().as_secs_f64();
                let deadline = Instant::now() + mirror_timeout;
                let t1 = Instant::now();
                let mut timed_out = false;
                while !relays.iter().all(|r| r.is_complete(step)) {
                    if Instant::now() > deadline {
                        timed_out = true;
                        break;
                    }
                    std::thread::sleep(Duration::from_millis(5));
                }
                records.push(BroadcastRecord {
                    step,
                    bytes: published.len(),
                    enqueued_at,
                    started_at,
                    completed_at: epoch.elapsed().as_secs_f64(),
                    publish_secs,
                    mirror_secs: t1.elapsed().as_secs_f64(),
                    timed_out,
                });
            }
            records
        })?;
        Ok(Broadcaster { tx: Some(tx), handle: Some(handle), epoch })
    }

    /// Instant that `*_at` record fields are relative to.
    pub fn epoch(&self) -> Instant {
        self.epoch
    }

    /// Hand a checkpoint to the background thread; returns immediately.
    pub fn enqueue(&self, step: u64, payload: Vec<u8>) -> anyhow::Result<()> {
        let enqueued_at = self.epoch.elapsed().as_secs_f64();
        self.tx
            .as_ref()
            .expect("broadcaster already finished")
            .send((step, payload, enqueued_at))
            .map_err(|_| anyhow::anyhow!("broadcast thread terminated"))
    }

    /// Close the queue, wait for in-flight broadcasts, return the records.
    pub fn finish(mut self) -> Vec<BroadcastRecord> {
        drop(self.tx.take());
        match self.handle.take().map(JoinHandle::join) {
            Some(Ok(records)) => records,
            Some(Err(_)) => {
                crate::error!("shardcast", "broadcast thread panicked; timing records lost");
                Vec::new()
            }
            None => Vec::new(),
        }
    }
}

impl Drop for Broadcaster {
    fn drop(&mut self) {
        drop(self.tx.take());
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn broadcasts_and_records_timings() {
        let origin = Store::new();
        let relay = Store::new();
        let b = Broadcaster::start(
            origin.clone(),
            vec![relay.clone()],
            1024,
            Duration::from_secs(2),
            2,
        )
        .unwrap();
        // Mirror thread standing in for a relay puller.
        let (o2, r2) = (origin.clone(), relay.clone());
        let mirror = std::thread::spawn(move || {
            let deadline = Instant::now() + Duration::from_secs(5);
            while Instant::now() < deadline {
                for step in o2.versions() {
                    if r2.is_complete(step) {
                        continue;
                    }
                    if let Some(m) = o2.manifest(step) {
                        let n = m.n_shards();
                        r2.publish_manifest(m);
                        for i in 0..n {
                            if let Some(s) = o2.shard(step, i) {
                                r2.put_shard(step, i, s);
                            }
                        }
                    }
                }
                if r2.is_complete(1) && r2.is_complete(2) {
                    break;
                }
                std::thread::sleep(Duration::from_millis(2));
            }
        });
        b.enqueue(1, vec![7u8; 5000]).unwrap();
        b.enqueue(2, vec![8u8; 3000]).unwrap();
        let records = b.finish();
        mirror.join().unwrap();
        assert_eq!(records.len(), 2);
        assert_eq!(records[0].step, 1);
        assert_eq!(records[1].step, 2);
        assert!(!records[0].timed_out && !records[1].timed_out);
        assert!(origin.is_complete(1) && origin.is_complete(2));
        assert!(relay.is_complete(1) && relay.is_complete(2));
        assert_eq!(records[0].bytes, 5000);
        // Timeline sanity: enqueue <= start <= complete, monotone steps.
        for r in &records {
            assert!(r.enqueued_at <= r.started_at + 1e-9);
            assert!(r.started_at <= r.completed_at);
        }
        assert!(records[0].completed_at <= records[1].completed_at);
    }

    #[test]
    fn delta_quantized_broadcast_publishes_wires_and_metadata() {
        // f32-looking payloads, sparsely changed between steps, so both
        // the quantizer and the delta encoder have realistic structure.
        let floats1: Vec<u8> =
            (0..1000u32).flat_map(|i| ((i % 97) as f32 * 0.01).to_le_bytes()).collect();
        let mut floats2 = floats1.clone();
        for chunk in [40usize, 2000] {
            floats2[chunk..chunk + 4].copy_from_slice(&1.5f32.to_le_bytes());
        }
        let origin = Store::new();
        let enc = BroadcastEncoding { delta: true, quantize: true };
        let b = Broadcaster::start_with_encoding(
            origin.clone(),
            Vec::new(),
            1024,
            Duration::from_millis(100),
            2,
            enc,
        )
        .unwrap();
        b.enqueue(1, floats1.clone()).unwrap();
        b.enqueue(2, floats2.clone()).unwrap();
        let records = b.finish();
        assert_eq!(records.len(), 2);

        // Step 1: quantized, no base (nothing to diff against).
        let m1 = origin.manifest(1).unwrap();
        assert_eq!(m1.encoding, "q8");
        assert_eq!(m1.base_step, None);
        // Step 2: quantized AND delta-advertised with wires stored.
        let m2 = origin.manifest(2).unwrap();
        assert_eq!(m2.encoding, "q8");
        assert_eq!(m2.base_step, Some(1));
        assert!(origin.delta(2, 0).is_some());

        // The published blob is exactly quantize_q8(payload): checksums
        // cover the wire bytes, and consumers dequantize after assemble.
        let shards: Vec<Vec<u8>> =
            (0..m2.n_shards()).map(|i| origin.shard(2, i).unwrap().as_ref().clone()).collect();
        let assembled = m2.assemble(&shards).unwrap();
        assert_eq!(assembled, quantize_q8(&floats2));
        let deq = super::super::encoding::dequantize_q8(&assembled).unwrap();
        assert_eq!(deq.len(), floats2.len());

        // Every delta wire decodes back to the exact published shard.
        let base_published = quantize_q8(&floats1);
        let base_shards: Vec<&[u8]> = base_published.chunks(1024).collect();
        for (i, shard) in shards.iter().enumerate() {
            let wire = origin.delta(2, i).unwrap();
            let decoded = super::super::encoding::decode_delta(
                base_shards.get(i).copied().unwrap_or(&[]),
                &wire,
            )
            .unwrap();
            assert_eq!(&decoded, shard, "shard {i} delta wire corrupt");
        }
        // Sparse update: total wire bytes must be far below full size.
        let wire_total: usize = (0..m2.n_shards()).map(|i| origin.delta(2, i).unwrap().len()).sum();
        assert!(wire_total * 2 < assembled.len(), "{wire_total} vs {}", assembled.len());
    }

    #[test]
    fn timeout_is_reported_not_fatal() {
        let origin = Store::new();
        let never_mirrors = Store::new();
        let b = Broadcaster::start(
            origin.clone(),
            vec![never_mirrors],
            256,
            Duration::from_millis(30),
            1,
        )
        .unwrap();
        b.enqueue(3, vec![1u8; 1000]).unwrap();
        let records = b.finish();
        assert_eq!(records.len(), 1);
        assert!(records[0].timed_out);
        // The origin still has the full checkpoint for late pullers.
        assert!(origin.is_complete(3));
    }
}
