//! Run configuration: typed defaults + `key = value` config files +
//! `--key value` CLI overrides (the launcher surface, see README).
//!
//! Swarm pipeline knobs (all overridable as `--knob value`):
//! - `async-level`: asynchrony k; the trainer accepts rollouts from policy
//!   versions in `[current - k, current]` and drops older ones (§3.2).
//! - `batch-timeout-secs`: how long the trainer waits for a full verified
//!   batch before training on what arrived (previously hard-coded 120 s).
//! - `broadcast-timeout-secs`: how long the background broadcaster waits
//!   for the relay tier to mirror a checkpoint before flagging it timed
//!   out (previously a hard-coded 60 s wait on the trainer thread).
//! - `origin-egress-bps`: shaped origin uplink in bytes/sec (0 = unshaped)
//!   so broadcast time is non-trivial like the paper's WAN links (§4.2).
//! - `validator-threads`: CPU-stage fan-out of the TOPLOC validation
//!   pipeline (stages 0–3 run across this many pool threads; <=1 = inline).
//! - `prefill-bucket-tokens`: length-bucket grain for validator prefill
//!   padding, in tokens (0 = the model's TOPLOC commit interval).
//! - `require-signed-submissions`: verify every rollout upload's envelope
//!   signature against the ledger's key registry before any other
//!   validation (stage 0). Default on — the real swarm slashes on proven
//!   attribution only; `--require-signed-submissions false` restores the
//!   legacy trust-the-claimed-address behavior for old fixtures.
//! - `gen-refill`: continuous-batching rollout generation (default on) —
//!   prompts prefill straight into the KV cache via the `prefill_kv_{T}`
//!   ladder, decode lanes refill the step a sequence hits EOS, and GRPO
//!   groups share one prompt forward per refill wave. `--gen-refill
//!   false` runs the static-batch reference path. Per-rollout RNG
//!   streams make the two paths byte-identical on a bit-deterministic
//!   backend (enforced by the scheduler property tests); on real device
//!   kernels they agree up to prefill-vs-decode fp rounding at prompt
//!   positions, which the TOPLOC tolerances absorb. Requires
//!   vectored-`pos` artifacts (`make artifacts`); older artifact sets
//!   fall back to the reference path automatically.
//! - `sampling-rate`: floor fraction of a *proven* node's uploads that
//!   still get full six-stage verification (trust-weighted sampled
//!   validation). 1.0 (default) = verify everything; 0.1 = spot-check a
//!   tenth once a node's clean streak has earned promotion. New, unsigned
//!   or recently-flagged nodes are always fully verified regardless.
//!   Clamped below to `protocol::MIN_SAMPLING_RATE` — a rate of 0 would
//!   size stakes against a verification probability the gate never
//!   actually enforces.
//! - `trust-promotion-streak`: consecutive fully-verified clean
//!   submissions a node needs before its verification probability starts
//!   decaying toward `sampling-rate`; any reject resets the streak (full
//!   re-escalation).
//! - `trust-stake-margin`: safety factor on the minimum stake that keeps
//!   cheating negative-EV at the configured `sampling-rate` (see
//!   `protocol::min_negative_ev_stake`). Workers bond this stake on
//!   joining; a slash forfeits it.
//! - `env-mix`: ordered per-environment task counts for the training
//!   dataset, e.g. `--env-mix math=900,code=100,seq=200,chain=50`
//!   (replaces the old hardcoded `n-math`/`n-code` pair). Env names are
//!   `verifier::Registry` keys; both swarm sides must run the same mix —
//!   the dataset's registry fingerprint enforces the env-set half of that.

use crate::rl::reward::RewardConfig;
use crate::runtime::GrpoHp;
use crate::tasks::dataset::EnvMix;
use crate::util::cli::Args;

#[derive(Clone, Debug)]
pub struct RunConfig {
    /// Model size key under artifacts/ ("nano", "micro", "small", ...).
    pub model: String,
    /// Run seed: every RNG stream (data sampling, generation, fault
    /// injection) derives from it. The sim *also* derives the sampled-
    /// validation commit-reveal secret from it (`coordinator/swarm.rs`) —
    /// acceptable only because swarmlint's `validator-secret` rule proves
    /// no worker-side module can read the derivation; a real deployment
    /// must source that secret from validator-local entropy instead.
    pub seed: u64,
    /// GRPO group size (completions per prompt; paper: 16).
    pub group_size: usize,
    /// Prompt groups per RL step (paper: 256 prompts x 16 = 4096 samples).
    pub prompts_per_step: usize,
    /// Optimizer micro-steps per rollout step (paper: 8).
    pub micro_steps: usize,
    /// Asynchrony level k: rollouts for step s use the policy from s-k
    /// (0 = synchronous, 2 = the paper's decentralized setting; §3.2).
    pub async_level: u64,
    pub rl_steps: u64,
    pub pretrain_steps: u64,
    pub pretrain_lr: f32,
    pub max_new_tokens: usize,
    pub temperature: f32,
    pub hp: GrpoHp,
    pub reward: RewardConfig,
    /// Training-dataset composition: ordered `(env, count)` pairs over the
    /// environment registry (`--env-mix math=400,code=60,...`).
    pub env_mix: EnvMix,
    /// Swarm shape (threaded e2e driver).
    pub n_workers: usize,
    pub n_relays: usize,
    /// Per-node fan-out bound when planning the SHARDCAST relay tree
    /// (`shardcast::plan_tree`); clamped to >= 1.
    pub shardcast_fanout: usize,
    /// Publish per-shard delta wires against the previous checkpoint.
    /// Transport-only: assembled checkpoints are byte-identical, only the
    /// origin's egress shrinks.
    pub delta_encoding: bool,
    /// Simulated per-worker downlink in bytes/sec (0 = unshaped).
    pub worker_ingress_bps: u64,
    /// Simulated origin uplink in bytes/sec (0 = unshaped): makes the
    /// origin -> relay mirror take real time, like the paper's WAN links.
    pub origin_egress_bps: u64,
    /// Trainer-side wait for a full verified batch before training on a
    /// partial one (seconds).
    pub batch_timeout_secs: u64,
    /// Background broadcaster's relay-mirror deadline (seconds).
    pub broadcast_timeout_secs: u64,
    /// TOPLOC validation pipeline: CPU-stage (schema/sanity/termination)
    /// fan-out threads; values <= 1 validate inline on the pipeline thread.
    pub validator_threads: usize,
    /// Validator prefill length-bucket grain in tokens; calls pad to a
    /// multiple of this. 0 = the model's TOPLOC commit interval (so commit
    /// rows always land inside the padded frame).
    pub prefill_bucket_tokens: usize,
    /// Continuous-batching rollout generation (lane refill + prompt
    /// prefill-into-KV + group-shared prompt forwards). Off = the static
    /// reference engine; equivalent outputs either way (bit-identical on
    /// a deterministic backend, fp-rounding-close on device kernels).
    pub gen_refill: bool,
    /// Verify submission-envelope signatures (stage 0) against the
    /// ledger's key registry; slash only on proven attribution. On by
    /// default for the real swarm; turn off for legacy unsigned fixtures.
    pub require_signed_submissions: bool,
    /// Trust-weighted sampled validation: floor fraction of a proven
    /// node's uploads entering the full pipeline. 1.0 disables sampling
    /// (every upload fully verified — the safe default); requires
    /// `require_signed_submissions` (no provable identity, no trust).
    pub sampling_rate: f64,
    /// Clean streak needed before verification probability decays below
    /// 1.0 (`TrustState::verify_probability`); rejects reset it.
    pub trust_promotion_streak: u64,
    /// Safety factor sizing the stake bond that keeps cheating
    /// negative-EV at `sampling_rate` (`min_negative_ev_stake`).
    pub trust_stake_margin: f64,
    pub lr_warmup_steps: u64,
    /// Offline difficulty filter (pass@k band) applied before training.
    pub offline_filter: bool,
    /// Serve mode: decode lanes each worker advertises for user traffic
    /// on its heartbeats (`serving::ServeCapacity`). 0 — the default for
    /// the RL-only swarm — advertises nothing, so the orchestrator never
    /// routes queries and the wire format matches pre-serving builds.
    pub serve_lanes: u32,
}

impl Default for RunConfig {
    fn default() -> Self {
        RunConfig {
            model: "nano".into(),
            seed: 1337,
            group_size: 4,
            prompts_per_step: 8,
            micro_steps: 4,
            async_level: 2,
            rl_steps: 30,
            pretrain_steps: 150,
            pretrain_lr: 3e-3,
            max_new_tokens: 24,
            temperature: 1.0,
            hp: GrpoHp::default(),
            reward: RewardConfig::default(),
            env_mix: EnvMix::of(&[("math", 400), ("code", 60), ("seq", 50), ("chain", 50)]),
            n_workers: 3,
            n_relays: 2,
            shardcast_fanout: 2,
            delta_encoding: false,
            worker_ingress_bps: 0,
            origin_egress_bps: 0,
            batch_timeout_secs: 120,
            broadcast_timeout_secs: 60,
            validator_threads: 4,
            prefill_bucket_tokens: 0,
            gen_refill: true,
            require_signed_submissions: true,
            sampling_rate: 1.0,
            trust_promotion_streak: 8,
            trust_stake_margin: 2.0,
            lr_warmup_steps: 5,
            offline_filter: false,
            serve_lanes: 0,
        }
    }
}

impl RunConfig {
    /// Apply `--key value` CLI overrides (unknown keys are ignored so
    /// harness-specific flags can coexist).
    pub fn apply_args(mut self, a: &Args) -> RunConfig {
        self.model = a.str_or("model", &self.model);
        self.seed = a.u64_or("seed", self.seed);
        self.group_size = a.usize_or("group-size", self.group_size);
        self.prompts_per_step = a.usize_or("prompts-per-step", self.prompts_per_step);
        self.micro_steps = a.usize_or("micro-steps", self.micro_steps);
        self.async_level = a.u64_or("async-level", self.async_level);
        self.rl_steps = a.u64_or("rl-steps", self.rl_steps);
        self.pretrain_steps = a.u64_or("pretrain-steps", self.pretrain_steps);
        self.pretrain_lr = a.f32_or("pretrain-lr", self.pretrain_lr);
        self.max_new_tokens = a.usize_or("max-new", self.max_new_tokens);
        self.temperature = a.f32_or("temperature", self.temperature);
        self.hp.lr = a.f32_or("lr", self.hp.lr);
        self.hp.grad_clip = a.f32_or("grad-clip", self.hp.grad_clip);
        self.hp.eps = a.f32_or("eps", self.hp.eps);
        self.hp.delta = a.f32_or("delta", self.hp.delta);
        self.hp.kl_coef = a.f32_or("kl-coef", self.hp.kl_coef);
        self.hp.ent_coef = a.f32_or("ent-coef", self.hp.ent_coef);
        self.n_workers = a.usize_or("workers", self.n_workers);
        self.n_relays = a.usize_or("relays", self.n_relays);
        self.shardcast_fanout = a.usize_or("shardcast-fanout", self.shardcast_fanout);
        self.delta_encoding = a.bool_or("delta-encoding", self.delta_encoding);
        if let Some(mix) = a.get("env-mix") {
            self.env_mix = EnvMix::parse(mix).expect("--env-mix");
        }
        self.worker_ingress_bps = a.u64_or("worker-ingress-bps", self.worker_ingress_bps);
        self.origin_egress_bps = a.u64_or("origin-egress-bps", self.origin_egress_bps);
        self.batch_timeout_secs = a.u64_or("batch-timeout-secs", self.batch_timeout_secs);
        self.broadcast_timeout_secs = a.u64_or("broadcast-timeout-secs", self.broadcast_timeout_secs);
        self.validator_threads = a.usize_or("validator-threads", self.validator_threads);
        self.prefill_bucket_tokens = a.usize_or("prefill-bucket-tokens", self.prefill_bucket_tokens);
        self.gen_refill = a.bool_or("gen-refill", self.gen_refill);
        self.require_signed_submissions =
            a.bool_or("require-signed-submissions", self.require_signed_submissions);
        // Floor shared with the trust decay and the stake sizing
        // (`protocol::MIN_SAMPLING_RATE`): a configured 0 would make the
        // EV bound reference a verification probability the gate never
        // reaches. The three clamps agree by construction.
        self.sampling_rate = a
            .f64_or("sampling-rate", self.sampling_rate)
            .clamp(crate::protocol::MIN_SAMPLING_RATE, 1.0);
        self.trust_promotion_streak =
            a.u64_or("trust-promotion-streak", self.trust_promotion_streak).max(1);
        self.trust_stake_margin = a.f64_or("trust-stake-margin", self.trust_stake_margin).max(1.0);
        self.serve_lanes = a.u64_or("serve-lanes", u64::from(self.serve_lanes)) as u32;
        if a.has_flag("offline-filter") {
            self.offline_filter = true;
        }
        if a.has_flag("target-short") {
            self.reward = RewardConfig::target_short();
        }
        if a.has_flag("target-long") {
            self.reward = RewardConfig::target_long();
        }
        self
    }

    /// Learning rate with linear warmup (paper: 25 warmup steps).
    pub fn lr_at(&self, step: u64) -> f32 {
        if step < self.lr_warmup_steps {
            self.hp.lr * (step + 1) as f32 / self.lr_warmup_steps as f32
        } else {
            self.hp.lr
        }
    }

    /// Load `key = value` lines from a config file, then CLI on top.
    pub fn from_file(path: &str) -> anyhow::Result<RunConfig> {
        let text = std::fs::read_to_string(path)?;
        let mut argv = Vec::new();
        for line in text.lines() {
            let line = line.split('#').next().unwrap_or("").trim();
            if line.is_empty() {
                continue;
            }
            let (k, v) = line
                .split_once('=')
                .ok_or_else(|| anyhow::anyhow!("bad config line: {line:?}"))?;
            let (k, v) = (k.trim(), v.trim());
            if v == "true" {
                argv.push(format!("--{k}"));
            } else {
                argv.push(format!("--{k}"));
                argv.push(v.to_string());
            }
        }
        Ok(RunConfig::default().apply_args(&Args::parse(argv)))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cli_overrides() {
        let a = Args::parse(
            "--model micro --async-level 4 --lr 0.001 --target-short \
             --batch-timeout-secs 7 --broadcast-timeout-secs 9 --origin-egress-bps 5000 \
             --validator-threads 8 --prefill-bucket-tokens 64 \
             --require-signed-submissions false --gen-refill false \
             --sampling-rate 0.25 --trust-promotion-streak 12 --trust-stake-margin 3.5 \
             --env-mix math=10,seq=5"
                .split_whitespace()
                .map(str::to_string),
        );
        let c = RunConfig::default().apply_args(&a);
        assert_eq!(c.model, "micro");
        assert_eq!(c.env_mix, EnvMix::of(&[("math", 10), ("seq", 5)]));
        // Default mix spans all four standard environments.
        for env in ["math", "code", "seq", "chain"] {
            assert!(RunConfig::default().env_mix.count(env) > 0, "{env}");
        }
        assert_eq!(c.async_level, 4);
        assert!((c.hp.lr - 0.001).abs() < 1e-9);
        assert_eq!(c.reward.targets, vec![16, 32, 48, 64]);
        assert_eq!(c.batch_timeout_secs, 7);
        assert_eq!(c.broadcast_timeout_secs, 9);
        assert_eq!(c.origin_egress_bps, 5000);
        assert_eq!(c.validator_threads, 8);
        assert_eq!(c.prefill_bucket_tokens, 64);
        assert!(!c.require_signed_submissions);
        assert!(!c.gen_refill);
        assert_eq!(c.sampling_rate, 0.25);
        assert_eq!(c.trust_promotion_streak, 12);
        assert_eq!(c.trust_stake_margin, 3.5);
        // Defaults: signatures required, continuous batching on, sampling
        // off (every upload fully verified).
        assert!(RunConfig::default().require_signed_submissions);
        assert!(RunConfig::default().gen_refill);
        assert_eq!(RunConfig::default().sampling_rate, 1.0);
        // Out-of-range knobs are clamped, not trusted.
        let a = Args::parse(
            "--sampling-rate 7.5 --trust-promotion-streak 0 --trust-stake-margin 0.1"
                .split_whitespace()
                .map(str::to_string),
        );
        let c = RunConfig::default().apply_args(&a);
        assert_eq!(c.sampling_rate, 1.0);
        assert_eq!(c.trust_promotion_streak, 1);
        assert_eq!(c.trust_stake_margin, 1.0);
        // Rate 0 ("never verify promoted nodes") clamps up to the shared
        // floor the trust decay and stake sizing also enforce.
        let a = Args::parse("--sampling-rate 0.0".split_whitespace().map(str::to_string));
        let c = RunConfig::default().apply_args(&a);
        assert_eq!(c.sampling_rate, crate::protocol::MIN_SAMPLING_RATE);
    }

    #[test]
    fn warmup_schedule() {
        let c = RunConfig { lr_warmup_steps: 4, ..Default::default() };
        assert!(c.lr_at(0) < c.lr_at(3));
        assert_eq!(c.lr_at(10), c.hp.lr);
    }

    #[test]
    fn config_file_roundtrip() {
        let path = "/tmp/i2_test_cfg.txt";
        std::fs::write(path, "model = micro\nrl-steps = 5\noffline-filter = true\n# comment\n").unwrap();
        let c = RunConfig::from_file(path).unwrap();
        assert_eq!(c.model, "micro");
        assert_eq!(c.rl_steps, 5);
        assert!(c.offline_filter);
    }
}
