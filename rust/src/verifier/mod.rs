//! GENESYS-style reward environments (paper §2.1.3): a registry mapping
//! task kinds to verifiers. Adding an environment = implementing one trait.

use crate::tasks::{dsl, math, Task, TaskKind};

pub trait Environment: Send + Sync {
    fn name(&self) -> &'static str;
    /// Binary verification of a completion against a task.
    fn verify(&self, task: &Task, completion: &str) -> bool;
}

pub struct MathEnv;

impl Environment for MathEnv {
    fn name(&self) -> &'static str {
        "math-symbolic"
    }
    fn verify(&self, task: &Task, completion: &str) -> bool {
        math::verify(task, completion)
    }
}

pub struct CodeEnv;

impl Environment for CodeEnv {
    fn name(&self) -> &'static str {
        "code-unit-tests"
    }
    fn verify(&self, task: &Task, completion: &str) -> bool {
        dsl::verify(task, completion)
    }
}

/// Registry dispatching tasks to environments.
pub struct Registry {
    math: MathEnv,
    code: CodeEnv,
}

impl Default for Registry {
    fn default() -> Self {
        Registry { math: MathEnv, code: CodeEnv }
    }
}

impl Registry {
    pub fn env(&self, kind: TaskKind) -> &dyn Environment {
        match kind {
            TaskKind::Math => &self.math,
            TaskKind::Code => &self.code,
        }
    }

    pub fn verify(&self, task: &Task, completion: &str) -> bool {
        self.env(task.kind).verify(task, completion)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn registry_dispatches() {
        let reg = Registry::default();
        let mut rng = Rng::new(1);
        let mt = math::generate(0, 1, &mut rng);
        let ct = dsl::generate(1, 1, &mut rng);
        assert!(reg.verify(&mt, &mt.answer));
        assert!(reg.verify(&ct, &ct.answer));
        assert!(!reg.verify(&mt, "nonsense"));
        assert_eq!(reg.env(TaskKind::Math).name(), "math-symbolic");
        assert_eq!(reg.env(TaskKind::Code).name(), "code-unit-tests");
    }
}
