//! GENESYS-style reward environments (paper §2.1.3) as a *pluggable
//! registry*: every task domain the swarm trains on is one self-contained
//! [`Environment`] plugin, and "adding an environment = implementing one
//! trait" is literally the integration story — one file implementing
//! [`Environment`], one `register` call (see `tasks::seq` / `tasks::chain`,
//! each added exactly this way).
//!
//! # The lifecycle trait
//!
//! An environment owns its whole task lifecycle:
//!
//! - **generate** — mint task `id` at a difficulty level, writing all
//!   hidden verification state (reference answers, unit tests, generating
//!   rules, ...) into the task's env-owned JSON payload. The only
//!   cross-env payload contract is the `"answer"` key: the reference
//!   completion, used by the pretraining corpus and by tests.
//! - **verify** — binary-reward check of a completion against the task,
//!   reading whatever hidden state `generate` stashed in the payload.
//! - **eval** — `eval_difficulties` derives the env's held-out eval suite
//!   (`tasks::eval::Suite::for_env`), disjoint from training by seed.
//! - **corrupt_answer** — pretraining-corpus noise (`coordinator::pretrain`
//!   renders a deliberately noisy worked-solutions corpus).
//!
//! # Determinism contract
//!
//! `generate` must be a pure function of `(id, difficulty, rng)`: workers
//! and validators independently rebuild the *entire dataset* from a seed
//! and an env mix, and §2.3.3 sample determinism is slashable — if the two
//! sides disagreed about what task 17 is, an honest worker would be
//! slashed for "lying" about rewards. The [`Registry::fingerprint`] makes
//! a registry mismatch *detectable instead of exploitable*: it hashes the
//! ordered env set (name, version, difficulty surface), both
//! `tasks::dataset::Dataset` and the validation pipeline carry it, and
//! construction fails fast on a mismatch before anything can be slashed.
//! Bump [`Environment::version`] on any change to generation or
//! verification semantics.
//!
//! # Adding an environment
//!
//! ```ignore
//! struct MyEnv;
//! impl Environment for MyEnv {
//!     fn name(&self) -> &'static str { "my-env" }
//!     fn max_difficulty(&self) -> u8 { 3 }
//!     fn generate(&self, id: u64, d: u8, rng: &mut Rng) -> Task { ... }
//!     fn verify(&self, task: &Task, completion: &str) -> bool { ... }
//! }
//! let mut reg = Registry::standard();
//! reg.register(Box::new(MyEnv))?;   // now `--env-mix my-env=200,...`
//! ```

use std::collections::BTreeMap;

use sha2::{Digest, Sha256};

use crate::tasks::Task;
use crate::util::rng::Rng;

/// One pluggable task domain: generation, verification, eval derivation
/// and corpus noise in a single object. See the module docs for the
/// determinism contract.
pub trait Environment: Send + Sync {
    /// Registry key (the `--env-mix` name). Short, stable, unique.
    fn name(&self) -> &'static str;

    /// Human-readable description for tables and logs.
    fn description(&self) -> &'static str {
        self.name()
    }

    /// Highest difficulty level `generate` understands (0 = easiest).
    /// Requests above this are clamped by the dataset builder.
    fn max_difficulty(&self) -> u8;

    /// Semantic version folded into the registry fingerprint. Bump on
    /// *any* change to generation or verification behavior — two parties
    /// running different task semantics must not fingerprint-match.
    fn version(&self) -> u32 {
        1
    }

    /// Mint task `id` at `difficulty`, drawing randomness only from `rng`.
    /// All hidden verification state goes into the task payload, which
    /// must contain the reference completion under `"answer"`.
    fn generate(&self, id: u64, difficulty: u8, rng: &mut Rng) -> Task;

    /// Binary verification of a completion against a task (§3.1.1:
    /// deliberately no partial credit).
    fn verify(&self, task: &Task, completion: &str) -> bool;

    /// Difficulty ladder of the env's derived held-out eval suite
    /// (`tasks::eval::Suite::for_env`). Default: the top two levels.
    fn eval_difficulties(&self) -> Vec<u8> {
        let top = self.max_difficulty();
        if top == 0 {
            vec![0]
        } else {
            vec![top - 1, top]
        }
    }

    /// Corrupt a reference answer for the noisy pretraining corpus.
    /// Default: perturb integers, reverse anything else.
    fn corrupt_answer(&self, answer: &str, rng: &mut Rng) -> String {
        match answer.parse::<i64>() {
            Ok(v) => (v + 1 + rng.range(0, 9) as i64).to_string(),
            Err(_) => answer.chars().rev().collect(),
        }
    }
}

/// Dynamic, deterministically-ordered collection of environments: the
/// single dispatch point for every task touch in the system (dataset
/// assembly, rollout rewards, TOPLOC reward re-verification, eval suites,
/// pretraining corpus noise).
///
/// Registration order is part of the identity: [`Registry::fingerprint`]
/// hashes the *ordered* env list, so two parties that register the same
/// envs in a different order provably differ (their datasets would too —
/// the mix iterates envs by name, but ids and rng state interleave).
pub struct Registry {
    envs: Vec<Box<dyn Environment>>,
    by_name: BTreeMap<&'static str, usize>,
}

impl Registry {
    /// An empty registry: the starting point for fully custom env sets.
    pub fn empty() -> Registry {
        Registry { envs: Vec::new(), by_name: BTreeMap::new() }
    }

    /// The standard swarm registry, in canonical order: `math`, `code`,
    /// `seq`, `chain`. Workers and validators both construct this, so
    /// their fingerprints match by default.
    pub fn standard() -> Registry {
        let mut r = Registry::empty();
        for env in [
            Box::new(crate::tasks::math::MathEnv) as Box<dyn Environment>,
            Box::new(crate::tasks::dsl::CodeEnv),
            Box::new(crate::tasks::seq::SeqEnv),
            Box::new(crate::tasks::chain::ChainEnv),
        ] {
            // swarmlint: allow(panic-path) — startup-time build over a fixed
            // env list; a duplicate name is a compiled-in bug, not input.
            r.register(env).expect("standard registry has unique names");
        }
        r
    }

    /// Append an environment. Errors on a duplicate name — silently
    /// shadowing an env would change task semantics without changing the
    /// lookup key.
    pub fn register(&mut self, env: Box<dyn Environment>) -> anyhow::Result<()> {
        let name = env.name();
        anyhow::ensure!(
            !self.by_name.contains_key(name),
            "environment {name:?} already registered"
        );
        self.by_name.insert(name, self.envs.len());
        self.envs.push(env);
        Ok(())
    }

    /// String-keyed lookup.
    pub fn get(&self, name: &str) -> Option<&dyn Environment> {
        self.by_name.get(name).map(|&i| self.envs[i].as_ref())
    }

    /// The environment owning `task` (by its env id).
    pub fn env_for(&self, task: &Task) -> Option<&dyn Environment> {
        self.get(task.env)
    }

    /// Registered envs in registration (= fingerprint) order.
    pub fn envs(&self) -> impl Iterator<Item = &dyn Environment> {
        self.envs.iter().map(|e| e.as_ref())
    }

    /// Registered names in registration order.
    pub fn names(&self) -> Vec<&'static str> {
        self.envs.iter().map(|e| e.name()).collect()
    }

    pub fn len(&self) -> usize {
        self.envs.len()
    }

    pub fn is_empty(&self) -> bool {
        self.envs.is_empty()
    }

    /// Generate one task through the named environment (difficulty is
    /// clamped to the env's ladder).
    pub fn generate(
        &self,
        env: &str,
        id: u64,
        difficulty: u8,
        rng: &mut Rng,
    ) -> anyhow::Result<Task> {
        let e = self
            .get(env)
            .ok_or_else(|| anyhow::anyhow!("environment {env:?} not registered"))?;
        Ok(e.generate(id, difficulty.min(e.max_difficulty()), rng))
    }

    /// Verify a completion through the task's owning environment. A task
    /// from an unregistered env verifies as `false` — but a registry that
    /// can produce such tasks is exactly what [`Registry::fingerprint`]
    /// guards against reaching the reward path at all.
    pub fn verify(&self, task: &Task, completion: &str) -> bool {
        match self.env_for(task) {
            Some(env) => env.verify(task, completion),
            None => false,
        }
    }

    /// Identity hash of the ordered env set: name, version and difficulty
    /// surface of every env, in registration order, under a domain-
    /// separation prefix. Two parties whose fingerprints match rebuild
    /// byte-identical datasets from the same `(seed, mix)`; a mismatch is
    /// refused at construction time (dataset / generator / validation
    /// pipeline), long before §2.3.3 sample determinism could slash
    /// anyone over it.
    pub fn fingerprint(&self) -> u64 {
        let mut h = Sha256::new();
        h.update(b"i2-env-registry-v1");
        for env in &self.envs {
            h.update(env.name().as_bytes());
            h.update([0u8]); // name terminator: ("ab","c") != ("a","bc")
            h.update(env.version().to_le_bytes());
            h.update([env.max_difficulty()]);
            let evals = env.eval_difficulties();
            h.update((evals.len() as u32).to_le_bytes());
            h.update(&evals);
        }
        let digest = h.finalize();
        // swarmlint: allow(panic-path) — slicing a sha256 digest (32 bytes)
        // down to 8 is infallible; no untrusted length is involved.
        u64::from_le_bytes(digest[..8].try_into().expect("sha256 >= 8 bytes"))
    }
}

impl Default for Registry {
    fn default() -> Self {
        Registry::standard()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tasks::math::MathEnv;
    use crate::util::json::Json;
    use crate::util::prop;

    #[test]
    fn registry_dispatches_by_env_id() {
        let reg = Registry::standard();
        assert_eq!(reg.names(), vec!["math", "code", "seq", "chain"]);
        let mut rng = Rng::new(1);
        for name in reg.names() {
            let t = reg.generate(name, 7, 1, &mut rng).unwrap();
            assert_eq!(t.env, name);
            assert!(reg.verify(&t, t.answer()), "{t:?}");
            assert!(!reg.verify(&t, "zzz nonsense zzz"), "{t:?}");
        }
        assert!(reg.generate("nope", 0, 0, &mut rng).is_err());
        // A task from an env this registry doesn't know never verifies.
        let mut foreign = reg.generate("math", 0, 0, &mut rng).unwrap();
        foreign.env = "martian";
        assert!(!reg.verify(&foreign, foreign.answer()));
    }

    #[test]
    fn duplicate_registration_rejected() {
        let mut reg = Registry::standard();
        assert!(reg.register(Box::new(MathEnv)).is_err());
        assert_eq!(reg.len(), 4);
    }

    /// Every env honors the payload contract: `"answer"` holds the
    /// reference completion, it verifies, and the payload round-trips
    /// losslessly through JSON text (what makes task state portable).
    #[test]
    fn payload_contract_and_json_roundtrip_for_every_env() {
        let reg = Registry::standard();
        let mut rng = Rng::new(42);
        for env in reg.envs() {
            for d in 0..=env.max_difficulty() {
                for i in 0..20 {
                    let t = env.generate(1000 + i, d, &mut rng);
                    assert!(!t.answer().is_empty(), "{}: no answer in payload", env.name());
                    assert!(env.verify(&t, t.answer()), "{t:?}");
                    let back = Json::parse(&t.payload.to_string()).unwrap();
                    assert_eq!(back, t.payload, "{}: payload not JSON-lossless", env.name());
                }
            }
        }
    }

    #[test]
    fn fingerprint_is_stable_and_sensitive() {
        // Same construction -> same fingerprint (the cross-party match).
        assert_eq!(Registry::standard().fingerprint(), Registry::standard().fingerprint());

        // Different env *set*.
        let mut subset = Registry::empty();
        subset.register(Box::new(MathEnv)).unwrap();
        assert_ne!(subset.fingerprint(), Registry::standard().fingerprint());

        // Different *order*, same set.
        let mut ab = Registry::empty();
        ab.register(Box::new(MathEnv)).unwrap();
        ab.register(Box::new(crate::tasks::dsl::CodeEnv)).unwrap();
        let mut ba = Registry::empty();
        ba.register(Box::new(crate::tasks::dsl::CodeEnv)).unwrap();
        ba.register(Box::new(MathEnv)).unwrap();
        assert_ne!(ab.fingerprint(), ba.fingerprint());

        // Different env *params* (version bump) under the same name.
        struct MathV2;
        impl Environment for MathV2 {
            fn name(&self) -> &'static str {
                "math"
            }
            fn max_difficulty(&self) -> u8 {
                crate::tasks::math::MAX_DIFFICULTY
            }
            fn version(&self) -> u32 {
                2
            }
            fn generate(&self, id: u64, d: u8, rng: &mut Rng) -> Task {
                crate::tasks::math::generate(id, d, rng)
            }
            fn verify(&self, task: &Task, completion: &str) -> bool {
                crate::tasks::math::verify(task, completion)
            }
        }
        let mut v1 = Registry::empty();
        v1.register(Box::new(MathEnv)).unwrap();
        let mut v2 = Registry::empty();
        v2.register(Box::new(MathV2)).unwrap();
        assert_ne!(v1.fingerprint(), v2.fingerprint());
    }

    /// Property: generation is a pure function of `(id, difficulty, rng
    /// state)` — two independently-built registries replay byte-identical
    /// tasks. This is the §2.3.3 slashing precondition at the env level.
    #[test]
    fn prop_generation_deterministic_across_registries() {
        prop::check(
            "env generation deterministic",
            64,
            |rng, _| {
                let names = Registry::standard().names();
                let name = *rng.choice(&names);
                (name, rng.next_u64() % 10_000, rng.usize(8) as u8, rng.next_u64())
            },
            |(name, id, difficulty, seed)| {
                let (a, b) = (Registry::standard(), Registry::standard());
                let ta = a.generate(name, *id, *difficulty, &mut Rng::new(*seed)).unwrap();
                let tb = b.generate(name, *id, *difficulty, &mut Rng::new(*seed)).unwrap();
                prop::ensure_eq(ta.prompt.clone(), tb.prompt.clone(), "prompt")?;
                prop::ensure_eq(
                    ta.payload.to_string(),
                    tb.payload.to_string(),
                    "payload bytes",
                )?;
                prop::ensure_eq(ta.difficulty, tb.difficulty, "difficulty")
            },
        );
    }
}
