//! The Prime Intellect protocol (paper §2.4): ledger, discovery service,
//! orchestrator and worker software — permissionless compute coordination
//! ("a decentralized SLURM").

pub mod discovery;
pub mod identity;
pub mod ledger;
pub mod orchestrator;
pub mod worker;

pub use discovery::{DiscoveryServer, DiscoveryService, NodeInfo};
pub use identity::{Identity, SigCheck};
pub use ledger::{Ledger, LedgerError, Tx};
pub use orchestrator::{NodeStatus, Orchestrator, OrchestratorServer, TaskSpec};
pub use worker::{HardwareSpec, SharedVolume, TaskHandler, Worker};
