//! The Prime Intellect protocol (paper §2.4): ledger, discovery service,
//! gossip membership, orchestrator and worker software — permissionless
//! compute coordination ("a decentralized SLURM").
//!
//! # Gossip membership vs invite authority
//!
//! Two separate trust planes, deliberately not merged:
//!
//! - **Membership is gossiped** ([`gossip`]): who is alive, where, with
//!   what hardware. Signed, TTL'd [`gossip::PeerRecord`]s spread
//!   epidemically between workers, relays and the orchestrator; every
//!   record is verified against the ledger's key registry before entering
//!   a view, and records expire on their subject's injected clock. The
//!   central discovery service degrades to a bootstrap convenience — its
//!   list endpoint counts its own hits ([`DiscoveryService::list_calls`])
//!   precisely so harnesses can prove the swarm converges without it.
//! - **Admission is invited** ([`orchestrator::invite_message`]): knowing
//!   a peer exists grants nothing. Joining the pool still requires an
//!   invite signed by the pool owner's ledger key, validated by the
//!   worker against [`Ledger::pool_owner`] — whether the orchestrator
//!   found the candidate via the token-gated discovery list
//!   ([`Orchestrator::sweep_discovery`]) or via its own gossip view
//!   ([`Orchestrator::sweep_gossip`]). The accepted invite also carries
//!   the orchestrator's gossip URL, so membership bootstrap inherits the
//!   invite signature's trust instead of needing its own.
//!
//! A forged peer record can therefore waste at most one verification per
//! honest hop; it cannot admit a node, redirect traffic (endpoints are
//! under the record signature), or resurrect an expired identity (replays
//! lose to the freshness version and the TTL).
//!
//! # Failure model
//!
//! Nodes are expected to vanish without warning and the control plane to
//! bounce. The protocol layer keeps training *live* (work is never lost,
//! only delayed) and *safe* (honest nodes are never slashed for churn):
//!
//! - **Worker crash mid-task** — the orchestrator's health sweep evicts
//!   nodes whose heartbeats stop and requeues the task they held at the
//!   front of the queue, so another worker picks it up next heartbeat
//!   (`tasks_requeued` counts these).
//! - **Orchestrator restart** — workers treat heartbeat failures as
//!   transient: they track the consecutive-failure streak, log once per
//!   streak, and keep beating. When the orchestrator returns on the same
//!   address, the next heartbeat re-delivers task state with no worker
//!   restart required.
//! - **Eviction of a live node** (e.g. a long GC pause) — the node's next
//!   heartbeat is rejected, but re-registration through discovery +
//!   orchestrator admission brings it back into the pool; eviction is
//!   quarantine, not a ban.
//!
//! Byzantine behavior (bad signatures, forged rollouts) is *not* churn:
//! it goes through the slashing path on the ledger instead.
//!
//! # Serving topology
//!
//! The orchestrator doubles as the serve-mode front door (see
//! [`crate::serving`]): user queries enter through `POST /query` /
//! [`Orchestrator::submit_query`] and wait in a
//! [`crate::serving::ServeRouter`] inside the orchestrator's state lock.
//! No second transport exists — assignment rides the heartbeat/
//! [`TaskSpec`] pull flow as `kind = "serve"` tasks, handed out *ahead
//! of* the regular task queue, and only to nodes whose heartbeat
//! advertised a [`crate::serving::ServeCapacity`] covering the query
//! ([`Orchestrator::heartbeat_with_capacity`], sent by
//! [`Worker::start_heartbeat_with_capacity`]). The failure model above
//! extends unchanged: a slashed or evicted holder's in-flight queries
//! requeue at the front (they have waited longest) and deadline
//! accounting runs on the orchestrator's injected
//! [`crate::serving::SloClock`], never ambient time.

pub mod discovery;
pub mod gossip;
pub mod identity;
pub mod ledger;
pub mod orchestrator;
pub mod worker;

pub use discovery::{DiscoveryServer, DiscoveryService, NodeInfo};
pub use gossip::{GossipAgent, GossipConfig, GossipServer, PeerRecord, PeerRole};
pub use identity::{Identity, SigCheck};
pub use ledger::{min_negative_ev_stake, Ledger, LedgerError, TrustState, Tx, MIN_SAMPLING_RATE};
pub use orchestrator::{NodeStatus, Orchestrator, OrchestratorServer, TaskSpec};
pub use worker::{HardwareSpec, SharedVolume, TaskHandler, Worker};
