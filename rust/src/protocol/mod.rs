//! The Prime Intellect protocol (paper §2.4): ledger, discovery service,
//! orchestrator and worker software — permissionless compute coordination
//! ("a decentralized SLURM").
//!
//! # Failure model
//!
//! Nodes are expected to vanish without warning and the control plane to
//! bounce. The protocol layer keeps training *live* (work is never lost,
//! only delayed) and *safe* (honest nodes are never slashed for churn):
//!
//! - **Worker crash mid-task** — the orchestrator's health sweep evicts
//!   nodes whose heartbeats stop and requeues the task they held at the
//!   front of the queue, so another worker picks it up next heartbeat
//!   (`tasks_requeued` counts these).
//! - **Orchestrator restart** — workers treat heartbeat failures as
//!   transient: they track the consecutive-failure streak, log once per
//!   streak, and keep beating. When the orchestrator returns on the same
//!   address, the next heartbeat re-delivers task state with no worker
//!   restart required.
//! - **Eviction of a live node** (e.g. a long GC pause) — the node's next
//!   heartbeat is rejected, but re-registration through discovery +
//!   orchestrator admission brings it back into the pool; eviction is
//!   quarantine, not a ban.
//!
//! Byzantine behavior (bad signatures, forged rollouts) is *not* churn:
//! it goes through the slashing path on the ledger instead.
//!
//! # Serving topology
//!
//! The orchestrator doubles as the serve-mode front door (see
//! [`crate::serving`]): user queries enter through `POST /query` /
//! [`Orchestrator::submit_query`] and wait in a
//! [`crate::serving::ServeRouter`] inside the orchestrator's state lock.
//! No second transport exists — assignment rides the heartbeat/
//! [`TaskSpec`] pull flow as `kind = "serve"` tasks, handed out *ahead
//! of* the regular task queue, and only to nodes whose heartbeat
//! advertised a [`crate::serving::ServeCapacity`] covering the query
//! ([`Orchestrator::heartbeat_with_capacity`], sent by
//! [`Worker::start_heartbeat_with_capacity`]). The failure model above
//! extends unchanged: a slashed or evicted holder's in-flight queries
//! requeue at the front (they have waited longest) and deadline
//! accounting runs on the orchestrator's injected
//! [`crate::serving::SloClock`], never ambient time.

pub mod discovery;
pub mod identity;
pub mod ledger;
pub mod orchestrator;
pub mod worker;

pub use discovery::{DiscoveryServer, DiscoveryService, NodeInfo};
pub use identity::{Identity, SigCheck};
pub use ledger::{min_negative_ev_stake, Ledger, LedgerError, TrustState, Tx, MIN_SAMPLING_RATE};
pub use orchestrator::{NodeStatus, Orchestrator, OrchestratorServer, TaskSpec};
pub use worker::{HardwareSpec, SharedVolume, TaskHandler, Worker};
