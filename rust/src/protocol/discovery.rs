//! Discovery service (§2.4.1): nodes upload their metadata (hardware,
//! invite endpoint); only the orchestrator — authenticated by token — can
//! list them, so worker addresses stay hidden from other workers
//! (DoS-surface reduction). In-memory store with TTL = the Redis stand-in.
//!
//! With gossip membership ([`super::gossip`]) the list endpoint is a
//! bootstrap convenience, not a dependency: [`DiscoveryService::list_calls`]
//! counts every `GET /nodes` hit so harnesses can *prove* the swarm
//! converged without it. TTL expiry runs on an injected [`Clock`] — test
//! time is advanced, never slept through.

use std::collections::BTreeMap;
use std::sync::{Arc, Mutex};

use crate::http::{HttpServer, Request, Response, ServerConfig};
use crate::util::json::Json;
use crate::util::metrics::Counter;
use crate::util::Clock;

#[derive(Clone, Debug, PartialEq)]
pub struct NodeInfo {
    pub address: u64,
    /// Invite endpoint of the worker's webserver.
    pub endpoint: String,
    /// Simulated hardware metadata (GPU kind, VRAM GiB, uplink Mb/s).
    pub gpu: String,
    pub vram_gb: u64,
    pub uplink_mbps: u64,
    pub registered_ms: u64,
}

struct Inner {
    nodes: BTreeMap<u64, NodeInfo>,
    ttl_ms: u64,
}

#[derive(Clone)]
pub struct DiscoveryService {
    inner: Arc<Mutex<Inner>>,
    pub token: String,
    clock: Clock,
    /// Hits on the central `GET /nodes` list endpoint — the SPOF the
    /// gossip layer exists to remove; tree harnesses assert this stays 0.
    pub list_calls: Arc<Counter>,
}

pub struct DiscoveryServer {
    pub service: DiscoveryService,
    pub server: HttpServer,
}

impl DiscoveryService {
    fn sweep(&self) {
        let now = (self.clock)();
        let mut inner = self.inner.lock().unwrap();
        let ttl = inner.ttl_ms;
        inner.nodes.retain(|_, n| now.saturating_sub(n.registered_ms) < ttl);
    }

    /// Now on the service's injected clock (stamps registrations).
    pub fn now_ms(&self) -> u64 {
        (self.clock)()
    }

    pub fn register(&self, info: NodeInfo) {
        self.inner.lock().unwrap().nodes.insert(info.address, info);
    }

    pub fn list(&self) -> Vec<NodeInfo> {
        self.sweep();
        self.inner.lock().unwrap().nodes.values().cloned().collect()
    }

    pub fn remove(&self, address: u64) {
        self.inner.lock().unwrap().nodes.remove(&address);
    }
}

fn handle(svc: &DiscoveryService, req: &Request) -> Response {
    match (req.method.as_str(), req.path.as_str()) {
        ("POST", "/register") => {
            let Ok(j) = req.json() else { return Response::error(400, "bad json") };
            let g = |k: &str| j.get(k).and_then(Json::as_u64);
            let (Some(address), Some(endpoint)) =
                (g("address"), j.get("endpoint").and_then(Json::as_str))
            else {
                return Response::error(400, "missing fields");
            };
            svc.register(NodeInfo {
                address,
                endpoint: endpoint.to_string(),
                gpu: j.get("gpu").and_then(Json::as_str).unwrap_or("sim").to_string(),
                vram_gb: g("vram_gb").unwrap_or(24),
                uplink_mbps: g("uplink_mbps").unwrap_or(100),
                registered_ms: svc.now_ms(),
            });
            Response::ok("ok")
        }
        ("GET", "/nodes") => {
            // Every hit counts, authorized or not: the gossip-convergence
            // gates assert the swarm never needed this endpoint at all.
            svc.list_calls.inc();
            // Authorized components only (the orchestrator).
            if req.query.get("token").map(String::as_str) != Some(svc.token.as_str()) {
                return Response::error(401, "unauthorized");
            }
            let nodes: Vec<Json> = svc
                .list()
                .into_iter()
                .map(|n| {
                    Json::obj(vec![
                        ("address", n.address.into()),
                        ("endpoint", n.endpoint.into()),
                        ("gpu", n.gpu.into()),
                        ("vram_gb", n.vram_gb.into()),
                        ("uplink_mbps", n.uplink_mbps.into()),
                    ])
                })
                .collect();
            Response::json(&Json::Arr(nodes))
        }
        _ => Response::error(404, "unknown endpoint"),
    }
}

impl DiscoveryServer {
    pub fn start(token: &str, ttl_ms: u64) -> anyhow::Result<DiscoveryServer> {
        DiscoveryServer::start_with_clock(token, ttl_ms, crate::util::real_clock())
    }

    /// [`DiscoveryServer::start`] with an injected clock, so TTL expiry is
    /// testable by advancing time instead of sleeping through it.
    pub fn start_with_clock(
        token: &str,
        ttl_ms: u64,
        clock: Clock,
    ) -> anyhow::Result<DiscoveryServer> {
        let service = DiscoveryService {
            inner: Arc::new(Mutex::new(Inner { nodes: BTreeMap::new(), ttl_ms })),
            token: token.to_string(),
            clock,
            list_calls: Arc::new(Counter::default()),
        };
        let svc = service.clone();
        let server = HttpServer::start(
            ServerConfig { worker_threads: 2, ..Default::default() },
            move |req| handle(&svc, req),
        )?;
        Ok(DiscoveryServer { service, server })
    }

    pub fn url(&self) -> String {
        self.server.url()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::http::HttpClient;

    #[test]
    fn register_list_with_auth() {
        let d = DiscoveryServer::start("sekrit", 60_000).unwrap();
        let c = HttpClient::new("n1");
        let body = Json::obj(vec![
            ("address", 42u64.into()),
            ("endpoint", "http://127.0.0.1:9999".into()),
            ("gpu", "sim-4090".into()),
            ("vram_gb", 24u64.into()),
        ]);
        assert_eq!(c.post_json(&format!("{}/register", d.url()), &body).unwrap().status, 200);
        // Unauthorized list.
        assert_eq!(c.get(&format!("{}/nodes", d.url())).unwrap().status, 401);
        assert_eq!(c.get(&format!("{}/nodes?token=wrong", d.url())).unwrap().status, 401);
        // Authorized list.
        let r = c.get(&format!("{}/nodes?token=sekrit", d.url())).unwrap();
        assert_eq!(r.status, 200);
        let j = Json::parse(std::str::from_utf8(&r.body).unwrap()).unwrap();
        assert_eq!(j.idx(0).unwrap().get("address").unwrap().as_u64().unwrap(), 42);
    }

    #[test]
    fn ttl_expiry_on_injected_clock() {
        // Deterministic: TTL is crossed by *advancing the clock*, not by
        // sleeping and hoping the scheduler cooperates.
        let cell = Arc::new(std::sync::atomic::AtomicU64::new(1_000));
        let c = Arc::clone(&cell);
        let clock: Clock = Arc::new(move || c.load(std::sync::atomic::Ordering::SeqCst));
        let d = DiscoveryServer::start_with_clock("t", 500, clock).unwrap();
        d.service.register(NodeInfo {
            address: 1,
            endpoint: "e".into(),
            gpu: "g".into(),
            vram_gb: 8,
            uplink_mbps: 50,
            registered_ms: d.service.now_ms(),
        });
        assert_eq!(d.service.list().len(), 1);
        // One tick short of the TTL: still listed.
        cell.store(1_499, std::sync::atomic::Ordering::SeqCst);
        assert_eq!(d.service.list().len(), 1);
        // At the TTL boundary: swept.
        cell.store(1_500, std::sync::atomic::Ordering::SeqCst);
        assert!(d.service.list().is_empty());
    }

    #[test]
    fn list_endpoint_hits_are_counted() {
        let d = DiscoveryServer::start("tok", 60_000).unwrap();
        let c = HttpClient::new("counter-probe");
        assert_eq!(d.service.list_calls.get(), 0);
        let _ = c.get(&format!("{}/nodes?token=tok", d.url()));
        let _ = c.get(&format!("{}/nodes?token=wrong", d.url()));
        // Authorized and unauthorized hits both count — the gossip gate
        // cares that nobody *needed* the endpoint, not who was told no.
        assert_eq!(d.service.list_calls.get(), 2);
        // Registration does not touch the list counter.
        let _ = c.post_json(
            &format!("{}/register", d.url()),
            &Json::obj(vec![("address", 9u64.into()), ("endpoint", "e".into())]),
        );
        assert_eq!(d.service.list_calls.get(), 2);
    }
}
