//! Gossip membership (§2.4.1, decentralized): workers, relays and the
//! orchestrator exchange signed, TTL'd peer records peer-to-peer so the
//! swarm converges on a live membership view without the central
//! discovery service's list endpoint being a single point of failure.
//!
//! Epidemic push/pull over the in-tree HTTP stack: each [`GossipAgent`]
//! `tick()` refreshes its own record, picks a seeded-deterministic
//! fan-out of peers from its current view (plus bootstrap seeds — the
//! invite flow hands workers the orchestrator's gossip URL), POSTs its
//! whole live view, and absorbs the responder's view in return.
//!
//! Trust model: every record is signed by its subject over the canonical
//! [`gossip_message`] and verified against the ledger's key registry
//! ([`super::Ledger::check_address_sig`]) before it enters a view —
//! gossip spreads *liveness*, never *authority*. A forged or replayed
//! record dies at the first honest hop; invites remain the orchestrator's
//! signed prerogative ([`super::orchestrator::invite_message`]). Records
//! carry explicit `expires_ms` stamped from the *subject's* injected
//! [`Clock`], so stale entries age out of every view symmetrically and no
//! decision path reads ambient time.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use super::identity::{Identity, SigCheck};
use super::ledger::Ledger;
use crate::http::{HttpClient, HttpServer, Request, Response, ServerConfig};
use crate::util::json::Json;
use crate::util::metrics::Counter;
use crate::util::rng::Rng;
use crate::util::Clock;

/// What a peer *is* in the swarm — drives parent selection (relays feed
/// the tree planner) and invite sweeps (the orchestrator invites workers).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PeerRole {
    Worker,
    Relay,
    Origin,
    Orchestrator,
}

impl PeerRole {
    pub fn as_str(&self) -> &'static str {
        match self {
            PeerRole::Worker => "worker",
            PeerRole::Relay => "relay",
            PeerRole::Origin => "origin",
            PeerRole::Orchestrator => "orchestrator",
        }
    }

    pub fn parse(s: &str) -> Option<PeerRole> {
        match s {
            "worker" => Some(PeerRole::Worker),
            "relay" => Some(PeerRole::Relay),
            "origin" => Some(PeerRole::Origin),
            "orchestrator" => Some(PeerRole::Orchestrator),
            _ => None,
        }
    }
}

/// Canonical signing payload for a peer record. Everything that matters
/// is under the signature: endpoint/gossip URLs (no traffic redirection),
/// hardware claims (no inflating your way into hub duty), version +
/// expiry (no replaying an old record to resurrect a dead peer).
pub fn gossip_message(
    address: u64,
    endpoint: &str,
    gossip_url: &str,
    role: PeerRole,
    uplink_mbps: u64,
    vram_gb: u64,
    version: u64,
    expires_ms: u64,
) -> Vec<u8> {
    format!(
        "gossip:{address}:{endpoint}:{gossip_url}:{}:{uplink_mbps}:{vram_gb}:{version}:{expires_ms}",
        role.as_str()
    )
    .into_bytes()
}

/// One signed, TTL'd membership claim: "`address` is alive, reachable at
/// `endpoint` (service) / `gossip` (membership plane), with this
/// hardware, until `expires_ms`".
#[derive(Clone, Debug, PartialEq)]
pub struct PeerRecord {
    pub address: u64,
    /// Service endpoint: invite URL for workers, shardcast URL for
    /// relays/origin, API URL for the orchestrator.
    pub endpoint: String,
    /// Where this peer's own gossip agent listens.
    pub gossip: String,
    pub role: PeerRole,
    /// Advertised hardware (§2.4.1) — feeds the tree planner's
    /// parent-selection score and the orchestrator's admission filter.
    pub uplink_mbps: u64,
    pub vram_gb: u64,
    /// Monotone per-subject freshness counter; newer wins in every view.
    pub version: u64,
    /// Absolute expiry on the subject's clock.
    pub expires_ms: u64,
    pub sig: [u8; 32],
}

impl PeerRecord {
    fn message(&self) -> Vec<u8> {
        gossip_message(
            self.address,
            &self.endpoint,
            &self.gossip,
            self.role,
            self.uplink_mbps,
            self.vram_gb,
            self.version,
            self.expires_ms,
        )
    }

    pub fn verify(&self, ledger: &Ledger) -> bool {
        ledger.check_address_sig(self.address, &self.message(), &self.sig) == SigCheck::Valid
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("address", self.address.into()),
            ("endpoint", self.endpoint.clone().into()),
            ("gossip", self.gossip.clone().into()),
            ("role", self.role.as_str().into()),
            ("uplink_mbps", self.uplink_mbps.into()),
            ("vram_gb", self.vram_gb.into()),
            ("version", self.version.into()),
            ("expires_ms", self.expires_ms.into()),
            ("sig", Json::hex(&self.sig)),
        ])
    }

    pub fn from_json(j: &Json) -> anyhow::Result<PeerRecord> {
        let g = |k: &str| j.get(k).and_then(Json::as_u64);
        let s = |k: &str| j.get(k).and_then(Json::as_str).map(str::to_string);
        let sig_bytes = j
            .get("sig")
            .and_then(Json::as_hex_bytes)
            .ok_or_else(|| anyhow::anyhow!("missing sig"))?;
        let sig: [u8; 32] =
            sig_bytes.try_into().map_err(|_| anyhow::anyhow!("bad sig length"))?;
        Ok(PeerRecord {
            address: g("address").ok_or_else(|| anyhow::anyhow!("missing address"))?,
            endpoint: s("endpoint").ok_or_else(|| anyhow::anyhow!("missing endpoint"))?,
            gossip: s("gossip").unwrap_or_default(),
            role: s("role")
                .as_deref()
                .and_then(PeerRole::parse)
                .ok_or_else(|| anyhow::anyhow!("bad role"))?,
            uplink_mbps: g("uplink_mbps").unwrap_or(0),
            vram_gb: g("vram_gb").unwrap_or(0),
            version: g("version").unwrap_or(0),
            expires_ms: g("expires_ms").unwrap_or(0),
            sig,
        })
    }
}

/// Static half of an agent's own advertisement.
#[derive(Clone, Debug)]
pub struct GossipConfig {
    pub role: PeerRole,
    /// Service endpoint to advertise (see [`PeerRecord::endpoint`]).
    pub endpoint: String,
    pub uplink_mbps: u64,
    pub vram_gb: u64,
    /// How long a record stays live without refresh.
    pub ttl_ms: u64,
    /// Peers contacted per `tick` (seeded-deterministic selection).
    pub fanout: usize,
    /// Seed for the fan-out sampling stream.
    pub seed: u64,
}

impl Default for GossipConfig {
    fn default() -> GossipConfig {
        GossipConfig {
            role: PeerRole::Worker,
            endpoint: String::new(),
            uplink_mbps: 100,
            vram_gb: 24,
            ttl_ms: 30_000,
            fanout: 3,
            seed: 0,
        }
    }
}

struct AgentInner {
    identity: Arc<Identity>,
    ledger: Ledger,
    cfg: GossipConfig,
    clock: Clock,
    /// address -> freshest verified record. Guard discipline: snapshot
    /// and drop before any network call or other lock.
    view: Mutex<BTreeMap<u64, PeerRecord>>,
    /// Bootstrap gossip URLs (contacted even before any record names
    /// them — how a freshly invited worker finds the swarm).
    seeds: Mutex<Vec<String>>,
    rng: Mutex<Rng>,
    version: AtomicU64,
    http: HttpClient,
    gossip_url: std::sync::OnceLock<String>,
}

/// Shared-handle gossip participant (clone = same agent).
#[derive(Clone)]
pub struct GossipAgent {
    inner: Arc<AgentInner>,
    /// Records rejected for bad/unknown signatures or being expired on
    /// arrival.
    pub rejected: Arc<Counter>,
    /// Records absorbed into the view (new or fresher version).
    pub absorbed: Arc<Counter>,
}

/// A [`GossipAgent`] plus the HTTP server exposing its `POST /gossip`
/// push/pull endpoint.
pub struct GossipServer {
    pub agent: GossipAgent,
    pub server: HttpServer,
}

impl GossipAgent {
    fn new(identity: Arc<Identity>, ledger: Ledger, cfg: GossipConfig, clock: Clock) -> GossipAgent {
        let seed = cfg.seed ^ identity.address.wrapping_mul(0x6055);
        GossipAgent {
            inner: Arc::new(AgentInner {
                http: HttpClient::new(&format!("gossip-{}", identity.address)),
                identity,
                ledger,
                cfg,
                clock,
                view: Mutex::new(BTreeMap::new()),
                seeds: Mutex::new(Vec::new()),
                rng: Mutex::new(Rng::new(seed)),
                version: AtomicU64::new(0),
                gossip_url: std::sync::OnceLock::new(),
            }),
            rejected: Arc::new(Counter::default()),
            absorbed: Arc::new(Counter::default()),
        }
    }

    pub fn address(&self) -> u64 {
        self.inner.identity.address
    }

    pub fn gossip_url(&self) -> String {
        self.inner.gossip_url.get().cloned().unwrap_or_default()
    }

    /// Add a bootstrap gossip URL (idempotent).
    pub fn add_seed(&self, url: &str) {
        let mut seeds = self.inner.seeds.lock().unwrap();
        if !seeds.iter().any(|s| s == url) {
            seeds.push(url.to_string());
        }
    }

    /// Build + sign this agent's own record, freshly versioned and
    /// expiring `ttl_ms` from the injected clock's now.
    fn self_record(&self) -> PeerRecord {
        let version = self.inner.version.fetch_add(1, Ordering::SeqCst) + 1;
        let expires_ms = (self.inner.clock)() + self.inner.cfg.ttl_ms;
        let gossip = self.gossip_url();
        let msg = gossip_message(
            self.address(),
            &self.inner.cfg.endpoint,
            &gossip,
            self.inner.cfg.role,
            self.inner.cfg.uplink_mbps,
            self.inner.cfg.vram_gb,
            version,
            expires_ms,
        );
        PeerRecord {
            address: self.address(),
            endpoint: self.inner.cfg.endpoint.clone(),
            gossip,
            role: self.inner.cfg.role,
            uplink_mbps: self.inner.cfg.uplink_mbps,
            vram_gb: self.inner.cfg.vram_gb,
            version,
            expires_ms,
            sig: self.inner.identity.sign(&msg),
        }
    }

    /// Verify + merge incoming records. Rejects bad signatures and
    /// records already expired on this agent's clock; otherwise freshest
    /// version wins. Returns how many records changed the view.
    pub fn absorb(&self, records: &[PeerRecord]) -> usize {
        let now = (self.inner.clock)();
        let mut accepted = Vec::new();
        for r in records {
            if r.expires_ms <= now || !r.verify(&self.inner.ledger) {
                self.rejected.inc();
                continue;
            }
            accepted.push(r.clone());
        }
        let mut changed = 0usize;
        let mut view = self.inner.view.lock().unwrap();
        for r in accepted {
            let fresher = view.get(&r.address).map_or(true, |old| r.version > old.version);
            if fresher {
                view.insert(r.address, r);
                changed += 1;
            }
        }
        drop(view);
        self.absorbed.add(changed as u64);
        changed
    }

    /// Sweep expired records and return the live view (self included).
    pub fn live_peers(&self) -> Vec<PeerRecord> {
        let now = (self.inner.clock)();
        let mut view = self.inner.view.lock().unwrap();
        view.retain(|_, r| r.expires_ms > now);
        view.values().cloned().collect()
    }

    /// Live peers holding a given role.
    pub fn peers_with_role(&self, role: PeerRole) -> Vec<PeerRecord> {
        self.live_peers().into_iter().filter(|r| r.role == role).collect()
    }

    /// One epidemic round: refresh own record, pick a seeded fan-out of
    /// targets from the live view + bootstrap seeds, push the whole view,
    /// absorb each response. Returns how many peers were contacted
    /// successfully.
    pub fn tick(&self) -> usize {
        let own = self.self_record();
        self.absorb(&[own]);
        let snapshot = self.live_peers();

        let me = self.gossip_url();
        let mut targets: Vec<String> = snapshot
            .iter()
            .filter(|r| r.address != self.address() && !r.gossip.is_empty())
            .map(|r| r.gossip.clone())
            .collect();
        let seeds = self.inner.seeds.lock().unwrap().clone();
        for s in seeds {
            if !targets.contains(&s) {
                targets.push(s);
            }
        }
        targets.retain(|t| *t != me);
        let fanout = self.inner.cfg.fanout.max(1);
        let picks = {
            let mut rng = self.inner.rng.lock().unwrap();
            if targets.len() > fanout {
                // Partial Fisher-Yates: deterministic in (seed, call no.).
                for i in 0..fanout {
                    let j = i + rng.usize(targets.len() - i);
                    targets.swap(i, j);
                }
                targets.truncate(fanout);
            }
            targets
        };

        let body = Json::obj(vec![(
            "records",
            Json::Arr(snapshot.iter().map(PeerRecord::to_json).collect()),
        )]);
        let mut reached = 0usize;
        for url in picks {
            let Ok(resp) = self.inner.http.post_json(&format!("{url}/gossip"), &body) else {
                continue;
            };
            if resp.status != 200 {
                continue;
            }
            if let Ok(j) = Json::parse(std::str::from_utf8(&resp.body).unwrap_or("")) {
                self.absorb(&parse_records(&j));
            }
            reached += 1;
        }
        reached
    }
}

fn parse_records(j: &Json) -> Vec<PeerRecord> {
    j.get("records")
        .and_then(Json::as_arr)
        .unwrap_or(&[])
        .iter()
        .filter_map(|r| PeerRecord::from_json(r).ok())
        .collect()
}

fn handle(agent: &GossipAgent, req: &Request) -> Response {
    match (req.method.as_str(), req.path.as_str()) {
        ("POST", "/gossip") => {
            let Ok(j) = req.json() else { return Response::error(400, "bad json") };
            agent.absorb(&parse_records(&j));
            let live = agent.live_peers();
            Response::json(&Json::obj(vec![(
                "records",
                Json::Arr(live.iter().map(PeerRecord::to_json).collect()),
            )]))
        }
        _ => Response::error(404, "unknown endpoint"),
    }
}

impl GossipServer {
    pub fn start(
        identity: Arc<Identity>,
        ledger: Ledger,
        cfg: GossipConfig,
        clock: Clock,
    ) -> anyhow::Result<GossipServer> {
        let agent = GossipAgent::new(identity, ledger, cfg, clock);
        let handler_agent = agent.clone();
        let server = HttpServer::start(
            ServerConfig { worker_threads: 2, ..Default::default() },
            move |req| handle(&handler_agent, req),
        )?;
        let _ = agent.inner.gossip_url.set(server.url());
        Ok(GossipServer { agent, server })
    }

    pub fn url(&self) -> String {
        self.server.url()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64 as ClockCell;

    fn fake_clock() -> (Arc<ClockCell>, Clock) {
        let cell = Arc::new(ClockCell::new(1_000));
        let c = Arc::clone(&cell);
        (cell, Arc::new(move || c.load(Ordering::SeqCst)))
    }

    fn agent_on(
        seed: u64,
        ledger: &Ledger,
        role: PeerRole,
        clock: Clock,
    ) -> (Arc<Identity>, GossipServer) {
        let id = Arc::new(Identity::from_seed(seed));
        ledger.register_key(&id);
        let cfg = GossipConfig {
            role,
            endpoint: format!("http://svc-{seed}"),
            uplink_mbps: 100 + seed,
            vram_gb: 24,
            ttl_ms: 10_000,
            fanout: 2,
            seed,
        };
        let gs = GossipServer::start(Arc::clone(&id), ledger.clone(), cfg, clock).unwrap();
        (id, gs)
    }

    #[test]
    fn record_roundtrip_and_signature_gate() {
        let (_, clock) = fake_clock();
        let ledger = Ledger::new();
        let (_, a) = agent_on(1, &ledger, PeerRole::Worker, Arc::clone(&clock));
        let rec = a.agent.self_record();
        assert!(rec.verify(&ledger));
        let parsed =
            PeerRecord::from_json(&Json::parse(&rec.to_json().to_string()).unwrap()).unwrap();
        assert_eq!(parsed, rec);

        // Tampering with any signed field kills the record at verify.
        let mut evil = rec.clone();
        evil.uplink_mbps = 999_999;
        assert!(!evil.verify(&ledger));
        let mut moved = rec.clone();
        moved.endpoint = "http://attacker".into();
        assert!(!moved.verify(&ledger));

        // Unknown signer (never registered) is rejected too.
        let ghost_id = Identity::from_seed(777);
        let msg = gossip_message(ghost_id.address, "e", "g", PeerRole::Worker, 1, 1, 1, 9_999);
        let ghost = PeerRecord {
            address: ghost_id.address,
            endpoint: "e".into(),
            gossip: "g".into(),
            role: PeerRole::Worker,
            uplink_mbps: 1,
            vram_gb: 1,
            version: 1,
            expires_ms: 9_999,
            sig: ghost_id.sign(&msg),
        };
        assert!(!ghost.verify(&ledger));
        let (_, b) = agent_on(2, &ledger, PeerRole::Worker, clock);
        assert_eq!(b.agent.absorb(&[evil, ghost]), 0);
        assert_eq!(b.agent.rejected.get(), 2);
    }

    #[test]
    fn ttl_expiry_is_deterministic_on_injected_clock() {
        let (cell, clock) = fake_clock();
        let ledger = Ledger::new();
        let (_, a) = agent_on(3, &ledger, PeerRole::Relay, Arc::clone(&clock));
        let (_, b) = agent_on(4, &ledger, PeerRole::Worker, clock);
        let rec = a.agent.self_record(); // expires at 1_000 + 10_000
        assert_eq!(b.agent.absorb(&[rec.clone()]), 1);
        assert_eq!(b.agent.live_peers().len(), 1);
        // Advance past expiry: no sleeping, no flakes.
        cell.store(11_001, Ordering::SeqCst);
        assert!(b.agent.live_peers().is_empty());
        // Expired-on-arrival records never enter the view.
        assert_eq!(b.agent.absorb(&[rec]), 0);
        assert_eq!(b.agent.rejected.get(), 1);
    }

    #[test]
    fn newer_version_wins_older_is_ignored() {
        let (_, clock) = fake_clock();
        let ledger = Ledger::new();
        let (_, a) = agent_on(5, &ledger, PeerRole::Worker, Arc::clone(&clock));
        let (_, b) = agent_on(6, &ledger, PeerRole::Worker, clock);
        let v1 = a.agent.self_record();
        let v2 = a.agent.self_record();
        assert!(v2.version > v1.version);
        assert_eq!(b.agent.absorb(&[v2.clone()]), 1);
        // Replaying the stale record cannot roll the view back.
        assert_eq!(b.agent.absorb(&[v1]), 0);
        let view = b.agent.live_peers();
        assert_eq!(view.len(), 1);
        assert_eq!(view[0].version, v2.version);
    }

    #[test]
    fn view_converges_through_a_seed_peer_only() {
        // Star bootstrap: every agent knows only the orchestrator's
        // gossip URL. After a few ticks, everyone must know everyone —
        // with zero calls to any central list endpoint (there is none
        // here to call).
        let (_, clock) = fake_clock();
        let ledger = Ledger::new();
        let (_, hub) = agent_on(10, &ledger, PeerRole::Orchestrator, Arc::clone(&clock));
        let spokes: Vec<(Arc<Identity>, GossipServer)> = (11..15)
            .map(|s| agent_on(s, &ledger, PeerRole::Worker, Arc::clone(&clock)))
            .collect();
        for (_, gs) in &spokes {
            gs.agent.add_seed(&hub.url());
        }
        for _round in 0..4 {
            hub.agent.tick();
            for (_, gs) in &spokes {
                gs.agent.tick();
            }
        }
        let expected = 1 + spokes.len();
        for gs in std::iter::once(&hub).chain(spokes.iter().map(|(_, g)| g)) {
            assert_eq!(
                gs.agent.live_peers().len(),
                expected,
                "agent {} never converged",
                gs.agent.address()
            );
        }
        assert_eq!(hub.agent.peers_with_role(PeerRole::Worker).len(), spokes.len());
    }
}
