//! Worker software (§2.4.1/§2.4.2): detects (simulated) hardware, registers
//! with discovery + ledger, starts a webserver and waits for a signed
//! invite, then heartbeats the orchestrator and executes pulled tasks —
//! the Docker-container lifecycle is a pluggable task handler, and the
//! "shared volume" (persistent weights across restarts) is an in-memory
//! blob store the handler can use.

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use super::identity::{Identity, SigCheck};
use super::ledger::{Ledger, Tx};
use super::orchestrator::{invite_message, TaskSpec};
use crate::http::{HttpClient, HttpServer, Response, ServerConfig};
use crate::rl::rollout_file::Submission;
use crate::util::json::Json;
use crate::util::metrics::Counter;

#[derive(Clone, Debug)]
pub struct HardwareSpec {
    pub gpu: String,
    pub vram_gb: u64,
    pub uplink_mbps: u64,
}

impl HardwareSpec {
    /// "Detect" simulated hardware from the node seed — heterogeneous by
    /// construction, like the paper's community swarm.
    pub fn detect(seed: u64) -> HardwareSpec {
        let mut rng = crate::util::rng::Rng::new(seed ^ 0x9A9D);
        let (gpu, vram) = *rng.choice(&[
            ("sim-3090", 24u64),
            ("sim-4090", 24),
            ("sim-a100", 80),
            ("sim-h100", 80),
            ("sim-3060", 12),
        ]);
        HardwareSpec {
            gpu: gpu.to_string(),
            vram_gb: vram,
            uplink_mbps: 50 + rng.range(0, 950),
        }
    }

    /// Compatibility check performed before registration (§2.4.2).
    pub fn compatible(&self, min_vram_gb: u64) -> bool {
        self.vram_gb >= min_vram_gb
    }
}

/// Shared volume: survives task restarts so checkpoints aren't re-fetched
/// (the paper's key insight about redundant downloads).
#[derive(Clone, Default)]
pub struct SharedVolume {
    blobs: Arc<Mutex<std::collections::BTreeMap<String, Arc<Vec<u8>>>>>,
}

impl SharedVolume {
    pub fn put(&self, key: &str, data: Vec<u8>) {
        self.blobs.lock().unwrap().insert(key.to_string(), Arc::new(data));
    }
    pub fn get(&self, key: &str) -> Option<Arc<Vec<u8>>> {
        self.blobs.lock().unwrap().get(key).cloned()
    }
    pub fn len(&self) -> usize {
        self.blobs.lock().unwrap().len()
    }
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

pub type TaskHandler = dyn Fn(&TaskSpec, &SharedVolume) -> anyhow::Result<String> + Send + Sync;

/// Is `j` a valid invite for `node` into `pool_id` — signed by the pool
/// owner's ledger-registered key over the canonical invite message?
/// `None` on any missing field, unknown pool/key or signature mismatch.
fn invite_authorized(ledger: &Ledger, node: u64, pool_id: u64, j: &Json) -> Option<()> {
    let invite_pool = j.get("pool_id").and_then(Json::as_u64)?;
    if invite_pool != pool_id {
        return None;
    }
    let domain = j.get("domain").and_then(Json::as_str)?;
    let sig: [u8; 32] = j.get("sig")?.as_hex_bytes()?.try_into().ok()?;
    let owner = ledger.pool_owner(invite_pool)?;
    let msg = invite_message(node, invite_pool, domain);
    (ledger.check_address_sig(owner, &msg, &sig) == SigCheck::Valid).then_some(())
}

pub struct Worker {
    pub identity: Identity,
    pub hardware: HardwareSpec,
    pub volume: SharedVolume,
    invite_server: Option<HttpServer>,
    invited: Arc<AtomicBool>,
    /// Gossip bootstrap URL carried by the accepted invite (the
    /// orchestrator's gossip agent) — where this worker's own gossip
    /// agent should aim its first ticks.
    gossip_seed: Arc<Mutex<Option<String>>>,
    stop: Arc<AtomicBool>,
    hb_thread: Option<std::thread::JoinHandle<()>>,
    pub tasks_completed: Arc<std::sync::atomic::AtomicU64>,
    /// Current streak of consecutive failed heartbeats (transport error or
    /// non-200). Resets to 0 on the first success — an orchestrator bounce
    /// shows up as a rise-then-reset, not a dead worker.
    pub hb_consecutive_failures: Arc<AtomicU64>,
    /// All heartbeat failures over the worker's lifetime.
    pub hb_failures_total: Arc<Counter>,
}

impl Worker {
    /// Boot the worker: hardware check, webserver, discovery + ledger
    /// registration. Returns Err if hardware is incompatible.
    pub fn boot(
        identity: Identity,
        ledger: &Ledger,
        pool_id: u64,
        discovery_url: &str,
        min_vram_gb: u64,
    ) -> anyhow::Result<Worker> {
        let hardware = HardwareSpec::detect(identity.address);
        anyhow::ensure!(
            hardware.compatible(min_vram_gb),
            "incompatible hardware: {} ({} GiB VRAM < {min_vram_gb})",
            hardware.gpu,
            hardware.vram_gb
        );
        let invited = Arc::new(AtomicBool::new(false));
        let gossip_seed: Arc<Mutex<Option<String>>> = Arc::new(Mutex::new(None));
        // Invite webserver: the worker doesn't know the orchestrator's
        // endpoint in advance (DoS protection, §2.4.2).
        let inv = Arc::clone(&invited);
        let seed_slot = Arc::clone(&gossip_seed);
        let address = identity.address;
        let invite_ledger = ledger.clone();
        let invite_server = HttpServer::start(
            ServerConfig { worker_threads: 1, ..Default::default() },
            move |req| {
                if req.method == "POST" && req.path == "/invite" {
                    let Ok(j) = req.json() else { return Response::error(400, "bad json") };
                    if j.get("node").and_then(Json::as_u64) != Some(address) {
                        return Response::error(400, "invite for someone else");
                    }
                    // Validate the invite signature on the ledger
                    // (§2.4.2): it must come from the registered key of
                    // the pool's actual owner for *this* pool.
                    if invite_authorized(&invite_ledger, address, pool_id, &j).is_none() {
                        return Response::error(403, "invalid invite signature");
                    }
                    // Membership bootstrap rides the accepted invite: the
                    // orchestrator's gossip URL (if any) is only trusted
                    // because the signature above checked out.
                    if let Some(g) = j.get("gossip").and_then(Json::as_str) {
                        *seed_slot.lock().unwrap() = Some(g.to_string());
                    }
                    inv.store(true, Ordering::SeqCst);
                    return Response::ok("accepted");
                }
                Response::error(404, "x")
            },
        )?;

        // Register with discovery.
        let c = HttpClient::new(&format!("worker-{address}"));
        let body = Json::obj(vec![
            ("address", address.into()),
            ("endpoint", invite_server.url().into()),
            ("gpu", hardware.gpu.clone().into()),
            ("vram_gb", hardware.vram_gb.into()),
            ("uplink_mbps", hardware.uplink_mbps.into()),
        ]);
        let r = c.post_json(&format!("{discovery_url}/register"), &body)?;
        anyhow::ensure!(r.status == 200, "discovery registration failed: {}", r.status);

        // Register on the ledger in parallel.
        ledger.register_key(&identity);
        ledger
            .submit(Tx::Register { pool_id, node: identity.address }, &identity)
            .map_err(|e| anyhow::anyhow!("ledger: {e}"))?;

        Ok(Worker {
            identity,
            hardware,
            volume: SharedVolume::default(),
            invite_server: Some(invite_server),
            invited,
            gossip_seed,
            stop: Arc::new(AtomicBool::new(false)),
            hb_thread: None,
            tasks_completed: Arc::new(std::sync::atomic::AtomicU64::new(0)),
            hb_consecutive_failures: Arc::new(AtomicU64::new(0)),
            hb_failures_total: Arc::new(Counter::default()),
        })
    }

    pub fn is_invited(&self) -> bool {
        self.invited.load(Ordering::SeqCst)
    }

    /// Gossip bootstrap URL from the accepted invite (None until an
    /// invite carrying one arrives).
    pub fn gossip_seed(&self) -> Option<String> {
        self.gossip_seed.lock().unwrap().clone()
    }

    /// The invite webserver's URL (what the worker registered with
    /// discovery; tests probe it directly).
    pub fn endpoint(&self) -> Option<String> {
        self.invite_server.as_ref().map(HttpServer::url)
    }

    /// Sign a rollout submission at upload time (§2.4.1: every API
    /// interaction is signed with the node keypair). The envelope binds
    /// the worker's address, the policy step, the submission index and the
    /// payload digest, so the validator can prove who sent what — and a
    /// replayed envelope ages out with the staleness window.
    pub fn sign_submission(&self, sub: &Submission) -> Vec<u8> {
        sub.encode_signed(&self.identity)
    }

    /// Start the heartbeat loop: poll the orchestrator, execute any pulled
    /// task with `handler`, report completion + logs.
    pub fn start_heartbeat(
        &mut self,
        orchestrator_url: String,
        interval: std::time::Duration,
        handler: Arc<TaskHandler>,
    ) {
        self.start_heartbeat_with_capacity(orchestrator_url, interval, None, handler);
    }

    /// [`Worker::start_heartbeat`], advertising serving capacity on every
    /// beat: a `Some(capacity)` worker tells the orchestrator how many
    /// decode lanes it keeps free for user traffic and the longest
    /// sequence it will serve, making it eligible for routed `kind =
    /// "serve"` tasks (which the handler executes like any other task).
    /// `None` preserves the exact pre-serving wire format.
    pub fn start_heartbeat_with_capacity(
        &mut self,
        orchestrator_url: String,
        interval: std::time::Duration,
        capacity: Option<crate::serving::ServeCapacity>,
        handler: Arc<TaskHandler>,
    ) {
        let stop = Arc::clone(&self.stop);
        let address = self.identity.address;
        let volume = self.volume.clone();
        let completed = Arc::clone(&self.tasks_completed);
        let hb_streak = Arc::clone(&self.hb_consecutive_failures);
        let hb_total = Arc::clone(&self.hb_failures_total);
        let t = std::thread::Builder::new()
            .name(format!("i2-worker-{address}"))
            .spawn(move || {
                let client = HttpClient::new(&format!("worker-{address}"));
                let mut done: Option<u64> = None;
                let mut log: Option<String> = None;
                while !stop.load(Ordering::SeqCst) {
                    let mut body = vec![("node", Json::from(address))];
                    if let Some(cap) = capacity {
                        body.push(("serve_lanes", u64::from(cap.free_lanes).into()));
                        body.push(("serve_max_tokens", u64::from(cap.max_tokens).into()));
                    }
                    if let Some(d) = done.take() {
                        body.push(("task_done", d.into()));
                    }
                    if let Some(l) = log.take() {
                        body.push(("log", l.into()));
                    }
                    let resp = client.post_json(&format!("{orchestrator_url}/heartbeat"), &Json::obj(body));
                    match resp {
                        Ok(r) if r.status == 200 => {
                            hb_streak.store(0, Ordering::SeqCst);
                            if let Ok(j) = Json::parse(std::str::from_utf8(&r.body).unwrap_or("")) {
                                if let Some(task_id) = j.get("task_id").and_then(Json::as_u64) {
                                    let task = TaskSpec {
                                        id: task_id,
                                        kind: j.get("kind").and_then(Json::as_str).unwrap_or("").to_string(),
                                        payload: j.get("payload").cloned().unwrap_or(Json::Null),
                                    };
                                    match handler(&task, &volume) {
                                        Ok(msg) => log = Some(msg),
                                        Err(e) => log = Some(format!("task {task_id} failed: {e}")),
                                    }
                                    done = Some(task_id);
                                    completed.fetch_add(1, Ordering::SeqCst);
                                }
                            }
                        }
                        // The orchestrator being down or refusing us is
                        // transient: keep beating (it may restart on the
                        // same address, or re-invite us after eviction),
                        // log only the first failure of each streak.
                        Ok(r) => {
                            let streak = hb_streak.fetch_add(1, Ordering::SeqCst);
                            hb_total.inc();
                            if streak == 0 {
                                crate::warn!(
                                    "worker",
                                    "node {address}: heartbeat refused (status {}), retrying",
                                    r.status
                                );
                            }
                        }
                        Err(e) => {
                            let streak = hb_streak.fetch_add(1, Ordering::SeqCst);
                            hb_total.inc();
                            if streak == 0 {
                                crate::warn!(
                                    "worker",
                                    "node {address}: heartbeat failed ({e}), retrying"
                                );
                            }
                        }
                    }
                    std::thread::sleep(interval);
                }
            })
            .expect("spawn heartbeat thread");
        self.hb_thread = Some(t);
    }

    pub fn shutdown(&mut self) {
        self.stop.store(true, Ordering::SeqCst);
        if let Some(t) = self.hb_thread.take() {
            let _ = t.join();
        }
        self.invite_server.take();
    }
}

impl Drop for Worker {
    fn drop(&mut self) {
        self.shutdown();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::protocol::discovery::DiscoveryServer;
    use crate::protocol::orchestrator::{Orchestrator, OrchestratorServer};

    fn pool() -> (Ledger, Identity) {
        let ledger = Ledger::new();
        let owner = Identity::from_seed(1);
        ledger.register_key(&owner);
        ledger
            .submit(Tx::CreatePool { domain: "dist-rl".into(), pool_id: 1, owner: owner.address }, &owner)
            .unwrap();
        (ledger, owner)
    }

    #[test]
    fn full_lifecycle_register_invite_task_execute() {
        let (ledger, owner) = pool();
        let discovery = DiscoveryServer::start("tok", 60_000).unwrap();
        let orch = Orchestrator::new(owner, ledger.clone(), 1, 5_000);
        let orch_srv = OrchestratorServer::start(orch.clone()).unwrap();

        let mut worker = Worker::boot(Identity::from_seed(7), &ledger, 1, &discovery.url(), 8).unwrap();
        assert!(!worker.is_invited());
        assert_eq!(ledger.members(1), vec![worker.identity.address]);

        // Orchestrator sweeps discovery and invites.
        assert_eq!(orch.sweep_discovery(&discovery.url(), "tok"), 1);
        assert!(worker.is_invited());

        // Queue a task; worker pulls and executes it via heartbeats.
        orch.create_task("echo", Json::Str("payload!".into()));
        let handler: Arc<TaskHandler> = Arc::new(|task, vol| {
            vol.put("weights", vec![1, 2, 3]);
            Ok(format!("ran {} ({})", task.id, task.kind))
        });
        worker.start_heartbeat(orch_srv.url(), std::time::Duration::from_millis(10), handler);
        let deadline = std::time::Instant::now() + std::time::Duration::from_secs(5);
        while worker.tasks_completed.load(Ordering::SeqCst) == 0 {
            assert!(std::time::Instant::now() < deadline, "task never ran");
            std::thread::sleep(std::time::Duration::from_millis(10));
        }
        // Shared volume persisted; logs reached the orchestrator.
        assert_eq!(worker.volume.get("weights").unwrap().as_ref(), &vec![1, 2, 3]);
        std::thread::sleep(std::time::Duration::from_millis(30));
        assert!(orch.logs(worker.identity.address).iter().any(|l| l.contains("ran 0")));
        worker.shutdown();
    }

    #[test]
    fn incompatible_hardware_rejected() {
        let (ledger, _) = pool();
        let discovery = DiscoveryServer::start("tok", 60_000).unwrap();
        // Demand more VRAM than any simulated GPU has.
        let err = match Worker::boot(Identity::from_seed(2), &ledger, 1, &discovery.url(), 999) {
            Err(e) => e.to_string(),
            Ok(_) => panic!("boot should have failed"),
        };
        assert!(err.contains("incompatible"), "{err}");
    }

    #[test]
    fn forged_invites_rejected_owner_invite_accepted() {
        let (ledger, owner) = pool();
        let discovery = DiscoveryServer::start("tok", 60_000).unwrap();
        let worker = Worker::boot(Identity::from_seed(7), &ledger, 1, &discovery.url(), 8).unwrap();
        let url = worker.endpoint().unwrap();
        let addr = worker.identity.address;
        let c = HttpClient::new("test");
        let body = |sig: &[u8; 32]| {
            Json::obj(vec![
                ("pool_id", 1u64.into()),
                ("domain", "dist-rl".into()),
                ("node", addr.into()),
                ("sig", Json::hex(sig)),
            ])
        };
        // Garbage signature: refused.
        let r = c.post_json(&format!("{url}/invite"), &body(&[0u8; 32])).unwrap();
        assert_eq!(r.status, 403);
        assert!(!worker.is_invited());
        // Registered identity that is not the pool owner: refused.
        let imposter = Identity::from_seed(66);
        ledger.register_key(&imposter);
        let sig = imposter.sign(&invite_message(addr, 1, "dist-rl"));
        let r = c.post_json(&format!("{url}/invite"), &body(&sig)).unwrap();
        assert_eq!(r.status, 403);
        assert!(!worker.is_invited());
        // The pool owner's genuine signature: accepted.
        let sig = owner.sign(&invite_message(addr, 1, "dist-rl"));
        let r = c.post_json(&format!("{url}/invite"), &body(&sig)).unwrap();
        assert_eq!(r.status, 200);
        assert!(worker.is_invited());
    }

    #[test]
    fn worker_survives_orchestrator_restart() {
        let (ledger, owner) = pool();
        let discovery = DiscoveryServer::start("tok", 60_000).unwrap();
        let orch = Orchestrator::new(owner, ledger.clone(), 1, 5_000);
        // Reserve a fixed port (bind-then-drop, no connections made), but
        // do NOT start the orchestrator server yet: the worker beats into
        // a refused port first.
        let addr = {
            let l = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
            l.local_addr().unwrap().to_string()
        };
        let mut worker = Worker::boot(Identity::from_seed(7), &ledger, 1, &discovery.url(), 8).unwrap();
        orch.admit(worker.identity.address);
        let handler: Arc<TaskHandler> = Arc::new(|task, _| Ok(format!("ran {}", task.id)));
        worker.start_heartbeat(
            format!("http://{addr}"),
            std::time::Duration::from_millis(15),
            handler,
        );
        // Failures accumulate while the orchestrator is down; the streak
        // counter exposes them.
        let deadline = std::time::Instant::now() + std::time::Duration::from_secs(5);
        while worker.hb_failures_total.get() < 2 {
            assert!(std::time::Instant::now() < deadline, "no heartbeat failures recorded");
            std::thread::sleep(std::time::Duration::from_millis(10));
        }
        assert!(worker.hb_consecutive_failures.load(Ordering::SeqCst) >= 1);

        // Orchestrator (re)starts on the address the worker already holds;
        // the worker resumes pulling tasks with no restart of its own.
        let _srv = OrchestratorServer::start_on(orch.clone(), &addr).unwrap();
        orch.create_task("echo", Json::Null);
        let deadline = std::time::Instant::now() + std::time::Duration::from_secs(5);
        while worker.tasks_completed.load(Ordering::SeqCst) == 0 {
            assert!(std::time::Instant::now() < deadline, "task never ran after restart");
            std::thread::sleep(std::time::Duration::from_millis(10));
        }
        assert_eq!(worker.hb_consecutive_failures.load(Ordering::SeqCst), 0);
        worker.shutdown();
    }

    #[test]
    fn capacity_advertising_worker_pulls_serve_task() {
        let (ledger, owner) = pool();
        let discovery = DiscoveryServer::start("tok", 60_000).unwrap();
        let orch = Orchestrator::new(owner, ledger.clone(), 1, 5_000);
        let orch_srv = OrchestratorServer::start(orch.clone()).unwrap();
        let mut worker = Worker::boot(Identity::from_seed(7), &ledger, 1, &discovery.url(), 8).unwrap();
        orch.admit(worker.identity.address);
        // A user query is queued before the worker ever beats; the
        // capacity-advertising heartbeat pulls it as a serve task.
        let qid = orch.submit_query(vec![1, 2, 3], 8, 60_000).unwrap();
        let served = Arc::new(Mutex::new(Vec::new()));
        let sv = served.clone();
        let handler: Arc<TaskHandler> = Arc::new(move |task, _| {
            let q = crate::serving::ServeRequest::from_json(&task.payload)
                .ok_or_else(|| anyhow::anyhow!("bad serve payload"))?;
            anyhow::ensure!(task.kind == crate::serving::SERVE_TASK_KIND);
            sv.lock().unwrap().push(q.query_id);
            Ok(format!("served {}", q.query_id))
        });
        worker.start_heartbeat_with_capacity(
            orch_srv.url(),
            std::time::Duration::from_millis(10),
            Some(crate::serving::ServeCapacity { free_lanes: 2, max_tokens: 128 }),
            handler,
        );
        let deadline = std::time::Instant::now() + std::time::Duration::from_secs(5);
        while worker.tasks_completed.load(Ordering::SeqCst) == 0 {
            assert!(std::time::Instant::now() < deadline, "query never served");
            std::thread::sleep(std::time::Duration::from_millis(10));
        }
        assert_eq!(*served.lock().unwrap(), vec![qid]);
        // The completion heartbeat settles deadline accounting.
        let deadline = std::time::Instant::now() + std::time::Duration::from_secs(5);
        while orch.serve_stats().2 == 0 {
            assert!(std::time::Instant::now() < deadline, "completion never reported");
            std::thread::sleep(std::time::Duration::from_millis(10));
        }
        assert_eq!(orch.serve_stats().3, 0, "served within a 60s SLO");
        worker.shutdown();
    }

    #[test]
    fn slashed_node_not_reinvited() {
        let (ledger, owner) = pool();
        let discovery = DiscoveryServer::start("tok", 60_000).unwrap();
        let orch = Orchestrator::new(owner, ledger.clone(), 1, 5_000);
        let worker = Worker::boot(Identity::from_seed(7), &ledger, 1, &discovery.url(), 8).unwrap();
        orch.slash(worker.identity.address, "toploc");
        assert_eq!(orch.sweep_discovery(&discovery.url(), "tok"), 0);
        assert!(!worker.is_invited());
    }
}
