//! Node identities: every contributor and pool owner holds a secret and a
//! derived address used to sign API interactions and ledger transactions
//! (§2.4.1). Signatures are HMAC-SHA256 under the node secret — the
//! in-process stand-in for the paper's on-chain public-key cryptography
//! (the ledger knows every registered secret, playing the role of the
//! public-key registry; see DESIGN.md substitutions).

use hmac::{Hmac, Mac};
use sha2::{Digest, Sha256};

type HmacSha256 = Hmac<Sha256>;

/// Node addresses are floored to 48 bits: they travel through JSON API
/// payloads as numbers, and `util::json` numbers are f64 (exact only up to
/// 2^53). Signatures and other byte blobs must NOT take that route — they
/// go hex-encoded (see `util::json::Json::hex`).
pub const ADDRESS_MASK: u64 = 0xFFFF_FFFF_FFFF;

/// HMAC-SHA256 verification against raw registered key material — what a
/// verifier holding the ledger's address→key registry uses (it has the
/// key bytes, not an [`Identity`]).
pub fn hmac_verify(key: &[u8; 32], msg: &[u8], sig: &[u8; 32]) -> bool {
    let mut mac = HmacSha256::new_from_slice(key).expect("hmac key");
    mac.update(msg);
    let want: [u8; 32] = mac.finalize().into_bytes().into();
    // Constant-time comparison: fold every byte difference instead of
    // short-circuiting at the first mismatch. The verification sites are
    // network-reachable (/submit envelopes, /invite signatures), and a
    // short-circuiting == would hand forgers a byte-at-a-time timing
    // oracle on the MAC.
    want.iter().zip(sig.iter()).fold(0u8, |acc, (a, b)| acc | (a ^ b)) == 0
}

/// Outcome of checking a signature against a key registry. Distinguishing
/// "no such key" from "wrong signature" matters for observability
/// (unregistered senders vs. framing attempts), but neither outcome ever
/// exposes key material to the caller — with HMAC stand-in signatures the
/// verification key IS the signing key, so handing out key bytes would
/// let any registry reader forge "proven" attributions.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SigCheck {
    /// The address has no registered key.
    NoKey,
    /// A key exists but the signature does not verify under it.
    Mismatch,
    /// The signature verifies under the address's registered key.
    Valid,
}

#[derive(Clone, Debug)]
pub struct Identity {
    pub address: u64,
    secret: [u8; 32],
}

impl Identity {
    /// Derive a deterministic identity from a seed (test swarms) — the
    /// address is a hash of the secret, as with real keypairs.
    pub fn from_seed(seed: u64) -> Identity {
        let secret: [u8; 32] = Sha256::digest(seed.to_le_bytes()).into();
        let addr_hash = Sha256::digest(secret);
        let address = u64::from_le_bytes(addr_hash[..8].try_into().unwrap()) & ADDRESS_MASK;
        Identity { address, secret }
    }

    pub fn sign(&self, msg: &[u8]) -> [u8; 32] {
        let mut mac = HmacSha256::new_from_slice(&self.secret).expect("hmac key");
        mac.update(msg);
        mac.finalize().into_bytes().into()
    }

    pub fn verify(&self, msg: &[u8], sig: &[u8; 32]) -> bool {
        hmac_verify(&self.secret, msg, sig)
    }

    pub(crate) fn secret(&self) -> [u8; 32] {
        self.secret
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sign_verify() {
        let id = Identity::from_seed(1);
        let sig = id.sign(b"hello");
        assert!(id.verify(b"hello", &sig));
        assert!(!id.verify(b"hullo", &sig));
        let other = Identity::from_seed(2);
        assert!(!other.verify(b"hello", &sig));
        assert_ne!(id.address, other.address);
    }

    #[test]
    fn deterministic() {
        assert_eq!(Identity::from_seed(9).address, Identity::from_seed(9).address);
    }

    #[test]
    fn addresses_are_json_safe_48_bit() {
        for seed in 0..64 {
            let a = Identity::from_seed(seed).address;
            assert_eq!(a & !ADDRESS_MASK, 0, "address {a:#x} exceeds 48 bits");
            // Exact through an f64 round-trip (the JSON number path).
            assert_eq!((a as f64) as u64, a);
        }
    }

    #[test]
    fn raw_key_verification_matches_identity() {
        let id = Identity::from_seed(4);
        let sig = id.sign(b"payload");
        assert!(hmac_verify(&id.secret(), b"payload", &sig));
        assert!(!hmac_verify(&id.secret(), b"payloaD", &sig));
        assert!(!hmac_verify(&Identity::from_seed(5).secret(), b"payload", &sig));
    }
}
