//! Node identities: every contributor and pool owner holds a secret and a
//! derived address used to sign API interactions and ledger transactions
//! (§2.4.1). Signatures are HMAC-SHA256 under the node secret — the
//! in-process stand-in for the paper's on-chain public-key cryptography
//! (the ledger knows every registered secret, playing the role of the
//! public-key registry; see DESIGN.md substitutions).

use hmac::{Hmac, Mac};
use sha2::{Digest, Sha256};

type HmacSha256 = Hmac<Sha256>;

#[derive(Clone, Debug)]
pub struct Identity {
    pub address: u64,
    secret: [u8; 32],
}

impl Identity {
    /// Derive a deterministic identity from a seed (test swarms) — the
    /// address is a hash of the secret, as with real keypairs.
    pub fn from_seed(seed: u64) -> Identity {
        let secret: [u8; 32] = Sha256::digest(seed.to_le_bytes()).into();
        let addr_hash = Sha256::digest(secret);
        // 48-bit addresses: they travel through JSON (f64-safe up to 2^53).
        let address =
            u64::from_le_bytes(addr_hash[..8].try_into().unwrap()) & 0xFFFF_FFFF_FFFF;
        Identity { address, secret }
    }

    pub fn sign(&self, msg: &[u8]) -> [u8; 32] {
        let mut mac = HmacSha256::new_from_slice(&self.secret).expect("hmac key");
        mac.update(msg);
        mac.finalize().into_bytes().into()
    }

    pub fn verify(&self, msg: &[u8], sig: &[u8; 32]) -> bool {
        self.sign(msg) == *sig
    }

    pub(crate) fn secret(&self) -> [u8; 32] {
        self.secret
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sign_verify() {
        let id = Identity::from_seed(1);
        let sig = id.sign(b"hello");
        assert!(id.verify(b"hello", &sig));
        assert!(!id.verify(b"hullo", &sig));
        let other = Identity::from_seed(2);
        assert!(!other.verify(b"hello", &sig));
        assert_ne!(id.address, other.address);
    }

    #[test]
    fn deterministic() {
        assert_eq!(Identity::from_seed(9).address, Identity::from_seed(9).address);
    }
}
