//! The decentralized ledger (§2.4.1): compute domains and pools, worker
//! registrations, contribution records, slashing — an append-only log of
//! signed transactions with hash chaining. In-process stand-in for the
//! paper's on-chain testnet (DESIGN.md substitutions).

use std::collections::BTreeMap;
use std::sync::{Arc, Mutex};

use sha2::{Digest, Sha256};

use super::identity::{hmac_verify, Identity, SigCheck};

#[derive(Clone, Debug, PartialEq)]
pub enum Tx {
    CreatePool { domain: String, pool_id: u64, owner: u64 },
    Register { pool_id: u64, node: u64 },
    Invite { pool_id: u64, node: u64, orchestrator: u64 },
    Contribution { pool_id: u64, node: u64, units: u64 },
    /// Bond `units` of stake behind future submissions. Forfeited in full
    /// on slash — sized (see [`min_negative_ev_stake`]) so that cheating
    /// is negative-EV even when only a fraction of uploads is verified.
    Stake { pool_id: u64, node: u64, units: u64 },
    Slash { pool_id: u64, node: u64, reason: String },
    Evict { pool_id: u64, node: u64 },
}

impl Tx {
    fn canonical(&self) -> Vec<u8> {
        format!("{self:?}").into_bytes()
    }

    fn signer(&self) -> u64 {
        match self {
            Tx::CreatePool { owner, .. } => *owner,
            Tx::Register { node, .. } => *node,
            Tx::Invite { orchestrator, .. } => *orchestrator,
            Tx::Contribution { node, .. } => *node,
            Tx::Stake { node, .. } => *node,
            Tx::Slash { .. } | Tx::Evict { .. } => 0, // pool owner, resolved below
        }
    }
}

/// Per-(pool, node) verification history driving trust-weighted sampled
/// validation. Pure integers — the verify probability is *derived* from
/// these counters at query time, so the ledger replays deterministically.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct TrustState {
    /// Consecutive fully-verified clean submissions since the last reject
    /// (or since registration). Reset to zero by any reject.
    pub clean_streak: u64,
    /// Lifetime fully-verified clean submissions.
    pub verified_clean: u64,
    /// Lifetime rejects. Nonzero means the node has been flagged at least
    /// once; until its streak re-crosses the promotion threshold it is
    /// back on full verification (re-escalation).
    pub rejects: u64,
}

/// Hard floor on the effective verification probability, shared by
/// everything that reasons about it: [`TrustState::verify_probability`]
/// never returns less than this, [`min_negative_ev_stake`] never sizes a
/// bond for a rate below it, and the config layer clamps `sampling-rate`
/// up to it. One constant, three call sites — so the probability the EV
/// bound assumes is always a probability the gate actually enforces. A
/// configured rate of 0 ("never verify promoted nodes") would otherwise
/// make the trust decay `promotion_streak / clean_streak` the only floor,
/// which decays without bound as the streak grows: the stake sized
/// against `1e-6` would correspond to no real verification probability.
pub const MIN_SAMPLING_RATE: f64 = 1e-3;

impl TrustState {
    /// Probability that this node's next submission is fully verified.
    ///
    /// New, low-trust, or recently-flagged nodes (streak below
    /// `promotion_streak`) are always fully verified. Proven nodes decay
    /// smoothly as `promotion_streak / clean_streak`, floored at
    /// `rate_floor` (the configured `sampling-rate`) — itself floored at
    /// [`MIN_SAMPLING_RATE`], so the probability the stake sizing assumes
    /// is a probability this function can actually return. A reject
    /// zeroes the streak, which re-escalates the node to full
    /// verification until it earns promotion again.
    pub fn verify_probability(&self, rate_floor: f64, promotion_streak: u64) -> f64 {
        let promotion = promotion_streak.max(1);
        if self.clean_streak < promotion {
            return 1.0;
        }
        let decayed = promotion as f64 / self.clean_streak as f64;
        decayed.max(rate_floor.clamp(MIN_SAMPLING_RATE, 1.0))
    }
}

/// Minimum stake (in reward units) that makes cheating negative-EV at the
/// floor verification rate `min_rate`, with safety factor `margin`.
///
/// A cheat that would gain `reward_units` when unverified is caught with
/// probability at least `min_rate` (the sampling floor — trust decay never
/// drops below it, and new/flagged nodes sit at 1.0). Expected value of one
/// cheat: `reward * (1 - p) - stake * p`, negative iff
/// `stake > reward * (1 - p) / p`. We scale that bound by `margin` and add
/// one unit so the inequality is strict even after integer rounding.
///
/// `min_rate` is clamped to the same [`MIN_SAMPLING_RATE`] floor
/// [`TrustState::verify_probability`] enforces, so the `p` in the bound is
/// the worst rate the gate can actually reach — never a fictitious one.
pub fn min_negative_ev_stake(reward_units: u64, min_rate: f64, margin: f64) -> u64 {
    let p = min_rate.clamp(MIN_SAMPLING_RATE, 1.0);
    let bound = reward_units as f64 * (1.0 - p) / p * margin.max(1.0);
    bound.ceil() as u64 + 1
}

#[derive(Clone, Debug)]
pub struct Entry {
    pub seq: u64,
    pub timestamp_ms: u64,
    pub tx: Tx,
    pub signer: u64,
    pub sig: [u8; 32],
    pub prev_hash: [u8; 32],
}

#[derive(Default)]
struct Inner {
    entries: Vec<Entry>,
    /// Registered identities (address -> secret), the "public key" registry.
    keys: BTreeMap<u64, [u8; 32]>,
    pools: BTreeMap<u64, (String, u64)>, // pool -> (domain, owner)
    members: BTreeMap<u64, Vec<u64>>,    // pool -> active nodes
    slashed: BTreeMap<u64, Vec<u64>>,    // pool -> slashed nodes
    contributions: BTreeMap<(u64, u64), u64>, // (pool, node) -> units
    stakes: BTreeMap<(u64, u64), u64>,        // (pool, node) -> bonded units
    forfeits: BTreeMap<(u64, u64), u64>,      // (pool, node) -> stake lost to slashes
    trust: BTreeMap<(u64, u64), TrustState>,  // (pool, node) -> verification history
}

/// Shared-handle ledger.
#[derive(Clone, Default)]
pub struct Ledger {
    inner: Arc<Mutex<Inner>>,
}

#[derive(Debug, PartialEq)]
pub enum LedgerError {
    UnknownSigner(u64),
    BadSignature,
    UnknownPool(u64),
    NotOwner,
    Slashed(u64),
}

impl std::fmt::Display for LedgerError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            LedgerError::UnknownSigner(a) => write!(f, "unknown signer {a}"),
            LedgerError::BadSignature => write!(f, "bad signature"),
            LedgerError::UnknownPool(p) => write!(f, "unknown pool {p}"),
            LedgerError::NotOwner => write!(f, "not pool owner"),
            LedgerError::Slashed(n) => write!(f, "node {n} is slashed from pool"),
        }
    }
}

impl std::error::Error for LedgerError {}

impl Ledger {
    pub fn new() -> Ledger {
        Ledger::default()
    }

    /// Register an identity's key material (account creation).
    pub fn register_key(&self, id: &Identity) {
        self.inner.lock().unwrap().keys.insert(id.address, id.secret());
    }

    /// Verify `sig` over `msg` against `address`'s registered key: the
    /// ledger's key registry playing the public-key-registry role
    /// (§2.4.1). Key material never leaves the ledger — with HMAC
    /// stand-in signatures the registered secret *is* the signing key, so
    /// an accessor returning key bytes would let any registry reader
    /// forge other nodes' signatures (exactly the framing attack envelope
    /// verification exists to close). Used by the TOPLOC validator's
    /// stage 0 and by workers validating signed invites.
    pub fn check_address_sig(&self, address: u64, msg: &[u8], sig: &[u8; 32]) -> SigCheck {
        match self.inner.lock().unwrap().keys.get(&address) {
            None => SigCheck::NoKey,
            Some(key) if hmac_verify(key, msg, sig) => SigCheck::Valid,
            Some(_) => SigCheck::Mismatch,
        }
    }

    /// Owner address of a pool (workers validate that invites come from
    /// the pool's actual owner).
    pub fn pool_owner(&self, pool_id: u64) -> Option<u64> {
        self.inner.lock().unwrap().pools.get(&pool_id).map(|(_, owner)| *owner)
    }

    /// Submit a signed transaction. `signer_override` lets pool owners sign
    /// Slash/Evict.
    pub fn submit(&self, tx: Tx, signer: &Identity) -> Result<u64, LedgerError> {
        let mut inner = self.inner.lock().unwrap();
        let key = inner.keys.get(&signer.address).copied().ok_or(LedgerError::UnknownSigner(signer.address))?;
        // Verify the signature against the registered key (not the caller's
        // claim): an imposter with a different secret fails here.
        let sig = signer.sign(&tx.canonical());
        if !hmac_verify(&key, &tx.canonical(), &sig) {
            return Err(LedgerError::BadSignature);
        }
        // Authorization rules.
        match &tx {
            Tx::CreatePool { owner, .. } => {
                if *owner != signer.address {
                    return Err(LedgerError::BadSignature);
                }
            }
            Tx::Register { pool_id, node }
            | Tx::Contribution { pool_id, node, .. }
            | Tx::Stake { pool_id, node, .. } => {
                if !inner.pools.contains_key(pool_id) {
                    return Err(LedgerError::UnknownPool(*pool_id));
                }
                if *node != signer.address {
                    return Err(LedgerError::BadSignature);
                }
                if inner.slashed.get(pool_id).map(|s| s.contains(node)).unwrap_or(false) {
                    return Err(LedgerError::Slashed(*node));
                }
            }
            Tx::Invite { pool_id, .. } | Tx::Slash { pool_id, .. } | Tx::Evict { pool_id, .. } => {
                let (_, owner) =
                    inner.pools.get(pool_id).ok_or(LedgerError::UnknownPool(*pool_id))?;
                // Invites come from the orchestrator == pool owner here.
                if *owner != signer.address {
                    return Err(LedgerError::NotOwner);
                }
            }
        }
        // Apply state transition.
        match &tx {
            Tx::CreatePool { domain, pool_id, owner } => {
                inner.pools.insert(*pool_id, (domain.clone(), *owner));
            }
            Tx::Register { pool_id, node } => {
                let members = inner.members.entry(*pool_id).or_default();
                if !members.contains(node) {
                    members.push(*node);
                }
            }
            Tx::Invite { .. } => {}
            Tx::Contribution { pool_id, node, units } => {
                *inner.contributions.entry((*pool_id, *node)).or_default() += units;
            }
            Tx::Stake { pool_id, node, units } => {
                *inner.stakes.entry((*pool_id, *node)).or_default() += units;
            }
            Tx::Slash { pool_id, node, .. } => {
                inner.slashed.entry(*pool_id).or_default().push(*node);
                if let Some(m) = inner.members.get_mut(pool_id) {
                    m.retain(|n| n != node);
                }
                // The bonded stake is forfeited in full: this is what makes
                // sampled verification safe (see `min_negative_ev_stake`).
                if let Some(stake) = inner.stakes.remove(&(*pool_id, *node)) {
                    *inner.forfeits.entry((*pool_id, *node)).or_default() += stake;
                }
            }
            Tx::Evict { pool_id, node } => {
                if let Some(m) = inner.members.get_mut(pool_id) {
                    m.retain(|n| n != node);
                }
            }
        }
        let prev_hash = inner
            .entries
            .last()
            .map(|e| Sha256::digest(format!("{:?}{:?}", e.tx, e.sig)).into())
            .unwrap_or([0u8; 32]);
        let seq = inner.entries.len() as u64;
        let signer_addr = if matches!(tx, Tx::Slash { .. } | Tx::Evict { .. } | Tx::Invite { .. }) {
            signer.address
        } else {
            tx.signer()
        };
        inner.entries.push(Entry {
            seq,
            timestamp_ms: crate::util::unix_ms(),
            tx,
            signer: signer_addr,
            sig,
            prev_hash,
        });
        Ok(seq)
    }

    pub fn members(&self, pool_id: u64) -> Vec<u64> {
        self.inner.lock().unwrap().members.get(&pool_id).cloned().unwrap_or_default()
    }

    pub fn is_slashed(&self, pool_id: u64, node: u64) -> bool {
        self.inner.lock().unwrap().slashed.get(&pool_id).map(|s| s.contains(&node)).unwrap_or(false)
    }

    pub fn contribution(&self, pool_id: u64, node: u64) -> u64 {
        self.inner.lock().unwrap().contributions.get(&(pool_id, node)).copied().unwrap_or(0)
    }

    /// Stake currently bonded by `node` in `pool_id` (0 if none, or if it
    /// was forfeited to a slash).
    pub fn stake_of(&self, pool_id: u64, node: u64) -> u64 {
        self.inner.lock().unwrap().stakes.get(&(pool_id, node)).copied().unwrap_or(0)
    }

    /// Stake `node` has lost to slashes in `pool_id` (EV accounting).
    pub fn forfeited(&self, pool_id: u64, node: u64) -> u64 {
        self.inner.lock().unwrap().forfeits.get(&(pool_id, node)).copied().unwrap_or(0)
    }

    /// Verification history of `node` in `pool_id`. Nodes with no history
    /// get the default state (zero streak — always fully verified).
    pub fn trust(&self, pool_id: u64, node: u64) -> TrustState {
        self.inner.lock().unwrap().trust.get(&(pool_id, node)).copied().unwrap_or_default()
    }

    /// Record the outcome of one *fully verified* submission. `clean`
    /// extends the node's streak; a reject zeroes it and bumps the reject
    /// count, which re-escalates the node to full verification. Skipped
    /// (spot-check-exempt) submissions are deliberately NOT recorded: only
    /// verification evidence moves trust, so a node cannot launder trust
    /// through uploads that were never checked.
    pub fn record_verification(&self, pool_id: u64, node: u64, clean: bool) {
        let mut inner = self.inner.lock().unwrap();
        let t = inner.trust.entry((pool_id, node)).or_default();
        if clean {
            t.clean_streak += 1;
            t.verified_clean += 1;
        } else {
            t.clean_streak = 0;
            t.rejects += 1;
        }
    }

    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn entries(&self) -> Vec<Entry> {
        self.inner.lock().unwrap().entries.clone()
    }

    /// Verify the hash chain (audit).
    pub fn verify_chain(&self) -> bool {
        let entries = self.entries();
        let mut prev = [0u8; 32];
        for e in &entries {
            if e.prev_hash != prev {
                return false;
            }
            prev = Sha256::digest(format!("{:?}{:?}", e.tx, e.sig)).into();
        }
        true
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn setup() -> (Ledger, Identity, Identity) {
        let ledger = Ledger::new();
        let owner = Identity::from_seed(1);
        let node = Identity::from_seed(2);
        ledger.register_key(&owner);
        ledger.register_key(&node);
        ledger
            .submit(Tx::CreatePool { domain: "dist-rl".into(), pool_id: 1, owner: owner.address }, &owner)
            .unwrap();
        (ledger, owner, node)
    }

    #[test]
    fn register_and_contribute() {
        let (ledger, _owner, node) = setup();
        ledger.submit(Tx::Register { pool_id: 1, node: node.address }, &node).unwrap();
        assert_eq!(ledger.members(1), vec![node.address]);
        ledger.submit(Tx::Contribution { pool_id: 1, node: node.address, units: 5 }, &node).unwrap();
        ledger.submit(Tx::Contribution { pool_id: 1, node: node.address, units: 3 }, &node).unwrap();
        assert_eq!(ledger.contribution(1, node.address), 8);
        assert!(ledger.verify_chain());
    }

    #[test]
    fn unregistered_signer_rejected() {
        let (ledger, ..) = setup();
        let stranger = Identity::from_seed(99);
        assert_eq!(
            ledger.submit(Tx::Register { pool_id: 1, node: stranger.address }, &stranger),
            Err(LedgerError::UnknownSigner(stranger.address))
        );
    }

    #[test]
    fn cannot_register_for_someone_else() {
        let (ledger, _owner, node) = setup();
        let other = Identity::from_seed(3);
        ledger.register_key(&other);
        assert_eq!(
            ledger.submit(Tx::Register { pool_id: 1, node: node.address }, &other),
            Err(LedgerError::BadSignature)
        );
    }

    #[test]
    fn slashing_requires_owner_and_blocks_reentry() {
        let (ledger, owner, node) = setup();
        ledger.submit(Tx::Register { pool_id: 1, node: node.address }, &node).unwrap();
        // Node cannot slash itself/others.
        assert_eq!(
            ledger.submit(Tx::Slash { pool_id: 1, node: node.address, reason: "x".into() }, &node),
            Err(LedgerError::NotOwner)
        );
        ledger
            .submit(Tx::Slash { pool_id: 1, node: node.address, reason: "toploc".into() }, &owner)
            .unwrap();
        assert!(ledger.is_slashed(1, node.address));
        assert!(ledger.members(1).is_empty());
        // Slashed node cannot re-register.
        assert_eq!(
            ledger.submit(Tx::Register { pool_id: 1, node: node.address }, &node),
            Err(LedgerError::Slashed(node.address))
        );
        assert!(ledger.verify_chain());
    }

    #[test]
    fn address_sig_checks_without_exposing_keys() {
        let (ledger, owner, node) = setup();
        let sig = node.sign(b"msg");
        assert_eq!(ledger.check_address_sig(node.address, b"msg", &sig), SigCheck::Valid);
        // Wrong message or someone else's signature: mismatch, not a leak.
        assert_eq!(ledger.check_address_sig(node.address, b"msG", &sig), SigCheck::Mismatch);
        assert_eq!(
            ledger.check_address_sig(owner.address, b"msg", &sig),
            SigCheck::Mismatch
        );
        // Unregistered address.
        let stranger = Identity::from_seed(99);
        assert_eq!(
            ledger.check_address_sig(stranger.address, b"msg", &stranger.sign(b"msg")),
            SigCheck::NoKey
        );
        assert_eq!(ledger.pool_owner(1), Some(owner.address));
        assert_eq!(ledger.pool_owner(9), None);
    }

    #[test]
    fn trust_decays_from_full_to_floor_with_clean_history() {
        let (ledger, _owner, node) = setup();
        ledger.submit(Tx::Register { pool_id: 1, node: node.address }, &node).unwrap();
        let (floor, promo) = (0.1, 8);
        // New node: full verification.
        assert_eq!(ledger.trust(1, node.address).verify_probability(floor, promo), 1.0);
        // Below the promotion threshold the probability stays pinned at 1.
        for i in 0..promo {
            let p = ledger.trust(1, node.address).verify_probability(floor, promo);
            assert_eq!(p, 1.0, "streak {i}");
            ledger.record_verification(1, node.address, true);
        }
        // At the threshold the decay starts: promo/streak, monotone down.
        let mut prev = ledger.trust(1, node.address).verify_probability(floor, promo);
        assert_eq!(prev, 1.0); // streak == promo -> promo/streak == 1
        for _ in 0..200 {
            ledger.record_verification(1, node.address, true);
            let p = ledger.trust(1, node.address).verify_probability(floor, promo);
            assert!(p <= prev && p >= floor);
            prev = p;
        }
        // Long-proven node sits at the configured floor, never below it.
        assert_eq!(prev, floor);
        let t = ledger.trust(1, node.address);
        assert_eq!(t.verified_clean, promo + 200);
        assert_eq!(t.rejects, 0);
    }

    #[test]
    fn reject_reescalates_to_full_verification() {
        let (ledger, _owner, node) = setup();
        ledger.submit(Tx::Register { pool_id: 1, node: node.address }, &node).unwrap();
        for _ in 0..50 {
            ledger.record_verification(1, node.address, true);
        }
        assert!(ledger.trust(1, node.address).verify_probability(0.1, 8) < 0.2);
        // One reject: streak zeroed, back to full verification.
        ledger.record_verification(1, node.address, false);
        let t = ledger.trust(1, node.address);
        assert_eq!(t.clean_streak, 0);
        assert_eq!(t.rejects, 1);
        assert_eq!(t.verify_probability(0.1, 8), 1.0);
        // It must earn the whole streak again before decaying.
        for _ in 0..7 {
            ledger.record_verification(1, node.address, true);
            assert_eq!(ledger.trust(1, node.address).verify_probability(0.1, 8), 1.0);
        }
    }

    #[test]
    fn stake_bonds_and_is_forfeited_on_slash() {
        let (ledger, owner, node) = setup();
        ledger.submit(Tx::Register { pool_id: 1, node: node.address }, &node).unwrap();
        ledger.submit(Tx::Stake { pool_id: 1, node: node.address, units: 40 }, &node).unwrap();
        ledger.submit(Tx::Stake { pool_id: 1, node: node.address, units: 2 }, &node).unwrap();
        assert_eq!(ledger.stake_of(1, node.address), 42);
        assert_eq!(ledger.forfeited(1, node.address), 0);
        // Nobody can stake on someone else's behalf.
        let other = Identity::from_seed(3);
        ledger.register_key(&other);
        assert_eq!(
            ledger.submit(Tx::Stake { pool_id: 1, node: node.address, units: 1 }, &other),
            Err(LedgerError::BadSignature)
        );
        ledger
            .submit(Tx::Slash { pool_id: 1, node: node.address, reason: "toploc".into() }, &owner)
            .unwrap();
        assert_eq!(ledger.stake_of(1, node.address), 0);
        assert_eq!(ledger.forfeited(1, node.address), 42);
        assert!(ledger.verify_chain());
    }

    #[test]
    fn min_stake_makes_cheating_negative_ev() {
        for &(reward, rate) in &[(1u64, 1.0f64), (1, 0.25), (1, 0.1), (7, 0.25), (100, 0.1)] {
            let stake = min_negative_ev_stake(reward, rate, 2.0);
            // EV of one cheat at the floor catch rate must be negative.
            let ev = reward as f64 * (1.0 - rate) - stake as f64 * rate;
            assert!(ev < 0.0, "reward {reward} rate {rate} stake {stake} ev {ev}");
        }
        // Full verification still demands a nonzero bond (strictness +1).
        assert_eq!(min_negative_ev_stake(10, 1.0, 2.0), 1);
    }

    #[test]
    fn rate_zero_floors_to_a_real_verification_probability() {
        // A configured sampling-rate of 0 must not open a gap between the
        // rate stakes are sized for and the rate the gate enforces: both
        // clamp to the same MIN_SAMPLING_RATE floor.
        let deep = TrustState { clean_streak: u64::MAX, verified_clean: u64::MAX, rejects: 0 };
        let p = deep.verify_probability(0.0, 8);
        assert_eq!(p, MIN_SAMPLING_RATE, "floor at rate 0 must be the shared constant");
        assert_eq!(
            min_negative_ev_stake(100, 0.0, 2.0),
            min_negative_ev_stake(100, MIN_SAMPLING_RATE, 2.0),
            "stake at rate 0 must be sized against the same floor the gate enforces"
        );
        // And the EV bound holds at the probability actually reachable.
        let stake = min_negative_ev_stake(100, 0.0, 2.0);
        let ev = 100.0 * (1.0 - p) - stake as f64 * p;
        assert!(ev < 0.0, "stake {stake} leaves positive EV {ev} at the real floor {p}");
    }

    #[test]
    fn unknown_pool_rejected() {
        let (ledger, _, node) = setup();
        assert_eq!(
            ledger.submit(Tx::Register { pool_id: 7, node: node.address }, &node),
            Err(LedgerError::UnknownPool(7))
        );
    }
}
