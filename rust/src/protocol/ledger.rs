//! The decentralized ledger (§2.4.1): compute domains and pools, worker
//! registrations, contribution records, slashing — an append-only log of
//! signed transactions with hash chaining. In-process stand-in for the
//! paper's on-chain testnet (DESIGN.md substitutions).

use std::collections::BTreeMap;
use std::sync::{Arc, Mutex};

use sha2::{Digest, Sha256};

use super::identity::{hmac_verify, Identity, SigCheck};

#[derive(Clone, Debug, PartialEq)]
pub enum Tx {
    CreatePool { domain: String, pool_id: u64, owner: u64 },
    Register { pool_id: u64, node: u64 },
    Invite { pool_id: u64, node: u64, orchestrator: u64 },
    Contribution { pool_id: u64, node: u64, units: u64 },
    Slash { pool_id: u64, node: u64, reason: String },
    Evict { pool_id: u64, node: u64 },
}

impl Tx {
    fn canonical(&self) -> Vec<u8> {
        format!("{self:?}").into_bytes()
    }

    fn signer(&self) -> u64 {
        match self {
            Tx::CreatePool { owner, .. } => *owner,
            Tx::Register { node, .. } => *node,
            Tx::Invite { orchestrator, .. } => *orchestrator,
            Tx::Contribution { node, .. } => *node,
            Tx::Slash { .. } | Tx::Evict { .. } => 0, // pool owner, resolved below
        }
    }
}

#[derive(Clone, Debug)]
pub struct Entry {
    pub seq: u64,
    pub timestamp_ms: u64,
    pub tx: Tx,
    pub signer: u64,
    pub sig: [u8; 32],
    pub prev_hash: [u8; 32],
}

#[derive(Default)]
struct Inner {
    entries: Vec<Entry>,
    /// Registered identities (address -> secret), the "public key" registry.
    keys: BTreeMap<u64, [u8; 32]>,
    pools: BTreeMap<u64, (String, u64)>, // pool -> (domain, owner)
    members: BTreeMap<u64, Vec<u64>>,    // pool -> active nodes
    slashed: BTreeMap<u64, Vec<u64>>,    // pool -> slashed nodes
    contributions: BTreeMap<(u64, u64), u64>, // (pool, node) -> units
}

/// Shared-handle ledger.
#[derive(Clone, Default)]
pub struct Ledger {
    inner: Arc<Mutex<Inner>>,
}

#[derive(Debug, PartialEq)]
pub enum LedgerError {
    UnknownSigner(u64),
    BadSignature,
    UnknownPool(u64),
    NotOwner,
    Slashed(u64),
}

impl std::fmt::Display for LedgerError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            LedgerError::UnknownSigner(a) => write!(f, "unknown signer {a}"),
            LedgerError::BadSignature => write!(f, "bad signature"),
            LedgerError::UnknownPool(p) => write!(f, "unknown pool {p}"),
            LedgerError::NotOwner => write!(f, "not pool owner"),
            LedgerError::Slashed(n) => write!(f, "node {n} is slashed from pool"),
        }
    }
}

impl std::error::Error for LedgerError {}

impl Ledger {
    pub fn new() -> Ledger {
        Ledger::default()
    }

    /// Register an identity's key material (account creation).
    pub fn register_key(&self, id: &Identity) {
        self.inner.lock().unwrap().keys.insert(id.address, id.secret());
    }

    /// Verify `sig` over `msg` against `address`'s registered key: the
    /// ledger's key registry playing the public-key-registry role
    /// (§2.4.1). Key material never leaves the ledger — with HMAC
    /// stand-in signatures the registered secret *is* the signing key, so
    /// an accessor returning key bytes would let any registry reader
    /// forge other nodes' signatures (exactly the framing attack envelope
    /// verification exists to close). Used by the TOPLOC validator's
    /// stage 0 and by workers validating signed invites.
    pub fn check_address_sig(&self, address: u64, msg: &[u8], sig: &[u8; 32]) -> SigCheck {
        match self.inner.lock().unwrap().keys.get(&address) {
            None => SigCheck::NoKey,
            Some(key) if hmac_verify(key, msg, sig) => SigCheck::Valid,
            Some(_) => SigCheck::Mismatch,
        }
    }

    /// Owner address of a pool (workers validate that invites come from
    /// the pool's actual owner).
    pub fn pool_owner(&self, pool_id: u64) -> Option<u64> {
        self.inner.lock().unwrap().pools.get(&pool_id).map(|(_, owner)| *owner)
    }

    /// Submit a signed transaction. `signer_override` lets pool owners sign
    /// Slash/Evict.
    pub fn submit(&self, tx: Tx, signer: &Identity) -> Result<u64, LedgerError> {
        let mut inner = self.inner.lock().unwrap();
        let key = inner.keys.get(&signer.address).copied().ok_or(LedgerError::UnknownSigner(signer.address))?;
        // Verify the signature against the registered key (not the caller's
        // claim): an imposter with a different secret fails here.
        let sig = signer.sign(&tx.canonical());
        if !hmac_verify(&key, &tx.canonical(), &sig) {
            return Err(LedgerError::BadSignature);
        }
        // Authorization rules.
        match &tx {
            Tx::CreatePool { owner, .. } => {
                if *owner != signer.address {
                    return Err(LedgerError::BadSignature);
                }
            }
            Tx::Register { pool_id, node } | Tx::Contribution { pool_id, node, .. } => {
                if !inner.pools.contains_key(pool_id) {
                    return Err(LedgerError::UnknownPool(*pool_id));
                }
                if *node != signer.address {
                    return Err(LedgerError::BadSignature);
                }
                if inner.slashed.get(pool_id).map(|s| s.contains(node)).unwrap_or(false) {
                    return Err(LedgerError::Slashed(*node));
                }
            }
            Tx::Invite { pool_id, .. } | Tx::Slash { pool_id, .. } | Tx::Evict { pool_id, .. } => {
                let (_, owner) =
                    inner.pools.get(pool_id).ok_or(LedgerError::UnknownPool(*pool_id))?;
                // Invites come from the orchestrator == pool owner here.
                if *owner != signer.address {
                    return Err(LedgerError::NotOwner);
                }
            }
        }
        // Apply state transition.
        match &tx {
            Tx::CreatePool { domain, pool_id, owner } => {
                inner.pools.insert(*pool_id, (domain.clone(), *owner));
            }
            Tx::Register { pool_id, node } => {
                let members = inner.members.entry(*pool_id).or_default();
                if !members.contains(node) {
                    members.push(*node);
                }
            }
            Tx::Invite { .. } => {}
            Tx::Contribution { pool_id, node, units } => {
                *inner.contributions.entry((*pool_id, *node)).or_default() += units;
            }
            Tx::Slash { pool_id, node, .. } => {
                inner.slashed.entry(*pool_id).or_default().push(*node);
                if let Some(m) = inner.members.get_mut(pool_id) {
                    m.retain(|n| n != node);
                }
            }
            Tx::Evict { pool_id, node } => {
                if let Some(m) = inner.members.get_mut(pool_id) {
                    m.retain(|n| n != node);
                }
            }
        }
        let prev_hash = inner
            .entries
            .last()
            .map(|e| Sha256::digest(format!("{:?}{:?}", e.tx, e.sig)).into())
            .unwrap_or([0u8; 32]);
        let seq = inner.entries.len() as u64;
        let signer_addr = if matches!(tx, Tx::Slash { .. } | Tx::Evict { .. } | Tx::Invite { .. }) {
            signer.address
        } else {
            tx.signer()
        };
        inner.entries.push(Entry {
            seq,
            timestamp_ms: crate::util::unix_ms(),
            tx,
            signer: signer_addr,
            sig,
            prev_hash,
        });
        Ok(seq)
    }

    pub fn members(&self, pool_id: u64) -> Vec<u64> {
        self.inner.lock().unwrap().members.get(&pool_id).cloned().unwrap_or_default()
    }

    pub fn is_slashed(&self, pool_id: u64, node: u64) -> bool {
        self.inner.lock().unwrap().slashed.get(&pool_id).map(|s| s.contains(&node)).unwrap_or(false)
    }

    pub fn contribution(&self, pool_id: u64, node: u64) -> u64 {
        self.inner.lock().unwrap().contributions.get(&(pool_id, node)).copied().unwrap_or(0)
    }

    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn entries(&self) -> Vec<Entry> {
        self.inner.lock().unwrap().entries.clone()
    }

    /// Verify the hash chain (audit).
    pub fn verify_chain(&self) -> bool {
        let entries = self.entries();
        let mut prev = [0u8; 32];
        for e in &entries {
            if e.prev_hash != prev {
                return false;
            }
            prev = Sha256::digest(format!("{:?}{:?}", e.tx, e.sig)).into();
        }
        true
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn setup() -> (Ledger, Identity, Identity) {
        let ledger = Ledger::new();
        let owner = Identity::from_seed(1);
        let node = Identity::from_seed(2);
        ledger.register_key(&owner);
        ledger.register_key(&node);
        ledger
            .submit(Tx::CreatePool { domain: "dist-rl".into(), pool_id: 1, owner: owner.address }, &owner)
            .unwrap();
        (ledger, owner, node)
    }

    #[test]
    fn register_and_contribute() {
        let (ledger, _owner, node) = setup();
        ledger.submit(Tx::Register { pool_id: 1, node: node.address }, &node).unwrap();
        assert_eq!(ledger.members(1), vec![node.address]);
        ledger.submit(Tx::Contribution { pool_id: 1, node: node.address, units: 5 }, &node).unwrap();
        ledger.submit(Tx::Contribution { pool_id: 1, node: node.address, units: 3 }, &node).unwrap();
        assert_eq!(ledger.contribution(1, node.address), 8);
        assert!(ledger.verify_chain());
    }

    #[test]
    fn unregistered_signer_rejected() {
        let (ledger, ..) = setup();
        let stranger = Identity::from_seed(99);
        assert_eq!(
            ledger.submit(Tx::Register { pool_id: 1, node: stranger.address }, &stranger),
            Err(LedgerError::UnknownSigner(stranger.address))
        );
    }

    #[test]
    fn cannot_register_for_someone_else() {
        let (ledger, _owner, node) = setup();
        let other = Identity::from_seed(3);
        ledger.register_key(&other);
        assert_eq!(
            ledger.submit(Tx::Register { pool_id: 1, node: node.address }, &other),
            Err(LedgerError::BadSignature)
        );
    }

    #[test]
    fn slashing_requires_owner_and_blocks_reentry() {
        let (ledger, owner, node) = setup();
        ledger.submit(Tx::Register { pool_id: 1, node: node.address }, &node).unwrap();
        // Node cannot slash itself/others.
        assert_eq!(
            ledger.submit(Tx::Slash { pool_id: 1, node: node.address, reason: "x".into() }, &node),
            Err(LedgerError::NotOwner)
        );
        ledger
            .submit(Tx::Slash { pool_id: 1, node: node.address, reason: "toploc".into() }, &owner)
            .unwrap();
        assert!(ledger.is_slashed(1, node.address));
        assert!(ledger.members(1).is_empty());
        // Slashed node cannot re-register.
        assert_eq!(
            ledger.submit(Tx::Register { pool_id: 1, node: node.address }, &node),
            Err(LedgerError::Slashed(node.address))
        );
        assert!(ledger.verify_chain());
    }

    #[test]
    fn address_sig_checks_without_exposing_keys() {
        let (ledger, owner, node) = setup();
        let sig = node.sign(b"msg");
        assert_eq!(ledger.check_address_sig(node.address, b"msg", &sig), SigCheck::Valid);
        // Wrong message or someone else's signature: mismatch, not a leak.
        assert_eq!(ledger.check_address_sig(node.address, b"msG", &sig), SigCheck::Mismatch);
        assert_eq!(
            ledger.check_address_sig(owner.address, b"msg", &sig),
            SigCheck::Mismatch
        );
        // Unregistered address.
        let stranger = Identity::from_seed(99);
        assert_eq!(
            ledger.check_address_sig(stranger.address, b"msg", &stranger.sign(b"msg")),
            SigCheck::NoKey
        );
        assert_eq!(ledger.pool_owner(1), Some(owner.address));
        assert_eq!(ledger.pool_owner(9), None);
    }

    #[test]
    fn unknown_pool_rejected() {
        let (ledger, _, node) = setup();
        assert_eq!(
            ledger.submit(Tx::Register { pool_id: 7, node: node.address }, &node),
            Err(LedgerError::UnknownPool(7))
        );
    }
}
