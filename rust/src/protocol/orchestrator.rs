//! Orchestrator (§2.4.1/§2.4.2): invites discovered nodes into the compute
//! pool (signed invites validated on the ledger), tracks node health via
//! heartbeats with missed-count eviction, and distributes tasks *in
//! response to heartbeats* — the paper's reactive pull-based model.
//!
//! Heartbeats are *membership-gated*: a node the orchestrator never
//! admitted (via the signed-invite sweep) or that the ledger has slashed
//! cannot heartbeat itself into the pool and receive tasks — that would
//! bypass the invite flow entirely. Such heartbeats are refused (HTTP
//! 403) and counted in [`Orchestrator::heartbeats_rejected`].
//!
//! # Serve mode (front-door router)
//!
//! The orchestrator doubles as the serving front door: user queries enter
//! through [`Orchestrator::submit_query`] (HTTP `POST /query`) and land in
//! a [`ServeRouter`] inside the state lock. Workers advertise per-node
//! serving capacity on each heartbeat (`serve_lanes` / `serve_max_tokens`
//! fields), and at handout time a routed query *preempts* the regular
//! task queue — it leaves as a `kind = "serve"` [`TaskSpec`] on the same
//! pull flow. Deadlines run on an injected SLO clock
//! ([`Orchestrator::slo_clock`]); eviction and slashing recover a dead
//! worker's in-flight query back into the router (counted under
//! [`Orchestrator::tasks_requeued`], like any orphaned task) unless its
//! deadline already passed, in which case it is dropped as expired.

use std::collections::{BTreeMap, VecDeque};
use std::sync::{Arc, Mutex};

use super::identity::Identity;
use super::ledger::{Ledger, Tx};
use crate::http::{HttpClient, HttpServer, Request, Response, ServerConfig};
use crate::serving::{ServeCapacity, ServeRequest, ServeRouter, SloClock, SERVE_TASK_KIND};
use crate::util::json::Json;
use crate::util::metrics::Counter;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum NodeStatus {
    Invited,
    Active,
    Dead,
}

/// Canonical bytes a signed invite covers. Shared between the
/// orchestrator (signing) and the worker (validating against the pool
/// owner's ledger-registered key, §2.4.2).
pub fn invite_message(node: u64, pool_id: u64, domain: &str) -> Vec<u8> {
    format!("invite:{node}:{pool_id}:{domain}").into_bytes()
}

#[derive(Clone, Debug)]
pub struct TaskSpec {
    pub id: u64,
    pub kind: String,
    pub payload: Json,
}

#[derive(Clone, Debug)]
struct NodeState {
    status: NodeStatus,
    last_heartbeat_ms: u64,
    missed: u32,
    /// The full spec of the task this node holds, so eviction can requeue
    /// it instead of losing the work with the node.
    current_task: Option<TaskSpec>,
    logs: VecDeque<String>,
}

struct Inner {
    nodes: BTreeMap<u64, NodeState>,
    queue: VecDeque<TaskSpec>,
    next_task_id: u64,
    /// Serve-mode front door: queued user queries + capacity table +
    /// in-flight deadline tracking (drained ahead of `queue` at handout).
    router: ServeRouter,
}

/// Why a heartbeat was refused (no state was recorded for the sender).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum HeartbeatRejected {
    /// The node was never admitted through the invite flow.
    NeverInvited,
    /// The node is slashed on the ledger; slashed nodes do not rejoin by
    /// heartbeating.
    Slashed,
    /// The node was evicted (missed-heartbeat sweep): it re-enters through
    /// a fresh invite, not by heartbeating back to life.
    Evicted,
}

#[derive(Clone)]
pub struct Orchestrator {
    inner: Arc<Mutex<Inner>>,
    pub identity: Arc<Identity>,
    pub ledger: Ledger,
    pub pool_id: u64,
    pub heartbeat_timeout_ms: u64,
    pub max_missed: u32,
    /// Heartbeats refused from never-invited or slashed senders.
    pub heartbeats_rejected: Arc<Counter>,
    /// Tasks orphaned by an evicted/slashed holder and pushed back to the
    /// front of the queue (the churn-survival counter: requeued, not lost).
    /// Serve queries recovered into the router count here too.
    pub tasks_requeued: Arc<Counter>,
    /// Injected SLO time source for serve-query deadline math (R2: the
    /// router never reads the wall clock itself). Defaults to real time;
    /// replace *before* cloning/serving to run deadlines on test ticks.
    pub slo_clock: SloClock,
}

pub struct OrchestratorServer {
    pub orch: Orchestrator,
    pub server: HttpServer,
}

impl Orchestrator {
    pub fn new(identity: Identity, ledger: Ledger, pool_id: u64, heartbeat_timeout_ms: u64) -> Orchestrator {
        Orchestrator {
            inner: Arc::new(Mutex::new(Inner {
                nodes: BTreeMap::new(),
                queue: VecDeque::new(),
                next_task_id: 0,
                router: ServeRouter::default(),
            })),
            identity: Arc::new(identity),
            ledger,
            pool_id,
            heartbeat_timeout_ms,
            max_missed: 3,
            heartbeats_rejected: Arc::new(Counter::default()),
            tasks_requeued: Arc::new(Counter::default()),
            slo_clock: Arc::new(crate::util::now_ms),
        }
    }

    /// Record a node as admitted (status `Invited`) — the bookkeeping half
    /// of the signed-invite flow. Normal operation reaches this only
    /// through [`Orchestrator::sweep_discovery`] after the worker accepted
    /// a signed invite; tests use it to set up membership directly. A
    /// previously-evicted (`Dead`) node is restored to `Invited`: a fresh
    /// invite is exactly its re-entry path.
    pub fn admit(&self, node: u64) {
        let mut inner = self.inner.lock().unwrap();
        let state = inner.nodes.entry(node).or_insert_with(|| NodeState {
            status: NodeStatus::Invited,
            last_heartbeat_ms: crate::util::now_ms(),
            missed: 0,
            current_task: None,
            logs: VecDeque::new(),
        });
        if state.status == NodeStatus::Dead {
            state.status = NodeStatus::Invited;
            state.last_heartbeat_ms = crate::util::now_ms();
            state.missed = 0;
            state.current_task = None;
        }
    }

    /// Should `addr` get an invite? Known-and-alive nodes are skipped; an
    /// evicted (Dead) node is eligible for re-invitation — that is its
    /// only way back in, since its heartbeats are refused. Slashed nodes
    /// never are.
    fn invite_eligible(&self, addr: u64) -> bool {
        let known_alive = self
            .inner
            .lock()
            .unwrap()
            .nodes
            .get(&addr)
            .is_some_and(|s| s.status != NodeStatus::Dead);
        !known_alive && !self.ledger.is_slashed(self.pool_id, addr)
    }

    /// Sign + deliver one invite to `endpoint`; records the ledger Tx and
    /// admits the node if the worker accepted. A non-empty `gossip_seed`
    /// rides along so the invited worker can bootstrap its gossip agent
    /// from the orchestrator (invite authority and membership bootstrap
    /// travel in one signed envelope).
    fn deliver_invite(
        &self,
        client: &HttpClient,
        addr: u64,
        endpoint: &str,
        gossip_seed: &str,
    ) -> bool {
        // Signed invite (signatures travel hex — see util::json).
        let sig = self.identity.sign(&invite_message(addr, self.pool_id, "dist-rl"));
        let mut pairs = vec![
            ("pool_id", self.pool_id.into()),
            ("domain", "dist-rl".into()),
            ("node", addr.into()),
            ("sig", Json::hex(&sig)),
        ];
        if !gossip_seed.is_empty() {
            pairs.push(("gossip", gossip_seed.into()));
        }
        let body = Json::obj(pairs);
        match client.post_json(&format!("{endpoint}/invite"), &body) {
            Ok(r) if r.status == 200 => {
                let _ = self.ledger.submit(
                    Tx::Invite {
                        pool_id: self.pool_id,
                        node: addr,
                        orchestrator: self.identity.address,
                    },
                    &self.identity,
                );
                self.admit(addr);
                true
            }
            _ => false,
        }
    }

    /// Periodic discovery sweep: invite any registered node we don't know.
    /// The invite carries a signature over (node, pool, domain) which the
    /// worker validates on the ledger (§2.4.2).
    pub fn sweep_discovery(&self, discovery_url: &str, token: &str) -> usize {
        let client = HttpClient::new("orchestrator");
        let Ok(resp) = client.get(&format!("{discovery_url}/nodes?token={token}")) else {
            return 0;
        };
        if resp.status != 200 {
            return 0;
        }
        let Ok(list) = Json::parse(std::str::from_utf8(&resp.body).unwrap_or("")) else {
            return 0;
        };
        let mut invited = 0;
        for n in list.as_arr().unwrap_or(&[]) {
            let (Some(addr), Some(endpoint)) = (
                n.get("address").and_then(Json::as_u64),
                n.get("endpoint").and_then(Json::as_str),
            ) else {
                continue;
            };
            if self.invite_eligible(addr) && self.deliver_invite(&client, addr, endpoint, "") {
                invited += 1;
            }
        }
        invited
    }

    /// Gossip-driven invite sweep: same authority, decentralized
    /// membership source. Walks worker-role records from the
    /// orchestrator's *own gossip view* (signature-verified on absorb) —
    /// no call to the discovery service's central list endpoint — and
    /// invites every eligible one, seeding its gossip agent with
    /// `gossip_seed` (normally the orchestrator's own gossip URL).
    pub fn sweep_gossip(
        &self,
        peers: &[super::gossip::PeerRecord],
        gossip_seed: &str,
    ) -> usize {
        let client = HttpClient::new("orchestrator");
        let mut invited = 0;
        for p in peers {
            if p.role != super::gossip::PeerRole::Worker || !self.invite_eligible(p.address) {
                continue;
            }
            if self.deliver_invite(&client, p.address, &p.endpoint, gossip_seed) {
                invited += 1;
            }
        }
        invited
    }

    /// Enqueue a task for pull-based distribution.
    pub fn create_task(&self, kind: &str, payload: Json) -> u64 {
        let mut inner = self.inner.lock().unwrap();
        let id = inner.next_task_id;
        inner.next_task_id += 1;
        inner.queue.push_back(TaskSpec { id, kind: kind.to_string(), payload });
        id
    }

    /// Record a heartbeat; hand out a queued task if the node is idle.
    ///
    /// Membership-gated (§2.4.2): heartbeats only count for nodes that
    /// entered through the signed-invite flow and are not slashed on the
    /// ledger. Previously an unknown sender was silently auto-registered
    /// as `Active` — an uninvited or slashed node could heartbeat itself
    /// into the pool and receive tasks, bypassing invites entirely.
    pub fn heartbeat(
        &self,
        node: u64,
        log: Option<String>,
        task_done: Option<u64>,
    ) -> Result<Option<TaskSpec>, HeartbeatRejected> {
        self.heartbeat_with_capacity(node, log, task_done, None)
    }

    /// [`Orchestrator::heartbeat`] with serve-capacity advertisement: a
    /// node offering `capacity` becomes eligible for routed user queries,
    /// which are handed out *ahead of* the regular task queue (serve
    /// traffic preempts pending RL work at assignment time). A node that
    /// never advertises never receives serve tasks.
    pub fn heartbeat_with_capacity(
        &self,
        node: u64,
        log: Option<String>,
        task_done: Option<u64>,
        capacity: Option<ServeCapacity>,
    ) -> Result<Option<TaskSpec>, HeartbeatRejected> {
        if self.ledger.is_slashed(self.pool_id, node) {
            self.heartbeats_rejected.inc();
            return Err(HeartbeatRejected::Slashed);
        }
        let now_slo = (self.slo_clock)();
        let mut inner = self.inner.lock().unwrap();
        let Some(state) = inner.nodes.get_mut(&node) else {
            drop(inner);
            self.heartbeats_rejected.inc();
            return Err(HeartbeatRejected::NeverInvited);
        };
        if state.status == NodeStatus::Dead {
            // Evicted from the pool (ledger `Tx::Evict`): heartbeats do
            // not resurrect it — only a fresh invite (`admit`) does.
            drop(inner);
            self.heartbeats_rejected.inc();
            return Err(HeartbeatRejected::Evicted);
        }
        state.status = NodeStatus::Active;
        state.last_heartbeat_ms = crate::util::now_ms();
        state.missed = 0;
        if let Some(l) = log {
            state.logs.push_back(l);
            while state.logs.len() > 50 {
                state.logs.pop_front();
            }
        }
        let mut finished: Option<TaskSpec> = None;
        if let Some(done) = task_done {
            if state.current_task.as_ref().map(|t| t.id) == Some(done) {
                finished = state.current_task.take();
            }
        }
        let idle = state.current_task.is_none();
        if let Some(cap) = capacity {
            inner.router.advertise(node, cap);
        }
        // A finished serve task settles its query's deadline accounting.
        if let Some(t) = &finished {
            if t.kind == SERVE_TASK_KIND {
                if let Some(q) = ServeRequest::from_json(&t.payload) {
                    inner.router.complete(q.query_id, now_slo);
                }
            }
        }
        if idle {
            // User queries first: the router is the priority queue.
            if let Some(q) = inner.router.assign(node, now_slo) {
                let id = inner.next_task_id;
                inner.next_task_id += 1;
                let task = TaskSpec { id, kind: SERVE_TASK_KIND.to_string(), payload: q.to_json() };
                inner.nodes.get_mut(&node).unwrap().current_task = Some(task.clone());
                return Ok(Some(task));
            }
            if let Some(task) = inner.queue.pop_front() {
                inner.nodes.get_mut(&node).unwrap().current_task = Some(task.clone());
                return Ok(Some(task));
            }
        }
        Ok(None)
    }

    /// Front-door entry for a user query: allocate an id, stamp the
    /// absolute deadline (`now + slo_ms` on the injected clock) and queue
    /// it for routed dispatch. `None` if the query is unserviceable
    /// (zero-length SLO).
    pub fn submit_query(&self, prompt: Vec<i32>, max_new: u32, slo_ms: u64) -> Option<u64> {
        let now = (self.slo_clock)();
        let mut inner = self.inner.lock().unwrap();
        let query_id = inner.router.next_query_id();
        let req =
            ServeRequest { query_id, prompt, max_new, deadline_ms: now.saturating_add(slo_ms) };
        inner.router.submit(req, now).then_some(query_id)
    }

    /// Serve-router observability: `(pending, in_flight, completed,
    /// deadlines_missed, expired, requeued)`.
    pub fn serve_stats(&self) -> (u64, u64, u64, u64, u64, u64) {
        let inner = self.inner.lock().unwrap();
        (
            inner.router.pending() as u64,
            inner.router.assigned() as u64,
            inner.router.queries_completed.get(),
            inner.router.deadlines_missed.get(),
            inner.router.queries_expired.get(),
            inner.router.queries_requeued.get(),
        )
    }

    /// Health sweep: count missed heartbeats, mark dead + evict from the
    /// ledger after `max_missed` (§2.4.2). Returns evicted node addresses.
    ///
    /// Any task an evicted node was holding is requeued at the *front* of
    /// the queue (it is the oldest outstanding work), so the next idle
    /// heartbeat picks it up — a crashed worker delays its task by one
    /// eviction window, never loses it. A serve query the node was holding
    /// re-enters the *router* queue the same way (unless its deadline
    /// already passed), and the node's capacity advertisement is dropped.
    pub fn health_sweep(&self) -> Vec<u64> {
        let now = crate::util::now_ms();
        let now_slo = (self.slo_clock)();
        let mut evicted = Vec::new();
        let mut orphans: Vec<TaskSpec> = Vec::new();
        let mut inner = self.inner.lock().unwrap();
        for (&addr, st) in inner.nodes.iter_mut() {
            if st.status == NodeStatus::Dead {
                continue;
            }
            if now.saturating_sub(st.last_heartbeat_ms) > self.heartbeat_timeout_ms {
                st.missed += 1;
                st.last_heartbeat_ms = now;
                if st.missed >= self.max_missed {
                    st.status = NodeStatus::Dead;
                    if let Some(task) = st.current_task.take() {
                        // Serve queries are recovered through the router
                        // below; only generic tasks ride the task queue.
                        if task.kind != SERVE_TASK_KIND {
                            orphans.push(task);
                        }
                    }
                    evicted.push(addr);
                }
            }
        }
        for task in orphans.into_iter().rev() {
            self.tasks_requeued.inc();
            inner.queue.push_front(task);
        }
        for &addr in &evicted {
            self.tasks_requeued.add(inner.router.requeue_node(addr, now_slo));
        }
        drop(inner);
        for addr in &evicted {
            let _ = self
                .ledger
                .submit(Tx::Evict { pool_id: self.pool_id, node: *addr }, &self.identity);
        }
        evicted
    }

    /// Slash a node after a TOPLOC rejection (§2.4.2 inference validation).
    /// A held task is requeued — the *node* is untrusted, the task spec is
    /// the pool's own work and goes back to the queue. A held serve query
    /// re-enters the router queue the same way (the *user's* query is not
    /// the cheater's property), and the node stops looking assignable.
    pub fn slash(&self, node: u64, reason: &str) {
        let _ = self.ledger.submit(
            Tx::Slash { pool_id: self.pool_id, node, reason: reason.to_string() },
            &self.identity,
        );
        let now_slo = (self.slo_clock)();
        let mut inner = self.inner.lock().unwrap();
        let orphan = inner.nodes.get_mut(&node).and_then(|st| {
            st.status = NodeStatus::Dead;
            st.current_task.take()
        });
        if let Some(task) = orphan {
            if task.kind != SERVE_TASK_KIND {
                self.tasks_requeued.inc();
                inner.queue.push_front(task);
            }
        }
        self.tasks_requeued.add(inner.router.requeue_node(node, now_slo));
    }

    pub fn status(&self, node: u64) -> Option<NodeStatus> {
        self.inner.lock().unwrap().nodes.get(&node).map(|s| s.status)
    }

    pub fn active_nodes(&self) -> Vec<u64> {
        self.inner
            .lock()
            .unwrap()
            .nodes
            .iter()
            .filter(|(_, s)| s.status == NodeStatus::Active)
            .map(|(a, _)| *a)
            .collect()
    }

    pub fn logs(&self, node: u64) -> Vec<String> {
        self.inner
            .lock()
            .unwrap()
            .nodes
            .get(&node)
            .map(|s| s.logs.iter().cloned().collect())
            .unwrap_or_default()
    }

    pub fn queue_len(&self) -> usize {
        self.inner.lock().unwrap().queue.len()
    }

    /// Active nodes currently holding a task. The churn harness picks its
    /// crash victims from these, so a kill always orphans real work.
    pub fn nodes_with_tasks(&self) -> Vec<u64> {
        self.inner
            .lock()
            .unwrap()
            .nodes
            .iter()
            .filter(|(_, s)| s.status == NodeStatus::Active && s.current_task.is_some())
            .map(|(a, _)| *a)
            .collect()
    }

    /// Tasks currently assigned to live nodes (not queued, not finished).
    pub fn tasks_in_flight(&self) -> usize {
        self.inner
            .lock()
            .unwrap()
            .nodes
            .values()
            .filter(|s| s.status != NodeStatus::Dead && s.current_task.is_some())
            .count()
    }
}

fn handle(orch: &Orchestrator, req: &Request) -> Response {
    match (req.method.as_str(), req.path.as_str()) {
        ("POST", "/heartbeat") => {
            let Ok(j) = req.json() else { return Response::error(400, "bad json") };
            let Some(node) = j.get("node").and_then(Json::as_u64) else {
                return Response::error(400, "missing node");
            };
            let log = j.get("log").and_then(Json::as_str).map(str::to_string);
            let done = j.get("task_done").and_then(Json::as_u64);
            // Optional serve-capacity advertisement (both fields or none).
            let capacity = match (
                j.get("serve_lanes").and_then(Json::as_u64),
                j.get("serve_max_tokens").and_then(Json::as_u64),
            ) {
                (Some(lanes), Some(max_tokens)) => Some(ServeCapacity {
                    free_lanes: lanes.min(u64::from(u32::MAX)) as u32,
                    max_tokens: max_tokens.min(u64::from(u32::MAX)) as u32,
                }),
                _ => None,
            };
            match orch.heartbeat_with_capacity(node, log, done, capacity) {
                Ok(Some(task)) => Response::json(&Json::obj(vec![
                    ("task_id", task.id.into()),
                    ("kind", task.kind.into()),
                    ("payload", task.payload),
                ])),
                Ok(None) => Response::json(&Json::obj(vec![("task_id", Json::Null)])),
                Err(why) => Response::error(403, &format!("heartbeat refused: {why:?}")),
            }
        }
        ("POST", "/query") => {
            let Ok(j) = req.json() else { return Response::error(400, "bad json") };
            let Some(prompt) = j.get("prompt").and_then(Json::as_arr).map(|a| {
                a.iter().filter_map(|t| t.as_u64().map(|v| v as u32 as i32)).collect::<Vec<i32>>()
            }) else {
                return Response::error(400, "missing prompt");
            };
            let max_new = j.get("max_new").and_then(Json::as_u64).unwrap_or(64) as u32;
            let slo_ms = j.get("slo_ms").and_then(Json::as_u64).unwrap_or(10_000);
            match orch.submit_query(prompt, max_new, slo_ms) {
                Some(query_id) => Response::json(&Json::obj(vec![("query_id", query_id.into())])),
                None => Response::error(400, "query refused (unserviceable SLO)"),
            }
        }
        ("POST", "/task") => {
            let Ok(j) = req.json() else { return Response::error(400, "bad json") };
            let kind = j.get("kind").and_then(Json::as_str).unwrap_or("generic").to_string();
            let payload = j.get("payload").cloned().unwrap_or(Json::Null);
            let id = orch.create_task(&kind, payload);
            Response::json(&Json::obj(vec![("task_id", id.into())]))
        }
        ("GET", "/nodes") => {
            let nodes: Vec<Json> = orch
                .inner
                .lock()
                .unwrap()
                .nodes
                .iter()
                .map(|(a, s)| {
                    Json::obj(vec![
                        ("address", (*a).into()),
                        ("status", format!("{:?}", s.status).into()),
                        ("missed", (s.missed as u64).into()),
                    ])
                })
                .collect();
            Response::json(&Json::Arr(nodes))
        }
        ("GET", "/logs") => {
            let node = req.query_u64("node", 0);
            Response::json(&Json::Arr(orch.logs(node).into_iter().map(Json::Str).collect()))
        }
        _ => Response::error(404, "unknown endpoint"),
    }
}

impl OrchestratorServer {
    pub fn start(orch: Orchestrator) -> anyhow::Result<OrchestratorServer> {
        let o = orch.clone();
        let server = HttpServer::start(
            ServerConfig { worker_threads: 2, ..Default::default() },
            move |req| handle(&o, req),
        )?;
        Ok(OrchestratorServer { orch, server })
    }

    /// Restart path: serve on a *fixed* address so workers holding the old
    /// URL reconnect as soon as the orchestrator comes back after a bounce.
    pub fn start_on(orch: Orchestrator, addr: &str) -> anyhow::Result<OrchestratorServer> {
        let o = orch.clone();
        let server = HttpServer::start_on(
            addr,
            ServerConfig { worker_threads: 2, ..Default::default() },
            move |req| handle(&o, req),
        )?;
        Ok(OrchestratorServer { orch, server })
    }

    pub fn url(&self) -> String {
        self.server.url()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn orch() -> Orchestrator {
        let ledger = Ledger::new();
        let owner = Identity::from_seed(1);
        ledger.register_key(&owner);
        ledger
            .submit(Tx::CreatePool { domain: "dist-rl".into(), pool_id: 1, owner: owner.address }, &owner)
            .unwrap();
        Orchestrator::new(owner, ledger, 1, 30)
    }

    #[test]
    fn pull_based_task_distribution() {
        let o = orch();
        o.admit(10);
        o.admit(11);
        o.create_task("rollout", Json::Null);
        o.create_task("rollout", Json::Null);
        // First heartbeat gets task 0.
        let t = o.heartbeat(10, None, None).unwrap().unwrap();
        assert_eq!(t.id, 0);
        // Same node, still busy: nothing.
        assert!(o.heartbeat(10, None, None).unwrap().is_none());
        // Second node gets task 1.
        assert_eq!(o.heartbeat(11, None, None).unwrap().unwrap().id, 1);
        // Node 10 finishes, queue is empty.
        assert!(o.heartbeat(10, Some("done".into()), Some(0)).unwrap().is_none());
        assert_eq!(o.logs(10), vec!["done".to_string()]);
        assert_eq!(o.queue_len(), 0);
    }

    #[test]
    fn uninvited_and_slashed_heartbeats_rejected() {
        let o = orch();
        o.create_task("rollout", Json::Null);
        // Never invited: refused, no state recorded, no task handed out.
        assert_eq!(o.heartbeat(66, None, None).unwrap_err(), HeartbeatRejected::NeverInvited);
        assert_eq!(o.status(66), None);
        assert_eq!(o.queue_len(), 1);
        // Slashed after admission: refused even though it is a member.
        o.admit(9);
        o.slash(9, "toploc rejection");
        assert_eq!(o.heartbeat(9, None, None).unwrap_err(), HeartbeatRejected::Slashed);
        assert_eq!(o.queue_len(), 1);
        assert_eq!(o.heartbeats_rejected.get(), 2);
        // An admitted, unslashed node still pulls the task.
        o.admit(10);
        assert!(o.heartbeat(10, None, None).unwrap().is_some());
    }

    #[test]
    fn health_sweep_evicts_after_missed_heartbeats() {
        let o = orch();
        o.admit(7);
        o.heartbeat(7, None, None).unwrap();
        assert_eq!(o.status(7), Some(NodeStatus::Active));
        // Three sweeps past the timeout -> dead + evicted on the ledger.
        for _ in 0..3 {
            std::thread::sleep(std::time::Duration::from_millis(35));
            o.health_sweep();
        }
        assert_eq!(o.status(7), Some(NodeStatus::Dead));
        assert!(o.active_nodes().is_empty());
        // An evicted node cannot heartbeat itself back into the pool —
        // only a fresh invite restores it.
        assert_eq!(o.heartbeat(7, None, None).unwrap_err(), HeartbeatRejected::Evicted);
        assert_eq!(o.status(7), Some(NodeStatus::Dead));
        o.admit(7);
        assert_eq!(o.status(7), Some(NodeStatus::Invited));
        assert!(o.heartbeat(7, None, None).is_ok());
    }

    #[test]
    fn eviction_requeues_orphaned_task_for_another_worker() {
        let o = orch();
        o.admit(1);
        o.admit(2);
        o.create_task("rollout", Json::Str("orphan-me".into()));
        // Node 1 takes the task, then crashes (stops heartbeating).
        let t = o.heartbeat(1, None, None).unwrap().unwrap();
        assert_eq!(t.id, 0);
        assert_eq!(o.nodes_with_tasks(), vec![1]);
        assert_eq!(o.tasks_in_flight(), 1);
        // Node 2 stays alive through the sweeps; only node 1 is evicted.
        for _ in 0..3 {
            std::thread::sleep(std::time::Duration::from_millis(35));
            assert!(o.heartbeat(2, None, None).unwrap().is_none());
            o.health_sweep();
        }
        assert_eq!(o.status(1), Some(NodeStatus::Dead));
        assert_eq!(o.tasks_requeued.get(), 1);
        assert_eq!(o.queue_len(), 1);
        assert_eq!(o.tasks_in_flight(), 0);
        // The surviving worker picks the orphan up and completes it.
        let t = o.heartbeat(2, None, None).unwrap().unwrap();
        assert_eq!(t.id, 0);
        assert_eq!(t.payload.as_str().unwrap(), "orphan-me");
        assert!(o.heartbeat(2, None, Some(0)).unwrap().is_none());
        assert_eq!(o.tasks_in_flight(), 0);
        assert_eq!(o.queue_len(), 0);
    }

    #[test]
    fn slash_requeues_held_task() {
        let o = orch();
        o.admit(3);
        o.create_task("rollout", Json::Null);
        o.heartbeat(3, None, None).unwrap().unwrap();
        o.slash(3, "toploc rejection");
        assert_eq!(o.queue_len(), 1);
        assert_eq!(o.tasks_requeued.get(), 1);
    }

    #[test]
    fn heartbeats_keep_node_alive() {
        let o = orch();
        o.admit(7);
        for _ in 0..5 {
            o.heartbeat(7, None, None).unwrap();
            std::thread::sleep(std::time::Duration::from_millis(10));
            o.health_sweep();
        }
        assert_eq!(o.status(7), Some(NodeStatus::Active));
    }

    #[test]
    fn slash_marks_dead_and_ledger() {
        let o = orch();
        o.admit(9);
        o.heartbeat(9, None, None).unwrap();
        o.slash(9, "toploc rejection");
        assert_eq!(o.status(9), Some(NodeStatus::Dead));
        assert!(o.ledger.is_slashed(1, 9));
    }

    /// Fixture with a deterministic SLO clock: deadlines advance only
    /// when the test bumps the returned atomic (heartbeat liveness still
    /// runs on real time — the two clocks are independent by design).
    fn serve_orch() -> (Orchestrator, Arc<std::sync::atomic::AtomicU64>) {
        let mut o = orch();
        let tick = Arc::new(std::sync::atomic::AtomicU64::new(0));
        let t = tick.clone();
        o.slo_clock = Arc::new(move || t.load(std::sync::atomic::Ordering::SeqCst));
        (o, tick)
    }

    fn cap() -> ServeCapacity {
        ServeCapacity { free_lanes: 2, max_tokens: 128 }
    }

    #[test]
    fn serve_queries_preempt_the_task_queue() {
        let (o, _) = serve_orch();
        o.admit(10);
        o.admit(11);
        o.create_task("rollout", Json::Null);
        let qid = o.submit_query(vec![1, 2, 3], 8, 1_000).unwrap();
        // The serving node gets the query *before* the queued RL task.
        let t = o.heartbeat_with_capacity(10, None, None, Some(cap())).unwrap().unwrap();
        assert_eq!(t.kind, SERVE_TASK_KIND);
        let q = ServeRequest::from_json(&t.payload).unwrap();
        assert_eq!((q.query_id, q.prompt, q.max_new), (qid, vec![1, 2, 3], 8));
        // Finishing it settles deadline accounting and frees the node for
        // the RL task it skipped.
        let t2 = o.heartbeat_with_capacity(10, None, Some(t.id), Some(cap())).unwrap().unwrap();
        assert_eq!(t2.kind, "rollout");
        let (_, _, completed, missed, _, _) = o.serve_stats();
        assert_eq!((completed, missed), (1, 0));
        // A node that never advertised capacity never receives queries.
        o.submit_query(vec![1], 4, 1_000).unwrap();
        assert!(o.heartbeat(11, None, None).unwrap().is_none());
        assert_eq!(o.serve_stats().0, 1);
    }

    #[test]
    fn eviction_requeues_orphaned_serve_query_into_router() {
        let (o, _) = serve_orch();
        o.admit(1);
        o.admit(2);
        let qid = o.submit_query(vec![1, 2], 8, 1_000_000).unwrap();
        let t = o.heartbeat_with_capacity(1, None, None, Some(cap())).unwrap().unwrap();
        assert_eq!(t.kind, SERVE_TASK_KIND);
        // Holder crashes: the query re-enters the *router* queue (not the
        // generic task queue) and counts as a requeued task.
        for _ in 0..3 {
            std::thread::sleep(std::time::Duration::from_millis(35));
            assert!(o.heartbeat(2, None, None).unwrap().is_none());
            o.health_sweep();
        }
        assert_eq!(o.status(1), Some(NodeStatus::Dead));
        assert_eq!(o.tasks_requeued.get(), 1);
        assert_eq!(o.queue_len(), 0);
        let (pending, in_flight, _, _, _, requeued) = o.serve_stats();
        assert_eq!((pending, in_flight, requeued), (1, 0, 1));
        // The survivor picks the same query up.
        let t = o.heartbeat_with_capacity(2, None, None, Some(cap())).unwrap().unwrap();
        assert_eq!(t.kind, SERVE_TASK_KIND);
        assert_eq!(ServeRequest::from_json(&t.payload).unwrap().query_id, qid);
    }

    #[test]
    fn slash_requeues_held_serve_query_and_forgets_capacity() {
        let (o, _) = serve_orch();
        o.admit(3);
        o.admit(4);
        let qid = o.submit_query(vec![5, 6], 4, 1_000_000).unwrap();
        let t = o.heartbeat_with_capacity(3, None, None, Some(cap())).unwrap().unwrap();
        assert_eq!(t.kind, SERVE_TASK_KIND);
        o.slash(3, "forged served response");
        assert_eq!(o.queue_len(), 0); // router, not the generic queue
        assert_eq!(o.tasks_requeued.get(), 1);
        assert_eq!(o.serve_stats().5, 1);
        // An honest node inherits the query; the slashed node's heartbeats
        // (and stale capacity) are gone.
        assert_eq!(o.heartbeat(3, None, None).unwrap_err(), HeartbeatRejected::Slashed);
        let t = o.heartbeat_with_capacity(4, None, None, Some(cap())).unwrap().unwrap();
        assert_eq!(ServeRequest::from_json(&t.payload).unwrap().query_id, qid);
    }

    #[test]
    fn deadline_expired_serve_queries_drop_instead_of_requeueing() {
        let (o, tick) = serve_orch();
        o.admit(1);
        // Unserviceable SLO: refused at the front door.
        assert_eq!(o.submit_query(vec![1], 4, 0), None);
        // Serviceable query assigned, then its holder dies *after* the
        // deadline passed: the orphan is dropped as expired, not requeued.
        o.submit_query(vec![1, 2], 4, 100).unwrap();
        let t = o.heartbeat_with_capacity(1, None, None, Some(cap())).unwrap().unwrap();
        assert_eq!(t.kind, SERVE_TASK_KIND);
        tick.store(200, std::sync::atomic::Ordering::SeqCst);
        for _ in 0..3 {
            std::thread::sleep(std::time::Duration::from_millis(35));
            o.health_sweep();
        }
        assert_eq!(o.status(1), Some(NodeStatus::Dead));
        assert_eq!(o.tasks_requeued.get(), 0);
        let (pending, in_flight, _, _, expired, requeued) = o.serve_stats();
        assert_eq!((pending, in_flight, expired, requeued), (0, 0, 2, 0));
        // A late *completion* (node alive, answer after deadline) is
        // counted as a missed deadline, not an expiry.
        tick.store(0, std::sync::atomic::Ordering::SeqCst);
        o.admit(2);
        o.submit_query(vec![1, 2], 4, 100).unwrap();
        let t = o.heartbeat_with_capacity(2, None, None, Some(cap())).unwrap().unwrap();
        tick.store(500, std::sync::atomic::Ordering::SeqCst);
        o.heartbeat_with_capacity(2, None, Some(t.id), Some(cap())).unwrap();
        let (_, _, completed, missed, _, _) = o.serve_stats();
        assert_eq!((completed, missed), (1, 1));
    }

    #[test]
    fn http_front_door_serves_queries() {
        let (o, _) = serve_orch();
        let srv = OrchestratorServer::start(o.clone()).unwrap();
        let c = HttpClient::new("user");
        // Submit a query over HTTP.
        let r = c
            .post_json(
                &format!("{}/query", srv.url()),
                &Json::obj(vec![
                    ("prompt", Json::Arr(vec![1u64.into(), 2u64.into()])),
                    ("max_new", 8u64.into()),
                    ("slo_ms", 5_000u64.into()),
                ]),
            )
            .unwrap();
        assert_eq!(r.status, 200);
        let qid = Json::parse(std::str::from_utf8(&r.body).unwrap())
            .unwrap()
            .get("query_id")
            .unwrap()
            .as_u64()
            .unwrap();
        // A capacity-advertising heartbeat pulls it as a serve task.
        o.admit(5);
        let hb = c
            .post_json(
                &format!("{}/heartbeat", srv.url()),
                &Json::obj(vec![
                    ("node", 5u64.into()),
                    ("serve_lanes", 2u64.into()),
                    ("serve_max_tokens", 128u64.into()),
                ]),
            )
            .unwrap();
        assert_eq!(hb.status, 200);
        let j = Json::parse(std::str::from_utf8(&hb.body).unwrap()).unwrap();
        assert_eq!(j.get("kind").unwrap().as_str().unwrap(), SERVE_TASK_KIND);
        let q = ServeRequest::from_json(j.get("payload").unwrap()).unwrap();
        assert_eq!((q.query_id, q.prompt), (qid, vec![1, 2]));
        // Malformed front-door requests are a clean 400.
        let bad = c
            .post_json(&format!("{}/query", srv.url()), &Json::obj(vec![("max_new", 8u64.into())]))
            .unwrap();
        assert_eq!(bad.status, 400);
    }

    #[test]
    fn http_surface() {
        let o = orch();
        let srv = OrchestratorServer::start(o.clone()).unwrap();
        let c = HttpClient::new("n");
        let r = c
            .post_json(
                &format!("{}/task", srv.url()),
                &Json::obj(vec![("kind", "rollout".into()), ("payload", Json::Null)]),
            )
            .unwrap();
        assert_eq!(r.status, 200);
        // An uninvited heartbeat over HTTP is a 403, and hands out nothing.
        let hb = c
            .post_json(&format!("{}/heartbeat", srv.url()), &Json::obj(vec![("node", 5u64.into())]))
            .unwrap();
        assert_eq!(hb.status, 403);
        assert_eq!(o.heartbeats_rejected.get(), 1);
        // After admission the same heartbeat pulls the task.
        o.admit(5);
        let hb = c
            .post_json(&format!("{}/heartbeat", srv.url()), &Json::obj(vec![("node", 5u64.into())]))
            .unwrap();
        assert_eq!(hb.status, 200);
        let j = Json::parse(std::str::from_utf8(&hb.body).unwrap()).unwrap();
        assert_eq!(j.get("kind").unwrap().as_str().unwrap(), "rollout");
        let nodes = c.get(&format!("{}/nodes", srv.url())).unwrap();
        assert!(std::str::from_utf8(&nodes.body).unwrap().contains("Active"));
    }
}
