//! Orchestrator (§2.4.1/§2.4.2): invites discovered nodes into the compute
//! pool (signed invites validated on the ledger), tracks node health via
//! heartbeats with missed-count eviction, and distributes tasks *in
//! response to heartbeats* — the paper's reactive pull-based model.
//!
//! Heartbeats are *membership-gated*: a node the orchestrator never
//! admitted (via the signed-invite sweep) or that the ledger has slashed
//! cannot heartbeat itself into the pool and receive tasks — that would
//! bypass the invite flow entirely. Such heartbeats are refused (HTTP
//! 403) and counted in [`Orchestrator::heartbeats_rejected`].

use std::collections::{BTreeMap, VecDeque};
use std::sync::{Arc, Mutex};

use super::identity::Identity;
use super::ledger::{Ledger, Tx};
use crate::http::{HttpClient, HttpServer, Request, Response, ServerConfig};
use crate::util::json::Json;
use crate::util::metrics::Counter;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum NodeStatus {
    Invited,
    Active,
    Dead,
}

/// Canonical bytes a signed invite covers. Shared between the
/// orchestrator (signing) and the worker (validating against the pool
/// owner's ledger-registered key, §2.4.2).
pub fn invite_message(node: u64, pool_id: u64, domain: &str) -> Vec<u8> {
    format!("invite:{node}:{pool_id}:{domain}").into_bytes()
}

#[derive(Clone, Debug)]
pub struct TaskSpec {
    pub id: u64,
    pub kind: String,
    pub payload: Json,
}

#[derive(Clone, Debug)]
struct NodeState {
    status: NodeStatus,
    last_heartbeat_ms: u64,
    missed: u32,
    /// The full spec of the task this node holds, so eviction can requeue
    /// it instead of losing the work with the node.
    current_task: Option<TaskSpec>,
    logs: VecDeque<String>,
}

struct Inner {
    nodes: BTreeMap<u64, NodeState>,
    queue: VecDeque<TaskSpec>,
    next_task_id: u64,
}

/// Why a heartbeat was refused (no state was recorded for the sender).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum HeartbeatRejected {
    /// The node was never admitted through the invite flow.
    NeverInvited,
    /// The node is slashed on the ledger; slashed nodes do not rejoin by
    /// heartbeating.
    Slashed,
    /// The node was evicted (missed-heartbeat sweep): it re-enters through
    /// a fresh invite, not by heartbeating back to life.
    Evicted,
}

#[derive(Clone)]
pub struct Orchestrator {
    inner: Arc<Mutex<Inner>>,
    pub identity: Arc<Identity>,
    pub ledger: Ledger,
    pub pool_id: u64,
    pub heartbeat_timeout_ms: u64,
    pub max_missed: u32,
    /// Heartbeats refused from never-invited or slashed senders.
    pub heartbeats_rejected: Arc<Counter>,
    /// Tasks orphaned by an evicted/slashed holder and pushed back to the
    /// front of the queue (the churn-survival counter: requeued, not lost).
    pub tasks_requeued: Arc<Counter>,
}

pub struct OrchestratorServer {
    pub orch: Orchestrator,
    pub server: HttpServer,
}

impl Orchestrator {
    pub fn new(identity: Identity, ledger: Ledger, pool_id: u64, heartbeat_timeout_ms: u64) -> Orchestrator {
        Orchestrator {
            inner: Arc::new(Mutex::new(Inner {
                nodes: BTreeMap::new(),
                queue: VecDeque::new(),
                next_task_id: 0,
            })),
            identity: Arc::new(identity),
            ledger,
            pool_id,
            heartbeat_timeout_ms,
            max_missed: 3,
            heartbeats_rejected: Arc::new(Counter::default()),
            tasks_requeued: Arc::new(Counter::default()),
        }
    }

    /// Record a node as admitted (status `Invited`) — the bookkeeping half
    /// of the signed-invite flow. Normal operation reaches this only
    /// through [`Orchestrator::sweep_discovery`] after the worker accepted
    /// a signed invite; tests use it to set up membership directly. A
    /// previously-evicted (`Dead`) node is restored to `Invited`: a fresh
    /// invite is exactly its re-entry path.
    pub fn admit(&self, node: u64) {
        let mut inner = self.inner.lock().unwrap();
        let state = inner.nodes.entry(node).or_insert_with(|| NodeState {
            status: NodeStatus::Invited,
            last_heartbeat_ms: crate::util::now_ms(),
            missed: 0,
            current_task: None,
            logs: VecDeque::new(),
        });
        if state.status == NodeStatus::Dead {
            state.status = NodeStatus::Invited;
            state.last_heartbeat_ms = crate::util::now_ms();
            state.missed = 0;
            state.current_task = None;
        }
    }

    /// Periodic discovery sweep: invite any registered node we don't know.
    /// The invite carries a signature over (node, pool, domain) which the
    /// worker validates on the ledger (§2.4.2).
    pub fn sweep_discovery(&self, discovery_url: &str, token: &str) -> usize {
        let client = HttpClient::new("orchestrator");
        let Ok(resp) = client.get(&format!("{discovery_url}/nodes?token={token}")) else {
            return 0;
        };
        if resp.status != 200 {
            return 0;
        }
        let Ok(list) = Json::parse(std::str::from_utf8(&resp.body).unwrap_or("")) else {
            return 0;
        };
        let mut invited = 0;
        for n in list.as_arr().unwrap_or(&[]) {
            let (Some(addr), Some(endpoint)) = (
                n.get("address").and_then(Json::as_u64),
                n.get("endpoint").and_then(Json::as_str),
            ) else {
                continue;
            };
            // Known-and-alive nodes are skipped; an evicted (Dead) node is
            // eligible for re-invitation — that is its only way back in,
            // since its heartbeats are refused.
            let known_alive = self
                .inner
                .lock()
                .unwrap()
                .nodes
                .get(&addr)
                .is_some_and(|s| s.status != NodeStatus::Dead);
            if known_alive {
                continue;
            }
            if self.ledger.is_slashed(self.pool_id, addr) {
                continue;
            }
            // Signed invite (signatures travel hex — see util::json).
            let sig = self.identity.sign(&invite_message(addr, self.pool_id, "dist-rl"));
            let body = Json::obj(vec![
                ("pool_id", self.pool_id.into()),
                ("domain", "dist-rl".into()),
                ("node", addr.into()),
                ("sig", Json::hex(&sig)),
            ]);
            if let Ok(r) = client.post_json(&format!("{endpoint}/invite"), &body) {
                if r.status == 200 {
                    let _ = self.ledger.submit(
                        Tx::Invite { pool_id: self.pool_id, node: addr, orchestrator: self.identity.address },
                        &self.identity,
                    );
                    self.admit(addr);
                    invited += 1;
                }
            }
        }
        invited
    }

    /// Enqueue a task for pull-based distribution.
    pub fn create_task(&self, kind: &str, payload: Json) -> u64 {
        let mut inner = self.inner.lock().unwrap();
        let id = inner.next_task_id;
        inner.next_task_id += 1;
        inner.queue.push_back(TaskSpec { id, kind: kind.to_string(), payload });
        id
    }

    /// Record a heartbeat; hand out a queued task if the node is idle.
    ///
    /// Membership-gated (§2.4.2): heartbeats only count for nodes that
    /// entered through the signed-invite flow and are not slashed on the
    /// ledger. Previously an unknown sender was silently auto-registered
    /// as `Active` — an uninvited or slashed node could heartbeat itself
    /// into the pool and receive tasks, bypassing invites entirely.
    pub fn heartbeat(
        &self,
        node: u64,
        log: Option<String>,
        task_done: Option<u64>,
    ) -> Result<Option<TaskSpec>, HeartbeatRejected> {
        if self.ledger.is_slashed(self.pool_id, node) {
            self.heartbeats_rejected.inc();
            return Err(HeartbeatRejected::Slashed);
        }
        let mut inner = self.inner.lock().unwrap();
        let Some(state) = inner.nodes.get_mut(&node) else {
            drop(inner);
            self.heartbeats_rejected.inc();
            return Err(HeartbeatRejected::NeverInvited);
        };
        if state.status == NodeStatus::Dead {
            // Evicted from the pool (ledger `Tx::Evict`): heartbeats do
            // not resurrect it — only a fresh invite (`admit`) does.
            drop(inner);
            self.heartbeats_rejected.inc();
            return Err(HeartbeatRejected::Evicted);
        }
        state.status = NodeStatus::Active;
        state.last_heartbeat_ms = crate::util::now_ms();
        state.missed = 0;
        if let Some(l) = log {
            state.logs.push_back(l);
            while state.logs.len() > 50 {
                state.logs.pop_front();
            }
        }
        if let Some(done) = task_done {
            if state.current_task.as_ref().map(|t| t.id) == Some(done) {
                state.current_task = None;
            }
        }
        if state.current_task.is_none() {
            if let Some(task) = inner.queue.pop_front() {
                inner.nodes.get_mut(&node).unwrap().current_task = Some(task.clone());
                return Ok(Some(task));
            }
        }
        Ok(None)
    }

    /// Health sweep: count missed heartbeats, mark dead + evict from the
    /// ledger after `max_missed` (§2.4.2). Returns evicted node addresses.
    ///
    /// Any task an evicted node was holding is requeued at the *front* of
    /// the queue (it is the oldest outstanding work), so the next idle
    /// heartbeat picks it up — a crashed worker delays its task by one
    /// eviction window, never loses it.
    pub fn health_sweep(&self) -> Vec<u64> {
        let now = crate::util::now_ms();
        let mut evicted = Vec::new();
        let mut orphans: Vec<TaskSpec> = Vec::new();
        let mut inner = self.inner.lock().unwrap();
        for (&addr, st) in inner.nodes.iter_mut() {
            if st.status == NodeStatus::Dead {
                continue;
            }
            if now.saturating_sub(st.last_heartbeat_ms) > self.heartbeat_timeout_ms {
                st.missed += 1;
                st.last_heartbeat_ms = now;
                if st.missed >= self.max_missed {
                    st.status = NodeStatus::Dead;
                    if let Some(task) = st.current_task.take() {
                        orphans.push(task);
                    }
                    evicted.push(addr);
                }
            }
        }
        for task in orphans.into_iter().rev() {
            self.tasks_requeued.inc();
            inner.queue.push_front(task);
        }
        drop(inner);
        for addr in &evicted {
            let _ = self
                .ledger
                .submit(Tx::Evict { pool_id: self.pool_id, node: *addr }, &self.identity);
        }
        evicted
    }

    /// Slash a node after a TOPLOC rejection (§2.4.2 inference validation).
    /// A held task is requeued — the *node* is untrusted, the task spec is
    /// the pool's own work and goes back to the queue.
    pub fn slash(&self, node: u64, reason: &str) {
        let _ = self.ledger.submit(
            Tx::Slash { pool_id: self.pool_id, node, reason: reason.to_string() },
            &self.identity,
        );
        let mut inner = self.inner.lock().unwrap();
        let orphan = inner.nodes.get_mut(&node).and_then(|st| {
            st.status = NodeStatus::Dead;
            st.current_task.take()
        });
        if let Some(task) = orphan {
            self.tasks_requeued.inc();
            inner.queue.push_front(task);
        }
    }

    pub fn status(&self, node: u64) -> Option<NodeStatus> {
        self.inner.lock().unwrap().nodes.get(&node).map(|s| s.status)
    }

    pub fn active_nodes(&self) -> Vec<u64> {
        self.inner
            .lock()
            .unwrap()
            .nodes
            .iter()
            .filter(|(_, s)| s.status == NodeStatus::Active)
            .map(|(a, _)| *a)
            .collect()
    }

    pub fn logs(&self, node: u64) -> Vec<String> {
        self.inner
            .lock()
            .unwrap()
            .nodes
            .get(&node)
            .map(|s| s.logs.iter().cloned().collect())
            .unwrap_or_default()
    }

    pub fn queue_len(&self) -> usize {
        self.inner.lock().unwrap().queue.len()
    }

    /// Active nodes currently holding a task. The churn harness picks its
    /// crash victims from these, so a kill always orphans real work.
    pub fn nodes_with_tasks(&self) -> Vec<u64> {
        self.inner
            .lock()
            .unwrap()
            .nodes
            .iter()
            .filter(|(_, s)| s.status == NodeStatus::Active && s.current_task.is_some())
            .map(|(a, _)| *a)
            .collect()
    }

    /// Tasks currently assigned to live nodes (not queued, not finished).
    pub fn tasks_in_flight(&self) -> usize {
        self.inner
            .lock()
            .unwrap()
            .nodes
            .values()
            .filter(|s| s.status != NodeStatus::Dead && s.current_task.is_some())
            .count()
    }
}

fn handle(orch: &Orchestrator, req: &Request) -> Response {
    match (req.method.as_str(), req.path.as_str()) {
        ("POST", "/heartbeat") => {
            let Ok(j) = req.json() else { return Response::error(400, "bad json") };
            let Some(node) = j.get("node").and_then(Json::as_u64) else {
                return Response::error(400, "missing node");
            };
            let log = j.get("log").and_then(Json::as_str).map(str::to_string);
            let done = j.get("task_done").and_then(Json::as_u64);
            match orch.heartbeat(node, log, done) {
                Ok(Some(task)) => Response::json(&Json::obj(vec![
                    ("task_id", task.id.into()),
                    ("kind", task.kind.into()),
                    ("payload", task.payload),
                ])),
                Ok(None) => Response::json(&Json::obj(vec![("task_id", Json::Null)])),
                Err(why) => Response::error(403, &format!("heartbeat refused: {why:?}")),
            }
        }
        ("POST", "/task") => {
            let Ok(j) = req.json() else { return Response::error(400, "bad json") };
            let kind = j.get("kind").and_then(Json::as_str).unwrap_or("generic").to_string();
            let payload = j.get("payload").cloned().unwrap_or(Json::Null);
            let id = orch.create_task(&kind, payload);
            Response::json(&Json::obj(vec![("task_id", id.into())]))
        }
        ("GET", "/nodes") => {
            let nodes: Vec<Json> = orch
                .inner
                .lock()
                .unwrap()
                .nodes
                .iter()
                .map(|(a, s)| {
                    Json::obj(vec![
                        ("address", (*a).into()),
                        ("status", format!("{:?}", s.status).into()),
                        ("missed", (s.missed as u64).into()),
                    ])
                })
                .collect();
            Response::json(&Json::Arr(nodes))
        }
        ("GET", "/logs") => {
            let node = req.query_u64("node", 0);
            Response::json(&Json::Arr(orch.logs(node).into_iter().map(Json::Str).collect()))
        }
        _ => Response::error(404, "unknown endpoint"),
    }
}

impl OrchestratorServer {
    pub fn start(orch: Orchestrator) -> anyhow::Result<OrchestratorServer> {
        let o = orch.clone();
        let server = HttpServer::start(
            ServerConfig { worker_threads: 2, ..Default::default() },
            move |req| handle(&o, req),
        )?;
        Ok(OrchestratorServer { orch, server })
    }

    /// Restart path: serve on a *fixed* address so workers holding the old
    /// URL reconnect as soon as the orchestrator comes back after a bounce.
    pub fn start_on(orch: Orchestrator, addr: &str) -> anyhow::Result<OrchestratorServer> {
        let o = orch.clone();
        let server = HttpServer::start_on(
            addr,
            ServerConfig { worker_threads: 2, ..Default::default() },
            move |req| handle(&o, req),
        )?;
        Ok(OrchestratorServer { orch, server })
    }

    pub fn url(&self) -> String {
        self.server.url()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn orch() -> Orchestrator {
        let ledger = Ledger::new();
        let owner = Identity::from_seed(1);
        ledger.register_key(&owner);
        ledger
            .submit(Tx::CreatePool { domain: "dist-rl".into(), pool_id: 1, owner: owner.address }, &owner)
            .unwrap();
        Orchestrator::new(owner, ledger, 1, 30)
    }

    #[test]
    fn pull_based_task_distribution() {
        let o = orch();
        o.admit(10);
        o.admit(11);
        o.create_task("rollout", Json::Null);
        o.create_task("rollout", Json::Null);
        // First heartbeat gets task 0.
        let t = o.heartbeat(10, None, None).unwrap().unwrap();
        assert_eq!(t.id, 0);
        // Same node, still busy: nothing.
        assert!(o.heartbeat(10, None, None).unwrap().is_none());
        // Second node gets task 1.
        assert_eq!(o.heartbeat(11, None, None).unwrap().unwrap().id, 1);
        // Node 10 finishes, queue is empty.
        assert!(o.heartbeat(10, Some("done".into()), Some(0)).unwrap().is_none());
        assert_eq!(o.logs(10), vec!["done".to_string()]);
        assert_eq!(o.queue_len(), 0);
    }

    #[test]
    fn uninvited_and_slashed_heartbeats_rejected() {
        let o = orch();
        o.create_task("rollout", Json::Null);
        // Never invited: refused, no state recorded, no task handed out.
        assert_eq!(o.heartbeat(66, None, None).unwrap_err(), HeartbeatRejected::NeverInvited);
        assert_eq!(o.status(66), None);
        assert_eq!(o.queue_len(), 1);
        // Slashed after admission: refused even though it is a member.
        o.admit(9);
        o.slash(9, "toploc rejection");
        assert_eq!(o.heartbeat(9, None, None).unwrap_err(), HeartbeatRejected::Slashed);
        assert_eq!(o.queue_len(), 1);
        assert_eq!(o.heartbeats_rejected.get(), 2);
        // An admitted, unslashed node still pulls the task.
        o.admit(10);
        assert!(o.heartbeat(10, None, None).unwrap().is_some());
    }

    #[test]
    fn health_sweep_evicts_after_missed_heartbeats() {
        let o = orch();
        o.admit(7);
        o.heartbeat(7, None, None).unwrap();
        assert_eq!(o.status(7), Some(NodeStatus::Active));
        // Three sweeps past the timeout -> dead + evicted on the ledger.
        for _ in 0..3 {
            std::thread::sleep(std::time::Duration::from_millis(35));
            o.health_sweep();
        }
        assert_eq!(o.status(7), Some(NodeStatus::Dead));
        assert!(o.active_nodes().is_empty());
        // An evicted node cannot heartbeat itself back into the pool —
        // only a fresh invite restores it.
        assert_eq!(o.heartbeat(7, None, None).unwrap_err(), HeartbeatRejected::Evicted);
        assert_eq!(o.status(7), Some(NodeStatus::Dead));
        o.admit(7);
        assert_eq!(o.status(7), Some(NodeStatus::Invited));
        assert!(o.heartbeat(7, None, None).is_ok());
    }

    #[test]
    fn eviction_requeues_orphaned_task_for_another_worker() {
        let o = orch();
        o.admit(1);
        o.admit(2);
        o.create_task("rollout", Json::Str("orphan-me".into()));
        // Node 1 takes the task, then crashes (stops heartbeating).
        let t = o.heartbeat(1, None, None).unwrap().unwrap();
        assert_eq!(t.id, 0);
        assert_eq!(o.nodes_with_tasks(), vec![1]);
        assert_eq!(o.tasks_in_flight(), 1);
        // Node 2 stays alive through the sweeps; only node 1 is evicted.
        for _ in 0..3 {
            std::thread::sleep(std::time::Duration::from_millis(35));
            assert!(o.heartbeat(2, None, None).unwrap().is_none());
            o.health_sweep();
        }
        assert_eq!(o.status(1), Some(NodeStatus::Dead));
        assert_eq!(o.tasks_requeued.get(), 1);
        assert_eq!(o.queue_len(), 1);
        assert_eq!(o.tasks_in_flight(), 0);
        // The surviving worker picks the orphan up and completes it.
        let t = o.heartbeat(2, None, None).unwrap().unwrap();
        assert_eq!(t.id, 0);
        assert_eq!(t.payload.as_str().unwrap(), "orphan-me");
        assert!(o.heartbeat(2, None, Some(0)).unwrap().is_none());
        assert_eq!(o.tasks_in_flight(), 0);
        assert_eq!(o.queue_len(), 0);
    }

    #[test]
    fn slash_requeues_held_task() {
        let o = orch();
        o.admit(3);
        o.create_task("rollout", Json::Null);
        o.heartbeat(3, None, None).unwrap().unwrap();
        o.slash(3, "toploc rejection");
        assert_eq!(o.queue_len(), 1);
        assert_eq!(o.tasks_requeued.get(), 1);
    }

    #[test]
    fn heartbeats_keep_node_alive() {
        let o = orch();
        o.admit(7);
        for _ in 0..5 {
            o.heartbeat(7, None, None).unwrap();
            std::thread::sleep(std::time::Duration::from_millis(10));
            o.health_sweep();
        }
        assert_eq!(o.status(7), Some(NodeStatus::Active));
    }

    #[test]
    fn slash_marks_dead_and_ledger() {
        let o = orch();
        o.admit(9);
        o.heartbeat(9, None, None).unwrap();
        o.slash(9, "toploc rejection");
        assert_eq!(o.status(9), Some(NodeStatus::Dead));
        assert!(o.ledger.is_slashed(1, 9));
    }

    #[test]
    fn http_surface() {
        let o = orch();
        let srv = OrchestratorServer::start(o.clone()).unwrap();
        let c = HttpClient::new("n");
        let r = c
            .post_json(
                &format!("{}/task", srv.url()),
                &Json::obj(vec![("kind", "rollout".into()), ("payload", Json::Null)]),
            )
            .unwrap();
        assert_eq!(r.status, 200);
        // An uninvited heartbeat over HTTP is a 403, and hands out nothing.
        let hb = c
            .post_json(&format!("{}/heartbeat", srv.url()), &Json::obj(vec![("node", 5u64.into())]))
            .unwrap();
        assert_eq!(hb.status, 403);
        assert_eq!(o.heartbeats_rejected.get(), 1);
        // After admission the same heartbeat pulls the task.
        o.admit(5);
        let hb = c
            .post_json(&format!("{}/heartbeat", srv.url()), &Json::obj(vec![("node", 5u64.into())]))
            .unwrap();
        assert_eq!(hb.status, 200);
        let j = Json::parse(std::str::from_utf8(&hb.body).unwrap()).unwrap();
        assert_eq!(j.get("kind").unwrap().as_str().unwrap(), "rollout");
        let nodes = c.get(&format!("{}/nodes", srv.url())).unwrap();
        assert!(std::str::from_utf8(&nodes.body).unwrap().contains("Active"));
    }
}
