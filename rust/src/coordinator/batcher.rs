//! Trainer-side batching (§2.1.1, §3.3.2, §4.1): online-filter rollouts,
//! compute group advantages, pack into `[B,T]` micro-batches, recompute
//! old logprobs under the current policy, run GRPO micro-steps.

use std::sync::Arc;

use crate::rl::advantage;
use crate::rl::packing;
use crate::rl::Rollout;
use crate::runtime::{EngineHost, GrpoHp, GrpoMetrics, HostTrainState};

#[derive(Clone, Debug, Default)]
pub struct StepReport {
    pub metrics: GrpoMetrics,
    pub n_rollouts: usize,
    pub n_micro_steps: usize,
    pub discarded_groups: usize,
    pub mean_task_reward: f64,
    pub mean_length_penalty: f64,
    pub mean_reward: f64,
    pub mean_completion_len: f64,
    pub padding_fraction: f64,
}

/// One full trainer rollout-step: filter → advantages → pack → old-lp
/// recompute → `micro_steps` GRPO updates (cycling through the packed
/// batches). Returns the new state + aggregated metrics.
pub fn train_on_rollouts(
    host: &Arc<EngineHost>,
    mut state: Box<HostTrainState>,
    rollouts: Vec<Rollout>,
    hp: &GrpoHp,
    micro_steps: usize,
    faulty: bool,
) -> anyhow::Result<(Box<HostTrainState>, StepReport)> {
    let spec = host.spec().clone();
    let mut report = StepReport::default();
    let n0 = rollouts.len();
    report.mean_task_reward =
        rollouts.iter().map(|r| r.task_reward as f64).sum::<f64>() / n0.max(1) as f64;
    report.mean_length_penalty =
        rollouts.iter().map(|r| r.length_penalty as f64).sum::<f64>() / n0.max(1) as f64;
    report.mean_reward = rollouts.iter().map(|r| r.reward as f64).sum::<f64>() / n0.max(1) as f64;
    report.mean_completion_len =
        rollouts.iter().map(|r| r.completion_len() as f64).sum::<f64>() / n0.max(1) as f64;

    // Online filtering (§3.3.2): drop zero-advantage groups.
    let (kept, discarded) = advantage::online_filter(rollouts);
    report.discarded_groups = discarded;
    report.n_rollouts = kept.len();
    if kept.is_empty() {
        return Ok((state, report));
    }

    // Cross-sample packing (§4.1).
    let packed = packing::pack(&kept, spec.batch_train, spec.max_seq);
    report.padding_fraction = packed.padding_fraction;

    // Old logprobs are recomputed with the *current* policy at optimization
    // start (§2.1.1) — one logprobs call per packed batch.
    let mut batches = packed.batches;
    for mb in &mut batches {
        let (lp, _ent, _valid) = host.logprobs(
            Arc::new(state.params.clone()),
            mb.tokens.clone(),
            mb.segs.clone(),
        )?;
        mb.old_logprobs = lp;
    }

    // Micro-steps cycle over the packed batches (paper: 8 optimizer steps
    // per rollout step over the 4096-sample batch).
    let artifact = if faulty { "grpo_step_faulty" } else { "grpo_step" };
    let n_micro = micro_steps.max(1);
    let mut agg = GrpoMetrics::default();
    for i in 0..n_micro {
        let mb = batches[i % batches.len()].clone();
        let (st, m) = host.grpo_step_with(artifact, state, mb, *hp)?;
        state = st;
        agg.loss += m.loss / n_micro as f32;
        agg.gnorm += m.gnorm / n_micro as f32;
        agg.clipfrac += m.clipfrac / n_micro as f32;
        agg.entropy += m.entropy / n_micro as f32;
        agg.kl += m.kl / n_micro as f32;
        agg.ratio_max = agg.ratio_max.max(m.ratio_max);
        agg.obj_mean += m.obj_mean / n_micro as f32;
    }
    report.metrics = agg;
    report.n_micro_steps = n_micro;
    Ok((state, report))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::tokenizer;

    fn artifacts_ready() -> bool {
        crate::runtime::Runtime::artifacts_dir("nano").join("spec.json").exists()
    }

    fn mk_rollout(group: u64, reward: f32, len: usize) -> Rollout {
        let mut tokens = vec![tokenizer::BOS];
        tokens.extend((0..len as i32).map(|i| 3 + (i % 40)));
        tokens.push(tokenizer::EOS);
        Rollout {
            task_id: 0,
            group_id: group,
            policy_step: 0,
            prompt_len: 4,
            target_len: None,
            task_reward: reward,
            length_penalty: 0.0,
            reward,
            advantage: 0.0,
            sampled_probs: vec![0.2; tokens.len() - 4],
            node_address: 1,
            tokens,
        }
    }

    #[test]
    fn full_train_step_runs_and_updates_params() {
        if !artifacts_ready() {
            eprintln!("skipping: run `make artifacts`");
            return;
        }
        let host = Arc::new(EngineHost::spawn_size("nano").unwrap());
        let state = host.fresh_train_state(1).unwrap();
        let before = state.params.checksum();
        let mut rollouts = Vec::new();
        for g in 0..4u64 {
            rollouts.push(mk_rollout(g, 1.0, 10 + g as usize * 3));
            rollouts.push(mk_rollout(g, 0.0, 12 + g as usize * 2));
            rollouts.push(mk_rollout(g, if g == 0 { 1.0 } else { 0.0 }, 9));
        }
        let hp = GrpoHp::default();
        let (state, report) = train_on_rollouts(&host, state, rollouts, &hp, 3, false).unwrap();
        assert_eq!(report.n_micro_steps, 3);
        assert!(report.n_rollouts > 0);
        assert!(report.metrics.loss.is_finite());
        assert!(report.metrics.gnorm > 0.0);
        assert_ne!(state.params.checksum(), before);
        assert_eq!(state.step, 3);
        assert!(report.padding_fraction < 1.0);
    }

    #[test]
    fn all_degenerate_groups_is_a_noop() {
        if !artifacts_ready() {
            eprintln!("skipping: run `make artifacts`");
            return;
        }
        let host = Arc::new(EngineHost::spawn_size("nano").unwrap());
        let state = host.fresh_train_state(1).unwrap();
        let before = state.params.checksum();
        let rollouts = vec![mk_rollout(0, 1.0, 8), mk_rollout(0, 1.0, 9)];
        let (state, report) =
            train_on_rollouts(&host, state, rollouts, &GrpoHp::default(), 2, false).unwrap();
        assert_eq!(report.n_rollouts, 0);
        assert_eq!(report.discarded_groups, 1);
        assert_eq!(state.params.checksum(), before);
    }
}
