//! Deterministic async-k RL pipeline (§3.2, Fig 6/7): the trainer loop with
//! an explicit policy-version queue. Rollouts for step s are generated
//! with the policy from step s-k (k=0 sync, k=1 centralized one-step,
//! k>=2 decentralized SHARDCAST-delay) — in-process and fully reproducible,
//! used by every recipe experiment (Figs 7-12). The free-running threaded
//! swarm with real HTTP lives in coordinator::swarm.

use std::collections::VecDeque;
use std::sync::Arc;

use crate::config::RunConfig;
use crate::coordinator::batcher::train_on_rollouts;
use crate::coordinator::gen::RolloutGenerator;
use crate::coordinator::pretrain;
use crate::coordinator::step::{filter_groups, record_step};
use crate::runtime::{EngineHost, HostTrainState, ParamSet};
use crate::tasks::dataset::{Dataset, DatasetConfig};
use crate::util::metrics::Series;
use crate::verifier::Registry;

pub struct SyncPipeline {
    pub cfg: RunConfig,
    pub host: Arc<EngineHost>,
    pub dataset: Arc<Dataset>,
    pub generator: RolloutGenerator,
    pub series: Series,
}

impl SyncPipeline {
    pub fn new(cfg: RunConfig) -> anyhow::Result<SyncPipeline> {
        let host = Arc::new(EngineHost::spawn_size(&cfg.model)?);
        let registry = Arc::new(Registry::default());
        let dataset = Arc::new(Dataset::generate(
            &registry,
            &DatasetConfig {
                seed: cfg.seed,
                mix: cfg.env_mix.clone(),
                ..Default::default()
            },
        )?);
        let generator = RolloutGenerator::with_registry(
            Arc::clone(&host),
            Arc::clone(&dataset),
            &cfg,
            registry,
        )?;
        Ok(SyncPipeline { cfg, host, dataset, generator, series: Series::default() })
    }

    /// The environment registry this pipeline dispatches through.
    pub fn registry(&self) -> &Registry {
        &self.generator.registry
    }

    /// Replace the dataset (offline filtering experiments). The same
    /// fingerprint invariant as construction: the incoming dataset must
    /// have been built from this pipeline's registry (`Dataset::filtered`
    /// preserves the fingerprint, so filtering experiments pass freely).
    pub fn set_dataset(&mut self, dataset: Dataset) -> anyhow::Result<()> {
        anyhow::ensure!(
            dataset.fingerprint == self.registry().fingerprint(),
            "dataset fingerprint {:#x} != registry fingerprint {:#x}",
            dataset.fingerprint,
            self.registry().fingerprint()
        );
        let d = Arc::new(dataset);
        self.dataset = Arc::clone(&d);
        self.generator.dataset = d;
        Ok(())
    }

    /// Init + pretrain the base model.
    pub fn bootstrap(&self) -> anyhow::Result<Box<HostTrainState>> {
        let state = self.host.fresh_train_state(self.cfg.seed as u32)?;
        pretrain::pretrain(
            &self.host,
            state,
            self.registry(),
            &self.dataset,
            &self.cfg,
            self.cfg.pretrain_steps,
            &self.series,
        )
    }

    /// Estimate pass@k for every task with the given policy (offline
    /// filtering, §3.3.1). Returns (task_id, passes) stats.
    pub fn estimate_pass_at_k(
        &self,
        params: &Arc<ParamSet>,
        k: usize,
        task_limit: usize,
    ) -> anyhow::Result<crate::rl::filtering::PassStats> {
        let mut stats = crate::rl::filtering::PassStats::default();
        let spec = self.host.spec().clone();
        let ids: Vec<u64> = self.dataset.tasks.iter().map(|t| t.id).take(task_limit).collect();
        let opts = crate::runtime::GenOpts {
            max_new: self.cfg.max_new_tokens,
            temperature: self.cfg.temperature,
            commit_interval: spec.toploc_interval,
        };
        for chunk in ids.chunks(spec.batch_infer / k.max(1)) {
            let mut prompts = Vec::new();
            for id in chunk {
                let task = self.dataset.get(*id).unwrap();
                let toks = crate::data::tokenizer::encode_prompt(&task.prompt);
                for _ in 0..k {
                    prompts.push(toks.clone());
                }
            }
            if prompts.is_empty() {
                continue;
            }
            let gens = self.host.generate(Arc::clone(params), prompts, opts, 0xF117 ^ chunk[0])?;
            for (i, id) in chunk.iter().enumerate() {
                let task = self.dataset.get(*id).unwrap();
                let passes = (0..k)
                    .filter(|&g| {
                        let gen = &gens[i * k + g];
                        let completion = crate::data::tokenizer::decode_clean(
                            &gen.tokens[gen.prompt_len..],
                        );
                        crate::rl::reward::task_reward(&self.generator.registry, task, &completion)
                            > 0.5
                    })
                    .count();
                stats.record(*id, task.env, passes);
            }
        }
        Ok(stats)
    }

    /// Run `steps` RL steps at asynchrony level `cfg.async_level`.
    /// `series_prefix` namespaces the recorded curves; `faulty` selects the
    /// Fig 11 fault-injected kernel.
    pub fn run_rl(
        &self,
        mut state: Box<HostTrainState>,
        steps: u64,
        series_prefix: &str,
        faulty: bool,
    ) -> anyhow::Result<Box<HostTrainState>> {
        let k = self.cfg.async_level;
        // Policy-version queue, bounded to the only versions that can ever
        // be consumed: the generator for step s uses the params from step
        // s-k, so after trimming the front is exactly that version and at
        // most k+1 entries are alive (previously every historical ParamSet
        // was retained — memory grew linearly with rl_steps).
        let mut published: VecDeque<Arc<ParamSet>> = VecDeque::new();
        published.push_back(Arc::new(state.params.clone()));

        for step in 0..steps {
            let gen_params = Arc::clone(published.front().expect("policy queue never empty"));

            // Online filtering loop (§3.3.2): keep sampling submissions
            // until we have enough non-degenerate groups.
            let mut rollouts = Vec::new();
            let mut groups_kept = 0usize;
            let mut submission_idx = 0u64;
            let mut extra_inference = 0usize;
            while groups_kept < self.cfg.prompts_per_step && submission_idx < 6 {
                let (sub, _gen_stats) = self.generator.generate_submission(
                    &gen_params,
                    /*node=*/ 0xA11CE,
                    step,
                    submission_idx,
                    self.cfg.prompts_per_step,
                    self.cfg.group_size,
                    // Same collision-resistant derivation as the swarm
                    // workers (the old `step * 1000 + idx * 100` base
                    // collided across submissions past 100 prompts).
                    crate::rl::group_id_base(0xA11CE, step, submission_idx),
                )?;
                let batch: Vec<crate::rl::Rollout> =
                    sub.rollouts.into_iter().map(|w| w.rollout).collect();
                let n_batch = batch.len();
                let out = filter_groups(batch);
                groups_kept += out.groups_kept;
                if submission_idx > 0 {
                    extra_inference += n_batch;
                }
                rollouts.extend(out.rollouts);
                submission_idx += 1;
            }

            let hp = crate::runtime::GrpoHp { lr: self.cfg.lr_at(step), ..self.cfg.hp };
            let (st, report) = train_on_rollouts(
                &self.host,
                state,
                rollouts,
                &hp,
                self.cfg.micro_steps,
                faulty,
            )?;
            state = st;
            published.push_back(Arc::new(state.params.clone()));
            while published.len() > (k + 1) as usize {
                published.pop_front();
            }
            record_step(&self.series, series_prefix, step, &report, extra_inference);
            crate::info!(
                "rl",
                "[{series_prefix}] step {step}: task_r {:.3} len_pen {:.3} loss {:.4} gnorm {:.3} clip {:.3} ent {:.3}",
                report.mean_task_reward,
                report.mean_length_penalty,
                report.metrics.loss,
                report.metrics.gnorm,
                report.metrics.clipfrac,
                report.metrics.entropy
            );
        }
        Ok(state)
    }

    /// Evaluate a policy on a held-out suite (Table 1). Returns the mean
    /// score in percent. Task generation and scoring both go through the
    /// pipeline's registry — the same dispatch the trainer uses.
    pub fn evaluate_suite(
        &self,
        params: &Arc<ParamSet>,
        suite: &crate::tasks::eval::Suite,
        n_tasks: usize,
    ) -> anyhow::Result<f64> {
        use crate::tasks::eval::Scoring;
        let spec = self.host.spec().clone();
        let registry = self.registry();
        let tasks = suite.tasks(registry, n_tasks)?;
        let target = match suite.scoring {
            Scoring::LengthFollow => self.cfg.reward.targets.last().copied().or(Some(32)),
            Scoring::Correctness => None,
        };
        let opts = crate::runtime::GenOpts {
            max_new: self.cfg.max_new_tokens.max(target.unwrap_or(0) + 16),
            temperature: 0.7,
            commit_interval: spec.toploc_interval,
        };
        let mut total = 0.0;
        let mut count = 0.0f64;
        for chunk in tasks.chunks(spec.batch_infer) {
            let prompts: Vec<Vec<i32>> = chunk
                .iter()
                .map(|t| crate::data::tokenizer::encode_prompt(&t.prompt_with_budget(target)))
                .collect();
            let gens = self.host.generate(Arc::clone(params), prompts, opts, 0xE7A1)?;
            for (t, g) in chunk.iter().zip(&gens) {
                let completion =
                    crate::data::tokenizer::decode_clean(&g.tokens[g.prompt_len..]);
                total += suite.score(registry, t, &completion, g.completion_len(), target);
                count += 1.0;
            }
        }
        Ok(100.0 * total / count.max(1.0))
    }
}
