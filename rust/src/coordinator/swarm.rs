//! The full decentralized swarm (Fig 1): trainer + SHARDCAST origin/relays
//! + TOPLOC validator + permissionless inference workers (protocol
//! lifecycle: discovery, signed invites, heartbeats, slashing) — all
//! free-running threads talking real HTTP over loopback, with optional
//! bandwidth shaping. Used by the e2e example, the §4.2 utilization table
//! and the swarm demo.

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use crate::config::RunConfig;
use crate::coordinator::batcher::train_on_rollouts;
use crate::coordinator::gen::RolloutGenerator;
use crate::coordinator::pretrain;
use crate::http::{HttpClient, HttpServer, Response, ServerConfig};
use crate::protocol::{DiscoveryServer, Identity, Ledger, Orchestrator, OrchestratorServer, Tx, Worker};
use crate::rl::rollout_file::Submission;
use crate::rl::Rollout;
use crate::runtime::{EngineHost, HostTrainState, ModelSpec, ParamSet};
use crate::shardcast::{Origin, Relay, ShardcastClient};
use crate::tasks::dataset::{Dataset, DatasetConfig};
use crate::toploc::{Validator, ValidatorConfig};
use crate::util::json::Json;
use crate::util::metrics::{Counter, Series};

/// Shared swarm state.
struct Shared {
    verified: Mutex<Vec<Rollout>>,
    /// Policy versions the trusted side knows (validator prefill).
    versions: Mutex<std::collections::BTreeMap<u64, Arc<ParamSet>>>,
    submissions: Mutex<Vec<Vec<u8>>>,
    current_step: AtomicU64,
    stop: AtomicBool,
    pub stats: SwarmStats,
}

#[derive(Default)]
pub struct SwarmStats {
    pub submissions_received: Counter,
    pub submissions_accepted: Counter,
    pub submissions_rejected: Counter,
    pub rollouts_verified: Counter,
    pub nodes_slashed: Counter,
    pub broadcast_bytes: Counter,
    pub decode_tokens: Counter,
}

pub struct SwarmResult {
    pub series: Series,
    pub final_state: Box<HostTrainState>,
    pub stats: Arc<SwarmStats>,
    pub ledger: Ledger,
    /// (broadcast_secs, batch_ready_secs, train_secs) per RL step.
    pub step_timings: Vec<(f64, f64, f64)>,
}

pub struct Swarm {
    pub cfg: RunConfig,
    pub host: Arc<EngineHost>,
    pub dataset: Arc<Dataset>,
}

impl Swarm {
    pub fn new(cfg: RunConfig) -> anyhow::Result<Swarm> {
        let host = Arc::new(EngineHost::spawn_size(&cfg.model)?);
        let dataset = Arc::new(Dataset::generate(&DatasetConfig {
            seed: cfg.seed,
            n_math: cfg.n_math,
            n_code: cfg.n_code,
            ..Default::default()
        }));
        Ok(Swarm { cfg, host, dataset })
    }

    /// Run the full decentralized pipeline for `cfg.rl_steps` steps.
    /// `evil_worker`: if true, one worker submits tampered rollouts and
    /// must get slashed (swarm_demo uses this).
    pub fn run(&self, pretrain_steps: u64, evil_worker: bool) -> anyhow::Result<SwarmResult> {
        let cfg = &self.cfg;
        let spec = self.host.spec().clone();
        let series = Series::default();
        let shared = Arc::new(Shared {
            verified: Mutex::new(Vec::new()),
            versions: Mutex::new(Default::default()),
            submissions: Mutex::new(Vec::new()),
            current_step: AtomicU64::new(0),
            stop: AtomicBool::new(false),
            stats: SwarmStats::default(),
        });

        // --- protocol substrate ---
        let ledger = Ledger::new();
        let owner = Identity::from_seed(cfg.seed ^ 0x0FF1CE);
        ledger.register_key(&owner);
        ledger.submit(
            Tx::CreatePool { domain: "dist-rl".into(), pool_id: 1, owner: owner.address },
            &owner,
        )?;
        let discovery = DiscoveryServer::start("pool-token", 600_000)?;
        let orch = Orchestrator::new(owner, ledger.clone(), 1, 2_000);
        let _orch_srv = OrchestratorServer::start(orch.clone())?;

        // --- shardcast tier ---
        let origin = Origin::start(ServerConfig::default())?;
        let relays: Vec<Relay> = (0..cfg.n_relays.max(1))
            .map(|i| {
                Relay::start(
                    &format!("relay-{i}"),
                    origin.url(),
                    ServerConfig { rate_limit_rps: 200.0, rate_limit_burst: 100.0, ..Default::default() },
                    Duration::from_millis(20),
                )
            })
            .collect::<anyhow::Result<Vec<_>>>()?;
        let relay_urls: Vec<String> = relays.iter().map(Relay::url).collect();

        // --- step/submission service (the PRIME-RL API the workers poll) ---
        let svc = Arc::clone(&shared);
        let step_srv = HttpServer::start(ServerConfig::default(), move |req| {
            match (req.method.as_str(), req.path.as_str()) {
                ("GET", "/step") => Response::json(&Json::obj(vec![(
                    "step",
                    svc.current_step.load(Ordering::SeqCst).into(),
                )])),
                ("POST", "/submit") => {
                    svc.stats.submissions_received.inc();
                    svc.submissions.lock().unwrap().push(req.body.clone());
                    Response::ok("accepted for validation")
                }
                _ => Response::error(404, "x"),
            }
        })?;

        // --- trainer bootstrap ---
        let t_boot = Instant::now();
        let mut state = self.host.fresh_train_state(cfg.seed as u32)?;
        state = pretrain::pretrain(&self.host, state, &self.dataset, cfg, pretrain_steps, &series)?;
        crate::info!("swarm", "bootstrap done in {:.1}s", t_boot.elapsed().as_secs_f64());

        // Publish checkpoint 0.
        let payload = state.params.to_bytes();
        shared.stats.broadcast_bytes.add(payload.len() as u64);
        origin.publish(0, &payload, 64 * 1024);
        shared.versions.lock().unwrap().insert(0, Arc::new(state.params.clone()));

        // --- validator thread ---
        let validator_handle = {
            let shared = Arc::clone(&shared);
            let host = Arc::clone(&self.host);
            let dataset = Arc::clone(&self.dataset);
            let orch = orch.clone();
            let reward_cfg = cfg.reward.clone();
            let vcfg = ValidatorConfig {
                expected_group: cfg.group_size,
                ..Default::default()
            };
            let max_new = cfg.max_new_tokens;
            let spec = spec.clone();
            std::thread::Builder::new().name("i2-validator".into()).spawn(move || {
                let validator = Validator::new(vcfg);
                while !shared.stop.load(Ordering::SeqCst) {
                    let next = shared.submissions.lock().unwrap().pop();
                    let Some(bytes) = next else {
                        std::thread::sleep(Duration::from_millis(10));
                        continue;
                    };
                    let verdict = validate_submission(
                        &validator, &bytes, &dataset, &reward_cfg, &host, &shared, &spec, max_new,
                    );
                    match verdict {
                        Ok(sub) => {
                            shared.stats.submissions_accepted.inc();
                            shared.stats.rollouts_verified.add(sub.rollouts.len() as u64);
                            let mut v = shared.verified.lock().unwrap();
                            v.extend(sub.rollouts.into_iter().map(|w| w.rollout));
                        }
                        Err((node, why)) => {
                            shared.stats.submissions_rejected.inc();
                            shared.stats.nodes_slashed.inc();
                            crate::warn!("validator", "rejecting node {node}: {why}");
                            orch.slash(node, &why);
                        }
                    }
                }
            })?
        };

        // --- inference worker threads (protocol lifecycle + rollouts) ---
        let mut worker_threads = Vec::new();
        let n_workers = cfg.n_workers + usize::from(evil_worker);
        for wi in 0..n_workers {
            let is_evil = evil_worker && wi == n_workers - 1;
            let identity = Identity::from_seed(cfg.seed ^ (0xBEEF + wi as u64));
            let mut worker = Worker::boot(identity, &ledger, 1, &discovery.url(), 8)?;
            orch.sweep_discovery(&discovery.url(), "pool-token");
            anyhow::ensure!(worker.is_invited(), "worker {wi} not invited");
            // Heartbeat loop (health only; rollout work is the main loop).
            worker.start_heartbeat(
                _orch_srv.url(),
                Duration::from_millis(300),
                Arc::new(|_, _| Ok("hb".into())),
            );

            let shared = Arc::clone(&shared);
            let host = Arc::clone(&self.host);
            let dataset = Arc::clone(&self.dataset);
            let generator_cfg = cfg.clone();
            let relay_urls = relay_urls.clone();
            let step_url = step_srv.url();
            let ingress = cfg.worker_ingress_bps;
            let t = std::thread::Builder::new()
                .name(format!("i2-infer-{wi}"))
                .spawn(move || {
                    let address = worker.identity.address;
                    let generator = RolloutGenerator::from_config(
                        Arc::clone(&host),
                        dataset,
                        &generator_cfg,
                    );
                    let sc = ShardcastClient::new(
                        &format!("worker-{address}"),
                        &relay_urls,
                        address,
                        true,
                    )
                    .with_ingress(ingress);
                    let http = HttpClient::new(&format!("worker-{address}"));
                    let mut held_version: Option<(u64, Arc<ParamSet>)> = None;
                    let mut submission_counter: std::collections::BTreeMap<u64, u64> =
                        Default::default();
                    while !shared.stop.load(Ordering::SeqCst) {
                        // Fetch newer weights when available (shared volume
                        // caching: only on version change).
                        if let Some(latest) = sc.latest_step() {
                            if held_version.as_ref().map(|(v, _)| *v) != Some(latest) {
                                match sc.fetch_checkpoint(latest) {
                                    Ok((bytes, report)) => {
                                        match ParamSet::from_bytes_spec(host.spec(), &bytes) {
                                            Ok(p) => {
                                                worker.volume.put("weights", bytes);
                                                crate::debug!(
                                                    "worker",
                                                    "node {address}: checkpoint {latest} in {:.2}s",
                                                    report.seconds
                                                );
                                                held_version = Some((latest, Arc::new(p)));
                                            }
                                            Err(e) => crate::warn!("worker", "bad params: {e}"),
                                        }
                                    }
                                    Err(e) => {
                                        crate::debug!("worker", "fetch {latest}: {e}");
                                        std::thread::sleep(Duration::from_millis(50));
                                    }
                                }
                            }
                        }
                        let Some((version, params)) = held_version.clone() else {
                            std::thread::sleep(Duration::from_millis(20));
                            continue;
                        };
                        let idx = submission_counter.entry(version).or_insert(0);
                        let sub = generator.generate_submission(
                            &params,
                            address,
                            version,
                            *idx,
                            generator_cfg.prompts_per_step.div_ceil(generator_cfg.n_workers),
                            generator_cfg.group_size,
                            // Group-id base unique per (node, version, idx).
                            (address << 20) ^ (version << 10) ^ (*idx << 4),
                        );
                        *idx += 1;
                        match sub {
                            Ok(mut sub) => {
                                shared.stats.decode_tokens.add(
                                    sub.rollouts
                                        .iter()
                                        .map(|r| r.rollout.completion_len() as u64)
                                        .sum(),
                                );
                                if is_evil {
                                    // Tamper: claim every rollout solved the
                                    // task (reward hacking attempt).
                                    for w in &mut sub.rollouts {
                                        w.rollout.task_reward = 1.0;
                                        w.rollout.reward = 1.0;
                                    }
                                }
                                let _ = http.post(&format!("{step_url}/submit"), sub.encode());
                            }
                            Err(e) => {
                                crate::warn!("worker", "generate: {e}");
                                std::thread::sleep(Duration::from_millis(50));
                            }
                        }
                    }
                    worker.shutdown();
                })?;
            worker_threads.push(t);
        }

        // --- trainer loop ---
        let need = cfg.prompts_per_step * cfg.group_size;
        let mut step_timings = Vec::new();
        for step in 0..cfg.rl_steps {
            shared.current_step.store(step, Ordering::SeqCst);
            let t_wait = Instant::now();
            loop {
                let n = shared.verified.lock().unwrap().len();
                if n >= need || t_wait.elapsed() > Duration::from_secs(120) {
                    break;
                }
                std::thread::sleep(Duration::from_millis(20));
            }
            let batch_ready_secs = t_wait.elapsed().as_secs_f64();
            let rollouts: Vec<Rollout> = {
                let mut v = shared.verified.lock().unwrap();
                std::mem::take(&mut *v)
            };
            anyhow::ensure!(!rollouts.is_empty(), "no verified rollouts arrived (step {step})");

            let t_train = Instant::now();
            let hp = crate::runtime::GrpoHp { lr: cfg.lr_at(step), ..cfg.hp };
            let (st, report) =
                train_on_rollouts(&self.host, state, rollouts, &hp, cfg.micro_steps, false)?;
            state = st;
            let train_secs = t_train.elapsed().as_secs_f64();

            // Broadcast the new checkpoint (overlapped with ongoing
            // inference on the workers — they keep generating with the old
            // version until the new one lands).
            let t_bcast = Instant::now();
            let payload = state.params.to_bytes();
            shared.stats.broadcast_bytes.add(payload.len() as u64);
            origin.publish(step + 1, &payload, 64 * 1024);
            shared.versions.lock().unwrap().insert(step + 1, Arc::new(state.params.clone()));
            // Wait for the relay tier to finish mirroring (broadcast time).
            let deadline = Instant::now() + Duration::from_secs(60);
            while !relays.iter().all(|r| r.store.is_complete(step + 1)) {
                if Instant::now() > deadline {
                    break;
                }
                std::thread::sleep(Duration::from_millis(10));
            }
            let broadcast_secs = t_bcast.elapsed().as_secs_f64();
            step_timings.push((broadcast_secs, batch_ready_secs, train_secs));

            series.push(step, "task_reward", report.mean_task_reward);
            series.push(step, "length_penalty", report.mean_length_penalty);
            series.push(step, "reward", report.mean_reward);
            series.push(step, "loss", report.metrics.loss as f64);
            series.push(step, "gnorm", report.metrics.gnorm as f64);
            series.push(step, "entropy", report.metrics.entropy as f64);
            series.push(step, "completion_len", report.mean_completion_len);
            series.push(step, "batch_ready_secs", batch_ready_secs);
            series.push(step, "train_secs", train_secs);
            series.push(step, "broadcast_secs", broadcast_secs);
            orch.health_sweep();
            crate::info!(
                "swarm",
                "step {step}: task_r {:.3} wait {batch_ready_secs:.1}s train {train_secs:.1}s bcast {broadcast_secs:.1}s verified {} slashed {}",
                report.mean_task_reward,
                shared.stats.rollouts_verified.get(),
                shared.stats.nodes_slashed.get()
            );
        }

        shared.stop.store(true, Ordering::SeqCst);
        for t in worker_threads {
            let _ = t.join();
        }
        let _ = validator_handle.join();

        Ok(SwarmResult {
            series,
            final_state: state,
            stats: shared.stats_arc(),
            ledger,
            step_timings,
        })
    }
}

impl Shared {
    fn stats_arc(self: &Arc<Self>) -> Arc<SwarmStats> {
        // Project the stats out of the shared block (cheap counters only).
        let s = SwarmStats::default();
        s.submissions_received.add(self.stats.submissions_received.get());
        s.submissions_accepted.add(self.stats.submissions_accepted.get());
        s.submissions_rejected.add(self.stats.submissions_rejected.get());
        s.rollouts_verified.add(self.stats.rollouts_verified.get());
        s.nodes_slashed.add(self.stats.nodes_slashed.get());
        s.broadcast_bytes.add(self.stats.broadcast_bytes.get());
        s.decode_tokens.add(self.stats.decode_tokens.get());
        Arc::new(s)
    }
}

/// Full validation of one submission (all five TOPLOC stages). Returns the
/// submission on success or (node, reason) for slashing.
#[allow(clippy::too_many_arguments)]
fn validate_submission(
    validator: &Validator,
    bytes: &[u8],
    dataset: &Dataset,
    reward_cfg: &crate::rl::reward::RewardConfig,
    host: &Arc<EngineHost>,
    shared: &Arc<Shared>,
    spec: &ModelSpec,
    max_new: usize,
) -> Result<Submission, (u64, String)> {
    let mut sub = validator
        .check_file(bytes)
        .map_err(|e| (0u64, format!("{e:?}")))?;
    let node = sub.node_address;
    let current = shared.current_step.load(Ordering::SeqCst);
    validator
        .check_sanity(&sub, dataset, reward_cfg, current, max_new)
        .map_err(|e| (node, format!("{e:?}")))?;
    // Termination failures on individual rollouts are *soft*: an honest
    // sampler occasionally draws a low-probability EOS, so those rollouts
    // are discarded (their whole group with them) rather than slashing the
    // node. Systematic early truncation still surfaces as the node's
    // contributions evaporating.
    let mut bad_groups: Vec<u64> = Vec::new();
    for w in &sub.rollouts {
        if validator.check_termination(w, max_new, spec.max_seq).is_err() {
            bad_groups.push(w.rollout.group_id);
        }
    }
    sub.rollouts.retain(|w| !bad_groups.contains(&w.rollout.group_id));
    if sub.rollouts.is_empty() {
        // Nothing usable, but not evidence of cheating — discard quietly.
        return Ok(sub);
    }
    // Computation + sampling checks need prefill under the claimed policy.
    let params = shared
        .versions
        .lock()
        .unwrap()
        .get(&sub.step)
        .cloned()
        .ok_or((node, format!("unknown policy version {}", sub.step)))?;
    let (b, t, d, v) = (spec.batch_infer, spec.max_seq, spec.d_model, spec.vocab);
    for chunk in sub.rollouts.chunks(b) {
        let mut padded = vec![spec.pad_id; b * t];
        for (i, w) in chunk.iter().enumerate() {
            for (j, &tok) in w.rollout.tokens.iter().enumerate() {
                padded[i * t + j] = tok;
            }
        }
        let (logits, hidden) = host
            .prefill(Arc::clone(&params), padded)
            .map_err(|e| (node, format!("prefill: {e}")))?;
        for (i, w) in chunk.iter().enumerate() {
            let h = &hidden[i * t * d..(i + 1) * t * d];
            let l = &logits[i * t * v..(i + 1) * t * v];
            validator
                .check_computation(w, h, d)
                .map_err(|e| (node, format!("{e:?}")))?;
            validator
                .check_sampling(w, l, v)
                .map_err(|e| (node, format!("{e:?}")))?;
        }
    }
    Ok(sub)
}
