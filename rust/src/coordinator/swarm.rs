//! The full decentralized swarm (Fig 1): trainer + SHARDCAST origin/relays
//! + TOPLOC validator + permissionless inference workers (protocol
//! lifecycle: discovery, signed invites, heartbeats, slashing) — all
//! free-running threads talking real HTTP over loopback, with optional
//! bandwidth shaping. Used by the e2e example, the §4.2 utilization table
//! and the swarm demo.
//!
//! The trainer is genuinely two-step asynchronous (§3.2): checkpoint
//! publishing + relay mirroring run on a background [`Broadcaster`] thread
//! so training of step `s+1` overlaps broadcasting of step `s`'s weights,
//! and verified rollouts land in a version-tagged [`RolloutBuffer`] that
//! enforces the `[current - async_level, current]` staleness window.
//!
//! Inference workers generate rollouts through the continuous-batching
//! decode scheduler (`runtime::scheduler`, `gen-refill` knob): prompts
//! are prefilled straight into the KV cache, lanes refill the step a
//! sequence hits EOS, and GRPO groups share one prompt forward per refill
//! wave. Per-submission decode steps / prefill calls / lane occupancy are
//! aggregated into [`SwarmStats`].
//!
//! Verification runs as a parallel, length-bucketed pipeline
//! ([`ValidationPipeline`]): uploads land in a bounded FIFO
//! [`SubmissionQueue`], CPU checks fan out across `validator-threads`
//! pool workers, and prefill calls pack rollouts from many submissions
//! into `batch_infer` lanes padded only to their bucket's length.
//!
//! Every upload is a signed envelope (§2.4.1): workers sign at upload
//! time with their node key, and the validator's stage 0 verifies the
//! signature against the ledger's key registry before any other work —
//! slashing acts on *proven* attribution, unsigned/forged uploads are
//! counted and dropped, and replays are closed from both ends: an old
//! envelope ages out with the staleness window because the signature
//! binds the policy step, and an in-window re-post is deduplicated by a
//! first-seen `ReplayGuard` on `(node, step, submission_idx)`
//! (`require-signed-submissions` knob, on by default).
//!
//! With `sampling-rate < 1.0`, a trust-weighted [`SamplingGate`] runs
//! before the pipeline: new/flagged nodes are always fully verified,
//! proven nodes decay to spot-checks selected by the validator's
//! commit-reveal secret (unpredictable to workers, replayable by
//! auditors), and skipped uploads are admitted on stage 0 + schema alone
//! with their claimed rewards flagged unverified in `env_pass`. Workers
//! bond a stake (`Tx::Stake`) sized by `min_negative_ev_stake` so a
//! cheat caught at the sampling floor costs more than every skipped
//! cheat earned — the cheat-EV CI gate (`coordinator::cheatev`) proves
//! this end to end at rates {1.0, 0.25, 0.1}.

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use crate::config::RunConfig;
use crate::coordinator::batcher::train_on_rollouts;
use crate::coordinator::gen::{group_id_base, RolloutGenerator};
use crate::coordinator::pretrain;
use crate::coordinator::step::record_step;
use crate::coordinator::validation::{
    GateOutcome, SamplerConfig, SamplingGate, SubmissionQueue, TrustOracle, ValidationPipeline,
    ValidatorCommitment, Verdict, SUBMISSION_QUEUE_CAP, VALIDATION_WAVE,
};
use crate::http::{HttpClient, HttpServer, Response, ServerConfig};
use crate::protocol::{
    DiscoveryServer, HardwareSpec, Identity, Ledger, Orchestrator, OrchestratorServer, Tx, Worker,
};
use crate::rl::buffer::{Admission, RolloutBuffer, StalenessStats};
use crate::runtime::{EngineHost, HostTrainState, ParamSet};
use crate::shardcast::{
    plan_tree, BroadcastEncoding, BroadcastRecord, Broadcaster, Origin, Relay, RelayPeer,
    ShardcastClient,
};
use crate::tasks::dataset::{Dataset, DatasetConfig};
use crate::toploc::{Validator, ValidatorConfig};
use crate::util::json::Json;
use crate::util::metrics::{Counter, PassRates, Series};
use crate::verifier::Registry;

/// Shared swarm state.
struct Shared {
    /// Verified rollouts, tagged with their producing policy version.
    buffer: RolloutBuffer,
    /// Policy versions the trusted side knows (validator prefill). Pruned
    /// to the staleness window plus a margin — see `prune_versions`.
    versions: Mutex<std::collections::BTreeMap<u64, Arc<ParamSet>>>,
    /// Bounded FIFO between the HTTP ingest handler and the validation
    /// pipeline (condvar-woken; sheds oldest-first under overload).
    submissions: SubmissionQueue,
    current_step: AtomicU64,
    stop: AtomicBool,
    pub stats: SwarmStats,
}

#[derive(Default)]
pub struct SwarmStats {
    pub submissions_received: Counter,
    pub submissions_accepted: Counter,
    pub submissions_rejected: Counter,
    /// Valid-looking submissions outside the staleness window: dropped and
    /// counted, not slashed (being slow is not cheating).
    pub submissions_stale: Counter,
    /// Rejected submissions whose sender could not be attributed from the
    /// envelope (nothing to slash).
    pub submissions_unattributed: Counter,
    /// Uploads rejected in stage 0 because signing is required and no
    /// envelope was present. Never slashed — there is nobody to hold
    /// accountable for anonymous bytes.
    pub submissions_unsigned: Counter,
    /// Uploads rejected in stage 0 because the envelope does not prove its
    /// claimed sender (unregistered address, bad signature, or payload not
    /// matching the signed digest). Never slashed against the claimed
    /// address — that is the framing attack signing exists to close.
    pub submissions_forged: Counter,
    /// Fully-valid submissions dropped because their exact
    /// `(node, step, submission_idx)` identity was already accepted this
    /// window (`ReplayGuard`): re-posting a captured envelope must not
    /// double-weight a node's rollouts. Not slashed — the bytes are
    /// genuine, and the replayer may not be the signer.
    pub submissions_replayed: Counter,
    /// Uploads shed unvalidated because the ingest queue was full
    /// (oldest-first; a sustained non-zero rate means the validation
    /// pipeline is under-provisioned — raise `validator-threads`).
    pub submissions_shed: Counter,
    /// Submissions dropped unjudged because the validator's own side
    /// failed mid-check (engine errors and firewalled checker panics).
    /// Neither accepted nor rejected — without this counter a
    /// panic-probing attacker would be invisible in the stats.
    pub submissions_engine_failed: Counter,
    pub rollouts_verified: Counter,
    /// Rollouts dropped for staleness anywhere in the pipeline: stale
    /// submissions, buffer-push rejections, and evictions when the trainer
    /// advanced past their window.
    pub rollouts_dropped_stale: Counter,
    pub nodes_slashed: Counter,
    pub broadcast_bytes: Counter,
    pub decode_tokens: Counter,
    /// Generation-engine perf, aggregated over worker submissions (the
    /// Fig-3 gen-side mirror of the validator columns in `util_table`):
    /// `decode_step` artifact calls...
    pub gen_decode_steps: Counter,
    /// ...bucketed `prefill_kv_{T}` calls (one per refill wave+bucket)...
    pub gen_prefill_calls: Counter,
    /// ...unique prompt forwards inside those calls (group-shared prompts
    /// count once per wave, not once per rollout)...
    pub gen_prefill_prompts: Counter,
    /// ...and decode-lane occupancy: Σ lanes over all decode steps
    /// (capacity) vs Σ occupied lanes (the continuous scheduler's whole
    /// point is keeping active/slots near 1.0 under mixed-length,
    /// early-EOS workloads).
    pub gen_lane_slots: Counter,
    pub gen_lane_active: Counter,
    /// Workers evicted by the orchestrator's missed-heartbeat sweep during
    /// the run (churn visibility: crashes show up here, not as hangs).
    pub churn_workers_evicted: Counter,
    /// Tasks orphaned by evicted/slashed holders and requeued (mirrors
    /// [`Orchestrator::tasks_requeued`] at run end).
    pub churn_tasks_requeued: Counter,
    /// Failed checkpoint-fetch attempts absorbed by retry/failover across
    /// all workers (non-zero under relay churn; the checkpoints still
    /// arrived).
    pub churn_fetch_retries: Counter,
    /// Sampled validation (`sampling-rate < 1.0`): uploads the gate routed
    /// into the full six-stage pipeline...
    pub submissions_sampled_full: Counter,
    /// ...uploads admitted without reward replay / engine stages (stage 0
    /// + schema + the deterministic CPU checks; their rewards are
    /// *claimed*, tracked under "(unverified)" env_pass keys)...
    pub submissions_skipped_unverified: Counter,
    /// ...full verifications forced by a reject on record (re-escalation:
    /// the node's streak has not re-earned promotion)...
    pub submissions_escalated: Counter,
    /// ...and uploads that lost the selection draw but failed one of the
    /// gate's deterministic checks — settled at the gate, neither sampled
    /// nor skipped.
    pub submissions_rejected_unsampled: Counter,
    /// Rollouts buffered from skipped submissions — trained on under
    /// claimed rewards, backed by the sender's slashable stake.
    pub rollouts_admitted_unverified: Counter,
    /// Per-environment task pass rates over *verified* rollouts (the
    /// validator re-checked these rewards), keyed by env registry name —
    /// mixed-env runs are unobservable from one aggregate reward number.
    pub env_pass: PassRates,
    /// Per-lag histogram of rollouts consumed by the trainer:
    /// lag = training step - producing policy version.
    pub trained_by_lag: Mutex<std::collections::BTreeMap<u64, u64>>,
}

impl SwarmStats {
    /// `(lag, n_rollouts)` pairs of everything the trainer consumed.
    pub fn staleness_hist(&self) -> Vec<(u64, u64)> {
        self.trained_by_lag.lock().unwrap().iter().map(|(&l, &n)| (l, n)).collect()
    }

    /// One-line rendering of the per-lag histogram ("lag 0: 12 | lag 1: 3").
    pub fn staleness_summary(&self) -> String {
        let hist = self.staleness_hist();
        if hist.is_empty() {
            return "none".into();
        }
        hist.iter()
            .map(|(lag, n)| format!("lag {lag}: {n}"))
            .collect::<Vec<_>>()
            .join(" | ")
    }

    fn merge_staleness(&self, stats: &StalenessStats) {
        let mut hist = self.trained_by_lag.lock().unwrap();
        hist.clear();
        for &(lag, n) in &stats.trained_by_lag {
            hist.insert(lag, n);
        }
    }
}

/// Wall-clock accounting for one RL step. All `*_at` timestamps are
/// seconds relative to the run epoch shared with [`BroadcastRecord`]s in
/// [`SwarmResult::broadcasts`], so overlap between training step `s+1` and
/// the broadcast of step `s`'s checkpoint is directly measurable.
#[derive(Clone, Copy, Debug)]
pub struct StepTiming {
    pub step: u64,
    /// Background broadcast duration (publish + relay mirror) of the
    /// checkpoint this step produced (version `step + 1`); 0 when the
    /// broadcaster recorded nothing (e.g. the run was cut short).
    pub broadcast_secs: f64,
    /// Time the trainer waited for a full verified batch.
    pub batch_ready_secs: f64,
    pub train_secs: f64,
    /// Time the trainer was blocked handing the checkpoint to the
    /// broadcaster (backpressure: more than `async_level` checkpoints in
    /// flight). Non-zero means broadcast time is gating the trainer and
    /// the overlap columns alone would overstate pipelining.
    pub enqueue_wait_secs: f64,
    pub train_started_at: f64,
    pub train_ended_at: f64,
}

pub struct SwarmResult {
    pub series: Series,
    pub final_state: Box<HostTrainState>,
    pub stats: Arc<SwarmStats>,
    pub ledger: Ledger,
    pub step_timings: Vec<StepTiming>,
    /// Background broadcast records, same epoch as `step_timings`.
    pub broadcasts: Vec<BroadcastRecord>,
}

impl SwarmResult {
    /// Seconds of the broadcast each step *produced* (checkpoint
    /// `step + 1`) that overlapped *subsequent* training steps — the
    /// paper's "communication hidden behind compute" claim, measured
    /// rather than simulated. A slow broadcast can span several training
    /// steps; every hidden second counts. `(producing step, overlap_secs)`;
    /// the final step has no later training to hide behind and is omitted.
    pub fn broadcast_overlap(&self) -> Vec<(u64, f64)> {
        self.step_timings
            .iter()
            .filter_map(|t| {
                let b = self.broadcasts.iter().find(|r| r.step == t.step + 1)?;
                let later: Vec<&StepTiming> =
                    self.step_timings.iter().filter(|n| n.step > t.step).collect();
                if later.is_empty() {
                    return None;
                }
                // Training intervals are disjoint, so intersections sum.
                let overlap: f64 = later
                    .iter()
                    .map(|n| {
                        (b.completed_at.min(n.train_ended_at)
                            - b.started_at.max(n.train_started_at))
                            .max(0.0)
                    })
                    .sum();
                Some((t.step, overlap))
            })
            .collect()
    }

    /// The common timing table, one row per step:
    /// `[step, broadcast_s, batch_ready_s, train_s, overlap_s]` — both the
    /// broadcast duration and the overlap refer to the checkpoint this
    /// step produced.
    pub fn timing_rows(&self) -> Vec<Vec<String>> {
        self.timing_rows_with(|_, overlap| overlap.map_or("-".into(), |o| format!("{o:.2}")))
    }

    /// `timing_rows` with a custom renderer for the overlap column
    /// (receives the step's timing and its measured overlap, if any).
    pub fn timing_rows_with(
        &self,
        overlap_col: impl Fn(&StepTiming, Option<f64>) -> String,
    ) -> Vec<Vec<String>> {
        let overlaps: std::collections::BTreeMap<u64, f64> =
            self.broadcast_overlap().into_iter().collect();
        self.step_timings
            .iter()
            .map(|t| {
                vec![
                    t.step.to_string(),
                    format!("{:.2}", t.broadcast_secs),
                    format!("{:.2}", t.batch_ready_secs),
                    format!("{:.2}", t.train_secs),
                    overlap_col(t, overlaps.get(&t.step).copied()),
                ]
            })
            .collect()
    }
}

pub struct Swarm {
    pub cfg: RunConfig,
    pub host: Arc<EngineHost>,
    pub dataset: Arc<Dataset>,
    /// The environment registry every side of the swarm dispatches
    /// through (generation rewards, TOPLOC re-verification, pretrain
    /// corpus noise). Its fingerprint is stamped on the dataset, so a
    /// worker or validator holding a different registry fails loudly at
    /// construction instead of producing slashable "determinism" drift.
    pub registry: Arc<Registry>,
}

impl Swarm {
    pub fn new(cfg: RunConfig) -> anyhow::Result<Swarm> {
        let host = Arc::new(EngineHost::spawn_size(&cfg.model)?);
        let registry = Arc::new(Registry::default());
        let dataset = Arc::new(Dataset::generate(
            &registry,
            &DatasetConfig {
                seed: cfg.seed,
                mix: cfg.env_mix.clone(),
                ..Default::default()
            },
        )?);
        Ok(Swarm { cfg, host, dataset, registry })
    }

    /// Run the full decentralized pipeline for `cfg.rl_steps` steps.
    /// `evil_worker`: if true, one worker submits tampered rollouts and
    /// must get slashed (swarm_demo uses this).
    pub fn run(&self, pretrain_steps: u64, evil_worker: bool) -> anyhow::Result<SwarmResult> {
        let cfg = &self.cfg;
        let series = Series::default();
        let shared = Arc::new(Shared {
            buffer: RolloutBuffer::new(cfg.async_level),
            versions: Mutex::new(Default::default()),
            submissions: SubmissionQueue::new(SUBMISSION_QUEUE_CAP),
            current_step: AtomicU64::new(0),
            stop: AtomicBool::new(false),
            stats: SwarmStats::default(),
        });

        // --- protocol substrate ---
        let ledger = Ledger::new();
        let owner = Identity::from_seed(cfg.seed ^ 0x0FF1CE);
        ledger.register_key(&owner);
        ledger.submit(
            Tx::CreatePool { domain: "dist-rl".into(), pool_id: 1, owner: owner.address },
            &owner,
        )?;
        let discovery = DiscoveryServer::start("pool-token", 600_000)?;
        let orch = Orchestrator::new(owner, ledger.clone(), 1, 2_000);
        let _orch_srv = OrchestratorServer::start(orch.clone())?;

        // --- shardcast tier ---
        let origin = Origin::start(ServerConfig {
            egress_bytes_per_sec: cfg.origin_egress_bps,
            ..Default::default()
        })?;
        let relays: Vec<Relay> = (0..cfg.n_relays.max(1))
            .map(|i| {
                Relay::start(
                    &format!("relay-{i}"),
                    origin.url(),
                    ServerConfig { rate_limit_rps: 200.0, rate_limit_burst: 100.0, ..Default::default() },
                    Duration::from_millis(20),
                )
            })
            .collect::<anyhow::Result<Vec<_>>>()?;
        // Plan the relay tree from the same simulated hardware metadata
        // the nodes advertise (§2.4.1), fan-out-bounded, and push each
        // relay its candidate-parent list (origin always last).
        let relay_peers: Vec<RelayPeer> = relays
            .iter()
            .enumerate()
            .map(|(i, r)| RelayPeer {
                name: r.name.clone(),
                url: r.url(),
                uplink_mbps: HardwareSpec::detect(cfg.seed ^ (0x8E1A + i as u64)).uplink_mbps,
                pull_latency_ms: 0,
            })
            .collect();
        let tree = plan_tree(&origin.url(), &relay_peers, cfg.shardcast_fanout);
        for r in &relays {
            if let Some(cands) = tree.parents.get(&r.name) {
                r.set_parents(cands.clone());
            }
        }
        let relay_urls: Vec<String> = relays.iter().map(Relay::url).collect();

        // Background broadcast thread: the trainer hands checkpoints over
        // and immediately returns to training (two-step async, §3.2).
        // Delta encoding is transport-only, so it is safe to toggle here:
        // workers assemble byte-identical checkpoints either way.
        let broadcaster = Broadcaster::start_with_encoding(
            origin.store.clone(),
            relays.iter().map(|r| r.store.clone()).collect(),
            64 * 1024,
            Duration::from_secs(cfg.broadcast_timeout_secs),
            // Backpressure at the async level: the trainer may run at most
            // this many checkpoints ahead of the broadcast tier.
            cfg.async_level.max(1) as usize,
            BroadcastEncoding { delta: cfg.delta_encoding, quantize: false },
        )?;
        let epoch = broadcaster.epoch();

        // --- step/submission service (the PRIME-RL API the workers poll) ---
        let svc = Arc::clone(&shared);
        let step_srv = HttpServer::start(ServerConfig::default(), move |req| {
            match (req.method.as_str(), req.path.as_str()) {
                ("GET", "/step") => Response::json(&Json::obj(vec![(
                    "step",
                    svc.current_step.load(Ordering::SeqCst).into(),
                )])),
                ("POST", "/submit") => {
                    svc.stats.submissions_received.inc();
                    let shed = svc.submissions.push(req.body.clone());
                    if shed > 0 {
                        svc.stats.submissions_shed.add(shed);
                    }
                    Response::ok("accepted for validation")
                }
                _ => Response::error(404, "x"),
            }
        })?;

        // --- trainer bootstrap ---
        let t_boot = Instant::now();
        let mut state = self.host.fresh_train_state(cfg.seed as u32)?;
        state = pretrain::pretrain(
            &self.host,
            state,
            &self.registry,
            &self.dataset,
            cfg,
            pretrain_steps,
            &series,
        )?;
        crate::info!("swarm", "bootstrap done in {:.1}s", t_boot.elapsed().as_secs_f64());

        // Publish checkpoint 0 (through the broadcaster so even the
        // bootstrap broadcast is off the trainer thread).
        let payload = state.params.to_bytes();
        shared.stats.broadcast_bytes.add(payload.len() as u64);
        shared.versions.lock().unwrap().insert(0, Arc::new(state.params.clone()));
        broadcaster.enqueue(0, payload)?;

        // --- validator thread (drives the parallel, length-bucketed
        // validation pipeline: CPU stages fan out over a thread pool,
        // prefill calls pack lanes across submissions) ---
        let validator_handle = {
            let shared = Arc::clone(&shared);
            let host = Arc::clone(&self.host);
            let dataset = Arc::clone(&self.dataset);
            let orch = orch.clone();
            let reward_cfg = cfg.reward.clone();
            let vcfg = ValidatorConfig {
                expected_group: cfg.group_size,
                // TOPLOC enforces the same off-policy window as the trainer
                // buffer (§3.2) — not just exact-version existence.
                max_policy_lag: cfg.async_level,
                // Per-submission rollout cap = the per-worker quota every
                // worker (including the evil one) actually generates. The
                // stake sized below assumes a submission can claim at most
                // this many reward units; the validator enforces it on the
                // full path and the sampling gate's skip path alike, so a
                // skipped upload cannot inflate its claimable value past
                // what the bond prices in.
                max_rollouts_per_sub: cfg.prompts_per_step.div_ceil(cfg.n_workers)
                    * cfg.group_size,
                ..Default::default()
            };
            let max_new = cfg.max_new_tokens;
            let (threads, bucket) = (cfg.validator_threads, cfg.prefill_bucket_tokens);
            let require_signed = cfg.require_signed_submissions;
            let async_level = cfg.async_level;
            let keys_ledger = ledger.clone();
            // Built *before* the thread spawns: a registry/dataset
            // fingerprint mismatch aborts the run here, loudly, instead
            // of killing a background thread.
            let mut pipeline = ValidationPipeline::new(
                Validator::with_registry(vcfg.clone(), Arc::clone(&self.registry)),
                Arc::clone(&dataset),
                reward_cfg,
                host,
                max_new,
                threads,
                bucket,
            )?;
            if require_signed {
                // Stage 0: envelope signatures verified against the
                // ledger's key registry (key bytes never leave the
                // ledger); slashing needs proof.
                pipeline = pipeline.with_signing(Arc::new(
                    move |addr, msg: &[u8], sig: &[u8; 32]| {
                        keys_ledger.check_address_sig(addr, msg, sig)
                    },
                ));
            }
            // Trust-weighted sampling pre-stage, only when sampling is on
            // AND identities are provable — without signatures there is no
            // identity to hang trust on, so everything stays fully
            // verified. At rate 1.0 no gate exists and the wave reaches
            // the pipeline byte-identically to the pre-sampling swarm.
            let gate = (require_signed && cfg.sampling_rate < 1.0).then(|| {
                let trust_ledger = ledger.clone();
                let trust: Arc<TrustOracle> = Arc::new(move |node| trust_ledger.trust(1, node));
                SamplingGate::new(
                    // Commit-reveal secret: SIM-ONLY derivation from the
                    // run seed — anyone holding the shared RunConfig can
                    // reconstruct the selection stream. Sound here only
                    // because the whole swarm is one deterministic process
                    // and no worker code path reads it: swarmlint's
                    // `validator-secret` rule rejects any reference to
                    // `ValidatorCommitment` (or this XOR constant) from
                    // worker modules. A production validator draws the
                    // secret privately and publishes only `commitment()`.
                    ValidatorCommitment::new(cfg.seed ^ 0x5E1EC7),
                    SamplerConfig {
                        sampling_rate: cfg.sampling_rate,
                        promotion_streak: cfg.trust_promotion_streak,
                    },
                    trust,
                    Arc::clone(&dataset),
                    cfg.reward.clone(),
                    max_new,
                    self.host.spec().max_seq,
                )
            });
            let gate_validator = Validator::with_registry(vcfg, Arc::clone(&self.registry));
            // The gate re-runs stage 0 itself (selection is keyed on the
            // *proven* identity), so it gets its own oracle handle.
            let gate_signing: Option<Arc<crate::coordinator::validation::SigOracle>> =
                require_signed.then(|| {
                    let l = ledger.clone();
                    Arc::new(move |addr: u64, msg: &[u8], sig: &[u8; 32]| {
                        l.check_address_sig(addr, msg, sig)
                    }) as Arc<crate::coordinator::validation::SigOracle>
                });
            let trust_ledger = ledger.clone();
            std::thread::Builder::new().name("i2-validator".into()).spawn(move || {
                // In-window replay dedup: a captured valid envelope can be
                // re-posted before its step ages out; each (node, step,
                // idx) identity may be buffered at most once.
                let mut replay_guard = crate::coordinator::validation::ReplayGuard::new();
                while !shared.stop.load(Ordering::SeqCst) {
                    // Condvar-woken (a /submit wakes us immediately); the
                    // timeout only bounds how long a stop takes to notice.
                    let wave = shared
                        .submissions
                        .drain_wait(VALIDATION_WAVE, Duration::from_millis(100));
                    if wave.is_empty() {
                        continue;
                    }
                    let current = || shared.current_step.load(Ordering::SeqCst);
                    let versions =
                        |v: u64| shared.versions.lock().unwrap().get(&v).cloned();
                    replay_guard.advance(current().saturating_sub(async_level));
                    // Sampling pre-stage: route each raw upload. No gate
                    // (rate 1.0 / unsigned mode) means the whole wave goes
                    // to the pipeline — byte-identical to pre-sampling.
                    let mut fulls: Vec<Vec<u8>> = Vec::new();
                    let mut skips = Vec::new();
                    let mut early: Vec<Verdict> = Vec::new();
                    match &gate {
                        None => fulls = wave,
                        Some(g) => {
                            for bytes in wave {
                                match g.gate(
                                    gate_signing.as_ref(),
                                    &gate_validator,
                                    current(),
                                    bytes,
                                ) {
                                    GateOutcome::Full(b) => fulls.push(b),
                                    GateOutcome::Done(v) => early.push(v),
                                    GateOutcome::Skip(sub) => skips.push(sub),
                                }
                            }
                        }
                    }
                    // Skipped-but-admitted path: stage 0 proved the sender
                    // and every deterministic CPU check passed in the gate
                    // (sanity-minus-reward-replay, overlong, termination);
                    // replay + staleness checks still apply before the
                    // claimed rewards are buffered.
                    for sub in skips {
                        if !replay_guard.first_sighting(
                            sub.node_address,
                            sub.step,
                            sub.submission_idx,
                        ) {
                            shared.stats.submissions_replayed.inc();
                            continue;
                        }
                        let now = current();
                        if sub.step > now + 1 {
                            // No published checkpoint could have produced
                            // this: a proven fabrication — trust cannot buy
                            // a pass on arithmetic.
                            shared.stats.submissions_rejected.inc();
                            shared.stats.nodes_slashed.inc();
                            let why =
                                format!("unpublished policy version {} (current {now})", sub.step);
                            crate::warn!("validator", "rejecting node {}: {why}", sub.node_address);
                            trust_ledger.record_verification(1, sub.node_address, false);
                            orch.slash(sub.node_address, &why);
                            continue;
                        }
                        if sub.step + async_level < now {
                            shared.stats.submissions_stale.inc();
                            shared
                                .stats
                                .rollouts_dropped_stale
                                .add(sub.rollouts.len() as u64);
                            continue;
                        }
                        let n = sub.rollouts.len();
                        if n == 0 {
                            // Every group soft-dropped by the gate's
                            // termination screen: nothing to buffer, and
                            // deliberately no trust movement — a skipped
                            // upload is not verification evidence.
                            continue;
                        }
                        shared.stats.rollouts_admitted_unverified.add(n as u64);
                        // Observability must not shrink to the sampled
                        // subset: claimed rewards are tracked per-env,
                        // explicitly flagged as unverified.
                        for w in &sub.rollouts {
                            if let Some(task) = dataset.get(w.rollout.task_id) {
                                shared.stats.env_pass.record(
                                    &format!("{} (unverified)", task.env),
                                    w.rollout.task_reward > 0.5,
                                );
                            }
                        }
                        let version = sub.step;
                        let rollouts = sub.rollouts.into_iter().map(|w| w.rollout).collect();
                        if let Admission::TooStale { .. } =
                            shared.buffer.push(version, rollouts)
                        {
                            shared.stats.rollouts_dropped_stale.add(n as u64);
                        }
                    }
                    let judged = pipeline.validate_batch(fulls, &current, &versions);
                    for verdict in early.into_iter().chain(judged) {
                        match verdict {
                            Verdict::Accept(sub) => {
                                if !replay_guard.first_sighting(
                                    sub.node_address,
                                    sub.step,
                                    sub.submission_idx,
                                ) {
                                    // Genuine bytes, already consumed:
                                    // dropped + counted, never slashed
                                    // (the replayer may not be the signer).
                                    shared.stats.submissions_replayed.inc();
                                    crate::warn!(
                                        "validator",
                                        "dropping replayed submission (node {}, step {}, idx {})",
                                        sub.node_address,
                                        sub.step,
                                        sub.submission_idx
                                    );
                                    continue;
                                }
                                let n = sub.rollouts.len();
                                shared.stats.submissions_accepted.inc();
                                shared.stats.rollouts_verified.add(n as u64);
                                if require_signed {
                                    // Clean full verification extends the
                                    // node's trust streak (decays its
                                    // future verify probability).
                                    trust_ledger.record_verification(
                                        1,
                                        sub.node_address,
                                        true,
                                    );
                                }
                                // Per-env pass rates over verified rollouts
                                // (rewards were re-checked in stage 2).
                                for w in &sub.rollouts {
                                    if let Some(task) = dataset.get(w.rollout.task_id) {
                                        shared
                                            .stats
                                            .env_pass
                                            .record(task.env, w.rollout.task_reward > 0.5);
                                    }
                                }
                                if n == 0 {
                                    // Every group was soft-dropped
                                    // (termination check): nothing to buffer.
                                    continue;
                                }
                                let version = sub.step;
                                let rollouts =
                                    sub.rollouts.into_iter().map(|w| w.rollout).collect();
                                if let Admission::TooStale { lag } =
                                    shared.buffer.push(version, rollouts)
                                {
                                    // Went stale between verification start
                                    // and buffer admission.
                                    shared.stats.rollouts_dropped_stale.add(n as u64);
                                    crate::debug!(
                                        "validator",
                                        "verified batch of {n} went stale (lag {lag})"
                                    );
                                }
                            }
                            Verdict::Stale { node, submitted, current, n_rollouts } => {
                                shared.stats.submissions_stale.inc();
                                shared.stats.rollouts_dropped_stale.add(n_rollouts as u64);
                                crate::debug!(
                                    "validator",
                                    "node {node}: dropping stale submission (policy {submitted}, current {current})"
                                );
                            }
                            Verdict::EngineFailure { node, why } => {
                                // Not the node's fault: drop unjudged
                                // (counted so panic-probing is visible).
                                shared.stats.submissions_engine_failed.inc();
                                let who = node.map_or_else(
                                    || "an unattributed sender".to_string(),
                                    |n| format!("node {n}"),
                                );
                                crate::warn!(
                                    "validator",
                                    "engine failure while validating {who}'s submission (dropped unjudged): {why}"
                                );
                            }
                            Verdict::Reject { node: Some(node), why } => {
                                shared.stats.submissions_rejected.inc();
                                shared.stats.nodes_slashed.inc();
                                crate::warn!("validator", "rejecting node {node}: {why}");
                                if require_signed {
                                    // Reject: streak zeroed, node back on
                                    // full verification (re-escalation).
                                    trust_ledger.record_verification(1, node, false);
                                }
                                orch.slash(node, &why);
                            }
                            Verdict::Reject { node: None, why } => {
                                // Malformed beyond attribution: count it,
                                // but never slash an address the file
                                // doesn't prove.
                                shared.stats.submissions_rejected.inc();
                                shared.stats.submissions_unattributed.inc();
                                crate::warn!(
                                    "validator",
                                    "rejecting unattributable submission: {why}"
                                );
                            }
                            Verdict::Unsigned { why } => {
                                // Signature required, none present: counted
                                // and dropped — anonymous bytes slash nobody.
                                shared.stats.submissions_rejected.inc();
                                shared.stats.submissions_unsigned.inc();
                                crate::warn!(
                                    "validator",
                                    "rejecting unsigned submission: {why}"
                                );
                            }
                            Verdict::Forged { claimed, why } => {
                                // Unprovable envelope: the claimed address
                                // is a log detail, never a slash target.
                                shared.stats.submissions_rejected.inc();
                                shared.stats.submissions_forged.inc();
                                crate::warn!(
                                    "validator",
                                    "rejecting forged submission claiming node {claimed}: {why}"
                                );
                            }
                        }
                    }
                }
                // Gate counters surface once, at shutdown (stats_arc runs
                // after this thread joins).
                if let Some(g) = &gate {
                    shared.stats.submissions_sampled_full.add(g.sampled_full.get());
                    shared.stats.submissions_skipped_unverified.add(g.skipped.get());
                    shared.stats.submissions_escalated.add(g.escalated.get());
                    shared
                        .stats
                        .submissions_rejected_unsampled
                        .add(g.rejected_unsampled.get());
                }
            })?
        };

        // --- inference worker threads (protocol lifecycle + rollouts) ---
        let mut worker_threads = Vec::new();
        let n_workers = cfg.n_workers + usize::from(evil_worker);
        for wi in 0..n_workers {
            let is_evil = evil_worker && wi == n_workers - 1;
            let identity = Identity::from_seed(cfg.seed ^ (0xBEEF + wi as u64));
            let mut worker = Worker::boot(identity, &ledger, 1, &discovery.url(), 8)?;
            orch.sweep_discovery(&discovery.url(), "pool-token");
            anyhow::ensure!(worker.is_invited(), "worker {wi} not invited");
            if cfg.require_signed_submissions {
                // Bond the stake that keeps cheating negative-EV at the
                // configured sampling floor: one submission can claim at
                // most rollouts-per-submission reward units, and a cheat
                // is caught with probability >= sampling_rate (new and
                // flagged nodes sit at 1.0), so forfeiting this stake
                // costs more than every skipped cheat could earn.
                let per_sub = (cfg.prompts_per_step.div_ceil(cfg.n_workers)
                    * cfg.group_size) as u64;
                let stake = crate::protocol::min_negative_ev_stake(
                    per_sub,
                    cfg.sampling_rate,
                    cfg.trust_stake_margin,
                );
                ledger.submit(
                    Tx::Stake { pool_id: 1, node: worker.identity.address, units: stake },
                    &worker.identity,
                )?;
            }
            // Heartbeat loop (health only; rollout work is the main
            // loop). With `--serve-lanes > 0` each beat also advertises
            // serving capacity, making the worker eligible for routed
            // user queries (serve mode; `crate::serving`).
            let serve_cap = (cfg.serve_lanes > 0).then(|| crate::serving::ServeCapacity {
                free_lanes: cfg.serve_lanes,
                max_tokens: self.host.spec().max_seq as u32,
            });
            worker.start_heartbeat_with_capacity(
                _orch_srv.url(),
                Duration::from_millis(300),
                serve_cap,
                Arc::new(|_, _| Ok("hb".into())),
            );

            let shared = Arc::clone(&shared);
            let host = Arc::clone(&self.host);
            let dataset = Arc::clone(&self.dataset);
            let registry = Arc::clone(&self.registry);
            let generator_cfg = cfg.clone();
            let relay_urls = relay_urls.clone();
            let step_url = step_srv.url();
            let ingress = cfg.worker_ingress_bps;
            let t = std::thread::Builder::new()
                .name(format!("i2-infer-{wi}"))
                .spawn(move || {
                    let address = worker.identity.address;
                    // The swarm's own registry (never a freshly-built
                    // default): with a custom env set, a default-registry
                    // worker would fail the fingerprint check and silently
                    // produce zero rollouts.
                    let generator = match RolloutGenerator::with_registry(
                        Arc::clone(&host),
                        dataset,
                        &generator_cfg,
                        registry,
                    ) {
                        Ok(g) => g,
                        Err(e) => {
                            // Registry/dataset mismatch: this worker would
                            // only produce slash-bait — refuse to run.
                            crate::warn!("worker", "node {address}: {e}");
                            worker.shutdown();
                            return;
                        }
                    };
                    let sc = ShardcastClient::new(
                        &format!("worker-{address}"),
                        &relay_urls,
                        address,
                        true,
                    )
                    .with_ingress(ingress);
                    let http = HttpClient::new(&format!("worker-{address}"));
                    let mut held_version: Option<(u64, Arc<ParamSet>)> = None;
                    let mut submission_counter: std::collections::BTreeMap<u64, u64> =
                        Default::default();
                    while !shared.stop.load(Ordering::SeqCst) {
                        // Fetch newer weights when available (shared volume
                        // caching: only on version change).
                        if let Some(latest) = sc.latest_step() {
                            if held_version.as_ref().map(|(v, _)| *v) != Some(latest) {
                                match sc.fetch_checkpoint(latest) {
                                    Ok((bytes, report)) => {
                                        shared.stats.churn_fetch_retries.add(report.retries as u64);
                                        match ParamSet::from_bytes_spec(host.spec(), &bytes) {
                                            Ok(p) => {
                                                worker.volume.put("weights", bytes);
                                                crate::debug!(
                                                    "worker",
                                                    "node {address}: checkpoint {latest} in {:.2}s",
                                                    report.seconds
                                                );
                                                held_version = Some((latest, Arc::new(p)));
                                            }
                                            Err(e) => crate::warn!("worker", "bad params: {e}"),
                                        }
                                    }
                                    Err(e) => {
                                        crate::debug!("worker", "fetch {latest}: {e}");
                                        std::thread::sleep(Duration::from_millis(50));
                                    }
                                }
                            }
                        }
                        let Some((version, params)) = held_version.clone() else {
                            std::thread::sleep(Duration::from_millis(20));
                            continue;
                        };
                        let idx = submission_counter.entry(version).or_insert(0);
                        let sub = generator.generate_submission(
                            &params,
                            address,
                            version,
                            *idx,
                            generator_cfg.prompts_per_step.div_ceil(generator_cfg.n_workers),
                            generator_cfg.group_size,
                            // Collision-resistant base unique per
                            // (node, version, idx) — full-width hash.
                            group_id_base(address, version, *idx),
                        );
                        *idx += 1;
                        match sub {
                            Ok((mut sub, gen_stats)) => {
                                shared.stats.gen_decode_steps.add(gen_stats.decode_steps);
                                shared.stats.gen_prefill_calls.add(gen_stats.prefill_calls);
                                shared.stats.gen_prefill_prompts.add(gen_stats.prefill_prompts);
                                shared.stats.gen_lane_slots.add(gen_stats.lane_slots);
                                shared.stats.gen_lane_active.add(gen_stats.lane_active);
                                shared.stats.decode_tokens.add(
                                    sub.rollouts
                                        .iter()
                                        .map(|r| r.rollout.completion_len() as u64)
                                        .sum(),
                                );
                                if is_evil {
                                    // Tamper: claim every rollout solved the
                                    // task (reward hacking attempt). The evil
                                    // worker still signs its upload — which
                                    // is what turns its slash from claimed to
                                    // *proven* attribution.
                                    for w in &mut sub.rollouts {
                                        w.rollout.task_reward = 1.0;
                                        w.rollout.reward = 1.0;
                                    }
                                }
                                // Sign at upload time (§2.4.1): the envelope
                                // binds node, step, idx and payload digest.
                                let _ = http.post(
                                    &format!("{step_url}/submit"),
                                    worker.sign_submission(&sub),
                                );
                            }
                            Err(e) => {
                                crate::warn!("worker", "generate: {e}");
                                std::thread::sleep(Duration::from_millis(50));
                            }
                        }
                    }
                    worker.shutdown();
                })?;
            worker_threads.push(t);
        }

        // --- trainer loop (pipelined: broadcast of step s overlaps
        // training of step s+1) ---
        let need = cfg.prompts_per_step * cfg.group_size;
        let batch_timeout = Duration::from_secs(cfg.batch_timeout_secs.max(1));
        let mut step_timings: Vec<StepTiming> = Vec::new();
        for step in 0..cfg.rl_steps {
            shared.current_step.store(step, Ordering::SeqCst);
            let evicted = shared.buffer.advance(step);
            if evicted > 0 {
                shared.stats.rollouts_dropped_stale.add(evicted);
                crate::debug!("swarm", "step {step}: evicted {evicted} stale buffered rollouts");
            }
            let t_wait = Instant::now();
            while shared.buffer.len() < need && t_wait.elapsed() < batch_timeout {
                std::thread::sleep(Duration::from_millis(20));
            }
            let batch_ready_secs = t_wait.elapsed().as_secs_f64();
            let rollouts = shared.buffer.drain();
            anyhow::ensure!(
                !rollouts.is_empty(),
                "no verified rollouts arrived within {}s (step {step})",
                cfg.batch_timeout_secs
            );

            let train_started_at = epoch.elapsed().as_secs_f64();
            let t_train = Instant::now();
            let hp = crate::runtime::GrpoHp { lr: cfg.lr_at(step), ..cfg.hp };
            let (st, report) =
                train_on_rollouts(&self.host, state, rollouts, &hp, cfg.micro_steps, false)?;
            state = st;
            let train_secs = t_train.elapsed().as_secs_f64();
            let train_ended_at = epoch.elapsed().as_secs_f64();

            // Hand the new checkpoint to the background broadcaster and
            // keep training: workers keep generating with the old version
            // until the new one lands on the relays.
            let payload = state.params.to_bytes();
            shared.stats.broadcast_bytes.add(payload.len() as u64);
            {
                let mut versions = shared.versions.lock().unwrap();
                versions.insert(step + 1, Arc::new(state.params.clone()));
                // Window + margin: validators never need anything older.
                let min_keep = (step + 1).saturating_sub(cfg.async_level + 1);
                versions.retain(|&v, _| v >= min_keep);
            }
            let t_enq = Instant::now();
            broadcaster.enqueue(step + 1, payload)?;
            let enqueue_wait_secs = t_enq.elapsed().as_secs_f64();

            step_timings.push(StepTiming {
                step,
                broadcast_secs: 0.0, // filled from the broadcast records below
                batch_ready_secs,
                train_secs,
                enqueue_wait_secs,
                train_started_at,
                train_ended_at,
            });
            record_step(&series, "", step, &report, 0);
            series.push(step, "batch_ready_secs", batch_ready_secs);
            series.push(step, "train_secs", train_secs);
            series.push(step, "broadcast_backpressure_secs", enqueue_wait_secs);
            series.push(
                step,
                "rollouts_dropped_stale",
                shared.stats.rollouts_dropped_stale.get() as f64,
            );
            let evicted_nodes = orch.health_sweep();
            shared.stats.churn_workers_evicted.add(evicted_nodes.len() as u64);
            crate::info!(
                "swarm",
                "step {step}: task_r {:.3} wait {batch_ready_secs:.1}s train {train_secs:.1}s verified {} stale-dropped {} slashed {}",
                report.mean_task_reward,
                shared.stats.rollouts_verified.get(),
                shared.stats.rollouts_dropped_stale.get(),
                shared.stats.nodes_slashed.get()
            );
        }

        shared.stop.store(true, Ordering::SeqCst);
        for t in worker_threads {
            let _ = t.join();
        }
        let _ = validator_handle.join();
        let broadcasts = broadcaster.finish();

        // Back-fill measured broadcast durations (checkpoint `step + 1` is
        // the one step `step` produced).
        for t in &mut step_timings {
            if let Some(r) = broadcasts.iter().find(|r| r.step == t.step + 1) {
                t.broadcast_secs = r.total_secs();
                series.push(t.step, "broadcast_secs", t.broadcast_secs);
            }
        }
        shared.stats.merge_staleness(&shared.buffer.stats());
        shared.stats.churn_tasks_requeued.add(orch.tasks_requeued.get());

        Ok(SwarmResult {
            series,
            final_state: state,
            stats: shared.stats_arc(),
            ledger,
            step_timings,
            broadcasts,
        })
    }
}

impl Shared {
    fn stats_arc(self: &Arc<Self>) -> Arc<SwarmStats> {
        // Project the stats out of the shared block (cheap counters only).
        let s = SwarmStats::default();
        s.submissions_received.add(self.stats.submissions_received.get());
        s.submissions_accepted.add(self.stats.submissions_accepted.get());
        s.submissions_rejected.add(self.stats.submissions_rejected.get());
        s.submissions_stale.add(self.stats.submissions_stale.get());
        s.submissions_unattributed.add(self.stats.submissions_unattributed.get());
        s.submissions_unsigned.add(self.stats.submissions_unsigned.get());
        s.submissions_forged.add(self.stats.submissions_forged.get());
        s.submissions_replayed.add(self.stats.submissions_replayed.get());
        s.submissions_shed.add(self.stats.submissions_shed.get());
        s.submissions_engine_failed.add(self.stats.submissions_engine_failed.get());
        s.rollouts_verified.add(self.stats.rollouts_verified.get());
        s.rollouts_dropped_stale.add(self.stats.rollouts_dropped_stale.get());
        s.nodes_slashed.add(self.stats.nodes_slashed.get());
        s.broadcast_bytes.add(self.stats.broadcast_bytes.get());
        s.decode_tokens.add(self.stats.decode_tokens.get());
        s.gen_decode_steps.add(self.stats.gen_decode_steps.get());
        s.gen_prefill_calls.add(self.stats.gen_prefill_calls.get());
        s.gen_prefill_prompts.add(self.stats.gen_prefill_prompts.get());
        s.gen_lane_slots.add(self.stats.gen_lane_slots.get());
        s.gen_lane_active.add(self.stats.gen_lane_active.get());
        s.churn_workers_evicted.add(self.stats.churn_workers_evicted.get());
        s.churn_tasks_requeued.add(self.stats.churn_tasks_requeued.get());
        s.churn_fetch_retries.add(self.stats.churn_fetch_retries.get());
        s.submissions_sampled_full.add(self.stats.submissions_sampled_full.get());
        s.submissions_skipped_unverified.add(self.stats.submissions_skipped_unverified.get());
        s.submissions_escalated.add(self.stats.submissions_escalated.get());
        s.submissions_rejected_unsampled.add(self.stats.submissions_rejected_unsampled.get());
        s.rollouts_admitted_unverified.add(self.stats.rollouts_admitted_unverified.get());
        for (env, attempts, passes) in self.stats.env_pass.snapshot() {
            s.env_pass.add(&env, attempts, passes);
        }
        // Two statements, not one: the source guard is released before the
        // destination lock is taken (same lock class — nesting them is a
        // self-deadlock pattern under swarmlint `lock-order`).
        let hist = self.stats.trained_by_lag.lock().unwrap().clone();
        *s.trained_by_lag.lock().unwrap() = hist;
        Arc::new(s)
    }
}

// `Verdict` and per-submission validation live in
// `coordinator::validation` now: the validator thread above drives the
// parallel, length-bucketed `ValidationPipeline`, and the pre-pipeline
// single-submission full-pad path survives there as
// `validate_submission_fullpad` (the bench/test baseline).
