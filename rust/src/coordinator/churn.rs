//! Churn-torture harness: the full protocol + SHARDCAST stack (ledger,
//! discovery, orchestrator, heartbeating workers, origin + relay tree)
//! driven through a deterministic churn schedule — workers crash mid-task,
//! relays die and are replaced, fresh workers join — with optional
//! server-side fault injection ([`crate::http::FaultInjector`]) layered on
//! top. Engine-free by construction (tasks are checkpoint fetches with
//! synthetic payloads), so it runs in CI without model artifacts.
//!
//! Victim selection and payload bytes all derive from
//! [`crate::util::rng::Rng`] streams of one seed, so a torture run is
//! replayable: same seed, same crashes, same kills, same join order.
//!
//! The invariants a torture run must uphold (asserted by
//! `tests/churn_e2e.rs` and gated in the `churn_bench` bin):
//! - every step's task quota completes (orphaned tasks are requeued by the
//!   health sweep, not lost);
//! - no honest node ends up slashed on the ledger (churn is not cheating);
//! - goodput under churn stays within a constant factor of fault-free;
//! - every commitment-selected fetch passes a byte-for-byte payload audit
//!   ([`ChurnConfig::sampling_rate`]; selection mirrors the validation
//!   pipeline's trust-weighted sampling gate).

use std::collections::{BTreeMap, BTreeSet};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use crate::coordinator::validation::ValidatorCommitment;
use crate::http::{FaultInjector, FaultPlan, FaultSpec, Partition, ServerConfig};
use crate::protocol::{
    DiscoveryServer, GossipAgent, GossipConfig, GossipServer, HardwareSpec, Identity, Ledger,
    Orchestrator, OrchestratorServer, PeerRole, Tx, Worker,
};
use crate::shardcast::{
    dequantize_q8, encode_delta, plan_tree, quantize_q8, reform, Manifest, Origin, Relay,
    RelayPeer, ShardcastClient, TreePlan,
};
use crate::util::json::Json;
use crate::util::metrics::Counter;
use crate::util::rng::Rng;
use crate::util::Clock;

/// Churn-pick domains (streams of the shared [`FaultPlan`]).
const DOMAIN_WORKER_CRASH: u64 = 1;
const DOMAIN_RELAY_KILL: u64 = 2;

#[derive(Clone, Debug)]
pub struct ChurnConfig {
    pub seed: u64,
    /// Checkpoint steps to publish and fully distribute.
    pub steps: u64,
    pub n_relays: usize,
    pub n_workers: usize,
    /// Synthetic checkpoint size.
    pub payload_bytes: usize,
    pub shard_bytes: usize,
    /// Fetch tasks enqueued per step (> `n_workers` keeps survivors busy
    /// while an evicted worker's orphan waits out the health sweep).
    pub tasks_per_step: usize,
    /// Process-level churn: crash a worker, kill a relay and join a fresh
    /// worker every step.
    pub churn: bool,
    /// Request-level faults injected into every relay server.
    pub server_faults: Option<FaultSpec>,
    /// Fraction of completed fetches whose payload is fully audited
    /// (re-derived from the publisher's seed and compared byte for byte).
    /// Selection comes from a validator commitment over `(step, node)`,
    /// so workers cannot predict which downloads get checked; `1.0`
    /// audits every fetch.
    pub sampling_rate: f64,
    /// Per-step liveness deadline; a step that cannot finish its quota in
    /// this window ends the run early (reported, not hung).
    pub step_timeout: Duration,
}

impl Default for ChurnConfig {
    fn default() -> ChurnConfig {
        ChurnConfig {
            seed: 7,
            steps: 5,
            n_relays: 3,
            n_workers: 3,
            payload_bytes: 64 * 1024,
            shard_bytes: 8 * 1024,
            tasks_per_step: 12,
            churn: false,
            server_faults: None,
            sampling_rate: 1.0,
            step_timeout: Duration::from_secs(30),
        }
    }
}

/// What a torture run survived and what it cost.
#[derive(Debug, Clone)]
pub struct ChurnReport {
    /// Steps whose full task quota completed within the deadline.
    pub steps_completed: u64,
    /// Fetch tasks that completed (may exceed the quota under churn: a
    /// crashed worker's orphan re-executes on another worker).
    pub tasks_completed: u64,
    /// Failed fetch attempts absorbed by retry/failover.
    pub fetch_retries: u64,
    /// Re-parent events observed on relays still alive at the end.
    pub reparent_events: u64,
    pub workers_crashed: u64,
    pub workers_joined: u64,
    pub relays_killed: u64,
    pub relays_restarted: u64,
    /// Evictions by the orchestrator's health sweep.
    pub workers_evicted: u64,
    /// Orphaned tasks requeued on eviction ([`Orchestrator::tasks_requeued`]).
    pub tasks_requeued: u64,
    /// Workers slashed on the ledger — must stay 0: churn is not cheating.
    pub honest_slashed: u64,
    /// Completed fetches whose payload was fully audited (commitment-
    /// selected at [`ChurnConfig::sampling_rate`]) — every one matched.
    pub audits_full: u64,
    /// Completed fetches admitted without a payload audit.
    pub audits_skipped: u64,
    pub elapsed_secs: f64,
    pub step_secs: Vec<f64>,
}

/// Shared spot-check spec for every worker's fetch handler.
struct AuditSpec {
    commitment: ValidatorCommitment,
    rate: f64,
    payload_bytes: usize,
    seed: u64,
    full: Counter,
    skipped: Counter,
}

struct WorkerSlot {
    worker: Worker,
    address: u64,
}

/// Boot a worker, get it invited + admitted, and start its heartbeat loop
/// with a fetch-task handler that downloads checkpoints through the live
/// relay directory.
#[allow(clippy::too_many_arguments)]
fn join_worker(
    identity: Identity,
    ledger: &Ledger,
    discovery_url: &str,
    orch: &Orchestrator,
    orch_url: &str,
    relay_dir: &Arc<Mutex<Vec<String>>>,
    tasks_ok: &Arc<Counter>,
    retries: &Arc<Counter>,
    audit: &Arc<AuditSpec>,
    seed: u64,
) -> anyhow::Result<WorkerSlot> {
    let mut worker = Worker::boot(identity, ledger, 1, discovery_url, 8)?;
    orch.sweep_discovery(discovery_url, "pool-token");
    anyhow::ensure!(worker.is_invited(), "worker {} not invited", worker.identity.address);
    let address = worker.identity.address;
    let dir = Arc::clone(relay_dir);
    let tasks_ok = Arc::clone(tasks_ok);
    let retries = Arc::clone(retries);
    let audit = Arc::clone(audit);
    worker.start_heartbeat(
        orch_url.to_string(),
        Duration::from_millis(25),
        Arc::new(move |task, _vol| {
            let step = task
                .payload
                .get("step")
                .and_then(Json::as_u64)
                .ok_or_else(|| anyhow::anyhow!("fetch task without step"))?;
            // The worker reports a task done even when the handler errors,
            // so resilience lives here: keep retrying with a fresh relay
            // directory snapshot until the checkpoint lands or a liveness
            // deadline passes.
            let deadline = Instant::now() + Duration::from_secs(20);
            loop {
                let urls: Vec<String> = dir.lock().unwrap().clone();
                let sc = ShardcastClient::new(
                    &format!("churn-{address}"),
                    &urls,
                    seed ^ address ^ step,
                    false,
                );
                match sc.fetch_checkpoint(step) {
                    Ok((bytes, report)) => {
                        retries.add(report.retries as u64);
                        // Trust-weighted spot-check: commitment-selected
                        // fetches re-derive the publisher's deterministic
                        // payload and compare byte for byte; the rest are
                        // admitted unaudited (shardcast's own digests
                        // still ran) and counted as such.
                        if audit.commitment.selects(step, address, 0, audit.rate) {
                            let mut prng = Rng::new(audit.seed).fold(step);
                            let expect: Vec<u8> = (0..audit.payload_bytes)
                                .map(|_| prng.range(0, 256) as u8)
                                .collect();
                            anyhow::ensure!(
                                bytes == expect,
                                "step {step}: fetched checkpoint fails audit ({} bytes)",
                                bytes.len()
                            );
                            audit.full.inc();
                        } else {
                            audit.skipped.inc();
                        }
                        tasks_ok.inc();
                        return Ok(format!("step {step}: {} bytes", bytes.len()));
                    }
                    Err(e) => {
                        retries.inc();
                        anyhow::ensure!(
                            Instant::now() < deadline,
                            "fetch {step} never succeeded: {e}"
                        );
                        std::thread::sleep(Duration::from_millis(40));
                    }
                }
            }
        }),
    );
    Ok(WorkerSlot { worker, address })
}

fn start_relay(
    slot: usize,
    generation: u64,
    parents: Vec<String>,
    faults: &Option<FaultSpec>,
    seed: u64,
) -> anyhow::Result<Relay> {
    let cfg = ServerConfig {
        faults: faults
            .clone()
            .map(|spec| FaultInjector::from_seed(seed ^ (0xFA00 + slot as u64), spec)),
        ..Default::default()
    };
    Relay::start_with_parents(
        &format!("churn-r{slot}g{generation}"),
        parents,
        cfg,
        Duration::from_millis(10),
    )
}

/// Run the torture schedule described by `cfg`.
pub fn run_churn(cfg: &ChurnConfig) -> anyhow::Result<ChurnReport> {
    anyhow::ensure!(cfg.n_relays >= 2, "need >= 2 relays for kill/failover churn");
    anyhow::ensure!(cfg.n_workers >= 2, "need >= 2 workers for crash churn");
    let t0 = Instant::now();
    let plan = FaultPlan::new(cfg.seed, cfg.server_faults.clone().unwrap_or_default());

    // --- control plane ---
    let ledger = Ledger::new();
    let owner = Identity::from_seed(cfg.seed ^ 0x0FF1CE);
    ledger.register_key(&owner);
    ledger.submit(
        Tx::CreatePool { domain: "dist-rl".into(), pool_id: 1, owner: owner.address },
        &owner,
    )?;
    let discovery = DiscoveryServer::start("pool-token", 600_000)?;
    let mut orch = Orchestrator::new(owner, ledger.clone(), 1, 100);
    orch.max_missed = 2; // fast eviction — churn recovery is the point
    let orch_srv = OrchestratorServer::start(orch.clone())?;

    // --- shardcast tier: chain topology with the origin as everyone's
    // fallback parent, so killing relay k forces its child to re-parent ---
    let origin = Origin::start(ServerConfig::default())?;
    let relay_dir: Arc<Mutex<Vec<String>>> = Arc::new(Mutex::new(Vec::new()));
    let mut relays: Vec<Option<Relay>> = Vec::new();
    for slot in 0..cfg.n_relays {
        let parents = match relays.last().and_then(|r| r.as_ref()) {
            Some(prev) => vec![prev.url(), origin.url()],
            None => vec![origin.url()],
        };
        let r = start_relay(slot, 0, parents, &cfg.server_faults, cfg.seed)?;
        relay_dir.lock().unwrap().push(r.url());
        relays.push(Some(r));
    }

    // --- workers ---
    let tasks_ok = Arc::new(Counter::default());
    let retries = Arc::new(Counter::default());
    let audit = Arc::new(AuditSpec {
        commitment: ValidatorCommitment::new(cfg.seed ^ 0xA0D1),
        rate: cfg.sampling_rate,
        payload_bytes: cfg.payload_bytes,
        seed: cfg.seed,
        full: Counter::default(),
        skipped: Counter::default(),
    });
    let mut workers: Vec<Option<WorkerSlot>> = Vec::new();
    let mut all_addresses: Vec<u64> = Vec::new();
    for wi in 0..cfg.n_workers {
        let slot = join_worker(
            Identity::from_seed(cfg.seed ^ (0xBEEF + wi as u64)),
            &ledger,
            &discovery.url(),
            &orch,
            &orch_srv.url(),
            &relay_dir,
            &tasks_ok,
            &retries,
            &audit,
            cfg.seed,
        )?;
        all_addresses.push(slot.address);
        workers.push(Some(slot));
    }

    let mut report = ChurnReport {
        steps_completed: 0,
        tasks_completed: 0,
        fetch_retries: 0,
        reparent_events: 0,
        workers_crashed: 0,
        workers_joined: 0,
        relays_killed: 0,
        relays_restarted: 0,
        workers_evicted: 0,
        tasks_requeued: 0,
        honest_slashed: 0,
        audits_full: 0,
        audits_skipped: 0,
        elapsed_secs: 0.0,
        step_secs: Vec::new(),
    };

    let mut tasks_created: u64 = 0;
    let mut joined: u64 = 0;
    'steps: for step in 1..=cfg.steps {
        let t_step = Instant::now();
        // Deterministic synthetic checkpoint for this step.
        let mut prng = Rng::new(cfg.seed).fold(step);
        let payload: Vec<u8> = (0..cfg.payload_bytes).map(|_| prng.range(0, 256) as u8).collect();
        origin.publish(step, &payload, cfg.shard_bytes);

        // Enqueue the step's quota first, give the 25 ms heartbeats a
        // moment to pick tasks up, and only then churn — so crashes land
        // mid-task and relay kills land mid-download.
        for _ in 0..cfg.tasks_per_step {
            orch.create_task("fetch", Json::obj(vec![("step", step.into())]));
            tasks_created += 1;
        }

        if cfg.churn {
            std::thread::sleep(Duration::from_millis(60));

            // Restart one slot that died in an earlier step, so the tier
            // keeps roughly constant size across the run. Its preferred
            // parent may be the relay killed below — then the fallback
            // chain (-> origin) is what keeps it mirroring.
            if let Some(slot) = (0..relays.len()).find(|&i| relays[i].is_none()) {
                let live_parent = relays.iter().flatten().next().map(Relay::url);
                let parents = match live_parent {
                    Some(p) => vec![p, origin.url()],
                    None => vec![origin.url()],
                };
                let r = start_relay(slot, step, parents, &cfg.server_faults, cfg.seed)?;
                relay_dir.lock().unwrap().push(r.url());
                relays[slot] = Some(r);
                report.relays_restarted += 1;
            }

            // Kill one live relay (never the last one standing): clients
            // lose it mid-run and must fail over + quarantine it.
            let live: Vec<usize> = (0..relays.len()).filter(|&i| relays[i].is_some()).collect();
            if live.len() > 1 {
                let victim = live[plan.pick(DOMAIN_RELAY_KILL, step, live.len())];
                if let Some(r) = relays[victim].take() {
                    let url = r.url();
                    drop(r);
                    relay_dir.lock().unwrap().retain(|u| u != &url);
                    report.relays_killed += 1;
                }
            }

            // Crash a worker — preferring one that holds a task, so the
            // orphan-requeue path is exercised.
            let holding = orch.nodes_with_tasks();
            let live: Vec<usize> = (0..workers.len()).filter(|&i| workers[i].is_some()).collect();
            if live.len() > 1 {
                let by_addr = |addr: u64| {
                    live.iter()
                        .copied()
                        .find(|&i| workers[i].as_ref().is_some_and(|w| w.address == addr))
                };
                let victim = holding
                    .get(plan.pick(DOMAIN_WORKER_CRASH, step, holding.len().max(1)))
                    .copied()
                    .and_then(by_addr)
                    .unwrap_or_else(|| live[plan.pick(DOMAIN_WORKER_CRASH, step, live.len())]);
                if let Some(mut w) = workers[victim].take() {
                    w.worker.shutdown();
                    report.workers_crashed += 1;
                }
            }

            // A fresh worker joins the swarm mid-run.
            joined += 1;
            let slot = join_worker(
                Identity::from_seed(cfg.seed ^ (0x7A11_0000 + joined)),
                &ledger,
                &discovery.url(),
                &orch,
                &orch_srv.url(),
                &relay_dir,
                &tasks_ok,
                &retries,
                &audit,
                cfg.seed,
            )?;
            all_addresses.push(slot.address);
            workers.push(Some(slot));
            report.workers_joined += 1;
        }

        // Wait out the step's quota, sweeping for dead nodes as we go (the
        // sweep is what requeues a crashed worker's orphaned task).
        while tasks_ok.get() < tasks_created {
            if t_step.elapsed() > cfg.step_timeout {
                crate::warn!(
                    "churn",
                    "step {step}: {} of {tasks_created} tasks after {:?} — ending run",
                    tasks_ok.get(),
                    cfg.step_timeout
                );
                break 'steps;
            }
            report.workers_evicted += orch.health_sweep().len() as u64;
            std::thread::sleep(Duration::from_millis(15));
        }
        report.steps_completed += 1;
        report.step_secs.push(t_step.elapsed().as_secs_f64());
    }

    // --- teardown + verdicts ---
    for w in workers.iter_mut().flatten() {
        w.worker.shutdown();
    }
    report.tasks_completed = tasks_ok.get();
    report.fetch_retries = retries.get();
    report.reparent_events = relays.iter().flatten().map(Relay::reparent_count).sum();
    report.tasks_requeued = orch.tasks_requeued.get();
    report.honest_slashed =
        all_addresses.iter().filter(|&&a| ledger.is_slashed(1, a)).count() as u64;
    report.audits_full = audit.full.get();
    report.audits_skipped = audit.skipped.get();
    report.elapsed_secs = t0.elapsed().as_secs_f64();
    Ok(report)
}

// ---------------------------------------------------------------------------
// Tree-churn harness: a gossip-bootstrapped swarm distributing per-step
// checkpoints through a planned SHARDCAST tree that is killed, partitioned
// and re-formed mid-epoch, with optional delta + q8 wire encoding. Drives
// the `churn_bench` tree leg and `tests/churn_e2e.rs`.
// ---------------------------------------------------------------------------

/// Logical milliseconds advanced per tree-churn step (the shared injected
/// clock that discovery TTLs and gossip record expiry run on).
const TREE_STEP_MS: u64 = 1_000;
/// Gossip record TTL in logical ms — records survive a few missed steps,
/// then age out of every view symmetrically.
const TREE_GOSSIP_TTL_MS: u64 = 5_000;
/// Harness steps a partition cut stays live before healing.
const PARTITION_STEPS: u64 = 2;

#[derive(Clone, Debug)]
pub struct TreeChurnConfig {
    pub seed: u64,
    pub steps: u64,
    pub n_relays: usize,
    pub n_workers: usize,
    /// Synthetic checkpoint size. Must be a multiple of 4: the payload is
    /// generated as little-endian `f32`s so q8 quantization is meaningful.
    pub payload_bytes: usize,
    pub shard_bytes: usize,
    /// Per-node fan-out bound for the planned tree.
    pub fanout: usize,
    /// Publish per-shard delta wires against the previous checkpoint.
    pub delta: bool,
    /// Quantize checkpoints to q8 before the manifest is built.
    pub quantize: bool,
    /// Fraction of floats rewritten per step — in contiguous spans, the
    /// way RL policy updates move layer-locally, so most q8 blocks (and
    /// hence most delta wires) stay near-empty.
    pub mutation_frac: f64,
    /// Step at which one hub relay is killed and a partition is cut
    /// between a surviving relay and its new preferred parent (0 = no
    /// faults).
    pub fault_step: u64,
    /// Per-step delivery deadline shared by all workers.
    pub step_timeout: Duration,
}

impl Default for TreeChurnConfig {
    fn default() -> TreeChurnConfig {
        TreeChurnConfig {
            seed: 11,
            steps: 6,
            n_relays: 4,
            n_workers: 3,
            payload_bytes: 64 * 1024,
            shard_bytes: 8 * 1024,
            fanout: 2,
            delta: true,
            quantize: true,
            mutation_frac: 0.05,
            fault_step: 3,
            step_timeout: Duration::from_secs(30),
        }
    }
}

/// What a tree-torture run survived and what the origin paid for it.
#[derive(Debug, Clone, Default)]
pub struct TreeChurnReport {
    /// Steps on which *every* worker assembled the checkpoint in time.
    pub steps_completed: u64,
    pub deliveries: u64,
    pub delivery_attempts: u64,
    /// `deliveries / delivery_attempts` — the binding gate wants 1.0.
    pub delivery_rate: f64,
    /// Total bytes the origin server sent (manifest polls + shards +
    /// delta wires to the tier-1 relays — workers never touch it).
    pub origin_egress_bytes: u64,
    /// Bytes workers actually pulled over the wire (delta wires where the
    /// ladder hit, full shards where it fell back).
    pub worker_wire_bytes: u64,
    /// Worker-side shards satisfied by a `/delta` wire.
    pub delta_shards: u64,
    pub relays_killed: u64,
    pub partitions_cut: u64,
    /// Connections dropped by live partition cuts — proves the cut bit.
    pub partition_refusals: u64,
    /// Parent rotations on relays still alive at the end.
    pub reparent_events: u64,
    /// Steps from the fault until every surviving relay had fully
    /// mirrored the current checkpoint again (0 = same step).
    pub reform_latency_steps: u64,
    /// Invites delivered off the orchestrator's own gossip view.
    pub invites_via_gossip: u64,
    /// Gossip records rejected across all agents (bad sig / expired).
    pub gossip_rejected: u64,
    /// After the dead relay aged out: every live agent's view held
    /// exactly the live membership.
    pub gossip_converged: bool,
    /// Hits on the central discovery list endpoint — must stay 0.
    pub list_calls: u64,
    /// Honest participants slashed on the ledger — must stay 0.
    pub honest_slashed: u64,
    pub elapsed_secs: f64,
}

struct TreeWorker {
    worker: Worker,
    gossip: GossipServer,
    address: u64,
    /// Previously assembled (step, published bytes) — the delta base this
    /// worker can offer on its next fetch.
    prev: Option<(u64, Vec<u8>)>,
}

/// Project the Relay-role records of a gossip view onto the tree
/// planner's input, keeping only relays this harness actually booted.
fn relay_peers_from(agent: &GossipAgent, names: &BTreeMap<u64, String>) -> Vec<RelayPeer> {
    agent
        .peers_with_role(PeerRole::Relay)
        .into_iter()
        .filter_map(|r| {
            names.get(&r.address).map(|n| RelayPeer {
                name: n.clone(),
                url: r.endpoint.clone(),
                uplink_mbps: r.uplink_mbps,
                pull_latency_ms: 0,
            })
        })
        .collect()
}

/// Run the tree-torture schedule described by `cfg`.
///
/// Membership converges through gossip alone (the discovery list endpoint
/// is never consulted — [`TreeChurnReport::list_calls`] proves it), the
/// relay tree is planned from the gossiped view's advertised uplinks, and
/// at [`TreeChurnConfig::fault_step`] a hub relay dies *and* a surviving
/// relay is partitioned from its new preferred parent — mid-broadcast.
/// Every worker must still assemble a checksum-valid checkpoint for every
/// step.
pub fn run_tree_churn(cfg: &TreeChurnConfig) -> anyhow::Result<TreeChurnReport> {
    anyhow::ensure!(cfg.n_relays >= 3, "need >= 3 relays for a tree worth re-forming");
    anyhow::ensure!(cfg.n_workers >= 2, "need >= 2 workers");
    anyhow::ensure!(
        cfg.payload_bytes > 0 && cfg.payload_bytes % 4 == 0,
        "payload must be f32-aligned"
    );
    let t0 = Instant::now();

    // Logical time: one injected clock shared by discovery and every
    // gossip agent — advanced by the harness, never slept on.
    let cell = Arc::new(AtomicU64::new(1_000));
    let clock: Clock = {
        let c = Arc::clone(&cell);
        Arc::new(move || c.load(Ordering::SeqCst))
    };
    // Epidemic fan-out large enough to cover the whole swarm per tick:
    // convergence becomes deterministic instead of merely very likely.
    let gossip_fanout = cfg.n_relays + cfg.n_workers + 2;

    // --- control plane (discovery is register-only in this harness) ---
    let ledger = Ledger::new();
    let owner = Identity::from_seed(cfg.seed ^ 0x0FF1CE);
    ledger.register_key(&owner);
    ledger.submit(
        Tx::CreatePool { domain: "dist-rl".into(), pool_id: 1, owner: owner.address },
        &owner,
    )?;
    let discovery = DiscoveryServer::start_with_clock("pool-token", 600_000, Arc::clone(&clock))?;
    let orch = Orchestrator::new(owner, ledger.clone(), 1, 100);
    let orch_srv = OrchestratorServer::start(orch.clone())?;
    // The orchestrator's gossip half signs with the same pool-owner key
    // (`Identity::from_seed` is deterministic).
    let orch_gossip = GossipServer::start(
        Arc::new(Identity::from_seed(cfg.seed ^ 0x0FF1CE)),
        ledger.clone(),
        GossipConfig {
            role: PeerRole::Orchestrator,
            endpoint: orch_srv.url(),
            ttl_ms: TREE_GOSSIP_TTL_MS,
            fanout: gossip_fanout,
            seed: cfg.seed,
            ..GossipConfig::default()
        },
        Arc::clone(&clock),
    )?;

    // --- origin + relay tier: every server shares one Partition handle,
    // so cuts can sever any (client, server-domain) edge mid-run ---
    let partition = Partition::new();
    let origin = Origin::start(ServerConfig {
        partition: Some(Arc::clone(&partition)),
        domain: "origin".into(),
        ..ServerConfig::default()
    })?;
    let mut uprng = Rng::new(cfg.seed ^ 0x0B15);
    let mut relays: Vec<Option<Relay>> = Vec::new();
    let mut relay_gossip: Vec<Option<GossipServer>> = Vec::new();
    let mut names: Vec<String> = Vec::new();
    let mut addr_names: BTreeMap<u64, String> = BTreeMap::new();
    for slot in 0..cfg.n_relays {
        let name = format!("t{slot}");
        let relay = Relay::start_with_parents(
            &name,
            vec![origin.url()],
            ServerConfig {
                partition: Some(Arc::clone(&partition)),
                domain: name.clone(),
                ..ServerConfig::default()
            },
            Duration::from_millis(10),
        )?;
        let id = Arc::new(Identity::from_seed(cfg.seed ^ (0x0E1A_0000 + slot as u64)));
        ledger.register_key(&id);
        addr_names.insert(id.address, name.clone());
        let gs = GossipServer::start(
            Arc::clone(&id),
            ledger.clone(),
            GossipConfig {
                role: PeerRole::Relay,
                endpoint: relay.url(),
                // Heterogeneous advertised uplinks: what the planner ranks.
                uplink_mbps: 50 + uprng.range(0, 950),
                ttl_ms: TREE_GOSSIP_TTL_MS,
                fanout: gossip_fanout,
                seed: cfg.seed ^ slot as u64,
                ..GossipConfig::default()
            },
            Arc::clone(&clock),
        )?;
        gs.agent.add_seed(&orch_gossip.url());
        relays.push(Some(relay));
        relay_gossip.push(Some(gs));
        names.push(name);
    }

    // --- workers: boot (registers with discovery — the allowed half),
    // gossip from the public bootnode URL, get invited *through gossip* ---
    let mut workers: Vec<TreeWorker> = Vec::new();
    let mut wseed = cfg.seed ^ 0xBEEF;
    while workers.len() < cfg.n_workers {
        let seed_i = wseed;
        wseed = wseed.wrapping_add(1);
        // Hardware-gated boot: skip simulated-incompatible identities.
        let Ok(worker) = Worker::boot(Identity::from_seed(seed_i), &ledger, 1, &discovery.url(), 8)
        else {
            continue;
        };
        let address = worker.identity.address;
        let endpoint = worker
            .endpoint()
            .ok_or_else(|| anyhow::anyhow!("worker {address} has no invite endpoint"))?;
        let hw = HardwareSpec::detect(address);
        let gs = GossipServer::start(
            Arc::new(Identity::from_seed(seed_i)),
            ledger.clone(),
            GossipConfig {
                role: PeerRole::Worker,
                endpoint,
                uplink_mbps: hw.uplink_mbps,
                vram_gb: hw.vram_gb,
                ttl_ms: TREE_GOSSIP_TTL_MS,
                fanout: gossip_fanout,
                seed: seed_i,
            },
            Arc::clone(&clock),
        )?;
        gs.agent.add_seed(&orch_gossip.url());
        workers.push(TreeWorker { worker, gossip: gs, address, prev: None });
    }

    let tick_all = |relay_gossip: &[Option<GossipServer>], workers: &[TreeWorker]| {
        orch_gossip.agent.tick();
        for gs in relay_gossip.iter().flatten() {
            gs.agent.tick();
        }
        for w in workers {
            w.gossip.agent.tick();
        }
    };

    // Membership + admission bootstrap, all through gossip: epidemic
    // rounds until the orchestrator's own view holds every worker, then
    // signed invites (each carrying the gossip bootstrap URL) off that
    // view. The central list endpoint is never consulted.
    let mut report = TreeChurnReport::default();
    for _round in 0..8 {
        tick_all(&relay_gossip, &workers);
        report.invites_via_gossip += orch
            .sweep_gossip(&orch_gossip.agent.peers_with_role(PeerRole::Worker), &orch_gossip.url())
            as u64;
        if workers.iter().all(|w| w.worker.is_invited()) {
            break;
        }
    }
    for w in &workers {
        anyhow::ensure!(w.worker.is_invited(), "worker {} never invited via gossip", w.address);
        anyhow::ensure!(
            w.worker.gossip_seed().as_deref() == Some(orch_gossip.url().as_str()),
            "worker {}: invite did not carry the gossip bootstrap URL",
            w.address
        );
    }

    // Plan the initial tree from the *gossiped* relay records.
    let relay_peers = relay_peers_from(&orch_gossip.agent, &addr_names);
    anyhow::ensure!(
        relay_peers.len() == cfg.n_relays,
        "orchestrator's gossip view holds {} of {} relays",
        relay_peers.len(),
        cfg.n_relays
    );
    let mut plan = plan_tree(&origin.url(), &relay_peers, cfg.fanout);
    let apply = |plan: &TreePlan, relays: &[Option<Relay>], names: &[String]| {
        for (slot, r) in relays.iter().enumerate() {
            if let (Some(r), Some(cands)) = (r.as_ref(), plan.parents.get(&names[slot])) {
                r.set_parents(cands.clone());
            }
        }
    };
    apply(&plan, &relays, &names);

    // --- step loop ---
    let n_floats = cfg.payload_bytes / 4;
    let mut frng = Rng::new(cfg.seed ^ 0xF10A7);
    let mut floats: Vec<f32> = (0..n_floats).map(|_| (frng.f64() * 2.0 - 1.0) as f32).collect();
    let mut origin_prev: Option<(u64, Vec<u8>)> = None;
    let mut reform_pending = false;
    for step in 1..=cfg.steps {
        cell.fetch_add(TREE_STEP_MS, Ordering::SeqCst);
        partition.advance_to(step);
        tick_all(&relay_gossip, &workers);
        report.invites_via_gossip += orch
            .sweep_gossip(&orch_gossip.agent.peers_with_role(PeerRole::Worker), &orch_gossip.url())
            as u64;

        // Evolve the checkpoint in a few contiguous spans, encode, publish.
        if step > 1 {
            let span = ((n_floats as f64) * cfg.mutation_frac / 4.0).ceil() as usize;
            for _ in 0..4 {
                let start = frng.usize(n_floats.saturating_sub(span).max(1));
                for f in floats.iter_mut().skip(start).take(span) {
                    *f = (frng.f64() * 2.0 - 1.0) as f32;
                }
            }
        }
        let raw: Vec<u8> = floats.iter().flat_map(|f| f.to_le_bytes()).collect();
        let published = if cfg.quantize { quantize_q8(&raw) } else { raw };
        let (manifest, shards) = Manifest::build(step, &published, cfg.shard_bytes);
        let manifest = if cfg.quantize { manifest.with_encoding("q8") } else { manifest };
        match origin_prev.as_ref().filter(|_| cfg.delta) {
            Some((bstep, bbytes)) => {
                let wires: Vec<Vec<u8>> = shards
                    .iter()
                    .enumerate()
                    .map(|(i, s)| {
                        let lo = (i * cfg.shard_bytes).min(bbytes.len());
                        let hi = ((i + 1) * cfg.shard_bytes).min(bbytes.len());
                        encode_delta(&bbytes[lo..hi], s)
                    })
                    .collect();
                origin.store.publish_full_with_deltas(manifest.with_base(*bstep), shards, wires);
            }
            None => origin.store.publish_full(manifest, shards),
        }
        if cfg.delta {
            origin_prev = Some((step, published.clone()));
        }

        if step == cfg.fault_step {
            // Let the broadcast get part-way down the tree first.
            std::thread::sleep(Duration::from_millis(30));

            // Kill a hub: the first live relay that currently has
            // children, so a whole subtree loses its preferred parent.
            let victim = (0..relays.len())
                .filter(|&i| relays[i].is_some())
                .find(|&i| plan.children_of(&names[i]) > 0)
                .or_else(|| (0..relays.len()).find(|&i| relays[i].is_some()));
            if let Some(v) = victim {
                relays[v] = None; // Drop stops the puller and the server.
                relay_gossip[v] = None;
                report.relays_killed += 1;

                // Re-form over the survivors of the gossiped view. The
                // victim's record has not expired yet — the dead-list
                // drops it, exactly as a quarantine decision would.
                let peers = relay_peers_from(&orch_gossip.agent, &addr_names);
                plan = reform(&origin.url(), &peers, std::slice::from_ref(&names[v]), cfg.fanout);
                apply(&plan, &relays, &names);
                reform_pending = true;

                // And partition one survivor from its *new* preferred
                // parent, so re-formation has to ride the fallback
                // rotation (REPARENT_AFTER) mid-epoch.
                let cut_slot = (0..relays.len()).filter(|&i| relays[i].is_some()).find(|&i| {
                    plan.parents
                        .get(&names[i])
                        .and_then(|c| c.first())
                        .is_some_and(|p| *p != origin.url())
                });
                if let Some(cs) = cut_slot {
                    let parent_url = plan.parents[&names[cs]][0].clone();
                    let parent_domain = (0..relays.len())
                        .find(|&i| relays[i].as_ref().is_some_and(|r| r.url() == parent_url))
                        .map(|i| names[i].clone())
                        .unwrap_or_else(|| "origin".to_string());
                    partition.cut(
                        &format!("relay-{}", names[cs]),
                        &parent_domain,
                        PARTITION_STEPS,
                    );
                    report.partitions_cut += 1;
                }
            }
        }

        // Harness-driven fetches: every worker must assemble this step's
        // checkpoint through the (possibly re-forming) relay tier.
        let urls: Vec<String> = relays.iter().flatten().map(Relay::url).collect();
        let step_deadline = Instant::now() + cfg.step_timeout;
        let mut delivered = 0usize;
        for w in &mut workers {
            report.delivery_attempts += 1;
            let sc = ShardcastClient::new(
                &format!("tw-{}", w.address),
                &urls,
                cfg.seed ^ w.address ^ step,
                false,
            );
            let base_owned = w.prev.clone();
            loop {
                let base = base_owned.as_ref().map(|(s, b)| (*s, b.as_slice()));
                match sc.fetch_checkpoint_with_base(step, base) {
                    Ok((bytes, rep)) => {
                        anyhow::ensure!(
                            bytes == published,
                            "step {step}: worker {} assembled {} bytes that fail the audit",
                            w.address,
                            bytes.len()
                        );
                        if cfg.quantize {
                            anyhow::ensure!(
                                dequantize_q8(&bytes)?.len() == cfg.payload_bytes,
                                "step {step}: q8 checkpoint does not dequantize back to size"
                            );
                        }
                        report.worker_wire_bytes += rep.wire_bytes as u64;
                        report.delta_shards += rep.delta_shards as u64;
                        report.deliveries += 1;
                        delivered += 1;
                        w.prev = Some((step, bytes));
                        break;
                    }
                    Err(e) => {
                        if Instant::now() > step_deadline {
                            crate::warn!(
                                "churn",
                                "tree step {step}: worker {} never assembled the checkpoint: {e}",
                                w.address
                            );
                            break;
                        }
                        std::thread::sleep(Duration::from_millis(20));
                    }
                }
            }
        }
        if delivered == workers.len() {
            report.steps_completed += 1;
        }

        // Re-formation is *done* when every surviving relay has fully
        // mirrored the current checkpoint again.
        if reform_pending {
            let deadline = Instant::now() + Duration::from_secs(3);
            loop {
                if relays.iter().flatten().all(|r| r.store.is_complete(step)) {
                    report.reform_latency_steps = step - cfg.fault_step;
                    reform_pending = false;
                    break;
                }
                if Instant::now() > deadline {
                    break;
                }
                std::thread::sleep(Duration::from_millis(15));
            }
        }
    }
    if reform_pending {
        report.reform_latency_steps = cfg.steps.saturating_sub(cfg.fault_step) + 1;
    }

    // --- teardown + verdicts: age the dead relay's records out, then
    // every live agent's view must hold exactly the live membership ---
    cell.fetch_add(TREE_GOSSIP_TTL_MS + TREE_STEP_MS, Ordering::SeqCst);
    for _ in 0..3 {
        tick_all(&relay_gossip, &workers);
    }
    let expected: BTreeSet<u64> = std::iter::once(orch_gossip.agent.address())
        .chain(relay_gossip.iter().flatten().map(|gs| gs.agent.address()))
        .chain(workers.iter().map(|w| w.address))
        .collect();
    let converged = |agent: &GossipAgent| {
        let got: BTreeSet<u64> = agent.live_peers().iter().map(|r| r.address).collect();
        got == expected
    };
    report.gossip_converged = converged(&orch_gossip.agent)
        && relay_gossip.iter().flatten().all(|gs| converged(&gs.agent))
        && workers.iter().all(|w| converged(&w.gossip.agent));

    for w in &mut workers {
        w.worker.shutdown();
    }
    report.reparent_events = relays.iter().flatten().map(Relay::reparent_count).sum();
    report.partition_refusals = partition.refused.get();
    report.list_calls = discovery.service.list_calls.get();
    report.origin_egress_bytes = origin.server.stats.bytes_out.get();
    report.gossip_rejected = orch_gossip.agent.rejected.get()
        + relay_gossip.iter().flatten().map(|gs| gs.agent.rejected.get()).sum::<u64>()
        + workers.iter().map(|w| w.gossip.agent.rejected.get()).sum::<u64>();
    report.honest_slashed = workers.iter().filter(|w| ledger.is_slashed(1, w.address)).count()
        as u64
        + addr_names.keys().filter(|&&a| ledger.is_slashed(1, a)).count() as u64;
    report.delivery_rate = if report.delivery_attempts == 0 {
        1.0
    } else {
        report.deliveries as f64 / report.delivery_attempts as f64
    };
    report.elapsed_secs = t0.elapsed().as_secs_f64();
    Ok(report)
}
