//! Churn-torture harness: the full protocol + SHARDCAST stack (ledger,
//! discovery, orchestrator, heartbeating workers, origin + relay tree)
//! driven through a deterministic churn schedule — workers crash mid-task,
//! relays die and are replaced, fresh workers join — with optional
//! server-side fault injection ([`crate::http::FaultInjector`]) layered on
//! top. Engine-free by construction (tasks are checkpoint fetches with
//! synthetic payloads), so it runs in CI without model artifacts.
//!
//! Victim selection and payload bytes all derive from
//! [`crate::util::rng::Rng`] streams of one seed, so a torture run is
//! replayable: same seed, same crashes, same kills, same join order.
//!
//! The invariants a torture run must uphold (asserted by
//! `tests/churn_e2e.rs` and gated in the `churn_bench` bin):
//! - every step's task quota completes (orphaned tasks are requeued by the
//!   health sweep, not lost);
//! - no honest node ends up slashed on the ledger (churn is not cheating);
//! - goodput under churn stays within a constant factor of fault-free;
//! - every commitment-selected fetch passes a byte-for-byte payload audit
//!   ([`ChurnConfig::sampling_rate`]; selection mirrors the validation
//!   pipeline's trust-weighted sampling gate).

use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use crate::coordinator::validation::ValidatorCommitment;
use crate::http::{FaultInjector, FaultPlan, FaultSpec, ServerConfig};
use crate::protocol::{
    DiscoveryServer, Identity, Ledger, Orchestrator, OrchestratorServer, Tx, Worker,
};
use crate::shardcast::{Origin, Relay, ShardcastClient};
use crate::util::json::Json;
use crate::util::metrics::Counter;
use crate::util::rng::Rng;

/// Churn-pick domains (streams of the shared [`FaultPlan`]).
const DOMAIN_WORKER_CRASH: u64 = 1;
const DOMAIN_RELAY_KILL: u64 = 2;

#[derive(Clone, Debug)]
pub struct ChurnConfig {
    pub seed: u64,
    /// Checkpoint steps to publish and fully distribute.
    pub steps: u64,
    pub n_relays: usize,
    pub n_workers: usize,
    /// Synthetic checkpoint size.
    pub payload_bytes: usize,
    pub shard_bytes: usize,
    /// Fetch tasks enqueued per step (> `n_workers` keeps survivors busy
    /// while an evicted worker's orphan waits out the health sweep).
    pub tasks_per_step: usize,
    /// Process-level churn: crash a worker, kill a relay and join a fresh
    /// worker every step.
    pub churn: bool,
    /// Request-level faults injected into every relay server.
    pub server_faults: Option<FaultSpec>,
    /// Fraction of completed fetches whose payload is fully audited
    /// (re-derived from the publisher's seed and compared byte for byte).
    /// Selection comes from a validator commitment over `(step, node)`,
    /// so workers cannot predict which downloads get checked; `1.0`
    /// audits every fetch.
    pub sampling_rate: f64,
    /// Per-step liveness deadline; a step that cannot finish its quota in
    /// this window ends the run early (reported, not hung).
    pub step_timeout: Duration,
}

impl Default for ChurnConfig {
    fn default() -> ChurnConfig {
        ChurnConfig {
            seed: 7,
            steps: 5,
            n_relays: 3,
            n_workers: 3,
            payload_bytes: 64 * 1024,
            shard_bytes: 8 * 1024,
            tasks_per_step: 12,
            churn: false,
            server_faults: None,
            sampling_rate: 1.0,
            step_timeout: Duration::from_secs(30),
        }
    }
}

/// What a torture run survived and what it cost.
#[derive(Debug, Clone)]
pub struct ChurnReport {
    /// Steps whose full task quota completed within the deadline.
    pub steps_completed: u64,
    /// Fetch tasks that completed (may exceed the quota under churn: a
    /// crashed worker's orphan re-executes on another worker).
    pub tasks_completed: u64,
    /// Failed fetch attempts absorbed by retry/failover.
    pub fetch_retries: u64,
    /// Re-parent events observed on relays still alive at the end.
    pub reparent_events: u64,
    pub workers_crashed: u64,
    pub workers_joined: u64,
    pub relays_killed: u64,
    pub relays_restarted: u64,
    /// Evictions by the orchestrator's health sweep.
    pub workers_evicted: u64,
    /// Orphaned tasks requeued on eviction ([`Orchestrator::tasks_requeued`]).
    pub tasks_requeued: u64,
    /// Workers slashed on the ledger — must stay 0: churn is not cheating.
    pub honest_slashed: u64,
    /// Completed fetches whose payload was fully audited (commitment-
    /// selected at [`ChurnConfig::sampling_rate`]) — every one matched.
    pub audits_full: u64,
    /// Completed fetches admitted without a payload audit.
    pub audits_skipped: u64,
    pub elapsed_secs: f64,
    pub step_secs: Vec<f64>,
}

/// Shared spot-check spec for every worker's fetch handler.
struct AuditSpec {
    commitment: ValidatorCommitment,
    rate: f64,
    payload_bytes: usize,
    seed: u64,
    full: Counter,
    skipped: Counter,
}

struct WorkerSlot {
    worker: Worker,
    address: u64,
}

/// Boot a worker, get it invited + admitted, and start its heartbeat loop
/// with a fetch-task handler that downloads checkpoints through the live
/// relay directory.
#[allow(clippy::too_many_arguments)]
fn join_worker(
    identity: Identity,
    ledger: &Ledger,
    discovery_url: &str,
    orch: &Orchestrator,
    orch_url: &str,
    relay_dir: &Arc<Mutex<Vec<String>>>,
    tasks_ok: &Arc<Counter>,
    retries: &Arc<Counter>,
    audit: &Arc<AuditSpec>,
    seed: u64,
) -> anyhow::Result<WorkerSlot> {
    let mut worker = Worker::boot(identity, ledger, 1, discovery_url, 8)?;
    orch.sweep_discovery(discovery_url, "pool-token");
    anyhow::ensure!(worker.is_invited(), "worker {} not invited", worker.identity.address);
    let address = worker.identity.address;
    let dir = Arc::clone(relay_dir);
    let tasks_ok = Arc::clone(tasks_ok);
    let retries = Arc::clone(retries);
    let audit = Arc::clone(audit);
    worker.start_heartbeat(
        orch_url.to_string(),
        Duration::from_millis(25),
        Arc::new(move |task, _vol| {
            let step = task
                .payload
                .get("step")
                .and_then(Json::as_u64)
                .ok_or_else(|| anyhow::anyhow!("fetch task without step"))?;
            // The worker reports a task done even when the handler errors,
            // so resilience lives here: keep retrying with a fresh relay
            // directory snapshot until the checkpoint lands or a liveness
            // deadline passes.
            let deadline = Instant::now() + Duration::from_secs(20);
            loop {
                let urls: Vec<String> = dir.lock().unwrap().clone();
                let sc = ShardcastClient::new(
                    &format!("churn-{address}"),
                    &urls,
                    seed ^ address ^ step,
                    false,
                );
                match sc.fetch_checkpoint(step) {
                    Ok((bytes, report)) => {
                        retries.add(report.retries as u64);
                        // Trust-weighted spot-check: commitment-selected
                        // fetches re-derive the publisher's deterministic
                        // payload and compare byte for byte; the rest are
                        // admitted unaudited (shardcast's own digests
                        // still ran) and counted as such.
                        if audit.commitment.selects(step, address, 0, audit.rate) {
                            let mut prng = Rng::new(audit.seed).fold(step);
                            let expect: Vec<u8> = (0..audit.payload_bytes)
                                .map(|_| prng.range(0, 256) as u8)
                                .collect();
                            anyhow::ensure!(
                                bytes == expect,
                                "step {step}: fetched checkpoint fails audit ({} bytes)",
                                bytes.len()
                            );
                            audit.full.inc();
                        } else {
                            audit.skipped.inc();
                        }
                        tasks_ok.inc();
                        return Ok(format!("step {step}: {} bytes", bytes.len()));
                    }
                    Err(e) => {
                        retries.inc();
                        anyhow::ensure!(
                            Instant::now() < deadline,
                            "fetch {step} never succeeded: {e}"
                        );
                        std::thread::sleep(Duration::from_millis(40));
                    }
                }
            }
        }),
    );
    Ok(WorkerSlot { worker, address })
}

fn start_relay(
    slot: usize,
    generation: u64,
    parents: Vec<String>,
    faults: &Option<FaultSpec>,
    seed: u64,
) -> anyhow::Result<Relay> {
    let cfg = ServerConfig {
        faults: faults
            .clone()
            .map(|spec| FaultInjector::from_seed(seed ^ (0xFA00 + slot as u64), spec)),
        ..Default::default()
    };
    Relay::start_with_parents(
        &format!("churn-r{slot}g{generation}"),
        parents,
        cfg,
        Duration::from_millis(10),
    )
}

/// Run the torture schedule described by `cfg`.
pub fn run_churn(cfg: &ChurnConfig) -> anyhow::Result<ChurnReport> {
    anyhow::ensure!(cfg.n_relays >= 2, "need >= 2 relays for kill/failover churn");
    anyhow::ensure!(cfg.n_workers >= 2, "need >= 2 workers for crash churn");
    let t0 = Instant::now();
    let plan = FaultPlan::new(cfg.seed, cfg.server_faults.clone().unwrap_or_default());

    // --- control plane ---
    let ledger = Ledger::new();
    let owner = Identity::from_seed(cfg.seed ^ 0x0FF1CE);
    ledger.register_key(&owner);
    ledger.submit(
        Tx::CreatePool { domain: "dist-rl".into(), pool_id: 1, owner: owner.address },
        &owner,
    )?;
    let discovery = DiscoveryServer::start("pool-token", 600_000)?;
    let mut orch = Orchestrator::new(owner, ledger.clone(), 1, 100);
    orch.max_missed = 2; // fast eviction — churn recovery is the point
    let orch_srv = OrchestratorServer::start(orch.clone())?;

    // --- shardcast tier: chain topology with the origin as everyone's
    // fallback parent, so killing relay k forces its child to re-parent ---
    let origin = Origin::start(ServerConfig::default())?;
    let relay_dir: Arc<Mutex<Vec<String>>> = Arc::new(Mutex::new(Vec::new()));
    let mut relays: Vec<Option<Relay>> = Vec::new();
    for slot in 0..cfg.n_relays {
        let parents = match relays.last().and_then(|r| r.as_ref()) {
            Some(prev) => vec![prev.url(), origin.url()],
            None => vec![origin.url()],
        };
        let r = start_relay(slot, 0, parents, &cfg.server_faults, cfg.seed)?;
        relay_dir.lock().unwrap().push(r.url());
        relays.push(Some(r));
    }

    // --- workers ---
    let tasks_ok = Arc::new(Counter::default());
    let retries = Arc::new(Counter::default());
    let audit = Arc::new(AuditSpec {
        commitment: ValidatorCommitment::new(cfg.seed ^ 0xA0D1),
        rate: cfg.sampling_rate,
        payload_bytes: cfg.payload_bytes,
        seed: cfg.seed,
        full: Counter::default(),
        skipped: Counter::default(),
    });
    let mut workers: Vec<Option<WorkerSlot>> = Vec::new();
    let mut all_addresses: Vec<u64> = Vec::new();
    for wi in 0..cfg.n_workers {
        let slot = join_worker(
            Identity::from_seed(cfg.seed ^ (0xBEEF + wi as u64)),
            &ledger,
            &discovery.url(),
            &orch,
            &orch_srv.url(),
            &relay_dir,
            &tasks_ok,
            &retries,
            &audit,
            cfg.seed,
        )?;
        all_addresses.push(slot.address);
        workers.push(Some(slot));
    }

    let mut report = ChurnReport {
        steps_completed: 0,
        tasks_completed: 0,
        fetch_retries: 0,
        reparent_events: 0,
        workers_crashed: 0,
        workers_joined: 0,
        relays_killed: 0,
        relays_restarted: 0,
        workers_evicted: 0,
        tasks_requeued: 0,
        honest_slashed: 0,
        audits_full: 0,
        audits_skipped: 0,
        elapsed_secs: 0.0,
        step_secs: Vec::new(),
    };

    let mut tasks_created: u64 = 0;
    let mut joined: u64 = 0;
    'steps: for step in 1..=cfg.steps {
        let t_step = Instant::now();
        // Deterministic synthetic checkpoint for this step.
        let mut prng = Rng::new(cfg.seed).fold(step);
        let payload: Vec<u8> = (0..cfg.payload_bytes).map(|_| prng.range(0, 256) as u8).collect();
        origin.publish(step, &payload, cfg.shard_bytes);

        // Enqueue the step's quota first, give the 25 ms heartbeats a
        // moment to pick tasks up, and only then churn — so crashes land
        // mid-task and relay kills land mid-download.
        for _ in 0..cfg.tasks_per_step {
            orch.create_task("fetch", Json::obj(vec![("step", step.into())]));
            tasks_created += 1;
        }

        if cfg.churn {
            std::thread::sleep(Duration::from_millis(60));

            // Restart one slot that died in an earlier step, so the tier
            // keeps roughly constant size across the run. Its preferred
            // parent may be the relay killed below — then the fallback
            // chain (-> origin) is what keeps it mirroring.
            if let Some(slot) = (0..relays.len()).find(|&i| relays[i].is_none()) {
                let live_parent = relays.iter().flatten().next().map(Relay::url);
                let parents = match live_parent {
                    Some(p) => vec![p, origin.url()],
                    None => vec![origin.url()],
                };
                let r = start_relay(slot, step, parents, &cfg.server_faults, cfg.seed)?;
                relay_dir.lock().unwrap().push(r.url());
                relays[slot] = Some(r);
                report.relays_restarted += 1;
            }

            // Kill one live relay (never the last one standing): clients
            // lose it mid-run and must fail over + quarantine it.
            let live: Vec<usize> = (0..relays.len()).filter(|&i| relays[i].is_some()).collect();
            if live.len() > 1 {
                let victim = live[plan.pick(DOMAIN_RELAY_KILL, step, live.len())];
                if let Some(r) = relays[victim].take() {
                    let url = r.url();
                    drop(r);
                    relay_dir.lock().unwrap().retain(|u| u != &url);
                    report.relays_killed += 1;
                }
            }

            // Crash a worker — preferring one that holds a task, so the
            // orphan-requeue path is exercised.
            let holding = orch.nodes_with_tasks();
            let live: Vec<usize> = (0..workers.len()).filter(|&i| workers[i].is_some()).collect();
            if live.len() > 1 {
                let by_addr = |addr: u64| {
                    live.iter()
                        .copied()
                        .find(|&i| workers[i].as_ref().is_some_and(|w| w.address == addr))
                };
                let victim = holding
                    .get(plan.pick(DOMAIN_WORKER_CRASH, step, holding.len().max(1)))
                    .copied()
                    .and_then(by_addr)
                    .unwrap_or_else(|| live[plan.pick(DOMAIN_WORKER_CRASH, step, live.len())]);
                if let Some(mut w) = workers[victim].take() {
                    w.worker.shutdown();
                    report.workers_crashed += 1;
                }
            }

            // A fresh worker joins the swarm mid-run.
            joined += 1;
            let slot = join_worker(
                Identity::from_seed(cfg.seed ^ (0x7A11_0000 + joined)),
                &ledger,
                &discovery.url(),
                &orch,
                &orch_srv.url(),
                &relay_dir,
                &tasks_ok,
                &retries,
                &audit,
                cfg.seed,
            )?;
            all_addresses.push(slot.address);
            workers.push(Some(slot));
            report.workers_joined += 1;
        }

        // Wait out the step's quota, sweeping for dead nodes as we go (the
        // sweep is what requeues a crashed worker's orphaned task).
        while tasks_ok.get() < tasks_created {
            if t_step.elapsed() > cfg.step_timeout {
                crate::warn!(
                    "churn",
                    "step {step}: {} of {tasks_created} tasks after {:?} — ending run",
                    tasks_ok.get(),
                    cfg.step_timeout
                );
                break 'steps;
            }
            report.workers_evicted += orch.health_sweep().len() as u64;
            std::thread::sleep(Duration::from_millis(15));
        }
        report.steps_completed += 1;
        report.step_secs.push(t_step.elapsed().as_secs_f64());
    }

    // --- teardown + verdicts ---
    for w in workers.iter_mut().flatten() {
        w.worker.shutdown();
    }
    report.tasks_completed = tasks_ok.get();
    report.fetch_retries = retries.get();
    report.reparent_events = relays.iter().flatten().map(Relay::reparent_count).sum();
    report.tasks_requeued = orch.tasks_requeued.get();
    report.honest_slashed =
        all_addresses.iter().filter(|&&a| ledger.is_slashed(1, a)).count() as u64;
    report.audits_full = audit.full.get();
    report.audits_skipped = audit.skipped.get();
    report.elapsed_secs = t0.elapsed().as_secs_f64();
    Ok(report)
}
