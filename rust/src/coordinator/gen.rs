//! Rollout generation (§2.1.2): what an inference worker does for one
//! submission — deterministic task sampling (seed formula), batched
//! KV-cache generation, on-node reward computation (sandboxed verifiers),
//! TOPLOC commitments — producing an `rpq` submission file.

use std::sync::Arc;

use crate::config::RunConfig;
use crate::data::tokenizer;
use crate::rl::reward::{self, RewardConfig};
use crate::rl::rollout_file::{Submission, WireRollout};
use crate::rl::Rollout;
use crate::runtime::{rollout_rng, EngineHost, Finish, GenOpts, GenRequest, GenStats, ParamSet};
use crate::tasks::dataset::{node_sample_seed, Dataset};
use crate::toploc::Commitment;
use crate::util::rng::Rng;
use crate::verifier::Registry;

pub use crate::rl::group_id_base;

pub struct RolloutGenerator {
    pub host: Arc<EngineHost>,
    pub dataset: Arc<Dataset>,
    pub reward_cfg: RewardConfig,
    pub registry: Arc<Registry>,
    pub max_new: usize,
    pub temperature: f32,
    /// Continuous-batching generation (`gen-refill` knob, default on):
    /// prompt prefill into KV, lane refill on EOS, group-shared prompt
    /// forwards. Requires artifacts with the vectored-`pos` decode
    /// contract (`ModelSpec::supports_continuous`); falls back to the
    /// static reference path otherwise. Both paths are byte-equivalent.
    pub gen_refill: bool,
}

impl RolloutGenerator {
    /// Generator over the standard registry. Errors if `dataset` was built
    /// from a *different* registry (fingerprint mismatch): computing
    /// rewards with env semantics the dataset's tasks don't carry is
    /// exactly the silent divergence §2.3.3 would slash an honest node for.
    pub fn from_config(
        host: Arc<EngineHost>,
        dataset: Arc<Dataset>,
        cfg: &RunConfig,
    ) -> anyhow::Result<Self> {
        RolloutGenerator::with_registry(host, dataset, cfg, Arc::new(Registry::default()))
    }

    /// Generator over a custom registry (plugin deployments). The registry
    /// fingerprint must match the dataset's.
    pub fn with_registry(
        host: Arc<EngineHost>,
        dataset: Arc<Dataset>,
        cfg: &RunConfig,
        registry: Arc<Registry>,
    ) -> anyhow::Result<Self> {
        anyhow::ensure!(
            registry.fingerprint() == dataset.fingerprint,
            "registry fingerprint {:#x} != dataset fingerprint {:#x}: the generator would \
             compute rewards under different env semantics than the dataset was built with",
            registry.fingerprint(),
            dataset.fingerprint
        );
        Ok(RolloutGenerator {
            host,
            dataset,
            reward_cfg: cfg.reward.clone(),
            registry,
            max_new: cfg.max_new_tokens,
            temperature: cfg.temperature,
            gen_refill: cfg.gen_refill,
        })
    }

    /// Generate one submission: `n_prompts` tasks drawn from the fixed
    /// seed, `group_size` completions each (§3.4 groups), with rewards,
    /// probs and TOPLOC commitments attached. `group_base` offsets group
    /// ids so batches from different nodes stay distinct. Returns the
    /// submission plus the scheduler's perf accounting (decode steps,
    /// prefill calls, lane occupancy — surfaced in `SwarmStats`).
    ///
    /// Rollout `i` samples from the stream `rollout_rng(gen_seed, i)`, so
    /// the emitted bytes are identical whether the continuous or the
    /// static reference engine produced them — the validator's §2.3.3
    /// recomputation narrative never sees the worker's scheduling.
    pub fn generate_submission(
        &self,
        params: &Arc<ParamSet>,
        node_address: u64,
        policy_step: u64,
        submission_idx: u64,
        n_prompts: usize,
        group_size: usize,
        group_base: u64,
    ) -> anyhow::Result<(Submission, GenStats)> {
        let spec = self.host.spec();
        let seed = node_sample_seed(node_address, policy_step, submission_idx);
        let task_ids = self.dataset.sample_for(seed, n_prompts);
        // Target lengths are drawn from the same deterministic stream.
        let mut target_rng = Rng::new(seed ^ 0x7A36_22);

        // Build the prompt batch: each task repeated group_size times.
        let mut prompts = Vec::with_capacity(n_prompts * group_size);
        let mut metas = Vec::with_capacity(n_prompts * group_size);
        for (pi, id) in task_ids.iter().enumerate() {
            let task = self
                .dataset
                .get(*id)
                .ok_or_else(|| anyhow::anyhow!("task {id} missing"))?;
            let target = self.reward_cfg.sample_target(&mut target_rng);
            let text = task.prompt_with_budget(target);
            let toks = tokenizer::encode_prompt(&text);
            for g in 0..group_size {
                prompts.push(toks.clone());
                metas.push((*id, group_base + pi as u64, target, g));
            }
        }

        let opts = GenOpts {
            max_new: self.max_new,
            temperature: self.temperature,
            commit_interval: spec.toploc_interval,
        };
        // Generation seed: deterministic in (node, step, submission) so the
        // validator's recomputation narrative holds.
        let gen_seed = seed ^ 0x5EED;
        let refill = self.gen_refill && spec.supports_continuous();
        let (gens, stats) = if refill {
            // Continuous batching: all rollouts in one scheduler run.
            // prompt_key = task index, so a GRPO group's identical prompts
            // are prefilled once per refill wave and KV-replicated.
            let requests: Vec<GenRequest> = prompts
                .into_iter()
                .enumerate()
                .map(|(i, prompt)| GenRequest {
                    prompt,
                    rng: rollout_rng(gen_seed, i as u64),
                    prompt_key: metas[i].1,
                })
                .collect();
            self.host.generate_continuous(Arc::clone(params), requests, opts)?
        } else {
            // Static reference path (gen-refill off, or pre-refill
            // artifacts): same per-rollout streams, so same bytes.
            self.host.generate_streams(Arc::clone(params), prompts, opts, gen_seed, 0)?
        };
        let mut rollouts = Vec::with_capacity(gens.len());
        for (g, &(task_id, group_id, target, _)) in gens.iter().zip(&metas) {
            let task = self.dataset.get(task_id).unwrap();
            let completion = tokenizer::decode_clean(&g.tokens[g.prompt_len..]);
            // Rewards are computed on the inference node (§2.1.3).
            let task_r = reward::task_reward(&self.registry, task, &completion);
            let pen = reward::length_penalty(self.reward_cfg.alpha, g.completion_len(), target);
            let (finish_eos, eos_prob) = match g.finish {
                Finish::Eos { prob } => (true, prob),
                Finish::MaxLen => (false, 0.0),
            };
            rollouts.push(WireRollout {
                rollout: Rollout {
                    task_id,
                    group_id,
                    policy_step,
                    tokens: g.tokens.clone(),
                    prompt_len: g.prompt_len,
                    target_len: target,
                    task_reward: task_r,
                    length_penalty: pen,
                    reward: task_r - pen,
                    advantage: 0.0,
                    sampled_probs: g.sampled_probs.clone(),
                    node_address,
                },
                commitment: Commitment::build(&g.hidden_rows, spec.toploc_topk).encode(),
                finish_eos,
                eos_prob,
            });
        }
        Ok((Submission { node_address, step: policy_step, submission_idx, rollouts }, stats))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tasks::dataset::DatasetConfig;

    fn artifacts_ready() -> bool {
        crate::runtime::Runtime::artifacts_dir("nano").join("spec.json").exists()
    }

    #[test]
    fn group_id_base_is_collision_resistant() {
        // Regression: the old `(address << 20) ^ (version << 10) ^ (idx << 4)`
        // base dropped the high 20 bits of the address, so these two
        // distinct nodes collided exactly.
        let a = 0x0000_1234_5678_9ABCu64;
        let b = a ^ (1u64 << 45); // differs only in a discarded-by-<<20 bit
        assert_eq!(a << 20, b << 20, "old scheme collides by construction");
        assert_ne!(group_id_base(a, 3, 1), group_id_base(b, 3, 1));
        // Distinct across versions and submission indices too.
        assert_ne!(group_id_base(a, 3, 1), group_id_base(a, 4, 1));
        assert_ne!(group_id_base(a, 3, 1), group_id_base(a, 3, 2));
        // Low 16 bits are reserved for per-prompt offsets.
        assert_eq!(group_id_base(a, 3, 1) & 0xFFFF, 0);
        // Deterministic (validators recompute the same ids).
        assert_eq!(group_id_base(a, 3, 1), group_id_base(a, 3, 1));
        // No collisions across a realistic swarm's worth of submissions.
        let mut seen = std::collections::BTreeSet::new();
        for node in 0..64u64 {
            let addr = node.wrapping_mul(0x1357_9BDF_2468_ACE0) ^ (node << 44);
            for version in 0..16 {
                for idx in 0..4 {
                    assert!(
                        seen.insert(group_id_base(addr, version, idx)),
                        "collision at node {node} version {version} idx {idx}"
                    );
                }
            }
        }
    }

    #[test]
    fn submission_is_deterministic_and_grouped() {
        if !artifacts_ready() {
            eprintln!("skipping: run `make artifacts`");
            return;
        }
        let host = Arc::new(EngineHost::spawn_size("nano").unwrap());
        let registry = crate::verifier::Registry::standard();
        let dataset = Arc::new(
            Dataset::generate(
                &registry,
                &DatasetConfig {
                    mix: crate::tasks::dataset::EnvMix::of(&[("math", 50), ("code", 10)]),
                    ..Default::default()
                },
            )
            .unwrap(),
        );
        let cfg = RunConfig { max_new_tokens: 12, ..Default::default() };
        let mut generator =
            RolloutGenerator::from_config(Arc::clone(&host), dataset, &cfg).unwrap();
        let params = Arc::new(host.init_params(3).unwrap());

        let (a, _) = generator.generate_submission(&params, 42, 1, 0, 2, 3, 100).unwrap();
        let (b, _) = generator.generate_submission(&params, 42, 1, 0, 2, 3, 100).unwrap();
        assert_eq!(a.rollouts.len(), 6);
        for (x, y) in a.rollouts.iter().zip(&b.rollouts) {
            assert_eq!(x.rollout.tokens, y.rollout.tokens);
            assert_eq!(x.rollout.reward, y.rollout.reward);
        }
        // Groups: 2 groups of 3, same task within group.
        assert_eq!(a.rollouts[0].rollout.group_id, 100);
        assert_eq!(a.rollouts[3].rollout.group_id, 101);
        assert_eq!(a.rollouts[0].rollout.task_id, a.rollouts[1].rollout.task_id);
        // Commitments decode.
        for w in &a.rollouts {
            Commitment::decode(&w.commitment).unwrap();
        }
        // Encodes to a valid submission file.
        let decoded = Submission::decode(&a.encode()).unwrap();
        assert_eq!(decoded.rollouts.len(), 6);

        // Continuous vs static reference on the real engine. Tokens must
        // agree (a divergence would need a sampling near-tie flipped by
        // last-ulp prefill-vs-decode kernel rounding — vanishingly
        // unlikely at nano scale, and a systematic mismatch is a real
        // bug); probs get an fp tolerance because the prompt frontier is
        // computed by a differently-shaped kernel. Bit-exact equivalence
        // is enforced on the deterministic mock (tests/gen_scheduler.rs).
        if host.spec().supports_continuous() {
            generator.gen_refill = false;
            let (s, st) = generator.generate_submission(&params, 42, 1, 0, 2, 3, 100).unwrap();
            assert_eq!(a.rollouts.len(), s.rollouts.len());
            for (x, y) in a.rollouts.iter().zip(&s.rollouts) {
                assert_eq!(x.rollout.tokens, y.rollout.tokens);
                assert_eq!(x.rollout.group_id, y.rollout.group_id);
                for (p, q) in x.rollout.sampled_probs.iter().zip(&y.rollout.sampled_probs) {
                    assert!((p - q).abs() < 2e-3, "{p} vs {q}");
                }
            }
            generator.gen_refill = true;
            let (_, ct) = generator.generate_submission(&params, 42, 1, 0, 2, 3, 100).unwrap();
            assert!(ct.prefill_calls > 0);
            // Group sharing: 2 tasks x 3 completions needs at most one
            // prompt forward per task per wave, never one per rollout.
            assert!(ct.prefill_prompts < 6);
            assert!(ct.decode_steps <= st.decode_steps);
        }
    }
}
