//! Cheat-EV harness: an engine-free adversarial economy that proves the
//! trust-weighted sampling gate is safe to run at any configured rate.
//!
//! The question sampled validation must answer is not "do we catch every
//! cheat?" (we deliberately do not — that is the whole throughput win)
//! but "is cheating *profitable*?". This module stands up the real
//! ingredients — a [`Ledger`] with stake bonding and trust history, a
//! [`SamplingGate`] seeded from a validator commitment, and the CPU
//! projection of the TOPLOC pipeline
//! ([`validate_submission_cpu`][validation::validate_submission_cpu],
//! whose stage-2 reward re-verification is the economically relevant
//! catch) — and drives honest and cheating workers through a multi-step
//! run. No model artifacts, no engine: it runs in CI as a binding gate
//! (`cargo run --release --bin cheat_ev_bench`).
//!
//! The economic argument it certifies, per cheat submission worth `R`
//! reward units caught with probability at least the floor rate `p`:
//!
//! ```text
//! EV(cheat) = (1 - p) * R  -  p * stake   < 0
//!        iff  stake > R * (1 - p) / p
//! ```
//!
//! [`min_negative_ev_stake`] sizes the bond above that bound with a
//! safety margin, so a worker's best strategy at *any* trust level is
//! honesty. The harness checks the realized run agrees: every node that
//! ever submitted a cheat ends the run slashed with its stake forfeited,
//! no honest node is slashed, and at rate 1.0 the gated pipeline's
//! verdict stream is byte-identical to the ungated baseline.

use std::sync::Arc;

use anyhow::Result;

use crate::coordinator::validation::{
    self, GateOutcome, SamplerConfig, SamplingGate, SigOracle, TrustOracle, ValidatorCommitment,
    Verdict,
};
use crate::data::tokenizer::{encode, BOS, EOS};
use crate::protocol::{min_negative_ev_stake, Identity, Ledger, Tx};
use crate::rl::reward::RewardConfig;
use crate::rl::rollout_file::{Submission, WireRollout};
use crate::rl::Rollout;
use crate::tasks::dataset::{node_sample_seed, Dataset, DatasetConfig, EnvMix};
use crate::toploc::{Commitment, Validator, ValidatorConfig};
use crate::verifier::Registry;

/// Worker behavior in the adversarial run.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Strategy {
    Honest,
    /// Cheats from its very first submission. New nodes carry zero trust,
    /// so the gate fully verifies them — this one is caught immediately.
    Eager,
    /// Builds genuine trust first, then claims full reward on fabricated
    /// answers the moment its verification probability dips below 1.
    Sleeper,
    /// Like [`Strategy::Sleeper`], but enters the run with a long
    /// pre-recorded clean history, so its verification probability sits at
    /// the configured floor from step 0 — the worst case the stake sizing
    /// has to cover.
    DeepSleeper,
    /// Deep-trusted node that inflates its rollout *count* past the
    /// per-worker quota once skips begin — the task stream is
    /// prefix-stable, so without the per-submission cap the extra rollouts
    /// would pass the seed check and claim unbounded reward against a
    /// fixed stake. Must be caught at the gate on its *first* defection,
    /// skip or no skip (the cap is a deterministic check).
    Inflator,
    /// Deep-trusted node that keeps the honest rollout count but claims a
    /// reward far outside the environment's bounds (1e30 per rollout).
    /// Like [`Strategy::Inflator`], a deterministic lie: the gate's
    /// value-bounds check rejects it even on a would-be skip.
    BoundsLiar,
}

/// Knobs for one adversarial run. The defaults mirror the swarm's
/// (`sampling-rate`, `trust-promotion-streak`, `trust-stake-margin`).
#[derive(Clone, Debug)]
pub struct CheatEvConfig {
    pub seed: u64,
    /// Floor verification rate handed to the gate *and* to the stake
    /// sizing (the bond must cover the lowest rate the gate can reach).
    pub sampling_rate: f64,
    pub promotion_streak: u64,
    pub stake_margin: f64,
    /// Policy steps to simulate; each live node uploads once per step.
    pub steps: u64,
    pub prompts_per_sub: usize,
    pub group_size: usize,
    /// Worker roster. Order fixes node addresses, so runs with the same
    /// seed and roster are replayable end to end.
    pub roster: Vec<Strategy>,
}

impl Default for CheatEvConfig {
    fn default() -> CheatEvConfig {
        CheatEvConfig {
            seed: 0xC4EA7,
            sampling_rate: 0.1,
            promotion_streak: 4,
            stake_margin: 2.0,
            // Enough cheat opportunities that a floor-rate cheater's
            // survival odds are negligible: a deep sleeper skates past a
            // full check with probability (1 - 0.1)^120 ~ 3e-6 per run,
            // and the run is deterministic per seed anyway.
            steps: 120,
            prompts_per_sub: 2,
            group_size: 2,
            roster: vec![
                Strategy::Honest,
                Strategy::Honest,
                Strategy::Eager,
                Strategy::Sleeper,
                Strategy::DeepSleeper,
                Strategy::Inflator,
                Strategy::BoundsLiar,
            ],
        }
    }
}

/// Where one worker ended the run.
#[derive(Clone, Debug)]
pub struct NodeOutcome {
    pub address: u64,
    pub strategy: Strategy,
    pub slashed: bool,
    /// Submissions uploaded with fabricated rewards.
    pub cheats_submitted: u64,
    /// Cheat submissions the gate admitted unverified (spot-check misses).
    pub cheats_admitted: u64,
    /// Reward units (one per rollout) banked from admitted cheats.
    pub cheat_gain: u64,
    /// Stake bonded at registration.
    pub stake: u64,
    /// Stake forfeited to slashes.
    pub forfeited: u64,
}

impl NodeOutcome {
    pub fn is_cheater(&self) -> bool {
        self.strategy != Strategy::Honest
    }

    /// Realized cheat profit in reward units: what the node banked from
    /// admitted cheats minus the stake it lost. Negative means cheating
    /// did not pay *in this run* (the analytic gate covers expectation).
    pub fn realized_profit(&self) -> i64 {
        self.cheat_gain as i64 - self.forfeited as i64
    }
}

/// Everything the CI gate and the bench JSON need from one run.
#[derive(Clone, Debug)]
pub struct CheatEvReport {
    pub sampling_rate: f64,
    /// Reward units per submission (`prompts_per_sub * group_size`).
    pub per_sub_reward: u64,
    /// Stake each worker bonded ([`min_negative_ev_stake`] at the floor).
    pub stake: u64,
    pub nodes: Vec<NodeOutcome>,
    pub uploads: u64,
    pub sampled_full: u64,
    pub skipped: u64,
    pub escalated: u64,
    /// Uploads that lost the selection draw but failed one of the gate's
    /// deterministic checks (cap, bounds, seed, group ids): settled at the
    /// gate — neither fully sampled nor admitted.
    pub rejected_unsampled: u64,
    /// Verdict fingerprints from the gated run, in upload order (gate
    /// early-rejects and full-pipeline verdicts; skips produce none).
    pub gated_fingerprints: Vec<(&'static str, Option<u64>, String)>,
    /// Fingerprints from replaying the *identical* upload stream through
    /// the ungated CPU pipeline — the pre-sampling baseline. At rate 1.0
    /// the two streams must be byte-identical.
    pub baseline_fingerprints: Vec<(&'static str, Option<u64>, String)>,
}

impl CheatEvReport {
    pub fn honest_slashed(&self) -> u64 {
        self.nodes.iter().filter(|n| !n.is_cheater() && n.slashed).count() as u64
    }

    /// Cheaters that submitted at least one cheat and were never slashed
    /// — must be zero for the run to certify the configuration.
    pub fn cheaters_escaped(&self) -> u64 {
        self.nodes.iter().filter(|n| n.cheats_submitted > 0 && !n.slashed).count() as u64
    }

    /// Analytic per-cheat expected value at the floor rate, in reward
    /// units: `(1 - p) * R - p * stake`. The CI gate requires this to be
    /// negative — by [`min_negative_ev_stake`]'s construction it is, at
    /// any configured rate, and this method recomputes it from the run's
    /// *actual* stake so a sizing regression cannot hide.
    pub fn analytic_cheat_ev(&self) -> f64 {
        let p = self.sampling_rate.clamp(1e-6, 1.0);
        (1.0 - p) * self.per_sub_reward as f64 - p * self.stake as f64
    }

    /// Worst realized cheat profit across the roster (units; negative
    /// when every cheater lost more stake than it banked).
    pub fn worst_realized_profit(&self) -> i64 {
        self.nodes
            .iter()
            .filter(|n| n.is_cheater())
            .map(NodeOutcome::realized_profit)
            .max()
            .unwrap_or(0)
    }
}

/// The lie (if any) baked into one upload.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum Lie {
    /// Wire-honest: reference answer, true rewards, quota-sized.
    None,
    /// Fabricated completion claimed at reward 1.0 — only stage 2's
    /// expensive reward replay can tell, so this is the lie sampling
    /// deliberately lets through and stake must price in.
    FakeAnswer,
    /// 4x the quota of rollouts, each claiming 1.0. The task stream is
    /// prefix-stable, so every extra prompt still matches the seed draw —
    /// only the per-submission cap stops the claimable value.
    InflateCount,
    /// Honest completions but a claimed reward of 1e30 per rollout —
    /// caught by the deterministic value-bounds check.
    RewardBounds,
}

/// Build one submission for `(node, step)`: tasks drawn from the §2.3.3
/// seed formula, group ids from the deterministic base, the reference
/// answer as the completion — except where `lie` says otherwise.
fn build_submission(
    dataset: &Dataset,
    cfg: &CheatEvConfig,
    node: u64,
    step: u64,
    lie: Lie,
) -> Submission {
    let seed = node_sample_seed(node, step, 0);
    let base = crate::rl::group_id_base(node, step, 0);
    let n_prompts = match lie {
        Lie::InflateCount => cfg.prompts_per_sub * 4,
        _ => cfg.prompts_per_sub,
    };
    let ids = dataset.sample_for(seed, n_prompts);
    let mut rollouts = Vec::new();
    for (pi, id) in ids.iter().enumerate() {
        let task = dataset.get(*id).expect("sampled id in dataset");
        for _ in 0..cfg.group_size {
            let mut tokens = vec![BOS];
            tokens.extend(encode(&task.prompt));
            let plen = tokens.len();
            if lie == Lie::FakeAnswer {
                // A completion the verifier scores 0 — claimed as 1.0.
                tokens.extend(encode("wrong"));
            } else {
                tokens.extend(encode(task.answer()));
            }
            tokens.push(EOS);
            let n = tokens.len() - plen;
            let claimed = if lie == Lie::RewardBounds { 1e30 } else { 1.0 };
            rollouts.push(WireRollout {
                rollout: Rollout {
                    task_id: *id,
                    group_id: base + pi as u64,
                    policy_step: step,
                    tokens,
                    prompt_len: plen,
                    target_len: None,
                    task_reward: claimed,
                    length_penalty: 0.0,
                    reward: claimed,
                    advantage: 0.0,
                    sampled_probs: vec![0.5; n],
                    node_address: node,
                },
                commitment: Commitment::default().encode(),
                finish_eos: true,
                eos_prob: 0.9,
            });
        }
    }
    Submission { node_address: node, step, submission_idx: 0, rollouts }
}

struct NodeState {
    identity: Identity,
    strategy: Strategy,
    cheats_submitted: u64,
    cheats_admitted: u64,
    cheat_gain: u64,
}

/// Run the adversarial economy described by `cfg` and report what every
/// strategy earned and lost.
pub fn run_cheat_ev(cfg: &CheatEvConfig) -> Result<CheatEvReport> {
    let dataset = Arc::new(Dataset::generate(
        &Registry::standard(),
        &DatasetConfig { seed: cfg.seed, mix: EnvMix::of(&[("math", 40)]), ..Default::default() },
    )?);
    let validator = Validator::new(ValidatorConfig {
        expected_group: cfg.group_size,
        // The quota every honest worker generates — what the stake sizing
        // below assumes a submission can claim at most.
        max_rollouts_per_sub: cfg.prompts_per_sub * cfg.group_size,
        ..Default::default()
    });
    let reward_cfg = RewardConfig::default();
    let (max_new, max_seq) = (128usize, 512usize);

    // --- ledger: pool, identities, stake bonds ---
    let ledger = Ledger::new();
    let owner = Identity::from_seed(cfg.seed ^ 0xB055);
    ledger.register_key(&owner);
    ledger.submit(
        Tx::CreatePool { domain: "cheat-ev".into(), pool_id: 1, owner: owner.address },
        &owner,
    )?;
    let per_sub_reward = (cfg.prompts_per_sub * cfg.group_size) as u64;
    let stake = min_negative_ev_stake(per_sub_reward, cfg.sampling_rate, cfg.stake_margin);
    let mut nodes: Vec<NodeState> = Vec::new();
    for (i, &strategy) in cfg.roster.iter().enumerate() {
        let identity = Identity::from_seed(cfg.seed ^ (0x1D00 + i as u64));
        ledger.register_key(&identity);
        ledger.submit(Tx::Register { pool_id: 1, node: identity.address }, &identity)?;
        ledger.submit(
            Tx::Stake { pool_id: 1, node: identity.address, units: stake },
            &identity,
        )?;
        if matches!(
            strategy,
            Strategy::DeepSleeper | Strategy::Inflator | Strategy::BoundsLiar
        ) {
            // A long clean record from "before" the run: decays the
            // verification probability to the configured floor — every
            // deep strategy defects from its best possible position.
            for _ in 0..cfg.promotion_streak * 64 {
                ledger.record_verification(1, identity.address, true);
            }
        }
        nodes.push(NodeState {
            identity,
            strategy,
            cheats_submitted: 0,
            cheats_admitted: 0,
            cheat_gain: 0,
        });
    }

    // --- gate + signing oracle, wired exactly like the swarm's ---
    let trust_ledger = ledger.clone();
    let trust: Arc<TrustOracle> = Arc::new(move |node| trust_ledger.trust(1, node));
    let gate = SamplingGate::new(
        ValidatorCommitment::new(cfg.seed ^ 0x5A3D),
        SamplerConfig { sampling_rate: cfg.sampling_rate, promotion_streak: cfg.promotion_streak },
        trust,
        Arc::clone(&dataset),
        reward_cfg.clone(),
        max_new,
        max_seq,
    );
    let sig_ledger = ledger.clone();
    let signing: Arc<SigOracle> = Arc::new(move |addr, msg: &[u8], sig: &[u8; 32]| {
        sig_ledger.check_address_sig(addr, msg, sig)
    });

    // --- the run: every live node uploads once per step ---
    let mut uploads = 0u64;
    let mut recorded: Vec<(u64, Vec<u8>)> = Vec::new();
    let mut gated_fingerprints = Vec::new();
    for step in 0..cfg.steps {
        for node in &mut nodes {
            let addr = node.identity.address;
            if ledger.is_slashed(1, addr) {
                continue;
            }
            let t = ledger.trust(1, addr);
            let p = t.verify_probability(cfg.sampling_rate, cfg.promotion_streak);
            let lie = match node.strategy {
                Strategy::Honest => Lie::None,
                Strategy::Eager => Lie::FakeAnswer,
                // The patient strategies only defect once full
                // verification has relaxed.
                Strategy::Sleeper | Strategy::DeepSleeper => {
                    if p < 1.0 { Lie::FakeAnswer } else { Lie::None }
                }
                Strategy::Inflator => {
                    if p < 1.0 { Lie::InflateCount } else { Lie::None }
                }
                Strategy::BoundsLiar => {
                    if p < 1.0 { Lie::RewardBounds } else { Lie::None }
                }
            };
            let sub = build_submission(&dataset, cfg, addr, step, lie);
            let bytes = sub.encode_signed(&node.identity);
            recorded.push((step, bytes.clone()));
            uploads += 1;
            if lie != Lie::None {
                node.cheats_submitted += 1;
            }
            match gate.gate(Some(&signing), &validator, step, bytes) {
                GateOutcome::Full(b) => {
                    let v = validation::validate_submission_cpu(
                        &validator, Some(&signing), &b, &dataset, &reward_cfg, step, max_new,
                        max_seq,
                    );
                    match &v {
                        Verdict::Accept(s) => {
                            ledger.record_verification(1, s.node_address, true);
                        }
                        Verdict::Reject { node: Some(n), why } => {
                            ledger.record_verification(1, *n, false);
                            ledger.submit(
                                Tx::Slash { pool_id: 1, node: *n, reason: why.clone() },
                                &owner,
                            )?;
                        }
                        _ => {}
                    }
                    gated_fingerprints.push(v.fingerprint());
                }
                GateOutcome::Skip(s) => {
                    // Admitted on stake + trust: claimed rewards are
                    // banked unverified. For a cheater this is the payoff
                    // the stake sizing must dominate. Only the reward lie
                    // can land here — deterministic lies (count, bounds)
                    // reject at the gate even on a lost draw.
                    if lie != Lie::None {
                        node.cheats_admitted += 1;
                        node.cheat_gain += s.rollouts.len() as u64;
                    }
                }
                // Mirrors the swarm's verdict loop: a gate reject with a
                // proven sender zeroes trust and slashes the bond; stale /
                // unattributed outcomes settle without slashing.
                GateOutcome::Done(v) => {
                    if let Verdict::Reject { node: Some(n), why } = &v {
                        ledger.record_verification(1, *n, false);
                        ledger.submit(
                            Tx::Slash { pool_id: 1, node: *n, reason: why.clone() },
                            &owner,
                        )?;
                    }
                    gated_fingerprints.push(v.fingerprint());
                }
            }
        }
    }

    // --- baseline: the identical upload stream, ungated ---
    let baseline_fingerprints = recorded
        .iter()
        .map(|(step, bytes)| {
            validation::validate_submission_cpu(
                &validator, Some(&signing), bytes, &dataset, &reward_cfg, *step, max_new, max_seq,
            )
            .fingerprint()
        })
        .collect();

    let outcomes = nodes
        .iter()
        .map(|n| NodeOutcome {
            address: n.identity.address,
            strategy: n.strategy,
            slashed: ledger.is_slashed(1, n.identity.address),
            cheats_submitted: n.cheats_submitted,
            cheats_admitted: n.cheats_admitted,
            cheat_gain: n.cheat_gain,
            stake,
            forfeited: ledger.forfeited(1, n.identity.address),
        })
        .collect();
    Ok(CheatEvReport {
        sampling_rate: cfg.sampling_rate,
        per_sub_reward,
        stake,
        nodes: outcomes,
        uploads,
        sampled_full: gate.sampled_full.get(),
        skipped: gate.skipped.get(),
        escalated: gate.escalated.get(),
        rejected_unsampled: gate.rejected_unsampled.get(),
        gated_fingerprints,
        baseline_fingerprints,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn full_rate_matches_ungated_baseline_and_catches_eager_cheat() {
        let cfg = CheatEvConfig { sampling_rate: 1.0, steps: 12, ..Default::default() };
        let r = run_cheat_ev(&cfg).unwrap();
        // Rate 1.0 disables skipping entirely...
        assert_eq!(r.skipped, 0);
        assert_eq!(r.sampled_full, r.uploads);
        // ...and the gated verdict stream is byte-identical to running the
        // same uploads through the ungated pipeline.
        assert_eq!(r.gated_fingerprints, r.baseline_fingerprints);
        // The eager cheater is caught on its first upload; sleepers never
        // see a relaxed verification probability, so they never defect.
        let eager = r.nodes.iter().find(|n| n.strategy == Strategy::Eager).unwrap();
        assert!(eager.slashed && eager.cheat_gain == 0 && eager.forfeited == r.stake);
        for n in r.nodes.iter().filter(|n| n.strategy != Strategy::Eager) {
            assert!(!n.slashed, "{:?} slashed at rate 1.0", n.strategy);
            assert_eq!(n.cheats_submitted, 0);
        }
        assert!(r.analytic_cheat_ev() < 0.0);
    }

    #[test]
    fn sampled_rate_still_makes_every_cheater_lose() {
        let r = run_cheat_ev(&CheatEvConfig::default()).unwrap();
        assert_eq!(r.sampling_rate, 0.1);
        // Sampling actually skipped work (honest proven nodes exist), and
        // every upload was fully verified, spot-check exempted, or settled
        // by a deterministic check at the gate (nothing fails stage 0).
        assert!(r.skipped > 0, "no submission was ever spot-check exempted");
        assert_eq!(r.sampled_full + r.skipped + r.rejected_unsampled, r.uploads);
        // Every strategy that defected ended slashed; honest nodes never.
        assert_eq!(r.honest_slashed(), 0);
        assert_eq!(r.cheaters_escaped(), 0);
        let deep = r.nodes.iter().find(|n| n.strategy == Strategy::DeepSleeper).unwrap();
        assert!(deep.cheats_submitted > 0, "deep sleeper never defected");
        assert!(deep.slashed && deep.forfeited == r.stake);
        // The stake sizing makes the *expected* cheat value negative at
        // the floor rate even though individual skips were admitted.
        assert!(r.analytic_cheat_ev() < 0.0, "EV {} not negative", r.analytic_cheat_ev());
    }

    #[test]
    fn deterministic_lies_never_profit_even_when_unsampled() {
        // The review scenario: a deep-trusted node tries to beat the
        // stake bound not by lying about rewards within bounds but by
        // inflating the claim itself — more rollouts than the quota, or
        // out-of-bounds reward values. Both are deterministic CPU checks,
        // so they must be caught on the *first* defection regardless of
        // the selection draw: zero admitted, zero banked, slashed.
        let r = run_cheat_ev(&CheatEvConfig::default()).unwrap();
        for s in [Strategy::Inflator, Strategy::BoundsLiar] {
            let n = r.nodes.iter().find(|n| n.strategy == s).unwrap();
            assert_eq!(n.cheats_submitted, 1, "{s:?} defected more than once");
            assert_eq!(n.cheats_admitted, 0, "{s:?} had a lie admitted");
            assert_eq!(n.cheat_gain, 0, "{s:?} banked units from a lie");
            assert!(n.slashed && n.forfeited == r.stake, "{s:?} kept its stake");
        }
        // And the gate actually settled defections without sampling them
        // (at rate 0.1 at least one of the two loses the draw with
        // overwhelming probability for this seed; pin it).
        assert!(r.rejected_unsampled > 0, "every deterministic lie won the draw");
    }
}
