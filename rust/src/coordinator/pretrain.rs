//! Base-model pretraining on a synthetic "worked solutions" corpus — the
//! QwQ-32B stand-in (DESIGN.md substitutions). The corpus is noisy on
//! purpose (a fraction of wrong answers, sloppy thinking-budget filler) so
//! the base model lands at mid-range task accuracy and RL has signal to
//! improve, mirroring the paper's base-model starting point.

use std::sync::Arc;

use crate::config::RunConfig;
use crate::data::tokenizer;
use crate::runtime::{EngineHost, HostTrainState};
use crate::tasks::dataset::Dataset;
use crate::util::metrics::Series;
use crate::util::rng::Rng;
use crate::verifier::Registry;

/// Fraction of corpus samples with a corrupted answer.
pub const NOISE_FRAC: f64 = 0.25;
/// Fraction of samples rendered with a thinking-budget prefix + filler.
pub const BUDGET_FRAC: f64 = 0.4;

/// Render one corpus sample: `prompt>answer$` (optionally with `<N|` budget
/// prefix and `~` filler of roughly N tokens before the answer). Noise is
/// env-owned: each environment's `corrupt_answer` hook decides what a
/// plausible-but-wrong completion looks like in its domain.
pub fn render_sample(
    registry: &Registry,
    dataset: &Dataset,
    rng: &mut Rng,
    targets: &[usize],
) -> Vec<i32> {
    let task = &dataset.tasks[rng.usize(dataset.len())];
    let corrupt = rng.bool(NOISE_FRAC);
    let answer = if corrupt {
        match registry.env_for(task) {
            Some(env) => env.corrupt_answer(task.answer(), rng),
            None => task.answer().to_string(),
        }
    } else {
        task.answer().to_string()
    };
    let mut text = String::new();
    if !targets.is_empty() && rng.bool(BUDGET_FRAC) {
        let target = *rng.choice(targets);
        // Filler length is only roughly on-target: RL must tighten it.
        let lo = (target / 2).max(1);
        let hi = target + target / 2;
        let fill = rng.range(lo as u64, hi as u64 + 1) as usize;
        text.push_str(&format!("<{target}|{}", task.prompt));
        text.push('>');
        for _ in 0..fill.saturating_sub(answer.len() + 1) {
            text.push('~');
        }
    } else {
        text.push_str(&task.prompt);
        text.push('>');
    }
    text.push_str(&answer);
    let mut toks = tokenizer::encode_prompt(&text);
    toks.push(tokenizer::EOS);
    toks
}

/// Build one packed `[B,T]` pretraining batch (greedy row fill).
pub fn corpus_batch(
    registry: &Registry,
    dataset: &Dataset,
    rng: &mut Rng,
    b: usize,
    t: usize,
    targets: &[usize],
) -> (Vec<i32>, Vec<i32>) {
    let mut tokens = vec![0i32; b * t];
    let mut segs = vec![0i32; b * t];
    for row in 0..b {
        let mut pos = 0usize;
        let mut seg = 1i32;
        loop {
            let sample = render_sample(registry, dataset, rng, targets);
            if pos + sample.len() > t {
                break;
            }
            for (j, &tok) in sample.iter().enumerate() {
                tokens[row * t + pos + j] = tok;
                segs[row * t + pos + j] = seg;
            }
            pos += sample.len();
            seg += 1;
            if pos >= t.saturating_sub(8) {
                break;
            }
        }
    }
    (tokens, segs)
}

/// Pretrain for `steps` steps, logging the loss curve to `series`.
pub fn pretrain(
    host: &Arc<EngineHost>,
    mut state: Box<HostTrainState>,
    registry: &Registry,
    dataset: &Dataset,
    cfg: &RunConfig,
    steps: u64,
    series: &Series,
) -> anyhow::Result<Box<HostTrainState>> {
    let spec = host.spec().clone();
    let mut rng = Rng::new(cfg.seed ^ 0x9E7A);
    for step in 0..steps {
        let (tokens, segs) = corpus_batch(
            registry,
            dataset,
            &mut rng,
            spec.batch_train,
            spec.max_seq,
            &cfg.reward.targets,
        );
        let (st, loss, gnorm) =
            host.pretrain_step(state, tokens, segs, cfg.pretrain_lr, 1.0)?;
        state = st;
        series.push(step, "pretrain_loss", loss as f64);
        series.push(step, "pretrain_gnorm", gnorm as f64);
        if step % 20 == 0 {
            crate::info!("pretrain", "step {step}: loss {loss:.4} gnorm {gnorm:.3}");
        }
    }
    Ok(state)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tasks::dataset::DatasetConfig;

    fn gen(mix: &[(&str, usize)]) -> (Registry, Dataset) {
        let reg = Registry::standard();
        let cfg = DatasetConfig {
            mix: crate::tasks::dataset::EnvMix::of(mix),
            ..Default::default()
        };
        let d = Dataset::generate(&reg, &cfg).unwrap();
        (reg, d)
    }

    #[test]
    fn corpus_batch_shape_and_segments() {
        let (reg, dataset) = gen(&[("math", 30), ("code", 5)]);
        let mut rng = Rng::new(1);
        let (tokens, segs) = corpus_batch(&reg, &dataset, &mut rng, 4, 128, &[16, 32]);
        assert_eq!(tokens.len(), 4 * 128);
        // Every row has at least one sample; segments are contiguous runs.
        for row in 0..4 {
            let s = &segs[row * 128..(row + 1) * 128];
            assert!(s[0] == 1, "row {row} starts with a sample");
            for w in s.windows(2) {
                assert!(w[1] == w[0] || w[1] == w[0] + 1 || w[1] == 0);
            }
        }
        // EOS tokens present.
        assert!(tokens.iter().any(|&t| t == tokenizer::EOS));
    }

    #[test]
    fn render_sample_formats() {
        // All four envs in the corpus: noise goes through each env's own
        // corrupt_answer hook without panicking.
        let (reg, dataset) = gen(&[("math", 20), ("code", 5), ("seq", 5), ("chain", 5)]);
        let mut rng = Rng::new(2);
        let mut saw_budget = false;
        let mut saw_plain = false;
        for _ in 0..50 {
            let toks = render_sample(&reg, &dataset, &mut rng, &[16, 32]);
            assert_eq!(toks[0], tokenizer::BOS);
            assert_eq!(*toks.last().unwrap(), tokenizer::EOS);
            let text = tokenizer::decode_clean(&toks);
            assert!(text.contains('>'), "{text}");
            if text.starts_with('<') {
                saw_budget = true;
            } else {
                saw_plain = true;
            }
        }
        assert!(saw_budget && saw_plain);
    }
}
