//! Shared per-step accounting between the deterministic async-k driver
//! (`sync_driver`) and the free-running swarm (`swarm`): online group
//! filtering of freshly generated rollouts and the canonical set of series
//! every RL step records. Previously both drivers carried private copies
//! of this logic that drifted apart.

use crate::coordinator::batcher::StepReport;
use crate::rl::{advantage, Rollout};
use crate::util::metrics::Series;

/// Result of online-filtering one submission's worth of rollouts (§3.3.2).
pub struct FilterOutcome {
    /// Rollouts from informative (non-degenerate) groups, advantages set.
    pub rollouts: Vec<Rollout>,
    /// Number of groups kept.
    pub groups_kept: usize,
    /// Number of all-same-reward groups discarded.
    pub groups_discarded: usize,
}

/// Compute group advantages and drop degenerate groups: the shared
/// "keep sampling until the batch fills" building block. The filtering
/// rule itself lives in [`advantage::online_filter`] — this only adds the
/// group accounting the drivers need.
pub fn filter_groups(batch: Vec<Rollout>) -> FilterOutcome {
    let (rollouts, groups_discarded) = advantage::online_filter(batch);
    let mut kept_ids: Vec<u64> = rollouts.iter().map(|r| r.group_id).collect();
    kept_ids.sort_unstable();
    kept_ids.dedup();
    FilterOutcome { rollouts, groups_kept: kept_ids.len(), groups_discarded }
}

/// Record the canonical per-step training series under `prefix` (empty for
/// the swarm; experiment drivers namespace with e.g. `"async2/"`).
pub fn record_step(
    series: &Series,
    prefix: &str,
    step: u64,
    r: &StepReport,
    extra_inference: usize,
) {
    let p = |name: &str| format!("{prefix}{name}");
    series.push(step, &p("task_reward"), r.mean_task_reward);
    series.push(step, &p("length_penalty"), r.mean_length_penalty);
    series.push(step, &p("reward"), r.mean_reward);
    series.push(step, &p("completion_len"), r.mean_completion_len);
    series.push(step, &p("loss"), r.metrics.loss as f64);
    series.push(step, &p("gnorm"), r.metrics.gnorm as f64);
    series.push(step, &p("clipfrac"), r.metrics.clipfrac as f64);
    series.push(step, &p("entropy"), r.metrics.entropy as f64);
    series.push(step, &p("kl"), r.metrics.kl as f64);
    series.push(step, &p("ratio_max"), r.metrics.ratio_max as f64);
    series.push(step, &p("discarded_groups"), r.discarded_groups as f64);
    series.push(step, &p("padding_fraction"), r.padding_fraction);
    series.push(step, &p("extra_inference_samples"), extra_inference as f64);
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mk(group: u64, reward: f32) -> Rollout {
        Rollout {
            task_id: 0,
            group_id: group,
            policy_step: 0,
            tokens: vec![1, 5, 6, 2],
            prompt_len: 2,
            target_len: None,
            task_reward: reward,
            length_penalty: 0.0,
            reward,
            advantage: 0.0,
            sampled_probs: vec![0.5, 0.5],
            node_address: 0,
        }
    }

    #[test]
    fn filter_groups_counts_and_keeps_informative() {
        let out = filter_groups(vec![
            mk(1, 1.0),
            mk(1, 0.0),
            mk(2, 1.0),
            mk(2, 1.0), // degenerate
        ]);
        assert_eq!(out.groups_kept, 1);
        assert_eq!(out.groups_discarded, 1);
        assert_eq!(out.rollouts.len(), 2);
        assert!(out.rollouts.iter().all(|r| r.group_id == 1));
        assert!(out.rollouts.iter().any(|r| r.advantage > 0.0));
    }

    #[test]
    fn record_step_writes_canonical_series() {
        let series = Series::default();
        let report = StepReport { mean_task_reward: 0.5, ..Default::default() };
        record_step(&series, "x/", 3, &report, 7);
        assert_eq!(series.get("x/task_reward"), vec![(3, 0.5)]);
        assert_eq!(series.get("x/extra_inference_samples"), vec![(3, 7.0)]);
        assert!(series.names().contains(&"x/padding_fraction".to_string()));
    }
}
