//! PRIME-RL (paper §2.1, §3.2): the asynchronous RL coordination layer —
//! rollout generation, trainer batching, the deterministic async-k
//! pipeline driver, and the full free-running decentralized swarm.

pub mod batcher;
pub mod cheatev;
pub mod churn;
pub mod gen;
pub mod pretrain;
pub mod serve;
pub mod step;
pub mod swarm;
pub mod sync_driver;
pub mod validation;

pub use batcher::{train_on_rollouts, StepReport};
pub use cheatev::{run_cheat_ev, CheatEvConfig, CheatEvReport, NodeOutcome, Strategy};
pub use churn::{
    run_churn, run_tree_churn, ChurnConfig, ChurnReport, TreeChurnConfig, TreeChurnReport,
};
pub use gen::{group_id_base, RolloutGenerator};
pub use serve::{run_serve_load, ServeLoadConfig, ServeLoadReport};
pub use step::{filter_groups, record_step, FilterOutcome};
pub use swarm::{StepTiming, Swarm, SwarmResult, SwarmStats};
pub use sync_driver::SyncPipeline;
pub use validation::{
    GateOutcome, ReplayGuard, SamplerConfig, SamplingGate, ServeGateOutcome, SigOracle,
    SubmissionQueue, TrustOracle, ValidationPipeline, ValidatorCommitment, Verdict,
};
