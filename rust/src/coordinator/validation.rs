//! The validator node's pipeline (§2.3 at swarm scale): verification must
//! keep pace with a permissionless fleet of inference workers, so the
//! single-threaded pad-everything-to-`max_seq` path is replaced by a
//! two-stage pipeline over *waves* of submissions:
//!
//! 1. **CPU stage** — envelope signature (TOPLOC stage 0, when a
//!    [`SigOracle`] is configured), then schema / sanity / termination
//!    (stages 1–3) fan out across a [`ThreadPool`], one job per
//!    submission. Stage 0 settles attribution before any other work: a
//!    verified envelope upgrades slash attribution from "claimed" to
//!    "proven" (the signer answers for the payload, well-formed or not),
//!    while missing or unprovable envelopes yield [`Verdict::Unsigned`] /
//!    [`Verdict::Forged`] — counted, never slashed against the claimed
//!    address, and never allowed near the engine.
//! 2. **Prefill stage** — survivors are grouped by claimed policy
//!    version; [`plan_prefills`] packs their rollouts — across
//!    submissions — into length-bucketed `batch_infer`-lane prefill
//!    calls, and the computation + sampling checks (stages 4–5) run per
//!    lane with verdicts attributed back per submission.
//!
//! Verdicts come back in input order and are byte-identical to running
//! [`validate_submission_fullpad`] (the pre-pipeline reference path) on
//! each submission alone, regardless of thread count or bucket grain —
//! the equivalence tests in `tests/validation_pipeline.rs` enforce this.
//! The one deliberate exception is a mid-wave engine failure, where call
//! partitioning makes exact replay impossible: the pipeline is then
//! strictly conservative — every submission touched by a failed call is
//! dropped unjudged (never slashed), even if a sibling call saw a check
//! fail.

use std::collections::{BTreeMap, BTreeSet, VecDeque};
use std::sync::{Arc, Condvar, Mutex};
use std::time::Duration;

use crate::protocol::{SigCheck, TrustState};
use crate::rl::reward::RewardConfig;
use crate::rl::rollout_file::{Envelope, Submission};
use crate::runtime::{EngineHost, ModelSpec, ParamSet};
use crate::serving::{serve_submission_idx, ServedResponse};
use crate::tasks::dataset::Dataset;
use crate::toploc::pipeline::{plan_prefills, LaneReq};
use crate::toploc::{Rejection, Validator};
use crate::util::metrics::Counter;
use crate::util::pool::ThreadPool;
use crate::util::rng::Rng;

/// Max submissions validated per pipeline wave: bounds verdict latency
/// while leaving plenty of cross-submission material for lane packing.
pub const VALIDATION_WAVE: usize = 32;

/// Ingest queue bound: at sustained overload the oldest uploads are shed
/// first (they are the nearest to aging out of the staleness window).
pub const SUBMISSION_QUEUE_CAP: usize = 512;

/// Shared `why` for a stage-4/5 checker panic, so the packed pipeline and
/// the full-pad reference emit identical EngineFailure verdicts.
const PREFILL_CHECK_PANIC: &str = "validator panicked during prefill-stage checks";

/// Signature oracle for envelope verification (stage 0): answers whether
/// `sig` over `msg` verifies under `address`'s registered key — the
/// ledger's registry playing the public-key-registry role (§2.4.1).
/// Deliberately *not* an address→key lookup: with HMAC stand-in
/// signatures the verification key is the signing key, so key bytes must
/// never leave the registry (see `Ledger::check_address_sig`).
pub type SigOracle = dyn Fn(u64, &[u8], &[u8; 32]) -> SigCheck + Send + Sync;

/// Outcome of validating one submission.
pub enum Verdict {
    /// Every TOPLOC stage passed: feed the rollouts trainer-ward.
    Accept(Submission),
    /// Well-formed but outside the off-policy window: dropped + counted.
    /// Staleness is a liveness property, not evidence of cheating.
    Stale { node: u64, submitted: u64, current: u64, n_rollouts: usize },
    /// The validator's own side failed mid-check (engine error or a
    /// checker panic): nothing provable about the sender, so the
    /// submission is dropped unjudged. `node` is best-effort attribution
    /// for the logs (`None` when the envelope itself was unreadable).
    EngineFailure { node: Option<u64>, why: String },
    /// Failed a trust check. Slash `node` when the sender is known — with
    /// signing on that means *proven* by a verified envelope (stage 0);
    /// in legacy signature-optional mode it is the file's own unsigned
    /// claim. `None` means the file was mangled beyond attribution.
    Reject { node: Option<u64>, why: String },
    /// Signing is required but the upload carries no (version-1) envelope.
    /// Counted, never slashed: there is no one to hold accountable.
    Unsigned { why: String },
    /// An envelope is present but does not prove its claimed sender: the
    /// address is unregistered, the signature fails against the registered
    /// key, or the payload does not match the signed digest. Rejected
    /// without slashing `claimed` — slashing on an unproven claim is
    /// exactly the framing vector signing exists to close.
    Forged { claimed: u64, why: String },
}

impl Verdict {
    /// Compact comparable form `(kind, node, detail)` — what the
    /// pipeline-equivalence tests diff across configurations.
    pub fn fingerprint(&self) -> (&'static str, Option<u64>, String) {
        match self {
            Verdict::Accept(sub) => {
                ("accept", Some(sub.node_address), format!("{} rollouts", sub.rollouts.len()))
            }
            Verdict::Stale { node, submitted, current, n_rollouts } => {
                ("stale", Some(*node), format!("{submitted}/{current}/{n_rollouts}"))
            }
            Verdict::EngineFailure { node, why } => ("engine-failure", *node, why.clone()),
            Verdict::Reject { node, why } => ("reject", *node, why.clone()),
            Verdict::Unsigned { why } => ("unsigned", None, why.clone()),
            Verdict::Forged { claimed, why } => ("forged", Some(*claimed), why.clone()),
        }
    }
}

/// Bounded FIFO of raw submission uploads between the HTTP ingest handler
/// and the validator thread. FIFO matters: the previous `Vec::pop` drained
/// LIFO, starving the oldest submissions until they went stale. Consumers
/// block on a condvar (no sleep-polling); producers wake them on push.
pub struct SubmissionQueue {
    inner: Mutex<VecDeque<Vec<u8>>>,
    nonempty: Condvar,
    cap: usize,
}

impl SubmissionQueue {
    pub fn new(cap: usize) -> SubmissionQueue {
        SubmissionQueue {
            inner: Mutex::new(VecDeque::new()),
            nonempty: Condvar::new(),
            cap: cap.max(1),
        }
    }

    /// Enqueue an upload. When full, the *oldest* entries are shed (newer
    /// uploads are closer to the current policy and worth more); returns
    /// the number shed so the caller can count the drops.
    pub fn push(&self, bytes: Vec<u8>) -> u64 {
        let mut q = self.inner.lock().unwrap();
        let mut shed = 0;
        while q.len() >= self.cap {
            q.pop_front();
            shed += 1;
        }
        q.push_back(bytes);
        drop(q);
        self.nonempty.notify_one();
        shed
    }

    /// Dequeue up to `max` entries, oldest first. Blocks until at least
    /// one entry is available or `timeout` elapses (the timeout only
    /// exists so callers can re-check their stop flag — a push wakes the
    /// consumer immediately).
    pub fn drain_wait(&self, max: usize, timeout: Duration) -> Vec<Vec<u8>> {
        let mut q = self.inner.lock().unwrap();
        if q.is_empty() {
            let (guard, _) = self.nonempty.wait_timeout(q, timeout).unwrap();
            q = guard;
        }
        let n = q.len().min(max.max(1));
        q.drain(..n).collect()
    }

    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

/// First-seen registry closing the *in-window* replay gap. Binding the
/// policy step into the envelope signature makes replays worthless once
/// the step ages out of the staleness window, but an identical valid
/// envelope re-posted *within* the window would verify (and be accepted)
/// every time — double-weighting one node's rollouts in the gradient for
/// zero extra compute. The swarm's validator loop consults this before
/// buffering an accepted submission: each `(node, step, submission_idx)`
/// lands at most once, and [`ReplayGuard::advance`] prunes steps the
/// signature binding already protects. Honest workers never collide —
/// they increment `submission_idx` per upload.
#[derive(Default)]
pub struct ReplayGuard {
    /// step → set of (node, submission_idx) first sightings; keyed by
    /// step so pruning to the staleness window is one range split. The
    /// inner set is ordered too (swarmlint `unordered-iter`): guard
    /// contents feed logs and state snapshots, which must not vary by
    /// hasher seed across validator processes.
    seen: BTreeMap<u64, BTreeSet<(u64, u64)>>,
}

impl ReplayGuard {
    pub fn new() -> ReplayGuard {
        ReplayGuard::default()
    }

    /// Record a sighting; `false` means this exact submission identity
    /// was already accepted (a replay — drop it).
    pub fn first_sighting(&mut self, node: u64, step: u64, submission_idx: u64) -> bool {
        self.seen.entry(step).or_default().insert((node, submission_idx))
    }

    /// Drop bookkeeping for steps below `min_step`: anything that old is
    /// outside the staleness window, where the signature's step binding
    /// already makes replays stale-reject.
    pub fn advance(&mut self, min_step: u64) {
        self.seen = self.seen.split_off(&min_step);
    }

    pub fn len(&self) -> usize {
        // swarmlint: allow(float-fold) — usize sum; integer addition is
        // associative, only float folds need a pinned order.
        self.seen.values().map(BTreeSet::len).sum()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Every recorded sighting as `(step, node, submission_idx)`, in
    /// deterministic (fully ordered) traversal order.
    pub fn entries(&self) -> Vec<(u64, u64, u64)> {
        let mut out = Vec::with_capacity(self.len());
        for (step, ids) in &self.seen {
            for (node, idx) in ids {
                out.push((*step, *node, *idx));
            }
        }
        out
    }
}

/// The validator's commit-reveal secret for sample selection.
///
/// Selection must be *deterministic* (so a revealed secret lets anyone
/// replay exactly which submissions were checked — the validator cannot
/// bias the sample after seeing uploads) yet *unpredictable* (so a worker
/// cannot enumerate its own rollouts and cheat only on the unchecked
/// ones). Both follow from one secret: the validator publishes
/// `commitment()` (a hash of the secret) before the step, selects with
/// the secret, and reveals it after uploads close. The selection stream
/// is pure [`Rng`] folds over `(secret, step, node, submission_idx)` —
/// no wall-clock, no ambient entropy — so it survives `swarmlint` and
/// replays byte-identically on any machine.
pub struct ValidatorCommitment {
    secret: u64,
}

impl ValidatorCommitment {
    pub fn new(secret: u64) -> ValidatorCommitment {
        ValidatorCommitment { secret }
    }

    /// The public commitment to publish before uploads: a hash of the
    /// secret. Workers can verify a later reveal against this, but cannot
    /// recover the selection stream from it.
    pub fn commitment(&self) -> [u8; 32] {
        use sha2::{Digest, Sha256};
        Sha256::digest(self.secret.to_le_bytes()).into()
    }

    /// Reveal the secret (post-upload): auditors replay `selects` calls.
    pub fn reveal(&self) -> u64 {
        self.secret
    }

    /// The uniform draw in `[0, 1)` for one submission identity.
    pub fn draw(&self, step: u64, node: u64, submission_idx: u64) -> f64 {
        Rng::new(self.secret).fold(step).fold(node).fold(submission_idx).f64()
    }

    /// Whether `(step, node, submission_idx)` enters full verification at
    /// probability `p`. `p >= 1` always selects (draws live in `[0, 1)`).
    pub fn selects(&self, step: u64, node: u64, submission_idx: u64, p: f64) -> bool {
        self.draw(step, node, submission_idx) < p
    }
}

/// Knobs for the sampling pre-stage (config: `sampling-rate`,
/// `trust-promotion-streak`).
#[derive(Clone, Copy, Debug)]
pub struct SamplerConfig {
    /// Floor fraction of a proven node's submissions that still get full
    /// verification. `1.0` disables sampling (every upload is checked).
    pub sampling_rate: f64,
    /// Clean-streak length a node must hold before its verification
    /// probability starts decaying (see [`TrustState::verify_probability`]).
    pub promotion_streak: u64,
}

/// Trust lookup for the gate: node address → verification history (the
/// swarm wires this to `Ledger::trust`). A boxed closure rather than the
/// ledger itself so engine-free harnesses and tests can substitute
/// synthetic histories.
pub type TrustOracle = dyn Fn(u64) -> TrustState + Send + Sync;

/// What the sampling pre-stage decided for one upload.
pub enum GateOutcome {
    /// Selected for the full six-stage pipeline (raw bytes pass through).
    Full(Vec<u8>),
    /// Spot-check exempt this time: stage 0 proved the sender, the payload
    /// decoded cleanly, and every *deterministic* CPU check passed —
    /// sanity minus the env reward replay ([`Validator::check_sanity_pre`]:
    /// staleness, seed/rollout-count, group ids, value/reward bounds, the
    /// per-submission rollout cap) plus the stage-3 termination screen
    /// (failing groups already soft-dropped, exactly as on the full path).
    /// Only then may the submission's *claimed* rewards be admitted to the
    /// `RolloutBuffer` (flagged unverified in stats): what was sampled
    /// away is solely the expensive reward replay and the engine stages,
    /// whose lies are the ones stake + spot checks price in. May carry
    /// zero rollouts (all groups termination-dropped) — callers must not
    /// treat that as verification evidence.
    Skip(Submission),
    /// Settled before selection: forged/unsigned envelopes, undecodable
    /// payloads, identity lies, or a deterministic-check failure on the
    /// skip path — cheap proof beats any sampling rate.
    Done(Verdict),
}

/// What the serve spot-check decided for one signed [`ServedResponse`]
/// upload (see [`SamplingGate::gate_served`]).
pub enum ServeGateOutcome {
    /// Admitted on stake + trust: stage 0 proved the signer, the response
    /// decoded cleanly and passed every cheap deterministic check, but the
    /// completion was *not* recomputed this time.
    Skip(ServedResponse),
    /// Selected for full verification and the deterministic recompute
    /// reproduced the served completion token for token.
    Verified(ServedResponse),
    /// An identical `(node, step, query)` served response was already
    /// accepted — dropped, never slashed (same policy as rollout replays).
    Replay { node: u64, query_id: u64 },
    /// Settled: forged/unsigned envelope, staleness, a proven cheap-check
    /// lie, or a recompute mismatch (the slashing outcome).
    Done(Verdict),
}

/// The sampling pre-stage: decides, per upload, whether the six-stage
/// pipeline runs or the submission is admitted on stake + trust.
///
/// Ordering matters for safety: stage 0 (envelope) runs *first*, so an
/// upload nobody provably signed can never skip past verification, and
/// trust is keyed by the proven — not claimed — sender. In legacy
/// unsigned mode there is no identity to hang trust on, so everything is
/// fully verified regardless of the configured rate.
pub struct SamplingGate {
    commitment: ValidatorCommitment,
    cfg: SamplerConfig,
    trust: Arc<TrustOracle>,
    /// Deterministic-check inputs for the skip path: a skipped submission
    /// still runs every cheap CPU check (see [`GateOutcome::Skip`]) —
    /// only the env reward replay and the engine stages are sampled away.
    dataset: Arc<Dataset>,
    reward_cfg: RewardConfig,
    max_new: usize,
    max_seq: usize,
    /// Uploads routed into the full pipeline.
    pub sampled_full: Counter,
    /// Uploads admitted without reward replay / engine stages (stage 0 +
    /// decode + the deterministic CPU checks only).
    pub skipped: Counter,
    /// Full verifications forced by a reject on record (re-escalation):
    /// the node's streak has not yet re-crossed the promotion threshold.
    pub escalated: Counter,
    /// Uploads that lost the selection draw but *failed* a deterministic
    /// check: settled (rejected/stale) at the gate without ever counting
    /// as sampled or skipped.
    pub rejected_unsampled: Counter,
    /// Served responses routed into full deterministic recompute.
    pub served_full: Counter,
    /// Served responses admitted on stake + trust (cheap checks only).
    pub served_skipped: Counter,
}

impl SamplingGate {
    pub fn new(
        commitment: ValidatorCommitment,
        cfg: SamplerConfig,
        trust: Arc<TrustOracle>,
        dataset: Arc<Dataset>,
        reward_cfg: RewardConfig,
        max_new: usize,
        max_seq: usize,
    ) -> SamplingGate {
        SamplingGate {
            commitment,
            cfg,
            trust,
            dataset,
            reward_cfg,
            max_new,
            max_seq,
            sampled_full: Counter::default(),
            skipped: Counter::default(),
            escalated: Counter::default(),
            rejected_unsampled: Counter::default(),
            served_full: Counter::default(),
            served_skipped: Counter::default(),
        }
    }

    /// Gate one raw upload. On the skip path `validator` runs stage 1's
    /// schema check *and* the deterministic subset of stages 2–3
    /// ([`Validator::check_sanity_pre`] + overlong/termination screens):
    /// a submission only rides on stake + trust past the checks a replay
    /// could not run from the file alone. `current` is the trainer's
    /// policy version — the same staleness input the full pipeline gets.
    pub fn gate(
        &self,
        signing: Option<&Arc<SigOracle>>,
        validator: &Validator,
        current: u64,
        bytes: Vec<u8>,
    ) -> GateOutcome {
        let env = match check_envelope(signing, &bytes) {
            Stage0::Done(v) => return GateOutcome::Done(v),
            Stage0::Payload { proven, .. } => match proven {
                // No provable sender (legacy mode): trust has nothing to
                // key on, so sampling never applies.
                None => {
                    self.sampled_full.inc();
                    return GateOutcome::Full(bytes);
                }
                Some(env) => env,
            },
        };
        let t = (self.trust)(env.node_address);
        let p = t.verify_probability(self.cfg.sampling_rate, self.cfg.promotion_streak);
        if p >= 1.0 {
            if t.rejects > 0 {
                self.escalated.inc();
            }
            self.sampled_full.inc();
            return GateOutcome::Full(bytes);
        }
        if self.commitment.selects(env.step, env.node_address, env.submission_idx, p) {
            self.sampled_full.inc();
            return GateOutcome::Full(bytes);
        }
        // Skip path: the envelope is already proven (stage 0 ran above);
        // the payload must still decode and agree with the identity the
        // signature proves. Both failures are the signer's to answer for.
        // swarmlint: allow(panic-path) — check_envelope proved an envelope
        // is present, so re-parsing the same bytes cannot fail.
        let (_, payload) = Envelope::parse(&bytes).expect("envelope re-parse");
        let sub = match validator.check_file(payload) {
            Ok(sub) => sub,
            Err(e) => {
                return GateOutcome::Done(Verdict::Reject {
                    node: Some(env.node_address),
                    why: format!("{e:?}"),
                });
            }
        };
        if sub.node_address != env.node_address
            || sub.step != env.step
            || sub.submission_idx != env.submission_idx
        {
            return GateOutcome::Done(Verdict::Reject {
                node: Some(env.node_address),
                why: format!(
                    "payload claims node {}/step {}/idx {} but the envelope proves \
                     node {}/step {}/idx {}",
                    sub.node_address,
                    sub.step,
                    sub.submission_idx,
                    env.node_address,
                    env.step,
                    env.submission_idx
                ),
            });
        }
        // Deterministic CPU checks, mirroring `cpu_stages` minus the env
        // reward replay. Without these, a skipped upload could claim
        // arbitrarily many rollouts at arbitrary reward values under
        // colliding group ids — unbounded claimable value against a fixed
        // forfeitable stake, which breaks the negative-EV sizing
        // (`protocol::min_negative_ev_stake` assumes at most the
        // per-submission cap in reward units per upload).
        let node = env.node_address;
        if let Err(e) =
            validator.check_sanity_pre(&sub, &self.dataset, &self.reward_cfg, current, self.max_new)
        {
            self.rejected_unsampled.inc();
            return GateOutcome::Done(match e {
                Rejection::StalePolicy { submitted, current } => {
                    Verdict::Stale { node, submitted, current, n_rollouts: sub.rollouts.len() }
                }
                other => Verdict::Reject { node: Some(node), why: format!("{other:?}") },
            });
        }
        if let Some((i, w)) =
            sub.rollouts.iter().enumerate().find(|(_, w)| w.rollout.tokens.len() > self.max_seq)
        {
            self.rejected_unsampled.inc();
            return GateOutcome::Done(Verdict::Reject {
                node: Some(node),
                why: format!(
                    "rollout {i}: {} tokens exceeds max_seq {}",
                    w.rollout.tokens.len(),
                    self.max_seq
                ),
            });
        }
        // Stage-3 termination screen, soft exactly as on the full path:
        // failing groups are discarded, never slashed. The submission may
        // come out empty — still a Skip (the caller drops it from the
        // buffer), never an Accept: a skipped upload must not manufacture
        // clean-verification trust evidence.
        let mut sub = sub;
        let mut bad_groups: BTreeSet<u64> = BTreeSet::new();
        for w in &sub.rollouts {
            if validator.check_termination(w, self.max_new, self.max_seq).is_err() {
                bad_groups.insert(w.rollout.group_id);
            }
        }
        if !bad_groups.is_empty() {
            sub.rollouts.retain(|w| !bad_groups.contains(&w.rollout.group_id));
        }
        self.skipped.inc();
        GateOutcome::Skip(sub)
    }

    /// Spot-check one signed served response through the same trust
    /// machinery as rollout uploads. Serve-mode completions are
    /// deterministic in public fields (`serving::serve_rng(step,
    /// query_id)` over the response's own prompt), so `recompute` — any
    /// closure that replays the decode under the claimed policy step —
    /// returns the full expected token sequence and a mismatch is a
    /// *proven* forgery by the envelope's signer: the slashing outcome.
    ///
    /// Ordering mirrors [`SamplingGate::gate`]: stage 0 (envelope) first,
    /// then decode + identity agreement, staleness, the cheap
    /// deterministic checks every response must pass (probs shape/range,
    /// EOS-termination plausibility against
    /// [`Validator`](crate::toploc::Validator)'s `eos_prob_min`), then the
    /// replay guard (keyed on the [`serve_submission_idx`]-namespaced
    /// index, so serve replays can never shadow rollout replays), and only
    /// then the trust-weighted selection draw. `recompute` failing is an
    /// [`Verdict::EngineFailure`] — our side broke, nothing proven, no
    /// slash. In legacy unsigned mode there is no identity to hang trust
    /// on, so every response is fully recomputed.
    pub fn gate_served(
        &self,
        signing: Option<&Arc<SigOracle>>,
        validator: &crate::toploc::Validator,
        current: u64,
        replay: &mut ReplayGuard,
        bytes: &[u8],
        recompute: &dyn Fn(&ServedResponse) -> anyhow::Result<Vec<i32>>,
    ) -> ServeGateOutcome {
        let (payload, proven) = match check_envelope(signing, bytes) {
            Stage0::Done(v) => return ServeGateOutcome::Done(v),
            Stage0::Payload { payload, proven } => (payload, proven),
        };
        let resp = match ServedResponse::decode(payload) {
            Ok(r) => r,
            Err(e) => {
                // With a verified envelope the garbage is provably the
                // signer's; without one there is no attribution.
                return ServeGateOutcome::Done(Verdict::Reject {
                    node: proven.as_ref().map(|env| env.node_address),
                    why: format!("served response: {e}"),
                });
            }
        };
        if let Some(env) = &proven {
            if resp.node_address != env.node_address
                || resp.step != env.step
                || serve_submission_idx(resp.query_id) != env.submission_idx
            {
                return ServeGateOutcome::Done(Verdict::Reject {
                    node: Some(env.node_address),
                    why: format!(
                        "served response claims node {}/step {}/query {} but the envelope \
                         proves node {}/step {}/idx {:#x}",
                        resp.node_address,
                        resp.step,
                        resp.query_id,
                        env.node_address,
                        env.step,
                        env.submission_idx
                    ),
                });
            }
        }
        let node = resp.node_address;
        // Staleness: the same off-policy window rollouts live under — a
        // response decoded under an aged-out policy is dropped, not
        // slashed (liveness, not dishonesty).
        if resp.step + validator.cfg.max_policy_lag < current {
            return ServeGateOutcome::Done(Verdict::Stale {
                node,
                submitted: resp.step,
                current,
                n_rollouts: 1,
            });
        }
        // Cheap deterministic checks: shape lies no replay is needed to
        // catch. Token-alphabet checks deliberately stay out — serving
        // prompts are model-alphabet, not the RL task tokenizer's.
        let completion_len = resp.tokens.len() - resp.prompt_len;
        if resp.sampled_probs.len() != completion_len {
            self.rejected_unsampled.inc();
            return ServeGateOutcome::Done(Verdict::Reject {
                node: Some(node),
                why: format!(
                    "{} sampled probs for a {completion_len}-token completion",
                    resp.sampled_probs.len()
                ),
            });
        }
        if !resp.sampled_probs.iter().all(|p| (0.0..=1.0).contains(p) && p.is_finite()) {
            self.rejected_unsampled.inc();
            return ServeGateOutcome::Done(Verdict::Reject {
                node: Some(node),
                why: "sampled prob outside [0,1]".into(),
            });
        }
        if resp.finish_eos
            && (resp.tokens.last() != Some(&crate::data::tokenizer::EOS)
                || resp.eos_prob <= validator.cfg.eos_prob_min)
        {
            self.rejected_unsampled.inc();
            return ServeGateOutcome::Done(Verdict::Reject {
                node: Some(node),
                why: format!("implausible EOS termination (p={})", resp.eos_prob),
            });
        }
        // Replay guard, shared keyspace with rollouts: SERVE_IDX_BIT keeps
        // the identities disjoint, so a re-posted served response can
        // never evict or shadow a rollout sighting (or vice versa).
        if !replay.first_sighting(node, resp.step, serve_submission_idx(resp.query_id)) {
            return ServeGateOutcome::Replay { node, query_id: resp.query_id };
        }
        // Trust-weighted selection — proven senders only.
        let full = match &proven {
            None => true,
            Some(env) => {
                let t = (self.trust)(node);
                let p = t.verify_probability(self.cfg.sampling_rate, self.cfg.promotion_streak);
                if p >= 1.0 {
                    if t.rejects > 0 {
                        self.escalated.inc();
                    }
                    true
                } else {
                    self.commitment.selects(env.step, node, env.submission_idx, p)
                }
            }
        };
        if !full {
            self.served_skipped.inc();
            return ServeGateOutcome::Skip(resp);
        }
        self.served_full.inc();
        match recompute(&resp) {
            Err(e) => ServeGateOutcome::Done(Verdict::EngineFailure {
                node: Some(node),
                why: format!("serve recompute: {e}"),
            }),
            Ok(want) if want == resp.tokens => ServeGateOutcome::Verified(resp),
            Ok(want) => ServeGateOutcome::Done(Verdict::Reject {
                node: Some(node),
                why: format!(
                    "served completion does not match deterministic recompute \
                     ({} claimed vs {} recomputed tokens)",
                    resp.tokens.len(),
                    want.len()
                ),
            }),
        }
    }
}

/// Stage 0–3 output for one submission.
enum CpuOutcome {
    /// Passed the CPU stages (soft-dropped groups removed): needs prefill.
    Ready(Submission),
    /// Verdict settled without touching the engine.
    Done(Verdict),
}

/// Stage 0 outcome: the payload to keep checking, or an early verdict.
enum Stage0<'a> {
    /// `proven` is the verified envelope when signing is on (`None` in
    /// legacy signature-optional mode, where a present envelope is
    /// stripped but proves nothing).
    Payload { payload: &'a [u8], proven: Option<Envelope> },
    Done(Verdict),
}

/// Stage 0 — envelope signature check, before any other work. With
/// signing on, only three outcomes exist: a *proven* sender (valid
/// signature from the registered key over exactly these payload bytes),
/// [`Verdict::Unsigned`], or [`Verdict::Forged`]. A valid signature makes
/// every later failure the signer's to answer for; an invalid one must
/// never be slashed against the claimed address (framing).
fn check_envelope<'a>(signing: Option<&Arc<SigOracle>>, bytes: &'a [u8]) -> Stage0<'a> {
    let parsed = Envelope::parse(bytes);
    let Some(oracle) = signing else {
        // Legacy mode: strip an envelope if present so signed workers and
        // unsigned fixtures interoperate; attribution stays best-effort.
        return match parsed {
            Some((_, payload)) => Stage0::Payload { payload, proven: None },
            None => Stage0::Payload { payload: bytes, proven: None },
        };
    };
    let Some((env, payload)) = parsed else {
        return Stage0::Done(Verdict::Unsigned {
            why: "submission carries no signed envelope".into(),
        });
    };
    let msg = Envelope::signing_bytes(
        env.node_address,
        env.step,
        env.submission_idx,
        &env.payload_digest,
    );
    match oracle(env.node_address, &msg, &env.sig) {
        SigCheck::NoKey => {
            return Stage0::Done(Verdict::Forged {
                claimed: env.node_address,
                why: format!("address {} has no registered key", env.node_address),
            });
        }
        SigCheck::Mismatch => {
            return Stage0::Done(Verdict::Forged {
                claimed: env.node_address,
                why: "signature does not verify against the registered key".into(),
            });
        }
        SigCheck::Valid => {}
    }
    if !env.digest_matches(payload) {
        // The signature only vouches for the signed digest; these payload
        // bytes are someone else's tamper (or corruption in flight).
        return Stage0::Done(Verdict::Forged {
            claimed: env.node_address,
            why: "payload does not match the signed digest".into(),
        });
    }
    Stage0::Payload { payload, proven: Some(env) }
}

/// Stages 0–3: envelope, file, sanity, termination. Pure CPU — safe to
/// fan out.
fn cpu_stages(
    validator: &Validator,
    dataset: &Dataset,
    reward_cfg: &RewardConfig,
    signing: Option<&Arc<SigOracle>>,
    bytes: &[u8],
    current: u64,
    max_new: usize,
    max_seq: usize,
) -> CpuOutcome {
    let (payload, proven) = match check_envelope(signing, bytes) {
        Stage0::Payload { payload, proven } => (payload, proven),
        Stage0::Done(v) => return CpuOutcome::Done(v),
    };
    let mut sub = match validator.check_file(payload) {
        Ok(sub) => sub,
        Err(e) => {
            // With a verified envelope the malformed payload is *proven*
            // to come from the signer — slash them, not a guess. Without
            // one (legacy mode), fall back to best-effort attribution:
            // the same trust level as a well-formed submission's
            // self-declared `node_address` column.
            return CpuOutcome::Done(Verdict::Reject {
                node: proven
                    .as_ref()
                    .map(|env| env.node_address)
                    .or_else(|| Submission::peek_node_address(bytes)),
                why: format!("{e:?}"),
            });
        }
    };
    if let Some(env) = &proven {
        // The payload's self-declared identity must match what the
        // signature proves; a mismatch is a proven lie by the signer.
        if sub.node_address != env.node_address
            || sub.step != env.step
            || sub.submission_idx != env.submission_idx
        {
            return CpuOutcome::Done(Verdict::Reject {
                node: Some(env.node_address),
                why: format!(
                    "payload claims node {}/step {}/idx {} but the envelope proves \
                     node {}/step {}/idx {}",
                    sub.node_address,
                    sub.step,
                    sub.submission_idx,
                    env.node_address,
                    env.step,
                    env.submission_idx
                ),
            });
        }
    }
    let node = sub.node_address;
    if let Err(e) = validator.check_sanity(&sub, dataset, reward_cfg, current, max_new) {
        return CpuOutcome::Done(match e {
            Rejection::StalePolicy { submitted, current } => {
                Verdict::Stale { node, submitted, current, n_rollouts: sub.rollouts.len() }
            }
            other => Verdict::Reject { node: Some(node), why: format!("{other:?}") },
        });
    }
    // Overlong sequences cannot be prefilled (no frame is wider than
    // max_seq; the old path would have panicked building its padded
    // buffer). Honest workers cannot produce them, so this is a hard
    // reject, not a soft drop.
    if let Some((i, w)) =
        sub.rollouts.iter().enumerate().find(|(_, w)| w.rollout.tokens.len() > max_seq)
    {
        return CpuOutcome::Done(Verdict::Reject {
            node: Some(node),
            why: format!(
                "rollout {i}: {} tokens exceeds max_seq {max_seq}",
                w.rollout.tokens.len()
            ),
        });
    }
    // Termination failures on individual rollouts are *soft*: an honest
    // sampler occasionally draws a low-probability EOS, so those rollouts
    // are discarded (their whole group with them) rather than slashing the
    // node. Systematic early truncation still surfaces as the node's
    // contributions evaporating.
    // Ordered set (swarmlint `unordered-iter`): group membership checks
    // don't iterate, but keeping trust-path containers ordered by policy
    // beats auditing each future use.
    let mut bad_groups: BTreeSet<u64> = BTreeSet::new();
    for w in &sub.rollouts {
        if validator.check_termination(w, max_new, max_seq).is_err() {
            bad_groups.insert(w.rollout.group_id);
        }
    }
    if !bad_groups.is_empty() {
        sub.rollouts.retain(|w| !bad_groups.contains(&w.rollout.group_id));
    }
    if sub.rollouts.is_empty() {
        // Nothing usable, but not evidence of cheating — discard quietly.
        return CpuOutcome::Done(Verdict::Accept(sub));
    }
    CpuOutcome::Ready(sub)
}

/// [`cpu_stages`] behind a panic firewall: the checks run over
/// attacker-controlled bytes on pool workers, and a panicking checker
/// must not hang the wave (a dead job would leave its result slot empty)
/// or take the validator thread down. A panic proves nothing about the
/// sender — our bug or their malice — so the submission is dropped
/// unjudged as an [`Verdict::EngineFailure`], never slashed.
fn cpu_stages_guarded(
    validator: &Validator,
    dataset: &Dataset,
    reward_cfg: &RewardConfig,
    signing: Option<&Arc<SigOracle>>,
    bytes: &[u8],
    current: u64,
    max_new: usize,
    max_seq: usize,
) -> CpuOutcome {
    std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
        cpu_stages(validator, dataset, reward_cfg, signing, bytes, current, max_new, max_seq)
    }))
    .unwrap_or_else(|_| {
        CpuOutcome::Done(Verdict::EngineFailure {
            node: Submission::peek_node_address(bytes),
            why: "validator panicked during CPU-stage checks".into(),
        })
    })
}

/// The parallel, length-bucketed validation pipeline (see module docs).
pub struct ValidationPipeline {
    validator: Arc<Validator>,
    dataset: Arc<Dataset>,
    reward_cfg: Arc<RewardConfig>,
    host: Arc<EngineHost>,
    spec: ModelSpec,
    max_new: usize,
    /// Length-bucket grain in tokens: prefill calls pad to a multiple of
    /// this (resolved from the TOPLOC commit interval when the config
    /// said 0).
    bucket_tokens: usize,
    /// CPU-stage fan-out; `None` runs stages 0–3 inline on the calling
    /// thread (the sequential path, `validator-threads <= 1`).
    pool: Option<ThreadPool>,
    /// Stage-0 key registry. `Some` = signatures required
    /// (`require-signed-submissions`, the real swarm); `None` = legacy
    /// signature-optional mode for fixtures and benches.
    signing: Option<Arc<SigOracle>>,
    /// Prefill calls issued (observability: lane efficiency is
    /// rollouts-verified / (calls x batch_infer)).
    pub prefill_calls: Counter,
}

impl ValidationPipeline {
    /// Build the pipeline. Errors when the validator's environment
    /// registry does not fingerprint-match the dataset's: reward
    /// re-verification (stage 2) replays each task's env verifier, and
    /// doing that under different env semantics than the dataset was
    /// generated with would slash honest workers — the silent-mismatch
    /// failure the registry fingerprint exists to make loud.
    pub fn new(
        validator: Validator,
        dataset: Arc<Dataset>,
        reward_cfg: RewardConfig,
        host: Arc<EngineHost>,
        max_new: usize,
        threads: usize,
        bucket_tokens: usize,
    ) -> anyhow::Result<ValidationPipeline> {
        anyhow::ensure!(
            validator.registry.fingerprint() == dataset.fingerprint,
            "validator registry fingerprint {:#x} != dataset fingerprint {:#x}: refusing to \
             re-verify rewards under mismatched environment semantics",
            validator.registry.fingerprint(),
            dataset.fingerprint
        );
        let spec = host.spec().clone();
        let bucket =
            if bucket_tokens == 0 { spec.toploc_interval.max(1) } else { bucket_tokens };
        Ok(ValidationPipeline {
            validator: Arc::new(validator),
            dataset,
            reward_cfg: Arc::new(reward_cfg),
            host,
            spec,
            max_new,
            bucket_tokens: bucket,
            pool: (threads > 1).then(|| ThreadPool::new(threads)),
            signing: None,
            prefill_calls: Counter::default(),
        })
    }

    /// Require signed submission envelopes, verified through `oracle`
    /// (the ledger's signature check against its key registry) as stage 0.
    pub fn with_signing(mut self, oracle: Arc<SigOracle>) -> ValidationPipeline {
        self.signing = Some(oracle);
        self
    }

    /// Validate one wave of raw submissions; verdicts in input order.
    ///
    /// `current_step` is read once for the whole CPU wave and re-read on a
    /// version-lookup miss (the trainer may have advanced — and pruned —
    /// while the checks ran, and judging "future" against a stale snapshot
    /// could slash an honest-but-aged-out version). `version_params` maps
    /// a policy version to the trusted checkpoint to prefill under.
    pub fn validate_batch(
        &self,
        batch: Vec<Vec<u8>>,
        current_step: &dyn Fn() -> u64,
        version_params: &dyn Fn(u64) -> Option<Arc<ParamSet>>,
    ) -> Vec<Verdict> {
        let n = batch.len();
        let now = current_step();

        // --- CPU stage: stages 0–3, one job per submission ---
        let outcomes: Vec<CpuOutcome> = match &self.pool {
            None => batch
                .iter()
                .map(|b| {
                    cpu_stages_guarded(
                        &self.validator,
                        &self.dataset,
                        &self.reward_cfg,
                        self.signing.as_ref(),
                        b,
                        now,
                        self.max_new,
                        self.spec.max_seq,
                    )
                })
                .collect(),
            Some(pool) => {
                let slots: Arc<Mutex<Vec<Option<CpuOutcome>>>> =
                    Arc::new(Mutex::new((0..n).map(|_| None).collect()));
                for (i, bytes) in batch.into_iter().enumerate() {
                    let validator = Arc::clone(&self.validator);
                    let dataset = Arc::clone(&self.dataset);
                    let reward = Arc::clone(&self.reward_cfg);
                    let signing = self.signing.clone();
                    let slots = Arc::clone(&slots);
                    let (max_new, max_seq) = (self.max_new, self.spec.max_seq);
                    pool.submit(move || {
                        let out = cpu_stages_guarded(
                            &validator,
                            &dataset,
                            &reward,
                            signing.as_ref(),
                            &bytes,
                            now,
                            max_new,
                            max_seq,
                        );
                        slots.lock().unwrap()[i] = Some(out);
                    });
                }
                pool.wait_idle();
                let mut slots = slots.lock().unwrap();
                std::mem::take(&mut *slots)
                    .into_iter()
                    // swarmlint: allow(panic-path) — wait_idle returns only after
                    // every pool job wrote its slot; a hole is our scheduling bug,
                    // not hostile input, and must not be silently dropped.
                    .map(|o| o.expect("cpu stage completed"))
                    .collect()
            }
        };

        // --- assemble: early verdicts out, survivors grouped by version ---
        let mut verdicts: Vec<Option<Verdict>> = (0..n).map(|_| None).collect();
        let mut pending: Vec<Option<Submission>> = (0..n).map(|_| None).collect();
        let mut by_version: BTreeMap<u64, Vec<usize>> = BTreeMap::new();
        for (i, out) in outcomes.into_iter().enumerate() {
            match out {
                CpuOutcome::Done(v) => verdicts[i] = Some(v),
                CpuOutcome::Ready(sub) => {
                    by_version.entry(sub.step).or_default().push(i);
                    pending[i] = Some(sub);
                }
            }
        }

        // --- prefill stage: stages 4–5 over packed, bucketed calls ---
        // Per-submission failure state. The winning rejection is the one
        // at the lowest rollout index, matching the sequential path (which
        // checks rollouts in order and stops at the first failure) no
        // matter which packed call surfaced it first.
        let mut failed: Vec<Option<(usize, String)>> = (0..n).map(|_| None).collect();
        let mut engine_failed: Vec<Option<String>> = (0..n).map(|_| None).collect();

        let (b, d, v) = (self.spec.batch_infer, self.spec.d_model, self.spec.vocab);
        for (&version, subs) in &by_version {
            // The versions map retains the whole staleness window (plus
            // margin): a miss on an old version means it aged out (stale,
            // not dishonest). A miss on a *future* version is different —
            // honest workers can hold at most the checkpoint published
            // during the current step (version current + 1), and anything
            // the trainer has published is in the map, so claiming a
            // version beyond that is provably fabricated.
            let Some(params) = version_params(version) else {
                let now = current_step();
                for &i in subs {
                    // swarmlint: allow(panic-path) — assemble-loop invariant:
                    // verdicts[i] is None exactly while pending[i] is Some.
                    let sub = pending[i].take().expect("pending submission");
                    verdicts[i] = Some(if version > now + 1 {
                        Verdict::Reject {
                            node: Some(sub.node_address),
                            why: format!(
                                "unpublished policy version {version} (current {now})"
                            ),
                        }
                    } else {
                        Verdict::Stale {
                            node: sub.node_address,
                            submitted: version,
                            current: now,
                            n_rollouts: sub.rollouts.len(),
                        }
                    });
                }
                continue;
            };
            let mut lanes = Vec::new();
            for &i in subs {
                // swarmlint: allow(panic-path) — assemble-loop invariant:
                // every index grouped under a version is still pending.
                let rollouts = &pending[i].as_ref().expect("pending submission").rollouts;
                for (ri, w) in rollouts.iter().enumerate() {
                    lanes.push(LaneReq { sub: i, rollout: ri, len: w.rollout.tokens.len() });
                }
            }
            for call in plan_prefills(lanes, b, self.bucket_tokens, self.spec.max_seq) {
                // Lanes that can no longer change their submission's
                // verdict are dead weight: anything from a submission
                // dropped unjudged (engine failure), and anything at a
                // higher rollout index than an already-recorded failure
                // (only a lower index can win the min-index attribution —
                // the sequential path would never have reached them).
                let doomed = |l: &LaneReq| {
                    engine_failed[l.sub].is_some()
                        || matches!(&failed[l.sub], Some((ri, _)) if l.rollout > *ri)
                };
                let live: Vec<LaneReq> =
                    call.lanes.iter().copied().filter(|l| !doomed(l)).collect();
                if live.is_empty() {
                    continue;
                }
                let t = call.seq_len;
                let mut padded = vec![self.spec.pad_id; live.len() * t];
                for (lane, l) in live.iter().enumerate() {
                    // swarmlint: allow(panic-path) — lanes are built from
                    // pending entries and `doomed` filtered the taken ones.
                    let psub = pending[l.sub].as_ref().expect("pending submission");
                    let toks = &psub.rollouts[l.rollout].rollout.tokens;
                    padded[lane * t..lane * t + toks.len()].copy_from_slice(toks);
                }
                self.prefill_calls.inc();
                let (logits, hidden, stride) =
                    match self.host.prefill_rows(Arc::clone(&params), padded, live.len(), t) {
                        Ok(out) => out,
                        // A trusted-side engine error proves nothing about
                        // the nodes — slashing here would exclude honest
                        // workers for our own infrastructure failures.
                        Err(e) => {
                            let why = format!("prefill: {e}");
                            for l in &live {
                                engine_failed[l.sub].get_or_insert_with(|| why.clone());
                            }
                            continue;
                        }
                    };
                for (lane, l) in live.iter().enumerate() {
                    // Re-check: a failure recorded earlier in this same
                    // call can doom later lanes of the same submission.
                    if engine_failed[l.sub].is_some()
                        || matches!(&failed[l.sub], Some((ri, _)) if l.rollout > *ri)
                    {
                        continue;
                    }
                    // swarmlint: allow(panic-path) — same lane invariant as the
                    // padding loop above: live lanes index pending submissions.
                    let w = &pending[l.sub].as_ref().expect("pending submission").rollouts
                        [l.rollout];
                    let h = &hidden[lane * stride * d..(lane + 1) * stride * d];
                    let lg = &logits[lane * stride * v..(lane + 1) * stride * v];
                    // Same panic firewall as the CPU stages: these checks
                    // also consume attacker-controlled data, and a panic
                    // must not kill the long-lived validator thread.
                    let res = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                        self.validator
                            .check_computation(w, h, d)
                            .and_then(|()| self.validator.check_sampling(w, lg, v))
                    }));
                    match res {
                        Ok(Ok(())) => {}
                        Ok(Err(e)) => {
                            if !matches!(&failed[l.sub], Some((ri, _)) if l.rollout >= *ri) {
                                failed[l.sub] = Some((l.rollout, format!("{e:?}")));
                            }
                        }
                        Err(_) => {
                            engine_failed[l.sub].get_or_insert_with(|| {
                                PREFILL_CHECK_PANIC.to_string()
                            });
                        }
                    }
                }
            }
        }

        // --- final assembly, input order ---
        // Engine failure outranks rejection: if any of a submission's
        // lanes hit a prefill error, the engine was unhealthy while
        // judging it, and a "failed" check from a sibling call can't be
        // trusted as slashing evidence (the sequential path would have
        // returned EngineFailure at its first bad chunk and never reached
        // the rest). Drop unjudged instead of slashing.
        for i in 0..n {
            if verdicts[i].is_some() {
                continue;
            }
            // swarmlint: allow(panic-path) — the guard above: no verdict yet
            // means this submission was never taken out of pending.
            let sub = pending[i].take().expect("pending submission");
            let node = sub.node_address;
            verdicts[i] = Some(if let Some(why) = engine_failed[i].take() {
                Verdict::EngineFailure { node: Some(node), why }
            } else if let Some((_, why)) = failed[i].take() {
                Verdict::Reject { node: Some(node), why }
            } else {
                Verdict::Accept(sub)
            });
        }
        // swarmlint: allow(panic-path) — the sweep above assigns a verdict
        // to every remaining None; a hole is a pipeline bug worth crashing.
        verdicts.into_iter().map(|v| v.expect("verdict assigned")).collect()
    }
}

/// The pre-pipeline reference path: validate one submission alone, every
/// prefill padded to the full `[batch_infer, max_seq]` frame. Kept as the
/// baseline that `toploc_bench` and the pipeline-equivalence tests compare
/// against — behavior changes here must be mirrored in
/// [`ValidationPipeline::validate_batch`].
#[allow(clippy::too_many_arguments)]
pub fn validate_submission_fullpad(
    validator: &Validator,
    signing: Option<&Arc<SigOracle>>,
    bytes: &[u8],
    dataset: &Dataset,
    reward_cfg: &RewardConfig,
    host: &Arc<EngineHost>,
    spec: &ModelSpec,
    max_new: usize,
    current_step: &dyn Fn() -> u64,
    version_params: &dyn Fn(u64) -> Option<Arc<ParamSet>>,
) -> Verdict {
    let sub = match cpu_stages_guarded(
        validator,
        dataset,
        reward_cfg,
        signing,
        bytes,
        current_step(),
        max_new,
        spec.max_seq,
    ) {
        CpuOutcome::Done(v) => return v,
        CpuOutcome::Ready(sub) => sub,
    };
    let node = sub.node_address;
    let Some(params) = version_params(sub.step) else {
        let now = current_step();
        if sub.step > now + 1 {
            return Verdict::Reject {
                node: Some(node),
                why: format!("unpublished policy version {} (current {now})", sub.step),
            };
        }
        return Verdict::Stale {
            node,
            submitted: sub.step,
            current: now,
            n_rollouts: sub.rollouts.len(),
        };
    };
    let (b, t, d, v) = (spec.batch_infer, spec.max_seq, spec.d_model, spec.vocab);
    for chunk in sub.rollouts.chunks(b) {
        let mut padded = vec![spec.pad_id; b * t];
        for (i, w) in chunk.iter().enumerate() {
            padded[i * t..i * t + w.rollout.tokens.len()].copy_from_slice(&w.rollout.tokens);
        }
        let (logits, hidden) = match host.prefill(Arc::clone(&params), padded) {
            Ok(out) => out,
            Err(e) => {
                return Verdict::EngineFailure { node: Some(node), why: format!("prefill: {e}") }
            }
        };
        for (i, w) in chunk.iter().enumerate() {
            let h = &hidden[i * t * d..(i + 1) * t * d];
            let l = &logits[i * t * v..(i + 1) * t * v];
            let res = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                validator.check_computation(w, h, d).and_then(|()| validator.check_sampling(w, l, v))
            }));
            match res {
                Ok(Ok(())) => {}
                Ok(Err(e)) => {
                    return Verdict::Reject { node: Some(node), why: format!("{e:?}") }
                }
                Err(_) => {
                    return Verdict::EngineFailure {
                        node: Some(node),
                        why: PREFILL_CHECK_PANIC.to_string(),
                    }
                }
            }
        }
    }
    Verdict::Accept(sub)
}

/// Stages 0–3 alone (envelope, schema, sanity, termination) — the CPU
/// projection of the pipeline, for engine-free harnesses. Stage 2's
/// reward re-verification is in here, and it is the economically relevant
/// catch: a worker claiming reward for a wrong answer is caught by pure
/// CPU replay of the task verifier, no prefill needed. The cheat-EV CI
/// gate (`coordinator::cheatev`) drives this against the sampling gate
/// and the ledger's stake accounting. `Accept` here means "passed every
/// check that doesn't need the engine" — the full pipeline may still
/// reject on stages 4–5.
#[allow(clippy::too_many_arguments)]
pub fn validate_submission_cpu(
    validator: &Validator,
    signing: Option<&Arc<SigOracle>>,
    bytes: &[u8],
    dataset: &Dataset,
    reward_cfg: &RewardConfig,
    current: u64,
    max_new: usize,
    max_seq: usize,
) -> Verdict {
    match cpu_stages_guarded(
        validator, dataset, reward_cfg, signing, bytes, current, max_new, max_seq,
    ) {
        CpuOutcome::Done(v) => v,
        CpuOutcome::Ready(sub) => Verdict::Accept(sub),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::protocol::Identity;

    /// Stage 0 never needs an engine: every outcome here settles before
    /// any prefill (or even rpq decoding) happens.
    #[test]
    fn stage0_envelope_outcomes() {
        let a = Identity::from_seed(1);
        let b = Identity::from_seed(2);
        // Stage 0 judges the envelope only — payload contents are opaque.
        let payload = b"opaque payload bytes".to_vec();
        let sealed_a = Envelope::seal(&a, 3, 0, &payload);
        // Oracle over a one-entry registry: only `a` is registered, and
        // the oracle answers verify-or-not without exposing key bytes.
        let keys = std::collections::BTreeMap::from([(a.address, a.secret())]);
        let lookup: Arc<SigOracle> =
            Arc::new(move |addr, msg: &[u8], sig: &[u8; 32]| match keys.get(&addr) {
                None => SigCheck::NoKey,
                Some(key) if crate::protocol::identity::hmac_verify(key, msg, sig) => {
                    SigCheck::Valid
                }
                Some(_) => SigCheck::Mismatch,
            });
        let signing = Some(&lookup);

        // Legacy mode passes raw bytes through untouched and strips (but
        // does not trust) an envelope.
        match check_envelope(None, &payload) {
            Stage0::Payload { payload: p, proven: None } => assert_eq!(p, &payload[..]),
            _ => panic!("legacy raw bytes should pass through"),
        }
        match check_envelope(None, &sealed_a) {
            Stage0::Payload { payload: p, proven: None } => assert_eq!(p, &payload[..]),
            _ => panic!("legacy sealed bytes should strip the envelope"),
        }

        // Signing on: raw bytes are Unsigned.
        match check_envelope(signing, &payload) {
            Stage0::Done(Verdict::Unsigned { .. }) => {}
            _ => panic!("raw bytes must be Unsigned when signing is required"),
        }
        // A genuine envelope from a registered key proves its sender.
        match check_envelope(signing, &sealed_a) {
            Stage0::Payload { payload: p, proven: Some(env) } => {
                assert_eq!(p, &payload[..]);
                assert_eq!(env.node_address, a.address);
                assert_eq!(env.step, 3);
            }
            _ => panic!("valid envelope must prove its sender"),
        }
        // Unregistered signer: forged, attribution is log-only.
        match check_envelope(signing, &Envelope::seal(&b, 3, 0, &payload)) {
            Stage0::Done(Verdict::Forged { claimed, why }) => {
                assert_eq!(claimed, b.address);
                assert!(why.contains("no registered key"), "{why}");
            }
            _ => panic!("unregistered address must be Forged"),
        }
        // Framing: node B signs a header claiming node A's address. The
        // signature fails against A's registered key — A is never slashed.
        use sha2::{Digest, Sha256};
        let digest: [u8; 32] = Sha256::digest(&payload).into();
        let framed = Envelope {
            node_address: a.address,
            step: 3,
            submission_idx: 0,
            payload_digest: digest,
            sig: b.sign(&Envelope::signing_bytes(a.address, 3, 0, &digest)),
        }
        .encode(&payload);
        match check_envelope(signing, &framed) {
            Stage0::Done(Verdict::Forged { claimed, why }) => {
                assert_eq!(claimed, a.address);
                assert!(why.contains("signature"), "{why}");
            }
            _ => panic!("framing must be Forged, not slashed against A"),
        }
        // Tampered payload under A's intact header: the signed digest no
        // longer covers the bytes.
        let mut tampered = sealed_a.clone();
        let last = tampered.len() - 1;
        tampered[last] ^= 0x01;
        match check_envelope(signing, &tampered) {
            Stage0::Done(Verdict::Forged { claimed, why }) => {
                assert_eq!(claimed, a.address);
                assert!(why.contains("digest"), "{why}");
            }
            _ => panic!("post-signing tamper must be Forged"),
        }
    }

    /// Dataset the gate's skip-path sanity checks run against (the gate
    /// fixtures draw their task ids from it so `check_sanity_pre` passes).
    fn gate_dataset() -> Arc<Dataset> {
        use crate::tasks::dataset::{DatasetConfig, EnvMix};
        Arc::new(
            Dataset::generate(
                &crate::verifier::Registry::standard(),
                &DatasetConfig { seed: 11, mix: EnvMix::of(&[("math", 40)]), ..Default::default() },
            )
            .unwrap(),
        )
    }

    /// Gate over `dataset` with `expected_group = 1` (fixtures carry one
    /// rollout per upload) and room for the tiny test sequences.
    fn gate_over(
        dataset: &Arc<Dataset>,
        rate: f64,
        trust: Arc<TrustOracle>,
    ) -> (SamplingGate, crate::toploc::Validator) {
        use crate::toploc::{Validator, ValidatorConfig};
        let cfg = SamplerConfig { sampling_rate: rate, promotion_streak: 8 };
        let gate = SamplingGate::new(
            ValidatorCommitment::new(0xC0FFEE),
            cfg,
            trust,
            Arc::clone(dataset),
            RewardConfig::default(),
            64,
            64,
        );
        (gate, Validator::new(ValidatorConfig { expected_group: 1, ..Default::default() }))
    }

    /// One wire-honest single-rollout submission: task id from the §2.3.3
    /// seed draw, group id from the deterministic base — it passes every
    /// deterministic check the skip path runs (the reference answer is
    /// irrelevant there: reward replay is exactly what skipping defers).
    fn tiny_submission(
        dataset: &Dataset,
        node: u64,
        step: u64,
        idx: u64,
    ) -> crate::rl::rollout_file::Submission {
        use crate::rl::rollout_file::WireRollout;
        use crate::rl::Rollout;
        let seed = crate::tasks::dataset::node_sample_seed(node, step, idx);
        let task_id = dataset.sample_for(seed, 1)[0];
        Submission {
            node_address: node,
            step,
            submission_idx: idx,
            rollouts: vec![WireRollout {
                rollout: Rollout {
                    task_id,
                    group_id: crate::rl::group_id_base(node, step, idx),
                    policy_step: step,
                    tokens: vec![1, 5, 2],
                    prompt_len: 1,
                    target_len: None,
                    task_reward: 1.0,
                    length_penalty: 0.0,
                    reward: 1.0,
                    advantage: 0.0,
                    sampled_probs: vec![0.5, 0.5],
                    node_address: node,
                },
                commitment: Vec::new(),
                finish_eos: true,
                eos_prob: 0.9,
            }],
        }
    }

    fn one_key_oracle(id: &Identity) -> Arc<SigOracle> {
        let keys = std::collections::BTreeMap::from([(id.address, id.secret())]);
        Arc::new(move |addr, msg: &[u8], sig: &[u8; 32]| match keys.get(&addr) {
            None => SigCheck::NoKey,
            Some(key) if crate::protocol::identity::hmac_verify(key, msg, sig) => SigCheck::Valid,
            Some(_) => SigCheck::Mismatch,
        })
    }

    #[test]
    fn sampling_gate_routes_by_trust_and_selection() {
        let worker = Identity::from_seed(5);
        let dataset = gate_dataset();
        let oracle = one_key_oracle(&worker);
        let signing = Some(&oracle);
        // Trust oracle: a long-proven clean history for everyone.
        let proven: Arc<TrustOracle> = Arc::new(|_| TrustState {
            clean_streak: 1000,
            verified_clean: 1000,
            rejects: 0,
        });
        let (gate, validator) = gate_over(&dataset, 0.25, Arc::clone(&proven));

        // A proven node's uploads split into Full / Skip exactly as the
        // commitment dictates, and every Skip decodes to the submission.
        let (mut fulls, mut skips) = (0u64, 0u64);
        for idx in 0..200 {
            let bytes = tiny_submission(&dataset, worker.address, 3, idx).encode_signed(&worker);
            match gate.gate(signing, &validator, 3, bytes) {
                GateOutcome::Full(_) => fulls += 1,
                GateOutcome::Skip(sub) => {
                    skips += 1;
                    assert_eq!(sub.node_address, worker.address);
                    assert_eq!(sub.submission_idx, idx);
                }
                GateOutcome::Done(_) => panic!("clean upload must not settle in the gate"),
            }
        }
        assert_eq!(fulls, gate.sampled_full.get());
        assert_eq!(skips, gate.skipped.get());
        assert!(fulls > 20 && skips > 100, "rate 0.25 over 200: {fulls} full / {skips} skip");

        // New node (default trust): always Full, never skipped.
        let fresh: Arc<TrustOracle> = Arc::new(|_| TrustState::default());
        let (gate, _) = gate_over(&dataset, 0.25, fresh);
        for idx in 0..20 {
            let bytes = tiny_submission(&dataset, worker.address, 3, idx).encode_signed(&worker);
            assert!(matches!(gate.gate(signing, &validator, 3, bytes), GateOutcome::Full(_)));
        }
        assert_eq!(gate.escalated.get(), 0);

        // Flagged node (reject on record, streak not yet re-promoted):
        // full verification, counted as escalated.
        let flagged: Arc<TrustOracle> = Arc::new(|_| TrustState {
            clean_streak: 2,
            verified_clean: 500,
            rejects: 1,
        });
        let (gate, _) = gate_over(&dataset, 0.25, flagged);
        let bytes = tiny_submission(&dataset, worker.address, 3, 0).encode_signed(&worker);
        assert!(matches!(gate.gate(signing, &validator, 3, bytes), GateOutcome::Full(_)));
        assert_eq!(gate.escalated.get(), 1);

        // Rate 1.0: sampling disabled, everything Full even when proven.
        let (gate, _) = gate_over(&dataset, 1.0, proven);
        for idx in 0..50 {
            let bytes = tiny_submission(&dataset, worker.address, 3, idx).encode_signed(&worker);
            assert!(matches!(gate.gate(signing, &validator, 3, bytes), GateOutcome::Full(_)));
        }
        assert_eq!(gate.skipped.get(), 0);
    }

    #[test]
    fn sampling_gate_never_skips_unproven_or_lying_uploads() {
        let worker = Identity::from_seed(5);
        let stranger = Identity::from_seed(6);
        let dataset = gate_dataset();
        let oracle = one_key_oracle(&worker);
        let signing = Some(&oracle);
        // Effectively-zero verify probability: every proven upload takes
        // the skip path, so any Full/Done below is the gate's own doing.
        let proven: Arc<TrustOracle> = Arc::new(|_| TrustState {
            clean_streak: u64::MAX,
            verified_clean: u64::MAX,
            rejects: 0,
        });
        let (gate, validator) = gate_over(&dataset, 0.0, proven);

        // Unsigned upload with signing required: settles as Unsigned.
        let raw = tiny_submission(&dataset, worker.address, 3, 0).encode();
        match gate.gate(signing, &validator, 3, raw) {
            GateOutcome::Done(Verdict::Unsigned { .. }) => {}
            _ => panic!("unsigned upload must settle in stage 0"),
        }
        // Unregistered signer: Forged, trust never consulted.
        let sealed = tiny_submission(&dataset, stranger.address, 3, 0).encode_signed(&stranger);
        match gate.gate(signing, &validator, 3, sealed) {
            GateOutcome::Done(Verdict::Forged { claimed, .. }) => {
                assert_eq!(claimed, stranger.address)
            }
            _ => panic!("forged upload must settle in stage 0"),
        }
        // Proven envelope over a payload claiming a different identity:
        // skip path catches the lie (proven Reject), no admission.
        let mut lying = tiny_submission(&dataset, worker.address, 3, 0);
        lying.node_address = stranger.address;
        lying.rollouts[0].rollout.node_address = stranger.address;
        let payload = lying.encode();
        let bytes = Envelope::seal(&worker, 3, 0, &payload);
        match gate.gate(signing, &validator, 3, bytes) {
            GateOutcome::Done(Verdict::Reject { node, why }) => {
                assert_eq!(node, Some(worker.address));
                assert!(why.contains("envelope proves"), "{why}");
            }
            _ => panic!("identity lie must be a proven reject"),
        }
        // Undecodable payload under a valid envelope: proven Reject.
        let bytes = Envelope::seal(&worker, 3, 1, b"not an rpq file");
        match gate.gate(signing, &validator, 3, bytes) {
            GateOutcome::Done(Verdict::Reject { node, .. }) => {
                assert_eq!(node, Some(worker.address))
            }
            _ => panic!("garbage payload must be a proven reject"),
        }
        // Legacy mode (no signing): sampling never applies — Full.
        let raw2 = tiny_submission(&dataset, worker.address, 3, 0).encode();
        assert!(matches!(gate.gate(None, &validator, 3, raw2), GateOutcome::Full(_)));
        assert_eq!(gate.skipped.get(), 0);
    }

    /// One wire-honest served response: EOS-terminated, probs shaped to
    /// the completion, tokens free of any tokenizer-alphabet constraint
    /// (serving is model-alphabet).
    fn served(worker: &Identity, step: u64, query_id: u64) -> crate::serving::ServedResponse {
        ServedResponse {
            query_id,
            node_address: worker.address,
            step,
            tokens: vec![9, 5, 7, 2],
            prompt_len: 2,
            sampled_probs: vec![0.5, 0.9],
            commitment: vec![1, 2, 3],
            finish_eos: true,
            eos_prob: 0.9,
        }
    }

    #[test]
    fn serve_gate_slashes_forged_completions_and_passes_honest_ones() {
        let worker = Identity::from_seed(5);
        let stranger = Identity::from_seed(6);
        let dataset = gate_dataset();
        let oracle = one_key_oracle(&worker);
        let signing = Some(&oracle);
        let proven: Arc<TrustOracle> = Arc::new(|_| TrustState {
            clean_streak: 1000,
            verified_clean: 1000,
            rejects: 0,
        });
        // Rate 1.0: every served response is recomputed.
        let (gate, validator) = gate_over(&dataset, 1.0, proven);
        let mut replay = ReplayGuard::new();
        let honest: &dyn Fn(&ServedResponse) -> anyhow::Result<Vec<i32>> =
            &|r| Ok(r.tokens.clone());

        // Honest response, recompute agrees: Verified.
        let bytes = served(&worker, 3, 0).encode_signed(&worker);
        match gate.gate_served(signing, &validator, 3, &mut replay, &bytes, honest) {
            ServeGateOutcome::Verified(r) => {
                assert_eq!(r.query_id, 0);
                assert_eq!(r.node_address, worker.address);
            }
            _ => panic!("honest served response must verify"),
        }
        assert_eq!(gate.served_full.get(), 1);

        // Re-posting the identical accepted response: Replay, not a slash.
        match gate.gate_served(signing, &validator, 3, &mut replay, &bytes, honest) {
            ServeGateOutcome::Replay { node, query_id } => {
                assert_eq!((node, query_id), (worker.address, 0));
            }
            _ => panic!("duplicate served response must be a replay"),
        }

        // Forged completion: recompute disagrees — proven Reject by the
        // signer, exactly the slashing outcome rollout forgeries get.
        let bytes = served(&worker, 3, 1).encode_signed(&worker);
        let forged: &dyn Fn(&ServedResponse) -> anyhow::Result<Vec<i32>> =
            &|_| Ok(vec![9, 5, 8, 2]);
        match gate.gate_served(signing, &validator, 3, &mut replay, &bytes, forged) {
            ServeGateOutcome::Done(Verdict::Reject { node, why }) => {
                assert_eq!(node, Some(worker.address));
                assert!(why.contains("recompute"), "{why}");
            }
            _ => panic!("forged served completion must be a proven reject"),
        }

        // Recompute infrastructure failure: EngineFailure, never a slash.
        let bytes = served(&worker, 3, 2).encode_signed(&worker);
        let broken: &dyn Fn(&ServedResponse) -> anyhow::Result<Vec<i32>> =
            &|_| anyhow::bail!("backend down");
        match gate.gate_served(signing, &validator, 3, &mut replay, &bytes, broken) {
            ServeGateOutcome::Done(Verdict::EngineFailure { node, .. }) => {
                assert_eq!(node, Some(worker.address));
            }
            _ => panic!("recompute failure must settle as EngineFailure"),
        }

        // Unsigned / stranger-signed envelopes settle in stage 0.
        let raw = served(&worker, 3, 3).encode();
        assert!(matches!(
            gate.gate_served(signing, &validator, 3, &mut replay, &raw, honest),
            ServeGateOutcome::Done(Verdict::Unsigned { .. })
        ));
        let sealed = served(&stranger, 3, 3).encode_signed(&stranger);
        assert!(matches!(
            gate.gate_served(signing, &validator, 3, &mut replay, &sealed, honest),
            ServeGateOutcome::Done(Verdict::Forged { .. })
        ));

        // Aged-out policy step: Stale (liveness, not dishonesty).
        let bytes = served(&worker, 3, 4).encode_signed(&worker);
        assert!(matches!(
            gate.gate_served(signing, &validator, 100, &mut replay, &bytes, honest),
            ServeGateOutcome::Done(Verdict::Stale { .. })
        ));
    }

    #[test]
    fn serve_gate_skip_path_still_catches_cheap_lies() {
        use std::sync::atomic::{AtomicU64, Ordering};
        let worker = Identity::from_seed(5);
        let stranger = Identity::from_seed(6);
        let dataset = gate_dataset();
        let oracle = one_key_oracle(&worker);
        let signing = Some(&oracle);
        let proven: Arc<TrustOracle> = Arc::new(|_| TrustState {
            clean_streak: u64::MAX,
            verified_clean: u64::MAX,
            rejects: 0,
        });
        // Rate 0.0 over a maxed-out trust history: every clean response
        // takes the skip path, so the recompute closure must never run.
        let (gate, validator) = gate_over(&dataset, 0.0, proven);
        let mut replay = ReplayGuard::new();
        let recomputes = AtomicU64::new(0);
        let counting: &dyn Fn(&ServedResponse) -> anyhow::Result<Vec<i32>> = &|r| {
            recomputes.fetch_add(1, Ordering::SeqCst);
            Ok(r.tokens.clone())
        };

        let bytes = served(&worker, 3, 0).encode_signed(&worker);
        match gate.gate_served(signing, &validator, 3, &mut replay, &bytes, counting) {
            ServeGateOutcome::Skip(r) => assert_eq!(r.query_id, 0),
            _ => panic!("proven node at rate 0 must skip"),
        }
        assert_eq!(recomputes.load(Ordering::SeqCst), 0);
        assert_eq!(gate.served_skipped.get(), 1);

        // Identity lie under a valid envelope: proven Reject, no skip.
        let mut lying = served(&worker, 3, 1);
        lying.node_address = stranger.address;
        let bytes = Envelope::seal(&worker, 3, serve_submission_idx(1), &lying.encode());
        match gate.gate_served(signing, &validator, 3, &mut replay, &bytes, counting) {
            ServeGateOutcome::Done(Verdict::Reject { node, why }) => {
                assert_eq!(node, Some(worker.address));
                assert!(why.contains("envelope"), "{why}");
            }
            _ => panic!("identity lie must be a proven reject"),
        }

        // Probs shaped wrong for the completion: cheap reject.
        let mut short = served(&worker, 3, 2);
        short.sampled_probs.pop();
        let bytes = short.encode_signed(&worker);
        assert!(matches!(
            gate.gate_served(signing, &validator, 3, &mut replay, &bytes, counting),
            ServeGateOutcome::Done(Verdict::Reject { .. })
        ));

        // EOS-termination lie: claims finish_eos but does not end in EOS.
        let mut no_eos = served(&worker, 3, 3);
        no_eos.tokens = vec![9, 5, 7, 8];
        let bytes = no_eos.encode_signed(&worker);
        assert!(matches!(
            gate.gate_served(signing, &validator, 3, &mut replay, &bytes, counting),
            ServeGateOutcome::Done(Verdict::Reject { .. })
        ));
        assert_eq!(gate.rejected_unsampled.get(), 2);
        assert_eq!(recomputes.load(Ordering::SeqCst), 0);

        // Legacy unsigned mode: no identity to trust, so even at rate 0
        // the completion is fully recomputed.
        let raw = served(&worker, 3, 4).encode();
        assert!(matches!(
            gate.gate_served(None, &validator, 3, &mut replay, &raw, counting),
            ServeGateOutcome::Verified(_)
        ));
        assert_eq!(recomputes.load(Ordering::SeqCst), 1);
        assert_eq!(gate.served_full.get(), 1);
    }

    #[test]
    fn commitment_selection_is_deterministic_and_committing() {
        let c = ValidatorCommitment::new(42);
        let again = ValidatorCommitment::new(42);
        for step in 0..4u64 {
            for node in [7u64, 9, 1000] {
                for idx in 0..8u64 {
                    assert_eq!(
                        c.selects(step, node, idx, 0.25),
                        again.selects(step, node, idx, 0.25)
                    );
                }
            }
        }
        // The published commitment binds the secret without revealing it.
        assert_eq!(c.commitment(), again.commitment());
        assert_ne!(c.commitment(), ValidatorCommitment::new(43).commitment());
        assert_eq!(c.reveal(), 42);
        // p >= 1 always selects; p == 0 never does.
        assert!(c.selects(1, 2, 3, 1.0));
        assert!(!c.selects(1, 2, 3, 0.0));
    }

    #[test]
    fn replay_guard_dedupes_within_window_and_prunes() {
        let mut g = ReplayGuard::new();
        assert!(g.first_sighting(7, 3, 0));
        assert!(g.first_sighting(7, 3, 1)); // next upload, same node/step
        assert!(g.first_sighting(8, 3, 0)); // other node, same step/idx
        assert!(g.first_sighting(7, 4, 0)); // same node/idx, next step
        // Exact re-post within the window: caught.
        assert!(!g.first_sighting(7, 3, 0));
        assert_eq!(g.len(), 4);
        // Steps below the window are pruned; the signature's step binding
        // covers them (replays go stale, not duplicate).
        g.advance(4);
        assert_eq!(g.len(), 1);
        assert!(g.first_sighting(7, 4, 1));
        // A pruned identity re-posted would re-enter the guard — but only
        // after its step left the window, where stage 1–2 reject it as
        // stale before buffering.
        assert!(g.first_sighting(7, 3, 0));
    }

    #[test]
    fn replay_guard_traversal_order_is_insertion_independent() {
        // Regression for the unordered-iter class: guard contents must
        // come out in one canonical order no matter the arrival order
        // (hash-seeded iteration varied across validator processes).
        let sightings = [(9u64, 2u64, 1u64), (3, 7, 0), (9, 1, 0), (3, 2, 5), (9, 2, 0)];
        let mut fwd = ReplayGuard::new();
        for &(n, s, i) in &sightings {
            fwd.first_sighting(n, s, i);
        }
        let mut rev = ReplayGuard::new();
        for &(n, s, i) in sightings.iter().rev() {
            rev.first_sighting(n, s, i);
        }
        let want = vec![(1, 9, 0), (2, 3, 5), (2, 9, 0), (2, 9, 1), (7, 3, 0)];
        assert_eq!(fwd.entries(), want);
        assert_eq!(rev.entries(), want);
    }

    #[test]
    fn queue_is_fifo_and_wakes_consumer() {
        let q = Arc::new(SubmissionQueue::new(8));
        q.push(vec![1]);
        q.push(vec![2]);
        q.push(vec![3]);
        assert_eq!(q.len(), 3);
        // Oldest first, up to max.
        assert_eq!(q.drain_wait(2, Duration::from_millis(1)), vec![vec![1], vec![2]]);
        assert_eq!(q.drain_wait(9, Duration::from_millis(1)), vec![vec![3]]);
        assert!(q.is_empty());
        // Empty + timeout: returns empty without hanging.
        assert!(q.drain_wait(4, Duration::from_millis(5)).is_empty());
        // A push from another thread wakes a blocked consumer well before
        // the (generous) timeout.
        let q2 = Arc::clone(&q);
        let t = std::thread::spawn(move || q2.drain_wait(1, Duration::from_secs(30)));
        std::thread::sleep(Duration::from_millis(20));
        q.push(vec![7]);
        assert_eq!(t.join().unwrap(), vec![vec![7]]);
    }

    #[test]
    fn queue_sheds_oldest_when_full() {
        let q = SubmissionQueue::new(3);
        assert_eq!(q.push(vec![1]), 0);
        assert_eq!(q.push(vec![2]), 0);
        assert_eq!(q.push(vec![3]), 0);
        // Full: the oldest entry is shed, the newest kept.
        assert_eq!(q.push(vec![4]), 1);
        assert_eq!(q.len(), 3);
        assert_eq!(q.drain_wait(8, Duration::from_millis(1)), vec![vec![2], vec![3], vec![4]]);
    }
}
