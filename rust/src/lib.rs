//! INTELLECT-2 reproduction: globally decentralized reinforcement learning.
//!
//! This crate is Layer 3 of the three-layer stack (see DESIGN.md): the Rust
//! coordinator owning the event loop, process topology, networking, metrics
//! and CLI. The policy model itself (Layer 2, JAX) and its compute hot-spots
//! (Layer 1, Pallas) are AOT-compiled to `artifacts/*.hlo.txt` and executed
//! through [`runtime`] — Python never runs on any request or training path.
//!
//! Subsystems (paper section in parentheses):
//! - [`util`], [`http`], [`data`]: from-scratch substrates (JSON, HTTP/1.1,
//!   PRNG, metrics, bench/property harnesses, columnar rollout format,
//!   tokenizer) — the vendored crate set has no tokio/serde/etc.
//! - [`runtime`]: PJRT artifact loading + train/sample engines.
//! - [`tasks`], [`verifier`], [`rl`]: the pluggable environment registry
//!   (GENESYS-style reward environments, §2.1.3/§3.1 — adding one is one
//!   file implementing `verifier::Environment`, with a registry
//!   fingerprint keeping worker and validator env sets provably in sync),
//!   GRPO batching/advantages/filtering (§3.3), sequence packing (§4.1),
//!   and the version-tagged rollout buffer enforcing the
//!   `[current - k, current]` off-policy staleness window (§3.2).
//! - [`shardcast`]: policy weight broadcast network (§2.2), including the
//!   background [`shardcast::Broadcaster`] that overlaps checkpoint
//!   distribution with the next training step.
//! - [`toploc`]: trustless inference verification (§2.3) — the validator
//!   enforces the same staleness window as the trainer buffer.
//! - [`protocol`]: ledger/discovery/orchestrator/worker lifecycle (§2.4).
//! - [`serving`]: serve mode — user queries dispatched onto the same
//!   worker fleet co-tenant with RL rollouts (front-door router, per-node
//!   capacity advertisement, deadline/SLO clock, signed + spot-checked
//!   responses riding the rollout trust machinery).
//! - [`analysis`]: `swarmlint` — a from-scratch lexer + rules engine that
//!   lints this crate's own sources for determinism / slashability
//!   hazards (unordered iteration, wall-clock inputs, panics on untrusted
//!   bytes, order-unspecified float folds, lock-order violations).
//! - [`coordinator`]: PRIME-RL — the asynchronous RL pipeline itself
//!   (§2.1, §3.2): a deterministic async-k driver for experiments and the
//!   free-running swarm whose trainer is genuinely two-step asynchronous
//!   (training of step s+1 overlaps broadcasting of step s's weights,
//!   with measured per-step overlap in `SwarmResult`).

pub mod analysis;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod http;
pub mod protocol;
pub mod rl;
pub mod runtime;
pub mod serving;
pub mod shardcast;
pub mod tasks;
pub mod toploc;
pub mod util;
pub mod verifier;
