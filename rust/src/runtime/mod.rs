//! PJRT runtime: loads the AOT artifacts (HLO text) produced by
//! `python/compile/aot.py` and exposes typed engines to the coordinator.
//! Start-to-finish self-contained: after `make artifacts`, no Python.
//!
//! # Generation topology (continuous batching)
//!
//! Rollout generation — the swarm's dominant compute (§2.1.2 / Fig 3) —
//! runs through the [`scheduler`] module's continuously-batched
//! [`scheduler::run_continuous`] path by default (`gen-refill` knob):
//!
//! - **Vectored decode contract**: `decode_step` takes `pos: i32[B]`, one
//!   position per `batch_infer` lane, because lanes advance independently
//!   once refill decouples them. `ModelSpec::decode_pos_per_lane` detects
//!   the contract; pre-refill artifacts (scalar `pos`) still run the
//!   static reference path.
//! - **Prompt prefill into KV**: the `prefill_kv_{T}` artifact ladder
//!   computes an entire prompt forward in one bucketed call, returns its
//!   per-position logits/hidden (commit-grid rows + the first frontier
//!   sample) and installs the per-layer k/v projections into assigned
//!   lanes of the persistent decode cache — an L-token prompt costs one
//!   call instead of L decode steps.
//! - **Lane refill**: the step a sequence hits EOS or its length limit,
//!   its lane is retired and the next pending prompt is prefilled into it;
//!   occupancy never drops while prompts are pending.
//! - **Group-shared prompt KV**: GRPO groups repeat one prompt
//!   `group_size` times (§3.4); a refill wave computes each unique prompt
//!   once and replicates the KV rows across the group's lanes via the
//!   artifact's `lane_src` gather input.
//! - **Lane-invariant determinism**: sampling draws from per-rollout RNG
//!   streams keyed by `(gen_seed, rollout_index)`
//!   ([`scheduler::rollout_rng`]), so tokens, `sampled_probs` and TOPLOC
//!   commitments are byte-identical whatever the lane assignment or swarm
//!   load — the §2.3.3 fixed-sampling check stays slashable. The kept
//!   static-batch loop ([`scheduler::run_static_reference`]) is the
//!   equivalence oracle, enforced by engine-free property tests over
//!   [`scheduler::MockBackend`].
//!
//! # Serving co-tenancy (priority refill)
//!
//! Serve mode (see [`crate::serving`]) runs user queries through the
//! *same* scheduler as RL rollouts, not a second engine:
//! [`scheduler::run_continuous_prioritized`] takes a per-request
//! priority flag, and at every lane-refill wave flagged requests (user
//! queries) are admitted ahead of all pending unflagged prompts (RL
//! work). Decode ticks are shared — co-tenancy changes *lane admission
//! order only*, so time-to-first-token drops for queries while the
//! lane-invariant determinism above keeps every RL rollout's bytes
//! identical to its solo run (the serve harness in
//! `coordinator::serve` enforces this against
//! [`scheduler::run_static_reference`] under mixed load).
//! [`scheduler::GenStats::first_token_ticks`] records when each
//! request sampled its first token, which is what `serving_bench`
//! turns into p50/p99 TTFT on the simulated clock.
//!
//! # Threading
//!
//! `xla::PjRtClient` is `Rc`-based and thread-confined, so a [`Runtime`]
//! stays on the thread that created it; cross-thread access goes through
//! [`EngineHost`], which owns a `Runtime` on a dedicated thread and serves
//! requests over channels — one inference server per node, exactly like a
//! real deployment.

pub mod client;
pub mod engine;
pub mod host;
pub mod scheduler;
pub mod spec;

pub use client::Runtime;
pub use engine::{
    Finish, GenOpts, Generation, GrpoHp, GrpoMetrics, MicroBatch, ParamSet, SampleEngine,
    TrainEngine, TrainState,
};
pub use host::{EngineHost, HostTrainState};
pub use scheduler::{rollout_rng, GenRequest, GenStats};
pub use spec::ModelSpec;
