//! PJRT runtime: loads the AOT artifacts (HLO text) produced by
//! `python/compile/aot.py` and exposes typed engines to the coordinator.
//! Start-to-finish self-contained: after `make artifacts`, no Python.

pub mod client;
pub mod engine;
pub mod host;
pub mod spec;

pub use client::Runtime;
pub use engine::{
    Finish, GenOpts, Generation, GrpoHp, GrpoMetrics, MicroBatch, ParamSet, SampleEngine,
    TrainEngine, TrainState,
};
pub use host::{EngineHost, HostTrainState};
pub use spec::ModelSpec;
