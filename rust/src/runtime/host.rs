//! `EngineHost`: cross-thread facade over a thread-confined
//! [`Runtime`](super::Runtime).
//!
//! `xla::PjRtClient` is `Rc`-based, so all PJRT objects live on one thread.
//! The host spawns that thread, compiles artifacts there, and serves
//! requests over channels. This mirrors the real topology: every node in
//! the swarm runs its own inference server; simulated nodes here share one
//! host per model size (same executables, per-request weights) so N
//! workers with different policy versions don't need N XLA clients.

use std::path::PathBuf;
use std::sync::mpsc::{channel, Sender};
use std::sync::Arc;
use std::thread::JoinHandle;

use super::engine::{GenOpts, Generation, GrpoHp, GrpoMetrics, MicroBatch, ParamSet, SampleEngine, TrainEngine};
use super::scheduler::{GenRequest, GenStats};
use super::spec::ModelSpec;

enum Req {
    Generate {
        params: Arc<ParamSet>,
        prompts: Vec<Vec<i32>>,
        opts: GenOpts,
        seed: u64,
        stream_base: u64,
        reply: Sender<anyhow::Result<(Vec<Generation>, GenStats)>>,
    },
    GenerateContinuous {
        params: Arc<ParamSet>,
        requests: Vec<GenRequest>,
        opts: GenOpts,
        reply: Sender<anyhow::Result<(Vec<Generation>, GenStats)>>,
    },
    Prefill {
        params: Arc<ParamSet>,
        tokens: Vec<i32>,
        reply: Sender<anyhow::Result<(Vec<f32>, Vec<f32>)>>,
    },
    PrefillRows {
        params: Arc<ParamSet>,
        tokens: Vec<i32>,
        rows: usize,
        seq_len: usize,
        reply: Sender<anyhow::Result<(Vec<f32>, Vec<f32>, usize)>>,
    },
    Logprobs {
        params: Arc<ParamSet>,
        tokens: Vec<i32>,
        segs: Vec<i32>,
        reply: Sender<anyhow::Result<(Vec<f32>, Vec<f32>, Vec<f32>)>>,
    },
    Init {
        seed: u32,
        reply: Sender<anyhow::Result<ParamSet>>,
    },
    GrpoStep {
        artifact: String,
        state: Box<HostTrainState>,
        mb: MicroBatch,
        hp: GrpoHp,
        reply: Sender<anyhow::Result<(Box<HostTrainState>, GrpoMetrics)>>,
    },
    PretrainStep {
        state: Box<HostTrainState>,
        tokens: Vec<i32>,
        segs: Vec<i32>,
        lr: f32,
        grad_clip: f32,
        reply: Sender<anyhow::Result<(Box<HostTrainState>, f32, f32)>>,
    },
}

/// Send-able training state (plain host floats).
#[derive(Clone)]
pub struct HostTrainState {
    pub params: ParamSet,
    pub m: ParamSet,
    pub v: ParamSet,
    pub step: u64,
}

pub struct EngineHost {
    tx: Sender<Req>,
    spec: ModelSpec,
    thread: Option<JoinHandle<()>>,
}

impl EngineHost {
    /// Spawn the runtime thread for `artifacts/<size>`.
    pub fn spawn(dir: PathBuf) -> anyhow::Result<EngineHost> {
        let (tx, rx) = channel::<Req>();
        let (spec_tx, spec_rx) = channel::<anyhow::Result<ModelSpec>>();
        let thread = std::thread::Builder::new().name("i2-engine-host".into()).spawn(move || {
            let rt = match super::Runtime::load(&dir) {
                Ok(rt) => {
                    let _ = spec_tx.send(Ok(rt.spec.clone()));
                    rt
                }
                Err(e) => {
                    let _ = spec_tx.send(Err(e));
                    return;
                }
            };
            let train = TrainEngine::new(rt.clone());
            let mut sample = SampleEngine::new(rt.clone(), ParamSet { tensors: Vec::new() });
            while let Ok(req) = rx.recv() {
                match req {
                    Req::Generate { params, prompts, opts, seed, stream_base, reply } => {
                        sample.set_params((*params).clone());
                        let _ = reply.send(sample.generate(&prompts, &opts, seed, stream_base));
                    }
                    Req::GenerateContinuous { params, requests, opts, reply } => {
                        sample.set_params((*params).clone());
                        let _ = reply.send(sample.generate_continuous(&requests, &opts));
                    }
                    Req::Prefill { params, tokens, reply } => {
                        sample.set_params((*params).clone());
                        let _ = reply.send(sample.prefill(&tokens));
                    }
                    Req::PrefillRows { params, tokens, rows, seq_len, reply } => {
                        sample.set_params((*params).clone());
                        let _ = reply.send(sample.prefill_rows(&tokens, rows, seq_len));
                    }
                    Req::Logprobs { params, tokens, segs, reply } => {
                        let _ = reply.send(train.logprobs(&params, &tokens, &segs));
                    }
                    Req::Init { seed, reply } => {
                        let _ = reply.send(train.init_state(seed).map(|st| st.params));
                    }
                    Req::GrpoStep { artifact, state, mb, hp, reply } => {
                        let mut st = super::engine::TrainState {
                            params: state.params,
                            m: state.m,
                            v: state.v,
                            step: state.step,
                        };
                        let r = train.grpo_step_with(&artifact, &mut st, &mb, &hp).map(|metrics| {
                            (
                                Box::new(HostTrainState {
                                    params: st.params,
                                    m: st.m,
                                    v: st.v,
                                    step: st.step,
                                }),
                                metrics,
                            )
                        });
                        let _ = reply.send(r);
                    }
                    Req::PretrainStep { state, tokens, segs, lr, grad_clip, reply } => {
                        let mut st = super::engine::TrainState {
                            params: state.params,
                            m: state.m,
                            v: state.v,
                            step: state.step,
                        };
                        let r = train.pretrain_step(&mut st, &tokens, &segs, lr, grad_clip).map(
                            |(loss, gnorm)| {
                                (
                                    Box::new(HostTrainState {
                                        params: st.params,
                                        m: st.m,
                                        v: st.v,
                                        step: st.step,
                                    }),
                                    loss,
                                    gnorm,
                                )
                            },
                        );
                        let _ = reply.send(r);
                    }
                }
            }
        })?;
        let spec = spec_rx.recv().map_err(|_| anyhow::anyhow!("engine host died on startup"))??;
        Ok(EngineHost { tx, spec, thread: Some(thread) })
    }

    /// Spawn for a model size using the default artifacts dir.
    pub fn spawn_size(size: &str) -> anyhow::Result<EngineHost> {
        EngineHost::spawn(super::Runtime::artifacts_dir(size))
    }

    pub fn spec(&self) -> &ModelSpec {
        &self.spec
    }

    pub fn init_params(&self, seed: u32) -> anyhow::Result<ParamSet> {
        let (reply, rx) = channel();
        self.tx.send(Req::Init { seed, reply }).map_err(closed)?;
        rx.recv().map_err(closed)?
    }

    /// Static-batch generation, rollout streams starting at index 0 (see
    /// [`EngineHost::generate_streams`] for the full contract).
    pub fn generate(
        &self,
        params: Arc<ParamSet>,
        prompts: Vec<Vec<i32>>,
        opts: GenOpts,
        seed: u64,
    ) -> anyhow::Result<Vec<Generation>> {
        Ok(self.generate_streams(params, prompts, opts, seed, 0)?.0)
    }

    /// Static-batch reference generation: row `i` samples from the
    /// per-rollout stream `rollout_rng(seed, stream_base + i)` — the same
    /// streams the continuous path uses, so the two are equivalent (see
    /// [`EngineHost::generate_continuous`] for the fp caveat).
    /// Prompts beyond `batch_infer` are chunked internally.
    pub fn generate_streams(
        &self,
        params: Arc<ParamSet>,
        prompts: Vec<Vec<i32>>,
        opts: GenOpts,
        seed: u64,
        stream_base: u64,
    ) -> anyhow::Result<(Vec<Generation>, GenStats)> {
        let (reply, rx) = channel();
        self.tx
            .send(Req::Generate { params, prompts, opts, seed, stream_base, reply })
            .map_err(closed)?;
        rx.recv().map_err(closed)?
    }

    /// Continuously-batched generation (`gen-refill`): prompt prefill into
    /// KV, lane refill on EOS, group-shared prompt forwards — see
    /// [`super::scheduler`]. Outputs are in request order and equivalent
    /// to the static reference path on the same streams (bit-identical up
    /// to prefill-vs-decode kernel rounding on real devices).
    pub fn generate_continuous(
        &self,
        params: Arc<ParamSet>,
        requests: Vec<GenRequest>,
        opts: GenOpts,
    ) -> anyhow::Result<(Vec<Generation>, GenStats)> {
        let (reply, rx) = channel();
        self.tx
            .send(Req::GenerateContinuous { params, requests, opts, reply })
            .map_err(closed)?;
        rx.recv().map_err(closed)?
    }

    pub fn prefill(
        &self,
        params: Arc<ParamSet>,
        tokens: Vec<i32>,
    ) -> anyhow::Result<(Vec<f32>, Vec<f32>)> {
        let (reply, rx) = channel();
        self.tx.send(Req::Prefill { params, tokens, reply }).map_err(closed)?;
        rx.recv().map_err(closed)?
    }

    /// Length-bucketed validator prefill (see [`super::engine::SampleEngine::prefill_rows`]):
    /// `tokens` is row-major `[rows, seq_len]`; returns
    /// `(logits, hidden, stride)` where consecutive rows are `stride`
    /// positions apart in both outputs.
    pub fn prefill_rows(
        &self,
        params: Arc<ParamSet>,
        tokens: Vec<i32>,
        rows: usize,
        seq_len: usize,
    ) -> anyhow::Result<(Vec<f32>, Vec<f32>, usize)> {
        let (reply, rx) = channel();
        self.tx
            .send(Req::PrefillRows { params, tokens, rows, seq_len, reply })
            .map_err(closed)?;
        rx.recv().map_err(closed)?
    }

    pub fn logprobs(
        &self,
        params: Arc<ParamSet>,
        tokens: Vec<i32>,
        segs: Vec<i32>,
    ) -> anyhow::Result<(Vec<f32>, Vec<f32>, Vec<f32>)> {
        let (reply, rx) = channel();
        self.tx.send(Req::Logprobs { params, tokens, segs, reply }).map_err(closed)?;
        rx.recv().map_err(closed)?
    }

    pub fn grpo_step(
        &self,
        state: Box<HostTrainState>,
        mb: MicroBatch,
        hp: GrpoHp,
    ) -> anyhow::Result<(Box<HostTrainState>, GrpoMetrics)> {
        self.grpo_step_with("grpo_step", state, mb, hp)
    }

    pub fn grpo_step_with(
        &self,
        artifact: &str,
        state: Box<HostTrainState>,
        mb: MicroBatch,
        hp: GrpoHp,
    ) -> anyhow::Result<(Box<HostTrainState>, GrpoMetrics)> {
        let (reply, rx) = channel();
        self.tx
            .send(Req::GrpoStep { artifact: artifact.to_string(), state, mb, hp, reply })
            .map_err(closed)?;
        rx.recv().map_err(closed)?
    }

    pub fn pretrain_step(
        &self,
        state: Box<HostTrainState>,
        tokens: Vec<i32>,
        segs: Vec<i32>,
        lr: f32,
        grad_clip: f32,
    ) -> anyhow::Result<(Box<HostTrainState>, f32, f32)> {
        let (reply, rx) = channel();
        self.tx
            .send(Req::PretrainStep { state, tokens, segs, lr, grad_clip, reply })
            .map_err(closed)?;
        rx.recv().map_err(closed)?
    }

    pub fn fresh_train_state(&self, seed: u32) -> anyhow::Result<Box<HostTrainState>> {
        let params = self.init_params(seed)?;
        let zeros = ParamSet {
            tensors: self
                .spec
                .param_specs
                .iter()
                .map(|(_, s)| vec![0.0f32; s.iter().product()])
                .collect(),
        };
        Ok(Box::new(HostTrainState { params, m: zeros.clone(), v: zeros, step: 0 }))
    }
}

impl Drop for EngineHost {
    fn drop(&mut self) {
        // Closing the channel stops the worker loop.
        let (tx, _) = channel();
        drop(std::mem::replace(&mut self.tx, tx));
        if let Some(t) = self.thread.take() {
            let _ = t.join();
        }
    }
}

fn closed<E>(_: E) -> anyhow::Error {
    anyhow::anyhow!("engine host thread terminated")
}
