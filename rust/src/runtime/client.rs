//! PJRT runtime: load `artifacts/<size>/*.hlo.txt`, compile once per
//! process, execute from the coordinator hot paths. Mirrors
//! /opt/xla-example/load_hlo (HLO text -> HloModuleProto -> compile).

use std::cell::RefCell;
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};
use std::rc::Rc;

use super::spec::ModelSpec;

/// A PJRT runtime bound to one artifact directory.
///
/// NOTE: `xla::PjRtClient` is `Rc`-based and thread-confined, so a
/// `Runtime` must stay on the thread that created it. Cross-thread access
/// goes through [`super::host::EngineHost`], which owns a `Runtime` on a
/// dedicated thread and serves requests over channels — exactly how a real
/// deployment runs one inference server process per node.
pub struct Runtime {
    pub spec: ModelSpec,
    client: xla::PjRtClient,
    dir: PathBuf,
    /// Ordered map: compile-cache traversal (debug dumps, future warmup
    /// sweeps) stays deterministic across processes.
    exes: RefCell<BTreeMap<String, Rc<xla::PjRtLoadedExecutable>>>,
}

impl Runtime {
    /// Load a model's artifact directory (e.g. `artifacts/nano`).
    pub fn load(dir: impl AsRef<Path>) -> anyhow::Result<Rc<Runtime>> {
        let dir = dir.as_ref().to_path_buf();
        let spec_text = std::fs::read_to_string(dir.join("spec.json"))
            .map_err(|e| anyhow::anyhow!("read {}/spec.json: {e} (run `make artifacts`)", dir.display()))?;
        let spec = ModelSpec::parse(&spec_text)?;
        let client = xla::PjRtClient::cpu()?;
        Ok(Rc::new(Runtime { spec, client, dir, exes: RefCell::new(BTreeMap::new()) }))
    }

    /// Locate the artifacts directory from the repo root (tests/examples).
    pub fn artifacts_dir(size: &str) -> PathBuf {
        let base = std::env::var("I2_ARTIFACTS").unwrap_or_else(|_| "artifacts".to_string());
        PathBuf::from(base).join(size)
    }

    pub fn client(&self) -> &xla::PjRtClient {
        &self.client
    }

    /// Compile (once) and return the named artifact's executable.
    pub fn executable(&self, name: &str) -> anyhow::Result<Rc<xla::PjRtLoadedExecutable>> {
        if let Some(exe) = self.exes.borrow().get(name) {
            return Ok(Rc::clone(exe));
        }
        let meta = self.spec.artifact(name)?;
        let path = self.dir.join(&meta.file);
        let t0 = std::time::Instant::now();
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().ok_or_else(|| anyhow::anyhow!("non-utf8 path"))?,
        )?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = Rc::new(self.client.compile(&comp)?);
        crate::debug!(
            "runtime",
            "compiled {}/{name} in {:.2}s",
            self.spec.name,
            t0.elapsed().as_secs_f64()
        );
        self.exes.borrow_mut().insert(name.to_string(), Rc::clone(&exe));
        Ok(exe)
    }

    /// Execute an artifact with host literals; returns the decomposed
    /// output tuple (artifacts are lowered with `return_tuple=True`).
    pub fn call(&self, name: &str, inputs: &[xla::Literal]) -> anyhow::Result<Vec<xla::Literal>> {
        let refs: Vec<&xla::Literal> = inputs.iter().collect();
        self.call_refs(name, &refs)
    }

    /// Like [`Runtime::call`] but borrowing the inputs — hot loops (the
    /// decode scheduler) keep the parameter literals alive across calls
    /// instead of cloning the full weight set every step.
    pub fn call_refs(&self, name: &str, inputs: &[&xla::Literal]) -> anyhow::Result<Vec<xla::Literal>> {
        let meta = self.spec.artifact(name)?;
        anyhow::ensure!(
            inputs.len() == meta.inputs.len(),
            "{name}: {} inputs supplied, {} expected",
            inputs.len(),
            meta.inputs.len()
        );
        let exe = self.executable(name)?;
        let outs = exe.execute::<&xla::Literal>(inputs)?;
        let mut tuple = outs[0][0].to_literal_sync()?;
        Ok(tuple.decompose_tuple()?)
    }
}

// --- literal helpers -------------------------------------------------------

pub fn lit_f32(data: &[f32], shape: &[usize]) -> xla::Literal {
    debug_assert_eq!(data.len(), shape.iter().product::<usize>());
    let bytes: &[u8] =
        unsafe { std::slice::from_raw_parts(data.as_ptr() as *const u8, data.len() * 4) };
    xla::Literal::create_from_shape_and_untyped_data(xla::ElementType::F32, shape, bytes)
        .expect("create f32 literal")
}

pub fn lit_i32(data: &[i32], shape: &[usize]) -> xla::Literal {
    debug_assert_eq!(data.len(), shape.iter().product::<usize>());
    let bytes: &[u8] =
        unsafe { std::slice::from_raw_parts(data.as_ptr() as *const u8, data.len() * 4) };
    xla::Literal::create_from_shape_and_untyped_data(xla::ElementType::S32, shape, bytes)
        .expect("create i32 literal")
}

pub fn scalar_f32(v: f32) -> xla::Literal {
    xla::Literal::scalar(v)
}

pub fn scalar_i32(v: i32) -> xla::Literal {
    xla::Literal::scalar(v)
}

pub fn scalar_u32(v: u32) -> xla::Literal {
    xla::Literal::scalar(v)
}

pub fn to_f32(l: &xla::Literal) -> anyhow::Result<Vec<f32>> {
    Ok(l.to_vec::<f32>()?)
}

pub fn first_f32(l: &xla::Literal) -> anyhow::Result<f32> {
    Ok(l.get_first_element::<f32>()?)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrip() {
        let data = vec![1.0f32, 2.0, 3.0, 4.0, 5.0, 6.0];
        let lit = lit_f32(&data, &[2, 3]);
        assert_eq!(lit.element_count(), 6);
        assert_eq!(to_f32(&lit).unwrap(), data);
        let ids = vec![1i32, -2, 3];
        let lit = lit_i32(&ids, &[3]);
        assert_eq!(lit.to_vec::<i32>().unwrap(), ids);
    }

    #[test]
    fn scalar_literals() {
        assert_eq!(first_f32(&scalar_f32(2.5)).unwrap(), 2.5);
        assert_eq!(scalar_i32(-7).get_first_element::<i32>().unwrap(), -7);
    }
}
