//! Engines over the AOT artifacts: training (GRPO/pretrain/logprobs),
//! sampling (batched KV-cache autoregressive generation, §2.1.2) and
//! validation (prefill recompute for TOPLOC, §2.3.1).

use std::rc::Rc;

use super::client::{first_f32, lit_f32, lit_i32, scalar_f32, scalar_i32, scalar_u32, to_f32, Runtime};
use super::scheduler::{self, rollout_rng, DecodeBackend, GenRequest, GenStats, SchedSpec};
use sha2::{Digest, Sha256};

/// Per-row [`GenRequest`]s for the static reference path: stream index =
/// `stream_base + row`, prompt_key = row (no group sharing implied).
fn requests_for(prompts: &[Vec<i32>], seed: u64, stream_base: u64) -> Vec<GenRequest> {
    prompts
        .iter()
        .enumerate()
        .map(|(i, p)| GenRequest {
            prompt: p.clone(),
            rng: rollout_rng(seed, stream_base + i as u64),
            prompt_key: i as u64,
        })
        .collect()
}

/// Host-side parameter set in the canonical order of `spec.param_specs`.
#[derive(Clone)]
pub struct ParamSet {
    pub tensors: Vec<Vec<f32>>,
}

impl ParamSet {
    pub fn zeros_like(rt: &Runtime) -> ParamSet {
        ParamSet {
            tensors: rt
                .spec
                .param_specs
                .iter()
                .map(|(_, s)| vec![0.0; s.iter().product()])
                .collect(),
        }
    }

    pub fn n_params(&self) -> usize {
        self.tensors.iter().map(|t| t.len()).sum()
    }

    /// Flat little-endian f32 serialization (the SHARDCAST payload).
    pub fn to_bytes(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(self.n_params() * 4);
        for t in &self.tensors {
            let bytes: &[u8] =
                unsafe { std::slice::from_raw_parts(t.as_ptr() as *const u8, t.len() * 4) };
            out.extend_from_slice(bytes);
        }
        out
    }

    pub fn from_bytes(rt: &Runtime, bytes: &[u8]) -> anyhow::Result<ParamSet> {
        Self::from_bytes_spec(&rt.spec, bytes)
    }

    /// Deserialize against a bare spec (no runtime needed — worker threads
    /// use this on SHARDCAST payloads).
    pub fn from_bytes_spec(spec: &super::spec::ModelSpec, bytes: &[u8]) -> anyhow::Result<ParamSet> {
        anyhow::ensure!(
            bytes.len() == spec.params_bytes(),
            "param payload {} bytes, expected {}",
            bytes.len(),
            spec.params_bytes()
        );
        let mut tensors = Vec::with_capacity(spec.param_specs.len());
        let mut pos = 0;
        for (_, shape) in &spec.param_specs {
            let n: usize = shape.iter().product();
            let mut t = vec![0.0f32; n];
            let src = &bytes[pos..pos + n * 4];
            for (i, c) in src.chunks_exact(4).enumerate() {
                t[i] = f32::from_le_bytes(c.try_into().unwrap());
            }
            tensors.push(t);
            pos += n * 4;
        }
        Ok(ParamSet { tensors })
    }

    /// SHA-256 of the serialized weights (assembled-checkpoint integrity
    /// check, §2.2.3).
    pub fn checksum(&self) -> [u8; 32] {
        Sha256::digest(self.to_bytes()).into()
    }

    fn literals(&self, rt: &Runtime) -> Vec<xla::Literal> {
        self.tensors
            .iter()
            .zip(&rt.spec.param_specs)
            .map(|(t, (_, s))| lit_f32(t, s))
            .collect()
    }
}

/// Trainer-side optimizer state.
pub struct TrainState {
    pub params: ParamSet,
    pub m: ParamSet,
    pub v: ParamSet,
    pub step: u64,
}

#[derive(Clone, Copy, Debug)]
pub struct GrpoHp {
    pub lr: f32,
    pub grad_clip: f32,
    pub eps: f32,
    pub delta: f32,
    pub kl_coef: f32,
    pub ent_coef: f32,
}

impl Default for GrpoHp {
    /// Paper §4.1: eps=0.2, delta=4, ent coef 1e-4, KL coef 0.001,
    /// lr 3e-7 (we scale lr up for tiny models), grad clip 0.1.
    fn default() -> Self {
        GrpoHp { lr: 1e-4, grad_clip: 0.1, eps: 0.2, delta: 4.0, kl_coef: 0.001, ent_coef: 1e-4 }
    }
}

#[derive(Clone, Copy, Debug, Default)]
pub struct GrpoMetrics {
    pub loss: f32,
    pub gnorm: f32,
    pub clipfrac: f32,
    pub entropy: f32,
    pub kl: f32,
    pub ratio_max: f32,
    pub obj_mean: f32,
}

/// One packed training micro-batch, shapes `[batch_train, max_seq]` flat.
#[derive(Clone, Debug, Default)]
pub struct MicroBatch {
    pub tokens: Vec<i32>,
    pub segs: Vec<i32>,
    pub loss_mask: Vec<f32>,
    pub advantages: Vec<f32>,
    pub old_logprobs: Vec<f32>,
}

pub struct TrainEngine {
    rt: Rc<Runtime>,
}

impl TrainEngine {
    pub fn new(rt: Rc<Runtime>) -> TrainEngine {
        TrainEngine { rt }
    }

    pub fn rt(&self) -> &Runtime {
        &self.rt
    }

    pub fn init_state(&self, seed: u32) -> anyhow::Result<TrainState> {
        let outs = self.rt.call("init", &[scalar_u32(seed)])?;
        let tensors = outs.iter().map(to_f32).collect::<anyhow::Result<Vec<_>>>()?;
        Ok(TrainState {
            params: ParamSet { tensors },
            m: ParamSet::zeros_like(&self.rt),
            v: ParamSet::zeros_like(&self.rt),
            step: 0,
        })
    }

    fn bt_shape(&self) -> [usize; 2] {
        [self.rt.spec.batch_train, self.rt.spec.max_seq]
    }

    /// One pretraining step (next-token CE + Adam). tokens/segs are
    /// `[batch_train * max_seq]`, row-major.
    pub fn pretrain_step(
        &self,
        st: &mut TrainState,
        tokens: &[i32],
        segs: &[i32],
        lr: f32,
        grad_clip: f32,
    ) -> anyhow::Result<(f32, f32)> {
        let shape = self.bt_shape();
        let mut inputs = st.params.literals(&self.rt);
        inputs.extend(st.m.literals(&self.rt));
        inputs.extend(st.v.literals(&self.rt));
        inputs.push(scalar_f32(st.step as f32));
        inputs.push(lit_i32(tokens, &shape));
        inputs.push(lit_i32(segs, &shape));
        inputs.push(lit_f32(&[lr, grad_clip], &[2]));
        let outs = self.rt.call("pretrain_step", &inputs)?;
        let n = st.params.tensors.len();
        self.unpack_state(st, &outs, n)?;
        let loss = first_f32(&outs[3 * n])?;
        let gnorm = first_f32(&outs[3 * n + 1])?;
        st.step += 1;
        Ok((loss, gnorm))
    }

    /// One GRPO optimizer micro-step over a packed batch (paper §3.4/§4.1).
    /// `artifact` selects "grpo_step" or the Fig 11 "grpo_step_faulty".
    pub fn grpo_step_with(
        &self,
        artifact: &str,
        st: &mut TrainState,
        mb: &MicroBatch,
        hp: &GrpoHp,
    ) -> anyhow::Result<GrpoMetrics> {
        let shape = self.bt_shape();
        let hp_vec = [hp.lr, hp.grad_clip, hp.eps, hp.delta, hp.kl_coef, hp.ent_coef, 0.0, 0.0];
        let mut inputs = st.params.literals(&self.rt);
        inputs.extend(st.m.literals(&self.rt));
        inputs.extend(st.v.literals(&self.rt));
        inputs.push(scalar_f32(st.step as f32));
        inputs.push(lit_i32(&mb.tokens, &shape));
        inputs.push(lit_i32(&mb.segs, &shape));
        inputs.push(lit_f32(&mb.loss_mask, &shape));
        inputs.push(lit_f32(&mb.advantages, &shape));
        inputs.push(lit_f32(&mb.old_logprobs, &shape));
        inputs.push(lit_f32(&hp_vec, &[8]));
        let outs = self.rt.call(artifact, &inputs)?;
        let n = st.params.tensors.len();
        self.unpack_state(st, &outs, n)?;
        let m = to_f32(&outs[3 * n])?;
        st.step += 1;
        Ok(GrpoMetrics {
            loss: m[0],
            gnorm: m[1],
            clipfrac: m[2],
            entropy: m[3],
            kl: m[4],
            ratio_max: m[5],
            obj_mean: m[6],
        })
    }

    pub fn grpo_step(
        &self,
        st: &mut TrainState,
        mb: &MicroBatch,
        hp: &GrpoHp,
    ) -> anyhow::Result<GrpoMetrics> {
        self.grpo_step_with("grpo_step", st, mb, hp)
    }

    fn unpack_state(
        &self,
        st: &mut TrainState,
        outs: &[xla::Literal],
        n: usize,
    ) -> anyhow::Result<()> {
        for i in 0..n {
            st.params.tensors[i] = to_f32(&outs[i])?;
            st.m.tensors[i] = to_f32(&outs[n + i])?;
            st.v.tensors[i] = to_f32(&outs[2 * n + i])?;
        }
        Ok(())
    }

    /// Per-token logprobs + entropy under `params` (the trainer recomputes
    /// old_lp with the *current* policy at optimization start, §2.1.1).
    pub fn logprobs(
        &self,
        params: &ParamSet,
        tokens: &[i32],
        segs: &[i32],
    ) -> anyhow::Result<(Vec<f32>, Vec<f32>, Vec<f32>)> {
        let shape = self.bt_shape();
        let mut inputs = params.literals(&self.rt);
        inputs.push(lit_i32(tokens, &shape));
        inputs.push(lit_i32(segs, &shape));
        let outs = self.rt.call("logprobs", &inputs)?;
        Ok((to_f32(&outs[0])?, to_f32(&outs[1])?, to_f32(&outs[2])?))
    }
}

// ---------------------------------------------------------------------------
// Sampling (inference workers)

#[derive(Clone, Copy, Debug)]
pub struct GenOpts {
    pub max_new: usize,
    pub temperature: f32,
    /// TOPLOC hidden-state capture interval (tokens).
    pub commit_interval: usize,
}

impl Default for GenOpts {
    fn default() -> Self {
        GenOpts { max_new: 128, temperature: 1.0, commit_interval: 32 }
    }
}

#[derive(Clone, Debug, PartialEq)]
pub enum Finish {
    /// Ended on EOS; carries the model probability of EOS at that step.
    Eos { prob: f32 },
    MaxLen,
}

#[derive(Clone, Debug)]
pub struct Generation {
    /// Prompt + completion tokens (no padding; includes final EOS if any).
    pub tokens: Vec<i32>,
    pub prompt_len: usize,
    /// Model probability of each sampled completion token (TOPLOC sampling
    /// check input, §2.3.2).
    pub sampled_probs: Vec<f32>,
    /// Hidden-state rows captured every `commit_interval` positions plus at
    /// the final position: (position, hidden[d_model]).
    pub hidden_rows: Vec<(usize, Vec<f32>)>,
    pub finish: Finish,
}

impl Generation {
    pub fn completion_len(&self) -> usize {
        self.tokens.len() - self.prompt_len
    }
}

pub struct SampleEngine {
    rt: Rc<Runtime>,
    pub params: ParamSet,
    /// Count of decode_step invocations (perf accounting).
    pub steps_executed: std::sync::atomic::AtomicU64,
}

/// [`DecodeBackend`] over the AOT artifacts: a device-resident KV cache
/// threaded through `decode_step` / `prefill_kv_{T}` calls. Parameter
/// literals are built **once** per generation run and passed by reference
/// every call (the old loop cloned the full parameter set every
/// `decode_step`), and the host-side token/position buffers are reused
/// across steps.
struct EngineBackend<'a> {
    rt: &'a Runtime,
    params: Vec<xla::Literal>,
    kv: xla::Literal,
    /// decode_step's `pos` input: per-lane `i32[batch_infer]` (new
    /// contract) vs the legacy position-synchronized scalar.
    pos_per_lane: bool,
    buckets: Vec<usize>,
    steps: &'a std::sync::atomic::AtomicU64,
    posbuf: Vec<i32>,
    tokbuf: Vec<i32>,
}

impl DecodeBackend for EngineBackend<'_> {
    fn spec(&self) -> SchedSpec {
        SchedSpec::from(&self.rt.spec)
    }

    fn decode(&mut self, toks: &[i32], pos: &[usize]) -> anyhow::Result<(Vec<f32>, Vec<f32>)> {
        let b = self.rt.spec.batch_infer;
        anyhow::ensure!(toks.len() == b && pos.len() == b, "lane-shaped inputs required");
        let tok_lit = lit_i32(toks, &[b]);
        let pos_lit = if self.pos_per_lane {
            for (dst, &p) in self.posbuf.iter_mut().zip(pos) {
                *dst = p as i32;
            }
            lit_i32(&self.posbuf, &[b])
        } else {
            anyhow::ensure!(
                pos.iter().all(|&p| p == pos[0]),
                "per-lane positions need the vectored decode_step artifact (run `make artifacts`)"
            );
            scalar_i32(pos[0] as i32)
        };
        let mut refs: Vec<&xla::Literal> = Vec::with_capacity(self.params.len() + 3);
        refs.extend(self.params.iter());
        refs.push(&self.kv);
        refs.push(&tok_lit);
        refs.push(&pos_lit);
        let mut outs = self.rt.call_refs("decode_step", &refs)?;
        self.steps.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        self.kv = outs.pop().unwrap();
        Ok((to_f32(&outs[0])?, to_f32(&outs[1])?))
    }

    fn prefill_buckets(&self) -> &[usize] {
        &self.buckets
    }

    fn prefill_kv(
        &mut self,
        rows: &[&[i32]],
        t_b: usize,
        assign: &[Option<usize>],
    ) -> anyhow::Result<(Vec<f32>, Vec<f32>)> {
        let spec = &self.rt.spec;
        let (b, v, d) = (spec.batch_infer, spec.vocab, spec.d_model);
        anyhow::ensure!(!rows.is_empty() && rows.len() <= b, "prefill rows outside 1..={b}");
        anyhow::ensure!(assign.len() == b, "lane-shaped assign required");
        self.tokbuf.clear();
        self.tokbuf.resize(b * t_b, spec.pad_id);
        for (ri, r) in rows.iter().enumerate() {
            anyhow::ensure!(r.len() <= t_b, "prompt longer than bucket {t_b}");
            self.tokbuf[ri * t_b..ri * t_b + r.len()].copy_from_slice(r);
        }
        // lane_src gathers the computed row each lane's KV comes from
        // (group sharing: one forward, many lanes); lane_mask guards the
        // lanes whose caches must not be disturbed.
        let mut src = vec![0i32; b];
        let mut mask = vec![0.0f32; b];
        for (l, a) in assign.iter().enumerate() {
            if let Some(ri) = *a {
                anyhow::ensure!(ri < rows.len(), "assign row out of range");
                src[l] = ri as i32;
                mask[l] = 1.0;
            }
        }
        let tok_lit = lit_i32(&self.tokbuf, &[b, t_b]);
        let src_lit = lit_i32(&src, &[b]);
        let mask_lit = lit_f32(&mask, &[b]);
        let mut refs: Vec<&xla::Literal> = Vec::with_capacity(self.params.len() + 4);
        refs.extend(self.params.iter());
        refs.push(&self.kv);
        refs.push(&tok_lit);
        refs.push(&src_lit);
        refs.push(&mask_lit);
        let mut outs = self.rt.call_refs(&format!("prefill_kv_{t_b}"), &refs)?;
        self.kv = outs.pop().unwrap();
        let mut logits = to_f32(&outs[0])?; // [B, t_b, V]
        let mut hidden = to_f32(&outs[1])?; // [B, t_b, D]
        logits.truncate(rows.len() * t_b * v);
        hidden.truncate(rows.len() * t_b * d);
        Ok((logits, hidden))
    }
}

impl SampleEngine {
    pub fn new(rt: Rc<Runtime>, params: ParamSet) -> SampleEngine {
        SampleEngine { rt, params, steps_executed: std::sync::atomic::AtomicU64::new(0) }
    }

    pub fn rt(&self) -> &Runtime {
        &self.rt
    }

    pub fn set_params(&mut self, params: ParamSet) {
        self.params = params;
    }

    fn backend(&self) -> EngineBackend<'_> {
        let spec = &self.rt.spec;
        let (b, t, d) = (spec.batch_infer, spec.max_seq, spec.d_model);
        let kv_shape = [spec.n_layers, 2, b, t, d];
        EngineBackend {
            rt: &self.rt,
            params: self.params.literals(&self.rt),
            kv: lit_f32(&vec![0.0f32; kv_shape.iter().product()], &kv_shape),
            pos_per_lane: spec.decode_pos_per_lane(),
            buckets: spec.prefill_kv_lengths(),
            steps: &self.steps_executed,
            posbuf: vec![0i32; b],
            tokbuf: Vec::new(),
        }
    }

    /// Static-batch autoregressive generation (the `gen-refill off`
    /// reference path — [`scheduler::run_static_reference`]). Any number
    /// of prompts (chunked into `batch_infer` lanes internally); prompts
    /// must start with BOS. Row `i` samples from the per-rollout stream
    /// `rollout_rng(seed, stream_base + i)`.
    pub fn generate(
        &self,
        prompts: &[Vec<i32>],
        opts: &GenOpts,
        seed: u64,
        stream_base: u64,
    ) -> anyhow::Result<(Vec<Generation>, GenStats)> {
        let requests = requests_for(prompts, seed, stream_base);
        let mut stats = GenStats::default();
        let gens =
            scheduler::run_static_reference(&mut self.backend(), &requests, opts, &mut stats)?;
        Ok((gens, stats))
    }

    /// Continuously-batched generation ([`scheduler::run_continuous`]):
    /// prompt prefill into KV, lane refill on EOS, group-shared prompt
    /// forwards. Equivalent to [`SampleEngine::generate`] on the same
    /// request streams — bit-identical given bit-deterministic kernels;
    /// on real devices, prompt-position values agree up to
    /// prefill-vs-decode kernel rounding (absorbed by the TOPLOC
    /// tolerances). Requires the vectored-`pos` decode
    /// artifact plus the `prefill_kv_{T}` ladder
    /// (`ModelSpec::supports_continuous`; run `make artifacts`).
    pub fn generate_continuous(
        &self,
        requests: &[GenRequest],
        opts: &GenOpts,
    ) -> anyhow::Result<(Vec<Generation>, GenStats)> {
        anyhow::ensure!(
            self.rt.spec.supports_continuous(),
            "artifacts predate continuous batching: decode_step pos must be [batch_infer] and \
             a prefill_kv ladder must be shipped (run `make artifacts`)"
        );
        let mut stats = GenStats::default();
        let gens = scheduler::run_continuous(&mut self.backend(), requests, opts, &mut stats)?;
        Ok((gens, stats))
    }

    /// Validator prefill: full-sequence logits + hidden states in one call
    /// (this is why verification runs ~sequence-length× faster than
    /// generation, §2.3 / Fig 3). `sequences` are padded to `[B, T]`.
    pub fn prefill(&self, tokens: &[i32]) -> anyhow::Result<(Vec<f32>, Vec<f32>)> {
        let spec = &self.rt.spec;
        let shape = [spec.batch_infer, spec.max_seq];
        let mut inputs = self.params.literals(&self.rt);
        inputs.push(lit_i32(tokens, &shape));
        let outs = self.rt.call("prefill", &inputs)?;
        Ok((to_f32(&outs[0])?, to_f32(&outs[1])?))
    }

    /// Length-bucketed validator prefill: `tokens` is row-major
    /// `[rows, seq_len]` with `rows <= batch_infer` and
    /// `seq_len <= max_seq`. Picks the cheapest compiled `prefill_{T}`
    /// artifact with `T >= seq_len` (falling back to the full
    /// `[batch_infer, max_seq]` frame when no bucketed artifacts are
    /// shipped — packing across submissions still wins there by filling
    /// all lanes), pads rows into that frame and returns
    /// `(logits, hidden, stride)`: row `i`'s positions start at
    /// `i * stride` rows of `vocab` / `d_model` respectively. Rows are
    /// causal and independent, so lane position and co-tenants never
    /// change a row's outputs; a bucketed artifact can differ from the
    /// full frame only by kernel-shape fp rounding, which the TOPLOC
    /// tolerances absorb.
    pub fn prefill_rows(
        &self,
        tokens: &[i32],
        rows: usize,
        seq_len: usize,
    ) -> anyhow::Result<(Vec<f32>, Vec<f32>, usize)> {
        let spec = &self.rt.spec;
        let b = spec.batch_infer;
        anyhow::ensure!((1..=b).contains(&rows), "prefill rows {rows} outside 1..={b}");
        anyhow::ensure!(
            (1..=spec.max_seq).contains(&seq_len),
            "prefill seq_len {seq_len} outside 1..={}",
            spec.max_seq
        );
        anyhow::ensure!(
            tokens.len() == rows * seq_len,
            "prefill tokens {} != rows*seq_len {}",
            tokens.len(),
            rows * seq_len
        );
        let (artifact, t) = spec.prefill_artifact_for(seq_len)?;
        let mut padded = vec![spec.pad_id; b * t];
        for r in 0..rows {
            padded[r * t..r * t + seq_len].copy_from_slice(&tokens[r * seq_len..(r + 1) * seq_len]);
        }
        let mut inputs = self.params.literals(&self.rt);
        inputs.push(lit_i32(&padded, &[b, t]));
        let outs = self.rt.call(&artifact, &inputs)?;
        Ok((to_f32(&outs[0])?, to_f32(&outs[1])?, t))
    }
}

pub fn softmax_prob(logits: &[f32], idx: usize) -> f32 {
    let max = logits.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
    let z: f64 = logits.iter().map(|&l| ((l - max) as f64).exp()).sum();
    (((logits[idx] - max) as f64).exp() / z) as f32
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn softmax_prob_normalizes() {
        let l = [0.0f32, 1.0, 2.0];
        let total: f32 = (0..3).map(|i| softmax_prob(&l, i)).sum();
        assert!((total - 1.0).abs() < 1e-5);
        assert!(softmax_prob(&l, 2) > softmax_prob(&l, 0));
    }
}
